//! End-to-end validation driver (EXPERIMENTS.md §End-to-end): exercises
//! all three layers on a realistic workload and reports the paper's
//! headline metrics.
//!
//! 1. Generate the Porto-analog trace (50K points) — L3 dataset substrate.
//! 2. Run TrueKNN vs the maxDist fixed-radius baseline on the simulated
//!    RT pipeline — the paper's Table 1/2 headline (speedup + test ratio).
//! 3. Load the AOT artifacts (L2 JAX graph wrapping the L1 Pallas
//!    kernel) through PJRT and serve batched kNN requests through the
//!    coordinator on both routes, reporting latency/throughput — proving
//!    Python never runs on the request path.
//!
//! ```bash
//! make artifacts && cargo run --release --example end_to_end
//! ```

use trueknn::coordinator::{KnnRequest, QueryMode, Service, ServiceConfig};
use trueknn::dataset::{DatasetKind, DistanceProfile};
use trueknn::index::{Backend, IndexBuilder, NeighborIndex};
use trueknn::util::{Pcg32, Stopwatch};

fn main() {
    let n = 50_000;
    let k = 5;
    println!("=== end-to-end: TrueKNN on the Porto analog, n={n}, k={k} ===\n");
    let ds = DatasetKind::Taxi.generate(n, 42);

    // ---- headline experiment: TrueKNN vs maxDist baseline -------------
    println!("[1/3] TrueKNN vs fixed-radius baseline (RT simulator, index API)");
    let mut t_index = IndexBuilder::new(Backend::TrueKnn).build(ds.points.clone());
    let t = t_index.knn(&ds.points, k);
    assert!(
        t.is_complete(k, n - 1),
        "TrueKNN must find k neighbors for every point"
    );
    assert_eq!(t_index.build_stats().counters.builds, 1);
    let prof = DistanceProfile::compute(&ds, k);
    let mut b_index = IndexBuilder::new(Backend::FixedRadius)
        .radius(prof.max_dist() as f32 * 1.0001)
        .build(ds.points.clone());
    let b = b_index.knn(&ds.points, k);
    println!(
        "  TrueKNN : {:>10} ray-sphere tests, {} rounds, sim {:.3}s, wall {:.3}s",
        t.counters.prim_tests,
        t.rounds.len(),
        t.sim_seconds,
        t.wall_seconds
    );
    println!(
        "  baseline: {:>10} ray-sphere tests, maxDist={:.4}, sim {:.3}s, wall {:.3}s",
        b.counters.prim_tests,
        prof.max_dist(),
        b.sim_seconds,
        b.wall_seconds
    );
    println!(
        "  headline: speedup {:.1}x (sim), test ratio {:.1}x\n",
        b.sim_seconds / t.sim_seconds,
        b.counters.prim_tests as f64 / t.counters.prim_tests as f64
    );

    // ---- serving: batched requests through the coordinator ------------
    println!("[2/3] coordinator serving (RT route)");
    let cfg = ServiceConfig {
        use_pjrt: true,
        ..Default::default()
    };
    let (svc, handle) = Service::start(ds.points.clone(), cfg);
    let mut rng = Pcg32::new(99);

    let run_route = |label: &str, mode: QueryMode, handle: &trueknn::coordinator::ServiceHandle| {
        let n_req = 32;
        let qpr = 64;
        let sw = Stopwatch::start();
        let rxs: Vec<_> = (0..n_req)
            .map(|id| {
                let mut local = Pcg32::new(id as u64 * 7 + 1);
                let queries: Vec<_> = (0..qpr)
                    .map(|_| ds.points[local.below_usize(ds.len())])
                    .collect();
                handle
                    .submit(KnnRequest::new(id as u64, queries, k).with_mode(mode))
                    .expect("submit")
            })
            .collect();
        let mut lat_sum = 0.0;
        let mut served = 0usize;
        for rx in rxs {
            let resp = rx.recv().expect("recv");
            assert!(resp.neighbors.iter().all(|nb| nb.len() == k));
            lat_sum += resp.latency_seconds;
            served += resp.neighbors.len();
        }
        let wall = sw.elapsed_secs();
        println!(
            "  {label:<10} {served} queries in {:.3}s -> {:>7.0} q/s, mean latency {:.2}ms",
            wall,
            served as f64 / wall,
            lat_sum / n_req as f64 * 1e3
        );
        served
    };

    let _ = rng.next_u32();
    let served_rt = run_route("RT route", QueryMode::Rt, &handle);

    println!("[3/3] coordinator serving (PJRT brute route — L1 Pallas kernel via L2 HLO)");
    let served_brute = run_route("PJRT route", QueryMode::Brute, &handle);

    let m = handle.metrics().snapshot();
    println!(
        "\nservice metrics: requests={} responses={} batches={} rt={} brute={} rejected={} builds={}",
        m.requests, m.responses, m.batches, m.rt_requests, m.brute_requests, m.rejected, m.builds
    );
    assert!(
        m.builds <= 2,
        "one index per served route path — builds must not scale with batches"
    );
    svc.shutdown();

    assert_eq!(served_rt, served_brute);
    println!("\nend_to_end OK");
}
