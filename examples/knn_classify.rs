//! kNN classification — the canonical application from the paper's §2.1:
//! "a query point can be classified into the same class as a majority of
//! its neighbors".
//!
//! Builds a labeled 3-cluster dataset, splits train/test, classifies the
//! test points by majority vote over TrueKNN neighbors (served through
//! the coordinator), and reports accuracy with k = √N like the paper's
//! classifier-oriented k choice.
//!
//! ```bash
//! cargo run --release --example knn_classify
//! ```

use trueknn::coordinator::{KnnRequest, Service, ServiceConfig};
use trueknn::geom::Point3;
use trueknn::util::Pcg32;

fn make_labeled(n: usize, rng: &mut Pcg32) -> (Vec<Point3>, Vec<u8>) {
    // three anisotropic Gaussian classes with mild overlap
    let centers = [
        Point3::new(0.25, 0.25, 0.3),
        Point3::new(0.75, 0.4, 0.6),
        Point3::new(0.45, 0.8, 0.4),
    ];
    let spread = [0.09f32, 0.07, 0.08];
    let mut pts = Vec::with_capacity(n);
    let mut labels = Vec::with_capacity(n);
    for _ in 0..n {
        let c = rng.below(3) as usize;
        pts.push(Point3::new(
            centers[c].x + rng.normal() * spread[c],
            centers[c].y + rng.normal() * spread[c],
            centers[c].z + rng.normal() * spread[c],
        ));
        labels.push(c as u8);
    }
    (pts, labels)
}

fn main() {
    let mut rng = Pcg32::new(2023);
    let (train, train_labels) = make_labeled(8_000, &mut rng);
    let (test, test_labels) = make_labeled(1_000, &mut rng);
    let k = (train.len() as f64).sqrt() as usize; // paper's classifier k

    println!(
        "kNN classifier: {} train / {} test points, k={k}",
        train.len(),
        test.len()
    );

    // serve the queries through the coordinator (batched)
    let (svc, handle) = Service::start(train.clone(), ServiceConfig::default());
    let mut correct = 0usize;
    let chunk = 128;
    let mut rxs = Vec::new();
    for (i, queries) in test.chunks(chunk).enumerate() {
        rxs.push(
            handle
                .submit(KnnRequest::new(i as u64, queries.to_vec(), k))
                .expect("submit"),
        );
    }
    let mut idx = 0usize;
    for rx in rxs {
        let resp = rx.recv().expect("response");
        for nb in &resp.neighbors {
            let mut votes = [0usize; 3];
            for h in nb {
                votes[train_labels[h.idx as usize] as usize] += 1;
            }
            let pred = votes
                .iter()
                .enumerate()
                .max_by_key(|(_, v)| **v)
                .map(|(c, _)| c as u8)
                .unwrap();
            if pred == test_labels[idx] {
                correct += 1;
            }
            idx += 1;
        }
    }
    let m = handle.metrics().snapshot();
    svc.shutdown();
    println!(
        "served {} batches with {} index build(s) — the BVH amortizes across the test set",
        m.batches, m.builds
    );

    let acc = correct as f64 / test.len() as f64;
    println!("accuracy: {acc:.3} ({correct}/{})", test.len());
    // clusters overlap mildly; majority vote should stay far above chance
    assert!(acc > 0.9, "accuracy {acc} too low");
    println!("OK");
}
