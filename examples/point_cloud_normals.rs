//! Surface-normal estimation for a LiDAR point cloud — one of the kNN
//! applications the paper's introduction motivates (point-cloud
//! processing, [26] in the paper).
//!
//! For every point: find its k nearest neighbors with TrueKNN, fit a
//! plane (PCA via the covariance's smallest eigenvector, computed with
//! inverse power iteration), and report normal quality statistics.
//!
//! ```bash
//! cargo run --release --example point_cloud_normals
//! ```

use trueknn::dataset::DatasetKind;
use trueknn::geom::Point3;
use trueknn::index::{Backend, IndexBuilder, NeighborIndex};
use trueknn::util::Stopwatch;

/// Smallest-eigenvector of a 3x3 symmetric covariance via inverse power
/// iteration with Tikhonov shift (plenty for plane fitting).
fn plane_normal(pts: &[Point3]) -> Point3 {
    let n = pts.len() as f32;
    let mut c = Point3::ZERO;
    for &p in pts {
        c = c + p;
    }
    c = c / n;
    // covariance (upper triangle)
    let (mut xx, mut xy, mut xz, mut yy, mut yz, mut zz) = (0.0, 0.0, 0.0, 0.0, 0.0, 0.0);
    for &p in pts {
        let d = p - c;
        xx += d.x * d.x;
        xy += d.x * d.y;
        xz += d.x * d.z;
        yy += d.y * d.y;
        yz += d.y * d.z;
        zz += d.z * d.z;
    }
    // power iteration on (C + eps I)^-1 ~ iterate v <- solve(C+eps, v)
    let eps = (xx + yy + zz) * 1e-4 / 3.0 + 1e-12;
    let a = [[xx + eps, xy, xz], [xy, yy + eps, yz], [xz, yz, zz + eps]];
    let mut v = Point3::new(0.577, 0.577, 0.577);
    for _ in 0..20 {
        v = solve3(&a, v).normalized();
    }
    v
}

/// Solve A x = b for symmetric positive-definite 3x3 A (Cramer).
fn solve3(a: &[[f32; 3]; 3], b: Point3) -> Point3 {
    let det = |m: &[[f32; 3]; 3]| -> f32 {
        m[0][0] * (m[1][1] * m[2][2] - m[1][2] * m[2][1])
            - m[0][1] * (m[1][0] * m[2][2] - m[1][2] * m[2][0])
            + m[0][2] * (m[1][0] * m[2][1] - m[1][1] * m[2][0])
    };
    let d = det(a);
    if d.abs() < 1e-20 {
        return b;
    }
    let col = |m: &[[f32; 3]; 3], i: usize, v: Point3| -> [[f32; 3]; 3] {
        let mut out = *m;
        out[0][i] = v.x;
        out[1][i] = v.y;
        out[2][i] = v.z;
        out
    };
    Point3::new(
        det(&col(a, 0, b)) / d,
        det(&col(a, 1, b)) / d,
        det(&col(a, 2, b)) / d,
    )
}

fn main() {
    let n = 20_000;
    let k = 12;
    let ds = DatasetKind::Lidar.generate(n, 7);
    println!("estimating surface normals for {n} LiDAR-like points (k={k})");

    let sw = Stopwatch::start();
    let mut index = IndexBuilder::new(Backend::TrueKnn).build(ds.points.clone());
    let knn = index.knn(&ds.points, k);
    let knn_s = sw.elapsed_secs();

    let sw = Stopwatch::start();
    let mut normals = Vec::with_capacity(n);
    let mut degenerate = 0usize;
    for (i, nb) in knn.neighbors.iter().enumerate() {
        let mut patch: Vec<Point3> = nb.iter().map(|h| ds.points[h.idx as usize]).collect();
        patch.push(ds.points[i]);
        let normal = plane_normal(&patch);
        if normal.norm() < 0.5 {
            degenerate += 1;
        }
        normals.push(normal);
    }
    let fit_s = sw.elapsed_secs();

    // quality proxy: normals on a scanned surface should be locally
    // consistent — mean |cos| between a point's normal and its nearest
    // neighbor's normal
    let mut coherence = 0.0f64;
    for (i, nb) in knn.neighbors.iter().enumerate() {
        if let Some(first) = nb.first() {
            coherence += normals[i].dot(normals[first.idx as usize]).abs() as f64;
        }
    }
    coherence /= n as f64;

    println!(
        "kNN: {} rounds, {} ray-sphere tests, {:.3}s wall",
        knn.rounds.len(),
        knn.counters.prim_tests,
        knn_s
    );
    println!("plane fits: {:.3}s ({degenerate} degenerate patches)", fit_s);
    println!("normal coherence (mean |cos| vs nearest neighbor): {coherence:.3}");
    assert!(coherence > 0.7, "normals should be locally consistent");
    println!("OK");
}
