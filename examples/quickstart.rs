//! Quickstart: build a `NeighborIndex` once, query it many times, and
//! compare TrueKNN against the paper's fixed-radius baseline.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use trueknn::dataset::{DatasetKind, DistanceProfile};
use trueknn::index::{Backend, IndexBuilder, NeighborIndex};

fn main() {
    // 1. A Porto-like point cloud: dense city core + GPS outliers.
    let ds = DatasetKind::Taxi.generate(10_000, 42);
    let k = 5;

    // 2. Build a TrueKNN index ONCE. No radius needed — it samples a
    //    start radius (Alg. 2) at build time and grows per query.
    let mut index = IndexBuilder::new(Backend::TrueKnn)
        .seed(42)
        .build(ds.points.clone());

    // 3. Query it MANY times: the BVH is built exactly once and only
    //    refit between calls — the serving-side version of the paper's
    //    amortization argument.
    let result = index.knn(&ds.points, k);
    println!("TrueKNN found {k} neighbors for all {} points:", ds.len());
    println!(
        "  rounds={} ray-sphere tests={} simulated GPU time={:.4}s wall={:.4}s",
        result.rounds.len(),
        result.counters.prim_tests,
        result.sim_seconds,
        result.wall_seconds
    );
    let again = index.knn(&ds.points, 16); // new k, same structure
    let near = index.range(&ds.points[..4], 0.02); // range query, same structure
    let stats = index.build_stats();
    println!(
        "  three queries, {} BVH build(s) (start radius {:.5})",
        stats.counters.builds,
        stats.start_radius.unwrap()
    );
    assert_eq!(stats.counters.builds, 1, "the structure must be reused");
    assert!(again.is_complete(16, ds.len() - 1));
    println!(
        "  range r=0.02 around point 0: {} neighbors",
        near.neighbors[0].len()
    );

    // 4. The baseline backend needs the a-priori-unknowable maxDist
    //    radius (paper §5.2.1 grants it that best case; it still loses).
    let prof = DistanceProfile::compute(&ds, k);
    let mut baseline = IndexBuilder::new(Backend::FixedRadius)
        .radius(prof.max_dist() as f32 * 1.0001)
        .build(ds.points.clone());
    let base = baseline.knn(&ds.points, k);
    println!("Fixed-radius RT-kNNS baseline at radius {:.4}:", prof.max_dist());
    println!(
        "  ray-sphere tests={} simulated GPU time={:.4}s",
        base.counters.prim_tests, base.sim_seconds
    );
    println!(
        "TrueKNN speedup: {:.1}x (intersection-test ratio {:.1}x)",
        base.sim_seconds / result.sim_seconds,
        base.counters.prim_tests as f64 / result.counters.prim_tests as f64
    );

    // 5. Results are exact: first query's neighbors.
    print!("point 0 neighbors:");
    for n in &result.neighbors[0] {
        print!(" ({}, {:.4})", n.idx, n.dist);
    }
    println!();

    // Migrating from the old free functions? Each maps to a backend:
    //   knn::trueknn            -> Backend::TrueKnn
    //   knn::fixed_radius_knns  -> Backend::FixedRadius
    //   knn::rtnn::rtnn_knns    -> Backend::Rtnn
    //   KdTree::knn             -> Backend::KdTree
    //   knn::brute::brute_knn   -> Backend::BruteCpu
    //   runtime::PjrtBruteForce -> Backend::BrutePjrt
    // The free functions still work; they now build a throwaway index
    // per call — hold an index to stop paying that build.
}
