//! Quickstart: run TrueKNN on a synthetic point cloud and compare it
//! against the paper's fixed-radius baseline.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use trueknn::dataset::{DatasetKind, DistanceProfile};
use trueknn::knn::{fixed_radius_knns, trueknn as trueknn_search, FixedRadiusParams, TrueKnnParams};

fn main() {
    // 1. A Porto-like point cloud: dense city core + GPS outliers.
    let ds = DatasetKind::Taxi.generate(10_000, 42);
    let k = 5;

    // 2. TrueKNN: no radius needed — it samples a start radius and grows.
    let result = trueknn_search(&ds.points, &ds.points, &TrueKnnParams { k, ..Default::default() });
    println!("TrueKNN found {k} neighbors for all {} points:", ds.len());
    println!(
        "  rounds={} ray-sphere tests={} simulated GPU time={:.4}s wall={:.4}s",
        result.rounds.len(),
        result.counters.prim_tests,
        result.sim_seconds,
        result.wall_seconds
    );

    // 3. The baseline needs the a-priori-unknowable maxDist radius
    //    (paper §5.2.1 grants it that best case; it still loses).
    let prof = DistanceProfile::compute(&ds, k);
    let baseline = fixed_radius_knns(
        &ds.points,
        &ds.points,
        &FixedRadiusParams {
            k,
            radius: prof.max_dist() as f32 * 1.0001,
            ..Default::default()
        },
    );
    println!("Fixed-radius RT-kNNS baseline at radius {:.4}:", prof.max_dist());
    println!(
        "  ray-sphere tests={} simulated GPU time={:.4}s",
        baseline.counters.prim_tests, baseline.sim_seconds
    );
    println!(
        "TrueKNN speedup: {:.1}x (intersection-test ratio {:.1}x)",
        baseline.sim_seconds / result.sim_seconds,
        baseline.counters.prim_tests as f64 / result.counters.prim_tests as f64
    );

    // 4. Results are exact: first query's neighbors.
    print!("point 0 neighbors:");
    for n in &result.neighbors[0] {
        print!(" ({}, {:.4})", n.idx, n.dist);
    }
    println!();
}
