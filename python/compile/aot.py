"""AOT lowering: JAX (L2) + Pallas (L1) -> HLO text artifacts for Rust.

HLO *text* is the interchange format, not serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Run once via `make artifacts`; Python never executes on the query path.

Usage: python -m compile.aot --out ../artifacts
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# (Q, N, k) variants compiled ahead of time. The coordinator pads any
# request batch up to the nearest variant. Block sizes (128, 256) bound
# the valid shapes: Q % 128 == 0, N % 256 == 0.
VARIANTS = [
    (128, 1024, 32),
    (128, 4096, 32),
    (256, 16384, 32),
]
RADIUS_VARIANTS = [
    (128, 4096),
]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (with return_tuple)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_knn(q: int, n: int, k: int) -> str:
    spec_q = jax.ShapeDtypeStruct((q, 3), jnp.float32)
    spec_d = jax.ShapeDtypeStruct((n, 3), jnp.float32)
    lowered = jax.jit(
        lambda a, b: model.brute_knn_tuple(a, b, k)
    ).lower(spec_q, spec_d)
    return to_hlo_text(lowered)


def lower_radius_count(q: int, n: int) -> str:
    spec_q = jax.ShapeDtypeStruct((q, 3), jnp.float32)
    spec_d = jax.ShapeDtypeStruct((n, 3), jnp.float32)
    spec_r = jax.ShapeDtypeStruct((), jnp.float32)
    lowered = jax.jit(model.radius_count).lower(spec_q, spec_d, spec_r)
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts", help="artifact dir")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest = {"pad_sentinel": model.PAD_SENTINEL, "artifacts": []}

    for (q, n, k) in VARIANTS:
        name = f"brute_knn_q{q}_n{n}_k{k}"
        path = os.path.join(args.out, f"{name}.hlo.txt")
        text = lower_knn(q, n, k)
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"].append({
            "name": name,
            "kind": "brute_knn",
            "q": q, "n": n, "k": k,
            "file": os.path.basename(path),
        })
        print(f"wrote {path} ({len(text)} chars)")

    for (q, n) in RADIUS_VARIANTS:
        name = f"radius_count_q{q}_n{n}"
        path = os.path.join(args.out, f"{name}.hlo.txt")
        text = lower_radius_count(q, n)
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"].append({
            "name": name,
            "kind": "radius_count",
            "q": q, "n": n, "k": 0,
            "file": os.path.basename(path),
        })
        print(f"wrote {path} ({len(text)} chars)")

    mpath = os.path.join(args.out, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {mpath} ({len(manifest['artifacts'])} artifacts)")


if __name__ == "__main__":
    main()
