"""Layer-1 Pallas kernels (build-time only; never imported at runtime).

`pairwise` holds the tiled squared-distance kernel — the TPU adaptation
of the paper's software ray-sphere intersection hot loop (DESIGN.md §10).
`ref` holds the pure-jnp oracles the kernels are validated against.
"""

from . import pairwise, ref  # noqa: F401
