"""Layer-1 Pallas kernel: tiled pairwise squared distances.

Hardware adaptation (DESIGN.md §2, §10): the paper's software hot loop is
the ray-sphere test — a squared-distance comparison executed per
(query, candidate) pair on CUDA shader cores. On TPU the same computation
is reshaped for the MXU systolic array using

    ||q - d||^2 = ||q||^2 + ||d||^2 - 2 * (Q @ D^T)

so the inner loop is a [BQ, 3] x [3, BN] matmul instead of elementwise
lane work, and `BlockSpec` expresses the HBM->VMEM staging that the CUDA
version expressed with threadblock shared-memory tiles.

`interpret=True` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO, which both the pytest
oracle checks and the Rust runtime execute. Real-TPU tile-size estimates
live in DESIGN.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes. BQ x BN f32 accumulator = 128*256*4 = 128 KiB which
# sits comfortably in a TPU core's ~16 MiB VMEM alongside the two point
# tiles (3-wide, negligible) and double-buffering headroom.
BLOCK_Q = 128
BLOCK_N = 256


def _dist2_kernel(q_ref, d_ref, o_ref):
    """One [BQ, BN] output tile.

    q_ref: [BQ, 3] query tile (VMEM)
    d_ref: [BN, 3] data tile (VMEM)
    o_ref: [BQ, BN] squared distances (VMEM)
    """
    q = q_ref[...]
    d = d_ref[...]
    qn = jnp.sum(q * q, axis=1, keepdims=True)          # [BQ, 1]
    dn = jnp.sum(d * d, axis=1, keepdims=True).T        # [1, BN]
    # MXU-shaped inner product; accumulate in f32 even for bf16 inputs.
    cross = jax.lax.dot_general(
        q, d,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                   # [BQ, BN]
    # clamp: catastrophic cancellation can give tiny negatives
    o_ref[...] = jnp.maximum(qn + dn - 2.0 * cross, 0.0)


@functools.partial(jax.jit, static_argnames=("block_q", "block_n"))
def pairwise_dist2(q: jax.Array, d: jax.Array,
                   block_q: int = BLOCK_Q, block_n: int = BLOCK_N) -> jax.Array:
    """Tiled squared distances, [Q, 3] x [N, 3] -> [Q, N] (f32).

    Q and N must be multiples of the block sizes (aot.py pads); the
    hypothesis sweep uses `pairwise_dist2_padded` for arbitrary shapes.
    """
    nq, _ = q.shape
    nd, _ = d.shape
    assert nq % block_q == 0, f"Q={nq} not a multiple of {block_q}"
    assert nd % block_n == 0, f"N={nd} not a multiple of {block_n}"
    grid = (nq // block_q, nd // block_n)
    return pl.pallas_call(
        _dist2_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_q, 3), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, 3), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_q, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((nq, nd), jnp.float32),
        interpret=True,
    )(q.astype(jnp.float32), d.astype(jnp.float32))


def pad_rows(x: jax.Array, multiple: int, fill: float) -> jax.Array:
    """Pad the leading dim up to a multiple; fill rows sort last in kNN."""
    n = x.shape[0]
    rem = (-n) % multiple
    if rem == 0:
        return x
    pad = jnp.full((rem,) + x.shape[1:], fill, dtype=x.dtype)
    return jnp.concatenate([x, pad], axis=0)


def pairwise_dist2_padded(q: jax.Array, d: jax.Array,
                          block_q: int = BLOCK_Q, block_n: int = BLOCK_N) -> jax.Array:
    """Arbitrary-shape wrapper: pad to tile multiples, then slice back."""
    nq, nd = q.shape[0], d.shape[0]
    qp = pad_rows(q, block_q, 0.0)
    dp = pad_rows(d, block_n, 0.0)
    out = pairwise_dist2(qp, dp, block_q=block_q, block_n=block_n)
    return out[:nq, :nd]
