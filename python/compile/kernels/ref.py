"""Pure-jnp oracles for the Pallas kernels.

These are the ground truth for pytest/hypothesis correctness sweeps and
double as the naive (unfused, O(Q*N*3) memory) implementation whose
roofline the kernel is compared against in DESIGN.md §Perf.
"""

import jax.numpy as jnp
import jax


def pairwise_dist2_ref(q: jax.Array, d: jax.Array) -> jax.Array:
    """Squared Euclidean distances, [Q, 3] x [N, 3] -> [Q, N].

    Broadcasting form: materializes the [Q, N, 3] difference tensor, so
    it is memory-bound — exactly what the MXU-shaped kernel avoids.
    """
    diff = q[:, None, :] - d[None, :, :]
    return jnp.sum(diff * diff, axis=-1)


def knn_ref(q: jax.Array, d: jax.Array, k: int):
    """Exact brute-force kNN: (distances [Q, k], indices [Q, k])."""
    d2 = pairwise_dist2_ref(q, d)
    neg, idx = jax.lax.top_k(-d2, k)
    return jnp.sqrt(jnp.maximum(-neg, 0.0)), idx


def radius_count_ref(q: jax.Array, d: jax.Array, r) -> jax.Array:
    """Number of data points within radius r of each query, [Q]."""
    d2 = pairwise_dist2_ref(q, d)
    return jnp.sum(d2 <= r * r, axis=1).astype(jnp.int32)
