"""Layer-2 JAX compute graphs, built on the Layer-1 Pallas kernel.

Two graphs are AOT-lowered for the Rust runtime:

- `brute_knn`: the cuML-analog brute-force kNN (paper Fig 4's baseline):
  tiled pairwise distances (Pallas) + per-query top-k selection. The
  Rust coordinator routes dense batches here.
- `radius_count`: per-query candidate counts within a radius — the
  coordinator's workload estimator (used to predict round cost before
  committing a batch to the RT path).

Both functions take fixed shapes at lowering time; `aot.py` emits one
artifact per (Q, N, k) variant plus a manifest the Rust side reads.
Data-point padding uses the `PAD_SENTINEL` coordinate so padded rows sort
strictly last and can never displace a real neighbor.
"""

import jax
import jax.numpy as jnp

from .kernels import pairwise

# Padded data rows live at this coordinate: dist^2 ~ 3e18 (finite in f32,
# far above any real squared distance in normalized clouds).
PAD_SENTINEL = 1e9


def brute_knn(q: jax.Array, d: jax.Array, k: int):
    """Exact brute-force kNN over fixed shapes.

    Returns (dists [Q, k] f32 ascending, idx [Q, k] i32).

    Top-k is expressed as a full key-value sort + slice rather than
    `lax.top_k`: jax >= 0.6 lowers top_k to a `topk(..., largest=true)`
    HLO op whose text form the xla_extension 0.5.1 parser (the Rust
    runtime) rejects; `sort` round-trips cleanly.
    """
    d2 = pairwise.pairwise_dist2(q, d)
    d2_k, idx_k = _partial_topk_min(d2, k)
    dists = jnp.sqrt(jnp.maximum(d2_k, 0.0))
    return dists, idx_k.astype(jnp.int32)


def _partial_topk_min(d2: jax.Array, k: int, block: int = 128):
    """Exact k smallest per row via two-stage hierarchical selection.

    A full [Q, N] row sort costs N·log N comparator stages; since k ≤ 32
    and N goes to 16384+, we sort fixed-size blocks (N·log(block)), keep
    each block's k best (a superset of the global k best — §Perf L2
    optimization, ~4x faster than the full sort), then sort only the
    surviving candidates.
    """
    qn, n = d2.shape
    if n <= block or k >= block:
        iota = jax.lax.broadcasted_iota(jnp.int32, d2.shape, 1)
        d2s, ids = jax.lax.sort_key_val(d2, iota, dimension=1)
        return d2s[:, :k], ids[:, :k]
    assert n % block == 0, f"N={n} not a multiple of block={block}"
    nb = n // block
    iota = jax.lax.broadcasted_iota(jnp.int32, d2.shape, 1)
    d2b = d2.reshape(qn, nb, block)
    ib = iota.reshape(qn, nb, block)
    d2s, ids = jax.lax.sort_key_val(d2b, ib, dimension=2)
    cand_d = d2s[:, :, :k].reshape(qn, nb * k)
    cand_i = ids[:, :, :k].reshape(qn, nb * k)
    cd, ci = jax.lax.sort_key_val(cand_d, cand_i, dimension=1)
    return cd[:, :k], ci[:, :k]


def radius_count(q: jax.Array, d: jax.Array, r: jax.Array):
    """Candidates within radius r (scalar) of each query: [Q] i32."""
    d2 = pairwise.pairwise_dist2(q, d)
    return (jnp.sum(d2 <= r * r, axis=1).astype(jnp.int32),)


def brute_knn_tuple(q, d, k: int):
    """Tuple-returning wrapper (jax.jit output must be a tuple for the
    HLO-text interchange, see aot.py)."""
    dists, idx = brute_knn(q, d, k)
    return (dists, idx)
