"""AOT lowering smoke tests: HLO text emission and manifest integrity.

The full `make artifacts` output is exercised end-to-end by the Rust
runtime integration tests; here we verify the lowering path itself.
"""

import json
import subprocess
import sys
import os

import pytest

from compile import aot


class TestLowering:
    def test_knn_lowers_to_hlo_text(self):
        text = aot.lower_knn(128, 1024, 8)
        assert "ENTRY" in text
        assert "f32[128,1024]" in text  # the distance matrix
        # top-k output shapes present
        assert "f32[128,8]" in text
        assert "s32[128,8]" in text

    def test_radius_count_lowers(self):
        text = aot.lower_radius_count(128, 1024)
        assert "ENTRY" in text
        assert "s32[128]" in text

    def test_no_mosaic_custom_calls(self):
        # interpret=True must keep the kernel executable on CPU PJRT:
        # a Mosaic custom-call in the HLO would break the Rust runtime
        text = aot.lower_knn(128, 1024, 8)
        assert "tpu_custom_call" not in text
        assert "mosaic" not in text.lower()


class TestMainOutput:
    @pytest.fixture(scope="class")
    def outdir(self, tmp_path_factory):
        out = tmp_path_factory.mktemp("artifacts")
        env = dict(os.environ)
        subprocess.run(
            [sys.executable, "-m", "compile.aot", "--out", str(out)],
            check=True,
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
            env=env,
        )
        return out

    def test_manifest_lists_every_file(self, outdir):
        manifest = json.loads((outdir / "manifest.json").read_text())
        assert len(manifest["artifacts"]) == len(aot.VARIANTS) + len(aot.RADIUS_VARIANTS)
        for entry in manifest["artifacts"]:
            f = outdir / entry["file"]
            assert f.exists(), entry
            assert f.stat().st_size > 1000

    def test_manifest_variant_fields(self, outdir):
        manifest = json.loads((outdir / "manifest.json").read_text())
        kinds = {e["kind"] for e in manifest["artifacts"]}
        assert kinds == {"brute_knn", "radius_count"}
        for e in manifest["artifacts"]:
            assert e["q"] > 0 and e["n"] > 0
