"""L1 correctness: the Pallas pairwise-distance kernel vs the pure-jnp
oracle, including a hypothesis sweep over shapes and dtypes."""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile.kernels import pairwise, ref


def rand(shape, seed, dtype=np.float32, scale=1.0):
    rng = np.random.default_rng(seed)
    return (rng.standard_normal(shape) * scale).astype(dtype)


class TestPairwiseAligned:
    def test_matches_ref_exact_shapes(self):
        q = rand((128, 3), 0)
        d = rand((256, 3), 1)
        got = pairwise.pairwise_dist2(q, d)
        want = ref.pairwise_dist2_ref(q, d)
        assert got.shape == (128, 256)
        assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_multi_tile_grid(self):
        q = rand((256, 3), 2)
        d = rand((1024, 3), 3)
        got = pairwise.pairwise_dist2(q, d)
        want = ref.pairwise_dist2_ref(q, d)
        assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_rejects_misaligned_shapes(self):
        q = rand((100, 3), 4)
        d = rand((256, 3), 5)
        with pytest.raises(AssertionError):
            pairwise.pairwise_dist2(q, d)

    def test_zero_distance_on_diagonal(self):
        q = rand((128, 3), 6)
        got = pairwise.pairwise_dist2(q, pairwise.pad_rows(q, 256, 0.0))
        diag = np.diagonal(np.asarray(got)[:, :128])
        assert_allclose(diag, np.zeros(128), atol=1e-4)

    def test_nonnegative_everywhere(self):
        # the kernel clamps cancellation-induced negatives
        q = rand((128, 3), 7, scale=1e3)
        d = q + 1e-4
        got = pairwise.pairwise_dist2(q, pairwise.pad_rows(d, 256, 0.0))
        assert np.all(np.asarray(got) >= 0.0)

    def test_custom_block_sizes(self):
        q = rand((64, 3), 8)
        d = rand((128, 3), 9)
        got = pairwise.pairwise_dist2(q, d, block_q=32, block_n=64)
        want = ref.pairwise_dist2_ref(q, d)
        assert_allclose(got, want, rtol=1e-5, atol=1e-5)


class TestPairwisePadded:
    @hypothesis.settings(deadline=None, max_examples=25)
    @hypothesis.given(
        nq=st.integers(min_value=1, max_value=300),
        nd=st.integers(min_value=1, max_value=600),
        seed=st.integers(min_value=0, max_value=2**31),
    )
    def test_arbitrary_shapes_match_ref(self, nq, nd, seed):
        q = rand((nq, 3), seed)
        d = rand((nd, 3), seed + 1)
        got = pairwise.pairwise_dist2_padded(q, d, block_q=64, block_n=128)
        want = ref.pairwise_dist2_ref(q, d)
        assert got.shape == (nq, nd)
        assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    @hypothesis.settings(deadline=None, max_examples=10)
    @hypothesis.given(
        dtype=st.sampled_from([np.float32, np.float16, jnp.bfloat16]),
        seed=st.integers(min_value=0, max_value=2**31),
    )
    def test_dtype_sweep(self, dtype, seed):
        # inputs in any float dtype; accumulation is always f32
        q = rand((96, 3), seed).astype(dtype)
        d = rand((200, 3), seed + 1).astype(dtype)
        got = pairwise.pairwise_dist2_padded(q, d, block_q=32, block_n=64)
        want = ref.pairwise_dist2_ref(
            np.asarray(q, dtype=np.float32), np.asarray(d, dtype=np.float32)
        )
        tol = 1e-4 if dtype == np.float32 else 5e-2
        assert got.dtype == jnp.float32
        assert_allclose(got, want, rtol=tol, atol=tol)

    def test_scale_invariance_of_relative_error(self):
        for scale in [1e-3, 1.0, 1e3]:
            q = rand((40, 3), 11, scale=scale)
            d = rand((70, 3), 12, scale=scale)
            got = pairwise.pairwise_dist2_padded(q, d, block_q=32, block_n=64)
            want = ref.pairwise_dist2_ref(q, d)
            assert_allclose(got, want, rtol=1e-3)


class TestPadRows:
    def test_pads_to_multiple(self):
        x = jnp.ones((5, 3))
        p = pairwise.pad_rows(x, 8, 0.0)
        assert p.shape == (8, 3)
        assert_allclose(np.asarray(p[5:]), np.zeros((3, 3)))

    def test_noop_when_aligned(self):
        x = jnp.ones((8, 3))
        assert pairwise.pad_rows(x, 8, 0.0) is x
