"""L2 correctness: brute_knn / radius_count graphs vs oracles, padding
semantics, and top-k edge cases."""

import hypothesis
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
from numpy.testing import assert_allclose

from compile import model
from compile.kernels import ref, pairwise


def cloud(n, seed):
    rng = np.random.default_rng(seed)
    return rng.random((n, 3)).astype(np.float32)


def brute_np(q, d, k):
    d2 = ((q[:, None, :] - d[None, :, :]) ** 2).sum(-1)
    idx = np.argsort(d2, axis=1, kind="stable")[:, :k]
    return np.sqrt(np.take_along_axis(d2, idx, axis=1)), idx


class TestBruteKnn:
    def test_matches_numpy_oracle(self):
        q = cloud(128, 0)
        d = cloud(1024, 1)
        dists, idx = model.brute_knn(jnp.asarray(q), jnp.asarray(d), 8)
        nd, _ = brute_np(q, d, 8)
        assert dists.shape == (128, 8)
        assert idx.shape == (128, 8)
        assert_allclose(np.asarray(dists), nd, rtol=1e-4, atol=1e-5)

    def test_distances_ascending(self):
        q = cloud(128, 2)
        d = cloud(256, 3)
        dists, _ = model.brute_knn(jnp.asarray(q), jnp.asarray(d), 16)
        arr = np.asarray(dists)
        assert np.all(np.diff(arr, axis=1) >= -1e-6)

    def test_self_query_returns_zero_first(self):
        d = cloud(256, 4)
        dists, idx = model.brute_knn(jnp.asarray(d[:128]), jnp.asarray(d), 3)
        # the matmul expansion leaves ~1e-7 absolute fuzz in dist^2, i.e.
        # ~3e-4 after sqrt — far below the ~2e-2 nearest-other distance
        assert_allclose(np.asarray(dists)[:, 0], np.zeros(128), atol=2e-3)
        assert np.array_equal(np.asarray(idx)[:, 0], np.arange(128))

    def test_pad_sentinel_rows_never_selected(self):
        q = cloud(128, 5)
        d_real = cloud(200, 6)
        d = np.full((256, 3), model.PAD_SENTINEL, dtype=np.float32)
        d[:200] = d_real
        _, idx = model.brute_knn(jnp.asarray(q), jnp.asarray(d), 10)
        assert np.all(np.asarray(idx) < 200), "padding must sort last"

    @hypothesis.settings(deadline=None, max_examples=15)
    @hypothesis.given(
        k=st.integers(min_value=1, max_value=32),
        seed=st.integers(min_value=0, max_value=2**31),
    )
    def test_k_sweep(self, k, seed):
        q = cloud(128, seed)
        d = cloud(512, seed + 1)
        dists, _ = model.brute_knn(jnp.asarray(q), jnp.asarray(d), k)
        nd, _ = brute_np(q, d, k)
        assert_allclose(np.asarray(dists), nd, rtol=1e-3, atol=1e-3)


class TestRadiusCount:
    def test_matches_ref(self):
        q = cloud(128, 7)
        d = cloud(1024, 8)
        (got,) = model.radius_count(jnp.asarray(q), jnp.asarray(d), jnp.float32(0.3))
        want = ref.radius_count_ref(jnp.asarray(q), jnp.asarray(d), 0.3)
        assert np.array_equal(np.asarray(got), np.asarray(want))

    def test_tiny_radius_counts_self_only(self):
        # exact r=0 is not representable through the matmul expansion's
        # ~1e-7 dist^2 fuzz; a tiny-but-above-fuzz radius must count
        # exactly the point itself (random clouds have no 1e-3-neighbors)
        d = cloud(256, 9)
        (got,) = model.radius_count(jnp.asarray(d[:128]), jnp.asarray(d), jnp.float32(1e-3))
        assert np.all(np.asarray(got) == 1)

    def test_huge_radius_counts_everything(self):
        q = cloud(128, 10)
        d = cloud(512, 11)
        (got,) = model.radius_count(jnp.asarray(q), jnp.asarray(d), jnp.float32(100.0))
        assert np.all(np.asarray(got) == 512)


class TestTupleWrapper:
    def test_brute_knn_tuple_is_tuple(self):
        q = cloud(128, 12)
        d = cloud(256, 13)
        out = model.brute_knn_tuple(jnp.asarray(q), jnp.asarray(d), 4)
        assert isinstance(out, tuple) and len(out) == 2
