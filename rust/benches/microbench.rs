//! `cargo bench --bench microbench` — component-level benchmarks:
//! the §4 refit-vs-rebuild ablation, BVH builder strategies, kd-tree vs
//! RT-path query cost, heap throughput, and the PJRT brute-force path
//! (when artifacts are present).

use trueknn::bench::{bench, fmt_secs, BenchConfig, Table};
use trueknn::dataset::DatasetKind;
use trueknn::exp::{self, ExpScale};
use trueknn::index::{Backend, IndexBuilder, NeighborIndex};
use trueknn::knn::{trueknn as trueknn_search, KHeap, TrueKnnParams};
use trueknn::util::Pcg32;

fn main() {
    let cfg = BenchConfig::from_env();
    let scale = ExpScale::from_env();

    // ---- §4 ablation: refit vs rebuild --------------------------------
    let rows = exp::ablations::refit_vs_rebuild(&[10_000, 50_000, 200_000]);
    exp::ablations::render_refit(&rows).print();

    // ---- builder strategy ablation -------------------------------------
    let rows = exp::ablations::builder_ablation(scale);
    exp::ablations::render_builder(&rows).print();

    // ---- query-path microbenches ---------------------------------------
    let mut t = Table::new("component microbenches", &["component", "workload", "median"]);

    let ds = DatasetKind::Taxi.generate(20_000, 1);
    let r = bench("trueknn", &cfg, || {
        std::hint::black_box(trueknn_search(
            &ds.points,
            &ds.points,
            &TrueKnnParams {
                k: 5,
                ..Default::default()
            },
        ));
    });
    t.row(vec![
        "trueknn k=5 (one-shot shim)".into(),
        "taxi 20K".into(),
        fmt_secs(r.median_s),
    ]);

    // build-once/query-many: the index amortizes the BVH build that the
    // one-shot shim above pays on every iteration
    let mut index = IndexBuilder::new(Backend::TrueKnn)
        .exclude_self(false)
        .build(ds.points.clone());
    let batch = ds.points[..1024].to_vec();
    let r = bench("index-knn", &cfg, || {
        std::hint::black_box(index.knn(&batch, 5));
    });
    t.row(vec![
        "TrueKnn index knn 1024q (cached BVH)".into(),
        "taxi 20K".into(),
        fmt_secs(r.median_s),
    ]);
    let r = bench("index-build", &cfg, || {
        std::hint::black_box(IndexBuilder::new(Backend::TrueKnn).build(ds.points.clone()));
    });
    t.row(vec![
        "TrueKnn index build".into(),
        "taxi 20K".into(),
        fmt_secs(r.median_s),
    ]);

    let tree = trueknn::knn::kdtree::KdTree::build(&ds.points);
    let r = bench("kdtree", &cfg, || {
        for i in (0..ds.len()).step_by(10) {
            std::hint::black_box(tree.knn_excluding(ds.points[i], 5, Some(i as u32)));
        }
    });
    t.row(vec![
        "kdtree knn x2000".into(),
        "taxi 20K".into(),
        fmt_secs(r.median_s),
    ]);

    let mut rng = Pcg32::new(3);
    let vals: Vec<f32> = (0..1_000_000).map(|_| rng.f32()).collect();
    let r = bench("kheap", &cfg, || {
        let mut h = KHeap::new(32);
        for (i, &v) in vals.iter().enumerate() {
            h.push(v, i as u32);
        }
        std::hint::black_box(h.len());
    });
    t.row(vec![
        "kheap 1M pushes k=32".into(),
        "uniform".into(),
        fmt_secs(r.median_s),
    ]);

    // ---- PJRT path (requires `make artifacts`) --------------------------
    match trueknn::runtime::PjrtRuntime::load_default() {
        Ok(rt) => {
            let bf = trueknn::runtime::PjrtBruteForce::new(&rt);
            let small = DatasetKind::Uniform.generate(4_096, 2);
            let queries = small.points[..1024].to_vec();
            let r = bench("pjrt", &cfg, || {
                std::hint::black_box(bf.knn(&small.points, &queries, 5, false).unwrap());
            });
            t.row(vec![
                "pjrt brute 1024q".into(),
                "uniform 4K".into(),
                fmt_secs(r.median_s),
            ]);
            let cpu = bench("cpu-brute", &cfg, || {
                std::hint::black_box(trueknn::knn::brute::brute_knn(
                    &small.points,
                    &queries,
                    5,
                    false,
                ));
            });
            t.row(vec![
                "cpu brute 1024q".into(),
                "uniform 4K".into(),
                fmt_secs(cpu.median_s),
            ]);
        }
        Err(e) => {
            eprintln!("skipping PJRT microbench: {e}");
        }
    }

    t.print();

    // ---- PR2: parallel launch engine + shell re-query -------------------
    // (same measurements `trueknn bench` writes to BENCH_PR2.json)
    let report = trueknn::bench::pr2::run(50_000, 10_000, cfg.iters);
    trueknn::bench::pr2::render(&report).print();

    // ---- PR3: SoA leaf loop + cohort scheduling + round bookkeeping -----
    // (same measurements `trueknn bench` writes to BENCH_PR3.json)
    let report = trueknn::bench::pr3::run(50_000, 10_000, cfg.iters);
    trueknn::bench::pr3::render(&report).print();
}
