//! `cargo bench --bench paper_figures` — regenerates the paper's
//! Figures 3–9 at the configured scale.

use trueknn::configx::KPolicy;
use trueknn::exp::{self, ExpScale};
use trueknn::util::Stopwatch;

fn main() {
    let scale = ExpScale::from_env();
    println!("paper_figures @ scale {scale:?} (TRUEKNN_SCALE=full for paper sizes)");
    let total = Stopwatch::start();

    let sw = Stopwatch::start();
    let rows = exp::table1::run(scale, KPolicy::SqrtN);
    exp::figures::fig3(&rows).print();
    println!("[fig3 in {:.1}s]", sw.elapsed_secs());

    let sw = Stopwatch::start();
    let f4 = exp::figures::fig4(scale);
    exp::figures::render_fig4(&f4).print();
    println!("[fig4 in {:.1}s]", sw.elapsed_secs());

    let sw = Stopwatch::start();
    let f5 = exp::figures::fig5(scale);
    exp::figures::render_fig5(&f5, exp::workloads::mid_size(scale)).print();
    println!("[fig5 in {:.1}s]", sw.elapsed_secs());

    let sw = Stopwatch::start();
    let f6 = exp::figures::fig6(scale);
    exp::figures::render_fig6(&f6).print();
    println!("[fig6 in {:.1}s]", sw.elapsed_secs());

    let sw = Stopwatch::start();
    let f7 = exp::figures::fig7(scale);
    exp::figures::render_fig7(&f7).print();
    println!("[fig7 in {:.1}s]", sw.elapsed_secs());

    let sw = Stopwatch::start();
    let f8 = exp::figures::fig8(scale);
    exp::figures::render_pct(&f8, "Fig 8: 99th-percentile speedups (k=√N)").print();
    println!("[fig8 in {:.1}s]", sw.elapsed_secs());

    let sw = Stopwatch::start();
    let f9 = exp::figures::fig9(scale);
    exp::figures::render_pct(&f9, "Fig 9: 99th-percentile 3DIono (k=5)").print();
    println!("[fig9 in {:.1}s]", sw.elapsed_secs());

    println!("\npaper_figures done in {:.1}s", total.elapsed_secs());
}
