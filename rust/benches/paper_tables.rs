//! `cargo bench --bench paper_tables` — regenerates the paper's Tables
//! 1, 2 and 3 plus the §5.3.1 RTNN comparison at the configured scale
//! (TRUEKNN_SCALE=small|full; see DESIGN.md §6 and EXPERIMENTS.md).

use trueknn::configx::KPolicy;
use trueknn::exp::{self, ExpScale};
use trueknn::util::Stopwatch;

fn main() {
    let scale = ExpScale::from_env();
    println!("paper_tables @ scale {scale:?} (TRUEKNN_SCALE=full for paper sizes)");
    let total = Stopwatch::start();

    let sw = Stopwatch::start();
    let t1 = exp::table1::run(scale, KPolicy::SqrtN);
    exp::table1::render(&t1).print();
    println!("[table1 in {:.1}s]", sw.elapsed_secs());

    let sw = Stopwatch::start();
    let t2 = exp::table2::run(scale);
    exp::table2::render(&t2).print();
    println!("[table2 in {:.1}s]", sw.elapsed_secs());

    let sw = Stopwatch::start();
    let t3 = exp::table3::run(scale);
    exp::table3::render(&t3).print();
    println!("[table3 in {:.1}s]", sw.elapsed_secs());

    let sw = Stopwatch::start();
    let rt = exp::ablations::rtnn_cmp(scale, None);
    exp::ablations::render_rtnn(&rt).print();
    println!("[rtnn_cmp in {:.1}s]", sw.elapsed_secs());

    println!("\npaper_tables done in {:.1}s", total.elapsed_secs());
}
