//! Tiny key=value reader for `rust/lint.toml`.
//!
//! The analyzer is zero-dependency by the PR 1 manifest contract, so the
//! config file is a deliberately small subset of TOML: blank lines, `#`
//! comments, and flat `rule-id.key = v1, v2, …` assignments. Two keys
//! exist per rule:
//!
//! * `scope`  — the rule fires **only** inside these module-path
//!   prefixes (empty/absent = everywhere).
//! * `allow`  — modules whose findings for this rule are dropped
//!   (the file-level counterpart of `// lint: allow(rule)`).
//!
//! Module prefixes match whole path segments: `util::timer` covers
//! `util::timer` and `util::timer::x`, never `util::timers`.

use std::collections::BTreeMap;

/// Parsed lint configuration: per-rule module scoping and allowlists.
#[derive(Debug, Default, Clone)]
pub struct LintConfig {
    /// rule id -> module prefixes the rule is restricted to.
    scope: BTreeMap<String, Vec<String>>,
    /// rule id -> module prefixes exempt from the rule.
    allow: BTreeMap<String, Vec<String>>,
}

/// A malformed line in the config file.
#[derive(Debug)]
pub struct ConfError {
    pub line: u32,
    pub message: String,
}

impl std::fmt::Display for ConfError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "lint config line {}: {}", self.line, self.message)
    }
}

impl std::error::Error for ConfError {}

impl LintConfig {
    /// Parse the `lint.toml` subset described in the module docs.
    pub fn parse(text: &str) -> Result<LintConfig, ConfError> {
        let mut cfg = LintConfig::default();
        for (idx, raw) in text.lines().enumerate() {
            let lineno = idx as u32 + 1;
            let line = match raw.find('#') {
                Some(p) => &raw[..p],
                None => raw,
            }
            .trim();
            if line.is_empty() {
                continue;
            }
            let Some((key, value)) = line.split_once('=') else {
                return Err(ConfError {
                    line: lineno,
                    message: format!("expected `rule.key = values`, got `{raw}`"),
                });
            };
            let key = key.trim();
            let Some((rule, field)) = key.rsplit_once('.') else {
                return Err(ConfError {
                    line: lineno,
                    message: format!("key `{key}` is missing the `.scope`/`.allow` suffix"),
                });
            };
            let mods: Vec<String> = value
                .split(',')
                .map(|s| s.trim().to_string())
                .filter(|s| !s.is_empty())
                .collect();
            let slot = match field {
                "scope" => &mut cfg.scope,
                "allow" => &mut cfg.allow,
                other => {
                    return Err(ConfError {
                        line: lineno,
                        message: format!("unknown field `{other}` (expected scope or allow)"),
                    });
                }
            };
            slot.entry(rule.to_string()).or_default().extend(mods);
        }
        Ok(cfg)
    }

    /// Load and parse a config file; missing file = default (empty) config.
    pub fn load(path: &std::path::Path) -> Result<LintConfig, String> {
        match std::fs::read_to_string(path) {
            Ok(text) => LintConfig::parse(&text).map_err(|e| e.to_string()),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(LintConfig::default()),
            Err(e) => Err(format!("reading {}: {e}", path.display())),
        }
    }

    /// Does `rule` fire in `module` at all? (scope check)
    pub fn in_scope(&self, rule: &str, module: &str) -> bool {
        match self.scope.get(rule) {
            None => true,
            Some(prefixes) => prefixes.iter().any(|p| module_matches(module, p)),
        }
    }

    /// Is `module` exempt from `rule`? (allow check)
    pub fn is_allowed(&self, rule: &str, module: &str) -> bool {
        match self.allow.get(rule) {
            None => false,
            Some(prefixes) => prefixes.iter().any(|p| module_matches(module, p)),
        }
    }
}

/// Whole-segment prefix match: `util::timer` covers `util::timer` and
/// `util::timer::x` but not `util::timers`.
fn module_matches(module: &str, prefix: &str) -> bool {
    module == prefix
        || (module.len() > prefix.len()
            && module.starts_with(prefix)
            && module[prefix.len()..].starts_with("::"))
}
