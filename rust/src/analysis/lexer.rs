//! A lightweight Rust lexer for the determinism-contract analyzer.
//!
//! This is **not** a full Rust parser — it is a token stream that is
//! exact about the three things a text-level lint must never get wrong:
//!
//! 1. **String/char literals.** `"HashMap"` inside a string, `'a'` char
//!    literals vs `'a` lifetimes, raw strings (`r"…"`, `r#"…"#`), and
//!    byte/raw-byte strings all lex as single literal tokens, so a rule
//!    matching identifier sequences can never fire inside one.
//! 2. **Comments.** Line comments, nested block comments and doc
//!    comments are stripped from the token stream (commented-out code is
//!    invisible to rules) but recorded on the side: doc-comment lines
//!    feed the `pub-missing-docs` rule, and `// lint: allow(rule)`
//!    comments feed the suppression engine.
//! 3. **`#[cfg(test)]` regions.** Tokens inside a `#[cfg(test)]`-gated
//!    item (the trailing `mod tests { … }` idiom, or a single gated fn)
//!    are marked so rules that only govern shipping library code can
//!    skip them.
//!
//! Everything else is intentionally coarse: keywords are just idents,
//! multi-char operators are consecutive single-char puncts, and numeric
//! literals keep their raw text so rules can ask "is this a float?".

/// Token kind. Literals carry no content (rules never need it); idents
/// and numbers keep their text.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword (`HashMap`, `for`, `pub`, …).
    Ident,
    /// Numeric literal, raw text preserved (`0.5`, `42usize`, `0x3ff`).
    Num,
    /// String / char / byte / raw-string literal (content dropped).
    Lit,
    /// Lifetime (`'a`, `'static`).
    Life,
    /// Single punctuation character (`::` is two `:` tokens).
    Punct(char),
}

/// One token with its source line (1-based) and test-region mark.
#[derive(Clone, Debug)]
pub struct Tok {
    pub kind: TokKind,
    /// Ident/Num text; empty for literals and puncts.
    pub text: String,
    /// 1-based line the token starts on.
    pub line: u32,
    /// Inside a `#[cfg(test)]`-gated item.
    pub in_test: bool,
}

impl Tok {
    pub fn is_ident(&self, s: &str) -> bool {
        self.kind == TokKind::Ident && self.text == s
    }

    pub fn is_punct(&self, c: char) -> bool {
        self.kind == TokKind::Punct(c)
    }
}

/// One inline suppression comment: `// lint: allow(rule-a, rule-b) — why`.
/// Only plain `//` comments count; a doc comment quoting the syntax is
/// prose, never a suppression.
#[derive(Clone, Debug)]
pub struct Allow {
    /// Line the comment sits on; it suppresses this line and the next.
    pub line: u32,
    /// Rule ids listed between the parens.
    pub rules: Vec<String>,
    /// Non-empty justification text followed the closing paren.
    pub justified: bool,
}

/// Lexer output: tokens plus the comment-derived side channels.
#[derive(Debug, Default)]
pub struct Lexed {
    pub tokens: Vec<Tok>,
    /// Lines (1-based) that hold a doc comment (`///`, `//!`, `/** */`).
    pub doc_lines: Vec<u32>,
    /// Inline `lint: allow(…)` suppressions, in source order.
    pub allows: Vec<Allow>,
    /// Total lines in the file.
    pub lines: u32,
}

impl Lexed {
    pub fn is_doc_line(&self, line: u32) -> bool {
        self.doc_lines.binary_search(&line).is_ok()
    }
}

/// Lex `src` into tokens + comment side channels, then mark
/// `#[cfg(test)]` regions.
pub fn lex(src: &str) -> Lexed {
    let chars: Vec<char> = src.chars().collect();
    let mut out = Lexed::default();
    let mut line: u32 = 1;
    let mut i = 0usize;
    let n = chars.len();

    while i < n {
        let c = chars[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        // ---- comments -------------------------------------------------
        if c == '/' && i + 1 < n && chars[i + 1] == '/' {
            let start = i;
            while i < n && chars[i] != '\n' {
                i += 1;
            }
            let text: String = chars[start..i].iter().collect();
            let body = &text[2..];
            if body.starts_with('/') || body.starts_with('!') {
                // doc comments document; only plain `//` comments can
                // carry a suppression (docs quoting the syntax are prose)
                out.doc_lines.push(line);
            } else {
                record_allow(&text, line, &mut out.allows);
            }
            continue;
        }
        if c == '/' && i + 1 < n && chars[i + 1] == '*' {
            let start = i;
            let start_line = line;
            let mut depth = 1;
            i += 2;
            while i < n && depth > 0 {
                if chars[i] == '\n' {
                    line += 1;
                    i += 1;
                } else if chars[i] == '/' && i + 1 < n && chars[i + 1] == '*' {
                    depth += 1;
                    i += 2;
                } else if chars[i] == '*' && i + 1 < n && chars[i + 1] == '/' {
                    depth -= 1;
                    i += 2;
                } else {
                    i += 1;
                }
            }
            let text: String = chars[start..i.min(n)].iter().collect();
            if text.starts_with("/**") || text.starts_with("/*!") {
                for l in start_line..=line {
                    out.doc_lines.push(l);
                }
            } else {
                record_allow(&text, start_line, &mut out.allows);
            }
            continue;
        }
        // ---- raw / byte strings --------------------------------------
        if c == 'r' || c == 'b' {
            if let Some((next_i, next_line)) = try_raw_or_byte_string(&chars, i, line) {
                out.tokens.push(Tok {
                    kind: TokKind::Lit,
                    text: String::new(),
                    line,
                    in_test: false,
                });
                line = next_line;
                i = next_i;
                continue;
            }
        }
        // ---- plain strings -------------------------------------------
        if c == '"' {
            let tok_line = line;
            i += 1;
            while i < n {
                match chars[i] {
                    '\\' => i += 2,
                    '\n' => {
                        line += 1;
                        i += 1;
                    }
                    '"' => {
                        i += 1;
                        break;
                    }
                    _ => i += 1,
                }
            }
            out.tokens.push(Tok {
                kind: TokKind::Lit,
                text: String::new(),
                line: tok_line,
                in_test: false,
            });
            continue;
        }
        // ---- char literal vs lifetime --------------------------------
        if c == '\'' {
            if i + 1 < n && chars[i + 1] == '\\' {
                // escaped char literal: '\n', '\u{…}', …
                i += 2;
                while i < n && chars[i] != '\'' {
                    i += 1;
                }
                i += 1;
                out.tokens.push(Tok {
                    kind: TokKind::Lit,
                    text: String::new(),
                    line,
                    in_test: false,
                });
                continue;
            }
            if i + 2 < n && chars[i + 2] == '\'' {
                // plain char literal 'x'
                i += 3;
                out.tokens.push(Tok {
                    kind: TokKind::Lit,
                    text: String::new(),
                    line,
                    in_test: false,
                });
                continue;
            }
            // lifetime 'a / 'static
            let start = i + 1;
            i += 1;
            while i < n && (chars[i].is_alphanumeric() || chars[i] == '_') {
                i += 1;
            }
            out.tokens.push(Tok {
                kind: TokKind::Life,
                text: chars[start..i].iter().collect(),
                line,
                in_test: false,
            });
            continue;
        }
        // ---- identifiers ---------------------------------------------
        if c.is_alphabetic() || c == '_' {
            let start = i;
            while i < n && (chars[i].is_alphanumeric() || chars[i] == '_') {
                i += 1;
            }
            out.tokens.push(Tok {
                kind: TokKind::Ident,
                text: chars[start..i].iter().collect(),
                line,
                in_test: false,
            });
            continue;
        }
        // ---- numbers -------------------------------------------------
        if c.is_ascii_digit() {
            let start = i;
            i += 1;
            while i < n && (chars[i].is_ascii_alphanumeric() || chars[i] == '_') {
                i += 1;
            }
            // fraction — but never swallow a `..` range operator
            if i + 1 < n && chars[i] == '.' && chars[i + 1].is_ascii_digit() {
                i += 1;
                while i < n && (chars[i].is_ascii_alphanumeric() || chars[i] == '_') {
                    i += 1;
                }
            } else if i + 1 < n
                && chars[i] == '.'
                && chars[i + 1] != '.'
                && !chars[i + 1].is_alphabetic()
            {
                // trailing-dot float like `1.` followed by `)` or `,`
                i += 1;
            }
            out.tokens.push(Tok {
                kind: TokKind::Num,
                text: chars[start..i].iter().collect(),
                line,
                in_test: false,
            });
            continue;
        }
        // ---- punctuation ---------------------------------------------
        out.tokens.push(Tok {
            kind: TokKind::Punct(c),
            text: String::new(),
            line,
            in_test: false,
        });
        i += 1;
    }

    out.lines = line;
    out.doc_lines.sort_unstable();
    out.doc_lines.dedup();
    mark_test_regions(&mut out.tokens);
    out
}

/// Try to lex a raw or byte string starting at `i` (`r"`, `r#"`, `b"`,
/// `br"`, `br#"`). Returns `(index_after, line_after)` on success.
fn try_raw_or_byte_string(chars: &[char], i: usize, line: u32) -> Option<(usize, u32)> {
    let n = chars.len();
    let mut j = i;
    let mut raw = false;
    if chars[j] == 'b' {
        j += 1;
        if j < n && chars[j] == 'r' {
            raw = true;
            j += 1;
        }
    } else if chars[j] == 'r' {
        raw = true;
        j += 1;
    }
    if raw {
        let mut hashes = 0usize;
        while j < n && chars[j] == '#' {
            hashes += 1;
            j += 1;
        }
        if j >= n || chars[j] != '"' {
            return None; // raw ident (`r#type`) or plain ident starting with r
        }
        j += 1;
        let mut ln = line;
        while j < n {
            if chars[j] == '\n' {
                ln += 1;
                j += 1;
                continue;
            }
            if chars[j] == '"' {
                let mut h = 0usize;
                while j + 1 + h < n && h < hashes && chars[j + 1 + h] == '#' {
                    h += 1;
                }
                if h == hashes {
                    return Some((j + 1 + hashes, ln));
                }
            }
            j += 1;
        }
        return Some((n, ln));
    }
    // byte string b"…" (escapes allowed)
    if j < n && chars[j] == '"' {
        j += 1;
        let mut ln = line;
        while j < n {
            match chars[j] {
                '\\' => j += 2,
                '\n' => {
                    ln += 1;
                    j += 1;
                }
                '"' => return Some((j + 1, ln)),
                _ => j += 1,
            }
        }
        return Some((n, ln));
    }
    None
}

/// Parse a `lint: allow(rule-a, rule-b) — justification` comment.
fn record_allow(comment: &str, line: u32, allows: &mut Vec<Allow>) {
    let Some(pos) = comment.find("lint: allow(") else {
        return;
    };
    let after = &comment[pos + "lint: allow(".len()..];
    let Some(close) = after.find(')') else {
        return;
    };
    let rules: Vec<String> = after[..close]
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    let rest = after[close + 1..]
        .trim_matches(|c: char| c.is_whitespace() || c == '-' || c == '—' || c == ':' || c == '*');
    allows.push(Allow {
        line,
        rules,
        justified: !rest.is_empty(),
    });
}

/// Mark tokens belonging to `#[cfg(test)]`-gated items. Handles the
/// common shapes: a gated `mod … { … }`, a gated `fn`/`struct`/`impl`
/// with a brace body, and gated single statements ending in `;`.
fn mark_test_regions(tokens: &mut [Tok]) {
    let mut i = 0usize;
    while i < tokens.len() {
        if let Some(attr_end) = match_cfg_test_attr(tokens, i) {
            // skip any further attributes stacked under the cfg
            let mut j = attr_end;
            while j + 1 < tokens.len() && tokens[j].is_punct('#') && tokens[j + 1].is_punct('[') {
                j = skip_attr_group(tokens, j);
            }
            // find the gated item's body: first `{` before any `;`
            let mut k = j;
            let mut body = None;
            while k < tokens.len() {
                if tokens[k].is_punct('{') {
                    body = Some(k);
                    break;
                }
                if tokens[k].is_punct(';') {
                    body = None;
                    k += 1;
                    break;
                }
                k += 1;
            }
            let end = match body {
                Some(open) => matching_brace(tokens, open),
                None => k,
            };
            for t in tokens.iter_mut().take(end.min(tokens.len())).skip(i) {
                t.in_test = true;
            }
            i = end;
        } else {
            i += 1;
        }
    }
}

/// If tokens at `i` start a `#[cfg(… test …)]` attribute, return the
/// index just past its closing `]`.
fn match_cfg_test_attr(tokens: &[Tok], i: usize) -> Option<usize> {
    if !(tokens.get(i)?.is_punct('#') && tokens.get(i + 1)?.is_punct('[')) {
        return None;
    }
    let end = skip_attr_group(tokens, i);
    let group = &tokens[i..end];
    let is_cfg = group.iter().any(|t| t.is_ident("cfg"));
    let has_test = group.iter().any(|t| t.is_ident("test"));
    let negated = group.iter().any(|t| t.is_ident("not"));
    if is_cfg && has_test && !negated {
        Some(end)
    } else {
        None
    }
}

/// `tokens[i]` is `#` opening an attribute; return index past its `]`.
fn skip_attr_group(tokens: &[Tok], i: usize) -> usize {
    let mut depth = 0i32;
    let mut j = i + 1;
    while j < tokens.len() {
        if tokens[j].is_punct('[') {
            depth += 1;
        } else if tokens[j].is_punct(']') {
            depth -= 1;
            if depth == 0 {
                return j + 1;
            }
        }
        j += 1;
    }
    tokens.len()
}

/// `tokens[open]` is `{`; return index just past its matching `}`.
pub fn matching_brace(tokens: &[Tok], open: usize) -> usize {
    let mut depth = 0i32;
    let mut j = open;
    while j < tokens.len() {
        if tokens[j].is_punct('{') {
            depth += 1;
        } else if tokens[j].is_punct('}') {
            depth -= 1;
            if depth == 0 {
                return j + 1;
            }
        }
        j += 1;
    }
    tokens.len()
}
