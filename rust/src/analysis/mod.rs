//! `trueknn lint`: a zero-dependency determinism-contract analyzer.
//!
//! Every PR since the seed leans on one standing invariant — **results
//! and counters are bitwise-identical at any threads × workers ×
//! shards**. Until now that contract was enforced only dynamically, by
//! oracle tests that can't see a nondeterminism hazard until a schedule
//! happens to expose it. This module turns the contract from
//! test-observed into build-enforced: a std-only static analyzer with
//! its own lightweight Rust lexer ([`lexer`]), a module-path-scoped
//! rule engine ([`rules`]), a tiny `lint.toml` reader ([`conf`]), and
//! machine-readable findings with stable ordering. It runs as the
//! `trueknn lint` CLI subcommand (exit code = finding count) and as a
//! blocking CI job.
//!
//! # Rules and their contract rationale
//!
//! * `unordered-iteration` — `HashMap`/`HashSet` iterate in randomized
//!   order (SipHash seeds differ per process). Any walk feeding a merge
//!   result, a [`crate::coordinator::MetricsSnapshot`], the `serve` CLI
//!   summary, or batch emission order silently varies across runs.
//!   Keyed access is order-free and stays legal; walks must go through
//!   a sorted key list or an ordered structure (`BTreeMap`, `Vec`).
//! * `wallclock-in-core` — `Instant::now`/`SystemTime` on a core path
//!   leaks schedule noise into outputs and makes replay diverge.
//!   Confined by `lint.toml` to `bench`, `exp`, and `util::timer`.
//! * `raw-threads` — all parallelism flows through
//!   [`crate::exec::Executor`] (deterministic shard-then-merge) or the
//!   coordinator service loop; a raw `thread::spawn`/`scope` anywhere
//!   else creates schedules the determinism suites never cover.
//!   Confined to `exec` and `coordinator::service`; everyone else uses
//!   [`crate::exec::scope`], the sanctioned chokepoint.
//! * `sync-in-exec` — the exec engine is lock-free by contract
//!   (disjoint writes + sequential merge); `Mutex`/`Atomic*`/`mpsc`
//!   inside `exec/` would mean one worker observes another.
//! * `float-reduce-order` — float addition is non-associative, so
//!   `.sum::<f32>()`/float `fold` in parallel-reachable modules gives
//!   chunk-boundary-dependent bits; reductions use ordered sequential
//!   merges instead.
//! * `panic-in-lib` — library panics abort serving workers; recoverable
//!   paths propagate `Error`s, and genuinely-infallible `unwrap`s carry
//!   an inline justification.
//! * `truncating-id-cast` — `as u32`/`as usize` on id *arithmetic* in
//!   merge/remap paths wraps silently past 2^32 points; id widening
//!   goes through checked helpers
//!   (e.g. [`crate::shard::Partition::global_id`]).
//! * `pub-missing-docs` — the `index`/`shard`/`coordinator` public API
//!   is the surface other layers build on; each `pub` item states its
//!   contract.
//! * `channel-unwrap-in-coordinator` — in the supervised pool a
//!   disconnected channel is the *normal* signature of a worker
//!   mid-restart or a pool tearing down, so `.send(…).unwrap()` /
//!   `.recv().expect(…)` in the coordinator turns every recovery path
//!   into a second panic site; the `Result` must flow into explicit
//!   handling. Scoped to `coordinator`; the supervisor module — the
//!   recovery path itself — is exempt via `lint.toml`.
//! * `io-unwrap-in-persist` — in the durability layer a failed disk
//!   operation (torn WAL tail, corrupt snapshot, full disk) is a
//!   *planned* input to cold-start recovery, so `File::open(…).unwrap()`
//!   / `.write_all(…).expect(…)` shapes would turn a
//!   readable-but-corrupt file into the crash loop the rebuild fallback
//!   exists to prevent; I/O `Result`s flow into
//!   [`crate::persist::PersistError`]. Scoped to `persist` and
//!   `coordinator` via `lint.toml`.
//! * `bare-allow` — meta-rule: an inline `lint: allow(…)` without a
//!   justification, or naming an unknown rule id, is itself a finding,
//!   so the suppression mechanism can't rot.
//!
//! # Suppression
//!
//! A plain line comment `// lint: allow(rule-a, rule-b) — justification`
//! suppresses those rules on its own line and the next line. The
//! justification text after the closing paren is mandatory, and doc
//! comments never carry suppressions (quoting the syntax is prose).
//! File-level scoping lives in `rust/lint.toml` (see [`conf`]).

pub mod conf;
pub mod lexer;
pub mod rules;

pub use conf::LintConfig;

use std::path::{Path, PathBuf};

/// One analyzer finding, ready for reporting.
#[derive(Debug, Clone)]
pub struct Finding {
    /// Path as scanned, relative to the scan root (slash-normalized).
    pub file: String,
    /// 1-based source line.
    pub line: u32,
    /// Rule id (one of [`rules::RULES`]).
    pub rule: &'static str,
    /// Human-readable explanation.
    pub message: String,
    /// The trimmed source line the finding anchors to.
    pub snippet: String,
}

/// A whole-tree analysis result.
#[derive(Debug, Default)]
pub struct Report {
    /// All findings, sorted by (file, line, rule).
    pub findings: Vec<Finding>,
    /// Number of `.rs` files scanned.
    pub files: usize,
    /// Total source lines scanned.
    pub lines: u64,
}

/// Map a path relative to the scan root onto a crate module path:
/// `lib.rs` → `` (crate root), `main.rs` → `main`, `foo/mod.rs` →
/// `foo`, `foo/bar.rs` → `foo::bar`.
pub fn module_path_of(rel: &str) -> String {
    let norm = rel.replace('\\', "/");
    let no_ext = norm.strip_suffix(".rs").unwrap_or(&norm);
    let mut parts: Vec<&str> = no_ext.split('/').filter(|p| !p.is_empty()).collect();
    if parts.last() == Some(&"mod") {
        parts.pop();
    }
    if parts == ["lib"] {
        return String::new();
    }
    parts.join("::")
}

/// Analyze one file's source. `module` is its crate module path (see
/// [`module_path_of`]); `file` is used only for labeling findings.
pub fn analyze_source(module: &str, file: &str, src: &str, cfg: &LintConfig) -> Vec<Finding> {
    let lexed = lexer::lex(src);
    // rules only see shipping code: drop `#[cfg(test)]` regions
    let shipping: Vec<lexer::Tok> = lexed
        .tokens
        .iter()
        .filter(|t| !t.in_test)
        .cloned()
        .collect();
    let raw = rules::scan(&shipping, &lexed);
    let src_lines: Vec<&str> = src.lines().collect();
    let snippet = |line: u32| -> String {
        src_lines
            .get(line as usize - 1)
            .map(|s| s.trim().to_string())
            .unwrap_or_default()
    };

    let mut out: Vec<Finding> = Vec::new();
    for f in raw {
        if !cfg.in_scope(f.rule, module) || cfg.is_allowed(f.rule, module) {
            continue;
        }
        if suppressed(&lexed.allows, f.rule, f.line) {
            continue;
        }
        out.push(Finding {
            file: file.to_string(),
            line: f.line,
            rule: f.rule,
            message: f.message,
            snippet: snippet(f.line),
        });
    }
    // meta-rule: suppressions must be justified and name real rules
    for a in &lexed.allows {
        if !a.justified {
            out.push(Finding {
                file: file.to_string(),
                line: a.line,
                rule: "bare-allow",
                message: "inline `lint: allow(…)` without a justification after the closing paren"
                    .to_string(),
                snippet: snippet(a.line),
            });
        }
        for r in &a.rules {
            if r != "all" && !rules::RULES.contains(&r.as_str()) {
                out.push(Finding {
                    file: file.to_string(),
                    line: a.line,
                    rule: "bare-allow",
                    message: format!("inline allow names unknown rule `{r}`"),
                    snippet: snippet(a.line),
                });
            }
        }
    }
    out.sort_by(|a, b| (a.line, a.rule).cmp(&(b.line, b.rule)));
    out
}

/// A justified allow on the finding's line or the line above covers it.
fn suppressed(allows: &[lexer::Allow], rule: &str, line: u32) -> bool {
    allows.iter().any(|a| {
        a.justified
            && (a.line == line || a.line + 1 == line)
            && a.rules.iter().any(|r| r == rule || r == "all")
    })
}

/// Recursively collect `.rs` files under `root`, sorted by path so the
/// report order is machine-independent.
fn collect_rs_files(root: &Path) -> Result<Vec<PathBuf>, String> {
    let mut out = Vec::new();
    let mut stack = vec![root.to_path_buf()];
    while let Some(dir) = stack.pop() {
        let entries =
            std::fs::read_dir(&dir).map_err(|e| format!("reading {}: {e}", dir.display()))?;
        for entry in entries {
            let entry = entry.map_err(|e| format!("reading {}: {e}", dir.display()))?;
            let path = entry.path();
            if path.is_dir() {
                stack.push(path);
            } else if path.extension().is_some_and(|x| x == "rs") {
                out.push(path);
            }
        }
    }
    out.sort();
    Ok(out)
}

/// Analyze every `.rs` file under `root` (normally `rust/src`) with
/// `cfg`. Findings come back sorted by (file, line, rule).
pub fn run_tree(root: &Path, cfg: &LintConfig) -> Result<Report, String> {
    let mut report = Report::default();
    for path in collect_rs_files(root)? {
        let rel = path
            .strip_prefix(root)
            .unwrap_or(&path)
            .to_string_lossy()
            .replace('\\', "/");
        let src = std::fs::read_to_string(&path)
            .map_err(|e| format!("reading {}: {e}", path.display()))?;
        let module = module_path_of(&rel);
        report.files += 1;
        report.lines += src.lines().count() as u64;
        report
            .findings
            .extend(analyze_source(&module, &rel, &src, cfg));
    }
    report
        .findings
        .sort_by(|a, b| (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule)));
    Ok(report)
}

/// Render the report as `file:line [rule] message` lines plus a
/// one-line summary — the human-facing CLI output.
pub fn render_text(report: &Report) -> String {
    let mut s = String::new();
    for f in &report.findings {
        s.push_str(&format!(
            "{}:{} [{}] {}\n    {}\n",
            f.file, f.line, f.rule, f.message, f.snippet
        ));
    }
    s.push_str(&format!(
        "lint: {} finding(s) across {} file(s), {} line(s)\n",
        report.findings.len(),
        report.files,
        report.lines
    ));
    s
}

/// Render the report as a machine-readable JSON document (the `--json`
/// CLI output and the CI artifact).
pub fn to_json(report: &Report) -> crate::configx::json::Json {
    use crate::configx::json::Json;
    let findings: Vec<Json> = report
        .findings
        .iter()
        .map(|f| {
            Json::obj(vec![
                ("file", Json::Str(f.file.clone())),
                ("line", Json::Num(f.line as f64)),
                ("rule", Json::Str(f.rule.to_string())),
                ("message", Json::Str(f.message.clone())),
                ("snippet", Json::Str(f.snippet.clone())),
            ])
        })
        .collect();
    Json::obj(vec![
        ("files", Json::Num(report.files as f64)),
        ("lines", Json::Num(report.lines as f64)),
        ("finding_count", Json::Num(report.findings.len() as f64)),
        ("findings", Json::Arr(findings)),
    ])
}
