//! The determinism-contract rules, matched over the lexed token stream.
//!
//! Each rule is a small token-pattern matcher. Rules see only tokens
//! outside `#[cfg(test)]` regions, and never see comments or string
//! contents (the lexer already collapsed those), so `"HashMap"` in a
//! string literal or `// let m = HashMap::new()` in commented-out code
//! can never fire. Module scoping (`scope` / `allow` in `lint.toml`)
//! and inline `// lint: allow(rule)` suppression are applied by the
//! engine in [`super`], not here.
//!
//! See the crate-level "Determinism contract" section in `lib.rs` for
//! the contract each rule id enforces.

use super::lexer::{Lexed, Tok, TokKind};
use std::collections::BTreeSet;

/// A rule match before the engine attaches file/snippet context.
#[derive(Debug)]
pub struct RawFinding {
    pub rule: &'static str,
    pub line: u32,
    pub message: String,
}

/// Every rule id the analyzer knows, in report order. `bare-allow` is
/// the meta-rule guarding the suppression mechanism itself.
pub const RULES: &[&str] = &[
    "unordered-iteration",
    "wallclock-in-core",
    "raw-threads",
    "sync-in-exec",
    "float-reduce-order",
    "panic-in-lib",
    "truncating-id-cast",
    "pub-missing-docs",
    "channel-unwrap-in-coordinator",
    "io-unwrap-in-persist",
    "bare-allow",
];

/// Run every token-level rule over the (test-filtered) token stream.
/// The engine filters by module scope/allow afterwards.
pub fn scan(toks: &[Tok], lexed: &Lexed) -> Vec<RawFinding> {
    let mut out = Vec::new();
    unordered_iteration(toks, &mut out);
    wallclock_in_core(toks, &mut out);
    raw_threads(toks, &mut out);
    sync_in_exec(toks, &mut out);
    float_reduce_order(toks, &mut out);
    panic_in_lib(toks, &mut out);
    truncating_id_cast(toks, &mut out);
    pub_missing_docs(toks, lexed, &mut out);
    channel_unwrap_in_coordinator(toks, &mut out);
    io_unwrap_in_persist(toks, &mut out);
    out
}

fn ident_at<'a>(toks: &'a [Tok], i: usize) -> Option<&'a str> {
    match toks.get(i) {
        Some(t) if t.kind == TokKind::Ident => Some(&t.text),
        _ => None,
    }
}

fn punct_at(toks: &[Tok], i: usize, c: char) -> bool {
    matches!(toks.get(i), Some(t) if t.is_punct(c))
}

/// `::` at positions i, i+1.
fn path_sep(toks: &[Tok], i: usize) -> bool {
    punct_at(toks, i, ':') && punct_at(toks, i + 1, ':')
}

// ---------------------------------------------------------------------
// unordered-iteration
// ---------------------------------------------------------------------

/// Keywords that can never be a map binding name (guards the backward
/// walk from a `HashMap` type token landing on `use`, `let`, …).
const KEYWORDS: &[&str] = &[
    "use", "let", "pub", "in", "as", "return", "if", "else", "match", "for", "while", "fn",
    "impl", "struct", "enum", "where", "type", "const", "static", "mut", "ref", "move", "crate",
    "super", "self", "Self", "dyn", "trait", "mod", "unsafe", "async", "await", "loop", "break",
    "continue",
];

const ITER_METHODS: &[&str] = &[
    "iter",
    "iter_mut",
    "keys",
    "values",
    "values_mut",
    "drain",
    "into_iter",
    "into_keys",
    "into_values",
];

/// Hash containers iterate in randomized order; any walk that feeds a
/// result, snapshot, or emission path breaks bitwise determinism. The
/// rule binds names declared or assigned as `HashMap`/`HashSet` within
/// a file, then flags `.iter()`-family calls and `for … in &name {`
/// loops on them. Keyed access (`get`/`insert`/`remove`/`contains_key`)
/// is order-free and stays legal.
fn unordered_iteration(toks: &[Tok], out: &mut Vec<RawFinding>) {
    // Pass 1: names bound to a hash container in this file, either as a
    // typed binding/field/param (`name: HashMap<…>`) or an assignment
    // (`name = HashMap::new()` / `with_capacity` / `default` / `from`).
    let mut names: BTreeSet<String> = BTreeSet::new();
    for i in 0..toks.len() {
        let Some(tyname) = ident_at(toks, i) else {
            continue;
        };
        if tyname != "HashMap" && tyname != "HashSet" {
            continue;
        }
        // walk back over path/borrow noise: `: &mut std::collections::`
        let mut j = i;
        while j > 0 {
            let t = &toks[j - 1];
            let skip = t.is_punct(':')
                || t.is_punct('&')
                || t.is_ident("mut")
                || t.is_ident("std")
                || t.is_ident("collections");
            if !skip {
                break;
            }
            j -= 1;
        }
        if j == 0 {
            continue;
        }
        let before = &toks[j - 1];
        // `name : HashMap` — the skip run starts with the type colon
        if j < i && toks[j].is_punct(':') {
            if before.kind == TokKind::Ident && !KEYWORDS.contains(&before.text.as_str()) {
                names.insert(before.text.clone());
            }
            continue;
        }
        // `name = HashMap::ctor(…)`
        if before.is_punct('=') && j >= 2 && path_sep(toks, i + 1) {
            if let Some(ctor) = ident_at(toks, i + 3) {
                if matches!(ctor, "new" | "with_capacity" | "default" | "from") {
                    if let Some(name) = ident_at(toks, j - 2) {
                        if !KEYWORDS.contains(&name) {
                            names.insert(name.to_string());
                        }
                    }
                }
            }
        }
    }
    if names.is_empty() {
        return;
    }
    // Pass 2: flag iteration over those names.
    for i in 0..toks.len() {
        // `name.iter()` family
        if let Some(name) = ident_at(toks, i) {
            if names.contains(name)
                && punct_at(toks, i + 1, '.')
                && ident_at(toks, i + 2).is_some_and(|m| ITER_METHODS.contains(&m))
                && punct_at(toks, i + 3, '(')
            {
                out.push(RawFinding {
                    rule: "unordered-iteration",
                    line: toks[i + 2].line,
                    message: format!(
                        "iteration over hash container `{name}` (.{}()) is order-nondeterministic; \
                         iterate a sorted key list or an ordered structure instead",
                        toks[i + 2].text
                    ),
                });
            }
        }
        // `for … in &[mut] name {`
        if toks[i].is_ident("in") {
            let mut j = i + 1;
            while punct_at(toks, j, '&') || ident_at(toks, j) == Some("mut") {
                j += 1;
            }
            if let Some(name) = ident_at(toks, j) {
                if names.contains(name) && punct_at(toks, j + 1, '{') {
                    out.push(RawFinding {
                        rule: "unordered-iteration",
                        line: toks[j].line,
                        message: format!(
                            "`for … in &{name}` walks a hash container in randomized order; \
                             iterate a sorted key list instead"
                        ),
                    });
                }
            }
        }
    }
}

// ---------------------------------------------------------------------
// wallclock-in-core
// ---------------------------------------------------------------------

/// Wall-clock reads on a result path make reruns incomparable and leak
/// schedule noise into outputs. `Instant::now`/`SystemTime` belong in
/// the measurement shells (`bench`, `exp`, `util::timer` — scoped via
/// lint.toml), never in core algorithm or merge code.
fn wallclock_in_core(toks: &[Tok], out: &mut Vec<RawFinding>) {
    for i in 0..toks.len() {
        if toks[i].is_ident("Instant")
            && path_sep(toks, i + 1)
            && ident_at(toks, i + 3) == Some("now")
        {
            out.push(RawFinding {
                rule: "wallclock-in-core",
                line: toks[i].line,
                message: "`Instant::now()` outside bench/exp/util::timer; core paths must be \
                          wall-clock free"
                    .to_string(),
            });
        }
        if toks[i].is_ident("SystemTime") {
            out.push(RawFinding {
                rule: "wallclock-in-core",
                line: toks[i].line,
                message: "`SystemTime` outside bench/exp/util::timer; core paths must be \
                          wall-clock free"
                    .to_string(),
            });
        }
    }
}

// ---------------------------------------------------------------------
// raw-threads
// ---------------------------------------------------------------------

/// All parallelism flows through `exec::Executor` (deterministic
/// shard-then-merge) or the coordinator's service loop. Raw
/// `thread::spawn`/`scope`/`Builder` anywhere else creates schedules
/// the determinism tests don't cover.
fn raw_threads(toks: &[Tok], out: &mut Vec<RawFinding>) {
    for i in 0..toks.len() {
        if toks[i].is_ident("thread") && path_sep(toks, i + 1) {
            if let Some(m) = ident_at(toks, i + 3) {
                if matches!(m, "spawn" | "scope" | "Builder") {
                    out.push(RawFinding {
                        rule: "raw-threads",
                        line: toks[i].line,
                        message: format!(
                            "`thread::{m}` outside exec/coordinator::service; route parallelism \
                             through exec::Executor or exec::scope"
                        ),
                    });
                }
            }
        }
    }
}

// ---------------------------------------------------------------------
// sync-in-exec
// ---------------------------------------------------------------------

/// The exec engine is lock-free by contract: workers write disjoint
/// result slots and merge sequentially. Any `Mutex`/`Atomic*`/`mpsc`
/// inside `exec/` means a worker observed another worker — the exact
/// coupling the shard-then-merge design exists to forbid.
fn sync_in_exec(toks: &[Tok], out: &mut Vec<RawFinding>) {
    for t in toks {
        if t.kind != TokKind::Ident {
            continue;
        }
        let hit = matches!(t.text.as_str(), "Mutex" | "RwLock" | "Condvar" | "Barrier" | "mpsc")
            || t.text.starts_with("Atomic");
        if hit {
            out.push(RawFinding {
                rule: "sync-in-exec",
                line: t.line,
                message: format!(
                    "`{}` inside exec/: the shard-then-merge engine is lock-free by contract",
                    t.text
                ),
            });
        }
    }
}

// ---------------------------------------------------------------------
// float-reduce-order
// ---------------------------------------------------------------------

fn is_float_token(t: &Tok) -> bool {
    match t.kind {
        TokKind::Num => {
            t.text.contains('.') || t.text.ends_with("f32") || t.text.ends_with("f64")
        }
        TokKind::Ident => t.text == "f32" || t.text == "f64",
        _ => false,
    }
}

/// Float addition is not associative: `.sum::<f32>()` or a float `fold`
/// in parallel-reachable modules produces chunk-boundary-dependent
/// bits. Reductions must go through the ordered sequential merges the
/// exec engine provides.
fn float_reduce_order(toks: &[Tok], out: &mut Vec<RawFinding>) {
    for i in 0..toks.len() {
        if punct_at(toks, i, '.')
            && ident_at(toks, i + 1) == Some("sum")
            && path_sep(toks, i + 2)
            && punct_at(toks, i + 4, '<')
            && ident_at(toks, i + 5).is_some_and(|t| t == "f32" || t == "f64")
        {
            out.push(RawFinding {
                rule: "float-reduce-order",
                line: toks[i + 1].line,
                message: "float `.sum()` reassociates under chunking; use an ordered sequential \
                          reduction"
                    .to_string(),
            });
        }
        if punct_at(toks, i, '.')
            && ident_at(toks, i + 1) == Some("fold")
            && punct_at(toks, i + 2, '(')
            && toks.get(i + 3).is_some_and(is_float_token)
        {
            out.push(RawFinding {
                rule: "float-reduce-order",
                line: toks[i + 1].line,
                message: "float `fold` reassociates under chunking; use an ordered sequential \
                          reduction"
                    .to_string(),
            });
        }
    }
}

// ---------------------------------------------------------------------
// panic-in-lib
// ---------------------------------------------------------------------

/// Library code must propagate errors, not abort the process: a panic
/// inside a worker poisons the whole serving pool. `unwrap`/`expect`
/// on genuinely-infallible invariants carry an inline
/// `// lint: allow(panic-in-lib) — why` justification instead.
fn panic_in_lib(toks: &[Tok], out: &mut Vec<RawFinding>) {
    for i in 0..toks.len() {
        if punct_at(toks, i, '.')
            && ident_at(toks, i + 1).is_some_and(|m| m == "unwrap" || m == "expect")
            && punct_at(toks, i + 2, '(')
        {
            out.push(RawFinding {
                rule: "panic-in-lib",
                line: toks[i + 1].line,
                message: format!(
                    "`.{}()` in library code; propagate an Error or justify with an inline allow",
                    toks[i + 1].text
                ),
            });
        }
        if toks[i].is_ident("panic") && punct_at(toks, i + 1, '!') {
            out.push(RawFinding {
                rule: "panic-in-lib",
                line: toks[i].line,
                message: "`panic!` in library code; propagate an Error or justify with an inline \
                          allow"
                    .to_string(),
            });
        }
    }
}

// ---------------------------------------------------------------------
// truncating-id-cast
// ---------------------------------------------------------------------

/// In merge/remap paths a truncating `as u32`/`as usize` on id
/// *arithmetic* silently wraps once a dataset crosses 2^32 points —
/// and the shard scatter-gather layer is exactly where global ids are
/// reconstituted from (shard, local) pairs. Flags casts whose operand
/// is an arithmetic expression; plain index-to-width casts stay legal.
fn truncating_id_cast(toks: &[Tok], out: &mut Vec<RawFinding>) {
    for i in 0..toks.len() {
        if !toks[i].is_ident("as") {
            continue;
        }
        let Some(ty) = ident_at(toks, i + 1) else {
            continue;
        };
        if ty != "u32" && ty != "usize" {
            continue;
        }
        if i == 0 {
            continue;
        }
        let arithmetic = if toks[i - 1].is_punct(')') {
            paren_group_has_arith(toks, i - 1)
        } else {
            // `a + b as u32` — binary op directly before the operand
            i >= 3
                && matches!(toks[i - 1].kind, TokKind::Ident | TokKind::Num)
                && (toks[i - 2].is_punct('+')
                    || toks[i - 2].is_punct('-')
                    || toks[i - 2].is_punct('*'))
                && matches!(
                    toks[i - 3].kind,
                    TokKind::Ident | TokKind::Num | TokKind::Punct(')')
                )
        };
        if arithmetic {
            out.push(RawFinding {
                rule: "truncating-id-cast",
                line: toks[i].line,
                message: format!(
                    "arithmetic result truncated by `as {ty}`; use a checked id-width helper"
                ),
            });
        }
    }
}

/// `toks[close]` is `)`; does the group it closes contain `+`/`-`/`*`
/// at any depth?
fn paren_group_has_arith(toks: &[Tok], close: usize) -> bool {
    let mut depth = 0i32;
    let mut j = close;
    loop {
        let t = &toks[j];
        if t.is_punct(')') {
            depth += 1;
        } else if t.is_punct('(') {
            depth -= 1;
            if depth == 0 {
                return false;
            }
        } else if depth >= 1 && (t.is_punct('+') || t.is_punct('-') || t.is_punct('*')) {
            return true;
        }
        if j == 0 {
            return false;
        }
        j -= 1;
    }
}

// ---------------------------------------------------------------------
// channel-unwrap-in-coordinator
// ---------------------------------------------------------------------

const CHANNEL_METHODS: &[&str] = &["send", "try_send", "recv", "try_recv", "recv_timeout"];

/// In the coordinator a disconnected channel is not a bug — it is the
/// normal signature of a worker mid-restart under its supervisor, or a
/// pool tearing down. Unwrapping a channel `send`/`recv` result turns
/// every recovery path into a second panic site (and a crash loop when
/// the supervisor's own replies hit it). The rule flags
/// `.send(…).unwrap()` / `.recv().expect(…)` shapes — the `Result` must
/// flow into explicit recovery handling (`let _ =`, `match`, `?`,
/// `map_err`).
fn channel_unwrap_in_coordinator(toks: &[Tok], out: &mut Vec<RawFinding>) {
    for i in 0..toks.len() {
        if !punct_at(toks, i, '.') {
            continue;
        }
        let Some(method) = ident_at(toks, i + 1) else {
            continue;
        };
        if !CHANNEL_METHODS.contains(&method) || !punct_at(toks, i + 2, '(') {
            continue;
        }
        let Some(close) = matching_close(toks, i + 2) else {
            continue;
        };
        if punct_at(toks, close + 1, '.')
            && ident_at(toks, close + 2).is_some_and(|m| m == "unwrap" || m == "expect")
            && punct_at(toks, close + 3, '(')
        {
            out.push(RawFinding {
                rule: "channel-unwrap-in-coordinator",
                line: toks[close + 2].line,
                message: format!(
                    "`.{method}(…).{}()` on a coordinator channel; a disconnect here is a \
                     recovery-path signal (worker restarting, pool shutting down) — handle the \
                     Result explicitly",
                    toks[close + 2].text
                ),
            });
        }
    }
}

// ---------------------------------------------------------------------
// io-unwrap-in-persist
// ---------------------------------------------------------------------

const IO_METHODS: &[&str] = &[
    "open",
    "create",
    "create_dir_all",
    "read",
    "read_to_end",
    "read_exact",
    "read_dir",
    "write",
    "write_all",
    "flush",
    "sync_all",
    "sync_data",
    "set_len",
    "seek",
    "rename",
    "remove_file",
    "metadata",
];

/// Durability code must treat every disk operation as fallible: a torn
/// WAL tail, a corrupt snapshot, or a full disk is a *planned* input to
/// cold-start recovery, not a bug. Unwrapping an I/O `Result` in the
/// persistence layer (or the coordinator paths that drive it) turns a
/// readable-but-corrupt file into the crash loop the rebuild fallback
/// exists to prevent. Flags `.write_all(…).unwrap()` method shapes and
/// `File::open(…).expect(…)` associated-fn shapes alike — the `Result`
/// must flow into `PersistError` (`map_err` + `io_err`) so cold start
/// can fall back to the deterministic rebuild.
fn io_unwrap_in_persist(toks: &[Tok], out: &mut Vec<RawFinding>) {
    for i in 0..toks.len() {
        // `.method(…)` receiver shape or `fs::method(…)` path shape
        let m_idx = if punct_at(toks, i, '.') {
            i + 1
        } else if path_sep(toks, i) {
            i + 2
        } else {
            continue;
        };
        let Some(method) = ident_at(toks, m_idx) else {
            continue;
        };
        if !IO_METHODS.contains(&method) || !punct_at(toks, m_idx + 1, '(') {
            continue;
        }
        let Some(close) = matching_close(toks, m_idx + 1) else {
            continue;
        };
        if punct_at(toks, close + 1, '.')
            && ident_at(toks, close + 2).is_some_and(|m| m == "unwrap" || m == "expect")
            && punct_at(toks, close + 3, '(')
        {
            out.push(RawFinding {
                rule: "io-unwrap-in-persist",
                line: toks[close + 2].line,
                message: format!(
                    "`{method}(…).{}()` on a fallible disk operation in a persistence path; \
                     I/O failure here is a recovery signal (torn tail, corrupt snapshot, full \
                     disk) — map it into PersistError and let cold start fall back to rebuild",
                    toks[close + 2].text
                ),
            });
        }
    }
}

/// `toks[open]` is `(`; index of the `)` closing it, walking forward
/// over nested groups. `None` if the stream ends first (unbalanced
/// source never reaches the matcher — the lexer would have dropped it —
/// but stay total anyway).
fn matching_close(toks: &[Tok], open: usize) -> Option<usize> {
    let mut depth = 0i32;
    for (j, t) in toks.iter().enumerate().skip(open) {
        if t.is_punct('(') {
            depth += 1;
        } else if t.is_punct(')') {
            depth -= 1;
            if depth == 0 {
                return Some(j);
            }
        }
    }
    None
}

// ---------------------------------------------------------------------
// pub-missing-docs
// ---------------------------------------------------------------------

const ITEM_KEYWORDS: &[&str] = &[
    "fn", "struct", "enum", "trait", "type", "const", "static", "mod", "union",
];

/// The `index`/`shard`/`coordinator` public API is the surface other
/// layers build on; every `pub` item there documents its contract.
/// `pub(crate)` internals, fields, and `pub use` re-exports are exempt.
fn pub_missing_docs(toks: &[Tok], lexed: &Lexed, out: &mut Vec<RawFinding>) {
    for i in 0..toks.len() {
        if !toks[i].is_ident("pub") {
            continue;
        }
        // pub(crate) / pub(super): restricted visibility, not public API
        if punct_at(toks, i + 1, '(') {
            continue;
        }
        // skip modifier keywords to the item keyword
        let mut j = i + 1;
        while ident_at(toks, j).is_some_and(|t| matches!(t, "unsafe" | "async" | "extern")) {
            j += 1;
        }
        let Some(kw) = ident_at(toks, j) else {
            continue;
        };
        if !ITEM_KEYWORDS.contains(&kw) {
            continue; // struct field, `pub use`, …
        }
        let name = ident_at(toks, j + 1).unwrap_or("?");
        // top line of the attribute chain stacked directly above `pub`
        let mut first = i;
        while first >= 1 && punct_at(toks, first - 1, ']') {
            match attr_open_before(toks, first - 1) {
                Some(h) => first = h,
                None => break,
            }
        }
        let attr_top_line = toks[first].line;
        let pub_line = toks[i].line;
        let documented = (attr_top_line >= 2 && lexed.is_doc_line(attr_top_line - 1))
            || (pub_line >= 2 && lexed.is_doc_line(pub_line - 1));
        if !documented {
            out.push(RawFinding {
                rule: "pub-missing-docs",
                line: pub_line,
                message: format!("public {kw} `{name}` has no doc comment"),
            });
        }
    }
}

/// `toks[close]` is `]`; if it closes an attribute (`# [ … ]`), return
/// the index of the opening `#`.
fn attr_open_before(toks: &[Tok], close: usize) -> Option<usize> {
    let mut depth = 0i32;
    let mut j = close;
    loop {
        if toks[j].is_punct(']') {
            depth += 1;
        } else if toks[j].is_punct('[') {
            depth -= 1;
            if depth == 0 {
                return if j >= 1 && toks[j - 1].is_punct('#') {
                    Some(j - 1)
                } else {
                    None
                };
            }
        }
        if j == 0 {
            return None;
        }
        j -= 1;
    }
}
