//! Micro/macro benchmark harness — offline substitute for `criterion`.
//!
//! Measures a closure with warmup + repeated timed runs and reports
//! mean/median/stddev/min. Output is a fixed-width table so `cargo
//! bench` logs read like the paper's tables.

pub mod pr2;
pub mod pr3;
pub mod pr4;
pub mod pr5;
pub mod pr6;
pub mod pr7;
pub mod pr8;
pub mod pr9;
pub mod pr10;

use crate::util::stats::{median, OnlineStats};
use crate::util::Stopwatch;

#[derive(Clone, Debug)]
pub struct BenchConfig {
    pub warmup_iters: usize,
    pub iters: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        Self {
            warmup_iters: 1,
            iters: 5,
        }
    }
}

/// From the environment: `TRUEKNN_BENCH_ITERS` overrides iterations
/// (useful to shorten CI runs).
impl BenchConfig {
    pub fn from_env() -> Self {
        let mut cfg = Self::default();
        if let Ok(v) = std::env::var("TRUEKNN_BENCH_ITERS") {
            if let Ok(n) = v.parse() {
                cfg.iters = n;
            }
        }
        cfg
    }
}

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub mean_s: f64,
    pub median_s: f64,
    pub stddev_s: f64,
    pub min_s: f64,
    pub iters: usize,
}

/// Time `f` under the config. The closure runs for its side effects; use
/// `std::hint::black_box` inside if the optimizer might elide work.
pub fn bench<F: FnMut()>(name: &str, cfg: &BenchConfig, mut f: F) -> BenchResult {
    for _ in 0..cfg.warmup_iters {
        f();
    }
    let mut stats = OnlineStats::new();
    let mut samples = Vec::with_capacity(cfg.iters);
    for _ in 0..cfg.iters.max(1) {
        let sw = Stopwatch::start();
        f();
        let s = sw.elapsed_secs();
        stats.push(s);
        samples.push(s);
    }
    BenchResult {
        name: name.to_string(),
        mean_s: stats.mean(),
        median_s: median(&samples),
        stddev_s: stats.stddev(),
        min_s: stats.min(),
        iters: cfg.iters.max(1),
    }
}

/// Fixed-width table printer used by every experiment driver.
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("\n=== {} ===\n", self.title));
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}", w = w))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Human formatting helpers shared by experiment drivers.
pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.2}s")
    } else if s >= 1e-3 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.2}µs", s * 1e6)
    }
}

pub fn fmt_count(c: u64) -> String {
    if c >= 1_000_000_000 {
        format!("{:.2}B", c as f64 / 1e9)
    } else if c >= 1_000_000 {
        format!("{:.2}M", c as f64 / 1e6)
    } else if c >= 1_000 {
        format!("{:.1}K", c as f64 / 1e3)
    } else {
        c.to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let mut count = 0;
        let r = bench(
            "noop",
            &BenchConfig {
                warmup_iters: 2,
                iters: 3,
            },
            || count += 1,
        );
        assert_eq!(count, 5);
        assert_eq!(r.iters, 3);
        assert!(r.mean_s >= 0.0 && r.min_s <= r.mean_s);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("demo", &["a", "long_header"]);
        t.row(vec!["1".into(), "2".into()]);
        let s = t.render();
        assert!(s.contains("demo"));
        assert!(s.contains("long_header"));
    }

    #[test]
    #[should_panic(expected = "column count mismatch")]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new("demo", &["a"]);
        t.row(vec!["1".into(), "2".into()]);
    }

    #[test]
    fn formatting_helpers() {
        assert_eq!(fmt_secs(2.5), "2.50s");
        assert_eq!(fmt_secs(0.0025), "2.50ms");
        assert_eq!(fmt_secs(2.5e-6), "2.50µs");
        assert_eq!(fmt_count(999), "999");
        assert_eq!(fmt_count(1_500), "1.5K");
        assert_eq!(fmt_count(2_500_000), "2.50M");
        assert_eq!(fmt_count(3_000_000_000), "3.00B");
    }
}
