//! The PR10 perf microbench: tracing overhead and result transparency,
//! emitted as `BENCH_PR10.json`.
//!
//! One measurement, one claim: request-scoped tracing is **free where
//! it matters**. The same mixed-route request log (even ids RT-forced
//! and scattered across 2 shards, odd ids brute-forced direct) is
//! replayed through two identically-configured services — one with
//! [`ServiceConfig::trace`] unset, one capturing spans into a temp
//! directory — and the gates enforce both halves of the transparency
//! contract:
//!
//! - **bitwise**: every traced replay's responses must equal the
//!   untraced oracle's, neighbor for neighbor, bit for bit
//!   (`results_match`);
//! - **overhead**: the best traced replay may cost at most
//!   [`OVERHEAD_BUDGET`] over the best untraced one
//!   (`overhead_frac`, gated in `trueknn bench`).
//!
//! The captured files are also read back through the `trueknn trace`
//! decoder (`trace_records` / `trace_truncated`), so the bench doubles
//! as an end-to-end check that the capture path produces verifiable
//! frames under a real serving load.
//!
//! [`ServiceConfig::trace`]: crate::coordinator::ServiceConfig

use crate::configx::Json;
use crate::coordinator::{QueryMode, Service, ServiceConfig, TraceConfig};
use crate::dataset::DatasetKind;
use crate::knn::TrueKnnParams;

use super::pr4::{replay, request_log_with, ResponseSig};
use super::{fmt_secs, Table};

/// Maximum tolerated tracing overhead (fraction of the untraced replay
/// time) before `trueknn bench` fails the run.
pub const OVERHEAD_BUDGET: f64 = 0.05;

#[derive(Clone, Debug)]
pub struct Pr10Report {
    pub n: usize,
    pub requests: usize,
    pub queries_per_request: usize,
    pub iters: usize,
    /// Best-of-`iters` wall seconds with tracing off.
    pub untraced_s: f64,
    /// Best-of-`iters` wall seconds with tracing on.
    pub traced_s: f64,
    /// `traced_s / untraced_s - 1` (negative means tracing measured
    /// faster — timing noise, not magic).
    pub overhead_frac: f64,
    /// Every traced replay answered bitwise-identically to the
    /// untraced oracle.
    pub results_match: bool,
    /// Verified span records read back from the capture directory.
    pub trace_records: u64,
    /// A trace file ended in a torn frame (must be false after a clean
    /// shutdown).
    pub trace_truncated: bool,
}

fn service_config(requests: usize, trace: Option<TraceConfig>) -> ServiceConfig {
    ServiceConfig {
        workers: 2,
        shards: 2,
        // size the queues for the whole log: the bench measures
        // throughput, not backpressure
        queue_depth: requests.max(256),
        trueknn: TrueKnnParams {
            exclude_self: false,
            ..Default::default()
        },
        trace,
        ..Default::default()
    }
}

/// Run the off/on sweep. `iters` timed replays per side, reporting the
/// minimum (the least-perturbed sample).
pub fn run(n: usize, requests: usize, qpr: usize, iters: usize) -> Pr10Report {
    let iters = iters.max(1);
    let ds = DatasetKind::Taxi.generate(n, 42);
    let qpr = qpr.min(ds.len());
    let log = request_log_with(&ds.points, requests, qpr, 137, |id| {
        if id % 2 == 0 {
            QueryMode::Rt
        } else {
            QueryMode::Brute
        }
    });

    // tracing off: the oracle side
    let (svc, handle) = Service::start(ds.points.clone(), service_config(requests, None));
    // untimed warmup replay: builds every route/shard index, so the
    // timed replays measure serving, not construction
    let (_, oracle): (f64, Vec<ResponseSig>) = replay(&handle, &log);
    let mut untraced_s = f64::INFINITY;
    let mut results_match = true;
    for _ in 0..iters {
        let (s, sigs) = replay(&handle, &log);
        results_match &= sigs == oracle;
        untraced_s = untraced_s.min(s);
    }
    svc.shutdown();

    // tracing on: same config plus a span capture into a temp dir
    let trace_dir = std::env::temp_dir().join(format!("trueknn-pr10-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&trace_dir);
    let cfg = service_config(requests, Some(TraceConfig::new(&trace_dir)));
    let (svc, handle) = Service::start(ds.points.clone(), cfg);
    let (_, sigs) = replay(&handle, &log);
    results_match &= sigs == oracle;
    let mut traced_s = f64::INFINITY;
    for _ in 0..iters {
        let (s, sigs) = replay(&handle, &log);
        results_match &= sigs == oracle;
        traced_s = traced_s.min(s);
    }
    // clean shutdown drains every worker's span ring before we read
    svc.shutdown();

    let (trace_records, trace_truncated) = match crate::obs::trace::read_trace_dir(&trace_dir) {
        Ok((records, truncated)) => (records.len() as u64, truncated),
        Err(e) => {
            crate::log_warn!("reading back the pr10 trace capture failed: {e}");
            (0, true)
        }
    };
    let _ = std::fs::remove_dir_all(&trace_dir);

    Pr10Report {
        n: ds.len(),
        requests,
        queries_per_request: qpr,
        iters,
        untraced_s,
        traced_s,
        overhead_frac: traced_s / untraced_s.max(1e-12) - 1.0,
        results_match,
        trace_records,
        trace_truncated,
    }
}

pub fn to_json(r: &Pr10Report) -> Json {
    Json::obj(vec![
        ("bench", Json::Str("pr10".into())),
        (
            "trace_overhead",
            Json::obj(vec![
                ("dataset", Json::Str("taxi".into())),
                ("n", Json::Num(r.n as f64)),
                ("requests", Json::Num(r.requests as f64)),
                ("queries_per_request", Json::Num(r.queries_per_request as f64)),
                ("iters", Json::Num(r.iters as f64)),
                ("untraced_s", Json::Num(r.untraced_s)),
                ("traced_s", Json::Num(r.traced_s)),
                ("overhead_frac", Json::Num(r.overhead_frac)),
                ("overhead_budget", Json::Num(OVERHEAD_BUDGET)),
                ("results_match", Json::Bool(r.results_match)),
                ("trace_records", Json::Num(r.trace_records as f64)),
                ("trace_truncated", Json::Bool(r.trace_truncated)),
            ]),
        ),
    ])
}

pub fn render(r: &Pr10Report) -> Table {
    let mut t = Table::new(
        "PR10 microbench: tracing overhead + transparency (mixed-route sharded log)",
        &["tracing", "replay", "q/s"],
    );
    let qps = |s: f64| (r.requests * r.queries_per_request) as f64 / s.max(1e-12);
    t.row(vec![
        "off".into(),
        fmt_secs(r.untraced_s),
        format!("{:.0}", qps(r.untraced_s)),
    ]);
    t.row(vec![
        "on".into(),
        fmt_secs(r.traced_s),
        format!("{:.0}", qps(r.traced_s)),
    ]);
    t.row(vec![
        "overhead".into(),
        format!("{:+.1}%", r.overhead_frac * 100.0),
        String::new(),
    ]);
    t.row(vec![
        "bitwise transparent".into(),
        r.results_match.to_string(),
        String::new(),
    ]);
    t.row(vec![
        "span records".into(),
        r.trace_records.to_string(),
        if r.trace_truncated { "TORN".into() } else { String::new() },
    ]);
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tracing_is_bitwise_transparent_and_capture_reads_back() {
        let r = run(1_500, 12, 4, 1);
        assert!(r.results_match, "tracing must not change responses");
        assert!(r.trace_records > 0, "the capture must produce verifiable frames");
        assert!(!r.trace_truncated, "a clean shutdown must not tear frames");
        // no overhead assertion here: unit-test machines are too noisy;
        // the budget gate lives in `trueknn bench`
        let j = to_json(&r).to_string();
        assert!(j.contains("\"bench\":\"pr10\""));
        assert!(j.contains("trace_overhead"));
        let parsed = crate::configx::parse_json(&j).unwrap();
        assert!(parsed.get("trace_overhead").is_some());
    }
}
