//! The PR2 perf microbench: parallel launch throughput and TrueKNN
//! shell re-query heap traffic, emitted as `BENCH_PR2.json` so CI can
//! archive the perf trajectory run over run.
//!
//! Two measurements:
//!
//! 1. **Launch throughput** — one `Pipeline::launch_parallel` over every
//!    point of a uniform dataset (spheres at the sampled Alg. 2 start
//!    radius, k = 5), at 1 thread and at all cores. The wall-clock
//!    numbers are machine-dependent; the JSON records both so the
//!    speedup ratio is what gets tracked.
//! 2. **Shell re-query** — a full TrueKNN search on the clustered taxi
//!    analog with shell re-query on vs. the reset-per-round baseline.
//!    `heap_pushes` is a deterministic counter, so this pair is exact
//!    telemetry, not timing.

use crate::configx::Json;
use crate::dataset::DatasetKind;
use crate::exec::Executor;
use crate::geom::Ray;
use crate::index::{Backend, IndexBuilder};
use crate::knn::program::KnnProgram;
use crate::knn::random_sample_radius;
use crate::rt::{HwCounters, Pipeline, Scene};
use crate::util::Stopwatch;

use super::{fmt_count, Table};

#[derive(Clone, Debug)]
pub struct LaunchRow {
    pub threads: usize,
    /// Best-of-`iters` wall seconds for one full launch.
    pub seconds: f64,
    pub rays_per_s: f64,
}

#[derive(Clone, Debug)]
pub struct Pr2Report {
    pub launch_n: usize,
    pub launch_radius: f32,
    pub iters: usize,
    pub launch: Vec<LaunchRow>,
    /// Throughput at max threads / throughput at 1 thread.
    pub launch_speedup: f64,
    pub shell_n: usize,
    pub shell_k: usize,
    pub shell_rounds: usize,
    pub heap_pushes_shell: u64,
    pub heap_pushes_reset: u64,
    /// Sanity: both variants returned identical neighbor distances.
    pub shell_exact: bool,
}

/// Run both measurements. `iters` timed repetitions per configuration,
/// reporting the minimum (the least-perturbed sample).
pub fn run(launch_n: usize, shell_n: usize, iters: usize) -> Pr2Report {
    let iters = iters.max(1);

    // ---- 1. launch throughput, 1 thread vs all cores ----------------
    let ds = DatasetKind::Uniform.generate(launch_n, 42);
    let radius = random_sample_radius(&ds.points, 42);
    let mut c = HwCounters::new();
    let scene = Scene::build(ds.points.clone(), radius, &mut c);
    let rays: Vec<Ray> = ds
        .points
        .iter()
        .enumerate()
        .map(|(i, &p)| Ray::knn(p, i as u32))
        .collect();

    // 1 and all-cores for the trajectory, plus the acceptance point at 4
    // threads (measured even on smaller machines — oversubscription is a
    // valid sample, just bounded by the cores available).
    let max_threads = Executor::auto().threads();
    let mut thread_counts = vec![1usize, 4, max_threads];
    thread_counts.sort_unstable();
    thread_counts.dedup();
    let mut launch = Vec::new();
    for &t in &thread_counts {
        let exec = Executor::new(t);
        let mut best = f64::INFINITY;
        for it in 0..=iters {
            let mut prog = KnnProgram::new(ds.len(), 5, true);
            let mut counters = HwCounters::new();
            let sw = Stopwatch::start();
            Pipeline::launch_parallel(&scene, &rays, &mut prog, &mut counters, &exec);
            let s = sw.elapsed_secs();
            if it > 0 {
                // iteration 0 is warmup
                best = best.min(s);
            }
        }
        launch.push(LaunchRow {
            threads: t,
            seconds: best,
            rays_per_s: ds.len() as f64 / best.max(1e-12),
        });
    }
    // speedup is all-cores vs 1 thread — NOT the pinned 4-thread sample,
    // which on small machines is an oversubscription artifact
    let launch_speedup = {
        let one = launch.iter().find(|r| r.threads == 1);
        let max = launch.iter().find(|r| r.threads == max_threads);
        match (one, max) {
            (Some(one), Some(max)) if max_threads > 1 => {
                max.rays_per_s / one.rays_per_s.max(1e-12)
            }
            _ => 1.0,
        }
    };

    // ---- 2. shell re-query vs reset-per-round heap traffic ----------
    let shell_k = 5usize;
    let tds = DatasetKind::Taxi.generate(shell_n, 42);
    let mut shell_idx = IndexBuilder::new(Backend::TrueKnn)
        .seed(42)
        .build(tds.points.clone());
    let shell_res = shell_idx.knn(&tds.points, shell_k);
    let mut reset_idx = IndexBuilder::new(Backend::TrueKnn)
        .seed(42)
        .shell_requery(false)
        .build(tds.points.clone());
    let reset_res = reset_idx.knn(&tds.points, shell_k);
    let shell_exact = shell_res
        .neighbors
        .iter()
        .zip(&reset_res.neighbors)
        .all(|(a, b)| {
            a.len() == b.len()
                && a.iter()
                    .zip(b)
                    .all(|(x, y)| (x.dist - y.dist).abs() < 1e-6)
        });

    Pr2Report {
        launch_n: ds.len(),
        launch_radius: radius,
        iters,
        launch,
        launch_speedup,
        shell_n: tds.len(),
        shell_k,
        shell_rounds: shell_res.rounds.len(),
        heap_pushes_shell: shell_res.counters.heap_pushes,
        heap_pushes_reset: reset_res.counters.heap_pushes,
        shell_exact,
    }
}

pub fn to_json(r: &Pr2Report) -> Json {
    let threads: Vec<Json> = r
        .launch
        .iter()
        .map(|row| {
            Json::obj(vec![
                ("threads", Json::Num(row.threads as f64)),
                ("seconds", Json::Num(row.seconds)),
                ("rays_per_s", Json::Num(row.rays_per_s)),
            ])
        })
        .collect();
    let savings = if r.heap_pushes_reset > 0 {
        1.0 - r.heap_pushes_shell as f64 / r.heap_pushes_reset as f64
    } else {
        0.0
    };
    Json::obj(vec![
        ("bench", Json::Str("pr2".into())),
        (
            "launch",
            Json::obj(vec![
                ("dataset", Json::Str("uniform".into())),
                ("n", Json::Num(r.launch_n as f64)),
                ("k", Json::Num(5.0)),
                ("radius", Json::Num(r.launch_radius as f64)),
                ("iters", Json::Num(r.iters as f64)),
                ("threads", Json::Arr(threads)),
                ("speedup_max_vs_1", Json::Num(r.launch_speedup)),
            ]),
        ),
        (
            "trueknn_shell",
            Json::obj(vec![
                ("dataset", Json::Str("taxi".into())),
                ("n", Json::Num(r.shell_n as f64)),
                ("k", Json::Num(r.shell_k as f64)),
                ("rounds", Json::Num(r.shell_rounds as f64)),
                ("heap_pushes_shell", Json::Num(r.heap_pushes_shell as f64)),
                ("heap_pushes_reset", Json::Num(r.heap_pushes_reset as f64)),
                ("push_savings", Json::Num(savings)),
                ("results_match", Json::Bool(r.shell_exact)),
            ]),
        ),
    ])
}

pub fn render(r: &Pr2Report) -> Table {
    let mut t = Table::new(
        "PR2 microbench: parallel launch + shell re-query",
        &["metric", "value"],
    );
    for row in &r.launch {
        t.row(vec![
            format!("launch {}k rays, {} thread(s)", r.launch_n / 1000, row.threads),
            format!("{:.0} rays/s ({:.3}s)", row.rays_per_s, row.seconds),
        ]);
    }
    t.row(vec![
        "launch speedup (max vs 1 thread)".into(),
        format!("{:.2}x", r.launch_speedup),
    ]);
    t.row(vec![
        format!("TrueKNN heap pushes, shell re-query (taxi {}k)", r.shell_n / 1000),
        fmt_count(r.heap_pushes_shell),
    ]);
    t.row(vec![
        "TrueKNN heap pushes, reset-per-round".into(),
        fmt_count(r.heap_pushes_reset),
    ]);
    t.row(vec![
        "shell results exact vs baseline".into(),
        r.shell_exact.to_string(),
    ]);
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_runs_small_and_serializes() {
        let r = run(2_000, 800, 1);
        assert_eq!(r.launch_n, 2_000);
        assert!(r.launch[0].rays_per_s > 0.0);
        assert!(r.shell_exact, "shell must not change results");
        assert!(
            r.heap_pushes_shell <= r.heap_pushes_reset,
            "shell {} vs reset {}",
            r.heap_pushes_shell,
            r.heap_pushes_reset
        );
        let j = to_json(&r).to_string();
        assert!(j.contains("\"bench\":\"pr2\""));
        assert!(j.contains("heap_pushes_shell"));
        // and it must parse back
        let parsed = crate::configx::parse_json(&j).unwrap();
        assert!(parsed.get("launch").is_some());
    }
}
