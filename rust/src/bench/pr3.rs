//! The PR3 perf microbench: cache-coherent hot path, emitted as
//! `BENCH_PR3.json` so CI can archive the perf trajectory alongside
//! `BENCH_PR2.json`.
//!
//! Three measurements:
//!
//! 1. **SoA vs AoS leaf loop** — one serial launch over every point
//!    (k = 5) through the leaf-ordered SoA [`crate::store::PointStore`]
//!    vs the pre-PR AoS reference loop
//!    ([`Pipeline::launch_aos_reference`]). Same traversal, same BVH —
//!    only the inner distance loop's memory layout differs.
//! 2. **Cohort scheduling on/off** — parallel launch throughput at
//!    1 thread and all cores, with and without Morton query-cohort
//!    scheduling. Results are bitwise-identical either way (checked);
//!    only the schedule, and hence the wall-clock, moves.
//! 3. **End-to-end TrueKNN** — a full multi-round search on the taxi
//!    analog at threads {1, 4, max}, timing the complete round loop
//!    (launch + retire/compact + refit + assembly, all of which are now
//!    parallel).

use crate::configx::Json;
use crate::dataset::DatasetKind;
use crate::exec::Executor;
use crate::geom::Ray;
use crate::index::{Backend, IndexBuilder};
use crate::knn::program::KnnProgram;
use crate::knn::random_sample_radius;
use crate::rt::{HwCounters, Pipeline, Scene};
use crate::util::Stopwatch;

use super::{fmt_secs, Table};

#[derive(Clone, Debug)]
pub struct CohortRow {
    pub threads: usize,
    /// Best-of-`iters` wall seconds with cohort scheduling on / off.
    pub on_seconds: f64,
    pub off_seconds: f64,
}

impl CohortRow {
    pub fn speedup(&self) -> f64 {
        self.off_seconds / self.on_seconds.max(1e-12)
    }
}

#[derive(Clone, Debug)]
pub struct TrueKnnRow {
    pub threads: usize,
    /// Best-of-`iters` wall seconds for one full multi-round search.
    pub seconds: f64,
    pub rounds: usize,
}

#[derive(Clone, Debug)]
pub struct Pr3Report {
    pub launch_n: usize,
    pub launch_radius: f32,
    pub iters: usize,
    /// Serial (1-thread) inner-loop layout comparison.
    pub soa_seconds: f64,
    pub aos_seconds: f64,
    /// Sanity: both loops returned identical results and counters.
    pub layout_match: bool,
    pub cohort: Vec<CohortRow>,
    /// Sanity: cohort on/off returned identical results and counters.
    pub cohort_match: bool,
    pub trueknn_n: usize,
    pub trueknn: Vec<TrueKnnRow>,
}

impl Pr3Report {
    pub fn soa_speedup(&self) -> f64 {
        self.aos_seconds / self.soa_seconds.max(1e-12)
    }
}

fn heap_signature(prog: &KnnProgram) -> Vec<(u32, u32)> {
    prog.heaps
        .iter()
        .flat_map(|h| h.sorted().into_iter().map(|n| (n.idx, n.dist.to_bits())))
        .collect()
}

/// Run all three measurements. `iters` timed repetitions per
/// configuration, reporting the minimum (the least-perturbed sample).
pub fn run(launch_n: usize, trueknn_n: usize, iters: usize) -> Pr3Report {
    let iters = iters.max(1);

    // ---- 1. SoA vs AoS inner loop (serial) --------------------------
    let ds = DatasetKind::Uniform.generate(launch_n, 42);
    let radius = random_sample_radius(&ds.points, 42);
    let mut c = HwCounters::new();
    let mut scene = Scene::build(ds.points.clone(), radius, &mut c);
    let rays: Vec<Ray> = ds
        .points
        .iter()
        .enumerate()
        .map(|(i, &p)| Ray::knn(p, i as u32))
        .collect();

    // warmup + reference signature for the match checks, untimed
    let (soa_sig, soa_counters) = {
        let mut prog = KnnProgram::new(ds.len(), 5, true);
        let mut counters = HwCounters::new();
        Pipeline::launch(&scene, &rays, &mut prog, &mut counters);
        (heap_signature(&prog), counters)
    };
    let mut soa_seconds = f64::INFINITY;
    for _ in 0..iters {
        let mut prog = KnnProgram::new(ds.len(), 5, true);
        let mut counters = HwCounters::new();
        let sw = Stopwatch::start();
        Pipeline::launch(&scene, &rays, &mut prog, &mut counters);
        soa_seconds = soa_seconds.min(sw.elapsed_secs());
    }
    // the AoS copy is materialized outside the timed region: the bench
    // compares loop layouts, not a one-time gather
    let aos_points = scene.store.to_aos();
    let layout_match = {
        let mut prog = KnnProgram::new(ds.len(), 5, true);
        let mut counters = HwCounters::new();
        Pipeline::launch_aos_reference(&scene, &aos_points, &rays, &mut prog, &mut counters);
        heap_signature(&prog) == soa_sig && counters == soa_counters
    };
    let mut aos_seconds = f64::INFINITY;
    for _ in 0..iters {
        let mut prog = KnnProgram::new(ds.len(), 5, true);
        let mut counters = HwCounters::new();
        let sw = Stopwatch::start();
        Pipeline::launch_aos_reference(&scene, &aos_points, &rays, &mut prog, &mut counters);
        aos_seconds = aos_seconds.min(sw.elapsed_secs());
    }

    // ---- 2. cohort scheduling on/off × threads {1, max} -------------
    let max_threads = Executor::auto().threads();
    let mut thread_counts = vec![1usize, max_threads];
    thread_counts.sort_unstable();
    thread_counts.dedup();
    let mut cohort = Vec::new();
    let mut cohort_match = true;
    for &t in &thread_counts {
        let exec = Executor::new(t);
        let mut measure = |enabled: bool| {
            scene.cohort = enabled;
            // warmup + signature, untimed
            let sig = {
                let mut prog = KnnProgram::new(ds.len(), 5, true);
                let mut counters = HwCounters::new();
                Pipeline::launch_parallel(&scene, &rays, &mut prog, &mut counters, &exec);
                heap_signature(&prog)
            };
            let mut best = f64::INFINITY;
            for _ in 0..iters {
                let mut prog = KnnProgram::new(ds.len(), 5, true);
                let mut counters = HwCounters::new();
                let sw = Stopwatch::start();
                Pipeline::launch_parallel(&scene, &rays, &mut prog, &mut counters, &exec);
                best = best.min(sw.elapsed_secs());
            }
            (best, sig)
        };
        let (off_seconds, off_sig) = measure(false);
        let (on_seconds, on_sig) = measure(true);
        cohort_match &= on_sig == off_sig;
        cohort.push(CohortRow {
            threads: t,
            on_seconds,
            off_seconds,
        });
    }

    // ---- 3. end-to-end TrueKNN rounds at threads {1, 4, max} --------
    let tds = DatasetKind::Taxi.generate(trueknn_n, 42);
    let mut tk_threads = vec![1usize, 4, max_threads];
    tk_threads.sort_unstable();
    tk_threads.dedup();
    let mut trueknn = Vec::new();
    for &t in &tk_threads {
        let mut index = IndexBuilder::new(Backend::TrueKnn)
            .seed(42)
            .threads(t)
            .build(tds.points.clone());
        let mut best = f64::INFINITY;
        let mut rounds = 0usize;
        for it in 0..=iters {
            let sw = Stopwatch::start();
            let res = index.knn(&tds.points, 5);
            let s = sw.elapsed_secs();
            if it > 0 {
                best = best.min(s);
            }
            rounds = res.rounds.len();
        }
        trueknn.push(TrueKnnRow {
            threads: t,
            seconds: best,
            rounds,
        });
    }

    Pr3Report {
        launch_n: ds.len(),
        launch_radius: radius,
        iters,
        soa_seconds,
        aos_seconds,
        layout_match,
        cohort,
        cohort_match,
        trueknn_n: tds.len(),
        trueknn,
    }
}

pub fn to_json(r: &Pr3Report) -> Json {
    let cohort: Vec<Json> = r
        .cohort
        .iter()
        .map(|row| {
            Json::obj(vec![
                ("threads", Json::Num(row.threads as f64)),
                ("cohort_on_seconds", Json::Num(row.on_seconds)),
                ("cohort_off_seconds", Json::Num(row.off_seconds)),
                ("cohort_speedup", Json::Num(row.speedup())),
            ])
        })
        .collect();
    let trueknn: Vec<Json> = r
        .trueknn
        .iter()
        .map(|row| {
            Json::obj(vec![
                ("threads", Json::Num(row.threads as f64)),
                ("seconds", Json::Num(row.seconds)),
                ("rounds", Json::Num(row.rounds as f64)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("bench", Json::Str("pr3".into())),
        (
            "leaf_loop",
            Json::obj(vec![
                ("dataset", Json::Str("uniform".into())),
                ("n", Json::Num(r.launch_n as f64)),
                ("k", Json::Num(5.0)),
                ("radius", Json::Num(r.launch_radius as f64)),
                ("iters", Json::Num(r.iters as f64)),
                ("soa_seconds", Json::Num(r.soa_seconds)),
                ("aos_seconds", Json::Num(r.aos_seconds)),
                ("soa_speedup", Json::Num(r.soa_speedup())),
                ("results_match", Json::Bool(r.layout_match)),
            ]),
        ),
        (
            "cohort_launch",
            Json::obj(vec![
                ("dataset", Json::Str("uniform".into())),
                ("n", Json::Num(r.launch_n as f64)),
                ("rows", Json::Arr(cohort)),
                ("results_match", Json::Bool(r.cohort_match)),
            ]),
        ),
        (
            "trueknn_rounds",
            Json::obj(vec![
                ("dataset", Json::Str("taxi".into())),
                ("n", Json::Num(r.trueknn_n as f64)),
                ("k", Json::Num(5.0)),
                ("rows", Json::Arr(trueknn)),
            ]),
        ),
    ])
}

pub fn render(r: &Pr3Report) -> Table {
    let mut t = Table::new(
        "PR3 microbench: SoA leaf loop + cohort scheduling + round bookkeeping",
        &["metric", "value"],
    );
    t.row(vec![
        format!("leaf loop SoA, {}k rays serial", r.launch_n / 1000),
        fmt_secs(r.soa_seconds),
    ]);
    t.row(vec![
        "leaf loop AoS reference".into(),
        fmt_secs(r.aos_seconds),
    ]);
    t.row(vec![
        "SoA speedup (AoS / SoA)".into(),
        format!("{:.2}x", r.soa_speedup()),
    ]);
    t.row(vec![
        "layouts agree bitwise".into(),
        r.layout_match.to_string(),
    ]);
    for row in &r.cohort {
        t.row(vec![
            format!("cohort launch, {} thread(s)", row.threads),
            format!(
                "on {} / off {} ({:.2}x)",
                fmt_secs(row.on_seconds),
                fmt_secs(row.off_seconds),
                row.speedup()
            ),
        ]);
    }
    t.row(vec![
        "cohorting invisible in results".into(),
        r.cohort_match.to_string(),
    ]);
    for row in &r.trueknn {
        t.row(vec![
            format!(
                "TrueKNN end-to-end (taxi {}k, {} rounds), {} thread(s)",
                r.trueknn_n / 1000,
                row.rounds,
                row.threads
            ),
            fmt_secs(row.seconds),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_runs_small_and_serializes() {
        let r = run(2_000, 600, 1);
        assert_eq!(r.launch_n, 2_000);
        assert!(r.soa_seconds > 0.0 && r.aos_seconds > 0.0);
        assert!(r.layout_match, "SoA and AoS loops must agree bitwise");
        assert!(r.cohort_match, "cohorting must not change results");
        assert!(!r.trueknn.is_empty());
        let j = to_json(&r).to_string();
        assert!(j.contains("\"bench\":\"pr3\""));
        assert!(j.contains("soa_speedup"));
        assert!(j.contains("cohort_launch"));
        let parsed = crate::configx::parse_json(&j).unwrap();
        assert!(parsed.get("leaf_loop").is_some());
        assert!(parsed.get("trueknn_rounds").is_some());
    }
}
