//! The PR4 perf microbench: worker-pool serving throughput, emitted as
//! `BENCH_PR4.json` so CI can archive the perf trajectory alongside
//! `BENCH_PR2/PR3.json`.
//!
//! One measurement, swept over the pool dimensions: a fixed mixed-route
//! request log (alternating RT-forced and brute-forced requests, so both
//! route owners work) replayed through a [`Service`] at
//! `workers × threads` ∈ {1, 2, max} × {1, max}. The pool dimension is
//! batch-level parallelism (concurrent batches on different workers);
//! the thread dimension is launch-level parallelism inside each batch —
//! the two-level story of the pool coordinator.
//!
//! Every configuration's responses are checked bitwise against the
//! `workers = 1, threads = 1` oracle (`pool_match`): the pool must be a
//! pure throughput knob.

use crate::configx::Json;
use crate::coordinator::{KnnRequest, QueryMode, RoutePath, Service, ServiceConfig};
use crate::dataset::DatasetKind;
use crate::exec::Executor;
use crate::geom::Point3;
use crate::knn::TrueKnnParams;
use crate::util::Stopwatch;

use super::{fmt_secs, Table};

const BENCH_K: usize = 5;

#[derive(Clone, Debug)]
pub struct PoolRow {
    pub workers: usize,
    pub threads: usize,
    /// Best-of-`iters` wall seconds for one full replay of the log.
    pub seconds: f64,
    pub qps: f64,
}

#[derive(Clone, Debug)]
pub struct Pr4Report {
    pub n: usize,
    pub requests: usize,
    pub queries_per_request: usize,
    pub k: usize,
    pub iters: usize,
    /// Every `(workers, threads)` configuration returned responses
    /// bitwise-identical to the `workers = 1, threads = 1` oracle.
    pub pool_match: bool,
    pub rows: Vec<PoolRow>,
}

/// Per-response bitwise signature: route + every neighbor's (idx, dist bits).
pub(crate) type ResponseSig = (RoutePath, Vec<(u32, u32)>);

/// Deterministic request log shared by the serving benches (PR4/PR5):
/// queries are dataset slices at `stride`-spaced offsets, `mode_of`
/// picks each request's forced mode. `qpr` clamps to the dataset size
/// so degenerate CLI combinations (`--serve-queries >= --serve-n`)
/// degrade instead of panicking on an empty offset range — callers
/// must clamp the same way before computing throughput.
pub(crate) fn request_log_with(
    points: &[Point3],
    requests: usize,
    qpr: usize,
    stride: usize,
    mode_of: impl Fn(u64) -> QueryMode,
) -> Vec<KnnRequest> {
    let qpr = qpr.min(points.len());
    let span = (points.len() - qpr).max(1);
    (0..requests as u64)
        .map(|id| {
            let start = (id as usize * stride) % span;
            KnnRequest::new(id, points[start..start + qpr].to_vec(), BENCH_K)
                .with_mode(mode_of(id))
        })
        .collect()
}

/// The PR4 mixed-route log: request i is RT-forced when even,
/// brute-forced when odd.
fn request_log(points: &[Point3], requests: usize, qpr: usize) -> Vec<KnnRequest> {
    request_log_with(points, requests, qpr, 137, |id| {
        if id % 2 == 0 {
            QueryMode::Rt
        } else {
            QueryMode::Brute
        }
    })
}

/// Replay the log once (all submits, then all receives) and return the
/// wall seconds plus each response's signature, indexed by request id.
/// Shared with the PR5 sharding bench.
pub(crate) fn replay(
    handle: &crate::coordinator::ServiceHandle,
    log: &[KnnRequest],
) -> (f64, Vec<ResponseSig>) {
    let sw = Stopwatch::start();
    let receivers: Vec<_> = log
        .iter()
        // lint: allow(panic-in-lib) — bench harness: queues are sized for the log, a reject is a harness bug
        .map(|req| handle.submit(req.clone()).expect("bench queue sized for the log"))
        .collect();
    let mut sigs: Vec<ResponseSig> = vec![(RoutePath::Rt, Vec::new()); log.len()];
    for rx in receivers {
        // lint: allow(panic-in-lib) — bench harness: a dead worker or typed failure invalidates the measurement
        let resp = rx.recv().expect("worker died mid-bench").expect("request failed");
        let sig = resp
            .neighbors
            .iter()
            .flat_map(|nb| nb.iter().map(|n| (n.idx, n.dist.to_bits())))
            .collect();
        sigs[resp.id as usize] = (resp.path, sig);
    }
    (sw.elapsed_secs(), sigs)
}

/// Run the sweep. `iters` timed replays per configuration, reporting the
/// minimum (the least-perturbed sample).
pub fn run(n: usize, requests: usize, qpr: usize, iters: usize) -> Pr4Report {
    let iters = iters.max(1);
    let ds = DatasetKind::Taxi.generate(n, 42);
    // the log clamps oversized requests the same way; clamping here too
    // keeps the reported queries_per_request and q/s honest
    let qpr = qpr.min(ds.len());
    let log = request_log(&ds.points, requests, qpr);

    // the service caps its pool at RoutePath::COUNT (more workers could
    // never own a route); label the rows with the effective size
    let max_workers = Executor::auto().threads().min(RoutePath::COUNT);
    let mut worker_counts = vec![1usize, 2, max_workers];
    worker_counts.sort_unstable();
    worker_counts.dedup();
    let mut thread_counts = vec![1usize, Executor::auto().threads()];
    thread_counts.sort_unstable();
    thread_counts.dedup();

    let mut oracle: Option<Vec<ResponseSig>> = None;
    let mut pool_match = true;
    let mut rows = Vec::new();
    for &workers in &worker_counts {
        for &threads in &thread_counts {
            let cfg = ServiceConfig {
                workers,
                // size the queues for the whole log: the bench measures
                // throughput, not backpressure
                queue_depth: requests.max(256),
                trueknn: TrueKnnParams {
                    exclude_self: false,
                    threads,
                    ..Default::default()
                },
                ..Default::default()
            };
            let (svc, handle) = Service::start(ds.points.clone(), cfg);
            // untimed warmup replay: builds both route indexes, so the
            // timed replays measure serving, not construction
            let (_, sigs) = replay(&handle, &log);
            match &oracle {
                None => oracle = Some(sigs),
                Some(want) => pool_match &= &sigs == want,
            }
            let mut best = f64::INFINITY;
            for _ in 0..iters {
                let (s, sigs) = replay(&handle, &log);
                pool_match &= Some(&sigs) == oracle.as_ref();
                best = best.min(s);
            }
            svc.shutdown();
            rows.push(PoolRow {
                workers,
                threads,
                seconds: best,
                qps: (requests * qpr) as f64 / best.max(1e-12),
            });
        }
    }

    Pr4Report {
        n: ds.len(),
        requests,
        queries_per_request: qpr,
        k: BENCH_K,
        iters,
        pool_match,
        rows,
    }
}

pub fn to_json(r: &Pr4Report) -> Json {
    let rows: Vec<Json> = r
        .rows
        .iter()
        .map(|row| {
            Json::obj(vec![
                ("workers", Json::Num(row.workers as f64)),
                ("threads", Json::Num(row.threads as f64)),
                ("seconds", Json::Num(row.seconds)),
                ("qps", Json::Num(row.qps)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("bench", Json::Str("pr4".into())),
        (
            "pool_serve",
            Json::obj(vec![
                ("dataset", Json::Str("taxi".into())),
                ("n", Json::Num(r.n as f64)),
                ("requests", Json::Num(r.requests as f64)),
                ("queries_per_request", Json::Num(r.queries_per_request as f64)),
                ("k", Json::Num(r.k as f64)),
                ("iters", Json::Num(r.iters as f64)),
                ("rows", Json::Arr(rows)),
                ("results_match", Json::Bool(r.pool_match)),
            ]),
        ),
    ])
}

pub fn render(r: &Pr4Report) -> Table {
    let mut t = Table::new(
        "PR4 microbench: worker-pool serving throughput (mixed-route log)",
        &["workers", "threads", "replay", "q/s"],
    );
    for row in &r.rows {
        t.row(vec![
            row.workers.to_string(),
            row.threads.to_string(),
            fmt_secs(row.seconds),
            format!("{:.0}", row.qps),
        ]);
    }
    t.row(vec![
        "pool invisible in results".into(),
        String::new(),
        String::new(),
        r.pool_match.to_string(),
    ]);
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_runs_small_and_serializes() {
        let r = run(1_500, 12, 4, 1);
        assert_eq!(r.requests, 12);
        assert!(r.pool_match, "pool must not change responses");
        assert!(!r.rows.is_empty());
        assert!(r.rows.iter().all(|row| row.seconds > 0.0));
        let j = to_json(&r).to_string();
        assert!(j.contains("\"bench\":\"pr4\""));
        assert!(j.contains("pool_serve"));
        let parsed = crate::configx::parse_json(&j).unwrap();
        assert!(parsed.get("pool_serve").is_some());
    }
}
