//! The PR5 perf microbench: **single hot route** serving throughput
//! under spatial dataset sharding, emitted as `BENCH_PR5.json` so CI can
//! archive the perf trajectory alongside `BENCH_PR2/PR3/PR4.json`.
//!
//! The PR4 bench showed batch-level parallelism across *routes*; its
//! ceiling is one worker per route, so a log that hammers one route
//! serializes again. This bench replays an RT-only request log (every
//! request forced down the hot path) through a [`Service`] at
//! `shards × workers` ∈ {1, 2, max} × {1, max}, with the launch engine
//! pinned to one thread so the shard/worker dimension is the only
//! parallelism being measured. Unsharded rows pin the serial baseline;
//! sharded rows show the hot route spreading across `min(S, pool)`
//! workers.
//!
//! Every configuration's responses are checked bitwise against the
//! `shards = 1, workers = 1` oracle (`shard_match`): spatial sharding
//! must be a pure throughput knob.

use crate::configx::Json;
use crate::coordinator::{KnnRequest, QueryMode, RoutePath, Service, ServiceConfig};
use crate::dataset::DatasetKind;
use crate::exec::Executor;
use crate::geom::Point3;
use crate::knn::TrueKnnParams;

use super::pr4::{replay, request_log_with, ResponseSig};
use super::{fmt_secs, Table};

const BENCH_K: usize = 5;

#[derive(Clone, Debug)]
pub struct ShardRow {
    pub shards: usize,
    /// Pool size requested (0 = all cores) and the size the service
    /// actually resolved it to.
    pub workers_requested: usize,
    pub workers: usize,
    /// Best-of-`iters` wall seconds for one full replay of the log.
    pub seconds: f64,
    pub qps: f64,
}

#[derive(Clone, Debug)]
pub struct Pr5Report {
    pub n: usize,
    pub requests: usize,
    pub queries_per_request: usize,
    pub k: usize,
    pub iters: usize,
    /// Every `(shards, workers)` configuration returned responses
    /// bitwise-identical to the `shards = 1, workers = 1` oracle.
    pub shard_match: bool,
    pub rows: Vec<ShardRow>,
}

/// The hot-route log: every request RT-forced, built on the shared
/// serving-bench log helper.
fn hot_route_log(points: &[Point3], requests: usize, qpr: usize) -> Vec<KnnRequest> {
    request_log_with(points, requests, qpr, 151, |_| QueryMode::Rt)
}

/// Run the sweep. `iters` timed replays per configuration, reporting the
/// minimum (the least-perturbed sample).
pub fn run(n: usize, requests: usize, qpr: usize, iters: usize) -> Pr5Report {
    let iters = iters.max(1);
    let ds = DatasetKind::Taxi.generate(n, 42);
    // the log clamps oversized requests the same way; clamping here too
    // keeps the reported queries_per_request and q/s honest
    let qpr = qpr.min(ds.len());
    let log = hot_route_log(&ds.points, requests, qpr);

    let cores = Executor::auto().threads();
    let mut shard_counts = vec![1usize, 2, cores.clamp(2, 8)];
    shard_counts.sort_unstable();
    shard_counts.dedup();
    // 0 = all cores; the service caps the pool at the owner-slot count
    // ((COUNT - 1) + shards when sharded), so the resolved size is
    // reported per row
    let worker_counts = [1usize, 0];

    let mut oracle: Option<Vec<ResponseSig>> = None;
    let mut shard_match = true;
    let mut rows = Vec::new();
    for &shards in &shard_counts {
        for &workers in &worker_counts {
            let cfg = ServiceConfig {
                workers,
                shards,
                // size the queues for the whole scatter (requests ×
                // shards messages): the bench measures throughput, not
                // backpressure
                queue_depth: (requests * shards).max(256),
                trueknn: TrueKnnParams {
                    exclude_self: false,
                    // launch-level parallelism pinned off: the sweep
                    // isolates the shard/worker (batch-level) dimension
                    threads: 1,
                    ..Default::default()
                },
                ..Default::default()
            };
            let (svc, handle) = Service::start(ds.points.clone(), cfg);
            // untimed warmup replay on top of the eager shard builds, so
            // timed replays measure serving, not construction
            let (_, sigs) = replay(&handle, &log);
            match &oracle {
                None => oracle = Some(sigs),
                Some(want) => shard_match &= &sigs == want,
            }
            let mut best = f64::INFINITY;
            for _ in 0..iters {
                let (s, sigs) = replay(&handle, &log);
                shard_match &= Some(&sigs) == oracle.as_ref();
                best = best.min(s);
            }
            let resolved = handle.workers();
            svc.shutdown();
            rows.push(ShardRow {
                shards,
                workers_requested: workers,
                workers: resolved,
                seconds: best,
                qps: (requests * qpr) as f64 / best.max(1e-12),
            });
        }
    }

    Pr5Report {
        n: ds.len(),
        requests,
        queries_per_request: qpr,
        k: BENCH_K,
        iters,
        shard_match,
        rows,
    }
}

pub fn to_json(r: &Pr5Report) -> Json {
    let rows: Vec<Json> = r
        .rows
        .iter()
        .map(|row| {
            Json::obj(vec![
                ("shards", Json::Num(row.shards as f64)),
                ("workers_requested", Json::Num(row.workers_requested as f64)),
                ("workers", Json::Num(row.workers as f64)),
                ("seconds", Json::Num(row.seconds)),
                ("qps", Json::Num(row.qps)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("bench", Json::Str("pr5".into())),
        (
            "sharded_hot_route",
            Json::obj(vec![
                ("dataset", Json::Str("taxi".into())),
                ("n", Json::Num(r.n as f64)),
                ("requests", Json::Num(r.requests as f64)),
                ("queries_per_request", Json::Num(r.queries_per_request as f64)),
                ("k", Json::Num(r.k as f64)),
                ("iters", Json::Num(r.iters as f64)),
                ("route", Json::Str(RoutePath::Rt.name().into())),
                ("rows", Json::Arr(rows)),
                ("results_match", Json::Bool(r.shard_match)),
            ]),
        ),
    ])
}

pub fn render(r: &Pr5Report) -> Table {
    let mut t = Table::new(
        "PR5 microbench: sharded hot-route serving throughput (RT-only log)",
        &["shards", "workers", "replay", "q/s"],
    );
    for row in &r.rows {
        t.row(vec![
            row.shards.to_string(),
            format!("{} ({})", row.workers, row.workers_requested),
            fmt_secs(row.seconds),
            format!("{:.0}", row.qps),
        ]);
    }
    t.row(vec![
        "sharding invisible in results".into(),
        String::new(),
        String::new(),
        r.shard_match.to_string(),
    ]);
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_runs_small_and_serializes() {
        let r = run(1_500, 10, 4, 1);
        assert_eq!(r.requests, 10);
        assert!(r.shard_match, "sharding must not change responses");
        assert!(!r.rows.is_empty());
        assert!(r.rows.iter().all(|row| row.seconds > 0.0));
        assert!(r.rows.iter().any(|row| row.shards > 1));
        let j = to_json(&r).to_string();
        assert!(j.contains("\"bench\":\"pr5\""));
        assert!(j.contains("sharded_hot_route"));
        let parsed = crate::configx::parse_json(&j).unwrap();
        assert!(parsed.get("sharded_hot_route").is_some());
    }
}
