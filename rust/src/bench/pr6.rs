//! The PR6 perf microbench: the lint gate's own cost, emitted as
//! `BENCH_PR6.json` alongside `BENCH_PR2/.../PR5.json`.
//!
//! `trueknn lint` runs as a blocking CI job and as a tier-1 test
//! (`tests/lint_suite.rs` asserts the live tree is finding-free), so
//! the analyzer itself must never become the slow step of the gate.
//! This bench times a full lex + rule sweep over `rust/src` with the
//! repo `lint.toml` (best of `iters`) and holds it to
//! [`BUDGET_SECONDS`]; `trueknn bench` fails the run if the analyzer
//! blows the budget, putting the gate's cost on the same perf
//! trajectory CI already archives.

use crate::analysis::{self, LintConfig};
use crate::configx::Json;
use crate::util::Stopwatch;

use super::{fmt_secs, Table};

/// The analyzer must sweep the whole tree in under this many seconds.
pub const BUDGET_SECONDS: f64 = 2.0;

#[derive(Clone, Debug)]
pub struct Pr6Report {
    /// `.rs` files swept.
    pub files: usize,
    /// Source lines swept.
    pub lines: u64,
    /// Findings on the live tree (0 on a green tree).
    pub findings: usize,
    /// Best-of-`iters` wall seconds for one full sweep.
    pub lint_seconds: f64,
    /// The enforced ceiling ([`BUDGET_SECONDS`]).
    pub budget_seconds: f64,
    pub iters: usize,
}

impl Pr6Report {
    /// Did the sweep stay under the CI budget?
    pub fn under_budget(&self) -> bool {
        self.lint_seconds < self.budget_seconds
    }
}

/// Time the analyzer over the crate's own `src/` with the repo
/// `lint.toml`. Paths resolve via `CARGO_MANIFEST_DIR`, so this works
/// from any working directory on the machine that built the binary.
pub fn run(iters: usize) -> Result<Pr6Report, String> {
    let manifest = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
    let cfg = LintConfig::load(&manifest.join("lint.toml"))?;
    let root = manifest.join("src");
    let mut best = f64::INFINITY;
    let mut last = None;
    for _ in 0..iters.max(1) {
        let sw = Stopwatch::start();
        let report = analysis::run_tree(&root, &cfg)?;
        let s = sw.elapsed_secs();
        if s < best {
            best = s;
        }
        last = Some(report);
    }
    let report = last.ok_or("lint bench produced no report")?;
    Ok(Pr6Report {
        files: report.files,
        lines: report.lines,
        findings: report.findings.len(),
        lint_seconds: best,
        budget_seconds: BUDGET_SECONDS,
        iters: iters.max(1),
    })
}

pub fn to_json(r: &Pr6Report) -> Json {
    Json::obj(vec![
        ("files", Json::Num(r.files as f64)),
        ("lines", Json::Num(r.lines as f64)),
        ("findings", Json::Num(r.findings as f64)),
        ("lint_seconds", Json::Num(r.lint_seconds)),
        ("budget_seconds", Json::Num(r.budget_seconds)),
        ("under_budget", Json::Bool(r.under_budget())),
        ("iters", Json::Num(r.iters as f64)),
    ])
}

pub fn render(r: &Pr6Report) -> Table {
    let mut t = Table::new(
        "PR6: determinism-lint gate cost",
        &["files", "lines", "findings", "lint", "budget", "ok"],
    );
    t.row(vec![
        r.files.to_string(),
        r.lines.to_string(),
        r.findings.to_string(),
        fmt_secs(r.lint_seconds),
        fmt_secs(r.budget_seconds),
        if r.under_budget() { "yes" } else { "NO" }.to_string(),
    ]);
    t
}
