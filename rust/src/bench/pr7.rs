//! The PR7 robustness microbench: recovery cost of the supervised
//! coordinator, emitted as `BENCH_PR7.json` so CI can archive the
//! robustness trajectory alongside the perf benches.
//!
//! One scenario, measured twice. A fixed RT-forced request log is
//! replayed sequentially through a two-worker pool — once under the
//! inert fault plan (baseline), once with a panic injected into the
//! route owner halfway through the timed replay (faulted). Each timed
//! replay runs on a fresh service after an untimed warmup replay, so
//! both runs pay identical build costs and the gap between them is the
//! end-to-end price of a worker death: supervised restart,
//! deterministic rebuild and journal replay. Every faulted replay's
//! responses are checked bitwise against the baseline — recovery must
//! be invisible in results, visible only in wall-clock and the
//! `restarts`/`replays` counters.

use std::time::Duration;

use crate::configx::Json;
use crate::coordinator::{
    KnnRequest, QueryMode, RoutePath, Router, Service, ServiceConfig, ServiceHandle,
};
use crate::dataset::DatasetKind;
use crate::faults::FaultPlan;
use crate::util::Stopwatch;

use super::pr4::{request_log_with, ResponseSig};
use super::{fmt_secs, Table};

const BENCH_K: usize = 5;

#[derive(Clone, Debug)]
pub struct Pr7Report {
    pub n: usize,
    pub requests: usize,
    pub queries_per_request: usize,
    pub k: usize,
    pub iters: usize,
    /// Best-of-`iters` wall seconds for the no-fault sequential replay.
    pub baseline_s: f64,
    /// Best-of-`iters` wall seconds with one worker kill mid-replay.
    pub faulted_s: f64,
    /// Wall-clock price of the kill: `faulted_s - baseline_s`, floored
    /// at zero (the time-to-recover headline number).
    pub recover_s: f64,
    /// `faulted_s / baseline_s`: the replay overhead factor.
    pub overhead: f64,
    /// Supervised restarts observed in the last faulted run (must be 1).
    pub restarts: u64,
    /// Journal replays observed in the last faulted run (must be 1).
    pub replays: u64,
    /// Every replay — baseline and faulted — answered bitwise
    /// identically to the first baseline replay.
    pub results_match: bool,
}

/// Replay the log one request at a time (so the victim's batch sequence
/// numbers are exact and the kill lands mid-log deterministically) and
/// return wall seconds plus each response's signature, in log order.
fn replay_sequential(handle: &ServiceHandle, log: &[KnnRequest]) -> (f64, Vec<ResponseSig>) {
    let sw = Stopwatch::start();
    let mut sigs: Vec<ResponseSig> = Vec::with_capacity(log.len());
    for req in log {
        // lint: allow(panic-in-lib) — bench harness: a lost request under a recoverable plan invalidates the measurement
        let resp = handle.query(req.clone()).expect("recoverable plan lost a request");
        sigs.push((
            resp.path,
            resp.neighbors
                .iter()
                .flat_map(|nb| nb.iter().map(|n| (n.idx, n.dist.to_bits())))
                .collect(),
        ));
    }
    (sw.elapsed_secs(), sigs)
}

/// Run the bench. `iters` timed replays per scenario, reporting the
/// minimum (the least-perturbed sample).
pub fn run(n: usize, requests: usize, qpr: usize, iters: usize) -> Pr7Report {
    let iters = iters.max(1);
    let requests = requests.max(2);
    let ds = DatasetKind::Taxi.generate(n, 42);
    let qpr = qpr.min(ds.len());
    let log = request_log_with(&ds.points, requests, qpr, 131, |_| QueryMode::Rt);
    let victim = Router::worker_for(RoutePath::Rt, 2);
    // the warmup replay drains the victim's sequences 0..requests, so a
    // kill halfway into the timed replay lands at requests + requests/2
    let kill_seq = requests as u64 + requests as u64 / 2;

    let run_once = |faults: &FaultPlan| {
        let cfg = ServiceConfig {
            workers: 2,
            // throughput is the measurement, not backpressure
            queue_depth: requests.max(256),
            // the restart path is what we price here; keep the failover
            // monitor out of the measurement
            heartbeat_timeout: Duration::from_secs(5),
            faults: faults.clone(),
            ..Default::default()
        };
        let (svc, handle) = Service::start(ds.points.clone(), cfg);
        // untimed warmup: builds both route indexes, never trips the kill
        let _ = replay_sequential(&handle, &log);
        let (s, sigs) = replay_sequential(&handle, &log);
        let m = handle.metrics().snapshot();
        svc.shutdown();
        (s, sigs, m.restarts, m.replays)
    };

    let mut oracle: Option<Vec<ResponseSig>> = None;
    let mut results_match = true;
    let mut baseline_s = f64::INFINITY;
    for _ in 0..iters {
        let (s, sigs, _, _) = run_once(&FaultPlan::inert());
        match &oracle {
            None => oracle = Some(sigs),
            Some(want) => results_match &= &sigs == want,
        }
        baseline_s = baseline_s.min(s);
    }

    let kill = FaultPlan::inert().with_panic(victim, kill_seq);
    let mut faulted_s = f64::INFINITY;
    let (mut restarts, mut replays) = (0u64, 0u64);
    for _ in 0..iters {
        let (s, sigs, r, rp) = run_once(&kill);
        results_match &= Some(&sigs) == oracle.as_ref();
        faulted_s = faulted_s.min(s);
        restarts = r;
        replays = rp;
    }

    Pr7Report {
        n: ds.len(),
        requests,
        queries_per_request: qpr,
        k: BENCH_K,
        iters,
        baseline_s,
        faulted_s,
        recover_s: (faulted_s - baseline_s).max(0.0),
        overhead: faulted_s / baseline_s.max(1e-12),
        restarts,
        replays,
        results_match,
    }
}

pub fn to_json(r: &Pr7Report) -> Json {
    Json::obj(vec![
        ("bench", Json::Str("pr7".into())),
        (
            "fault_recovery",
            Json::obj(vec![
                ("dataset", Json::Str("taxi".into())),
                ("n", Json::Num(r.n as f64)),
                ("requests", Json::Num(r.requests as f64)),
                ("queries_per_request", Json::Num(r.queries_per_request as f64)),
                ("k", Json::Num(r.k as f64)),
                ("iters", Json::Num(r.iters as f64)),
                ("baseline_seconds", Json::Num(r.baseline_s)),
                ("faulted_seconds", Json::Num(r.faulted_s)),
                ("time_to_recover_seconds", Json::Num(r.recover_s)),
                ("replay_overhead", Json::Num(r.overhead)),
                ("restarts", Json::Num(r.restarts as f64)),
                ("replays", Json::Num(r.replays as f64)),
                ("results_match", Json::Bool(r.results_match)),
            ]),
        ),
    ])
}

pub fn render(r: &Pr7Report) -> Table {
    let mut t = Table::new(
        "PR7 microbench: supervised recovery cost (one worker kill mid-replay)",
        &["run", "replay", "restarts", "replays"],
    );
    t.row(vec![
        "baseline".into(),
        fmt_secs(r.baseline_s),
        "0".into(),
        "0".into(),
    ]);
    t.row(vec![
        "faulted".into(),
        fmt_secs(r.faulted_s),
        r.restarts.to_string(),
        r.replays.to_string(),
    ]);
    t.row(vec![
        "time to recover".into(),
        fmt_secs(r.recover_s),
        String::new(),
        String::new(),
    ]);
    t.row(vec![
        "recovery invisible in results".into(),
        r.results_match.to_string(),
        String::new(),
        String::new(),
    ]);
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_runs_small_and_serializes() {
        let r = run(1_200, 8, 4, 1);
        assert_eq!(r.restarts, 1, "the injected kill must land");
        assert_eq!(r.replays, 1, "the in-flight request must replay once");
        assert!(r.results_match, "recovery must not change responses");
        assert!(r.baseline_s > 0.0 && r.faulted_s > 0.0);
        assert!(r.recover_s >= 0.0 && r.overhead > 0.0);
        let j = to_json(&r).to_string();
        assert!(j.contains("\"bench\":\"pr7\""));
        assert!(j.contains("fault_recovery"));
        assert!(j.contains("time_to_recover_seconds"));
        let parsed = crate::configx::parse_json(&j).unwrap();
        assert!(parsed.get("fault_recovery").is_some());
    }
}
