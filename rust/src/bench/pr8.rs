//! The PR8 durability microbench: what crash-safe persistence costs and
//! what it buys, emitted as `BENCH_PR8.json` so CI archives the
//! durability trajectory alongside the perf and robustness benches.
//!
//! Three measurements:
//!
//! 1. **Cold start** — for each dataset size, the wall cost of loading
//!    a checksummed snapshot versus rebuilding the TrueKNN index from
//!    raw points. The ratio is the headline number: how much faster a
//!    recovered process reaches "serving" than a rebuilt one. Every
//!    loaded index is checked bitwise against its original — a snapshot
//!    that loads fast but answers differently is worthless.
//! 2. **WAL replay** — records per second a cold start can re-apply
//!    from a group-committed log (the recovery path's other half).
//! 3. **Insert overhead** — the durable-insert tax: wall cost of an
//!    insert stream through [`crate::coordinator::ServiceHandle`] with
//!    the WAL fence on versus off.

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use crate::configx::Json;
use crate::coordinator::{PersistConfig, Service, ServiceConfig};
use crate::dataset::DatasetKind;
use crate::faults::FaultPlan;
use crate::geom::Point3;
use crate::index::{Backend, IndexBuilder, IndexConfig};
use crate::persist::Wal;
use crate::util::Stopwatch;

use super::{fmt_secs, Table};

const BENCH_K: usize = 5;
const BENCH_QUERIES: usize = 32;
const WAL_RECORDS: usize = 512;
const WAL_POINTS_PER_RECORD: usize = 8;
const INSERT_BATCHES: usize = 64;

/// Cold-start load vs rebuild at one dataset size.
#[derive(Clone, Debug)]
pub struct Pr8SizeRow {
    pub n: usize,
    /// Best-of-iters wall seconds to load + validate the snapshot blob.
    pub load_s: f64,
    /// Best-of-iters wall seconds to rebuild the index from raw points.
    pub rebuild_s: f64,
    /// `rebuild_s / load_s`: cold-start speedup bought by the snapshot.
    pub speedup: f64,
    /// Loaded index answered bitwise-identically to the original.
    pub results_match: bool,
}

#[derive(Clone, Debug)]
pub struct Pr8Report {
    pub k: usize,
    pub iters: usize,
    pub sizes: Vec<Pr8SizeRow>,
    /// WAL records appended and then replayed.
    pub wal_records: usize,
    pub wal_points_per_record: usize,
    /// Best-of-iters wall seconds to replay the whole log at open.
    pub wal_replay_s: f64,
    /// `wal_records / wal_replay_s`.
    pub wal_records_per_s: f64,
    /// Best-of-iters wall seconds for the insert stream, memory-only.
    pub insert_mem_s: f64,
    /// Same stream with the fsynced WAL fence ahead of every broadcast.
    pub insert_wal_s: f64,
    /// `insert_wal_s / insert_mem_s`: the durability tax.
    pub insert_overhead: f64,
    /// Every cold-start row answered bitwise-identically (the CI gate).
    pub results_match: bool,
}

/// A unique scratch directory per call (parallel bench/test runs).
fn bench_dir() -> PathBuf {
    static N: AtomicU64 = AtomicU64::new(0);
    let d = std::env::temp_dir().join(format!(
        "trueknn-bench-pr8-{}-{}",
        std::process::id(),
        N.fetch_add(1, Ordering::Relaxed)
    ));
    // lint: allow(panic-in-lib) — bench harness: an unusable temp dir invalidates the measurement
    std::fs::create_dir_all(&d).expect("bench temp dir");
    d
}

fn rt_make() -> IndexBuilder {
    IndexBuilder::new(Backend::TrueKnn).config(IndexConfig {
        seed: 42,
        ..Default::default()
    })
}

/// Bitwise knn signature over the bench query set.
fn knn_sig(index: &mut dyn crate::index::NeighborIndex, queries: &[Point3]) -> Vec<(u32, u32)> {
    index
        .knn(queries, BENCH_K)
        .neighbors
        .iter()
        .flat_map(|nb| nb.iter().map(|n| (n.idx, n.dist.to_bits())))
        .collect()
}

fn cold_start_row(n: usize, iters: usize) -> Pr8SizeRow {
    let ds = DatasetKind::Taxi.generate(n, 42);
    let queries = ds.points[..BENCH_QUERIES.min(ds.len())].to_vec();
    let mut built = rt_make().build(ds.points.clone());
    let bytes = rt_make().snapshot(built.as_ref(), 0);

    let mut load_s = f64::INFINITY;
    let mut loaded = None;
    for _ in 0..iters {
        let sw = Stopwatch::start();
        // lint: allow(panic-in-lib) — bench harness: a snapshot we just wrote failing to load invalidates the measurement
        let (ix, _) = rt_make().load(&bytes).expect("own snapshot loads");
        load_s = load_s.min(sw.elapsed_secs());
        loaded = Some(ix);
    }
    let mut rebuild_s = f64::INFINITY;
    for _ in 0..iters {
        let data = ds.points.clone();
        let sw = Stopwatch::start();
        let ix = rt_make().build(data);
        rebuild_s = rebuild_s.min(sw.elapsed_secs());
        std::hint::black_box(ix.len());
    }
    // lint: allow(panic-in-lib) — bench harness: iters >= 1, loaded is always set
    let mut loaded = loaded.expect("at least one load iteration");
    let results_match = knn_sig(loaded.as_mut(), &queries) == knn_sig(built.as_mut(), &queries);
    Pr8SizeRow {
        n: ds.len(),
        load_s,
        rebuild_s,
        speedup: rebuild_s / load_s.max(1e-12),
        results_match,
    }
}

fn wal_replay(iters: usize) -> (f64, f64) {
    let dir = bench_dir();
    let path = dir.join("wal.log");
    let batch = DatasetKind::Uniform.generate(WAL_POINTS_PER_RECORD, 7).points;
    {
        // a wide group-commit window: appends are the setup, not the
        // measurement — one fsync at the end
        // lint: allow(panic-in-lib) — bench harness: a broken scratch WAL invalidates the measurement
        let (mut wal, _) = Wal::open(&path, u64::MAX, FaultPlan::inert()).expect("open bench WAL");
        for _ in 0..WAL_RECORDS {
            // lint: allow(panic-in-lib) — bench harness: a failed setup append invalidates the measurement
            wal.append(&batch).expect("bench WAL append");
        }
        // lint: allow(panic-in-lib) — bench harness: a failed setup fsync invalidates the measurement
        wal.sync().expect("bench WAL sync");
    }
    let mut replay_s = f64::INFINITY;
    for _ in 0..iters {
        let sw = Stopwatch::start();
        // lint: allow(panic-in-lib) — bench harness: a log we just wrote failing to replay invalidates the measurement
        let (_, records) = Wal::open(&path, 1, FaultPlan::inert()).expect("replay bench WAL");
        replay_s = replay_s.min(sw.elapsed_secs());
        std::hint::black_box(records.len());
    }
    let _ = std::fs::remove_dir_all(&dir);
    (replay_s, WAL_RECORDS as f64 / replay_s.max(1e-12))
}

fn insert_stream(base: &[Point3], batches: &[Vec<Point3>], persist: Option<PersistConfig>) -> f64 {
    let cfg = ServiceConfig {
        workers: 2,
        queue_depth: 256,
        heartbeat_timeout: Duration::from_secs(5),
        persist,
        ..Default::default()
    };
    let (svc, handle) = Service::start(base.to_vec(), cfg);
    let sw = Stopwatch::start();
    for b in batches {
        // lint: allow(panic-in-lib) — bench harness: a refused insert under an inert plan invalidates the measurement
        handle.insert(b).expect("bench insert");
    }
    let s = sw.elapsed_secs();
    svc.shutdown();
    s
}

/// Run the bench: cold-start rows for each size in `sizes`, the WAL
/// replay rate, and the durable-insert overhead; `iters` timed samples
/// per measurement, reporting the minimum.
pub fn run(sizes: &[usize], iters: usize) -> Pr8Report {
    let iters = iters.max(1);
    let rows: Vec<Pr8SizeRow> = sizes.iter().map(|&n| cold_start_row(n, iters)).collect();
    let (wal_replay_s, wal_records_per_s) = wal_replay(iters);

    let base = DatasetKind::Taxi.generate(2_000, 42).points;
    let batches: Vec<Vec<Point3>> = (0..INSERT_BATCHES)
        .map(|i| DatasetKind::Uniform.generate(WAL_POINTS_PER_RECORD, 100 + i as u64).points)
        .collect();
    let mut insert_mem_s = f64::INFINITY;
    let mut insert_wal_s = f64::INFINITY;
    for _ in 0..iters {
        insert_mem_s = insert_mem_s.min(insert_stream(&base, &batches, None));
        // a fresh directory per sample: reusing one would replay the
        // previous sample's records into the service at start
        let dir = bench_dir();
        let durable = Some(PersistConfig::at(&dir));
        insert_wal_s = insert_wal_s.min(insert_stream(&base, &batches, durable));
        let _ = std::fs::remove_dir_all(&dir);
    }

    let results_match = rows.iter().all(|r| r.results_match);
    Pr8Report {
        k: BENCH_K,
        iters,
        sizes: rows,
        wal_records: WAL_RECORDS,
        wal_points_per_record: WAL_POINTS_PER_RECORD,
        wal_replay_s,
        wal_records_per_s,
        insert_mem_s,
        insert_wal_s,
        insert_overhead: insert_wal_s / insert_mem_s.max(1e-12),
        results_match,
    }
}

pub fn to_json(r: &Pr8Report) -> Json {
    let rows: Vec<Json> = r
        .sizes
        .iter()
        .map(|row| {
            Json::obj(vec![
                ("n", Json::Num(row.n as f64)),
                ("load_seconds", Json::Num(row.load_s)),
                ("rebuild_seconds", Json::Num(row.rebuild_s)),
                ("cold_start_speedup", Json::Num(row.speedup)),
                ("results_match", Json::Bool(row.results_match)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("bench", Json::Str("pr8".into())),
        (
            "crash_recovery",
            Json::obj(vec![
                ("dataset", Json::Str("taxi".into())),
                ("k", Json::Num(r.k as f64)),
                ("iters", Json::Num(r.iters as f64)),
                ("cold_start", Json::Arr(rows)),
                (
                    "wal",
                    Json::obj(vec![
                        ("records", Json::Num(r.wal_records as f64)),
                        ("points_per_record", Json::Num(r.wal_points_per_record as f64)),
                        ("replay_seconds", Json::Num(r.wal_replay_s)),
                        ("records_per_second", Json::Num(r.wal_records_per_s)),
                    ]),
                ),
                (
                    "insert",
                    Json::obj(vec![
                        ("batches", Json::Num(INSERT_BATCHES as f64)),
                        ("memory_seconds", Json::Num(r.insert_mem_s)),
                        ("wal_seconds", Json::Num(r.insert_wal_s)),
                        ("durability_overhead", Json::Num(r.insert_overhead)),
                    ]),
                ),
                ("results_match", Json::Bool(r.results_match)),
            ]),
        ),
    ])
}

pub fn render(r: &Pr8Report) -> Table {
    let mut t = Table::new(
        "PR8 microbench: crash-safe persistence (cold start, WAL replay, insert tax)",
        &["measurement", "load/wal", "rebuild/mem", "ratio"],
    );
    for row in &r.sizes {
        t.row(vec![
            format!("cold start n={}", row.n),
            fmt_secs(row.load_s),
            fmt_secs(row.rebuild_s),
            format!("{:.1}x", row.speedup),
        ]);
    }
    t.row(vec![
        format!("wal replay ({} rec)", r.wal_records),
        fmt_secs(r.wal_replay_s),
        String::new(),
        format!("{:.0} rec/s", r.wal_records_per_s),
    ]);
    t.row(vec![
        format!("insert stream ({} batches)", INSERT_BATCHES),
        fmt_secs(r.insert_wal_s),
        fmt_secs(r.insert_mem_s),
        format!("{:.2}x", r.insert_overhead),
    ]);
    t.row(vec![
        "snapshots answer identically".into(),
        r.results_match.to_string(),
        String::new(),
        String::new(),
    ]);
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_runs_small_and_serializes() {
        let r = run(&[600, 1_200], 1);
        assert_eq!(r.sizes.len(), 2);
        assert!(r.results_match, "loaded snapshots must answer identically");
        for row in &r.sizes {
            assert!(row.load_s > 0.0 && row.rebuild_s > 0.0 && row.speedup > 0.0);
        }
        assert!(r.wal_replay_s > 0.0 && r.wal_records_per_s > 0.0);
        assert!(r.insert_mem_s > 0.0 && r.insert_wal_s > 0.0 && r.insert_overhead > 0.0);
        let j = to_json(&r).to_string();
        assert!(j.contains("\"bench\":\"pr8\""));
        assert!(j.contains("crash_recovery"));
        assert!(j.contains("cold_start_speedup"));
        assert!(j.contains("records_per_second"));
        let parsed = crate::configx::parse_json(&j).unwrap();
        assert!(parsed.get("crash_recovery").is_some());
    }
}
