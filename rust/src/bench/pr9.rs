//! The PR9 perf microbench: what the pipelined scatter-gather bought,
//! emitted as `BENCH_PR9.json` so CI archives it alongside the earlier
//! perf benches.
//!
//! Three measurements:
//!
//! 1. **Pipelined serving** — the PR5 hot-route replay re-measured on
//!    the incremental gather: each shard owner merges its partial into
//!    the gather as it finishes (the merge itself fanned through the
//!    exec engine), replacing PR5's single O(queries·k·S) pass on the
//!    last owner. Same sweep shape as PR5 (`shards × workers` ∈
//!    {1, 2, max} × {1, max}, launch engine pinned to one thread) so the
//!    rows are directly comparable against `BENCH_PR5.json`.
//! 2. **Speculation ablation** — `IndexConfig::speculation` ∈ {0, 2} at
//!    shards {1, 2, max} × threads {1, max} on the library-level
//!    sharded index: the two-phase plan's parallel unpruned fan over the
//!    nearest shards versus the fully serial pruned walk
//!    (`speculation = 0`).
//! 3. **Fenced inserts** — one insert + probe workload run two ways:
//!    *pipelined* (every insert acknowledged back-to-back, then every
//!    probe — owners pull the whole log suffix in one catch-up) versus
//!    *lockstep* (a scattered probe after every insert, which is the
//!    visibility barrier the retired broadcast design imposed on each
//!    insert). The final probe of both runs lands on the same fence and
//!    must answer bitwise-identically.
//!
//! Every serving row is checked bitwise against the
//! `shards = 1, workers = 1` oracle and every ablation row against the
//! unsharded serial `speculation = 0` oracle; `results_match` is the CI
//! gate over all three sections.

use std::time::Duration;

use crate::configx::Json;
use crate::coordinator::{KnnRequest, KnnResponse, QueryMode, RoutePath, Service, ServiceConfig};
use crate::dataset::DatasetKind;
use crate::exec::Executor;
use crate::geom::Point3;
use crate::index::{Backend, IndexBuilder, IndexConfig};
use crate::knn::TrueKnnParams;
use crate::util::Stopwatch;

use super::pr4::{replay, request_log_with, ResponseSig};
use super::{fmt_secs, Table};

const BENCH_K: usize = 5;
const SPEC_QUERIES: usize = 192;
const INSERT_BATCHES: usize = 32;
const INSERT_POINTS: usize = 8;
const PROBE_QUERIES: usize = 4;

/// One serving-sweep configuration on the incremental gather.
#[derive(Clone, Debug)]
pub struct ServeRow {
    pub shards: usize,
    /// Pool size requested (0 = all cores) and the size the service
    /// actually resolved it to.
    pub workers_requested: usize,
    pub workers: usize,
    /// Best-of-`iters` wall seconds for one full replay of the log.
    pub seconds: f64,
    pub qps: f64,
}

/// One speculation-ablation configuration on the library sharded index.
#[derive(Clone, Debug)]
pub struct SpecRow {
    pub shards: usize,
    /// Exec threads requested (0 = all cores) and the resolved count.
    pub threads_requested: usize,
    pub threads: usize,
    pub speculation: usize,
    /// Best-of-`iters` wall seconds for one knn pass over the queries.
    pub seconds: f64,
    pub qps: f64,
}

#[derive(Clone, Debug)]
pub struct Pr9Report {
    pub n: usize,
    pub requests: usize,
    pub queries_per_request: usize,
    pub k: usize,
    pub iters: usize,
    pub serve_rows: Vec<ServeRow>,
    /// Every serving row answered bitwise-identically to the
    /// `shards = 1, workers = 1` oracle.
    pub serve_match: bool,
    pub spec_queries: usize,
    pub spec_rows: Vec<SpecRow>,
    /// Every ablation row answered bitwise-identically to the unsharded
    /// serial `speculation = 0` oracle.
    pub spec_match: bool,
    pub insert_shards: usize,
    pub insert_batches: usize,
    pub insert_points: usize,
    pub probe_queries: usize,
    /// Best-of-`iters` wall seconds: all inserts acked, then all probes.
    pub pipelined_s: f64,
    /// Best-of-`iters` wall seconds: a scattered probe after every
    /// insert (the retired broadcast barrier's visibility schedule).
    pub lockstep_s: f64,
    /// `lockstep_s / pipelined_s`.
    pub insert_speedup: f64,
    /// The final probe (same fence in both runs) answered
    /// bitwise-identically.
    pub insert_match: bool,
    /// All three bitwise gates together (the CI gate).
    pub results_match: bool,
}

/// Bitwise response signature: every neighbor's (idx, dist bits).
fn resp_sig(resp: &KnnResponse) -> Vec<(u32, u32)> {
    resp.neighbors
        .iter()
        .flat_map(|nb| nb.iter().map(|n| (n.idx, n.dist.to_bits())))
        .collect()
}

/// Section 1: the PR5 sweep replayed through the incremental gather.
fn serve_sweep(
    points: &[Point3],
    requests: usize,
    qpr: usize,
    iters: usize,
) -> (Vec<ServeRow>, bool) {
    let log = request_log_with(points, requests, qpr, 163, |_| QueryMode::Rt);
    let cores = Executor::auto().threads();
    let mut shard_counts = vec![1usize, 2, cores.clamp(2, 8)];
    shard_counts.sort_unstable();
    shard_counts.dedup();
    let worker_counts = [1usize, 0];

    let mut oracle: Option<Vec<ResponseSig>> = None;
    let mut serve_match = true;
    let mut rows = Vec::new();
    for &shards in &shard_counts {
        for &workers in &worker_counts {
            let cfg = ServiceConfig {
                workers,
                shards,
                // size the queues for the whole scatter (requests ×
                // shards messages): the bench measures throughput, not
                // backpressure
                queue_depth: (requests * shards).max(256),
                trueknn: TrueKnnParams {
                    exclude_self: false,
                    // launch-level parallelism pinned off: the sweep
                    // isolates the shard/worker (gather) dimension
                    threads: 1,
                    ..Default::default()
                },
                ..Default::default()
            };
            let (svc, handle) = Service::start(points.to_vec(), cfg);
            // untimed warmup replay on top of the eager shard builds, so
            // timed replays measure serving, not construction
            let (_, sigs) = replay(&handle, &log);
            match &oracle {
                None => oracle = Some(sigs),
                Some(want) => serve_match &= &sigs == want,
            }
            let mut best = f64::INFINITY;
            for _ in 0..iters {
                let (s, sigs) = replay(&handle, &log);
                serve_match &= Some(&sigs) == oracle.as_ref();
                best = best.min(s);
            }
            let resolved = handle.workers();
            svc.shutdown();
            rows.push(ServeRow {
                shards,
                workers_requested: workers,
                workers: resolved,
                seconds: best,
                qps: (requests * qpr) as f64 / best.max(1e-12),
            });
        }
    }
    (rows, serve_match)
}

/// Section 2: the speculative shard fan ablated on the library index.
fn spec_sweep(points: &[Point3], iters: usize) -> (usize, Vec<SpecRow>, bool) {
    let queries = points[..SPEC_QUERIES.min(points.len())].to_vec();
    let cores = Executor::auto().threads();
    let mut shard_counts = vec![1usize, 2, cores.clamp(2, 8)];
    shard_counts.sort_unstable();
    shard_counts.dedup();
    let thread_counts = [1usize, 0];

    let mut oracle: Option<Vec<(u32, u32)>> = None;
    let mut spec_match = true;
    let mut rows = Vec::new();
    for &shards in &shard_counts {
        for &threads in &thread_counts {
            // speculation is a property of the sharded walk; the
            // unsharded rows pin the oracle and skip the redundant knob
            let widths: &[usize] = if shards <= 1 { &[0] } else { &[0, 2] };
            for &speculation in widths {
                let mut index = IndexBuilder::new(Backend::TrueKnn)
                    .config(IndexConfig {
                        exclude_self: false,
                        seed: 42,
                        threads,
                        shards,
                        speculation,
                        ..Default::default()
                    })
                    .build(points.to_vec());
                // untimed warmup: the first pass settles any lazy state
                // so timed passes measure the walk, not construction
                let sig: Vec<(u32, u32)> = index
                    .knn(&queries, BENCH_K)
                    .neighbors
                    .iter()
                    .flat_map(|nb| nb.iter().map(|n| (n.idx, n.dist.to_bits())))
                    .collect();
                match &oracle {
                    None => oracle = Some(sig),
                    Some(want) => spec_match &= &sig == want,
                }
                let mut best = f64::INFINITY;
                for _ in 0..iters {
                    let sw = Stopwatch::start();
                    let res = index.knn(&queries, BENCH_K);
                    best = best.min(sw.elapsed_secs());
                    std::hint::black_box(res.neighbors.len());
                }
                rows.push(SpecRow {
                    shards,
                    threads_requested: threads,
                    threads: if threads == 0 { cores } else { threads },
                    speculation,
                    seconds: best,
                    qps: queries.len() as f64 / best.max(1e-12),
                });
            }
        }
    }
    (queries.len(), rows, spec_match)
}

fn insert_cfg(shards: usize, requests: usize) -> ServiceConfig {
    ServiceConfig {
        workers: 0,
        shards,
        queue_depth: (requests * shards * 2).max(256),
        heartbeat_timeout: Duration::from_secs(5),
        trueknn: TrueKnnParams {
            exclude_self: false,
            threads: 1,
            ..Default::default()
        },
        ..Default::default()
    }
}

/// Section 3, pipelined schedule: every insert acknowledged first (the
/// ack waits only on the log append + advance sends), then every probe
/// — owners catch up to the full fence once, amortizing the structure
/// maintenance. Returns wall seconds and the final probe's signature.
fn pipelined_run(
    base: &[Point3],
    batches: &[Vec<Point3>],
    probes: &[Point3],
    shards: usize,
) -> (f64, Vec<(u32, u32)>) {
    let (svc, handle) = Service::start(base.to_vec(), insert_cfg(shards, batches.len() + 1));
    warm_probe(&handle, probes);
    let sw = Stopwatch::start();
    for b in batches {
        // lint: allow(panic-in-lib) — bench harness: a refused insert under an inert plan invalidates the measurement
        handle.insert(b).expect("bench insert");
    }
    let receivers: Vec<_> = (0..batches.len() as u64)
        .map(|i| {
            let req = KnnRequest::new(1 + i, probes.to_vec(), BENCH_K).with_mode(QueryMode::Rt);
            // lint: allow(panic-in-lib) — bench harness: queues are sized for the run, a reject is a harness bug
            handle.submit(req).expect("bench queue sized for the probes")
        })
        .collect();
    let mut last = Vec::new();
    for rx in receivers {
        // lint: allow(panic-in-lib) — bench harness: a dead worker or typed failure invalidates the measurement
        let resp = rx.recv().expect("worker died mid-bench").expect("probe failed");
        last = resp_sig(&resp);
    }
    let s = sw.elapsed_secs();
    svc.shutdown();
    (s, last)
}

/// Section 3, lockstep schedule: a scattered probe is forced to
/// completion after every insert, so each insert is fully applied on
/// every shard owner before the next is submitted — the per-insert
/// visibility barrier the retired broadcast design imposed.
fn lockstep_run(
    base: &[Point3],
    batches: &[Vec<Point3>],
    probes: &[Point3],
    shards: usize,
) -> (f64, Vec<(u32, u32)>) {
    let (svc, handle) = Service::start(base.to_vec(), insert_cfg(shards, batches.len() + 1));
    warm_probe(&handle, probes);
    let sw = Stopwatch::start();
    let mut last = Vec::new();
    for (i, b) in batches.iter().enumerate() {
        // lint: allow(panic-in-lib) — bench harness: a refused insert under an inert plan invalidates the measurement
        handle.insert(b).expect("bench insert");
        let req =
            KnnRequest::new(1 + i as u64, probes.to_vec(), BENCH_K).with_mode(QueryMode::Rt);
        // lint: allow(panic-in-lib) — bench harness: queues are sized for the run, a reject is a harness bug
        let rx = handle.submit(req).expect("bench queue sized for the probes");
        // lint: allow(panic-in-lib) — bench harness: a dead worker or typed failure invalidates the measurement
        let resp = rx.recv().expect("worker died mid-bench").expect("probe failed");
        last = resp_sig(&resp);
    }
    let s = sw.elapsed_secs();
    svc.shutdown();
    (s, last)
}

/// Untimed warmup probe: shard builds are eager at start, this settles
/// the route so timed schedules measure serving, not construction.
fn warm_probe(handle: &crate::coordinator::ServiceHandle, probes: &[Point3]) {
    let req = KnnRequest::new(0, probes.to_vec(), BENCH_K).with_mode(QueryMode::Rt);
    // lint: allow(panic-in-lib) — bench harness: queues are sized for the run, a reject is a harness bug
    let rx = handle.submit(req).expect("bench queue sized for the warmup");
    // lint: allow(panic-in-lib) — bench harness: a dead worker or typed failure invalidates the measurement
    let _ = rx.recv().expect("worker died mid-bench").expect("warmup probe failed");
}

/// Run the bench: the serving sweep, the speculation ablation and the
/// insert-schedule comparison; `iters` timed samples per measurement,
/// reporting the minimum (the least-perturbed sample).
pub fn run(n: usize, requests: usize, qpr: usize, iters: usize) -> Pr9Report {
    let iters = iters.max(1);
    let ds = DatasetKind::Taxi.generate(n, 42);
    // the log clamps oversized requests the same way; clamping here too
    // keeps the reported queries_per_request and q/s honest
    let qpr = qpr.min(ds.len());

    let (serve_rows, serve_match) = serve_sweep(&ds.points, requests, qpr, iters);
    let (spec_queries, spec_rows, spec_match) = spec_sweep(&ds.points, iters);

    let insert_shards = Executor::auto().threads().clamp(2, 8);
    let batches: Vec<Vec<Point3>> = (0..INSERT_BATCHES)
        .map(|i| DatasetKind::Uniform.generate(INSERT_POINTS, 200 + i as u64).points)
        .collect();
    let probes = ds.points[..PROBE_QUERIES.min(ds.len())].to_vec();
    let mut pipelined_s = f64::INFINITY;
    let mut lockstep_s = f64::INFINITY;
    let mut insert_match = true;
    for _ in 0..iters {
        let (ps, psig) = pipelined_run(&ds.points, &batches, &probes, insert_shards);
        let (ls, lsig) = lockstep_run(&ds.points, &batches, &probes, insert_shards);
        pipelined_s = pipelined_s.min(ps);
        lockstep_s = lockstep_s.min(ls);
        // both final probes sit on the full-log fence: one answer
        insert_match &= !psig.is_empty() && psig == lsig;
    }

    let results_match = serve_match && spec_match && insert_match;
    Pr9Report {
        n: ds.len(),
        requests,
        queries_per_request: qpr,
        k: BENCH_K,
        iters,
        serve_rows,
        serve_match,
        spec_queries,
        spec_rows,
        spec_match,
        insert_shards,
        insert_batches: INSERT_BATCHES,
        insert_points: INSERT_POINTS,
        probe_queries: PROBE_QUERIES,
        pipelined_s,
        lockstep_s,
        insert_speedup: lockstep_s / pipelined_s.max(1e-12),
        insert_match,
        results_match,
    }
}

pub fn to_json(r: &Pr9Report) -> Json {
    let serve_rows: Vec<Json> = r
        .serve_rows
        .iter()
        .map(|row| {
            Json::obj(vec![
                ("shards", Json::Num(row.shards as f64)),
                ("workers_requested", Json::Num(row.workers_requested as f64)),
                ("workers", Json::Num(row.workers as f64)),
                ("seconds", Json::Num(row.seconds)),
                ("qps", Json::Num(row.qps)),
            ])
        })
        .collect();
    let spec_rows: Vec<Json> = r
        .spec_rows
        .iter()
        .map(|row| {
            Json::obj(vec![
                ("shards", Json::Num(row.shards as f64)),
                ("threads_requested", Json::Num(row.threads_requested as f64)),
                ("threads", Json::Num(row.threads as f64)),
                ("speculation", Json::Num(row.speculation as f64)),
                ("seconds", Json::Num(row.seconds)),
                ("qps", Json::Num(row.qps)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("bench", Json::Str("pr9".into())),
        (
            "pipelined_serving",
            Json::obj(vec![
                ("dataset", Json::Str("taxi".into())),
                ("n", Json::Num(r.n as f64)),
                ("requests", Json::Num(r.requests as f64)),
                ("queries_per_request", Json::Num(r.queries_per_request as f64)),
                ("k", Json::Num(r.k as f64)),
                ("iters", Json::Num(r.iters as f64)),
                ("route", Json::Str(RoutePath::Rt.name().into())),
                ("rows", Json::Arr(serve_rows)),
                ("results_match", Json::Bool(r.serve_match)),
            ]),
        ),
        (
            "speculation",
            Json::obj(vec![
                ("n", Json::Num(r.n as f64)),
                ("queries", Json::Num(r.spec_queries as f64)),
                ("k", Json::Num(r.k as f64)),
                ("iters", Json::Num(r.iters as f64)),
                ("rows", Json::Arr(spec_rows)),
                ("results_match", Json::Bool(r.spec_match)),
            ]),
        ),
        (
            "fenced_inserts",
            Json::obj(vec![
                ("shards", Json::Num(r.insert_shards as f64)),
                ("batches", Json::Num(r.insert_batches as f64)),
                ("points_per_batch", Json::Num(r.insert_points as f64)),
                ("probe_queries", Json::Num(r.probe_queries as f64)),
                ("pipelined_seconds", Json::Num(r.pipelined_s)),
                ("lockstep_seconds", Json::Num(r.lockstep_s)),
                ("speedup", Json::Num(r.insert_speedup)),
                ("results_match", Json::Bool(r.insert_match)),
            ]),
        ),
        ("results_match", Json::Bool(r.results_match)),
    ])
}

pub fn render(r: &Pr9Report) -> Table {
    let mut t = Table::new(
        "PR9 microbench: pipelined scatter-gather (incremental gather, speculative fan, fenced inserts)",
        &["measurement", "config", "time", "rate"],
    );
    for row in &r.serve_rows {
        t.row(vec![
            "serve replay".into(),
            format!(
                "S={} W={} ({})",
                row.shards, row.workers, row.workers_requested
            ),
            fmt_secs(row.seconds),
            format!("{:.0} q/s", row.qps),
        ]);
    }
    for row in &r.spec_rows {
        t.row(vec![
            "spec fan".into(),
            format!("S={} T={} spec={}", row.shards, row.threads, row.speculation),
            fmt_secs(row.seconds),
            format!("{:.0} q/s", row.qps),
        ]);
    }
    t.row(vec![
        "insert pipelined".into(),
        format!("S={} {} batches", r.insert_shards, r.insert_batches),
        fmt_secs(r.pipelined_s),
        format!("{:.2}x vs lockstep", r.insert_speedup),
    ]);
    t.row(vec![
        "insert lockstep".into(),
        format!("S={} {} batches", r.insert_shards, r.insert_batches),
        fmt_secs(r.lockstep_s),
        String::new(),
    ]);
    t.row(vec![
        "pipelining invisible in results".into(),
        String::new(),
        String::new(),
        r.results_match.to_string(),
    ]);
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_runs_small_and_serializes() {
        let r = run(1_500, 8, 4, 1);
        assert_eq!(r.requests, 8);
        assert!(r.serve_match, "incremental gather must not change responses");
        assert!(r.spec_match, "speculation must not change results");
        assert!(r.insert_match, "insert schedule must not change the fenced answer");
        assert!(r.results_match);
        assert!(!r.serve_rows.is_empty() && !r.spec_rows.is_empty());
        assert!(r.serve_rows.iter().all(|row| row.seconds > 0.0));
        assert!(r.serve_rows.iter().any(|row| row.shards > 1));
        assert!(r.spec_rows.iter().any(|row| row.speculation > 0));
        assert!(r.pipelined_s > 0.0 && r.lockstep_s > 0.0 && r.insert_speedup > 0.0);
        let j = to_json(&r).to_string();
        assert!(j.contains("\"bench\":\"pr9\""));
        assert!(j.contains("pipelined_serving"));
        assert!(j.contains("speculation"));
        assert!(j.contains("fenced_inserts"));
        let parsed = crate::configx::parse_json(&j).unwrap();
        assert!(parsed.get("pipelined_serving").is_some());
        assert!(parsed.get("fenced_inserts").is_some());
    }
}
