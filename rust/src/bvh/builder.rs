//! BVH construction strategies.
//!
//! `MedianSplit` (default): split primitives at the median of the longest
//! centroid-extent axis — O(n log n), good quality on point-like prims,
//! and close to what GPU LBVH builders produce in practice.
//! `Sah`: full-sweep surface-area heuristic — slower build, better trees;
//! exposed for the ablation bench (`microbench::refit_vs_rebuild`).

use super::{Bvh, Node};
use crate::geom::{Aabb, Point3};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BuildStrategy {
    MedianSplit,
    Sah,
}

pub fn build(aabbs: &[Aabb], strategy: BuildStrategy, leaf_size: u32) -> Bvh {
    let n = aabbs.len();
    let mut bvh = Bvh {
        nodes: Vec::with_capacity(2 * n.max(1)),
        prim_order: (0..n as u32).collect(),
        root: 0,
        leaf_size: leaf_size.max(1),
    };
    if n == 0 {
        return bvh;
    }
    let centroids: Vec<Point3> = aabbs.iter().map(|b| b.centroid()).collect();
    let mut order = std::mem::take(&mut bvh.prim_order);
    let root = subdivide(
        &mut bvh.nodes,
        &mut order,
        0,
        n,
        aabbs,
        &centroids,
        strategy,
        leaf_size.max(1),
    );
    bvh.prim_order = order;
    bvh.root = root;
    bvh
}

fn range_aabb(order: &[u32], lo: usize, hi: usize, aabbs: &[Aabb]) -> Aabb {
    let mut b = Aabb::EMPTY;
    for &p in &order[lo..hi] {
        b = b.union(&aabbs[p as usize]);
    }
    b
}

#[allow(clippy::too_many_arguments)]
fn subdivide(
    nodes: &mut Vec<Node>,
    order: &mut [u32],
    lo: usize,
    hi: usize,
    aabbs: &[Aabb],
    centroids: &[Point3],
    strategy: BuildStrategy,
    leaf_size: u32,
) -> u32 {
    let aabb = range_aabb(order, lo, hi, aabbs);
    let idx = nodes.len() as u32;
    nodes.push(Node {
        aabb,
        left: u32::MAX,
        right: u32::MAX,
        first_prim: lo as u32,
        prim_count: 0,
    });
    let count = hi - lo;
    if count <= leaf_size as usize {
        nodes[idx as usize].prim_count = count as u32;
        return idx;
    }

    let mid = match strategy {
        BuildStrategy::MedianSplit => median_split(order, lo, hi, centroids),
        BuildStrategy::Sah => sah_split(order, lo, hi, aabbs, centroids)
            .unwrap_or_else(|| median_split(order, lo, hi, centroids)),
    };

    // Degenerate split (all centroids identical): force a balanced cut so
    // recursion terminates.
    let mid = if mid == lo || mid == hi { lo + count / 2 } else { mid };

    let left = subdivide(nodes, order, lo, mid, aabbs, centroids, strategy, leaf_size);
    let right = subdivide(nodes, order, mid, hi, aabbs, centroids, strategy, leaf_size);
    nodes[idx as usize].left = left;
    nodes[idx as usize].right = right;
    // parents precede children in the arena: refit's reverse sweep relies
    // on this (child index > parent index).
    debug_assert!(left > idx && right > idx);
    idx
}

fn median_split(order: &mut [u32], lo: usize, hi: usize, centroids: &[Point3]) -> usize {
    let mut cb = Aabb::EMPTY;
    for &p in &order[lo..hi] {
        cb.grow(centroids[p as usize]);
    }
    let axis = cb.longest_axis();
    let mid = lo + (hi - lo) / 2;
    order[lo..hi].select_nth_unstable_by(mid - lo, |&a, &b| {
        centroids[a as usize][axis]
            .partial_cmp(&centroids[b as usize][axis])
            .unwrap_or(std::cmp::Ordering::Equal)
    });
    mid
}

/// Full-sweep SAH over the longest axis: sort by centroid, evaluate cost
/// at every split with prefix/suffix area sweeps, pick the cheapest.
fn sah_split(
    order: &mut [u32],
    lo: usize,
    hi: usize,
    aabbs: &[Aabb],
    centroids: &[Point3],
) -> Option<usize> {
    let count = hi - lo;
    let mut cb = Aabb::EMPTY;
    for &p in &order[lo..hi] {
        cb.grow(centroids[p as usize]);
    }
    let axis = cb.longest_axis();
    order[lo..hi].sort_unstable_by(|&a, &b| {
        centroids[a as usize][axis]
            .partial_cmp(&centroids[b as usize][axis])
            .unwrap_or(std::cmp::Ordering::Equal)
    });

    // suffix areas
    let mut suffix = vec![0.0f32; count + 1];
    let mut b = Aabb::EMPTY;
    for i in (0..count).rev() {
        b = b.union(&aabbs[order[lo + i] as usize]);
        suffix[i] = b.surface_area();
    }
    // prefix sweep picking the best split
    let mut best: Option<(f32, usize)> = None;
    let mut pb = Aabb::EMPTY;
    for i in 1..count {
        pb = pb.union(&aabbs[order[lo + i - 1] as usize]);
        let cost = pb.surface_area() * i as f32 + suffix[i] * (count - i) as f32;
        if best.map(|(c, _)| cost < c).unwrap_or(true) {
            best = Some((cost, lo + i));
        }
    }
    best.map(|(_, m)| m)
}
