//! BVH construction strategies.
//!
//! `MedianSplit` (default): split primitives at the median of the longest
//! centroid-extent axis — O(n log n), good quality on point-like prims,
//! and close to what GPU LBVH builders produce in practice.
//! `Sah`: full-sweep surface-area heuristic — slower build, better trees;
//! exposed for the ablation bench (`microbench::refit_vs_rebuild`).
//!
//! Both strategies build through one recursion that can fork left/right
//! subtrees onto the [`crate::exec`] engine. The serial arena layout is
//! preorder (node, left block, right block); the parallel path builds
//! each forked subtree into its own arena and grafts it back at exactly
//! the offset the serial recursion would have used, so the resulting
//! `nodes`/`prim_order` are **bitwise-identical at any thread count**.

use super::{Bvh, Node};
use crate::exec::{self, Executor};
use crate::geom::{Aabb, Point3};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BuildStrategy {
    MedianSplit,
    Sah,
}

/// Subtrees below this primitive count are never forked: the split work
/// itself is O(count), so spawning would cost more than it buys.
const PAR_BUILD_MIN: usize = 4096;

/// Immutable per-build context threaded through the recursion.
struct BuildCtx<'a> {
    aabbs: &'a [Aabb],
    centroids: &'a [Point3],
    strategy: BuildStrategy,
    leaf_size: u32,
}

pub fn build(aabbs: &[Aabb], strategy: BuildStrategy, leaf_size: u32, exec: Executor) -> Bvh {
    let n = aabbs.len();
    let mut bvh = Bvh {
        nodes: Vec::with_capacity(2 * n.max(1)),
        prim_order: (0..n as u32).collect(),
        root: 0,
        leaf_size: leaf_size.max(1),
    };
    if n == 0 {
        return bvh;
    }
    let centroids: Vec<Point3> = aabbs.iter().map(|b| b.centroid()).collect();
    let mut order = std::mem::take(&mut bvh.prim_order);
    let ctx = BuildCtx {
        aabbs,
        centroids: &centroids,
        strategy,
        leaf_size: leaf_size.max(1),
    };
    let root = subdivide(&mut bvh.nodes, &mut order, 0, &ctx, exec.threads());
    bvh.prim_order = order;
    bvh.root = root;
    bvh
}

fn range_aabb(order: &[u32], aabbs: &[Aabb]) -> Aabb {
    let mut b = Aabb::EMPTY;
    for &p in order {
        b = b.union(&aabbs[p as usize]);
    }
    b
}

/// Build the subtree over `order` (the primitive ids occupying the
/// global `prim_order` range starting at `base`) into `nodes`, returning
/// the subtree root's index. `threads` is this subtree's fork budget.
fn subdivide(
    nodes: &mut Vec<Node>,
    order: &mut [u32],
    base: usize,
    ctx: &BuildCtx<'_>,
    threads: usize,
) -> u32 {
    let count = order.len();
    let aabb = range_aabb(order, ctx.aabbs);
    let idx = nodes.len() as u32;
    nodes.push(Node {
        aabb,
        left: u32::MAX,
        right: u32::MAX,
        first_prim: base as u32,
        prim_count: 0,
    });
    if count <= ctx.leaf_size as usize {
        nodes[idx as usize].prim_count = count as u32;
        return idx;
    }

    let mid = match ctx.strategy {
        BuildStrategy::MedianSplit => median_split(order, ctx.centroids, ctx.leaf_size),
        BuildStrategy::Sah => sah_split(order, ctx.aabbs, ctx.centroids, ctx.leaf_size)
            .unwrap_or_else(|| median_split(order, ctx.centroids, ctx.leaf_size)),
    };
    // Safety net: both split strategies already guarantee an interior,
    // leaf-aligned cut; if a future edit breaks that, fall back to the
    // aligned median so recursion still terminates with packed leaves.
    debug_assert!(mid > 0 && mid < count, "split must be interior");
    let mid = if mid == 0 || mid == count {
        aligned_mid(count, ctx.leaf_size)
    } else {
        mid
    };

    let (lo_half, hi_half) = order.split_at_mut(mid);
    if threads > 1 && count >= PAR_BUILD_MIN {
        let lt = threads.div_ceil(2);
        let rt = (threads - lt).max(1);
        let (left_nodes, right_nodes) = exec::join(
            || {
                let mut v = Vec::with_capacity(2 * mid);
                subdivide(&mut v, lo_half, base, ctx, lt);
                v
            },
            || {
                let mut v = Vec::with_capacity(2 * (count - mid));
                subdivide(&mut v, hi_half, base + mid, ctx, rt);
                v
            },
        );
        let l_off = nodes.len() as u32;
        graft(nodes, left_nodes, l_off);
        let r_off = nodes.len() as u32;
        graft(nodes, right_nodes, r_off);
        nodes[idx as usize].left = l_off;
        nodes[idx as usize].right = r_off;
        debug_assert!(l_off > idx && r_off > idx);
    } else {
        let left = subdivide(nodes, lo_half, base, ctx, 1);
        let right = subdivide(nodes, hi_half, base + mid, ctx, 1);
        nodes[idx as usize].left = left;
        nodes[idx as usize].right = right;
        // parents precede children in the arena: refit's reverse sweep
        // relies on this (child index > parent index).
        debug_assert!(left > idx && right > idx);
    }
    idx
}

/// Splice a sub-arena (preorder, local indices) into the parent arena at
/// `offset`; the preorder layout means a fixed shift of every child link
/// reproduces exactly what direct recursion would have written.
fn graft(nodes: &mut Vec<Node>, sub: Vec<Node>, offset: u32) {
    nodes.extend(sub.into_iter().map(|mut n| {
        if n.prim_count == 0 {
            n.left += offset;
            n.right += offset;
        }
        n
    }));
}

fn median_split(order: &mut [u32], centroids: &[Point3], leaf_size: u32) -> usize {
    let mut cb = Aabb::EMPTY;
    for &p in order.iter() {
        cb.grow(centroids[p as usize]);
    }
    let axis = cb.longest_axis();
    let mid = aligned_mid(order.len(), leaf_size);
    order.select_nth_unstable_by(mid, |&a, &b| {
        centroids[a as usize][axis]
            .partial_cmp(&centroids[b as usize][axis])
            .unwrap_or(std::cmp::Ordering::Equal)
    });
    mid
}

/// The median cut rounded to the nearest `leaf_size` multiple, so leaves
/// pack full instead of fragmenting to 2–3 prims on odd halvings. Keeps
/// the node count at ~n/2 for *every* n (it swung between 0.5n and 0.8n
/// before), which means ~30% fewer hardware AABB tests on fragmented
/// sizes and a refit charge that tracks the cost model's calibration.
fn aligned_mid(count: usize, leaf_size: u32) -> usize {
    let leaf = leaf_size.max(1) as usize;
    let half = count / 2;
    let mid = ((half + leaf / 2) / leaf) * leaf;
    if mid == 0 || mid >= count {
        half
    } else {
        mid
    }
}

/// Full-sweep SAH over the longest axis: sort by centroid, evaluate cost
/// at every leaf-aligned split with prefix/suffix area sweeps, pick the
/// cheapest. Candidates are restricted to `leaf_size` multiples for the
/// same leaf-packing reason as [`aligned_mid`].
fn sah_split(
    order: &mut [u32],
    aabbs: &[Aabb],
    centroids: &[Point3],
    leaf_size: u32,
) -> Option<usize> {
    let count = order.len();
    let leaf = leaf_size.max(1) as usize;
    let mut cb = Aabb::EMPTY;
    for &p in order.iter() {
        cb.grow(centroids[p as usize]);
    }
    let axis = cb.longest_axis();
    order.sort_unstable_by(|&a, &b| {
        centroids[a as usize][axis]
            .partial_cmp(&centroids[b as usize][axis])
            .unwrap_or(std::cmp::Ordering::Equal)
    });

    // suffix areas
    let mut suffix = vec![0.0f32; count + 1];
    let mut b = Aabb::EMPTY;
    for i in (0..count).rev() {
        b = b.union(&aabbs[order[i] as usize]);
        suffix[i] = b.surface_area();
    }
    // prefix sweep picking the best leaf-aligned split
    let mut best: Option<(f32, usize)> = None;
    let mut pb = Aabb::EMPTY;
    for i in 1..count {
        pb = pb.union(&aabbs[order[i - 1] as usize]);
        if i % leaf != 0 {
            continue;
        }
        let cost = pb.surface_area() * i as f32 + suffix[i] * (count - i) as f32;
        if best.map(|(c, _)| cost < c).unwrap_or(true) {
            best = Some((cost, i));
        }
    }
    best.map(|(_, m)| m)
}
