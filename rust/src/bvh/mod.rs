//! Bounding Volume Hierarchy (paper §2.2.2) — the acceleration structure
//! the RT core traverses in hardware.
//!
//! Supports the two lifecycle operations the paper relies on:
//! - `build`: construct the tree over primitive AABBs (median-split on
//!   the longest centroid axis, with an optional SAH builder used by the
//!   ablation bench); `build_parallel` forks subtrees across the
//!   [`crate::exec`] engine and produces a bitwise-identical arena;
//! - `refit`: after every TrueKNN round grows the sphere radius, the
//!   boxes are re-fit bottom-up *without* changing topology — the OptiX
//!   refit the paper measured as 10–25% faster than rebuilding (§4).
//!   `refit_parallel` sweeps independent subtrees concurrently.
//!
//! The arena is laid out in **preorder** (node, left-subtree block,
//! right-subtree block). Two consumers rely on that invariant: the
//! refit reverse sweep (children have larger indices than parents) and
//! the parallel refit (every subtree is one contiguous node range).

mod builder;

pub use builder::BuildStrategy;

use crate::exec::Executor;
use crate::geom::{Aabb, Point3};

/// Arena node. Internal nodes store child indices; leaves store a range
/// into `prim_order`.
#[derive(Clone, Debug, PartialEq)]
pub struct Node {
    pub aabb: Aabb,
    /// Index of the left child, or `u32::MAX` for leaves.
    pub left: u32,
    /// Index of the right child, or `u32::MAX` for leaves.
    pub right: u32,
    /// Leaf payload: offset into `prim_order`.
    pub first_prim: u32,
    /// Leaf payload: number of primitives (0 for internal nodes).
    pub prim_count: u32,
}

impl Node {
    pub fn is_leaf(&self) -> bool {
        self.prim_count > 0
    }
}

#[derive(Clone, Debug)]
pub struct Bvh {
    pub nodes: Vec<Node>,
    /// Primitive ids in leaf order.
    pub prim_order: Vec<u32>,
    pub root: u32,
    /// Max primitives per leaf used at build time.
    pub leaf_size: u32,
}

/// Trees below this node count refit serially: the frontier bookkeeping
/// would cost more than the sweep itself.
const PAR_REFIT_MIN: usize = 4096;

impl Bvh {
    /// Build over primitive AABBs with the default strategy (serial).
    pub fn build(aabbs: &[Aabb]) -> Bvh {
        builder::build(aabbs, BuildStrategy::MedianSplit, 4, Executor::serial())
    }

    pub fn build_with(aabbs: &[Aabb], strategy: BuildStrategy, leaf_size: u32) -> Bvh {
        builder::build(aabbs, strategy, leaf_size, Executor::serial())
    }

    /// Build with subtree-level parallelism. The output arena is
    /// bitwise-identical to the serial build at any thread count (the
    /// builder grafts forked subtrees back at the serial preorder
    /// offsets).
    pub fn build_parallel(
        aabbs: &[Aabb],
        strategy: BuildStrategy,
        leaf_size: u32,
        exec: Executor,
    ) -> Bvh {
        builder::build(aabbs, strategy, leaf_size, exec)
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Bottom-up AABB recomputation over unchanged topology. Nodes are
    /// laid out so every child index is greater than its parent's, so a
    /// single reverse sweep suffices. Returns the number of nodes
    /// refit (the simulator charges refit cost per node).
    pub fn refit(&mut self, aabbs: &[Aabb]) -> usize {
        self.refit_parallel(aabbs, Executor::serial())
    }

    /// [`Bvh::refit`] with per-subtree parallelism: descend from the root
    /// to a frontier of independent subtrees, sweep each subtree's
    /// contiguous arena block on its own thread, then fix the handful of
    /// ancestor nodes above the frontier serially. Box values are unions
    /// in a fixed per-node order, so the result is bitwise-identical to
    /// the serial sweep.
    pub fn refit_parallel(&mut self, aabbs: &[Aabb], exec: Executor) -> usize {
        let n_nodes = self.nodes.len();
        if n_nodes == 0 {
            return 0;
        }
        if exec.threads() <= 1 || n_nodes < PAR_REFIT_MIN {
            refit_block(&mut self.nodes, 0, &self.prim_order, aabbs);
            return n_nodes;
        }

        // Frontier: split one level at a time until we have enough
        // independent subtrees to feed every worker a few blocks.
        let target = exec.threads() * 4;
        let mut frontier: Vec<u32> = vec![self.root];
        let mut interior: Vec<u32> = Vec::new();
        while frontier.len() < target
            && frontier.iter().any(|&i| !self.nodes[i as usize].is_leaf())
        {
            let mut next = Vec::with_capacity(frontier.len() * 2);
            for &i in &frontier {
                let nd = &self.nodes[i as usize];
                if nd.is_leaf() {
                    next.push(i);
                } else {
                    interior.push(i);
                    next.push(nd.left);
                    next.push(nd.right);
                }
            }
            frontier = next;
        }

        // Preorder layout ⇒ each frontier subtree is one contiguous node
        // block; carve them out as disjoint mutable slices.
        let mut blocks: Vec<(usize, usize)> = frontier
            .iter()
            .map(|&f| (f as usize, self.subtree_end(f)))
            .collect();
        blocks.sort_unstable();
        let prim_order = &self.prim_order;
        let mut tasks: Vec<(usize, &mut [Node])> = Vec::with_capacity(blocks.len());
        let mut rest: &mut [Node] = &mut self.nodes;
        let mut consumed = 0usize;
        for &(start, end) in &blocks {
            debug_assert!(start >= consumed && end > start);
            let (_gap, tail) = std::mem::take(&mut rest).split_at_mut(start - consumed);
            let (blk, tail) = tail.split_at_mut(end - start);
            tasks.push((start, blk));
            rest = tail;
            consumed = end;
        }

        crate::exec::scope(|s| {
            // Static round-robin over the index-sorted blocks: adjacent
            // blocks (which share subtree depth, hence size class) land
            // on different workers. Bucket 0 runs on the calling thread.
            let workers = exec.threads().min(tasks.len());
            let mut buckets: Vec<Vec<(usize, &mut [Node])>> =
                (0..workers).map(|_| Vec::new()).collect();
            for (i, t) in tasks.into_iter().enumerate() {
                buckets[i % workers].push(t);
            }
            let mut buckets = buckets.into_iter();
            let own = buckets.next().unwrap_or_default();
            for bucket in buckets {
                s.spawn(move || {
                    for (offset, blk) in bucket {
                        refit_block(blk, offset, prim_order, aabbs);
                    }
                });
            }
            for (offset, blk) in own {
                refit_block(blk, offset, prim_order, aabbs);
            }
        });

        // Ancestors above the frontier, children-first (reverse arena
        // order respects the child-after-parent invariant).
        interior.sort_unstable();
        for &i in interior.iter().rev() {
            let i = i as usize;
            let l = self.nodes[i].left as usize;
            let r = self.nodes[i].right as usize;
            let merged = self.nodes[l].aabb.union(&self.nodes[r].aabb);
            self.nodes[i].aabb = merged;
        }
        n_nodes
    }

    /// One-past-the-end of `idx`'s contiguous preorder block: the
    /// rightmost descendant leaf plus one.
    fn subtree_end(&self, mut idx: u32) -> usize {
        loop {
            let n = &self.nodes[idx as usize];
            if n.is_leaf() {
                return idx as usize + 1;
            }
            idx = n.right;
        }
    }

    /// The single traversal core shared by [`Bvh::visit_point`] and the
    /// RT pipeline's launch loop (they must not drift): visit every node
    /// whose AABB contains `p`, firing `on_node` per containment test and
    /// `on_leaf(first_prim, prim_count)` per containing leaf. The caller
    /// supplies the stack so a launch can reuse one allocation across
    /// rays.
    #[inline(always)]
    pub fn for_each_leaf_containing<N, L>(
        &self,
        p: Point3,
        stack: &mut Vec<u32>,
        mut on_node: N,
        mut on_leaf: L,
    ) where
        N: FnMut(),
        L: FnMut(usize, usize),
    {
        if self.nodes.is_empty() {
            return;
        }
        stack.clear();
        stack.push(self.root);
        while let Some(idx) = stack.pop() {
            let node = &self.nodes[idx as usize];
            on_node();
            if !node.aabb.contains(p) {
                continue;
            }
            if node.is_leaf() {
                on_leaf(node.first_prim as usize, node.prim_count as usize);
            } else {
                stack.push(node.left);
                stack.push(node.right);
            }
        }
    }

    /// Point-query traversal (the degenerate kNN-ray case): visit every
    /// leaf whose AABB contains `p`, invoking `on_leaf(prim_range)`.
    /// `on_node` fires per AABB containment test so the RT simulator can
    /// tally the hardware-unit work. Thin wrapper over
    /// [`Bvh::for_each_leaf_containing`].
    pub fn visit_point<FN, FL>(&self, p: Point3, on_node: FN, mut on_leaf: FL)
    where
        FN: FnMut(),
        FL: FnMut(&[u32]),
    {
        let mut stack: Vec<u32> = Vec::with_capacity(64);
        self.for_each_leaf_containing(p, &mut stack, on_node, |first, count| {
            on_leaf(&self.prim_order[first..first + count])
        });
    }

    /// Serialize the arena for a crash-safe snapshot: leaf size, root,
    /// nodes (AABB as 6 float bit patterns + 4 ids), then the leaf-order
    /// permutation. The arena is already a deterministic preorder
    /// layout, so encode/decode is a verbatim copy — a loaded tree is
    /// bitwise-identical to the built one.
    pub fn encode_into(&self, enc: &mut crate::persist::Enc) {
        enc.put_u32(self.leaf_size);
        enc.put_u32(self.root);
        enc.put_len(self.nodes.len());
        for n in &self.nodes {
            enc.put_f32(n.aabb.min.x);
            enc.put_f32(n.aabb.min.y);
            enc.put_f32(n.aabb.min.z);
            enc.put_f32(n.aabb.max.x);
            enc.put_f32(n.aabb.max.y);
            enc.put_f32(n.aabb.max.z);
            enc.put_u32(n.left);
            enc.put_u32(n.right);
            enc.put_u32(n.first_prim);
            enc.put_u32(n.prim_count);
        }
        enc.put_len(self.prim_order.len());
        for &p in &self.prim_order {
            enc.put_u32(p);
        }
    }

    /// Decode an arena written by [`Bvh::encode_into`], re-validating
    /// the structural invariants (root and child indices in range, leaf
    /// ranges inside `prim_order`) so corrupt payloads surface as typed
    /// errors instead of later panics.
    pub fn decode_from(
        dec: &mut crate::persist::Dec<'_>,
    ) -> Result<Bvh, crate::persist::PersistError> {
        use crate::persist::PersistError;
        let corrupt = |detail: String| PersistError::Corrupt { what: "bvh", detail };
        let leaf_size = dec.get_u32()?;
        let root = dec.get_u32()?;
        let n_nodes = dec.get_len()?;
        let mut nodes = Vec::with_capacity(n_nodes);
        for _ in 0..n_nodes {
            let min = Point3::new(dec.get_f32()?, dec.get_f32()?, dec.get_f32()?);
            let max = Point3::new(dec.get_f32()?, dec.get_f32()?, dec.get_f32()?);
            nodes.push(Node {
                aabb: Aabb { min, max },
                left: dec.get_u32()?,
                right: dec.get_u32()?,
                first_prim: dec.get_u32()?,
                prim_count: dec.get_u32()?,
            });
        }
        let n_prims = dec.get_len()?;
        let mut prim_order = Vec::with_capacity(n_prims);
        for _ in 0..n_prims {
            prim_order.push(dec.get_u32()?);
        }
        if !nodes.is_empty() && root as usize >= nodes.len() {
            return Err(corrupt(format!("root {root} outside {} nodes", nodes.len())));
        }
        for (i, n) in nodes.iter().enumerate() {
            if n.is_leaf() {
                let end = (n.first_prim as usize).checked_add(n.prim_count as usize);
                if end.is_none() || end.unwrap_or(usize::MAX) > prim_order.len() {
                    return Err(corrupt(format!("leaf {i} range outside prim_order")));
                }
            } else if n.left as usize >= nodes.len() || n.right as usize >= nodes.len() {
                return Err(corrupt(format!("node {i} child index out of range")));
            }
        }
        Ok(Bvh { nodes, prim_order, root, leaf_size })
    }

    /// Tree statistics for tests and the ablation bench.
    pub fn depth(&self) -> usize {
        fn go(bvh: &Bvh, idx: u32) -> usize {
            let n = &bvh.nodes[idx as usize];
            if n.is_leaf() {
                1
            } else {
                1 + go(bvh, n.left).max(go(bvh, n.right))
            }
        }
        if self.nodes.is_empty() {
            0
        } else {
            go(self, self.root)
        }
    }

    /// Total surface area of internal nodes (SAH quality proxy).
    pub fn total_surface_area(&self) -> f64 {
        self.nodes
            .iter()
            .map(|n| n.aabb.surface_area() as f64)
            .sum()
    }
}

/// Reverse-sweep refit of one contiguous (preorder) node block whose
/// global arena offset is `offset`. Children of a block node always lie
/// inside the block (they belong to the same subtree).
fn refit_block(nodes: &mut [Node], offset: usize, prim_order: &[u32], aabbs: &[Aabb]) {
    for i in (0..nodes.len()).rev() {
        if nodes[i].is_leaf() {
            let first = nodes[i].first_prim as usize;
            let count = nodes[i].prim_count as usize;
            let mut b = Aabb::EMPTY;
            for &prim in &prim_order[first..first + count] {
                b = b.union(&aabbs[prim as usize]);
            }
            nodes[i].aabb = b;
        } else {
            let l = nodes[i].left as usize - offset;
            let r = nodes[i].right as usize - offset;
            let merged = nodes[l].aabb.union(&nodes[r].aabb);
            nodes[i].aabb = merged;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geom::Sphere;
    use crate::util::prop;
    use crate::util::Pcg32;

    fn sphere_aabbs(pts: &[Point3], r: f32) -> Vec<Aabb> {
        pts.iter().map(|&c| Sphere::new(c, r).aabb()).collect()
    }

    #[test]
    fn empty_and_single() {
        let bvh = Bvh::build(&[]);
        assert!(bvh.is_empty());
        let mut visited = 0;
        bvh.visit_point(Point3::ZERO, || {}, |_| visited += 1);
        assert_eq!(visited, 0);

        let bvh = Bvh::build(&[Aabb::around_sphere(Point3::splat(0.5), 0.1)]);
        let mut prims = Vec::new();
        bvh.visit_point(Point3::splat(0.5), || {}, |p| prims.extend_from_slice(p));
        assert_eq!(prims, vec![0]);
    }

    #[test]
    fn every_prim_reachable_once() {
        let mut rng = Pcg32::new(5);
        let pts = prop::random_cloud(&mut rng, 300, false);
        let bvh = Bvh::build(&sphere_aabbs(&pts, 0.01));
        let mut sorted = bvh.prim_order.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..300).collect::<Vec<u32>>());
    }

    #[test]
    fn parent_contains_children_invariant() {
        prop::check("parent ⊇ children", 20, |rng| {
            let n = 16 + rng.below(256) as usize;
            let dims2 = rng.f32() < 0.5;
            let pts = prop::random_cloud(rng, n, dims2);
            let bvh = Bvh::build(&sphere_aabbs(&pts, 0.02));
            for node in &bvh.nodes {
                if !node.is_leaf() {
                    let l = &bvh.nodes[node.left as usize].aabb;
                    let r = &bvh.nodes[node.right as usize].aabb;
                    if !node.aabb.contains_box(l) || !node.aabb.contains_box(r) {
                        return Err("parent does not contain child".into());
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn point_query_finds_exactly_containing_leaves() {
        prop::check("visit_point completeness", 20, |rng| {
            let n = 8 + rng.below(200) as usize;
            let pts = prop::random_cloud(rng, n, false);
            let r = 0.05 + rng.f32() * 0.1;
            let aabbs = sphere_aabbs(&pts, r);
            let bvh = Bvh::build(&aabbs);
            let q = Point3::new(rng.f32(), rng.f32(), rng.f32());
            let mut got: Vec<u32> = Vec::new();
            bvh.visit_point(
                q,
                || {},
                |prims| {
                    for &p in prims {
                        if aabbs[p as usize].contains(q) {
                            got.push(p);
                        }
                    }
                },
            );
            got.sort_unstable();
            let mut expect: Vec<u32> = (0..n as u32)
                .filter(|&i| aabbs[i as usize].contains(q))
                .collect();
            expect.sort_unstable();
            if got != expect {
                return Err(format!("got {got:?} expected {expect:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn refit_matches_rebuild_aabbs() {
        prop::check("refit ≡ rebuild boxes", 10, |rng| {
            let n = 16 + rng.below(200) as usize;
            let pts = prop::random_cloud(rng, n, false);
            let mut bvh = Bvh::build(&sphere_aabbs(&pts, 0.01));
            let grown = sphere_aabbs(&pts, 0.08);
            bvh.refit(&grown);
            // every node must exactly equal the union of its leaf prims
            for node in &bvh.nodes {
                if node.is_leaf() {
                    let first = node.first_prim as usize;
                    let count = node.prim_count as usize;
                    let mut b = Aabb::EMPTY;
                    for &p in &bvh.prim_order[first..first + count] {
                        b = b.union(&grown[p as usize]);
                    }
                    if b != node.aabb {
                        return Err("leaf box mismatch after refit".into());
                    }
                }
            }
            // and the invariant still holds
            for node in &bvh.nodes {
                if !node.is_leaf() {
                    let l = &bvh.nodes[node.left as usize].aabb;
                    let r = &bvh.nodes[node.right as usize].aabb;
                    if !node.aabb.contains_box(l) || !node.aabb.contains_box(r) {
                        return Err("invariant broken after refit".into());
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn parallel_build_is_bitwise_identical_to_serial() {
        // above PAR_BUILD_MIN so forks actually happen
        let mut rng = Pcg32::new(11);
        let pts = prop::random_cloud(&mut rng, 12_000, false);
        let aabbs = sphere_aabbs(&pts, 0.01);
        let serial = Bvh::build(&aabbs);
        for threads in [2usize, 3, 8] {
            let par = Bvh::build_parallel(
                &aabbs,
                BuildStrategy::MedianSplit,
                4,
                Executor::new(threads),
            );
            assert_eq!(par.root, serial.root, "threads={threads}");
            assert_eq!(par.prim_order, serial.prim_order, "threads={threads}");
            assert_eq!(par.nodes, serial.nodes, "threads={threads}");
        }
    }

    #[test]
    fn parallel_refit_is_bitwise_identical_to_serial() {
        let mut rng = Pcg32::new(12);
        let pts = prop::random_cloud(&mut rng, 10_000, false);
        let base = Bvh::build(&sphere_aabbs(&pts, 0.005));
        let grown = sphere_aabbs(&pts, 0.02);
        let mut serial = base.clone();
        let n_serial = serial.refit(&grown);
        for threads in [2usize, 8] {
            let mut par = base.clone();
            let n_par = par.refit_parallel(&grown, Executor::new(threads));
            assert_eq!(n_par, n_serial);
            assert_eq!(par.nodes, serial.nodes, "threads={threads}");
        }
    }

    #[test]
    fn sah_not_worse_than_median_on_clusters() {
        let ds = crate::dataset::DatasetKind::Taxi.generate(2_000, 6);
        let aabbs = sphere_aabbs(&ds.points, 0.001);
        let med = Bvh::build_with(&aabbs, BuildStrategy::MedianSplit, 4);
        let sah = Bvh::build_with(&aabbs, BuildStrategy::Sah, 4);
        assert!(
            sah.total_surface_area() <= med.total_surface_area() * 1.05,
            "sah {} vs median {}",
            sah.total_surface_area(),
            med.total_surface_area()
        );
    }

    #[test]
    fn duplicate_points_build_fine() {
        let pts = vec![Point3::splat(0.5); 64];
        let bvh = Bvh::build(&sphere_aabbs(&pts, 0.1));
        let mut found = 0;
        bvh.visit_point(Point3::splat(0.5), || {}, |p| found += p.len());
        assert_eq!(found, 64);
    }
}
