//! Bounding Volume Hierarchy (paper §2.2.2) — the acceleration structure
//! the RT core traverses in hardware.
//!
//! Supports the two lifecycle operations the paper relies on:
//! - `build`: construct the tree over primitive AABBs (median-split on
//!   the longest centroid axis, with an optional SAH builder used by the
//!   ablation bench);
//! - `refit`: after every TrueKNN round grows the sphere radius, the
//!   boxes are re-fit bottom-up *without* changing topology — the OptiX
//!   refit the paper measured as 10–25% faster than rebuilding (§4).

mod builder;

pub use builder::BuildStrategy;

use crate::geom::{Aabb, Point3};

/// Arena node. Internal nodes store child indices; leaves store a range
/// into `prim_order`.
#[derive(Clone, Debug)]
pub struct Node {
    pub aabb: Aabb,
    /// Index of the left child, or `u32::MAX` for leaves.
    pub left: u32,
    /// Index of the right child, or `u32::MAX` for leaves.
    pub right: u32,
    /// Leaf payload: offset into `prim_order`.
    pub first_prim: u32,
    /// Leaf payload: number of primitives (0 for internal nodes).
    pub prim_count: u32,
}

impl Node {
    pub fn is_leaf(&self) -> bool {
        self.prim_count > 0
    }
}

#[derive(Clone, Debug)]
pub struct Bvh {
    pub nodes: Vec<Node>,
    /// Primitive ids in leaf order.
    pub prim_order: Vec<u32>,
    pub root: u32,
    /// Max primitives per leaf used at build time.
    pub leaf_size: u32,
}

impl Bvh {
    /// Build over primitive AABBs with the default strategy.
    pub fn build(aabbs: &[Aabb]) -> Bvh {
        builder::build(aabbs, BuildStrategy::MedianSplit, 4)
    }

    pub fn build_with(aabbs: &[Aabb], strategy: BuildStrategy, leaf_size: u32) -> Bvh {
        builder::build(aabbs, strategy, leaf_size)
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Bottom-up AABB recomputation over unchanged topology. Nodes are
    /// laid out so every child index is greater than its parent's, so a
    /// single reverse sweep suffices. Returns the number of nodes
    /// refit (the simulator charges refit cost per node).
    pub fn refit(&mut self, aabbs: &[Aabb]) -> usize {
        for i in (0..self.nodes.len()).rev() {
            if self.nodes[i].is_leaf() {
                let first = self.nodes[i].first_prim as usize;
                let count = self.nodes[i].prim_count as usize;
                let mut b = Aabb::EMPTY;
                for &prim in &self.prim_order[first..first + count] {
                    b = b.union(&aabbs[prim as usize]);
                }
                self.nodes[i].aabb = b;
            } else {
                let l = self.nodes[i].left as usize;
                let r = self.nodes[i].right as usize;
                self.nodes[i].aabb = self.nodes[l].aabb.union(&self.nodes[r].aabb);
            }
        }
        self.nodes.len()
    }

    /// Point-query traversal (the degenerate kNN-ray case): visit every
    /// leaf whose AABB contains `p`, invoking `on_leaf(prim_range)`.
    /// `on_node` fires per AABB containment test so the RT simulator can
    /// tally the hardware-unit work.
    pub fn visit_point<FN, FL>(&self, p: Point3, mut on_node: FN, mut on_leaf: FL)
    where
        FN: FnMut(),
        FL: FnMut(&[u32]),
    {
        if self.nodes.is_empty() {
            return;
        }
        let mut stack: Vec<u32> = Vec::with_capacity(64);
        stack.push(self.root);
        while let Some(idx) = stack.pop() {
            let node = &self.nodes[idx as usize];
            on_node();
            if !node.aabb.contains(p) {
                continue;
            }
            if node.is_leaf() {
                let first = node.first_prim as usize;
                let count = node.prim_count as usize;
                on_leaf(&self.prim_order[first..first + count]);
            } else {
                stack.push(node.left);
                stack.push(node.right);
            }
        }
    }

    /// Tree statistics for tests and the ablation bench.
    pub fn depth(&self) -> usize {
        fn go(bvh: &Bvh, idx: u32) -> usize {
            let n = &bvh.nodes[idx as usize];
            if n.is_leaf() {
                1
            } else {
                1 + go(bvh, n.left).max(go(bvh, n.right))
            }
        }
        if self.nodes.is_empty() {
            0
        } else {
            go(self, self.root)
        }
    }

    /// Total surface area of internal nodes (SAH quality proxy).
    pub fn total_surface_area(&self) -> f64 {
        self.nodes
            .iter()
            .map(|n| n.aabb.surface_area() as f64)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geom::Sphere;
    use crate::util::prop;
    use crate::util::Pcg32;

    fn sphere_aabbs(pts: &[Point3], r: f32) -> Vec<Aabb> {
        pts.iter().map(|&c| Sphere::new(c, r).aabb()).collect()
    }

    #[test]
    fn empty_and_single() {
        let bvh = Bvh::build(&[]);
        assert!(bvh.is_empty());
        let mut visited = 0;
        bvh.visit_point(Point3::ZERO, || {}, |_| visited += 1);
        assert_eq!(visited, 0);

        let bvh = Bvh::build(&[Aabb::around_sphere(Point3::splat(0.5), 0.1)]);
        let mut prims = Vec::new();
        bvh.visit_point(Point3::splat(0.5), || {}, |p| prims.extend_from_slice(p));
        assert_eq!(prims, vec![0]);
    }

    #[test]
    fn every_prim_reachable_once() {
        let mut rng = Pcg32::new(5);
        let pts = prop::random_cloud(&mut rng, 300, false);
        let bvh = Bvh::build(&sphere_aabbs(&pts, 0.01));
        let mut sorted = bvh.prim_order.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..300).collect::<Vec<u32>>());
    }

    #[test]
    fn parent_contains_children_invariant() {
        prop::check("parent ⊇ children", 20, |rng| {
            let n = 16 + rng.below(256) as usize;
            let dims2 = rng.f32() < 0.5;
            let pts = prop::random_cloud(rng, n, dims2);
            let bvh = Bvh::build(&sphere_aabbs(&pts, 0.02));
            for node in &bvh.nodes {
                if !node.is_leaf() {
                    let l = &bvh.nodes[node.left as usize].aabb;
                    let r = &bvh.nodes[node.right as usize].aabb;
                    if !node.aabb.contains_box(l) || !node.aabb.contains_box(r) {
                        return Err("parent does not contain child".into());
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn point_query_finds_exactly_containing_leaves() {
        prop::check("visit_point completeness", 20, |rng| {
            let n = 8 + rng.below(200) as usize;
            let pts = prop::random_cloud(rng, n, false);
            let r = 0.05 + rng.f32() * 0.1;
            let aabbs = sphere_aabbs(&pts, r);
            let bvh = Bvh::build(&aabbs);
            let q = Point3::new(rng.f32(), rng.f32(), rng.f32());
            let mut got: Vec<u32> = Vec::new();
            bvh.visit_point(
                q,
                || {},
                |prims| {
                    for &p in prims {
                        if aabbs[p as usize].contains(q) {
                            got.push(p);
                        }
                    }
                },
            );
            got.sort_unstable();
            let mut expect: Vec<u32> = (0..n as u32)
                .filter(|&i| aabbs[i as usize].contains(q))
                .collect();
            expect.sort_unstable();
            if got != expect {
                return Err(format!("got {got:?} expected {expect:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn refit_matches_rebuild_aabbs() {
        prop::check("refit ≡ rebuild boxes", 10, |rng| {
            let n = 16 + rng.below(200) as usize;
            let pts = prop::random_cloud(rng, n, false);
            let mut bvh = Bvh::build(&sphere_aabbs(&pts, 0.01));
            let grown = sphere_aabbs(&pts, 0.08);
            bvh.refit(&grown);
            // every node must exactly equal the union of its leaf prims
            for node in &bvh.nodes {
                if node.is_leaf() {
                    let first = node.first_prim as usize;
                    let count = node.prim_count as usize;
                    let mut b = Aabb::EMPTY;
                    for &p in &bvh.prim_order[first..first + count] {
                        b = b.union(&grown[p as usize]);
                    }
                    if b != node.aabb {
                        return Err("leaf box mismatch after refit".into());
                    }
                }
            }
            // and the invariant still holds
            for node in &bvh.nodes {
                if !node.is_leaf() {
                    let l = &bvh.nodes[node.left as usize].aabb;
                    let r = &bvh.nodes[node.right as usize].aabb;
                    if !node.aabb.contains_box(l) || !node.aabb.contains_box(r) {
                        return Err("invariant broken after refit".into());
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn sah_not_worse_than_median_on_clusters() {
        let ds = crate::dataset::DatasetKind::Taxi.generate(2_000, 6);
        let aabbs = sphere_aabbs(&ds.points, 0.001);
        let med = Bvh::build_with(&aabbs, BuildStrategy::MedianSplit, 4);
        let sah = Bvh::build_with(&aabbs, BuildStrategy::Sah, 4);
        assert!(
            sah.total_surface_area() <= med.total_surface_area() * 1.05,
            "sah {} vs median {}",
            sah.total_surface_area(),
            med.total_surface_area()
        );
    }

    #[test]
    fn duplicate_points_build_fine() {
        let pts = vec![Point3::splat(0.5); 64];
        let bvh = Bvh::build(&sphere_aabbs(&pts, 0.1));
        let mut found = 0;
        bvh.visit_point(Point3::splat(0.5), || {}, |p| found += p.len());
        assert_eq!(found, 64);
    }
}
