//! Dependency-free command-line argument parser (offline substitute for
//! `clap`).
//!
//! Supports subcommands, `--key value`, `--key=value`, boolean `--flag`s,
//! positional arguments, defaults and `--help` generation.

use std::collections::HashMap;
use std::fmt::Write as _;

/// Declarative option spec.
#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_flag: bool,
}

/// A parsed command line: option values + positionals.
#[derive(Debug, Default)]
pub struct Args {
    values: HashMap<String, String>,
    flags: HashMap<String, bool>,
    pub positional: Vec<String>,
}

#[derive(Debug, PartialEq)]
pub enum CliError {
    UnknownOption(String),
    MissingValue(String),
    BadValue(String, String),
    BadEnv(String, String),
    HelpRequested,
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::UnknownOption(name) => write!(f, "unknown option --{name}"),
            CliError::MissingValue(name) => write!(f, "option --{name} requires a value"),
            CliError::BadValue(name, v) => write!(f, "invalid value for --{name}: {v}"),
            CliError::BadEnv(name, v) => {
                write!(f, "invalid value for environment variable {name}: {v}")
            }
            CliError::HelpRequested => write!(f, "help requested"),
        }
    }
}

impl std::error::Error for CliError {}

/// Parse a typed value out of an environment variable.
///
/// Unset returns `Ok(None)`. A set-but-malformed value is a typed
/// [`CliError::BadEnv`] rather than a silent `None`, so a typo'd
/// `TRUEKNN_FAULT_SEED=0xbeef` fails the run loudly instead of quietly
/// disarming the fault plan it was meant to pin.
pub fn env_parse<T: std::str::FromStr>(name: &str) -> Result<Option<T>, CliError> {
    match std::env::var(name) {
        Err(std::env::VarError::NotPresent) => Ok(None),
        Err(std::env::VarError::NotUnicode(_)) => {
            Err(CliError::BadEnv(name.into(), "<non-unicode>".into()))
        }
        Ok(raw) => match raw.trim().parse() {
            Ok(v) => Ok(Some(v)),
            Err(_) => Err(CliError::BadEnv(name.into(), raw)),
        },
    }
}

impl Args {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn get_str(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.get(name).copied().unwrap_or(false)
    }

    pub fn get_parse<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T, CliError> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError::BadValue(name.into(), v.into())),
        }
    }
}

/// A command with a fixed option spec.
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    pub opts: Vec<OptSpec>,
}

impl Command {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Self {
            name,
            about,
            opts: Vec::new(),
        }
    }

    pub fn opt(mut self, name: &'static str, help: &'static str, default: &'static str) -> Self {
        self.opts.push(OptSpec {
            name,
            help,
            default: Some(default),
            is_flag: false,
        });
        self
    }

    pub fn req(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec {
            name,
            help,
            default: None,
            is_flag: false,
        });
        self
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec {
            name,
            help,
            default: None,
            is_flag: true,
        });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "{} — {}", self.name, self.about);
        let _ = writeln!(s, "options:");
        for o in &self.opts {
            let kind = if o.is_flag { "" } else { " <value>" };
            let def = o
                .default
                .map(|d| format!(" (default: {d})"))
                .unwrap_or_default();
            let _ = writeln!(s, "  --{}{kind}\t{}{def}", o.name, o.help);
        }
        s
    }

    /// Parse `argv` (without program/subcommand names).
    pub fn parse(&self, argv: &[String]) -> Result<Args, CliError> {
        let mut args = Args::default();
        // seed defaults
        for o in &self.opts {
            if let Some(d) = o.default {
                args.values.insert(o.name.to_string(), d.to_string());
            }
        }
        let mut i = 0;
        while i < argv.len() {
            let tok = &argv[i];
            if tok == "--help" || tok == "-h" {
                return Err(CliError::HelpRequested);
            }
            if let Some(body) = tok.strip_prefix("--") {
                let (key, inline_val) = match body.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == key)
                    .ok_or_else(|| CliError::UnknownOption(key.clone()))?;
                if spec.is_flag {
                    args.flags.insert(key, true);
                } else {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| CliError::MissingValue(key.clone()))?
                        }
                    };
                    args.values.insert(key, val);
                }
            } else {
                args.positional.push(tok.clone());
            }
            i += 1;
        }
        Ok(args)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cmd() -> Command {
        Command::new("gen", "generate a dataset")
            .opt("dataset", "dataset name", "taxi")
            .opt("n", "number of points", "1000")
            .flag("verbose", "chatty output")
            .req("out", "output path")
    }

    fn argv(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_and_overrides() {
        let a = cmd()
            .parse(&argv(&["--n", "500", "--out=/tmp/x.csv", "extra"]))
            .unwrap();
        assert_eq!(a.get("dataset"), Some("taxi"));
        assert_eq!(a.get_parse::<usize>("n", 0).unwrap(), 500);
        assert_eq!(a.get("out"), Some("/tmp/x.csv"));
        assert!(!a.flag("verbose"));
        assert_eq!(a.positional, vec!["extra"]);
    }

    #[test]
    fn flags_parse() {
        let a = cmd().parse(&argv(&["--verbose"])).unwrap();
        assert!(a.flag("verbose"));
    }

    #[test]
    fn unknown_option_rejected() {
        assert_eq!(
            cmd().parse(&argv(&["--bogus", "1"])).unwrap_err(),
            CliError::UnknownOption("bogus".into())
        );
    }

    #[test]
    fn missing_value_rejected() {
        assert_eq!(
            cmd().parse(&argv(&["--n"])).unwrap_err(),
            CliError::MissingValue("n".into())
        );
    }

    #[test]
    fn bad_value_reported() {
        let a = cmd().parse(&argv(&["--n", "xyz"])).unwrap();
        assert!(matches!(
            a.get_parse::<usize>("n", 0),
            Err(CliError::BadValue(_, _))
        ));
    }

    #[test]
    fn env_parse_unset_is_none() {
        // a name nothing else in the test binary reads or writes
        assert_eq!(env_parse::<u64>("TRUEKNN_CLI_TEST_UNSET"), Ok(None));
    }

    #[test]
    fn env_parse_roundtrips_and_rejects() {
        // unique names per assertion: tests run in parallel and the env
        // is process-global
        std::env::set_var("TRUEKNN_CLI_TEST_GOOD", " 42 ");
        assert_eq!(env_parse::<u64>("TRUEKNN_CLI_TEST_GOOD"), Ok(Some(42)));
        std::env::set_var("TRUEKNN_CLI_TEST_BAD", "0xbeef");
        assert_eq!(
            env_parse::<u64>("TRUEKNN_CLI_TEST_BAD"),
            Err(CliError::BadEnv(
                "TRUEKNN_CLI_TEST_BAD".into(),
                "0xbeef".into()
            ))
        );
        assert!(env_parse::<u64>("TRUEKNN_CLI_TEST_BAD")
            .unwrap_err()
            .to_string()
            .contains("TRUEKNN_CLI_TEST_BAD"));
    }

    #[test]
    fn help_short_circuits() {
        assert_eq!(
            cmd().parse(&argv(&["--help"])).unwrap_err(),
            CliError::HelpRequested
        );
        assert!(cmd().usage().contains("--dataset"));
    }
}
