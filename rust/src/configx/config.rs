//! Typed experiment configuration, loadable from JSON files or built
//! programmatically. This is the launcher's config system: every
//! experiment driver and the coordinator service take a `RunConfig`.

use super::json::{Json, JsonError};
use crate::dataset::DatasetKind;

/// How k is chosen for a run (paper §5.3 sweeps both regimes).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum KPolicy {
    /// Fixed k (the paper's k = 5 runs).
    Fixed(usize),
    /// k = √(dataset size) (the common classifier heuristic, paper [18]).
    SqrtN,
}

impl KPolicy {
    pub fn resolve(&self, n: usize) -> usize {
        match self {
            KPolicy::Fixed(k) => *k,
            KPolicy::SqrtN => ((n as f64).sqrt().round() as usize).max(1),
        }
    }
}

/// One experiment run: dataset, size, k, algorithm selection.
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub dataset: DatasetKind,
    pub n: usize,
    pub k: KPolicy,
    pub seed: u64,
    /// Stop the search at the 99th-percentile radius (paper §5.5.1).
    pub percentile_cap: Option<f64>,
    /// Override the sampled start radius (paper Fig 7 sensitivity).
    pub start_radius: Option<f32>,
    /// Worker threads for the parallel launch engine (None/0 = all
    /// cores). Purely a throughput knob — results never depend on it.
    pub threads: Option<usize>,
    /// Coordinator pool size for `serve` runs (None/0 = all cores).
    /// Like `threads`, a pure throughput knob: service responses are
    /// bitwise-identical at any pool size.
    pub workers: Option<usize>,
    /// Spatial shards for the RT route's dataset in `serve` runs
    /// (None/1 = unsharded). A pure throughput knob too: scatter-gather
    /// responses are bitwise-identical at any shard count.
    pub shards: Option<usize>,
}

impl Default for RunConfig {
    fn default() -> Self {
        Self {
            dataset: DatasetKind::Uniform,
            n: 10_000,
            k: KPolicy::Fixed(5),
            seed: 42,
            percentile_cap: None,
            start_radius: None,
            threads: None,
            workers: None,
            shards: None,
        }
    }
}

#[derive(Debug)]
pub enum ConfigError {
    Json(JsonError),
    Missing(&'static str),
    Bad(&'static str, String),
    Io(std::io::Error),
}

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ConfigError::Json(e) => write!(f, "json: {e}"),
            ConfigError::Missing(field) => write!(f, "missing field '{field}'"),
            ConfigError::Bad(field, why) => write!(f, "bad field '{field}': {why}"),
            ConfigError::Io(e) => write!(f, "io: {e}"),
        }
    }
}

impl std::error::Error for ConfigError {}

impl From<JsonError> for ConfigError {
    fn from(e: JsonError) -> Self {
        ConfigError::Json(e)
    }
}

impl From<std::io::Error> for ConfigError {
    fn from(e: std::io::Error) -> Self {
        ConfigError::Io(e)
    }
}

impl RunConfig {
    /// Parse from a JSON object like
    /// `{"dataset":"taxi","n":20000,"k":"sqrt","seed":1}`.
    pub fn from_json(v: &Json) -> Result<Self, ConfigError> {
        let mut cfg = RunConfig::default();
        if let Some(d) = v.get("dataset") {
            let name = d.as_str().ok_or(ConfigError::Missing("dataset"))?;
            cfg.dataset = name
                .parse()
                .map_err(|e: String| ConfigError::Bad("dataset", e))?;
        }
        if let Some(n) = v.get("n") {
            cfg.n = n
                .as_usize()
                .ok_or_else(|| ConfigError::Bad("n", "not a number".into()))?;
        }
        if let Some(k) = v.get("k") {
            cfg.k = match k {
                Json::Num(x) => KPolicy::Fixed(*x as usize),
                Json::Str(s) if s == "sqrt" => KPolicy::SqrtN,
                other => return Err(ConfigError::Bad("k", format!("{other:?}"))),
            };
        }
        if let Some(s) = v.get("seed") {
            cfg.seed = s
                .as_f64()
                .ok_or_else(|| ConfigError::Bad("seed", "not a number".into()))?
                as u64;
        }
        if let Some(p) = v.get("percentile_cap") {
            cfg.percentile_cap = Some(
                p.as_f64()
                    .ok_or_else(|| ConfigError::Bad("percentile_cap", "not a number".into()))?,
            );
        }
        if let Some(r) = v.get("start_radius") {
            cfg.start_radius = Some(
                r.as_f64()
                    .ok_or_else(|| ConfigError::Bad("start_radius", "not a number".into()))?
                    as f32,
            );
        }
        if let Some(t) = v.get("threads") {
            cfg.threads = Some(
                t.as_usize()
                    .ok_or_else(|| ConfigError::Bad("threads", "not a number".into()))?,
            );
        }
        if let Some(w) = v.get("workers") {
            cfg.workers = Some(
                w.as_usize()
                    .ok_or_else(|| ConfigError::Bad("workers", "not a number".into()))?,
            );
        }
        if let Some(s) = v.get("shards") {
            cfg.shards = Some(
                s.as_usize()
                    .ok_or_else(|| ConfigError::Bad("shards", "not a number".into()))?,
            );
        }
        Ok(cfg)
    }

    pub fn from_file(path: &str) -> Result<Self, ConfigError> {
        let text = std::fs::read_to_string(path)?;
        let v = super::json::parse(&text)?;
        Self::from_json(&v)
    }

    /// The index configuration this run asks for — the bridge consumers
    /// use so every knob here (seed, start radius, threads) actually
    /// reaches the engine. `radius_cap` stays with the caller: resolving
    /// a percentile needs the dataset's distance profile.
    pub fn to_index_config(&self) -> crate::index::IndexConfig {
        crate::index::IndexConfig {
            seed: self.seed,
            start_radius: self.start_radius,
            // 0/unset resolves to the TRUEKNN_THREADS-aware default
            // inside Executor::new
            threads: self.threads.unwrap_or(0),
            ..Default::default()
        }
    }

    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("dataset", Json::Str(self.dataset.name().into())),
            ("n", Json::Num(self.n as f64)),
            ("seed", Json::Num(self.seed as f64)),
            (
                "k",
                match self.k {
                    KPolicy::Fixed(k) => Json::Num(k as f64),
                    KPolicy::SqrtN => Json::Str("sqrt".into()),
                },
            ),
        ];
        if let Some(p) = self.percentile_cap {
            pairs.push(("percentile_cap", Json::Num(p)));
        }
        if let Some(r) = self.start_radius {
            pairs.push(("start_radius", Json::Num(r as f64)));
        }
        if let Some(t) = self.threads {
            pairs.push(("threads", Json::Num(t as f64)));
        }
        if let Some(w) = self.workers {
            pairs.push(("workers", Json::Num(w as f64)));
        }
        if let Some(s) = self.shards {
            pairs.push(("shards", Json::Num(s as f64)));
        }
        Json::obj(pairs)
    }
}

/// A batch of runs (one experiment = many RunConfigs + output options).
#[derive(Clone, Debug, Default)]
pub struct ExperimentConfig {
    pub runs: Vec<RunConfig>,
    pub repeats: usize,
    pub label: String,
}

impl ExperimentConfig {
    pub fn from_file(path: &str) -> Result<Self, ConfigError> {
        let text = std::fs::read_to_string(path)?;
        let v = super::json::parse(&text)?;
        let runs = v
            .get("runs")
            .and_then(|r| r.as_arr())
            .ok_or(ConfigError::Missing("runs"))?
            .iter()
            .map(RunConfig::from_json)
            .collect::<Result<Vec<_>, _>>()?;
        Ok(Self {
            runs,
            repeats: v.get("repeats").and_then(|x| x.as_usize()).unwrap_or(1),
            label: v
                .get("label")
                .and_then(|x| x.as_str())
                .unwrap_or("experiment")
                .to_string(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kpolicy_resolution() {
        assert_eq!(KPolicy::Fixed(5).resolve(1_000_000), 5);
        assert_eq!(KPolicy::SqrtN.resolve(400_000), 632);
        assert_eq!(KPolicy::SqrtN.resolve(0), 1);
    }

    #[test]
    fn run_config_round_trip() {
        let cfg = RunConfig {
            dataset: DatasetKind::Taxi,
            n: 12_345,
            k: KPolicy::SqrtN,
            seed: 7,
            percentile_cap: Some(99.0),
            start_radius: Some(0.001),
            threads: Some(8),
            workers: Some(4),
            shards: Some(2),
        };
        let re = RunConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(re.dataset, DatasetKind::Taxi);
        assert_eq!(re.n, 12_345);
        assert_eq!(re.k, KPolicy::SqrtN);
        assert_eq!(re.percentile_cap, Some(99.0));
        assert_eq!(re.start_radius, Some(0.001));
        assert_eq!(re.threads, Some(8));
        assert_eq!(re.workers, Some(4));
        assert_eq!(re.shards, Some(2));
        // the knob must reach the engine config, not just round-trip
        let idx = re.to_index_config();
        assert_eq!(idx.threads, 8);
        assert_eq!(idx.start_radius, Some(0.001));
        assert_eq!(idx.seed, 7);
        // the knob is pass-through: 0 stays 0 here, and Executor::new
        // resolves it (TRUEKNN_THREADS if set, else all cores)
        assert_eq!(RunConfig::default().to_index_config().threads, 0);
    }

    #[test]
    fn parse_from_json_text() {
        let v = crate::configx::json::parse(r#"{"dataset":"road","n":500,"k":7}"#).unwrap();
        let cfg = RunConfig::from_json(&v).unwrap();
        assert_eq!(cfg.dataset, DatasetKind::Road);
        assert_eq!(cfg.k, KPolicy::Fixed(7));
    }

    #[test]
    fn bad_dataset_rejected() {
        let v = crate::configx::json::parse(r#"{"dataset":"mars"}"#).unwrap();
        assert!(RunConfig::from_json(&v).is_err());
    }
}
