//! Minimal JSON value type, recursive-descent parser and serializer.
//!
//! Handles the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, booleans, null); used for experiment configs, the artifact
//! manifest written by `python/compile/aot.py`, and report output.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, PartialEq)]
pub enum JsonError {
    Eof(usize),
    Unexpected(char, usize),
    BadNumber(usize),
    BadEscape(char, usize),
    Trailing(usize),
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            JsonError::Eof(at) => write!(f, "unexpected end of input at byte {at}"),
            JsonError::Unexpected(c, at) => write!(f, "unexpected character '{c}' at byte {at}"),
            JsonError::BadNumber(at) => write!(f, "invalid number at byte {at}"),
            JsonError::BadEscape(c, at) => write!(f, "invalid escape '\\{c}' at byte {at}"),
            JsonError::Trailing(at) => write!(f, "trailing garbage at byte {at}"),
        }
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(
            pairs
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

/// Parse a complete JSON document.
pub fn parse(src: &str) -> Result<Json, JsonError> {
    let mut p = Parser {
        bytes: src.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(JsonError::Trailing(p.pos));
    }
    Ok(v)
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Result<u8, JsonError> {
        let b = self.peek().ok_or(JsonError::Eof(self.pos))?;
        self.pos += 1;
        Ok(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect_byte(&mut self, b: u8) -> Result<(), JsonError> {
        let got = self.bump()?;
        if got != b {
            return Err(JsonError::Unexpected(got as char, self.pos - 1));
        }
        Ok(())
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        for &c in word.as_bytes() {
            self.expect_byte(c)?;
        }
        Ok(v)
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek().ok_or(JsonError::Eof(self.pos))? {
            b'n' => self.lit("null", Json::Null),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'"' => self.string().map(Json::Str),
            b'[' => self.array(),
            b'{' => self.object(),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(JsonError::Unexpected(c as char, self.pos)),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect_byte(b'"')?;
        let mut s = String::new();
        loop {
            let b = self.bump()?;
            match b {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.bump()?;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            let mut code = 0u32;
                            for _ in 0..4 {
                                let h = self.bump()? as char;
                                code = code * 16
                                    + h.to_digit(16)
                                        .ok_or(JsonError::BadEscape(h, self.pos - 1))?;
                            }
                            s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                        }
                        c => return Err(JsonError::BadEscape(c as char, self.pos - 1)),
                    }
                }
                _ => {
                    // re-decode UTF-8 multibyte sequences faithfully
                    let start = self.pos - 1;
                    let len = utf8_len(b);
                    for _ in 1..len {
                        self.bump()?;
                    }
                    let chunk = std::str::from_utf8(&self.bytes[start..self.pos])
                        .map_err(|_| JsonError::Unexpected(b as char, start))?;
                    s.push_str(chunk);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')) {
            self.pos += 1;
        }
        // the scanned bytes are all ASCII digits/signs, but propagate
        // rather than assert — a number error is already representable
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| JsonError::BadNumber(start))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| JsonError::BadNumber(start))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect_byte(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b']' => return Ok(Json::Arr(v)),
                c => return Err(JsonError::Unexpected(c as char, self.pos - 1)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect_byte(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect_byte(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b'}' => return Ok(Json::Obj(m)),
                c => return Err(JsonError::Unexpected(c as char, self.pos - 1)),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse(" true ").unwrap(), Json::Bool(true));
        assert_eq!(parse("-12.5e2").unwrap(), Json::Num(-1250.0));
        assert_eq!(parse(r#""hi\nthere""#).unwrap(), Json::Str("hi\nthere".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "c"}], "d": false}"#).unwrap();
        assert_eq!(v.get("d"), Some(&Json::Bool(false)));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("c"));
    }

    #[test]
    fn unicode_escapes_and_multibyte() {
        assert_eq!(parse(r#""A""#).unwrap(), Json::Str("A".into()));
        assert_eq!(parse("\"héllo\"").unwrap(), Json::Str("héllo".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn round_trips() {
        for src in [
            r#"{"a":[1,2,3],"b":{"c":null},"s":"x\"y"}"#,
            "[true,false,null,0.5]",
            r#""line\nbreak""#,
        ] {
            let v = parse(src).unwrap();
            let re = parse(&v.to_string()).unwrap();
            assert_eq!(v, re, "round trip of {src}");
        }
    }
}
