//! Configuration subsystem: a JSON parser/serializer (offline substitute
//! for `serde_json`) and typed experiment configs layered on top.

pub mod json;
pub mod config;

pub use config::{ExperimentConfig, KPolicy, RunConfig};
pub use json::{parse as parse_json, Json};
