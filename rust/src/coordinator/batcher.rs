//! Dynamic batcher: groups compatible queued requests so one scene
//! build / one PJRT dispatch serves many callers — the serving-side
//! analog of the paper's insight that per-round fixed costs (context
//! switches, BVH work) amortize over query volume.
//!
//! Each pool worker owns one batcher, downstream of its bounded queue.
//! Requests arrive already routed (the handle routes at submit time so
//! it can pick the owning worker); the batcher carries the route path
//! through to the batch so the worker never re-routes — the submit-time
//! decision is the only routing decision.

use super::request::{KnnRequest, QueryMode, RoutePath};
use std::time::Instant;

/// A batch of requests sharing one execution: same k, same
/// [`QueryMode`], same [`RoutePath`] **and** same shard, so one index
/// (or one shard sub-index) serves the whole batch while every
/// request's explicit mode is honored.
#[derive(Debug)]
pub struct Batch {
    pub requests: Vec<(KnnRequest, Instant)>,
    /// Flattened query ranges: request i owns queries[ranges[i].0..ranges[i].1].
    pub ranges: Vec<(usize, usize)>,
    pub k: usize,
    pub mode: QueryMode,
    /// The submit-time routing decision, shared by every request here.
    pub path: RoutePath,
    /// For a sharded route: which spatial shard this batch queries
    /// (`None` = the route's whole unsharded index). Carried from the
    /// handle's scatter, so the worker serves it against exactly the
    /// shard sub-index the submit addressed.
    pub shard: Option<usize>,
    /// Insert-log fence every request here was stamped with at submit
    /// time: the batch must be served at exactly this insert prefix.
    /// Mixing fences in one batch would let one worker serve an older
    /// request's shard leg at a newer prefix while the sibling shards
    /// (on other workers) serve it at the older one — a mixed-prefix
    /// merge. Fence homogeneity in [`DynamicBatcher::next_batch`] is
    /// what makes "catch up once per batch" exact.
    pub fence: u64,
}

impl Batch {
    /// Total flattened query count across every request in the batch.
    pub fn total_queries(&self) -> usize {
        self.ranges.last().map(|r| r.1).unwrap_or(0)
    }

    /// `(request id, shard)` of every request in the batch, in batch
    /// order. The supervisor records these before serving a batch so a
    /// crash mid-batch can be attributed to exactly the requests that
    /// were in flight (the poison ledger's strike unit).
    pub fn request_keys(&self) -> Vec<(u64, Option<usize>)> {
        self.requests
            .iter()
            .map(|(r, _)| (r.id, self.shard))
            .collect()
    }
}

/// Size bounds that trip a batch flush.
#[derive(Clone, Debug)]
pub struct BatcherConfig {
    /// Flush a batch when it reaches this many queries.
    pub max_queries: usize,
    /// Flush whatever is pending after this much waiting.
    pub max_requests: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self {
            max_queries: 4096,
            max_requests: 64,
        }
    }
}

/// Pull-based batcher: the worker drains its queue, the batcher groups.
#[derive(Debug)]
pub struct DynamicBatcher {
    cfg: BatcherConfig,
    pending: Vec<(KnnRequest, RoutePath, Option<usize>, u64, Instant)>,
}

impl DynamicBatcher {
    /// An empty batcher with the given flush bounds.
    pub fn new(cfg: BatcherConfig) -> Self {
        Self {
            cfg,
            pending: Vec::new(),
        }
    }

    /// Queue one routed request (with its submit-time shard pin,
    /// insert-log fence and arrival instant) for batching.
    pub fn push(
        &mut self,
        req: KnnRequest,
        path: RoutePath,
        shard: Option<usize>,
        fence: u64,
        arrived: Instant,
    ) {
        self.pending.push((req, path, shard, fence, arrived));
    }

    /// Requests queued but not yet shipped in a batch.
    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }

    /// Form the next batch: take the oldest request, then greedily add
    /// every other pending request with the same k, mode, route path,
    /// shard and insert fence (order preserved) until a size bound
    /// trips. Returns None when idle. The (k, mode, path, shard, fence)
    /// homogeneity is what lets the worker serve a whole batch through
    /// one index at one insert prefix while still honoring each
    /// request's explicit `QueryMode`.
    pub fn next_batch(&mut self) -> Option<Batch> {
        if self.pending.is_empty() {
            return None;
        }
        let k = self.pending[0].0.k;
        let mode = self.pending[0].0.mode;
        let path = self.pending[0].1;
        let shard = self.pending[0].2;
        let fence = self.pending[0].3;
        let mut requests = Vec::new();
        let mut total_q = 0usize;
        let mut i = 0;
        while i < self.pending.len() {
            let (req_i, path_i, shard_i, fence_i, _) = &self.pending[i];
            let compatible = req_i.k == k
                && req_i.mode == mode
                && *path_i == path
                && *shard_i == shard
                && *fence_i == fence;
            let fits = total_q + req_i.queries.len() <= self.cfg.max_queries
                || requests.is_empty(); // an oversize request still ships alone
            if compatible && fits && requests.len() < self.cfg.max_requests {
                let (req, _, _, _, t) = self.pending.remove(i);
                total_q += req.queries.len();
                requests.push((req, t));
                if total_q >= self.cfg.max_queries {
                    break;
                }
            } else {
                i += 1;
            }
        }
        let mut ranges = Vec::with_capacity(requests.len());
        let mut off = 0;
        for (req, _) in &requests {
            ranges.push((off, off + req.queries.len()));
            off += req.queries.len();
        }
        Some(Batch {
            requests,
            ranges,
            k,
            mode,
            path,
            shard,
            fence,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geom::Point3;

    fn req(id: u64, nq: usize, k: usize) -> KnnRequest {
        KnnRequest::new(id, vec![Point3::ZERO; nq], k)
    }

    #[test]
    fn batches_group_same_k() {
        let mut b = DynamicBatcher::new(BatcherConfig::default());
        let now = Instant::now();
        b.push(req(1, 10, 5), RoutePath::Rt, None, 0, now);
        b.push(req(2, 10, 7), RoutePath::Rt, None, 0, now);
        b.push(req(3, 10, 5), RoutePath::Rt, None, 0, now);
        let batch = b.next_batch().unwrap();
        let ids: Vec<u64> = batch.requests.iter().map(|(r, _)| r.id).collect();
        assert_eq!(ids, vec![1, 3]);
        assert_eq!(batch.k, 5);
        assert_eq!(batch.path, RoutePath::Rt);
        assert_eq!(batch.total_queries(), 20);
        assert_eq!(batch.ranges, vec![(0, 10), (10, 20)]);
        // the k=7 request ships next
        let batch2 = b.next_batch().unwrap();
        assert_eq!(batch2.requests[0].0.id, 2);
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn request_keys_carry_the_batch_shard() {
        let mut b = DynamicBatcher::new(BatcherConfig::default());
        let now = Instant::now();
        b.push(req(4, 2, 5), RoutePath::Rt, Some(1), 0, now);
        b.push(req(9, 2, 5), RoutePath::Rt, Some(1), 0, now);
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.request_keys(), vec![(4, Some(1)), (9, Some(1))]);
    }

    #[test]
    fn size_bound_flushes() {
        let mut b = DynamicBatcher::new(BatcherConfig {
            max_queries: 15,
            max_requests: 64,
        });
        let now = Instant::now();
        b.push(req(1, 10, 5), RoutePath::Rt, None, 0, now);
        b.push(req(2, 10, 5), RoutePath::Rt, None, 0, now);
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.requests.len(), 1, "second request would exceed cap");
        assert_eq!(b.pending_len(), 1);
    }

    #[test]
    fn oversize_request_ships_alone() {
        let mut b = DynamicBatcher::new(BatcherConfig {
            max_queries: 5,
            max_requests: 64,
        });
        b.push(req(1, 100, 5), RoutePath::Rt, None, 0, Instant::now());
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.total_queries(), 100);
    }

    #[test]
    fn no_request_lost_or_duplicated() {
        use super::super::request::QueryMode;
        crate::util::prop::check("batcher conservation", 20, |rng| {
            let mut b = DynamicBatcher::new(BatcherConfig {
                max_queries: 1 + rng.below(50) as usize,
                max_requests: 1 + rng.below(8) as usize,
            });
            let n = 1 + rng.below(40) as usize;
            let now = Instant::now();
            let modes = [QueryMode::Auto, QueryMode::Rt, QueryMode::Brute];
            for id in 0..n as u64 {
                let r = req(id, 1 + rng.below(20) as usize, 1 + rng.below(3) as usize)
                    .with_mode(modes[rng.below(3) as usize]);
                let path = RoutePath::ALL[rng.below(3) as usize];
                let shard = match rng.below(3) {
                    0 => None,
                    s => Some(s as usize),
                };
                b.push(r, path, shard, rng.below(2) as u64, now);
            }
            let mut seen = std::collections::HashSet::new();
            while let Some(batch) = b.next_batch() {
                for (r, _) in &batch.requests {
                    if r.k != batch.k {
                        return Err("mixed k in batch".into());
                    }
                    if r.mode != batch.mode {
                        return Err("mixed mode in batch".into());
                    }
                    if !seen.insert(r.id) {
                        return Err(format!("request {} duplicated", r.id));
                    }
                }
            }
            if seen.len() != n {
                return Err(format!("lost requests: {} of {n}", seen.len()));
            }
            Ok(())
        });
    }

    #[test]
    fn mixed_modes_split_into_homogeneous_batches() {
        use super::super::request::QueryMode;
        let mut b = DynamicBatcher::new(BatcherConfig::default());
        let now = Instant::now();
        b.push(req(1, 4, 5).with_mode(QueryMode::Rt), RoutePath::Rt, None, 0, now);
        b.push(req(2, 4, 5).with_mode(QueryMode::Brute), RoutePath::BruteCpu, None, 0, now);
        b.push(req(3, 4, 5).with_mode(QueryMode::Rt), RoutePath::Rt, None, 0, now);
        let first = b.next_batch().unwrap();
        assert_eq!(first.mode, QueryMode::Rt);
        assert_eq!(first.path, RoutePath::Rt);
        let ids: Vec<u64> = first.requests.iter().map(|(r, _)| r.id).collect();
        assert_eq!(ids, vec![1, 3], "same-mode requests batch together");
        let second = b.next_batch().unwrap();
        assert_eq!(second.mode, QueryMode::Brute);
        assert_eq!(second.path, RoutePath::BruteCpu);
        assert_eq!(second.requests[0].0.id, 2);
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn different_shards_never_batch_together() {
        // a sharded route's scatter sends one message per shard; each
        // batch must stay pinned to one shard sub-index
        let mut b = DynamicBatcher::new(BatcherConfig::default());
        let now = Instant::now();
        b.push(req(1, 4, 5), RoutePath::Rt, Some(0), 0, now);
        b.push(req(1, 4, 5), RoutePath::Rt, Some(1), 0, now);
        b.push(req(2, 4, 5), RoutePath::Rt, Some(0), 0, now);
        let first = b.next_batch().unwrap();
        assert_eq!(first.shard, Some(0));
        let ids: Vec<u64> = first.requests.iter().map(|(r, _)| r.id).collect();
        assert_eq!(ids, vec![1, 2], "same-shard messages batch together");
        let second = b.next_batch().unwrap();
        assert_eq!(second.shard, Some(1));
        assert_eq!(second.requests.len(), 1);
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn different_fences_never_batch_together() {
        // two scatters straddling an insert carry different fences; the
        // older request's legs must be served at the older prefix on
        // every worker, so the batch splits on the fence
        let mut b = DynamicBatcher::new(BatcherConfig::default());
        let now = Instant::now();
        b.push(req(1, 4, 5), RoutePath::Rt, Some(0), 3, now);
        b.push(req(2, 4, 5), RoutePath::Rt, Some(0), 4, now);
        b.push(req(3, 4, 5), RoutePath::Rt, Some(0), 3, now);
        let first = b.next_batch().unwrap();
        assert_eq!(first.fence, 3);
        let ids: Vec<u64> = first.requests.iter().map(|(r, _)| r.id).collect();
        assert_eq!(ids, vec![1, 3], "same-fence requests batch together");
        let second = b.next_batch().unwrap();
        assert_eq!(second.fence, 4);
        assert_eq!(second.requests[0].0.id, 2);
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn same_mode_different_path_never_batches() {
        // Auto-mode requests can land on different paths when k differs;
        // if k matches but the submit-time route differs (e.g. a request
        // routed before an availability change), the batch must split
        let mut b = DynamicBatcher::new(BatcherConfig::default());
        let now = Instant::now();
        b.push(req(1, 4, 5), RoutePath::Rt, None, 0, now);
        b.push(req(2, 4, 5), RoutePath::BruteCpu, None, 0, now);
        let first = b.next_batch().unwrap();
        assert_eq!(first.requests.len(), 1);
        assert_eq!(first.path, RoutePath::Rt);
        let second = b.next_batch().unwrap();
        assert_eq!(second.path, RoutePath::BruteCpu);
    }
}
