//! Dynamic batcher: groups compatible queued requests so one scene
//! build / one PJRT dispatch serves many callers — the serving-side
//! analog of the paper's insight that per-round fixed costs (context
//! switches, BVH work) amortize over query volume.

use super::request::{KnnRequest, QueryMode};
use std::time::Instant;

/// A batch of requests sharing one execution: same k **and** same
/// [`QueryMode`], so the router's per-batch decision honors every
/// request's explicit mode.
#[derive(Debug)]
pub struct Batch {
    pub requests: Vec<(KnnRequest, Instant)>,
    /// Flattened query ranges: request i owns queries[ranges[i].0..ranges[i].1].
    pub ranges: Vec<(usize, usize)>,
    pub k: usize,
    pub mode: QueryMode,
}

impl Batch {
    pub fn total_queries(&self) -> usize {
        self.ranges.last().map(|r| r.1).unwrap_or(0)
    }
}

#[derive(Clone, Debug)]
pub struct BatcherConfig {
    /// Flush a batch when it reaches this many queries.
    pub max_queries: usize,
    /// Flush whatever is pending after this much waiting.
    pub max_requests: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self {
            max_queries: 4096,
            max_requests: 64,
        }
    }
}

/// Pull-based batcher: the worker drains the queue, the batcher groups.
#[derive(Debug)]
pub struct DynamicBatcher {
    cfg: BatcherConfig,
    pending: Vec<(KnnRequest, Instant)>,
}

impl DynamicBatcher {
    pub fn new(cfg: BatcherConfig) -> Self {
        Self {
            cfg,
            pending: Vec::new(),
        }
    }

    pub fn push(&mut self, req: KnnRequest, arrived: Instant) {
        self.pending.push((req, arrived));
    }

    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }

    /// Form the next batch: take the oldest request, then greedily add
    /// every other pending request with the same k and the same mode
    /// (order preserved) until a size bound trips. Returns None when
    /// idle. Mode homogeneity is what lets the service route a whole
    /// batch while still honoring each request's explicit `QueryMode`.
    pub fn next_batch(&mut self) -> Option<Batch> {
        if self.pending.is_empty() {
            return None;
        }
        let k = self.pending[0].0.k;
        let mode = self.pending[0].0.mode;
        let mut requests = Vec::new();
        let mut total_q = 0usize;
        let mut i = 0;
        while i < self.pending.len() {
            let compatible = self.pending[i].0.k == k && self.pending[i].0.mode == mode;
            let fits = total_q + self.pending[i].0.queries.len() <= self.cfg.max_queries
                || requests.is_empty(); // an oversize request still ships alone
            if compatible && fits && requests.len() < self.cfg.max_requests {
                let (req, t) = self.pending.remove(i);
                total_q += req.queries.len();
                requests.push((req, t));
                if total_q >= self.cfg.max_queries {
                    break;
                }
            } else {
                i += 1;
            }
        }
        let mut ranges = Vec::with_capacity(requests.len());
        let mut off = 0;
        for (req, _) in &requests {
            ranges.push((off, off + req.queries.len()));
            off += req.queries.len();
        }
        Some(Batch {
            requests,
            ranges,
            k,
            mode,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geom::Point3;

    fn req(id: u64, nq: usize, k: usize) -> KnnRequest {
        KnnRequest::new(id, vec![Point3::ZERO; nq], k)
    }

    #[test]
    fn batches_group_same_k() {
        let mut b = DynamicBatcher::new(BatcherConfig::default());
        let now = Instant::now();
        b.push(req(1, 10, 5), now);
        b.push(req(2, 10, 7), now);
        b.push(req(3, 10, 5), now);
        let batch = b.next_batch().unwrap();
        let ids: Vec<u64> = batch.requests.iter().map(|(r, _)| r.id).collect();
        assert_eq!(ids, vec![1, 3]);
        assert_eq!(batch.k, 5);
        assert_eq!(batch.total_queries(), 20);
        assert_eq!(batch.ranges, vec![(0, 10), (10, 20)]);
        // the k=7 request ships next
        let batch2 = b.next_batch().unwrap();
        assert_eq!(batch2.requests[0].0.id, 2);
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn size_bound_flushes() {
        let mut b = DynamicBatcher::new(BatcherConfig {
            max_queries: 15,
            max_requests: 64,
        });
        let now = Instant::now();
        b.push(req(1, 10, 5), now);
        b.push(req(2, 10, 5), now);
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.requests.len(), 1, "second request would exceed cap");
        assert_eq!(b.pending_len(), 1);
    }

    #[test]
    fn oversize_request_ships_alone() {
        let mut b = DynamicBatcher::new(BatcherConfig {
            max_queries: 5,
            max_requests: 64,
        });
        b.push(req(1, 100, 5), Instant::now());
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.total_queries(), 100);
    }

    #[test]
    fn no_request_lost_or_duplicated() {
        use super::super::request::QueryMode;
        crate::util::prop::check("batcher conservation", 20, |rng| {
            let mut b = DynamicBatcher::new(BatcherConfig {
                max_queries: 1 + rng.below(50) as usize,
                max_requests: 1 + rng.below(8) as usize,
            });
            let n = 1 + rng.below(40) as usize;
            let now = Instant::now();
            let modes = [QueryMode::Auto, QueryMode::Rt, QueryMode::Brute];
            for id in 0..n as u64 {
                let r = req(id, 1 + rng.below(20) as usize, 1 + rng.below(3) as usize)
                    .with_mode(modes[rng.below(3) as usize]);
                b.push(r, now);
            }
            let mut seen = std::collections::HashSet::new();
            while let Some(batch) = b.next_batch() {
                for (r, _) in &batch.requests {
                    if r.k != batch.k {
                        return Err("mixed k in batch".into());
                    }
                    if r.mode != batch.mode {
                        return Err("mixed mode in batch".into());
                    }
                    if !seen.insert(r.id) {
                        return Err(format!("request {} duplicated", r.id));
                    }
                }
            }
            if seen.len() != n {
                return Err(format!("lost requests: {} of {n}", seen.len()));
            }
            Ok(())
        });
    }

    #[test]
    fn mixed_modes_split_into_homogeneous_batches() {
        use super::super::request::QueryMode;
        let mut b = DynamicBatcher::new(BatcherConfig::default());
        let now = Instant::now();
        b.push(req(1, 4, 5).with_mode(QueryMode::Rt), now);
        b.push(req(2, 4, 5).with_mode(QueryMode::Brute), now);
        b.push(req(3, 4, 5).with_mode(QueryMode::Rt), now);
        let first = b.next_batch().unwrap();
        assert_eq!(first.mode, QueryMode::Rt);
        let ids: Vec<u64> = first.requests.iter().map(|(r, _)| r.id).collect();
        assert_eq!(ids, vec![1, 3], "same-mode requests batch together");
        let second = b.next_batch().unwrap();
        assert_eq!(second.mode, QueryMode::Brute);
        assert_eq!(second.requests[0].0.id, 2);
        assert!(b.next_batch().is_none());
    }
}
