//! Service metrics registry: lock-free counters + latency accumulator.

use crate::util::OnlineStats;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

#[derive(Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub responses: AtomicU64,
    pub rejected: AtomicU64,
    pub batches: AtomicU64,
    pub rt_requests: AtomicU64,
    pub brute_requests: AtomicU64,
    pub queries_served: AtomicU64,
    /// Acceleration-structure builds performed by the worker's indexes.
    /// Amortization claim: stays at 1 per dataset per route path no
    /// matter how many batches are served.
    pub builds: AtomicU64,
    latency: Mutex<OnlineStats>,
}

#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub responses: u64,
    pub rejected: u64,
    pub batches: u64,
    pub rt_requests: u64,
    pub brute_requests: u64,
    pub queries_served: u64,
    pub builds: u64,
    pub latency_mean_s: f64,
    pub latency_max_s: f64,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn inc(counter: &AtomicU64) {
        counter.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(counter: &AtomicU64, v: u64) {
        counter.fetch_add(v, Ordering::Relaxed);
    }

    pub fn record_latency(&self, seconds: f64) {
        self.latency.lock().unwrap().push(seconds);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let lat = self.latency.lock().unwrap();
        MetricsSnapshot {
            requests: self.requests.load(Ordering::Relaxed),
            responses: self.responses.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            rt_requests: self.rt_requests.load(Ordering::Relaxed),
            brute_requests: self.brute_requests.load(Ordering::Relaxed),
            queries_served: self.queries_served.load(Ordering::Relaxed),
            builds: self.builds.load(Ordering::Relaxed),
            latency_mean_s: if lat.count() > 0 { lat.mean() } else { 0.0 },
            latency_max_s: if lat.count() > 0 { lat.max() } else { 0.0 },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_latency_accumulate() {
        let m = Metrics::new();
        Metrics::inc(&m.requests);
        Metrics::add(&m.queries_served, 10);
        m.record_latency(0.5);
        m.record_latency(1.5);
        let s = m.snapshot();
        assert_eq!(s.requests, 1);
        assert_eq!(s.queries_served, 10);
        assert!((s.latency_mean_s - 1.0).abs() < 1e-12);
        assert_eq!(s.latency_max_s, 1.5);
    }
}
