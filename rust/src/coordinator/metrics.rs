//! Service metrics registry: lock-free counters + latency accumulator.
//!
//! Since the worker-pool coordinator, backpressure is accounted **per
//! worker**: each pool worker owns a [`WorkerMetrics`] slot (accepted
//! submits, rejects, batches, inserts, live queue depth and its
//! high-water mark), and acceleration-structure builds are tracked as a
//! **per-route gauge** — the amortization claim is now "each route's
//! structure is built exactly once, on exactly one worker", which the
//! gauge makes directly observable.

use super::request::RoutePath;
use crate::obs::{AtomicHistogram, LogHistogram};
use crate::util::OnlineStats;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Per-worker counters of the pool: the operator-facing backpressure
/// story ("which queue is hot, which rejects") lives here.
#[derive(Default)]
pub struct WorkerMetrics {
    /// Messages accepted into this worker's bounded queue.
    pub submitted: AtomicU64,
    /// Submissions bounced off this worker's full queue.
    pub rejected: AtomicU64,
    pub batches: AtomicU64,
    pub inserts: AtomicU64,
    /// Messages currently sitting in the queue (incremented before the
    /// send, decremented by the worker on receive — never underflows).
    pub queue_depth: AtomicU64,
    /// Deepest the queue has ever been: an **exact** high-water mark.
    /// Producers serialize `[depth bump, send, hwm record]` under a
    /// per-worker enqueue lock and record from a depth load taken after
    /// the successful send, so every recorded value is an occupancy the
    /// queue truly attained — never inflated by a concurrent
    /// submitter's in-flight attempt or a failed send's transient bump,
    /// and never above the queue's physical capacity.
    pub queue_hwm: AtomicU64,
    /// End-to-end request latencies this worker completed (nanoseconds,
    /// log2 buckets). Wall-clock telemetry: recorded where the reply is
    /// handed off, merged across workers in worker-index order at
    /// snapshot time.
    pub hist_e2e: AtomicHistogram,
    /// Queue-wait durations (request arrival → batch service start).
    pub hist_queue_wait: AtomicHistogram,
    /// Fence catch-up durations (replaying fenced inserts a batch is
    /// ordered after, before its queries run).
    pub hist_fence: AtomicHistogram,
    /// Batch service durations (the index `knn` call itself).
    pub hist_service: AtomicHistogram,
    /// Gather-merge durations (folding one scatter leg's partial into
    /// its request accumulators).
    pub hist_merge: AtomicHistogram,
}

/// Shared counter registry of the service: every field is updated with
/// relaxed atomics on the hot path and read via [`Metrics::snapshot`].
#[derive(Default)]
pub struct Metrics {
    /// Requests accepted by `submit` (scattered sub-batches excluded).
    pub requests: AtomicU64,
    pub responses: AtomicU64,
    pub rejected: AtomicU64,
    pub batches: AtomicU64,
    pub rt_requests: AtomicU64,
    pub brute_requests: AtomicU64,
    pub queries_served: AtomicU64,
    pub inserts: AtomicU64,
    pub points_inserted: AtomicU64,
    /// Acceleration-structure builds per route path (gauge: the owning
    /// worker stores its index's current build count after every install,
    /// batch and insert). Amortization claim: each exercised route stays
    /// at 1 per dataset no matter how many batches are served.
    route_builds: [AtomicU64; RoutePath::COUNT],
    /// Per-shard build gauges of the sharded route (empty when sharding
    /// is off). Like `route_builds`, the owning worker stores the shard
    /// structure's cumulative build count (rebalance rebuilds included).
    pub shard_builds: Vec<AtomicU64>,
    /// Queries served per shard of the sharded route, counted exactly
    /// **once per (request, shard)**: the tick happens when a shard's
    /// partial is first merged into its gather, keyed by the gather's
    /// per-shard `merged` flag — so a failover re-dispatch whose
    /// original owner recovers (both serve the same leg) still adds a
    /// shard's work to its slot only once.
    pub shard_queries: Vec<AtomicU64>,
    /// One slot per pool worker.
    pub workers: Vec<WorkerMetrics>,
    /// Worker incarnations restarted by the supervisor after a panic.
    pub restarts: AtomicU64,
    /// Recovery replays: journaled requests re-enqueued after a worker
    /// restart, plus scatter partials re-dispatched to a failover owner.
    pub replays: AtomicU64,
    /// Requests shed because they aged past `request_deadline`.
    pub deadline_misses: AtomicU64,
    /// Requests quarantined by the poison ledger (killed a worker twice).
    pub poisoned: AtomicU64,
    /// Indexes restored from a validated snapshot at cold start (one per
    /// materialization, so a supervised crash-restart that re-loads the
    /// snapshot counts again).
    pub recovered: AtomicU64,
    /// Indexes rebuilt from source data because persistence was on and a
    /// snapshot existed but failed validation — the deterministic
    /// fallback the recovery contract promises.
    pub rebuilt: AtomicU64,
    /// WAL records past the best snapshot's watermark at cold start: the
    /// suffix recovery re-applies instead of finding inside a snapshot.
    pub wal_replayed: AtomicU64,
    /// Snapshot files or payloads rejected by checksum, version,
    /// fingerprint, watermark, or structural validation.
    pub snapshot_corrupt: AtomicU64,
    latency: Mutex<OnlineStats>,
}

/// Plain-value copy of one worker's [`WorkerMetrics`] slot.
#[derive(Clone, Debug)]
pub struct WorkerSnapshot {
    /// Messages accepted into this worker's queue.
    pub submitted: u64,
    pub rejected: u64,
    pub batches: u64,
    pub inserts: u64,
    pub queue_depth: u64,
    pub queue_hwm: u64,
}

/// Point-in-time copy of the whole registry, with per-route build
/// gauges resolved to plain values in [`RoutePath::ALL`] order.
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    /// Requests accepted by `submit`.
    pub requests: u64,
    pub responses: u64,
    pub rejected: u64,
    pub batches: u64,
    pub rt_requests: u64,
    pub brute_requests: u64,
    pub queries_served: u64,
    pub inserts: u64,
    pub points_inserted: u64,
    /// Sum of the per-route build gauges.
    pub builds: u64,
    /// `(route, builds)` for every route path, exercised or not.
    pub route_builds: Vec<(RoutePath, u64)>,
    /// Per-shard builds of the sharded route (empty when sharding off).
    pub shard_builds: Vec<u64>,
    /// Per-shard queries served (aligned with `shard_builds`).
    pub shard_queries: Vec<u64>,
    pub workers: Vec<WorkerSnapshot>,
    /// Worker incarnations restarted by the supervisor after a panic.
    pub restarts: u64,
    /// Journaled requests replayed plus scatter partials re-dispatched.
    pub replays: u64,
    /// Requests shed for aging past `request_deadline`.
    pub deadline_misses: u64,
    /// Requests quarantined by the poison ledger.
    pub poisoned: u64,
    /// Indexes restored from a validated snapshot at cold start.
    pub recovered: u64,
    /// Indexes rebuilt from source after an unusable snapshot.
    pub rebuilt: u64,
    /// WAL records past the best snapshot's watermark at cold start.
    pub wal_replayed: u64,
    /// Snapshot files or payloads rejected by validation.
    pub snapshot_corrupt: u64,
    pub latency_mean_s: f64,
    pub latency_max_s: f64,
    /// End-to-end latency p50, in seconds (log2-bucket upper bound of
    /// the merged per-worker histograms; 0.0 with no samples).
    pub latency_p50_s: f64,
    /// End-to-end latency p95, in seconds (same basis as `latency_p50_s`).
    pub latency_p95_s: f64,
    /// End-to-end latency p99, in seconds (same basis as `latency_p50_s`).
    pub latency_p99_s: f64,
    /// End-to-end latency histogram, merged across workers in
    /// worker-index order (nanosecond log2 buckets).
    pub hist_e2e: LogHistogram,
    /// Queue-wait histogram (same merge order and bucketing).
    pub hist_queue_wait: LogHistogram,
    /// Fence catch-up histogram (same merge order and bucketing).
    pub hist_fence: LogHistogram,
    /// Batch service histogram (same merge order and bucketing).
    pub hist_service: LogHistogram,
    /// Gather-merge histogram (same merge order and bucketing).
    pub hist_merge: LogHistogram,
}

impl Metrics {
    /// A registry with no per-worker slots (standalone/unit use).
    pub fn new() -> Self {
        Self::default()
    }

    /// A registry for a pool of `workers` workers.
    pub fn with_workers(workers: usize) -> Self {
        Self::with_pool(workers, 0)
    }

    /// A registry for a pool of `workers` workers serving a route
    /// sharded `shards` ways (0 = sharding off: no per-shard slots).
    pub fn with_pool(workers: usize, shards: usize) -> Self {
        Metrics {
            workers: (0..workers).map(|_| WorkerMetrics::default()).collect(),
            shard_builds: (0..shards).map(|_| AtomicU64::new(0)).collect(),
            shard_queries: (0..shards).map(|_| AtomicU64::new(0)).collect(),
            ..Default::default()
        }
    }

    /// Bump a counter by one (relaxed).
    pub fn inc(counter: &AtomicU64) {
        counter.fetch_add(1, Ordering::Relaxed);
    }

    /// Bump a counter by `v` (relaxed).
    pub fn add(counter: &AtomicU64, v: u64) {
        counter.fetch_add(v, Ordering::Relaxed);
    }

    /// Update the per-route build gauge to the owning index's current
    /// build count.
    pub fn set_route_builds(&self, path: RoutePath, builds: u64) {
        self.route_builds[path.index()].store(builds, Ordering::Relaxed);
    }

    /// Update one shard's build gauge to its structure's cumulative
    /// build count (the owning worker calls this after every build,
    /// batch, insert and rebalance).
    pub fn set_shard_builds(&self, shard: usize, builds: u64) {
        self.shard_builds[shard].store(builds, Ordering::Relaxed);
    }

    /// Fold one request latency into the online accumulator.
    pub fn record_latency(&self, seconds: f64) {
        // poison only means another recorder panicked mid-push; the
        // accumulator itself is still consistent, so keep recording
        self.latency
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner)
            .push(seconds);
    }

    /// Consistent point-in-time copy of every counter and gauge.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let lat = self
            .latency
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner);
        // merge per-worker stage histograms in worker-index order —
        // per-bucket addition is order-insensitive, but a fixed order
        // keeps the merge auditable and byte-reproducible
        let mut hist_e2e = LogHistogram::new();
        let mut hist_queue_wait = LogHistogram::new();
        let mut hist_fence = LogHistogram::new();
        let mut hist_service = LogHistogram::new();
        let mut hist_merge = LogHistogram::new();
        for w in &self.workers {
            hist_e2e.merge(&w.hist_e2e.snapshot());
            hist_queue_wait.merge(&w.hist_queue_wait.snapshot());
            hist_fence.merge(&w.hist_fence.snapshot());
            hist_service.merge(&w.hist_service.snapshot());
            hist_merge.merge(&w.hist_merge.snapshot());
        }
        let route_builds: Vec<(RoutePath, u64)> = RoutePath::ALL
            .iter()
            .map(|&p| {
                // a sharded RT route's structure work lives in the
                // per-shard gauges; surface their sum as the route's
                // build count so the amortization gauge stays comparable
                // between sharded and unsharded runs
                let builds = if p == RoutePath::Rt && !self.shard_builds.is_empty() {
                    self.shard_builds
                        .iter()
                        .map(|b| b.load(Ordering::Relaxed))
                        .sum()
                } else {
                    self.route_builds[p.index()].load(Ordering::Relaxed)
                };
                (p, builds)
            })
            .collect();
        MetricsSnapshot {
            requests: self.requests.load(Ordering::Relaxed),
            responses: self.responses.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            rt_requests: self.rt_requests.load(Ordering::Relaxed),
            brute_requests: self.brute_requests.load(Ordering::Relaxed),
            queries_served: self.queries_served.load(Ordering::Relaxed),
            inserts: self.inserts.load(Ordering::Relaxed),
            points_inserted: self.points_inserted.load(Ordering::Relaxed),
            builds: route_builds.iter().map(|&(_, b)| b).sum(),
            route_builds,
            shard_builds: self
                .shard_builds
                .iter()
                .map(|b| b.load(Ordering::Relaxed))
                .collect(),
            shard_queries: self
                .shard_queries
                .iter()
                .map(|q| q.load(Ordering::Relaxed))
                .collect(),
            workers: self
                .workers
                .iter()
                .map(|w| WorkerSnapshot {
                    submitted: w.submitted.load(Ordering::Relaxed),
                    rejected: w.rejected.load(Ordering::Relaxed),
                    batches: w.batches.load(Ordering::Relaxed),
                    inserts: w.inserts.load(Ordering::Relaxed),
                    queue_depth: w.queue_depth.load(Ordering::Relaxed),
                    queue_hwm: w.queue_hwm.load(Ordering::Relaxed),
                })
                .collect(),
            restarts: self.restarts.load(Ordering::Relaxed),
            replays: self.replays.load(Ordering::Relaxed),
            deadline_misses: self.deadline_misses.load(Ordering::Relaxed),
            poisoned: self.poisoned.load(Ordering::Relaxed),
            recovered: self.recovered.load(Ordering::Relaxed),
            rebuilt: self.rebuilt.load(Ordering::Relaxed),
            wal_replayed: self.wal_replayed.load(Ordering::Relaxed),
            snapshot_corrupt: self.snapshot_corrupt.load(Ordering::Relaxed),
            latency_mean_s: if lat.count() > 0 { lat.mean() } else { 0.0 },
            latency_max_s: if lat.count() > 0 { lat.max() } else { 0.0 },
            latency_p50_s: LogHistogram::seconds(hist_e2e.percentile_upper_ns(50)),
            latency_p95_s: LogHistogram::seconds(hist_e2e.percentile_upper_ns(95)),
            latency_p99_s: LogHistogram::seconds(hist_e2e.percentile_upper_ns(99)),
            hist_e2e,
            hist_queue_wait,
            hist_fence,
            hist_service,
            hist_merge,
        }
    }
}

impl MetricsSnapshot {
    /// Builds performed for one route path.
    pub fn builds_of(&self, path: RoutePath) -> u64 {
        self.route_builds
            .iter()
            .find(|(p, _)| *p == path)
            .map(|&(_, b)| b)
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_latency_accumulate() {
        let m = Metrics::new();
        Metrics::inc(&m.requests);
        Metrics::add(&m.queries_served, 10);
        m.record_latency(0.5);
        m.record_latency(1.5);
        let s = m.snapshot();
        assert_eq!(s.requests, 1);
        assert_eq!(s.queries_served, 10);
        assert!((s.latency_mean_s - 1.0).abs() < 1e-12);
        assert_eq!(s.latency_max_s, 1.5);
        assert!(s.workers.is_empty());
    }

    #[test]
    fn route_builds_are_gauges_summed_into_builds() {
        let m = Metrics::new();
        m.set_route_builds(RoutePath::Rt, 1);
        m.set_route_builds(RoutePath::Rt, 1); // idempotent store, not add
        m.set_route_builds(RoutePath::BruteCpu, 2);
        let s = m.snapshot();
        assert_eq!(s.builds, 3);
        assert_eq!(s.builds_of(RoutePath::Rt), 1);
        assert_eq!(s.builds_of(RoutePath::Brute), 0);
        assert_eq!(s.builds_of(RoutePath::BruteCpu), 2);
    }

    #[test]
    fn shard_slots_gauge_and_accumulate() {
        let m = Metrics::with_pool(2, 3);
        m.set_shard_builds(1, 1);
        m.set_shard_builds(1, 2); // gauge: overwrites, e.g. after a rebalance
        Metrics::add(&m.shard_queries[1], 16);
        Metrics::add(&m.shard_queries[1], 4);
        let s = m.snapshot();
        assert_eq!(s.shard_builds, vec![0, 2, 0]);
        assert_eq!(s.shard_queries, vec![0, 20, 0]);
        // sharding off: no slots at all
        assert!(Metrics::with_workers(2).snapshot().shard_builds.is_empty());
    }

    #[test]
    fn recovery_counters_surface_in_snapshot() {
        let m = Metrics::with_workers(2);
        Metrics::inc(&m.restarts);
        Metrics::add(&m.replays, 3);
        Metrics::inc(&m.deadline_misses);
        Metrics::inc(&m.poisoned);
        let s = m.snapshot();
        assert_eq!(s.restarts, 1);
        assert_eq!(s.replays, 3);
        assert_eq!(s.deadline_misses, 1);
        assert_eq!(s.poisoned, 1);
        // a fresh registry reports all-zero recovery counters
        let z = Metrics::new().snapshot();
        assert_eq!(
            (z.restarts, z.replays, z.deadline_misses, z.poisoned),
            (0, 0, 0, 0)
        );
    }

    #[test]
    fn persistence_counters_surface_in_snapshot() {
        let m = Metrics::with_workers(2);
        Metrics::inc(&m.recovered);
        Metrics::inc(&m.rebuilt);
        Metrics::add(&m.wal_replayed, 5);
        Metrics::add(&m.snapshot_corrupt, 2);
        let s = m.snapshot();
        assert_eq!(s.recovered, 1);
        assert_eq!(s.rebuilt, 1);
        assert_eq!(s.wal_replayed, 5);
        assert_eq!(s.snapshot_corrupt, 2);
        let z = Metrics::new().snapshot();
        assert_eq!(
            (z.recovered, z.rebuilt, z.wal_replayed, z.snapshot_corrupt),
            (0, 0, 0, 0)
        );
    }

    #[test]
    fn worker_histograms_merge_into_the_snapshot() {
        let m = Metrics::with_workers(2);
        m.workers[0].hist_e2e.record(1_000);
        m.workers[1].hist_e2e.record(1_000_000);
        m.workers[1].hist_queue_wait.record(500);
        let s = m.snapshot();
        assert_eq!(s.hist_e2e.count(), 2);
        assert_eq!(s.hist_queue_wait.count(), 1);
        assert_eq!(s.hist_service.count(), 0);
        assert!(s.latency_p50_s > 0.0);
        assert!(s.latency_p99_s >= s.latency_p50_s);
        // a registry with no samples reports zero percentiles
        let z = Metrics::new().snapshot();
        assert_eq!((z.latency_p50_s, z.latency_p99_s), (0.0, 0.0));
    }

    #[test]
    fn per_worker_slots_track_independently() {
        let m = Metrics::with_workers(3);
        Metrics::inc(&m.workers[1].submitted);
        Metrics::add(&m.workers[1].queue_hwm, 7);
        let s = m.snapshot();
        assert_eq!(s.workers.len(), 3);
        assert_eq!(s.workers[1].submitted, 1);
        assert_eq!(s.workers[1].queue_hwm, 7);
        assert_eq!(s.workers[0].submitted, 0);
    }
}
