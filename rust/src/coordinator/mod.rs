//! The L3 serving layer: a batching kNN query service over the RT
//! simulator and the PJRT brute-force path.
//!
//! Architecture (vLLM-router-like, scaled to this problem):
//!
//! ```text
//!   clients ──submit()──▶ bounded queue ──▶ worker thread
//!                                            │  DynamicBatcher: group
//!                                            │  compatible requests
//!                                            ▼
//!                                  Router: RT path (TrueKNN over the
//!                                  BVH simulator) vs Brute path (PJRT
//!                                  artifacts), by workload shape
//!                                            │
//!                                            ▼ responses via channel
//! ```
//!
//! No tokio in the offline build; the event loop is a dedicated worker
//! thread with `std::sync::mpsc` channels, which is also the honest
//! analog of the paper's single-GPU dispatch loop.

mod request;
mod metrics;
mod batcher;
mod router;
mod service;

pub use batcher::DynamicBatcher;
pub use metrics::{Metrics, MetricsSnapshot};
pub use request::{KnnRequest, KnnResponse, QueryMode, RoutePath};
pub use router::{Router, RouterConfig};
pub use service::{Service, ServiceConfig, ServiceError, ServiceHandle};
