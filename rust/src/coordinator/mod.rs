//! The L3 serving layer: a batching kNN query service over the RT
//! simulator and the PJRT brute-force path, served by a route-sharded
//! worker pool.
//!
//! Architecture (vLLM-router-like, scaled to this problem):
//!
//! ```text
//!   clients ──submit()──▶ Router: pick path (RT vs brute, by workload
//!              │          shape) + owning worker (rendezvous hash of
//!              │          the route, so indexes never migrate)
//!              ▼
//!    per-worker bounded queues (backpressure accounted per worker)
//!       │            │            │
//!       ▼            ▼            ▼
//!    worker 0     worker 1  …  worker W-1      (ServiceConfig::workers)
//!    DynamicBatcher: group     each worker owns the persistent
//!    compatible requests       indexes of its route shard; per-batch
//!       │                      traversal fans across exec threads
//!       ▼ responses via channel  (batch-level × launch-level parallelism)
//! ```
//!
//! Responses are bitwise-identical at any pool size and any thread
//! count: routing is a pure function, a route's requests stay FIFO on
//! one worker, inserts are fenced through one shared append-once log,
//! and per-request results never depend on batch composition (engine
//! determinism contract).
//!
//! A route configured with `ServiceConfig::shards > 1` additionally
//! splits its *dataset* into spatial shards ([`crate::shard`]): each
//! shard's sub-index lives on its own worker
//! ([`Router::worker_for_shard`]), the handle scatters such a request
//! to every shard owner, and each owner **merges its partial into the
//! gather as it finishes** — the incremental pairwise merge (itself
//! fanned across the exec engine) replaces the old single
//! O(queries·k·S) pass on whichever worker delivered last. Merge order
//! cannot matter: every top-k cut is keep-k-smallest under the strict
//! `(distance, id)` total order, so the gathered response is bitwise
//! identical to the unsharded single-worker oracle at any
//! shards × workers × threads × speculation.
//!
//! **Inserts are fenced, not barriers.** An accepted insert is appended
//! exactly once to the pool-shared insert log and workers receive only
//! a sequence advance; each worker pulls the records it needs between
//! batches, so only owners materialize points. Every request is
//! stamped at submit with the log sequence it must observe — all S
//! legs of a scattered request share one fence read under the insert
//! lock, so an insert can never land *between* two shards of one
//! request, and a failover re-dispatch re-serves at the gather's
//! original fence (an ephemeral at-fence shard rebuild, if its new
//! worker already ran past it). Queries submitted after `insert`
//! returns observe the points on every route; queries racing it may or
//! may not, exactly as with a single worker.
//!
//! No tokio in the offline build; the event loop is a pool of dedicated
//! worker threads with `std::sync::mpsc` channels, which is also the
//! honest analog of a multi-GPU dispatch loop over per-device queues.
//!
//! # Failure model
//!
//! The pool is **fault-tolerant by supervision** (see `supervisor`):
//! every worker's serving loop runs under `catch_unwind`, and the
//! recovery paths are deterministic enough to assert on.
//!
//! **What is survived.**
//!
//! - *Worker panics* — genuine bugs or faults injected by a seeded
//!   [`crate::faults::FaultPlan`]. The supervisor restarts the loop on
//!   the same thread: indexes are rebuilt from the base dataset plus
//!   the shared insert log's fenced prefix (indexes are pure functions
//!   of `(base, log prefix, config)`, so the rebuild is bit-identical),
//!   and every accepted-but-unanswered request is re-enqueued from the
//!   journal in its original submit order, each carrying its original
//!   fence. Because a route's requests stay FIFO on one worker even
//!   across a restart, replayed responses are **bitwise-identical** to
//!   a run without the crash.
//! - *Worker hangs* — detected by heartbeat staleness. On a sharded
//!   pool, a dedicated monitor re-dispatches a timed-out scatter
//!   partial — at the gather's original insert fence — to the shard's
//!   deterministic failover owner
//!   ([`Router::worker_for_shard_excluding`]), which rebuilds the shard
//!   from its own partition replica at exactly that log prefix and
//!   delivers the identical partial. Partial delivery is idempotent
//!   *and counter-deduped* (per-shard merged flag), so the owner waking
//!   up later and delivering a duplicate neither changes the response
//!   nor double-counts the shard's work.
//! - *Crash loops* — a crash is attributed to the requests in flight at
//!   that moment; an id that kills its worker twice is **quarantined**:
//!   its pending entries fail with [`ServiceError::Poisoned`], later
//!   submits of the id are refused at the boundary, and the pool keeps
//!   serving everyone else. A worker crashing repeatedly *without batch
//!   progress* (a startup crash loop a restart cannot fix) is given up
//!   on after a bounded number of attempts; its journaled requests fail
//!   with [`ServiceError::ShutDown`] instead of hanging their clients.
//!
//! **What clients observe.** Every accepted request terminates: with its
//! response, or with a typed [`ServiceError`] (`DeadlineExceeded` when
//! it out-waited `ServiceConfig::request_deadline`, `Poisoned`,
//! `ShutDown`) delivered through the same [`ResponseReceiver`]. No
//! accepted, non-poisoned request is silently lost under any fault
//! schedule — the fault-injection suite asserts exactly that, plus
//! bitwise equality of all served responses against a no-fault
//! single-worker oracle, plus exact recovery counters
//! (`restarts`/`replays`/`deadline_misses`/`poisoned` in
//! [`MetricsSnapshot`]).
//!
//! Replay is insert-exact: a journaled request carries the fence it
//! was stamped with at submit, so re-serving it after a crash — even
//! once the log has grown past it — observes precisely the insert
//! prefix the original attempt would have (scattered legs exactly;
//! direct legs at-least, which is the same serve-at-least contract a
//! live direct request has).
//!
//! **Process-level crashes** (the whole service dying, not one worker)
//! are survived when [`ServiceConfig::persist`] is set — see
//! [`crate::persist`] for the on-disk formats. Every accepted insert is
//! appended to a checksummed WAL *before* the shared insert log (under
//! the same lock, so WAL order is fence order), and
//! the RT route's index is periodically serialized into a checksummed,
//! fingerprint-fenced snapshot (plus a final one at clean shutdown). A
//! cold [`Service::start`] repairs the WAL's torn tail, loads the
//! newest snapshot that survives **full** validation, and replays the
//! WAL suffix past its watermark — landing on a serving state bitwise
//! identical to the process that wrote it. Any checksum, version,
//! fingerprint, or structural mismatch rejects the whole file and falls
//! back to the deterministic rebuild from source data: recovery can
//! cost build time, never answers. The outcome is observable in
//! [`MetricsSnapshot`] (`recovered` / `rebuilt` / `wal_replayed` /
//! `snapshot_corrupt`), and the crash-recovery suite asserts bitwise
//! equality of post-recovery responses against a never-crashed
//! single-worker oracle under seeded I/O fault schedules
//! ([`crate::faults::IoFault`]).
//!
//! # Span taxonomy
//!
//! With [`ServiceConfig::trace`] set, every request leaves a span tree
//! in per-worker CRC-framed JSONL trace files (see [`crate::obs`];
//! read back with `trueknn trace`). The trace id is the request id;
//! the `request` root is synthesized by the reader from the spans'
//! extent. Span names and their attributes:
//!
//! | span | emitted by | parent | attributes |
//! |---|---|---|---|
//! | `request` | reader (synthesized root) | — | — |
//! | `queue_wait` | owning worker, per request | root | — |
//! | `fence_catchup` | owning worker, per request | root | `fence` |
//! | `shard_leg` | shard owner, per scattered request | root | `shard`, `fence`, `batch` |
//! | `service` | owning worker, per direct request | root | `fence`, `batch` |
//! | `round` | worker, per TrueKNN expansion round | leg / service | `round`, `radius`, `queries`, `survivors`, `heap_pushes` |
//! | `gather_merge` | delivering worker, per merged partial | root | `shard` |
//! | `reply` | replying worker (zero-duration event) | root | `queries` |
//! | `redispatched` | failover monitor (control file, event) | root | `shard`, `fence` |
//! | `recovery` | cold start / RT rebuild (event, trace 0) | — | `snapshot_rejected` or `recovered`, `watermark` |
//!
//! The `round` spans carry the **deterministic** per-round convergence
//! counters verbatim (the same values summed into
//! [`crate::knn::HwCounters`]), so a trace-reconstructed profile can be
//! checked *exactly* against the counter oracle; only start/end
//! timestamps are wall-clock, and those flow exclusively through the
//! [`crate::obs::clock`] chokepoint. Tracing is result-transparent:
//! responses and counters are bitwise identical with tracing on or off.

mod request;
mod metrics;
mod batcher;
mod router;
mod service;
mod supervisor;

pub use batcher::{Batch, BatcherConfig, DynamicBatcher};
pub use metrics::{Metrics, MetricsSnapshot, WorkerMetrics, WorkerSnapshot};
pub use request::{KnnRequest, KnnResponse, QueryMode, RoutePath};
pub use router::{Router, RouterConfig};
pub use service::{
    PersistConfig, ResponseReceiver, Service, ServiceConfig, ServiceError, ServiceHandle,
};
// the tracing config rides on ServiceConfig; re-export it here so
// serving callers configure observability without importing obs paths
pub use crate::obs::TraceConfig;
