//! The L3 serving layer: a batching kNN query service over the RT
//! simulator and the PJRT brute-force path, served by a route-sharded
//! worker pool.
//!
//! Architecture (vLLM-router-like, scaled to this problem):
//!
//! ```text
//!   clients ──submit()──▶ Router: pick path (RT vs brute, by workload
//!              │          shape) + owning worker (rendezvous hash of
//!              │          the route, so indexes never migrate)
//!              ▼
//!    per-worker bounded queues (backpressure accounted per worker)
//!       │            │            │
//!       ▼            ▼            ▼
//!    worker 0     worker 1  …  worker W-1      (ServiceConfig::workers)
//!    DynamicBatcher: group     each worker owns the persistent
//!    compatible requests       indexes of its route shard; per-batch
//!       │                      traversal fans across exec threads
//!       ▼ responses via channel  (batch-level × launch-level parallelism)
//! ```
//!
//! Responses are bitwise-identical at any pool size and any thread
//! count: routing is a pure function, a route's requests stay FIFO on
//! one worker, inserts are broadcast barriers, and per-request results
//! never depend on batch composition (engine determinism contract).
//!
//! A route configured with `ServiceConfig::shards > 1` additionally
//! splits its *dataset* into spatial shards ([`crate::shard`]): each
//! shard's sub-index lives on its own worker
//! ([`Router::worker_for_shard`]), the handle scatters such a request to
//! every shard owner, and the last-finishing owner gathers — merging the
//! per-shard partials into the one exact response. That turns the
//! remaining hot-route serialization into data parallelism while
//! keeping responses bitwise-identical to the unsharded single-worker
//! oracle at any shards × workers × threads.
//!
//! No tokio in the offline build; the event loop is a pool of dedicated
//! worker threads with `std::sync::mpsc` channels, which is also the
//! honest analog of a multi-GPU dispatch loop over per-device queues.

mod request;
mod metrics;
mod batcher;
mod router;
mod service;

pub use batcher::{Batch, BatcherConfig, DynamicBatcher};
pub use metrics::{Metrics, MetricsSnapshot, WorkerMetrics, WorkerSnapshot};
pub use request::{KnnRequest, KnnResponse, QueryMode, RoutePath};
pub use router::{Router, RouterConfig};
pub use service::{Service, ServiceConfig, ServiceError, ServiceHandle};
