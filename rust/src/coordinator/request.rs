//! Request/response types of the query service.

use crate::geom::Point3;
use crate::knn::Neighbor;

/// How the caller wants the query executed.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum QueryMode {
    /// Let the router pick a path from the workload shape.
    Auto,
    /// Force the RT-core (TrueKNN) path.
    Rt,
    /// Force the PJRT brute-force path.
    Brute,
}

/// Which path actually served the request. Also the key under which the
/// service holds its persistent [`crate::index::NeighborIndex`]es.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum RoutePath {
    Rt,
    Brute,
    /// PJRT unavailable (no artifacts); brute executed on CPU fallback.
    BruteCpu,
}

impl RoutePath {
    /// Every route path, in dense-[`RoutePath::index`] order — the one
    /// deterministic iteration order for per-route state.
    pub const ALL: [RoutePath; 3] = [RoutePath::Rt, RoutePath::Brute, RoutePath::BruteCpu];
    /// Number of route paths (`ALL.len()`).
    pub const COUNT: usize = 3;

    /// Dense index into per-route metric tables.
    pub fn index(self) -> usize {
        match self {
            RoutePath::Rt => 0,
            RoutePath::Brute => 1,
            RoutePath::BruteCpu => 2,
        }
    }

    /// Stable human-readable label (metrics lines, CLI summaries).
    pub fn name(self) -> &'static str {
        match self {
            RoutePath::Rt => "rt",
            RoutePath::Brute => "brute",
            RoutePath::BruteCpu => "brute-cpu",
        }
    }
}

/// One client request: `k` neighbors for each query point.
#[derive(Clone, Debug)]
pub struct KnnRequest {
    pub id: u64,
    pub queries: Vec<Point3>,
    pub k: usize,
    pub mode: QueryMode,
}

impl KnnRequest {
    /// A request with the default [`QueryMode::Auto`] routing.
    pub fn new(id: u64, queries: Vec<Point3>, k: usize) -> Self {
        Self {
            id,
            queries,
            k,
            mode: QueryMode::Auto,
        }
    }

    /// Same request with the execution path forced.
    pub fn with_mode(mut self, mode: QueryMode) -> Self {
        self.mode = mode;
        self
    }

    /// Boundary validation: why this request must not enter the pool,
    /// or `None` if it is well-formed. Checked once at `submit` so
    /// malformed requests get a typed rejection instead of threading
    /// degenerate shapes (k = 0, empty batches, NaN/infinite
    /// coordinates) into every downstream fallback path.
    pub fn reject_reason(&self) -> Option<&'static str> {
        if self.k == 0 {
            return Some("k must be at least 1");
        }
        if self.queries.is_empty() {
            return Some("empty query batch");
        }
        if self.queries.iter().any(|q| !q.is_finite()) {
            return Some("non-finite query coordinate");
        }
        None
    }
}

/// The service's answer to one [`KnnRequest`].
#[derive(Clone, Debug)]
pub struct KnnResponse {
    pub id: u64,
    /// Per query, sorted ascending by distance.
    pub neighbors: Vec<Vec<Neighbor>>,
    pub path: RoutePath,
    /// Seconds from dequeue to completion.
    pub service_seconds: f64,
    /// Seconds from submit to completion (includes queueing).
    pub latency_seconds: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reject_reason_flags_every_degenerate_shape() {
        let ok = KnnRequest::new(1, vec![Point3::splat(0.5)], 3);
        assert_eq!(ok.reject_reason(), None);
        assert!(KnnRequest::new(2, vec![Point3::splat(0.5)], 0)
            .reject_reason()
            .is_some());
        assert!(KnnRequest::new(3, Vec::new(), 3).reject_reason().is_some());
        for bad in [f32::NAN, f32::INFINITY, f32::NEG_INFINITY] {
            let req = KnnRequest::new(4, vec![Point3::new(0.0, bad, 0.0)], 3);
            assert!(req.reject_reason().is_some(), "{bad} must be rejected");
        }
    }
}
