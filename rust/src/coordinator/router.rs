//! Route policy: RT (TrueKNN) path vs PJRT brute-force path, and the
//! route→worker assignment of the pool coordinator.
//!
//! The crossover follows the paper's own findings: the RT reduction wins
//! when the BVH can prune (large n, modest k) and loses to dense matmul
//! when the candidate set approaches the whole dataset (k ~ n) or the
//! dataset is tiny (fixed costs dominate, §6.1/Fig 9).
//!
//! Worker assignment uses **rendezvous (highest-random-weight) hashing**:
//! every `(route, worker)` pair gets a deterministic pseudo-random
//! weight and the route lands on the arg-max worker. Properties the
//! pool relies on:
//!
//! - the assignment is a pure function of `(route, pool size)` — any
//!   handle, worker or test computes the same owner with no shared
//!   state;
//! - a route therefore has exactly **one** owning worker for the life of
//!   the pool: its index is built once and never migrates;
//! - growing the pool only ever moves routes *onto* the new worker
//!   (minimal disruption), so perf comparisons across pool sizes keep
//!   per-route build counts comparable.
//!
//! A **sharded** route (dataset split into spatial shards, see
//! [`crate::shard`]) maps shard → worker through
//! [`Router::worker_for_shard`]: the route's rendezvous anchor plus a
//! round-robin offset, so `S` shards always occupy `min(S, pool)`
//! distinct workers — the hot route's batches provably spread instead
//! of depending on hash luck.

use super::request::{KnnRequest, QueryMode, RoutePath};

/// Thresholds of the RT-vs-brute crossover policy.
#[derive(Clone, Debug)]
pub struct RouterConfig {
    /// Below this many data points, brute force always wins.
    pub brute_below_n: usize,
    /// If k exceeds this fraction of n, top-k covers most of the data —
    /// take the matmul path.
    pub brute_k_fraction: f64,
    /// Is a PJRT runtime available? (Otherwise brute falls back to CPU.)
    pub pjrt_available: bool,
}

impl Default for RouterConfig {
    fn default() -> Self {
        Self {
            brute_below_n: 2_000,
            brute_k_fraction: 0.05,
            pjrt_available: false,
        }
    }
}

/// Stateless route picker: holds the [`RouterConfig`] thresholds and
/// exposes the pure worker-assignment functions.
#[derive(Clone, Debug)]
pub struct Router {
    cfg: RouterConfig,
}

/// SplitMix64 finalizer — the weight function of the rendezvous hash.
fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

/// Salt mixed into the rendezvous weights. Any value keeps the
/// rendezvous properties; this one is chosen so the crate's three fixed
/// routes actually spread at the feasible pool sizes: at 2 workers Rt
/// sits alone (separated from both brute variants, so the two routes
/// that can serve traffic together never share a worker), and at 3
/// workers every route has its own worker. Changing it remaps routes —
/// harmless between runs (indexes are per-process), but keep it stable
/// within a release.
const SPREAD_SALT: u64 = 7;

impl Router {
    /// A router with the given crossover thresholds.
    pub fn new(cfg: RouterConfig) -> Self {
        Self { cfg }
    }

    /// The pool worker owning `path` in a pool of `workers` workers:
    /// rendezvous hashing, deterministic and shared-state-free (see the
    /// module docs for the properties the coordinator relies on).
    pub fn worker_for(path: RoutePath, workers: usize) -> usize {
        assert!(workers > 0, "worker pool cannot be empty");
        (0..workers)
            .max_by_key(|&w| {
                splitmix64(SPREAD_SALT ^ (((path.index() as u64) << 32) | (w as u64 + 1)))
            })
            // lint: allow(panic-in-lib) — 0..workers is non-empty (asserted above)
            .expect("non-empty range")
    }

    /// The pool worker owning spatial shard `shard` of a sharded route:
    /// the route's rendezvous anchor ([`Router::worker_for`]) plus a
    /// round-robin offset. Still a pure function of
    /// `(route, shard, pool size)` — every handle and worker computes
    /// the same owner with no shared state — and, unlike a per-shard
    /// rendezvous draw, it *guarantees* a route with `S` shards occupies
    /// exactly `min(S, workers)` distinct workers, which is the whole
    /// point of sharding a hot route: its batches are served
    /// concurrently the moment the pool has a second worker.
    pub fn worker_for_shard(path: RoutePath, shard: usize, workers: usize) -> usize {
        (Self::worker_for(path, workers) + shard) % workers
    }

    /// Failover owner of shard `shard` when worker `dead` is excluded
    /// from the pool: the shard's normal owner if it is alive, else the
    /// ring successor — the next worker in the same round-robin order
    /// [`Router::worker_for_shard`] walks, which is the first worker
    /// that would own the shard in a pool without `dead`. Still a pure
    /// function of `(route, shard, pool size, dead)`, so the supervisor
    /// and any test agree on where a timed-out partial is re-dispatched.
    /// Requires `workers >= 2` (with one worker there is nobody to fail
    /// over to).
    pub fn worker_for_shard_excluding(
        path: RoutePath,
        shard: usize,
        workers: usize,
        dead: usize,
    ) -> usize {
        assert!(workers >= 2, "failover needs a second worker");
        let w = Self::worker_for_shard(path, shard, workers);
        if w == dead {
            (w + 1) % workers
        } else {
            w
        }
    }

    /// Pick the execution path for a request against `n_data` points.
    pub fn route(&self, req: &KnnRequest, n_data: usize) -> RoutePath {
        let brute_path = if self.cfg.pjrt_available {
            RoutePath::Brute
        } else {
            RoutePath::BruteCpu
        };
        match req.mode {
            QueryMode::Rt => RoutePath::Rt,
            QueryMode::Brute => brute_path,
            QueryMode::Auto => {
                if n_data < self.cfg.brute_below_n {
                    return brute_path;
                }
                if (req.k as f64) > self.cfg.brute_k_fraction * n_data as f64 {
                    return brute_path;
                }
                RoutePath::Rt
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geom::Point3;

    fn req(k: usize, mode: QueryMode) -> KnnRequest {
        KnnRequest::new(0, vec![Point3::ZERO; 8], k).with_mode(mode)
    }

    #[test]
    fn explicit_modes_win() {
        let r = Router::new(RouterConfig::default());
        assert_eq!(r.route(&req(5, QueryMode::Rt), 10), RoutePath::Rt);
        assert_eq!(r.route(&req(5, QueryMode::Brute), 1_000_000), RoutePath::BruteCpu);
    }

    #[test]
    fn auto_routes_by_shape() {
        let r = Router::new(RouterConfig {
            pjrt_available: true,
            ..Default::default()
        });
        // tiny dataset → brute
        assert_eq!(r.route(&req(5, QueryMode::Auto), 500), RoutePath::Brute);
        // big dataset, small k → RT
        assert_eq!(r.route(&req(5, QueryMode::Auto), 100_000), RoutePath::Rt);
        // huge k → brute
        assert_eq!(r.route(&req(20_000, QueryMode::Auto), 100_000), RoutePath::Brute);
    }

    #[test]
    fn pjrt_unavailable_falls_back_to_cpu() {
        let r = Router::new(RouterConfig::default());
        assert_eq!(r.route(&req(5, QueryMode::Auto), 100), RoutePath::BruteCpu);
    }

    #[test]
    fn worker_assignment_is_deterministic_and_in_range() {
        for workers in 1..=16 {
            for path in RoutePath::ALL {
                let w = Router::worker_for(path, workers);
                assert!(w < workers, "{path:?} @ {workers} -> {w}");
                assert_eq!(w, Router::worker_for(path, workers), "not deterministic");
            }
        }
        // a single worker owns everything
        for path in RoutePath::ALL {
            assert_eq!(Router::worker_for(path, 1), 0);
        }
    }

    #[test]
    fn growing_the_pool_only_moves_routes_to_the_new_worker() {
        // the rendezvous property: going from W to W+1 workers, a route
        // either keeps its owner or moves to worker W — never between
        // two old workers (an old worker's weight for the route did not
        // change, so a different old worker cannot newly win)
        for workers in 1..16usize {
            for path in RoutePath::ALL {
                let before = Router::worker_for(path, workers);
                let after = Router::worker_for(path, workers + 1);
                assert!(
                    after == before || after == workers,
                    "{path:?}: {workers}->{} remapped {before}->{after}",
                    workers + 1
                );
            }
        }
    }

    #[test]
    fn pool_of_three_gives_every_route_its_own_worker() {
        // SPREAD_SALT is chosen for exactly this: at the max feasible
        // pool size, no two routes share a worker
        let owners: std::collections::HashSet<usize> = RoutePath::ALL
            .iter()
            .map(|&p| Router::worker_for(p, 3))
            .collect();
        assert_eq!(owners.len(), 3, "routes must spread across a 3-pool");
    }

    #[test]
    fn shard_owners_spread_round_robin_from_the_route_anchor() {
        for workers in 1..=8usize {
            let anchor = Router::worker_for(RoutePath::Rt, workers);
            let mut owners = std::collections::HashSet::new();
            for shard in 0..8 {
                let w = Router::worker_for_shard(RoutePath::Rt, shard, workers);
                assert!(w < workers);
                assert_eq!(w, (anchor + shard) % workers, "not anchored");
                owners.insert(w);
            }
            // 8 shards must occupy min(8, workers) distinct workers —
            // the concurrency guarantee the sharded hot route relies on
            assert_eq!(owners.len(), workers.min(8), "workers={workers}");
        }
        // shard 0 sits on the route's rendezvous anchor itself
        assert_eq!(
            Router::worker_for_shard(RoutePath::Rt, 0, 3),
            Router::worker_for(RoutePath::Rt, 3)
        );
    }

    #[test]
    fn failover_owner_excludes_the_dead_worker_deterministically() {
        for workers in 2..=6usize {
            for shard in 0..6 {
                let owner = Router::worker_for_shard(RoutePath::Rt, shard, workers);
                for dead in 0..workers {
                    let fo =
                        Router::worker_for_shard_excluding(RoutePath::Rt, shard, workers, dead);
                    assert!(fo < workers);
                    assert_ne!(fo, dead, "failover landed on the dead worker");
                    if owner != dead {
                        assert_eq!(fo, owner, "live owner must keep its shard");
                    } else {
                        assert_eq!(fo, (owner + 1) % workers, "ring successor");
                    }
                }
            }
        }
    }

    #[test]
    fn pool_of_two_separates_rt_from_both_brute_variants() {
        // only one brute variant serves traffic in a given process
        // (pjrt_available is fixed at startup), so the pairs that can
        // actually run concurrently are (Rt, Brute) and (Rt, BruteCpu) —
        // both must land on different workers for batch-level
        // parallelism to exist at 2 workers
        let rt = Router::worker_for(RoutePath::Rt, 2);
        assert_ne!(rt, Router::worker_for(RoutePath::Brute, 2));
        assert_ne!(rt, Router::worker_for(RoutePath::BruteCpu, 2));
    }
}
