//! Route policy: RT (TrueKNN) path vs PJRT brute-force path.
//!
//! The crossover follows the paper's own findings: the RT reduction wins
//! when the BVH can prune (large n, modest k) and loses to dense matmul
//! when the candidate set approaches the whole dataset (k ~ n) or the
//! dataset is tiny (fixed costs dominate, §6.1/Fig 9).

use super::request::{KnnRequest, QueryMode, RoutePath};

#[derive(Clone, Debug)]
pub struct RouterConfig {
    /// Below this many data points, brute force always wins.
    pub brute_below_n: usize,
    /// If k exceeds this fraction of n, top-k covers most of the data —
    /// take the matmul path.
    pub brute_k_fraction: f64,
    /// Is a PJRT runtime available? (Otherwise brute falls back to CPU.)
    pub pjrt_available: bool,
}

impl Default for RouterConfig {
    fn default() -> Self {
        Self {
            brute_below_n: 2_000,
            brute_k_fraction: 0.05,
            pjrt_available: false,
        }
    }
}

#[derive(Clone, Debug)]
pub struct Router {
    cfg: RouterConfig,
}

impl Router {
    pub fn new(cfg: RouterConfig) -> Self {
        Self { cfg }
    }

    /// Pick the execution path for a request against `n_data` points.
    pub fn route(&self, req: &KnnRequest, n_data: usize) -> RoutePath {
        let brute_path = if self.cfg.pjrt_available {
            RoutePath::Brute
        } else {
            RoutePath::BruteCpu
        };
        match req.mode {
            QueryMode::Rt => RoutePath::Rt,
            QueryMode::Brute => brute_path,
            QueryMode::Auto => {
                if n_data < self.cfg.brute_below_n {
                    return brute_path;
                }
                if (req.k as f64) > self.cfg.brute_k_fraction * n_data as f64 {
                    return brute_path;
                }
                RoutePath::Rt
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geom::Point3;

    fn req(k: usize, mode: QueryMode) -> KnnRequest {
        KnnRequest::new(0, vec![Point3::ZERO; 8], k).with_mode(mode)
    }

    #[test]
    fn explicit_modes_win() {
        let r = Router::new(RouterConfig::default());
        assert_eq!(r.route(&req(5, QueryMode::Rt), 10), RoutePath::Rt);
        assert_eq!(r.route(&req(5, QueryMode::Brute), 1_000_000), RoutePath::BruteCpu);
    }

    #[test]
    fn auto_routes_by_shape() {
        let r = Router::new(RouterConfig {
            pjrt_available: true,
            ..Default::default()
        });
        // tiny dataset → brute
        assert_eq!(r.route(&req(5, QueryMode::Auto), 500), RoutePath::Brute);
        // big dataset, small k → RT
        assert_eq!(r.route(&req(5, QueryMode::Auto), 100_000), RoutePath::Rt);
        // huge k → brute
        assert_eq!(r.route(&req(20_000, QueryMode::Auto), 100_000), RoutePath::Brute);
    }

    #[test]
    fn pjrt_unavailable_falls_back_to_cpu() {
        let r = Router::new(RouterConfig::default());
        assert_eq!(r.route(&req(5, QueryMode::Auto), 100), RoutePath::BruteCpu);
    }
}
