//! The query service: a worker thread owning one persistent
//! [`NeighborIndex`] per route path, fed through a bounded queue with
//! backpressure.
//!
//! This is where the paper's amortization argument pays off at the
//! serving layer: the worker builds each acceleration structure **once
//! per dataset** (tracked by the `builds` metric) and every batch after
//! that only refits/queries it. Before the index API, every batch paid a
//! full BVH build.
//!
//! The PJRT client wraps raw C pointers and is not `Send`, so the
//! runtime (and every index) is constructed *inside* the worker thread;
//! callers only touch channels.
//!
//! Per-batch ray launches go through the [`crate::exec`] parallel engine:
//! the RT index inherits `ServiceConfig::trueknn.threads` (0 = all
//! cores), so one worker thread owns the index while each batch's
//! traversal fans out across cores — results are identical at any
//! thread count by the engine's determinism contract.

use super::batcher::{BatcherConfig, DynamicBatcher};
use super::metrics::Metrics;
use super::request::{KnnRequest, KnnResponse, RoutePath};
use super::router::{Router, RouterConfig};
use crate::geom::Point3;
use crate::index::{BruteCpuIndex, BrutePjrtIndex, IndexConfig, NeighborIndex, TrueKnnIndex};
use crate::knn::TrueKnnParams;
use crate::runtime::PjrtRuntime;
use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, Sender, SyncSender, TrySendError};
use std::sync::Arc;
use std::time::Instant;

#[derive(Clone, Debug)]
pub struct ServiceConfig {
    pub batcher: BatcherConfig,
    pub router: RouterConfig,
    /// Bounded queue depth; submits beyond it are rejected (backpressure).
    pub queue_depth: usize,
    /// Try to load PJRT artifacts in the worker (falls back to CPU brute).
    pub use_pjrt: bool,
    pub trueknn: TrueKnnParams,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self {
            batcher: BatcherConfig::default(),
            router: RouterConfig::default(),
            queue_depth: 256,
            use_pjrt: false,
            trueknn: TrueKnnParams {
                exclude_self: false, // service queries are external points
                ..Default::default()
            },
        }
    }
}

#[derive(Debug, PartialEq, Eq)]
pub enum ServiceError {
    QueueFull,
    ShutDown,
}

impl std::fmt::Display for ServiceError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServiceError::QueueFull => write!(f, "service queue full (backpressure)"),
            ServiceError::ShutDown => write!(f, "service is shut down"),
        }
    }
}

impl std::error::Error for ServiceError {}

enum Msg {
    Request(KnnRequest, Sender<KnnResponse>, Instant),
    Shutdown,
}

/// Handle returned by `Service::start`; cheap to clone, submits requests.
#[derive(Clone)]
pub struct ServiceHandle {
    tx: SyncSender<Msg>,
    metrics: Arc<Metrics>,
    inflight: Arc<AtomicUsize>,
}

impl ServiceHandle {
    /// Submit a request; returns the response channel. Applies
    /// backpressure by rejecting when the queue is full.
    pub fn submit(&self, req: KnnRequest) -> Result<Receiver<KnnResponse>, ServiceError> {
        let (tx, rx) = std::sync::mpsc::channel();
        Metrics::inc(&self.metrics.requests);
        match self.tx.try_send(Msg::Request(req, tx, Instant::now())) {
            Ok(()) => {
                self.inflight.fetch_add(1, Ordering::SeqCst);
                Ok(rx)
            }
            Err(TrySendError::Full(_)) => {
                Metrics::inc(&self.metrics.rejected);
                Err(ServiceError::QueueFull)
            }
            Err(TrySendError::Disconnected(_)) => Err(ServiceError::ShutDown),
        }
    }

    /// Submit and wait for the response.
    pub fn query(&self, req: KnnRequest) -> Result<KnnResponse, ServiceError> {
        let rx = self.submit(req)?;
        rx.recv().map_err(|_| ServiceError::ShutDown)
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    pub fn inflight(&self) -> usize {
        self.inflight.load(Ordering::SeqCst)
    }
}

/// The service: owns the worker thread; dropping shuts it down.
pub struct Service {
    handle: ServiceHandle,
    worker: Option<std::thread::JoinHandle<()>>,
    tx: SyncSender<Msg>,
}

impl Service {
    /// Start the worker over a fixed dataset.
    pub fn start(data: Vec<Point3>, cfg: ServiceConfig) -> (Service, ServiceHandle) {
        let (tx, rx) = sync_channel::<Msg>(cfg.queue_depth);
        let metrics = Arc::new(Metrics::new());
        let inflight = Arc::new(AtomicUsize::new(0));
        let handle = ServiceHandle {
            tx: tx.clone(),
            metrics: metrics.clone(),
            inflight: inflight.clone(),
        };
        let worker_metrics = metrics;
        let worker_inflight = inflight;
        let worker = std::thread::spawn(move || {
            worker_loop(data, cfg, rx, worker_metrics, worker_inflight);
        });
        (
            Service {
                handle: handle.clone(),
                worker: Some(worker),
                tx,
            },
            handle,
        )
    }

    pub fn handle(&self) -> ServiceHandle {
        self.handle.clone()
    }

    pub fn shutdown(mut self) {
        self.shutdown_and_join();
        // Drop runs next but finds the worker already taken: exactly one
        // Msg::Shutdown is ever sent.
    }

    /// Shared by `shutdown` and `Drop`: signal the worker once and wait
    /// for it to drain. Idempotent — the `worker.take()` guard makes a
    /// second call a no-op.
    fn shutdown_and_join(&mut self) {
        if let Some(w) = self.worker.take() {
            let _ = self.tx.send(Msg::Shutdown);
            let _ = w.join();
        }
    }
}

impl Drop for Service {
    fn drop(&mut self) {
        self.shutdown_and_join();
    }
}

/// Per-worker index registry: one persistent [`NeighborIndex`] per route
/// path, built lazily on first use (the PJRT one eagerly, because the
/// router must know up front whether that path exists).
///
/// Each index owns a copy of the dataset (plus `data` here for building
/// further paths), trading memory for the zero-sharing ownership model —
/// at most 3 copies when every path is exercised. Sharing via
/// `Arc<[Point3]>` is the next step if dataset sizes outgrow that.
struct IndexRegistry {
    data: Vec<Point3>,
    trueknn: TrueKnnParams,
    by_path: HashMap<RoutePath, Box<dyn NeighborIndex>>,
}

impl IndexRegistry {
    fn new(data: Vec<Point3>, cfg: &ServiceConfig) -> Self {
        IndexRegistry {
            data,
            trueknn: cfg.trueknn.clone(),
            by_path: HashMap::new(),
        }
    }

    /// Service queries are external points: never self-exclude.
    fn brute_config() -> IndexConfig {
        IndexConfig {
            exclude_self: false,
            ..Default::default()
        }
    }

    fn install(&mut self, path: RoutePath, index: Box<dyn NeighborIndex>, metrics: &Metrics) {
        Metrics::add(&metrics.builds, index.build_stats().counters.builds);
        self.by_path.insert(path, index);
    }

    /// The index serving `path`, building it on first use. Each build is
    /// charged to the `builds` metric exactly once — every later batch on
    /// the same path reuses the structure.
    fn get(&mut self, path: RoutePath, metrics: &Metrics) -> &mut Box<dyn NeighborIndex> {
        if !self.by_path.contains_key(&path) {
            let index: Box<dyn NeighborIndex> = match path {
                RoutePath::Rt => Box::new(TrueKnnIndex::new(
                    self.data.clone(),
                    self.trueknn.to_index_config(),
                )),
                // Reached only if the eagerly-installed PJRT index is
                // missing (runtime load raced or failed): rebuild with
                // whatever runtime is available now.
                RoutePath::Brute => {
                    Box::new(BrutePjrtIndex::new(self.data.clone(), Self::brute_config()))
                }
                RoutePath::BruteCpu => {
                    Box::new(BruteCpuIndex::new(self.data.clone(), Self::brute_config()))
                }
            };
            self.install(path, index, metrics);
        }
        self.by_path.get_mut(&path).expect("just inserted")
    }
}

fn worker_loop(
    data: Vec<Point3>,
    mut cfg: ServiceConfig,
    rx: Receiver<Msg>,
    metrics: Arc<Metrics>,
    inflight: Arc<AtomicUsize>,
) {
    let mut registry = IndexRegistry::new(data, &cfg);
    // PJRT runtime is constructed here: the client is not Send. Loaded
    // eagerly (when asked for) so the router knows the path exists.
    if cfg.use_pjrt {
        let runtime = match PjrtRuntime::load_default() {
            Ok(rt) => Some(rt),
            Err(e) => {
                crate::log_warn!("PJRT unavailable, brute falls back to CPU: {e}");
                None
            }
        };
        cfg.router.pjrt_available = runtime.is_some();
        if runtime.is_some() {
            let index = BrutePjrtIndex::with_runtime(
                registry.data.clone(),
                runtime,
                IndexRegistry::brute_config(),
            );
            registry.install(RoutePath::Brute, Box::new(index), &metrics);
        }
    } else {
        cfg.router.pjrt_available = false;
    }
    let router = Router::new(cfg.router.clone());
    let mut batcher = DynamicBatcher::new(cfg.batcher.clone());
    // response channels ride alongside their request through the batcher
    let mut reply_of: HashMap<u64, Sender<KnnResponse>> = HashMap::new();

    'outer: loop {
        // block for the first message, then drain whatever else arrived
        match rx.recv() {
            Ok(Msg::Request(req, reply, t)) => {
                reply_of.insert(req.id, reply);
                batcher.push(req, t);
            }
            Ok(Msg::Shutdown) | Err(_) => break 'outer,
        }
        loop {
            match rx.try_recv() {
                Ok(Msg::Request(req, reply, t)) => {
                    reply_of.insert(req.id, reply);
                    batcher.push(req, t);
                }
                Ok(Msg::Shutdown) => {
                    // serve what's queued, then exit
                    drain(&router, &mut registry, &mut batcher, &mut reply_of, &metrics, &inflight);
                    break 'outer;
                }
                Err(_) => break,
            }
        }
        drain(&router, &mut registry, &mut batcher, &mut reply_of, &metrics, &inflight);
    }
}

fn drain(
    router: &Router,
    registry: &mut IndexRegistry,
    batcher: &mut DynamicBatcher,
    reply_of: &mut HashMap<u64, Sender<KnnResponse>>,
    metrics: &Arc<Metrics>,
    inflight: &Arc<AtomicUsize>,
) {
    while let Some(batch) = batcher.next_batch() {
        Metrics::inc(&metrics.batches);
        let served = Instant::now();
        let all_queries: Vec<Point3> = batch
            .requests
            .iter()
            .flat_map(|(r, _)| r.queries.iter().copied())
            .collect();

        // Batches are (k, mode)-homogeneous, so routing the first request
        // routes every request in the batch identically.
        let n_data = registry.data.len();
        let path = router.route(&batch.requests[0].0, n_data);
        match path {
            RoutePath::Rt => Metrics::add(&metrics.rt_requests, batch.requests.len() as u64),
            RoutePath::Brute | RoutePath::BruteCpu => {
                Metrics::add(&metrics.brute_requests, batch.requests.len() as u64)
            }
        }
        let neighbors = registry.get(path, metrics).knn(&all_queries, batch.k).neighbors;
        let service_seconds = served.elapsed().as_secs_f64();

        for ((req, arrived), range) in batch.requests.iter().zip(&batch.ranges) {
            let latency = arrived.elapsed().as_secs_f64();
            metrics.record_latency(latency);
            Metrics::inc(&metrics.responses);
            Metrics::add(&metrics.queries_served, req.queries.len() as u64);
            inflight.fetch_sub(1, Ordering::SeqCst);
            if let Some(reply) = reply_of.remove(&req.id) {
                let _ = reply.send(KnnResponse {
                    id: req.id,
                    neighbors: neighbors[range.0..range.1].to_vec(),
                    path,
                    service_seconds,
                    latency_seconds: latency,
                });
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::DatasetKind;
    use crate::knn::kdtree::KdTree;

    #[test]
    fn service_round_trip_exact() {
        let ds = DatasetKind::Uniform.generate(2_000, 70);
        let queries: Vec<Point3> = ds.points[..32].to_vec();
        let (svc, handle) = Service::start(ds.points.clone(), ServiceConfig::default());
        let resp = handle
            .query(KnnRequest::new(1, queries.clone(), 4))
            .unwrap();
        assert_eq!(resp.neighbors.len(), 32);
        let tree = KdTree::build(&ds.points);
        for (q, got) in queries.iter().zip(&resp.neighbors) {
            let want = tree.knn(*q, 4);
            assert_eq!(got.len(), 4);
            for (g, w) in got.iter().zip(&want) {
                assert!((g.dist - w.dist).abs() < 1e-5);
            }
        }
        svc.shutdown();
    }

    #[test]
    fn concurrent_clients_all_served() {
        let ds = DatasetKind::Uniform.generate(3_000, 71);
        let (svc, handle) = Service::start(ds.points.clone(), ServiceConfig::default());
        let mut joins = Vec::new();
        for t in 0..4u64 {
            let h = handle.clone();
            let pts = ds.points.clone();
            joins.push(std::thread::spawn(move || {
                for i in 0..5u64 {
                    let id = t * 100 + i;
                    let qs = pts[(id as usize * 7) % 1000..][..8].to_vec();
                    let resp = h.query(KnnRequest::new(id, qs, 3)).unwrap();
                    assert_eq!(resp.id, id);
                    assert_eq!(resp.neighbors.len(), 8);
                    assert!(resp.neighbors.iter().all(|n| n.len() == 3));
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        let m = handle.metrics().snapshot();
        assert_eq!(m.responses, 20);
        assert_eq!(m.rejected, 0);
        assert_eq!(m.queries_served, 160);
        svc.shutdown();
    }

    #[test]
    fn explicit_rt_mode_routes_rt() {
        let ds = DatasetKind::Uniform.generate(2_500, 72);
        let (svc, handle) = Service::start(ds.points.clone(), ServiceConfig::default());
        let resp = handle
            .query(KnnRequest::new(9, ds.points[..4].to_vec(), 2).with_mode(QueryMode::Rt))
            .unwrap();
        assert_eq!(resp.path, RoutePath::Rt);
        let m = handle.metrics().snapshot();
        assert_eq!(m.rt_requests, 1);
        svc.shutdown();
    }

    use super::super::request::QueryMode;

    #[test]
    fn serving_many_batches_builds_one_index() {
        // the tentpole claim: N batches against one dataset = exactly 1
        // acceleration-structure build (the seed rebuilt the BVH per batch)
        let ds = DatasetKind::Taxi.generate(3_000, 74);
        let (svc, handle) = Service::start(ds.points.clone(), ServiceConfig::default());
        let n_batches = 6u64;
        for id in 0..n_batches {
            let q = ds.points[(id as usize * 31) % 2000..][..8].to_vec();
            // query() waits for the response, so every request is its own batch
            let resp = handle
                .query(KnnRequest::new(id, q, 4).with_mode(QueryMode::Rt))
                .unwrap();
            assert_eq!(resp.path, RoutePath::Rt);
            assert!(resp.neighbors.iter().all(|n| n.len() == 4));
        }
        let m = handle.metrics().snapshot();
        assert_eq!(m.batches, n_batches);
        assert_eq!(m.builds, 1, "BVH must be built once, not once per batch");
        svc.shutdown();
    }

    #[test]
    fn mixed_mode_submissions_route_per_mode() {
        // regression for the old behavior where a whole batch followed
        // requests[0]'s mode: submit an interleaved burst and check every
        // response took the path its own request asked for
        let ds = DatasetKind::Uniform.generate(2_500, 75);
        let (svc, handle) = Service::start(ds.points.clone(), ServiceConfig::default());
        let mut rxs = Vec::new();
        for id in 0..12u64 {
            let mode = if id % 2 == 0 { QueryMode::Rt } else { QueryMode::Brute };
            let q = ds.points[(id as usize * 13) % 2000..][..4].to_vec();
            rxs.push((
                id,
                mode,
                handle
                    .submit(KnnRequest::new(id, q, 3).with_mode(mode))
                    .unwrap(),
            ));
        }
        for (id, mode, rx) in rxs {
            let resp = rx.recv().unwrap();
            assert_eq!(resp.id, id);
            let want = match mode {
                QueryMode::Rt => RoutePath::Rt,
                // no PJRT in this config: Brute lands on the CPU path
                QueryMode::Brute => RoutePath::BruteCpu,
                QueryMode::Auto => unreachable!(),
            };
            assert_eq!(resp.path, want, "request {id} mis-routed");
            assert!(resp.neighbors.iter().all(|n| n.len() == 3));
        }
        let m = handle.metrics().snapshot();
        assert_eq!(m.rt_requests, 6);
        assert_eq!(m.brute_requests, 6);
        svc.shutdown();
    }

    #[test]
    fn shutdown_serves_queued_work() {
        let ds = DatasetKind::Uniform.generate(1_000, 73);
        let (svc, handle) = Service::start(ds.points.clone(), ServiceConfig::default());
        let rx = handle
            .submit(KnnRequest::new(1, ds.points[..4].to_vec(), 2))
            .unwrap();
        svc.shutdown();
        let resp = rx.recv().expect("queued request must still be answered");
        assert_eq!(resp.id, 1);
    }
}
