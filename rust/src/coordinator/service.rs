//! The query service: a worker thread owning the dataset, the RT
//! simulator structures and (optionally) the PJRT runtime, fed through a
//! bounded queue with backpressure.
//!
//! The PJRT client wraps raw C pointers and is not `Send`, so the
//! runtime is constructed *inside* the worker thread; callers only touch
//! channels.

use super::batcher::{BatcherConfig, DynamicBatcher};
use super::metrics::Metrics;
use super::request::{KnnRequest, KnnResponse, RoutePath};
use super::router::{Router, RouterConfig};
use crate::geom::Point3;
use crate::knn::{brute::brute_knn, trueknn, TrueKnnParams};
use crate::runtime::{PjrtBruteForce, PjrtRuntime};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, Sender, SyncSender, TrySendError};
use std::sync::Arc;
use std::time::Instant;

#[derive(Clone, Debug)]
pub struct ServiceConfig {
    pub batcher: BatcherConfig,
    pub router: RouterConfig,
    /// Bounded queue depth; submits beyond it are rejected (backpressure).
    pub queue_depth: usize,
    /// Try to load PJRT artifacts in the worker (falls back to CPU brute).
    pub use_pjrt: bool,
    pub trueknn: TrueKnnParams,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self {
            batcher: BatcherConfig::default(),
            router: RouterConfig::default(),
            queue_depth: 256,
            use_pjrt: false,
            trueknn: TrueKnnParams {
                exclude_self: false, // service queries are external points
                ..Default::default()
            },
        }
    }
}

#[derive(Debug, thiserror::Error)]
pub enum ServiceError {
    #[error("service queue full (backpressure)")]
    QueueFull,
    #[error("service is shut down")]
    ShutDown,
}

enum Msg {
    Request(KnnRequest, Sender<KnnResponse>, Instant),
    Shutdown,
}

/// Handle returned by `Service::start`; cheap to clone, submits requests.
#[derive(Clone)]
pub struct ServiceHandle {
    tx: SyncSender<Msg>,
    metrics: Arc<Metrics>,
    inflight: Arc<AtomicUsize>,
}

impl ServiceHandle {
    /// Submit a request; returns the response channel. Applies
    /// backpressure by rejecting when the queue is full.
    pub fn submit(&self, req: KnnRequest) -> Result<Receiver<KnnResponse>, ServiceError> {
        let (tx, rx) = std::sync::mpsc::channel();
        Metrics::inc(&self.metrics.requests);
        match self.tx.try_send(Msg::Request(req, tx, Instant::now())) {
            Ok(()) => {
                self.inflight.fetch_add(1, Ordering::SeqCst);
                Ok(rx)
            }
            Err(TrySendError::Full(_)) => {
                Metrics::inc(&self.metrics.rejected);
                Err(ServiceError::QueueFull)
            }
            Err(TrySendError::Disconnected(_)) => Err(ServiceError::ShutDown),
        }
    }

    /// Submit and wait for the response.
    pub fn query(&self, req: KnnRequest) -> Result<KnnResponse, ServiceError> {
        let rx = self.submit(req)?;
        rx.recv().map_err(|_| ServiceError::ShutDown)
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    pub fn inflight(&self) -> usize {
        self.inflight.load(Ordering::SeqCst)
    }
}

/// The service: owns the worker thread; dropping shuts it down.
pub struct Service {
    handle: ServiceHandle,
    worker: Option<std::thread::JoinHandle<()>>,
    tx: SyncSender<Msg>,
}

impl Service {
    /// Start the worker over a fixed dataset.
    pub fn start(data: Vec<Point3>, cfg: ServiceConfig) -> (Service, ServiceHandle) {
        let (tx, rx) = sync_channel::<Msg>(cfg.queue_depth);
        let metrics = Arc::new(Metrics::new());
        let inflight = Arc::new(AtomicUsize::new(0));
        let handle = ServiceHandle {
            tx: tx.clone(),
            metrics: metrics.clone(),
            inflight: inflight.clone(),
        };
        let worker_metrics = metrics;
        let worker_inflight = inflight;
        let worker = std::thread::spawn(move || {
            worker_loop(data, cfg, rx, worker_metrics, worker_inflight);
        });
        (
            Service {
                handle: handle.clone(),
                worker: Some(worker),
                tx,
            },
            handle,
        )
    }

    pub fn handle(&self) -> ServiceHandle {
        self.handle.clone()
    }

    pub fn shutdown(mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

impl Drop for Service {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

fn worker_loop(
    data: Vec<Point3>,
    mut cfg: ServiceConfig,
    rx: Receiver<Msg>,
    metrics: Arc<Metrics>,
    inflight: Arc<AtomicUsize>,
) {
    // PJRT runtime is constructed here: the client is not Send.
    let pjrt: Option<PjrtRuntime> = if cfg.use_pjrt {
        match PjrtRuntime::load_default() {
            Ok(rt) => Some(rt),
            Err(e) => {
                crate::log_warn!("PJRT unavailable, brute falls back to CPU: {e}");
                None
            }
        }
    } else {
        None
    };
    cfg.router.pjrt_available = pjrt.is_some();
    let router = Router::new(cfg.router.clone());
    let mut batcher = DynamicBatcher::new(cfg.batcher.clone());
    // response channels ride alongside their request through the batcher
    let mut reply_of: std::collections::HashMap<u64, Sender<KnnResponse>> =
        std::collections::HashMap::new();

    'outer: loop {
        // block for the first message, then drain whatever else arrived
        match rx.recv() {
            Ok(Msg::Request(req, reply, t)) => {
                reply_of.insert(req.id, reply);
                batcher.push(req, t);
            }
            Ok(Msg::Shutdown) | Err(_) => break 'outer,
        }
        loop {
            match rx.try_recv() {
                Ok(Msg::Request(req, reply, t)) => {
                    reply_of.insert(req.id, reply);
                    batcher.push(req, t);
                }
                Ok(Msg::Shutdown) => {
                    // serve what's queued, then exit
                    drain(&data, &cfg, &router, &pjrt, &mut batcher, &mut reply_of, &metrics, &inflight);
                    break 'outer;
                }
                Err(_) => break,
            }
        }
        drain(&data, &cfg, &router, &pjrt, &mut batcher, &mut reply_of, &metrics, &inflight);
    }
}

#[allow(clippy::too_many_arguments)]
fn drain(
    data: &[Point3],
    cfg: &ServiceConfig,
    router: &Router,
    pjrt: &Option<PjrtRuntime>,
    batcher: &mut DynamicBatcher,
    reply_of: &mut std::collections::HashMap<u64, Sender<KnnResponse>>,
    metrics: &Arc<Metrics>,
    inflight: &Arc<AtomicUsize>,
) {
    while let Some(batch) = batcher.next_batch() {
        Metrics::inc(&metrics.batches);
        let served = Instant::now();
        // route by the first request (batch is mode/k-homogeneous enough:
        // explicit-mode requests are honored per request below)
        let all_queries: Vec<Point3> = batch
            .requests
            .iter()
            .flat_map(|(r, _)| r.queries.iter().copied())
            .collect();

        let path = router.route(&batch.requests[0].0, data.len());
        let neighbors = match path {
            RoutePath::Rt => {
                Metrics::add(&metrics.rt_requests, batch.requests.len() as u64);
                let params = TrueKnnParams {
                    k: batch.k,
                    ..cfg.trueknn.clone()
                };
                trueknn(data, &all_queries, &params).neighbors
            }
            RoutePath::Brute => {
                Metrics::add(&metrics.brute_requests, batch.requests.len() as u64);
                match pjrt.as_ref() {
                    Some(rt) => match PjrtBruteForce::new(rt).knn(data, &all_queries, batch.k, false) {
                        Ok(res) => res.neighbors,
                        Err(e) => {
                            crate::log_error!("PJRT execution failed, CPU fallback: {e}");
                            brute_knn(data, &all_queries, batch.k, false).neighbors
                        }
                    },
                    None => brute_knn(data, &all_queries, batch.k, false).neighbors,
                }
            }
            RoutePath::BruteCpu => {
                Metrics::add(&metrics.brute_requests, batch.requests.len() as u64);
                brute_knn(data, &all_queries, batch.k, false).neighbors
            }
        };
        let service_seconds = served.elapsed().as_secs_f64();

        for ((req, arrived), range) in batch.requests.iter().zip(&batch.ranges) {
            let latency = arrived.elapsed().as_secs_f64();
            metrics.record_latency(latency);
            Metrics::inc(&metrics.responses);
            Metrics::add(&metrics.queries_served, req.queries.len() as u64);
            inflight.fetch_sub(1, Ordering::SeqCst);
            if let Some(reply) = reply_of.remove(&req.id) {
                let _ = reply.send(KnnResponse {
                    id: req.id,
                    neighbors: neighbors[range.0..range.1].to_vec(),
                    path,
                    service_seconds,
                    latency_seconds: latency,
                });
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::DatasetKind;
    use crate::knn::kdtree::KdTree;

    #[test]
    fn service_round_trip_exact() {
        let ds = DatasetKind::Uniform.generate(2_000, 70);
        let queries: Vec<Point3> = ds.points[..32].to_vec();
        let (svc, handle) = Service::start(ds.points.clone(), ServiceConfig::default());
        let resp = handle
            .query(KnnRequest::new(1, queries.clone(), 4))
            .unwrap();
        assert_eq!(resp.neighbors.len(), 32);
        let tree = KdTree::build(&ds.points);
        for (q, got) in queries.iter().zip(&resp.neighbors) {
            let want = tree.knn(*q, 4);
            assert_eq!(got.len(), 4);
            for (g, w) in got.iter().zip(&want) {
                assert!((g.dist - w.dist).abs() < 1e-5);
            }
        }
        svc.shutdown();
    }

    #[test]
    fn concurrent_clients_all_served() {
        let ds = DatasetKind::Uniform.generate(3_000, 71);
        let (svc, handle) = Service::start(ds.points.clone(), ServiceConfig::default());
        let mut joins = Vec::new();
        for t in 0..4u64 {
            let h = handle.clone();
            let pts = ds.points.clone();
            joins.push(std::thread::spawn(move || {
                for i in 0..5u64 {
                    let id = t * 100 + i;
                    let qs = pts[(id as usize * 7) % 1000..][..8].to_vec();
                    let resp = h.query(KnnRequest::new(id, qs, 3)).unwrap();
                    assert_eq!(resp.id, id);
                    assert_eq!(resp.neighbors.len(), 8);
                    assert!(resp.neighbors.iter().all(|n| n.len() == 3));
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        let m = handle.metrics().snapshot();
        assert_eq!(m.responses, 20);
        assert_eq!(m.rejected, 0);
        assert_eq!(m.queries_served, 160);
        svc.shutdown();
    }

    #[test]
    fn explicit_rt_mode_routes_rt() {
        let ds = DatasetKind::Uniform.generate(2_500, 72);
        let (svc, handle) = Service::start(ds.points.clone(), ServiceConfig::default());
        let resp = handle
            .query(KnnRequest::new(9, ds.points[..4].to_vec(), 2).with_mode(QueryMode::Rt))
            .unwrap();
        assert_eq!(resp.path, RoutePath::Rt);
        let m = handle.metrics().snapshot();
        assert_eq!(m.rt_requests, 1);
        svc.shutdown();
    }

    use super::super::request::QueryMode;

    #[test]
    fn shutdown_serves_queued_work() {
        let ds = DatasetKind::Uniform.generate(1_000, 73);
        let (svc, handle) = Service::start(ds.points.clone(), ServiceConfig::default());
        let rx = handle
            .submit(KnnRequest::new(1, ds.points[..4].to_vec(), 2))
            .unwrap();
        svc.shutdown();
        let resp = rx.recv().expect("queued request must still be answered");
        assert_eq!(resp.id, 1);
    }
}
