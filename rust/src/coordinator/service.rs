//! The query service: a **pool** of worker threads, each owning the
//! persistent [`NeighborIndex`]es for a disjoint shard of route paths,
//! fed through per-worker bounded queues with backpressure.
//!
//! This is where the paper's amortization argument pays off at the
//! serving layer: the owning worker builds each route's acceleration
//! structure **once per dataset** (tracked by the per-route build gauge)
//! and every batch after that only refits/queries it. Before the index
//! API, every batch paid a full BVH build; before the pool, batches from
//! one queue never overlapped.
//!
//! Pool architecture:
//!
//! - **Routing at submit time.** [`ServiceHandle::submit`] validates the
//!   request at the boundary (typed [`ServiceError::InvalidRequest`] for
//!   degenerate shapes), routes it ([`Router::route`]) and picks the
//!   owning worker by rendezvous hashing ([`Router::worker_for`]) — a
//!   pure function of `(route, pool size)`, so a route's index is built
//!   exactly once, on exactly one worker, and never migrates.
//! - **Per-worker queues.** Each worker has its own bounded queue
//!   (`queue_depth` slots each); rejects, live depth and the high-water
//!   mark are accounted per worker in [`Metrics`]. Requests for one
//!   route keep their submit order (single queue, FIFO), which is what
//!   makes replays deterministic.
//! - **Two-level parallelism.** Workers serve batches concurrently
//!   (batch-level), and each worker's per-batch traversal fans out
//!   across the [`crate::exec`] engine threads (launch-level,
//!   `ServiceConfig::trueknn.threads`, 0 = all cores). Per-request
//!   results depend only on the request and the route's index state —
//!   never on batch composition or thread count — so responses are
//!   bitwise-identical to a `workers = 1` service by the engine's
//!   determinism contract.
//! - **Inserts are fenced, not barriers.** [`ServiceHandle::insert`]
//!   appends the record once to the shared [`InsertLog`] and broadcasts
//!   only a sequence-number *advance* — no worker receives (or copies)
//!   the points themselves; each one materializes exactly the slices it
//!   owns when it catches up. Every request is stamped at submit with
//!   the log sequence it must observe (its **fence**), and a worker
//!   catches its registry up to a batch's fence before serving it, so a
//!   query still observes exactly the inserts submitted before it — at
//!   any pool size — without the old full-pool drain barrier per
//!   insert.
//! - **Sharded hot route.** With `ServiceConfig::shards > 1` the RT
//!   route's dataset is cut into balanced Morton-range shards
//!   ([`crate::shard`]); shard `s` lives on worker
//!   [`Router::worker_for_shard`]`(Rt, s, pool)`, so one hot route
//!   occupies `min(S, pool)` workers. The handle **scatters** each RT
//!   request (one message per shard, stamped with one shared fence read
//!   under the insert lock so every scattered leg serves the identical
//!   insert prefix) and the gather is **incremental**: each arriving
//!   partial is pairwise-merged into the gather's accumulator (k
//!   smallest under `(distance, id)`, fanned per query across the exec
//!   engine) by the worker that delivered it, so the last-finishing
//!   worker sends a response that is already merged instead of paying
//!   one O(queries·k·S) pass under the gather lock. Keep-k-smallest
//!   under a total order is merge-order independent, which is why the
//!   accumulated result — and therefore the response — stays
//!   bitwise-identical to an unsharded single-worker service. Every
//!   worker holds a replica of the one partition `Service::start`
//!   computed and applies the shared insert log to it through the same
//!   routing step, so shard membership — and the rebalance-on-overflow
//!   rebuild — stays consistent across the pool with no coordination
//!   (and a failover worker can rebuild a dead owner's shard **at the
//!   request's exact fence** from its replica, even when its own
//!   registry has already run ahead).
//! - **Supervision.** Every worker runs under
//!   [`super::supervisor::supervise_worker`]: a panic (or an injected
//!   fault from [`crate::faults`]) is caught, the worker's index state
//!   is rebuilt deterministically from the base data plus its ordered
//!   insert log, and its un-replied requests are replayed in submit
//!   order. See the "Failure model" section in [`super`] for the full
//!   contract (deadlines, poison quarantine, scatter failover).
//!
//! The PJRT client wraps raw C pointers and is not `Send`, so the
//! runtime (and every index) is constructed *inside* the worker that
//! owns the Brute route; `Service::start` waits for a readiness
//! handshake from each worker so the handle's router knows up front
//! whether the PJRT path exists.

use super::batcher::{Batch, BatcherConfig, DynamicBatcher};
use super::metrics::Metrics;
use super::request::{KnnRequest, KnnResponse, RoutePath};
use super::router::{Router, RouterConfig};
use super::supervisor::{
    run_monitor, supervise_worker, JournalEntry, MonitorCtx, PoisonLedger, ServiceClock,
    WorkerCtx, WorkerHealth,
};
use crate::exec::Executor;
use crate::faults::{FaultPlan, InjectedFault, IoTarget};
use crate::geom::Point3;
use crate::index::{
    Backend, BruteCpuIndex, BrutePjrtIndex, IndexBuilder, IndexConfig, NeighborIndex, TrueKnnIndex,
};
use crate::knn::{Neighbor, RoundStats, TrueKnnParams};
use crate::obs::span::{names as span_names, SpanRecord};
use crate::obs::{clock, SpanSink, TraceConfig, Tracing};
use crate::persist::Wal;
use crate::runtime::PjrtRuntime;
use crate::shard::{merge_topk, Partition};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, Sender, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Minimum per-chunk query count when fanning gather merges and id
/// remaps over the exec engine — per-query work is a k-element table
/// lookup or a 2k-element sort, so chunks below this cost more to
/// schedule than to run serially.
const PAR_QUERY_MIN: usize = 64;

/// Tuning knobs of the batching query service (pool size, queue depth,
/// routing, RT-route sharding, deadlines/supervision, TrueKNN
/// parameters).
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    pub batcher: BatcherConfig,
    pub router: RouterConfig,
    /// Pool size: worker threads, each owning a disjoint shard of route
    /// paths (0 = all available cores). Capped at the owner-slot count —
    /// [`RoutePath::COUNT`], or `(COUNT - 1) + shards` when the RT route
    /// is sharded — a worker beyond that could never own anything, yet
    /// would still replicate every insert.
    pub workers: usize,
    /// Bounded queue depth **per worker**; submits beyond it are
    /// rejected (backpressure).
    pub queue_depth: usize,
    /// Try to load PJRT artifacts in the owning worker (falls back to
    /// CPU brute).
    pub use_pjrt: bool,
    /// Spatial shards for the **RT route's** dataset (1 = unsharded).
    /// Above 1 the route's points are cut into balanced Morton-range
    /// shards (see [`crate::shard`]); shard `s` lives on worker
    /// [`Router::worker_for_shard`]`(Rt, s, pool)`, every worker routes
    /// inserts through the identical deterministic partition, and the
    /// handle scatter-gathers each RT request across the shard owners —
    /// responses stay bitwise-identical to an unsharded single-worker
    /// service while a single hot route finally runs on several workers
    /// at once.
    pub shards: usize,
    /// Per-request deadline, measured from submit. A request still
    /// waiting when its worker dequeues it past the deadline is shed
    /// with [`ServiceError::DeadlineExceeded`] instead of served
    /// (`None` = never shed). `Duration::ZERO` deterministically sheds
    /// everything — useful for drain tests.
    pub request_deadline: Option<Duration>,
    /// Heartbeat staleness after which the failover monitor treats a
    /// worker as hung and re-dispatches its timed-out scatter partials
    /// to the shard's failover owner
    /// ([`Router::worker_for_shard_excluding`]). Only consulted when
    /// the RT route is sharded on a pool of at least two workers.
    pub heartbeat_timeout: Duration,
    /// Base backoff the supervisor sleeps between a worker crash and
    /// its replay; doubles per consecutive crash without progress
    /// (capped at 8×).
    pub replay_backoff: Duration,
    /// Seeded fault-injection plan (default inert — production configs
    /// never fire; see [`crate::faults`]).
    pub faults: FaultPlan,
    /// Crash-safe persistence ([`crate::persist`]): `Some` turns on the
    /// durable insert WAL, periodic RT-route snapshots, and cold-start
    /// recovery from the configured data directory. `None` (the
    /// default) keeps the service purely in-memory.
    pub persist: Option<PersistConfig>,
    /// Request-scoped tracing ([`crate::obs`]): `Some` buffers span
    /// trees per worker and drains them to CRC-framed JSONL files in
    /// the configured directory (read back by `trueknn trace`). `None`
    /// (the default) records no spans. Tracing is result-transparent
    /// by construction — spans are written from timestamps the serving
    /// path never branches on, so responses and deterministic counters
    /// are bitwise identical with tracing on or off.
    pub trace: Option<TraceConfig>,
    pub trueknn: TrueKnnParams,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self {
            batcher: BatcherConfig::default(),
            router: RouterConfig::default(),
            workers: 0,
            queue_depth: 256,
            use_pjrt: false,
            shards: 1,
            request_deadline: None,
            heartbeat_timeout: Duration::from_secs(1),
            replay_backoff: Duration::from_millis(1),
            faults: FaultPlan::inert(),
            persist: None,
            trace: None,
            trueknn: TrueKnnParams {
                exclude_self: false, // service queries are external points
                ..Default::default()
            },
        }
    }
}

/// Durability knobs of the service (see [`crate::persist`] for the
/// on-disk formats and trust model). The data directory holds one
/// `wal.log` plus `snapshot-{watermark}.tksn` files; a cold
/// [`Service::start`] replays them into a serving state bitwise
/// identical to the one that wrote them.
#[derive(Clone, Debug)]
pub struct PersistConfig {
    /// Directory holding the WAL and snapshots (created if missing).
    pub data_dir: PathBuf,
    /// Ask the RT-route owner for a snapshot every this many accepted
    /// inserts (0 = only at clean shutdown). Snapshots are fire-and-
    /// forget: a failed write degrades durability to WAL-only, never
    /// fails the insert.
    pub snapshot_interval: u64,
    /// WAL group-commit window: fsync every n-th append (1 = every
    /// append, the durable default; larger windows trade the tail of a
    /// power loss for insert throughput).
    pub wal_group_commit: u64,
}

impl PersistConfig {
    /// Durable defaults rooted at `data_dir`: fsync every append,
    /// snapshot only at clean shutdown.
    pub fn at(data_dir: impl Into<PathBuf>) -> Self {
        Self {
            data_dir: data_dir.into(),
            snapshot_interval: 0,
            wal_group_commit: 1,
        }
    }
}

/// Why a submit was refused or a request failed after acceptance.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ServiceError {
    /// Backpressure: the target worker's queue is full.
    QueueFull,
    /// The pool is stopped (or died before answering).
    ShutDown,
    /// Rejected at the API boundary: degenerate shape (k = 0, empty
    /// batch, non-finite coordinate). The reason is a static
    /// human-readable description.
    InvalidRequest(&'static str),
    /// Accepted but shed: the request was still queued past its
    /// [`ServiceConfig::request_deadline`].
    DeadlineExceeded,
    /// Quarantined by the poison ledger: this request id crashed its
    /// worker twice and is refused to protect the pool.
    Poisoned,
    /// The durable WAL append failed, so the insert was **not** applied:
    /// an insert is acknowledged only once it is in the log (the
    /// stringified [`crate::persist::PersistError`] says why the log
    /// refused it).
    PersistFailed(String),
}

impl std::fmt::Display for ServiceError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServiceError::QueueFull => write!(f, "service queue full (backpressure)"),
            ServiceError::ShutDown => write!(f, "service is shut down"),
            ServiceError::InvalidRequest(reason) => write!(f, "invalid request: {reason}"),
            ServiceError::DeadlineExceeded => write!(f, "request deadline exceeded; shed"),
            ServiceError::Poisoned => write!(f, "request quarantined by the poison ledger"),
            ServiceError::PersistFailed(detail) => {
                write!(f, "durable insert log append failed: {detail}")
            }
        }
    }
}

impl std::error::Error for ServiceError {}

/// Reply half handed back by [`ServiceHandle::submit`]: the response, or
/// the typed error the service failed the request with after accepting
/// it (shed deadline, poison quarantine, pool death). A plain channel
/// disconnect still means [`ServiceError::ShutDown`].
pub type ResponseReceiver = Receiver<Result<KnnResponse, ServiceError>>;

pub(super) type ResponseSender = Sender<Result<KnnResponse, ServiceError>>;

/// The shared, append-only insert log: every accepted insert record
/// lives here exactly **once** (an `Arc` per record), in the one global
/// order the insert lock serializes. Workers no longer receive point
/// broadcasts — they receive [`Msg::InsertAdvance`] sequence
/// notifications and pull the records they need from this log, so only
/// the worker that owns a slice of the data ever materializes it.
///
/// The log sequence doubles as the service's **fence** domain: a
/// request stamped with fence `f` must be served at exactly (scattered
/// shard legs) or at least (direct legs) the first `f` records. The
/// WAL, when persistence is on, is appended under the same lock, so
/// WAL order, log order and fence order are one order.
pub(super) struct InsertLog {
    records: Mutex<Vec<Arc<Vec<Point3>>>>,
}

impl InsertLog {
    /// A log seeded with the cold start's replayed WAL records (empty
    /// for an in-memory start): recovered inserts are part of the fence
    /// domain from the first submit.
    pub(super) fn new(seed: Vec<Arc<Vec<Point3>>>) -> Self {
        Self {
            records: Mutex::new(seed),
        }
    }

    /// Current sequence number = number of appended records. A fence
    /// read under the insert lock is stable until the lock is released.
    pub(super) fn seq(&self) -> u64 {
        self.records
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner)
            .len() as u64
    }

    /// Append one record; returns the new sequence number (the fence
    /// that observes this record). Called under the insert lock only.
    pub(super) fn append(&self, record: Arc<Vec<Point3>>) -> u64 {
        let mut recs = self
            .records
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner);
        recs.push(record);
        recs.len() as u64
    }

    /// The records in `[from, to)`, as cheap `Arc` clones. `to` beyond
    /// the head is clamped (a torn caller can never read past the log).
    pub(super) fn range(&self, from: u64, to: u64) -> Vec<Arc<Vec<Point3>>> {
        let recs = self
            .records
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner);
        let to = (to as usize).min(recs.len());
        let from = (from as usize).min(to);
        recs[from..to].to_vec()
    }
}

pub(super) enum Msg {
    /// One routed request (or, for a sharded route, one shard's slice of
    /// a scattered request — the `Option<usize>` names the shard). The
    /// `u64` is the request's insert-log fence, stamped at submit.
    Request(KnnRequest, RoutePath, Option<usize>, u64, ReplySink, Instant),
    /// Broadcast to every worker when the shared [`InsertLog`] grows:
    /// "the log now holds `seq` records". Carries no points — each
    /// worker pulls the records it owns from the log when it catches
    /// up, after draining the batches that must not observe them.
    InsertAdvance {
        /// The log sequence to catch up to.
        seq: u64,
    },
    /// Ask the RT route's owning worker to write a snapshot fenced at
    /// this WAL watermark (fire-and-forget; a failure only degrades
    /// durability to WAL-only).
    Snapshot {
        /// Sequence number of the last insert the snapshot must cover.
        watermark: u64,
    },
    Shutdown,
}

/// Where a request's result goes: straight back to the client, or into
/// the scatter-gather rendezvous of a sharded request. Cloneable so the
/// supervisor's journal can retain a sink across a worker crash while
/// the incarnation-local reply map holds its own copy.
#[derive(Clone)]
pub(super) enum ReplySink {
    Direct(ResponseSender),
    Gather(Arc<Gather>),
}

impl ReplySink {
    /// Deliver a typed failure to whoever is waiting. For a gather this
    /// fails the *whole* scattered request (the reply sender is taken),
    /// so a later partial delivery finds the gather completed and drops
    /// its data — the client never sees half an answer.
    pub(super) fn fail(&self, err: ServiceError) {
        match self {
            ReplySink::Direct(tx) => {
                let _ = tx.send(Err(err));
            }
            ReplySink::Gather(g) => {
                let mut st = g
                    .state
                    .lock()
                    .unwrap_or_else(std::sync::PoisonError::into_inner);
                if let Some(reply) = st.reply.take() {
                    let _ = reply.send(Err(err));
                }
            }
        }
    }
}

/// Rendezvous of one scattered request. Each arriving partial is
/// pairwise-merged into the per-query accumulator **as it lands** by
/// the worker that delivered it (the incremental gather), and whichever
/// worker merges the last shard's partial takes the reply sender and
/// responds — the response is already merged by then, so no worker ever
/// pays a full O(queries·k·S) pass under the gather lock. Keep-k-
/// smallest under the `(distance, id)` total order is independent of
/// merge order (every cut keeps the same lexicographically-smallest k
/// whatever order candidates arrive in), so the accumulated result
/// depends only on the partials, never on delivery order — that is
/// what keeps scatter-gather responses bitwise-identical to the
/// unsharded oracle, *including* when a partial arrives twice (owner
/// recovered after the monitor already re-dispatched it): the per-shard
/// `merged` flag makes delivery idempotent, and both copies are the
/// same deterministic answer.
pub(super) struct Gather {
    pub(super) id: u64,
    pub(super) k: usize,
    pub(super) path: RoutePath,
    /// The original request, retained so the failover monitor can
    /// re-dispatch a timed-out shard's slice verbatim.
    pub(super) req: KnnRequest,
    /// The insert-log fence every leg of this request was stamped with:
    /// one value, read under the insert lock at scatter time, so a
    /// failover re-dispatch serves the **same** insert prefix as every
    /// sibling shard — a mixed-prefix merge is impossible by
    /// construction.
    pub(super) fence: u64,
    pub(super) submitted: Instant,
    pub(super) state: Mutex<GatherState>,
}

pub(super) struct GatherState {
    /// Taken by the completing worker; behind the mutex so the gather
    /// stays `Sync` on every supported toolchain (`mpsc::Sender` only
    /// recently became `Sync` itself).
    pub(super) reply: Option<ResponseSender>,
    /// Per-query accumulator: the k best seen across every merged
    /// shard so far, under the `(distance, id)` total order.
    pub(super) acc: Vec<Vec<Neighbor>>,
    /// Per-shard flag: this shard's partial has been merged into `acc`
    /// (and counted — the idempotence **and** the counter-dedupe key,
    /// see `Metrics::shard_queries`).
    pub(super) merged: Vec<bool>,
    /// Shards merged so far; the delivery taking this to `shards`
    /// replies.
    pub(super) merged_count: usize,
    /// Per-shard flag: the monitor re-dispatched this shard's slice to
    /// a failover worker (at most once per shard per gather).
    pub(super) redispatched: Vec<bool>,
    /// Critical-path service time: the slowest shard batch.
    pub(super) service_seconds: f64,
}

/// Handle returned by `Service::start`; cheap to clone, submits requests.
#[derive(Clone)]
pub struct ServiceHandle {
    txs: Arc<Vec<SyncSender<Msg>>>,
    router: Arc<Router>,
    /// Indexed points (base + inserts) — the `n` of the routing policy.
    data_len: Arc<AtomicUsize>,
    /// Serializes inserts: concurrent inserts must append to the shared
    /// log (and the WAL) in one global order, or the workers' views of
    /// the data (and point ids) would fork per route. The sharded
    /// scatter takes the same lock to read its fence, so an insert can
    /// never land between two shards of one request — every leg is
    /// stamped with the identical log prefix.
    insert_lock: Arc<Mutex<()>>,
    /// The shared append-only insert log (see [`InsertLog`]): records
    /// live here once; workers pull what they own at catch-up.
    log: Arc<InsertLog>,
    /// One lock per worker queue, serializing `[depth bump, send, hwm
    /// record]` so the recorded high-water mark is always a truly
    /// attained queue occupancy (see `WorkerMetrics::queue_hwm`).
    enqueue_locks: Arc<Vec<Mutex<()>>>,
    /// RT-route shard count (1 = unsharded).
    shards: usize,
    metrics: Arc<Metrics>,
    inflight: Arc<AtomicUsize>,
    /// Quarantine ledger shared with every worker's supervisor: a
    /// request id that crashed its worker twice is refused at submit.
    ledger: Arc<PoisonLedger>,
    /// Pending scattered requests, swept by the failover monitor.
    /// `None` when no monitor runs (unsharded, or a single worker).
    gathers: Option<Arc<Mutex<Vec<Arc<Gather>>>>>,
    /// The durable insert WAL (persistence on): appended under the
    /// insert lock, **before** the broadcast, so the log order is the
    /// one global insert order every worker observed.
    wal: Option<Arc<Mutex<Wal>>>,
    /// Snapshot cadence in accepted inserts (0 = clean shutdown only).
    snapshot_interval: u64,
}

impl ServiceHandle {
    /// Submit a request; returns the response channel. Validates at the
    /// boundary (typed [`ServiceError::InvalidRequest`] for k = 0, an
    /// empty batch or non-finite coordinates; [`ServiceError::Poisoned`]
    /// for a quarantined id), then routes the request to its owning
    /// worker — or, on a sharded RT route, scatters it to every shard
    /// owner — and applies backpressure by rejecting when a target
    /// worker's queue is full.
    pub fn submit(&self, req: KnnRequest) -> Result<ResponseReceiver, ServiceError> {
        if let Some(reason) = req.reject_reason() {
            return Err(ServiceError::InvalidRequest(reason));
        }
        if self.ledger.is_poisoned(req.id) {
            return Err(ServiceError::Poisoned);
        }
        let (tx, rx) = std::sync::mpsc::channel();
        Metrics::inc(&self.metrics.requests);
        let path = self.router.route(&req, self.data_len.load(Ordering::SeqCst));
        if path == RoutePath::Rt && self.shards > 1 {
            self.scatter(req, path, tx)?;
        } else {
            let w = Router::worker_for(path, self.txs.len());
            // a direct request's fence is a *lower bound* (serve-at-
            // least): read without the insert lock, it still orders
            // after every insert whose `insert()` returned before this
            // submit, which is exactly the visibility contract
            let fence = self.log.seq();
            self.try_send(
                w,
                // submit stamp through the obs chokepoint: feeds latency
                // telemetry and trace spans only, never results
                Msg::Request(req, path, None, fence, ReplySink::Direct(tx), clock::now()),
            )?;
        }
        Ok(rx)
    }

    /// Try-send one message to worker `w` with full backpressure
    /// accounting, serialized per worker by its enqueue lock. The depth
    /// is incremented *before* the send so the worker-side decrement
    /// can never observe it missing (no underflow). The high-water mark
    /// is recorded from a **load after the successful send**: under the
    /// enqueue lock no other producer is mid-`[bump, send]` for this
    /// queue, so the gauge equals the live occupancy at that instant
    /// and every recorded value is one the queue truly attained (see
    /// `WorkerMetrics::queue_hwm`). A disconnected channel is a
    /// recovery-path signal (`ShutDown`), never a panic site — the
    /// supervisor may be mid-restart behind it.
    pub(super) fn try_send(&self, w: usize, msg: Msg) -> Result<(), ServiceError> {
        let wm = &self.metrics.workers[w];
        let _q = self.enqueue_locks[w]
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner);
        wm.queue_depth.fetch_add(1, Ordering::SeqCst);
        match self.txs[w].try_send(msg) {
            Ok(()) => {
                wm.queue_hwm
                    .fetch_max(wm.queue_depth.load(Ordering::SeqCst), Ordering::SeqCst);
                Metrics::inc(&wm.submitted);
                self.inflight.fetch_add(1, Ordering::SeqCst);
                Ok(())
            }
            Err(TrySendError::Full(_)) => {
                wm.queue_depth.fetch_sub(1, Ordering::SeqCst);
                Metrics::inc(&self.metrics.rejected);
                Metrics::inc(&wm.rejected);
                Err(ServiceError::QueueFull)
            }
            Err(TrySendError::Disconnected(_)) => {
                wm.queue_depth.fetch_sub(1, Ordering::SeqCst);
                Err(ServiceError::ShutDown)
            }
        }
    }

    /// Scatter a sharded-route request: one message per shard to that
    /// shard's owning worker. The fence is read — and every leg sent —
    /// under the insert lock, so all S legs are stamped with the
    /// identical log prefix and an insert can never interleave between
    /// two shards of the same request: the partials merged into one
    /// response are always computed at one consistent point set, even
    /// when a leg is later re-dispatched to a failover worker (it
    /// re-serves at [`Gather::fence`], not at whatever its registry
    /// holds). A mid-scatter rejection fails the gather before it is
    /// ever registered with the monitor: already-enqueued shard legs
    /// settle their gauges, then find the gather completed and drop.
    fn scatter(
        &self,
        req: KnnRequest,
        path: RoutePath,
        reply: ResponseSender,
    ) -> Result<(), ServiceError> {
        // clone the per-shard request payloads (the expensive part)
        // before taking the lock, so the critical section every scatter
        // and insert contends on is the fence read plus S try_sends
        let mut legs: Vec<KnnRequest> = (0..self.shards).map(|_| req.clone()).collect();
        let n_queries = req.queries.len();
        // a poisoned lock only means another handle's thread panicked
        // mid-scatter; the ordering guard itself carries no data
        let _order = self
            .insert_lock
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner);
        let fence = self.log.seq();
        let gather = Arc::new(Gather {
            id: req.id,
            k: req.k,
            path,
            req,
            fence,
            // submit stamp through the obs chokepoint: telemetry only
            submitted: clock::now(),
            state: Mutex::new(GatherState {
                reply: Some(reply),
                acc: vec![Vec::new(); n_queries],
                merged: vec![false; self.shards],
                merged_count: 0,
                redispatched: vec![false; self.shards],
                service_seconds: 0.0,
            }),
        });
        for (s, leg) in legs.drain(..).enumerate() {
            let w = Router::worker_for_shard(path, s, self.txs.len());
            let msg = Msg::Request(
                leg,
                path,
                Some(s),
                fence,
                ReplySink::Gather(gather.clone()),
                // per-shard arrival stamp through the obs chokepoint
                clock::now(),
            );
            if let Err(err) = self.try_send(w, msg) {
                ReplySink::Gather(gather).fail(err.clone());
                return Err(err);
            }
        }
        if let Some(gathers) = &self.gathers {
            gathers
                .lock()
                .unwrap_or_else(std::sync::PoisonError::into_inner)
                .push(gather);
        }
        Ok(())
    }

    /// Submit and wait for the response (flattening the typed failure a
    /// worker may have sent down the reply channel).
    pub fn query(&self, req: KnnRequest) -> Result<KnnResponse, ServiceError> {
        let rx = self.submit(req)?;
        rx.recv().map_err(|_| ServiceError::ShutDown)?
    }

    /// Add points to the served dataset: append the record **once** to
    /// the shared [`InsertLog`] and broadcast only a sequence advance —
    /// each worker pulls the slices it owns from the log between its
    /// batches, so the pool no longer materializes one copy of every
    /// insert per worker. Rejects the degenerate shapes at the boundary
    /// (empty batch, non-finite coordinates) — they would otherwise
    /// fork the workers' views or corrupt every downstream structure.
    /// Uses a blocking send (never backpressure-rejected) — inserts are
    /// rare, and dropping an advance on a full queue would silently
    /// fork the workers' views of the data.
    ///
    /// Ordering contract: queries **submitted** after `insert` returns
    /// observe the new points on every route (their fence is stamped at
    /// or past this record's sequence); queries submitted before it may
    /// or may not, exactly as with a single worker.
    ///
    /// Durability contract (persistence on): the points are appended to
    /// the WAL **before** any worker sees them, so an insert this method
    /// acknowledged survives a crash. An append failure is a typed
    /// [`ServiceError::PersistFailed`] and the insert is *not* applied —
    /// memory never runs ahead of the log.
    pub fn insert(&self, points: &[Point3]) -> Result<(), ServiceError> {
        if points.is_empty() {
            return Err(ServiceError::InvalidRequest("empty insert batch"));
        }
        if points.iter().any(|p| !p.is_finite()) {
            return Err(ServiceError::InvalidRequest("non-finite insert coordinate"));
        }
        let pts = Arc::new(points.to_vec());
        // one global insert order across all workers: without the lock,
        // two concurrent inserts could land as [A, B] in one worker's
        // catch-up and [B, A] in another's, forking point ids between
        // routes. see scatter(): the guard carries no data, poison is
        // harmless
        let _broadcast = self
            .insert_lock
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner);
        // write-ahead: under the same lock as the log append, so WAL
        // sequence order IS log order IS fence order
        let mut watermark = 0u64;
        if let Some(wal) = &self.wal {
            let mut wal = wal.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
            match wal.append(points) {
                Ok(seq) => watermark = seq,
                Err(e) => return Err(ServiceError::PersistFailed(e.to_string())),
            }
        }
        let seq = self.log.append(pts);
        for (w, tx) in self.txs.iter().enumerate() {
            let wm = &self.metrics.workers[w];
            // same enqueue discipline as try_send: the lock keeps the
            // recorded high-water mark a truly attained occupancy
            let _q = self.enqueue_locks[w]
                .lock()
                .unwrap_or_else(std::sync::PoisonError::into_inner);
            wm.queue_depth.fetch_add(1, Ordering::SeqCst);
            if tx.send(Msg::InsertAdvance { seq }).is_err() {
                wm.queue_depth.fetch_sub(1, Ordering::SeqCst);
                return Err(ServiceError::ShutDown);
            }
            wm.queue_hwm
                .fetch_max(wm.queue_depth.load(Ordering::SeqCst), Ordering::SeqCst);
            Metrics::inc(&wm.submitted);
        }
        self.data_len.fetch_add(points.len(), Ordering::SeqCst);
        Metrics::inc(&self.metrics.inserts);
        Metrics::add(&self.metrics.points_inserted, points.len() as u64);
        // still under the insert lock: the snapshot trigger lands on the
        // owner's queue behind the insert it fences, never before it
        if self.wal.is_some() && self.snapshot_interval > 0 && watermark % self.snapshot_interval == 0
        {
            self.request_snapshot(watermark);
        }
        Ok(())
    }

    /// Fire-and-forget snapshot trigger to the RT route's owning worker
    /// (unsharded persistence only — a sharded route's durability is
    /// WAL-only). A full queue just postpones the snapshot to the next
    /// trigger; the WAL already holds everything it would have covered.
    fn request_snapshot(&self, watermark: u64) {
        if self.shards > 1 {
            return;
        }
        let w = Router::worker_for(RoutePath::Rt, self.txs.len());
        let wm = &self.metrics.workers[w];
        // enqueue lock: a failed send's transient depth bump must not be
        // observable by a concurrent producer's high-water-mark load
        let _q = self.enqueue_locks[w]
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner);
        wm.queue_depth.fetch_add(1, Ordering::SeqCst);
        if self.txs[w].try_send(Msg::Snapshot { watermark }).is_err() {
            wm.queue_depth.fetch_sub(1, Ordering::SeqCst);
        }
    }

    /// Clean-shutdown durability: fsync whatever sits in the WAL's
    /// group-commit window, then ask the RT owner for a final snapshot
    /// fenced at the current watermark — so the next cold start loads it
    /// and replays **zero** records. No-op when persistence is off.
    fn flush_persist(&self) {
        let Some(wal) = &self.wal else { return };
        let watermark = {
            let mut wal = wal.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
            if let Err(e) = wal.sync() {
                crate::log_warn!("WAL fsync at shutdown failed: {e}");
            }
            wal.record_count()
        };
        self.request_snapshot(watermark);
    }

    /// Live service counters (shared across every handle and worker).
    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// Requests accepted but not yet answered (scatter legs count per
    /// shard).
    pub fn inflight(&self) -> usize {
        self.inflight.load(Ordering::SeqCst)
    }

    /// Pool size (resolved, never 0).
    pub fn workers(&self) -> usize {
        self.txs.len()
    }

    /// Points currently served (base dataset + accepted inserts).
    pub fn data_len(&self) -> usize {
        self.data_len.load(Ordering::SeqCst)
    }
}

/// The service: owns the worker pool; dropping shuts it down.
pub struct Service {
    handle: ServiceHandle,
    workers: Vec<std::thread::JoinHandle<()>>,
    txs: Vec<SyncSender<Msg>>,
    /// Failover monitor (stop signal + thread), present only when the
    /// RT route is sharded on a pool of at least two workers.
    monitor: Option<(SyncSender<()>, std::thread::JoinHandle<()>)>,
}

impl Service {
    /// Start the pool over a fixed dataset. Blocks until every worker
    /// has reported ready (and the Brute owner has resolved PJRT
    /// availability), so routing decisions are stable from the first
    /// submit.
    pub fn start(data: Vec<Point3>, cfg: ServiceConfig) -> (Service, ServiceHandle) {
        let requested = if cfg.workers == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            cfg.workers
        };
        let shards = cfg.shards.max(1);
        // cap the pool at the number of distinct owners that can ever
        // exist: each unsharded route is one owner, and a sharded RT
        // route expands into one owner per shard; workers beyond that
        // would idle forever while still replicating inserts
        let route_slots = if shards > 1 {
            RoutePath::COUNT - 1 + shards
        } else {
            RoutePath::COUNT
        };
        let n_workers = requested.clamp(1, route_slots);
        let metrics = Arc::new(Metrics::with_pool(
            n_workers,
            if shards > 1 { shards } else { 0 },
        ));
        let inflight = Arc::new(AtomicUsize::new(0));
        let clock = Arc::new(ServiceClock::default());
        let health: Arc<Vec<WorkerHealth>> = Arc::new(
            (0..n_workers).map(|_| WorkerHealth::new(&clock)).collect(),
        );
        let ledger = Arc::new(PoisonLedger::default());
        let base = Arc::new(data);
        // Request-scoped tracing: fix the session epoch and create the
        // trace directory up front so every sink stamps against one
        // origin. An unusable directory degrades the run to tracing-off
        // with a warning — exactly the persistence idiom below;
        // observability must never fail serving.
        let mut tracing = None;
        if let Some(tc) = &cfg.trace {
            match Tracing::create(tc) {
                Ok(t) => tracing = Some(t),
                Err(e) => {
                    crate::log_warn!("tracing disabled for this run: {e}");
                }
            }
        }
        // the control sink is shared by cold-start recovery (here) and
        // the failover monitor; both are low-rate, so one mutex is fine
        let control_sink: Option<Arc<Mutex<SpanSink>>> =
            tracing.as_ref().map(|t| Arc::new(Mutex::new(t.control())));
        // Durable cold start (persistence on): open the WAL — repairing
        // any torn tail — so its records seed every worker's insert log,
        // then scan for the newest snapshot that survives full
        // validation. A candidate failing any check only bumps
        // `snapshot_corrupt` and falls through to the rebuild path: a
        // partially-trusted file is never served. An unusable data
        // directory degrades the run to in-memory with a warning rather
        // than failing start.
        let mut wal = None;
        let mut wal_records: Vec<Arc<Vec<Point3>>> = Vec::new();
        let mut snapshot: Option<(Arc<Vec<u8>>, u64)> = None;
        let mut snapshot_rejected = false;
        if let Some(pc) = &cfg.persist {
            match open_persist(pc, &cfg, &metrics, shards, control_sink.as_deref()) {
                Ok(st) => {
                    wal_records = st.records;
                    snapshot = st.snapshot;
                    snapshot_rejected = st.rejected;
                    wal = Some(Arc::new(Mutex::new(st.wal)));
                }
                Err(e) => {
                    crate::log_warn!("persistence disabled for this run: {e}");
                }
            }
        }
        let recovered_points: usize = wal_records.iter().map(|r| r.len()).sum();
        // the shared insert log, seeded with the WAL's replayed records:
        // the cold start's recovered inserts are fence-visible (and
        // worker-pullable) from the first submit, exactly like a
        // supervised restart's replay
        let log = Arc::new(InsertLog::new(wal_records));
        // the partition is a pure function of (base, shards): build it
        // once here and hand every worker the same copy, instead of S
        // duplicate Morton-sort passes before the ready handshake. The
        // no-coordination argument is only needed for the post-start
        // insert stream, which each replica applies identically.
        let partition = if shards > 1 {
            let exec = Executor::new(cfg.trueknn.threads);
            Some(Arc::new(Partition::build(&base[..], shards, &exec)))
        } else {
            None
        };
        let (ready_tx, ready_rx) = sync_channel::<bool>(n_workers);
        let mut txs = Vec::with_capacity(n_workers);
        let mut workers = Vec::with_capacity(n_workers);
        for w in 0..n_workers {
            let (tx, rx) = sync_channel::<Msg>(cfg.queue_depth);
            let ctx = WorkerCtx {
                worker_id: w,
                n_workers,
                base: base.clone(),
                partition: partition.clone(),
                cfg: cfg.clone(),
                rx,
                ready: Some(ready_tx.clone()),
                metrics: metrics.clone(),
                inflight: inflight.clone(),
                health: health.clone(),
                clock: clock.clone(),
                ledger: ledger.clone(),
                journal: Vec::new(),
                // the shared log replaces the per-worker insert copy: a
                // restarted incarnation (and a cold start with WAL
                // records) pulls exactly the prefix each batch's fence
                // demands
                log: log.clone(),
                snapshot: snapshot.clone(),
                snapshot_rejected,
                snapshot_ops: 0,
                batch_seq: 0,
                crashing_keys: Vec::new(),
                tracer: tracing.as_ref().map(|t| t.worker(w)),
            };
            workers.push(std::thread::spawn(move || supervise_worker(ctx)));
            txs.push(tx);
        }
        drop(ready_tx);
        let mut pjrt_available = false;
        for _ in 0..n_workers {
            // a recv error means every remaining worker died before its
            // handshake (the supervisor gave up on it): degrade to
            // pjrt-unavailable routing instead of panicking the caller
            match ready_rx.recv() {
                Ok(avail) => pjrt_available |= avail,
                Err(_) => {
                    crate::log_warn!("worker pool lost a worker before its ready handshake");
                    break;
                }
            }
        }
        let mut router_cfg = cfg.router.clone();
        router_cfg.pjrt_available = pjrt_available;
        let gathers = if shards > 1 && n_workers >= 2 {
            Some(Arc::new(Mutex::new(Vec::new())))
        } else {
            None
        };
        let handle = ServiceHandle {
            txs: Arc::new(txs.clone()),
            router: Arc::new(Router::new(router_cfg)),
            // recovered WAL inserts are part of the served dataset from
            // the first submit, so the routing policy's n includes them
            data_len: Arc::new(AtomicUsize::new(base.len() + recovered_points)),
            insert_lock: Arc::new(Mutex::new(())),
            log,
            enqueue_locks: Arc::new((0..n_workers).map(|_| Mutex::new(())).collect()),
            shards,
            metrics,
            inflight,
            ledger,
            gathers,
            snapshot_interval: cfg.persist.as_ref().map_or(0, |p| p.snapshot_interval),
            wal,
        };
        let monitor = handle.gathers.as_ref().map(|gathers| {
            let (stop_tx, stop_rx) = sync_channel::<()>(1);
            let mc = MonitorCtx {
                handle: handle.clone(),
                gathers: gathers.clone(),
                health,
                clock,
                timeout: cfg.heartbeat_timeout,
                shards,
                stop: stop_rx,
                tracer: control_sink.clone(),
            };
            (stop_tx, std::thread::spawn(move || run_monitor(mc)))
        });
        (
            Service {
                handle: handle.clone(),
                workers,
                txs,
                monitor,
            },
            handle,
        )
    }

    /// A fresh submitting handle onto this pool.
    pub fn handle(&self) -> ServiceHandle {
        self.handle.clone()
    }

    /// Signal every worker, serve what's queued, and join the pool.
    /// With persistence on this is the **clean** stop: the WAL's
    /// group-commit window is fsynced and a final snapshot is written
    /// before the workers exit, so the next cold start replays zero
    /// records.
    pub fn shutdown(mut self) {
        self.shutdown_and_join();
        // Drop runs next but finds the pool already drained: exactly one
        // Msg::Shutdown is ever sent per worker.
    }

    /// Stop the pool **without** the durability flush: no final WAL
    /// fsync, no shutdown snapshot — the on-disk state is whatever the
    /// insert path and interval snapshots left behind, exactly as a
    /// process crash would leave it. Queued work is still served (use a
    /// seeded [`FaultPlan`] to also tear the on-disk tail). Built for
    /// the crash-recovery suite.
    pub fn shutdown_abrupt(mut self) {
        if let Some((stop, join)) = self.monitor.take() {
            let _ = stop.send(());
            let _ = join.join();
        }
        if self.workers.is_empty() {
            return;
        }
        for tx in &self.txs {
            let _ = tx.send(Msg::Shutdown);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }

    /// Shared by `shutdown` and `Drop`: stop the monitor, flush
    /// durability state, signal every worker once and wait for all of
    /// them to drain. Idempotent — draining `workers` (and taking
    /// `monitor`) makes a second call a no-op, so the flush and the
    /// final snapshot happen exactly once.
    fn shutdown_and_join(&mut self) {
        if let Some((stop, join)) = self.monitor.take() {
            let _ = stop.send(());
            let _ = join.join();
        }
        if self.workers.is_empty() {
            return;
        }
        // before the shutdown barrier: the snapshot request must land on
        // the owner's queue ahead of its Msg::Shutdown
        self.handle.flush_persist();
        for tx in &self.txs {
            let _ = tx.send(Msg::Shutdown);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for Service {
    fn drop(&mut self) {
        self.shutdown_and_join();
    }
}

/// The builder whose fingerprint fences the RT route's snapshots:
/// exactly the configuration the registry builds (and recovers) the
/// route with, so a snapshot written under any other backend or
/// result-affecting setting is refused at load.
fn rt_builder(trueknn: &TrueKnnParams) -> IndexBuilder {
    IndexBuilder::new(Backend::TrueKnn).config(IndexConfig {
        exclude_self: false,
        ..trueknn.to_index_config()
    })
}

/// The on-disk name of a snapshot fenced at `watermark` — zero-padded so
/// lexicographic order is watermark order and the newest candidate sorts
/// last.
fn snapshot_file_name(watermark: u64) -> String {
    format!("snapshot-{watermark:020}.tksn")
}

/// Relative name of the WAL inside the data directory.
const WAL_FILE: &str = "wal.log";

/// Everything a durable cold start recovered from the data directory.
struct PersistStart {
    /// The open (tail-repaired) WAL, ready for appends.
    wal: Wal,
    /// Replayed WAL records in sequence order, ready to seed every
    /// worker's insert log.
    records: Vec<Arc<Vec<Point3>>>,
    /// The newest snapshot that survived full validation, with its
    /// watermark.
    snapshot: Option<(Arc<Vec<u8>>, u64)>,
    /// Snapshot files existed but none survived validation (the fresh
    /// build replacing them is counted as `rebuilt`).
    rejected: bool,
}

/// Open the data directory for a cold start: create it, open + repair
/// the WAL, and (unsharded only — a sharded route's durability is
/// WAL-only) pick the newest trustworthy snapshot. `wal_replayed` is
/// credited with every record past the chosen snapshot's watermark: the
/// suffix recovery must re-apply instead of finding inside a snapshot.
fn open_persist(
    pc: &PersistConfig,
    cfg: &ServiceConfig,
    metrics: &Metrics,
    shards: usize,
    tracer: Option<&Mutex<SpanSink>>,
) -> Result<PersistStart, crate::persist::PersistError> {
    std::fs::create_dir_all(&pc.data_dir)
        .map_err(|e| crate::persist::io_err("create_dir_all", e))?;
    let (wal, raw) = Wal::open(
        &pc.data_dir.join(WAL_FILE),
        pc.wal_group_commit.max(1),
        cfg.faults.clone(),
    )?;
    let records: Vec<Arc<Vec<Point3>>> = raw.into_iter().map(|r| Arc::new(r.points)).collect();
    let (snapshot, rejected) = if shards > 1 {
        (None, false)
    } else {
        scan_snapshots(pc, cfg, metrics, wal.record_count(), tracer)
    };
    let watermark = snapshot.as_ref().map_or(0, |&(_, w)| w);
    Metrics::add(&metrics.wal_replayed, wal.record_count() - watermark);
    Ok(PersistStart {
        wal,
        records,
        snapshot,
        rejected,
    })
}

/// Find the newest snapshot in the data directory that survives **full**
/// validation: container checksums and format version
/// ([`crate::persist::Snapshot::parse`]), the RT route's config
/// fingerprint, and a watermark no newer than the repaired WAL. Every
/// rejected candidate bumps `snapshot_corrupt` and the scan falls back
/// to the next-newest file — corruption can only ever cost freshness,
/// never correctness.
fn scan_snapshots(
    pc: &PersistConfig,
    cfg: &ServiceConfig,
    metrics: &Metrics,
    wal_records: u64,
    tracer: Option<&Mutex<SpanSink>>,
) -> (Option<(Arc<Vec<u8>>, u64)>, bool) {
    let mut candidates: Vec<PathBuf> = match std::fs::read_dir(&pc.data_dir) {
        Ok(rd) => rd
            .filter_map(|e| e.ok())
            .map(|e| e.path())
            .filter(|p| {
                p.file_name()
                    .and_then(|n| n.to_str())
                    .is_some_and(|n| n.starts_with("snapshot-") && n.ends_with(".tksn"))
            })
            .collect(),
        Err(_) => return (None, false),
    };
    // zero-padded names: lexicographic descending = newest watermark first
    candidates.sort();
    candidates.reverse();
    let found_any = !candidates.is_empty();
    let fingerprint = rt_builder(&cfg.trueknn).fingerprint();
    for path in candidates {
        match validate_snapshot(&path, cfg, fingerprint, wal_records) {
            Ok((bytes, watermark)) => return (Some((Arc::new(bytes), watermark)), false),
            Err(e) => {
                Metrics::inc(&metrics.snapshot_corrupt);
                crate::log_warn!("rejecting snapshot {}: {e}", path.display());
                // recovery event for the trace: cold start rejected a
                // candidate (the enriched PersistError already named
                // the failing section and offset in the warn above)
                if let Some(tracer) = tracer {
                    let mut tr = tracer
                        .lock()
                        .unwrap_or_else(std::sync::PoisonError::into_inner);
                    tr.event(
                        0,
                        span_names::RECOVERY,
                        vec![("snapshot_rejected".to_string(), 1.0)],
                    );
                    tr.flush();
                }
            }
        }
    }
    (None, found_any)
}

/// Validate one snapshot candidate end to end; returns its raw bytes and
/// watermark only if every check passes. A watermark past the repaired
/// WAL means the snapshot covers inserts the log no longer has — the
/// file is from a diverged history and must not be replayed onto.
fn validate_snapshot(
    path: &Path,
    cfg: &ServiceConfig,
    fingerprint: u64,
    wal_records: u64,
) -> Result<(Vec<u8>, u64), crate::persist::PersistError> {
    let bytes = crate::persist::read_file(path, &cfg.faults, IoTarget::Snapshot)?;
    let snap = crate::persist::Snapshot::parse(&bytes)?;
    snap.check_fingerprint(fingerprint)?;
    if snap.watermark > wal_records {
        return Err(crate::persist::PersistError::Corrupt {
            what: "snapshot container",
            detail: format!(
                "watermark {} is past the WAL's {wal_records} records",
                snap.watermark
            ),
        });
    }
    Ok((bytes, snap.watermark))
}

/// One shard sub-index of the sharded RT route, held by its owning
/// worker (or, transiently, by a failover worker serving a dead owner's
/// re-dispatched partials). The shard-local→global id remap lives in the
/// registry's [`Partition`] (`shards[s].ids`) — one source of truth
/// shared with the routing/rebalance logic, not a second copy here.
struct ShardSlot {
    index: Box<dyn NeighborIndex>,
    /// Builds performed by sub-indexes this slot retired at rebalances,
    /// so the per-shard build gauge accumulates instead of resetting.
    retired_builds: u64,
}

/// Per-worker index registry: one persistent [`NeighborIndex`] per
/// **owned** route path, built lazily on first use (the PJRT one eagerly
/// in the owning worker, because the router must know up front whether
/// that path exists). When the RT route is sharded, the registry instead
/// holds one [`ShardSlot`] per **owned shard**, built eagerly at worker
/// start from the deterministic partition of the base data — every
/// worker computes the identical partition without coordination, which
/// is what lets each one route the shared insert stream (and detect
/// rebalance overflows) in lock-step, and lets a failover worker build
/// a dead owner's shard on demand from its own replica.
///
/// The base dataset is shared read-only across the pool (`Arc`); a
/// worker only materializes its own copy inside the indexes it actually
/// builds, so idle workers cost no dataset memory. The same holds for
/// inserts: the registry keeps `Arc` references to the applied prefix
/// of the shared [`InsertLog`] — never a flattened per-worker copy —
/// so a worker that owns nothing built copies no inserted points at
/// all.
struct IndexRegistry {
    base: Arc<Vec<Point3>>,
    /// Total inserted points applied so far (the flattened length of
    /// `inserts`): global ids for a new record start at
    /// `base.len() + extra_len`.
    extra_len: usize,
    trueknn: TrueKnnParams,
    by_path: HashMap<RoutePath, Box<dyn NeighborIndex>>,
    /// RT-route shard count (1 = sharding off).
    shards: usize,
    /// Shard ids of the RT route this worker owns.
    my_shards: Vec<usize>,
    /// The deterministic partition (built over the base data; present on
    /// **every** worker when sharding is on — owners serve from it, and
    /// a non-owner needs it the moment the monitor fails a dead owner's
    /// shard over to it). Every worker applies the shared insert stream
    /// to it through [`Partition::group_routed`], so all replicas hold
    /// identical shard membership — and evaluate the
    /// [`Partition::overflowed`] rebalance predicate to the same answer
    /// at the same insert barrier — with no coordination.
    partition: Option<Partition>,
    /// The pristine partition over the **base** data, untouched by the
    /// insert stream: the starting point for reconstructing shard
    /// membership at an arbitrary fence
    /// ([`IndexRegistry::shard_at_fence`]) when a failover leg arrives
    /// with a fence this registry has already run past.
    start_partition: Option<Arc<Partition>>,
    shard_slots: HashMap<usize, ShardSlot>,
    /// Validated snapshot handed down from cold start (persistence on,
    /// RT route unsharded only); consumed by the first RT build.
    snapshot: Option<(Arc<Vec<u8>>, u64)>,
    /// Snapshot files existed at cold start but none survived
    /// validation: the fresh RT build replacing them counts as
    /// `rebuilt`.
    snapshot_rejected: bool,
    /// Every insert record applied, in log order — `Arc` clones of the
    /// shared log's prefix `[0, applied_seq)`, record-granular so a
    /// snapshot-loaded index can replay exactly the records past its
    /// watermark. `inserts.len()` IS the applied sequence number.
    inserts: Vec<Arc<Vec<Point3>>>,
}

impl IndexRegistry {
    fn new(
        base: Arc<Vec<Point3>>,
        cfg: &ServiceConfig,
        worker_id: usize,
        n_workers: usize,
    ) -> Self {
        let shards = cfg.shards.max(1);
        let my_shards: Vec<usize> = if shards > 1 {
            (0..shards)
                .filter(|&s| Router::worker_for_shard(RoutePath::Rt, s, n_workers) == worker_id)
                .collect()
        } else {
            Vec::new()
        };
        IndexRegistry {
            base,
            extra_len: 0,
            trueknn: cfg.trueknn.clone(),
            by_path: HashMap::new(),
            shards,
            my_shards,
            partition: None,
            start_partition: None,
            shard_slots: HashMap::new(),
            snapshot: None,
            snapshot_rejected: false,
            inserts: Vec::new(),
        }
    }

    /// Insert-log records applied so far — the registry's position in
    /// the fence domain.
    fn applied_seq(&self) -> u64 {
        self.inserts.len() as u64
    }

    /// Pull and apply every log record in `[applied_seq, fence)`. A
    /// registry at or past `fence` is left untouched (catch-up is
    /// forward-only — the at-fence reconstruction for a leg that must
    /// observe *less* than the registry holds is
    /// [`IndexRegistry::shard_at_fence`]).
    fn catch_up_to(&mut self, fence: u64, log: &InsertLog, metrics: &Metrics) {
        let applied = self.applied_seq();
        if applied >= fence {
            return;
        }
        for rec in log.range(applied, fence) {
            self.apply_insert(&rec, metrics);
        }
    }

    /// Install the shared partition replica and eagerly build this
    /// worker's owned shard sub-indexes from the partition
    /// `Service::start` computed once over the base data (no-op when
    /// sharding is off). Runs before the ready handshake so a sharded
    /// route serves from the first submit. Non-owners install the
    /// replica too: the insert stream keeps it current, so a failover
    /// build ([`IndexRegistry::shard_slot_or_build`]) starts from the
    /// same membership every owner holds.
    fn build_owned_shards(&mut self, partition: Option<&Arc<Partition>>, metrics: &Metrics) {
        if self.shards <= 1 {
            return;
        }
        let part_arc = partition
            // lint: allow(panic-in-lib) — Service::start always builds the partition when shards > 1; a miss is a construction bug
            .expect("sharded service must hand its workers the start partition");
        let part: Partition = part_arc.as_ref().clone();
        // keep the pristine base partition around: at-fence shard
        // reconstruction replays the log onto it from sequence zero
        self.start_partition = Some(part_arc.clone());
        let base = self.base.clone();
        let owned = self.my_shards.clone();
        for s in owned {
            let slot = self.build_shard_slot(&base, &part, s, 0);
            metrics.set_shard_builds(
                s,
                slot.retired_builds + slot.index.build_stats().counters.builds,
            );
            self.shard_slots.insert(s, slot);
        }
        self.partition = Some(part);
    }

    /// Build one shard's sub-index over `data[part.shards[s]]` with the
    /// service's RT config — except `exclude_self`, which is forced off:
    /// shard-local positions don't align with batch query positions, so
    /// positional exclusion inside a shard would drop an arbitrary
    /// unrelated point per shard (the same reason `ShardedIndex` forces
    /// it off on its inner indexes). Service queries are external points
    /// by contract, so the gather needs no exclusion of its own.
    fn build_shard_slot(
        &self,
        data: &[Point3],
        part: &Partition,
        s: usize,
        retired_builds: u64,
    ) -> ShardSlot {
        let set = &part.shards[s];
        let pts: Vec<Point3> = set.ids.iter().map(|&i| data[i as usize]).collect();
        let cfg = IndexConfig {
            exclude_self: false,
            ..self.trueknn.to_index_config()
        };
        ShardSlot {
            index: Box::new(TrueKnnIndex::new(pts, cfg)),
            retired_builds,
        }
    }

    /// The sub-index serving shard `s`, building it on demand. Owners
    /// built theirs eagerly at start; a **failover** worker lands here
    /// when the monitor re-dispatched a dead owner's partial to it, and
    /// builds the shard deterministically from its own partition replica
    /// over the full dataset — byte-for-byte the same structure the
    /// owner held, because both are pure functions of
    /// `(base, insert log, shard membership)`.
    fn shard_slot_or_build(&mut self, s: usize, metrics: &Metrics) -> &mut ShardSlot {
        if !self.shard_slots.contains_key(&s) {
            let data = self.full_data();
            let slot = {
                let part = self
                    .partition
                    .as_ref()
                    // lint: allow(panic-in-lib) — every worker installs the partition replica before the ready handshake when shards > 1
                    .expect("sharded batch on a worker without a partition replica");
                self.build_shard_slot(&data, part, s, 0)
            };
            metrics.set_shard_builds(
                s,
                slot.retired_builds + slot.index.build_stats().counters.builds,
            );
            self.shard_slots.insert(s, slot);
        }
        // lint: allow(panic-in-lib) — the branch above inserts the key when absent; infallible by construction
        self.shard_slots.get_mut(&s).expect("just inserted")
    }

    /// Everything this registry indexes (base + applied insert records,
    /// flattened on demand — the registry holds no standing copy).
    fn full_data(&self) -> Vec<Point3> {
        let mut data = Vec::with_capacity(self.base.len() + self.extra_len);
        data.extend_from_slice(&self.base);
        for rec in &self.inserts {
            data.extend_from_slice(rec);
        }
        data
    }

    /// Rebuild shard `s` — index **and** global-id membership — at
    /// exactly insert prefix `fence`, from the pristine base partition
    /// plus the shared log's first `fence` records. The failover path:
    /// a re-dispatched scatter leg may land on a worker whose registry
    /// already applied inserts past the leg's fence, and serving it
    /// from the live slot would merge a newer prefix into a gather
    /// whose sibling shards served an older one. This replays the exact
    /// membership evolution every worker computed at that prefix —
    /// including any rebalance the growth triggered — so the partial is
    /// byte-for-byte what the dead owner would have delivered. The
    /// result is intentionally **not** cached (and the shard-build
    /// gauge untouched): it serves one stale-fence leg and is dropped.
    fn shard_at_fence(&self, s: usize, fence: u64, log: &InsertLog) -> (ShardSlot, Vec<u32>) {
        let mut part: Partition = self
            .start_partition
            .as_ref()
            // lint: allow(panic-in-lib) — every sharded worker stores the start partition before the ready handshake
            .expect("at-fence rebuild on a worker without the start partition")
            .as_ref()
            .clone();
        let mut data: Vec<Point3> = self.base.to_vec();
        for rec in log.range(0, fence) {
            let grouped = part.group_routed(&rec, data.len());
            for (si, (ids, pts)) in grouped.into_iter().enumerate() {
                if pts.is_empty() {
                    continue;
                }
                let set = &mut part.shards[si];
                for &p in &pts {
                    set.aabb.grow(p);
                }
                set.ids.extend(ids);
            }
            data.extend_from_slice(&rec);
            if part.overflowed(data.len()) {
                let exec = Executor::new(self.trueknn.threads);
                part = Partition::build(&data, self.shards, &exec);
            }
        }
        let slot = self.build_shard_slot(&data, &part, s, 0);
        let ids = std::mem::take(&mut part.shards[s].ids);
        (slot, ids)
    }

    /// Service queries are external points: never self-exclude. Brute
    /// scans inherit the service's launch-engine thread count so both
    /// routes get launch-level parallelism under batch-level parallelism.
    fn brute_config(&self) -> IndexConfig {
        IndexConfig {
            exclude_self: false,
            threads: self.trueknn.threads,
            ..Default::default()
        }
    }

    fn install(&mut self, path: RoutePath, index: Box<dyn NeighborIndex>, metrics: &Metrics) {
        metrics.set_route_builds(path, index.build_stats().counters.builds);
        self.by_path.insert(path, index);
    }

    /// The index serving `path`, building it on first use. The per-route
    /// build gauge tracks the index's build count — it stays at 1 across
    /// a serving session because every later batch on the same path
    /// reuses the structure.
    fn get(
        &mut self,
        path: RoutePath,
        metrics: &Metrics,
        tracer: &mut Option<SpanSink>,
    ) -> &mut Box<dyn NeighborIndex> {
        if !self.by_path.contains_key(&path) {
            let index: Box<dyn NeighborIndex> = match path {
                // service queries are external points: never
                // self-exclude (positional exclusion is meaningless
                // against batch-concatenated queries, and forcing it off
                // here keeps the unsharded RT route consistent with the
                // sharded one — sharding stays a pure throughput knob)
                RoutePath::Rt => self.build_rt(metrics, tracer),
                // Reached only if the eagerly-installed PJRT index is
                // missing (runtime load raced or failed): rebuild with
                // whatever runtime is available now.
                RoutePath::Brute => {
                    Box::new(BrutePjrtIndex::new(self.full_data(), self.brute_config()))
                }
                RoutePath::BruteCpu => {
                    Box::new(BruteCpuIndex::new(self.full_data(), self.brute_config()))
                }
            };
            self.install(path, index, metrics);
        }
        // lint: allow(panic-in-lib) — the branch above inserts the key when absent; infallible by construction
        self.by_path.get_mut(&path).expect("just inserted")
    }

    /// The RT route's index: **recovered** from the cold-start snapshot
    /// when one survived validation — load the container, then replay
    /// exactly the insert records past its watermark, landing on the
    /// same state as the run that wrote it — and **rebuilt** from source
    /// data otherwise. Every outcome is counted: `recovered` for a
    /// snapshot load, `rebuilt` for a fresh build that replaces an
    /// unusable snapshot, `snapshot_corrupt` for a deep decode failure
    /// the cold-start container scan could not see. A recovery failure
    /// can only ever cost build time, never answers.
    fn build_rt(
        &mut self,
        metrics: &Metrics,
        tracer: &mut Option<SpanSink>,
    ) -> Box<dyn NeighborIndex> {
        let cfg = IndexConfig {
            exclude_self: false,
            ..self.trueknn.to_index_config()
        };
        if let Some((bytes, _)) = self.snapshot.take() {
            match rt_builder(&self.trueknn).load(&bytes) {
                Ok((mut index, watermark)) if (watermark as usize) <= self.inserts.len() => {
                    // records at or below the watermark are already
                    // inside the snapshot; replay only the suffix
                    for rec in &self.inserts[watermark as usize..] {
                        index.insert(&rec[..]);
                    }
                    Metrics::inc(&metrics.recovered);
                    if let Some(sink) = tracer.as_mut() {
                        sink.event(
                            0,
                            span_names::RECOVERY,
                            vec![
                                ("recovered".to_string(), 1.0),
                                ("watermark".to_string(), watermark as f64),
                            ],
                        );
                    }
                    return index;
                }
                Ok(_) => {
                    // a watermark past the applied insert records means
                    // the snapshot covers history this process never saw
                    Metrics::inc(&metrics.snapshot_corrupt);
                    if let Some(sink) = tracer.as_mut() {
                        sink.event(
                            0,
                            span_names::RECOVERY,
                            vec![("snapshot_rejected".to_string(), 1.0)],
                        );
                    }
                }
                Err(e) => {
                    Metrics::inc(&metrics.snapshot_corrupt);
                    // the enriched PersistError names the failing
                    // section and offset — surface it verbatim
                    crate::log_warn!("snapshot rejected at decode; rebuilding: {e}");
                    if let Some(sink) = tracer.as_mut() {
                        sink.event(
                            0,
                            span_names::RECOVERY,
                            vec![("snapshot_rejected".to_string(), 1.0)],
                        );
                    }
                }
            }
            Metrics::inc(&metrics.rebuilt);
            return Box::new(TrueKnnIndex::new(self.full_data(), cfg));
        }
        if self.snapshot_rejected {
            Metrics::inc(&metrics.rebuilt);
        }
        Box::new(TrueKnnIndex::new(self.full_data(), cfg))
    }

    /// Apply one log record to every already-built index (lazily-built
    /// ones pick the points up from the applied record list at build
    /// time), refreshing the per-route build gauges in case an insert
    /// triggered a rebuild. Workers reach this only through
    /// [`IndexRegistry::catch_up_to`], so records are always applied in
    /// log order with no gaps.
    ///
    /// When sharding is on, the points are also routed through the
    /// shared deterministic partition (and into whatever shard
    /// sub-indexes this worker holds); global ids are assigned against
    /// the pre-insert total so they match the unsharded oracle's ids
    /// exactly. Every worker tracks all shards' sizes from the same
    /// stream, so the rebalance decision below fires on every worker at
    /// the same insert barrier.
    fn apply_insert(&mut self, record: &Arc<Vec<Point3>>, metrics: &Metrics) {
        self.inserts.push(record.clone());
        let points: &[Point3] = &record[..];
        if let Some(part) = &mut self.partition {
            let old_total = self.base.len() + self.extra_len;
            // the SAME grouping step ShardedIndex::insert runs — every
            // replica extends its partition identically, and only the
            // shards' sub-indexes actually held here do real work
            let grouped = part.group_routed(points, old_total);
            for (s, (ids, pts)) in grouped.into_iter().enumerate() {
                if pts.is_empty() {
                    continue;
                }
                let set = &mut part.shards[s];
                for &p in &pts {
                    set.aabb.grow(p);
                }
                set.ids.extend(ids);
                if let Some(slot) = self.shard_slots.get_mut(&s) {
                    slot.index.insert(&pts);
                    metrics.set_shard_builds(
                        s,
                        slot.retired_builds + slot.index.build_stats().counters.builds,
                    );
                }
            }
        }
        self.extra_len += points.len();
        // fixed route order (RoutePath::ALL), not a HashMap walk: insert
        // application and gauge refresh must happen in the same order on
        // every worker and every run
        for path in RoutePath::ALL {
            if let Some(index) = self.by_path.get_mut(&path) {
                index.insert(points);
                metrics.set_route_builds(path, index.build_stats().counters.builds);
            }
        }
        let total = self.base.len() + self.extra_len;
        if self.partition.as_ref().is_some_and(|p| p.overflowed(total)) {
            self.rebalance_shards(metrics);
        }
    }

    /// Rebalance: re-partition the full dataset and rebuild this
    /// worker's owned shards. Deterministic — every owner computes the
    /// same partition from the same data at the same barrier. Retired
    /// build counts roll into the per-shard gauges so they accumulate.
    /// Any lazily-built **failover** slot (a shard this worker does not
    /// own) is dropped first: it was built against the old partition and
    /// would serve stale membership; a later re-dispatch rebuilds it
    /// from the fresh replica on demand.
    fn rebalance_shards(&mut self, metrics: &Metrics) {
        let owned = self.my_shards.clone();
        self.shard_slots.retain(|s, _| owned.contains(s));
        let exec = Executor::new(self.trueknn.threads);
        let data = self.full_data();
        let part = Partition::build(&data, self.shards, &exec);
        // retire and rebuild in my_shards order (ascending by
        // construction) — slots only ever exist for owned shards after
        // the retain above, so the keyed removes cover everything a
        // drain() would have, without the HashMap's randomized visit
        // order
        for s in owned {
            let retired = match self.shard_slots.remove(&s) {
                Some(old) => old.retired_builds + old.index.build_stats().counters.builds,
                None => 0,
            };
            let slot = self.build_shard_slot(&data, &part, s, retired);
            metrics.set_shard_builds(
                s,
                slot.retired_builds + slot.index.build_stats().counters.builds,
            );
            self.shard_slots.insert(s, slot);
        }
        self.partition = Some(part);
    }
}

/// One incarnation of a worker: build (or deterministically rebuild)
/// the index state, replay the journal left by a crashed predecessor,
/// then serve the queue until shutdown. Runs under
/// [`supervise_worker`]'s `catch_unwind`; everything that must survive
/// a crash lives in the [`WorkerCtx`], everything local to this
/// incarnation (registry, batcher, reply map) is rebuilt here from the
/// ctx's persistent base + insert log.
pub(super) fn worker_body(ctx: &mut WorkerCtx) {
    let mut registry = IndexRegistry::new(ctx.base.clone(), &ctx.cfg, ctx.worker_id, ctx.n_workers);
    // Cold-start recovery state (persistence on): every incarnation gets
    // the same validated snapshot, so a crash-restart recovers exactly
    // like the first start did.
    registry.snapshot = ctx.snapshot.clone();
    registry.snapshot_rejected = ctx.snapshot_rejected;
    // Sharded RT route: owned shard sub-indexes are built before the
    // ready handshake, from the one partition Service::start computed
    // over the base data, so the route serves from the first submit and
    // every worker starts from identical shard membership.
    registry.build_owned_shards(ctx.partition.as_ref(), &ctx.metrics);
    // No eager insert replay: the registry starts at sequence zero and
    // pulls from the shared log per batch, to exactly each batch's
    // fence. A restarted incarnation is still a pure function of
    // (base, shared log prefix, config) — the journal's fences say
    // which prefix every replayed batch must observe, so the replay
    // reproduces the pre-crash answers bit for bit without reapplying
    // records no pending batch needs.
    // PJRT runtime is constructed here: the client is not Send. Only the
    // worker that owns the Brute route loads it (eagerly, so the
    // readiness handshake can tell the router the path exists).
    let mut pjrt_available = false;
    if ctx.cfg.use_pjrt && Router::worker_for(RoutePath::Brute, ctx.n_workers) == ctx.worker_id {
        match PjrtRuntime::load_default() {
            Ok(rt) => {
                let index = BrutePjrtIndex::with_runtime(
                    registry.full_data(),
                    Some(rt),
                    registry.brute_config(),
                );
                registry.install(RoutePath::Brute, Box::new(index), &ctx.metrics);
                pjrt_available = true;
            }
            Err(e) => {
                crate::log_warn!("PJRT unavailable, brute falls back to CPU: {e}");
            }
        }
    }
    // first incarnation only: later ones already shook hands
    if let Some(ready) = ctx.ready.take() {
        let _ = ready.send(pjrt_available);
    }

    let mut batcher = DynamicBatcher::new(ctx.cfg.batcher.clone());
    // response sinks ride alongside their request through the batcher,
    // keyed by (request id, shard tag) — a worker owning several shards
    // of one route receives one message per owned shard
    let mut reply_of: HashMap<(u64, u64), ReplySink> = HashMap::new();

    // Crash recovery: re-enqueue every journaled (accepted, un-replied)
    // request in its original submit order and serve it before touching
    // the queue — the replay is indistinguishable from the first
    // attempt to the client, and the replays counter records it.
    if !ctx.journal.is_empty() {
        Metrics::add(&ctx.metrics.replays, ctx.journal.len() as u64);
        for e in &ctx.journal {
            reply_of.insert(sink_key(e.req.id, e.shard), e.sink.clone());
            batcher.push(e.req.clone(), e.path, e.shard, e.fence, e.arrived);
        }
        drain(ctx, &mut registry, &mut batcher, &mut reply_of);
    }

    'outer: loop {
        // block for the first message, then drain whatever else arrived
        match ctx.rx.recv() {
            Ok(msg) => {
                ctx.beat();
                if !on_msg(ctx, msg, &mut registry, &mut batcher, &mut reply_of) {
                    break 'outer;
                }
            }
            Err(_) => break 'outer,
        }
        while let Ok(msg) = ctx.rx.try_recv() {
            if !on_msg(ctx, msg, &mut registry, &mut batcher, &mut reply_of) {
                break 'outer;
            }
        }
        drain(ctx, &mut registry, &mut batcher, &mut reply_of);
    }

    // Reconcile gauges for messages accepted behind the shutdown signal:
    // their replies are dropped (clients observe ShutDown on recv), but
    // queue depth and inflight must not stay overstated forever. A
    // submit that races past this sweep before the channel disconnects
    // can still leak one tick — the gauges are operator telemetry, not
    // invariants.
    let wm = &ctx.metrics.workers[ctx.worker_id];
    while let Ok(msg) = ctx.rx.try_recv() {
        match msg {
            Msg::Request(..) => {
                wm.queue_depth.fetch_sub(1, Ordering::SeqCst);
                ctx.inflight.fetch_sub(1, Ordering::SeqCst);
            }
            Msg::InsertAdvance { .. } | Msg::Snapshot { .. } => {
                wm.queue_depth.fetch_sub(1, Ordering::SeqCst);
            }
            Msg::Shutdown => {}
        }
    }
    // clean exit is the last chance for buffered spans to reach the
    // trace file; a crashed incarnation keeps its ring (the sink lives
    // in the supervisor-owned ctx) and the next one flushes it here
    if let Some(tracer) = ctx.tracer.as_mut() {
        tracer.flush();
    }
}

/// The reply-map key of one queued message: request id plus the shard
/// it addresses (`u64::MAX` = the unsharded whole-route message).
fn sink_key(id: u64, shard: Option<usize>) -> (u64, u64) {
    (id, shard.map_or(u64::MAX, |s| s as u64))
}

/// Handle one queue message on the worker thread; returns `false` when
/// the worker should exit.
fn on_msg(
    ctx: &mut WorkerCtx,
    msg: Msg,
    registry: &mut IndexRegistry,
    batcher: &mut DynamicBatcher,
    reply_of: &mut HashMap<(u64, u64), ReplySink>,
) -> bool {
    match msg {
        Msg::Request(req, path, shard, fence, sink, t) => {
            ctx.metrics.workers[ctx.worker_id]
                .queue_depth
                .fetch_sub(1, Ordering::SeqCst);
            // journal before batching: from this point until its reply
            // is sent, the request survives a worker crash (fence
            // included, so the replay serves the same insert prefix)
            ctx.journal.push(JournalEntry {
                req: req.clone(),
                path,
                shard,
                fence,
                sink: sink.clone(),
                arrived: t,
            });
            reply_of.insert(sink_key(req.id, shard), sink);
            batcher.push(req, path, shard, fence, t);
            true
        }
        Msg::InsertAdvance { seq } => {
            ctx.metrics.workers[ctx.worker_id]
                .queue_depth
                .fetch_sub(1, Ordering::SeqCst);
            // drain BEFORE catching up: every pending batch carries a
            // fence below `seq` (queue FIFO + the insert lock ordered
            // it ahead of this advance) and must be served at exactly
            // that older prefix — catching up first would force the
            // at-fence reconstruction path for all of them
            drain(ctx, registry, batcher, reply_of);
            registry.catch_up_to(seq, &ctx.log, &ctx.metrics);
            Metrics::inc(&ctx.metrics.workers[ctx.worker_id].inserts);
            true
        }
        Msg::Snapshot { watermark } => {
            ctx.metrics.workers[ctx.worker_id]
                .queue_depth
                .fetch_sub(1, Ordering::SeqCst);
            // snapshot settled state: pending batches first, so the
            // write never races index mutation on this worker; then pull
            // the log up to the watermark the snapshot must cover (the
            // trigger rode the queue behind its insert's advance, so
            // this is normally a no-op)
            drain(ctx, registry, batcher, reply_of);
            registry.catch_up_to(watermark, &ctx.log, &ctx.metrics);
            write_snapshot(ctx, registry, watermark);
            true
        }
        Msg::Shutdown => {
            // serve what's queued, then exit
            drain(ctx, registry, batcher, reply_of);
            false
        }
    }
}

/// Write the RT route's snapshot fenced at `watermark` via the
/// temp-file + fsync + atomic-rename path. Best-effort by design: a
/// failed (or fault-torn) write is logged and durability degrades to
/// WAL-only — the log already holds every insert the snapshot would
/// have covered, so correctness never depends on this write landing.
/// Skipped while the route has no built index (the WAL alone reproduces
/// that state) and on sharded pools (WAL-only durability).
fn write_snapshot(ctx: &mut WorkerCtx, registry: &IndexRegistry, watermark: u64) {
    let Some(pc) = &ctx.cfg.persist else { return };
    if registry.shards > 1 {
        return;
    }
    let Some(index) = registry.by_path.get(&RoutePath::Rt) else {
        return;
    };
    let bytes = rt_builder(&registry.trueknn).snapshot(index.as_ref(), watermark);
    let path = pc.data_dir.join(snapshot_file_name(watermark));
    ctx.snapshot_ops += 1;
    if let Err(e) = crate::persist::atomic_write(
        &path,
        &bytes,
        &ctx.cfg.faults,
        IoTarget::Snapshot,
        ctx.snapshot_ops,
    ) {
        crate::log_warn!("snapshot write failed (durability degrades to WAL-only): {e}");
    }
}

/// Shed every request in the batch whose deadline has passed: typed
/// [`ServiceError::DeadlineExceeded`] to the sink, a `deadline_misses`
/// tick, and the usual per-request finalization (inflight gauge,
/// journal completion). Survivors keep their order; ranges are rebuilt.
fn shed_expired(
    ctx: &mut WorkerCtx,
    batch: &mut Batch,
    reply_of: &mut HashMap<(u64, u64), ReplySink>,
    deadline: Duration,
) {
    let shard = batch.shard;
    let mut kept = Vec::with_capacity(batch.requests.len());
    for (req, arrived) in batch.requests.drain(..) {
        // `>=` so Duration::ZERO deterministically sheds everything
        if arrived.elapsed() >= deadline {
            Metrics::inc(&ctx.metrics.deadline_misses);
            if let Some(sink) = reply_of.remove(&sink_key(req.id, shard)) {
                sink.fail(ServiceError::DeadlineExceeded);
            }
            ctx.inflight.fetch_sub(1, Ordering::SeqCst);
            ctx.complete(req.id, shard);
        } else {
            kept.push((req, arrived));
        }
    }
    batch.requests = kept;
    let mut off = 0;
    batch.ranges = batch
        .requests
        .iter()
        .map(|(r, _)| {
            let start = off;
            off += r.queries.len();
            (start, off)
        })
        .collect();
}

fn drain(
    ctx: &mut WorkerCtx,
    registry: &mut IndexRegistry,
    batcher: &mut DynamicBatcher,
    reply_of: &mut HashMap<(u64, u64), ReplySink>,
) {
    while let Some(mut batch) = batcher.next_batch() {
        // per-worker batch sequence: monotonic across restarts, so a
        // scheduled fault fires exactly once (the replay drains at a
        // later sequence)
        let seq = ctx.batch_seq;
        ctx.batch_seq += 1;
        ctx.beat();
        let stall = ctx.cfg.faults.queue_stall_ms(ctx.worker_id, seq);
        let delay = ctx.cfg.faults.reply_delay_ms(ctx.worker_id, seq);
        let panic_now = ctx.cfg.faults.should_panic(ctx.worker_id, seq)
            || ctx
                .cfg
                .faults
                .poisons_any(batch.requests.iter().map(|(r, _)| r.id));
        if let Some(ms) = stall {
            // injected queue stall: the heartbeat above is the last one
            // until the sleep ends, so the monitor sees this worker go
            // stale — exactly the hang the failover path exists for
            std::thread::sleep(Duration::from_millis(ms));
        }
        // record the in-flight keys: a crash between here and the end of
        // the batch is attributed to exactly these requests (the poison
        // ledger's strike unit)
        ctx.crashing_keys = batch.request_keys();
        if panic_now {
            std::panic::panic_any(InjectedFault);
        }
        if let Some(deadline) = ctx.cfg.request_deadline {
            shed_expired(ctx, &mut batch, reply_of, deadline);
            if batch.requests.is_empty() {
                ctx.crashing_keys.clear();
                continue;
            }
            // a crash while serving the survivors belongs to them alone
            ctx.crashing_keys = batch.request_keys();
        }
        Metrics::inc(&ctx.metrics.batches);
        Metrics::inc(&ctx.metrics.workers[ctx.worker_id].batches);
        // serve stamp through the obs chokepoint: every duration below
        // is telemetry (histograms + spans) the results never observe
        let served = clock::now();
        // queue wait per request: submit stamp → this serve stamp
        for (_, arrived) in &batch.requests {
            ctx.metrics.workers[ctx.worker_id]
                .hist_queue_wait
                .record(served.saturating_duration_since(*arrived).as_nanos() as u64);
        }
        let all_queries: Vec<Point3> = batch
            .requests
            .iter()
            .flat_map(|(r, _)| r.queries.iter().copied())
            .collect();

        // the batch carries its submit-time routing decision; the worker
        // never re-routes
        let path = batch.path;

        if let Some(s) = batch.shard {
            // sharded scatter leg: serve this shard's slice of every
            // request at exactly the batch's insert fence, remap
            // shard-local ids to global ones (fanned across the exec
            // engine), and merge each partial into its gather — the
            // delivery merging the last shard replies.
            let exec = Executor::new(ctx.cfg.trueknn.threads);
            let fence_start = clock::now();
            let (neighbors, rounds, fence_end): (Vec<Vec<Neighbor>>, Vec<RoundStats>, Instant) =
                if registry.applied_seq() <= batch.fence {
                    // owned (or first-dispatch failover) leg: queue FIFO +
                    // the insert lock guarantee the registry has not run
                    // past the fence — pull the log up to exactly it
                    registry.catch_up_to(batch.fence, &ctx.log, &ctx.metrics);
                    let fence_end = clock::now();
                    let slot = registry.shard_slot_or_build(s, &ctx.metrics);
                    let res = slot.index.knn(&all_queries, batch.k);
                    ctx.metrics.set_shard_builds(
                        s,
                        slot.retired_builds + slot.index.build_stats().counters.builds,
                    );
                    let ids = &registry
                        .partition
                        .as_ref()
                        // lint: allow(panic-in-lib) — every worker installs the partition replica before the ready handshake
                        .expect("shard batch without a partition")
                        .shards[s]
                        .ids;
                    let mut nb = res.neighbors;
                    remap_global(&mut nb, ids, &exec);
                    (nb, res.rounds, fence_end)
                } else {
                    // re-dispatched failover leg whose fence is older than
                    // this registry's applied prefix: serve it from an
                    // ephemeral at-fence rebuild so the partial matches the
                    // prefix every sibling shard served. The rebuild IS
                    // this leg's fence reconciliation, so it lands in the
                    // fence-catch-up histogram bucket
                    let (mut slot, ids) = registry.shard_at_fence(s, batch.fence, &ctx.log);
                    let fence_end = clock::now();
                    let res = slot.index.knn(&all_queries, batch.k);
                    let mut nb = res.neighbors;
                    remap_global(&mut nb, &ids, &exec);
                    (nb, res.rounds, fence_end)
                };
            let leg_end = clock::now();
            let service_seconds = leg_end.saturating_duration_since(served).as_secs_f64();
            {
                let wm = &ctx.metrics.workers[ctx.worker_id];
                wm.hist_fence
                    .record(fence_end.saturating_duration_since(fence_start).as_nanos() as u64);
                wm.hist_service
                    .record(leg_end.saturating_duration_since(served).as_nanos() as u64);
            }
            // span emission after the leg is computed: the serving path
            // above never observed the sink, so tracing on/off cannot
            // perturb results (the bitwise oracle in the trace suite)
            if let Some(sink) = ctx.tracer.as_mut() {
                let served_ns = sink.ns_since_epoch(served);
                let fence_start_ns = sink.ns_since_epoch(fence_start);
                let fence_end_ns = sink.ns_since_epoch(fence_end);
                let leg_end_ns = sink.ns_since_epoch(leg_end);
                let worker = sink.worker();
                for (req, arrived) in &batch.requests {
                    let qw = sink.next_id();
                    sink.push(SpanRecord {
                        trace: req.id,
                        span: qw,
                        parent: 0,
                        name: span_names::QUEUE_WAIT.to_string(),
                        worker,
                        start_ns: sink.ns_since_epoch(*arrived),
                        end_ns: served_ns,
                        attrs: Vec::new(),
                    });
                    let fs = sink.next_id();
                    sink.push(SpanRecord {
                        trace: req.id,
                        span: fs,
                        parent: 0,
                        name: span_names::FENCE_CATCHUP.to_string(),
                        worker,
                        start_ns: fence_start_ns,
                        end_ns: fence_end_ns,
                        attrs: vec![("fence".to_string(), batch.fence as f64)],
                    });
                    let leg = sink.next_id();
                    sink.push(SpanRecord {
                        trace: req.id,
                        span: leg,
                        parent: 0,
                        name: span_names::SHARD_LEG.to_string(),
                        worker,
                        start_ns: served_ns,
                        end_ns: leg_end_ns,
                        attrs: vec![
                            ("shard".to_string(), s as f64),
                            ("fence".to_string(), batch.fence as f64),
                            ("batch".to_string(), seq as f64),
                        ],
                    });
                    push_round_spans(sink, req.id, leg, served_ns, leg_end_ns, &rounds);
                }
            }
            if let Some(ms) = delay {
                std::thread::sleep(Duration::from_millis(ms));
            }
            for ((req, _arrived), range) in batch.requests.iter().zip(&batch.ranges) {
                // finalization order: deliver, then gauges, then journal
                // completion — a crash mid-sequence replays the delivery
                // (idempotent) instead of double-decrementing the gauge
                if let Some(ReplySink::Gather(g)) = reply_of.remove(&sink_key(req.id, Some(s))) {
                    let partial = neighbors[range.0..range.1].to_vec();
                    deliver_partial(
                        &g,
                        s,
                        partial,
                        service_seconds,
                        ctx.worker_id,
                        &ctx.metrics,
                        &exec,
                        &mut ctx.tracer,
                    );
                }
                ctx.inflight.fetch_sub(1, Ordering::SeqCst);
                ctx.complete(req.id, Some(s));
            }
            ctx.crashing_keys.clear();
            ctx.beat();
            continue;
        }

        // direct leg: the fence is a lower bound — catch up if behind
        // (serving at a newer prefix is within the visibility contract
        // for requests that raced an insert)
        let fence_start = clock::now();
        registry.catch_up_to(batch.fence, &ctx.log, &ctx.metrics);
        let fence_end = clock::now();
        match path {
            RoutePath::Rt => Metrics::add(&ctx.metrics.rt_requests, batch.requests.len() as u64),
            RoutePath::Brute | RoutePath::BruteCpu => {
                Metrics::add(&ctx.metrics.brute_requests, batch.requests.len() as u64)
            }
        }
        let index = registry.get(path, &ctx.metrics, &mut ctx.tracer);
        let res = index.knn(&all_queries, batch.k);
        // refresh the gauge: queries only refit, but staying at the
        // index's own count keeps the claim honest if that ever changes
        ctx.metrics
            .set_route_builds(path, index.build_stats().counters.builds);
        let neighbors = res.neighbors;
        let rounds = res.rounds;
        let svc_end = clock::now();
        let service_seconds = svc_end.saturating_duration_since(served).as_secs_f64();
        {
            let wm = &ctx.metrics.workers[ctx.worker_id];
            wm.hist_fence
                .record(fence_end.saturating_duration_since(fence_start).as_nanos() as u64);
            wm.hist_service
                .record(svc_end.saturating_duration_since(served).as_nanos() as u64);
        }
        // span emission after the batch is computed (see the sharded
        // path above for the result-transparency argument)
        if let Some(sink) = ctx.tracer.as_mut() {
            let served_ns = sink.ns_since_epoch(served);
            let fence_start_ns = sink.ns_since_epoch(fence_start);
            let fence_end_ns = sink.ns_since_epoch(fence_end);
            let svc_end_ns = sink.ns_since_epoch(svc_end);
            let worker = sink.worker();
            for (req, arrived) in &batch.requests {
                let qw = sink.next_id();
                sink.push(SpanRecord {
                    trace: req.id,
                    span: qw,
                    parent: 0,
                    name: span_names::QUEUE_WAIT.to_string(),
                    worker,
                    start_ns: sink.ns_since_epoch(*arrived),
                    end_ns: served_ns,
                    attrs: Vec::new(),
                });
                let fs = sink.next_id();
                sink.push(SpanRecord {
                    trace: req.id,
                    span: fs,
                    parent: 0,
                    name: span_names::FENCE_CATCHUP.to_string(),
                    worker,
                    start_ns: fence_start_ns,
                    end_ns: fence_end_ns,
                    attrs: vec![("fence".to_string(), batch.fence as f64)],
                });
                let svc = sink.next_id();
                sink.push(SpanRecord {
                    trace: req.id,
                    span: svc,
                    parent: 0,
                    name: span_names::SERVICE.to_string(),
                    worker,
                    start_ns: served_ns,
                    end_ns: svc_end_ns,
                    attrs: vec![
                        ("fence".to_string(), batch.fence as f64),
                        ("batch".to_string(), seq as f64),
                    ],
                });
                push_round_spans(sink, req.id, svc, served_ns, svc_end_ns, &rounds);
            }
        }
        if let Some(ms) = delay {
            std::thread::sleep(Duration::from_millis(ms));
        }

        for ((req, arrived), range) in batch.requests.iter().zip(&batch.ranges) {
            let e2e = clock::now().saturating_duration_since(*arrived);
            let latency = e2e.as_secs_f64();
            ctx.metrics.workers[ctx.worker_id]
                .hist_e2e
                .record(e2e.as_nanos() as u64);
            ctx.metrics.record_latency(latency);
            Metrics::inc(&ctx.metrics.responses);
            Metrics::add(&ctx.metrics.queries_served, req.queries.len() as u64);
            // finalization order: reply, then gauge, then journal
            // completion — a crash mid-sequence re-sends a reply the
            // client already has (harmlessly buffered) instead of
            // double-decrementing the inflight gauge
            if let Some(ReplySink::Direct(reply)) = reply_of.remove(&sink_key(req.id, None)) {
                let _ = reply.send(Ok(KnnResponse {
                    id: req.id,
                    neighbors: neighbors[range.0..range.1].to_vec(),
                    path,
                    service_seconds,
                    latency_seconds: latency,
                }));
            }
            if let Some(sink) = ctx.tracer.as_mut() {
                sink.event(
                    req.id,
                    span_names::REPLY,
                    vec![("queries".to_string(), req.queries.len() as f64)],
                );
            }
            ctx.inflight.fetch_sub(1, Ordering::SeqCst);
            ctx.complete(req.id, None);
        }
        ctx.crashing_keys.clear();
        ctx.beat();
    }
}

/// Synthesize one [`span_names::ROUND`] child span per TrueKNN
/// expansion round under `parent`. Durations are each round's
/// wall-clock share laid end to end from the parent's start; the
/// convergence attributes (radius, query/survivor counts, heap pushes)
/// are the deterministic per-round counters verbatim, so a profile
/// reconstructed from the trace matches [`crate::knn::HwCounters`]
/// exactly.
fn push_round_spans(
    sink: &mut SpanSink,
    trace: u64,
    parent: u64,
    start_ns: u64,
    end_ns: u64,
    rounds: &[RoundStats],
) {
    let mut cursor = start_ns;
    for r in rounds {
        let dur = (r.wall_seconds * 1e9) as u64;
        let round_end = cursor.saturating_add(dur).min(end_ns.max(cursor));
        let span = sink.next_id();
        let worker = sink.worker();
        sink.push(SpanRecord {
            trace,
            span,
            parent,
            name: span_names::ROUND.to_string(),
            worker,
            start_ns: cursor,
            end_ns: round_end,
            attrs: vec![
                ("round".to_string(), r.round as f64),
                ("radius".to_string(), f64::from(r.radius)),
                ("queries".to_string(), r.queries as f64),
                ("survivors".to_string(), r.survivors as f64),
                ("heap_pushes".to_string(), r.heap_pushes as f64),
            ],
        });
        cursor = round_end;
    }
}

/// Remap shard-local neighbor ids to global ones, fanned per query
/// list across the exec engine. Pure elementwise table lookup, so the
/// parallel fan cannot change the result.
fn remap_global(neighbors: &mut [Vec<Neighbor>], ids: &[u32], exec: &Executor) {
    exec.for_each_chunk(neighbors, PAR_QUERY_MIN, |_, chunk| {
        for list in chunk.iter_mut() {
            for n in list.iter_mut() {
                n.idx = ids[n.idx as usize];
            }
        }
    });
}

/// Merge one shard's partial into the gather accumulator **as it
/// arrives** — no shard waits for the set to complete before its work
/// is folded in, so the old O(queries·k·S) single-pass merge on
/// whichever worker delivered last is gone. The pairwise merge is
/// fanned per query across the exec engine; keep-k-smallest under the
/// strict `(distance, id)` total order is associative and commutative,
/// so the accumulator is bitwise independent of delivery order.
/// Delivery is **idempotent**: `merged[shard]` gates both the merge
/// and the per-shard query accounting, so a duplicate partial (owner
/// recovered after the monitor re-dispatched its leg) neither
/// re-merges nor double-counts `shard_queries`.
#[allow(clippy::too_many_arguments)] // one call site; a struct would only rename the coupling
pub(super) fn deliver_partial(
    g: &Gather,
    shard: usize,
    mut partial: Vec<Vec<Neighbor>>,
    service_seconds: f64,
    worker_id: usize,
    metrics: &Arc<Metrics>,
    exec: &Executor,
    tracer: &mut Option<SpanSink>,
) {
    let merge_start = clock::now();
    let done = {
        // poisoned only if a sibling delivery panicked; the merges it
        // already folded in are still exactly the data we need
        let mut st = g
            .state
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner);
        if st.reply.is_none() {
            // completed (or failed) before this duplicate landed
            return;
        }
        if st.merged[shard] {
            // duplicate delivery: already merged and already counted
            return;
        }
        st.merged[shard] = true;
        st.merged_count += 1;
        // counted on first delivery, keyed by (request, shard) via the
        // merged flag — not at batch serve time, where a failover
        // re-dispatch would tally the same shard's work twice
        Metrics::add(&metrics.shard_queries[shard], partial.len() as u64);
        let k = g.k;
        exec.for_each_chunk2(&mut st.acc, &mut partial, PAR_QUERY_MIN, |_, acc, part| {
            for (dst, src) in acc.iter_mut().zip(part.iter()) {
                merge_topk(dst, src, k);
            }
        });
        st.service_seconds = st.service_seconds.max(service_seconds);
        if st.merged_count < st.merged.len() {
            None
        } else {
            // last shard in: the finished accumulator and the reply
            // move out with us; the send runs off the lock
            let neighbors = std::mem::take(&mut st.acc);
            let slowest = st.service_seconds;
            st.reply.take().map(|reply| (neighbors, slowest, reply))
        }
    };
    // the early returns above exit on duplicate/completed deliveries,
    // so everything below only runs for a partial that really merged
    let merge_end = clock::now();
    let wm = &metrics.workers[worker_id];
    wm.hist_merge
        .record(merge_end.saturating_duration_since(merge_start).as_nanos() as u64);
    if let Some(sink) = tracer.as_mut() {
        let span = sink.next_id();
        let worker = sink.worker();
        sink.push(SpanRecord {
            trace: g.id,
            span,
            parent: 0,
            name: span_names::GATHER_MERGE.to_string(),
            worker,
            start_ns: sink.ns_since_epoch(merge_start),
            end_ns: sink.ns_since_epoch(merge_end),
            attrs: vec![("shard".to_string(), shard as f64)],
        });
    }
    let Some((neighbors, service_seconds, reply)) = done else {
        return;
    };
    let n_queries = neighbors.len();
    let e2e = clock::now().saturating_duration_since(g.submitted);
    let latency = e2e.as_secs_f64();
    wm.hist_e2e.record(e2e.as_nanos() as u64);
    metrics.record_latency(latency);
    Metrics::inc(&metrics.responses);
    Metrics::add(&metrics.queries_served, n_queries as u64);
    Metrics::add(&metrics.rt_requests, 1);
    let _ = reply.send(Ok(KnnResponse {
        id: g.id,
        neighbors,
        path: g.path,
        service_seconds,
        latency_seconds: latency,
    }));
    if let Some(sink) = tracer.as_mut() {
        sink.event(
            g.id,
            span_names::REPLY,
            vec![("queries".to_string(), n_queries as f64)],
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::DatasetKind;
    use crate::knn::kdtree::KdTree;

    #[test]
    fn service_round_trip_exact() {
        let ds = DatasetKind::Uniform.generate(2_000, 70);
        let queries: Vec<Point3> = ds.points[..32].to_vec();
        let (svc, handle) = Service::start(ds.points.clone(), ServiceConfig::default());
        let resp = handle
            .query(KnnRequest::new(1, queries.clone(), 4))
            .unwrap();
        assert_eq!(resp.neighbors.len(), 32);
        let tree = KdTree::build(&ds.points);
        for (q, got) in queries.iter().zip(&resp.neighbors) {
            let want = tree.knn(*q, 4);
            assert_eq!(got.len(), 4);
            for (g, w) in got.iter().zip(&want) {
                assert!((g.dist - w.dist).abs() < 1e-5);
            }
        }
        svc.shutdown();
    }

    #[test]
    fn concurrent_clients_all_served() {
        let ds = DatasetKind::Uniform.generate(3_000, 71);
        let (svc, handle) = Service::start(ds.points.clone(), ServiceConfig::default());
        let mut joins = Vec::new();
        for t in 0..4u64 {
            let h = handle.clone();
            let pts = ds.points.clone();
            joins.push(std::thread::spawn(move || {
                for i in 0..5u64 {
                    let id = t * 100 + i;
                    let qs = pts[(id as usize * 7) % 1000..][..8].to_vec();
                    let resp = h.query(KnnRequest::new(id, qs, 3)).unwrap();
                    assert_eq!(resp.id, id);
                    assert_eq!(resp.neighbors.len(), 8);
                    assert!(resp.neighbors.iter().all(|n| n.len() == 3));
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        let m = handle.metrics().snapshot();
        assert_eq!(m.responses, 20);
        assert_eq!(m.rejected, 0);
        assert_eq!(m.queries_served, 160);
        svc.shutdown();
    }

    #[test]
    fn explicit_rt_mode_routes_rt() {
        let ds = DatasetKind::Uniform.generate(2_500, 72);
        let (svc, handle) = Service::start(ds.points.clone(), ServiceConfig::default());
        let resp = handle
            .query(KnnRequest::new(9, ds.points[..4].to_vec(), 2).with_mode(QueryMode::Rt))
            .unwrap();
        assert_eq!(resp.path, RoutePath::Rt);
        let m = handle.metrics().snapshot();
        assert_eq!(m.rt_requests, 1);
        svc.shutdown();
    }

    use super::super::request::QueryMode;

    #[test]
    fn submit_rejects_degenerate_requests_with_typed_errors() {
        let ds = DatasetKind::Uniform.generate(1_000, 79);
        let (svc, handle) = Service::start(ds.points.clone(), ServiceConfig::default());
        // k = 0
        let err = handle
            .submit(KnnRequest::new(1, ds.points[..2].to_vec(), 0))
            .unwrap_err();
        assert!(matches!(err, ServiceError::InvalidRequest(_)), "{err}");
        // empty query batch
        let err = handle.submit(KnnRequest::new(2, Vec::new(), 3)).unwrap_err();
        assert!(matches!(err, ServiceError::InvalidRequest(_)), "{err}");
        // non-finite coordinate
        let err = handle
            .submit(KnnRequest::new(3, vec![Point3::new(0.0, f32::NAN, 0.0)], 3))
            .unwrap_err();
        assert!(matches!(err, ServiceError::InvalidRequest(_)), "{err}");
        // degenerate inserts
        assert!(handle.insert(&[]).is_err());
        assert!(handle.insert(&[Point3::new(f32::INFINITY, 0.0, 0.0)]).is_err());
        // none of it touched the pool
        let m = handle.metrics().snapshot();
        assert_eq!(m.requests, 0);
        assert_eq!(m.inserts, 0);
        // a well-formed request still round-trips
        let resp = handle.query(KnnRequest::new(4, ds.points[..2].to_vec(), 3)).unwrap();
        assert_eq!(resp.id, 4);
        svc.shutdown();
    }

    #[test]
    fn submit_after_shutdown_is_a_typed_shutdown_error() {
        let ds = DatasetKind::Uniform.generate(1_000, 80);
        let (svc, handle) = Service::start(ds.points.clone(), ServiceConfig::default());
        svc.shutdown();
        let err = handle
            .submit(KnnRequest::new(1, ds.points[..2].to_vec(), 2))
            .unwrap_err();
        assert_eq!(err, ServiceError::ShutDown);
        assert_eq!(handle.insert(&[Point3::ZERO]).unwrap_err(), ServiceError::ShutDown);
    }

    #[test]
    fn serving_many_batches_builds_one_index() {
        // the tentpole claim: N batches against one dataset = exactly 1
        // acceleration-structure build (the seed rebuilt the BVH per batch)
        let ds = DatasetKind::Taxi.generate(3_000, 74);
        let (svc, handle) = Service::start(ds.points.clone(), ServiceConfig::default());
        let n_batches = 6u64;
        for id in 0..n_batches {
            let q = ds.points[(id as usize * 31) % 2000..][..8].to_vec();
            // query() waits for the response, so every request is its own batch
            let resp = handle
                .query(KnnRequest::new(id, q, 4).with_mode(QueryMode::Rt))
                .unwrap();
            assert_eq!(resp.path, RoutePath::Rt);
            assert!(resp.neighbors.iter().all(|n| n.len() == 4));
        }
        let m = handle.metrics().snapshot();
        assert_eq!(m.batches, n_batches);
        assert_eq!(m.builds, 1, "BVH must be built once, not once per batch");
        assert_eq!(m.builds_of(RoutePath::Rt), 1);
        svc.shutdown();
    }

    #[test]
    fn mixed_mode_submissions_route_per_mode() {
        // regression for the old behavior where a whole batch followed
        // requests[0]'s mode: submit an interleaved burst and check every
        // response took the path its own request asked for
        let ds = DatasetKind::Uniform.generate(2_500, 75);
        let (svc, handle) = Service::start(ds.points.clone(), ServiceConfig::default());
        let mut rxs = Vec::new();
        for id in 0..12u64 {
            let mode = if id % 2 == 0 { QueryMode::Rt } else { QueryMode::Brute };
            let q = ds.points[(id as usize * 13) % 2000..][..4].to_vec();
            rxs.push((
                id,
                mode,
                handle
                    .submit(KnnRequest::new(id, q, 3).with_mode(mode))
                    .unwrap(),
            ));
        }
        for (id, mode, rx) in rxs {
            let resp = rx.recv().unwrap().unwrap();
            assert_eq!(resp.id, id);
            let want = match mode {
                QueryMode::Rt => RoutePath::Rt,
                // no PJRT in this config: Brute lands on the CPU path
                QueryMode::Brute => RoutePath::BruteCpu,
                QueryMode::Auto => unreachable!(),
            };
            assert_eq!(resp.path, want, "request {id} mis-routed");
            assert!(resp.neighbors.iter().all(|n| n.len() == 3));
        }
        let m = handle.metrics().snapshot();
        assert_eq!(m.rt_requests, 6);
        assert_eq!(m.brute_requests, 6);
        svc.shutdown();
    }

    #[test]
    fn shutdown_serves_queued_work() {
        let ds = DatasetKind::Uniform.generate(1_000, 73);
        let (svc, handle) = Service::start(ds.points.clone(), ServiceConfig::default());
        let rx = handle
            .submit(KnnRequest::new(1, ds.points[..4].to_vec(), 2))
            .unwrap();
        svc.shutdown();
        let resp = rx
            .recv()
            .expect("queued request must still be answered")
            .expect("and answered with a response, not a typed failure");
        assert_eq!(resp.id, 1);
    }

    #[test]
    fn pool_spreads_routes_across_workers() {
        // with 2 workers the rendezvous hash puts Rt and BruteCpu on
        // different workers (pinned by Router::worker_for); per-worker
        // batch counters must show both of them working
        let ds = DatasetKind::Uniform.generate(2_500, 76);
        let cfg = ServiceConfig {
            workers: 2,
            ..Default::default()
        };
        let (svc, handle) = Service::start(ds.points.clone(), cfg);
        assert_eq!(handle.workers(), 2);
        let w_rt = Router::worker_for(RoutePath::Rt, 2);
        let w_cpu = Router::worker_for(RoutePath::BruteCpu, 2);
        assert_ne!(w_rt, w_cpu, "2-worker pool must split the test routes");
        for id in 0..6u64 {
            let mode = if id % 2 == 0 { QueryMode::Rt } else { QueryMode::Brute };
            let q = ds.points[(id as usize * 11) % 2000..][..4].to_vec();
            let resp = handle.query(KnnRequest::new(id, q, 3).with_mode(mode)).unwrap();
            assert_eq!(resp.id, id);
        }
        let m = handle.metrics().snapshot();
        assert_eq!(m.workers.len(), 2);
        assert!(m.workers[w_rt].batches >= 1, "Rt owner served nothing");
        assert!(m.workers[w_cpu].batches >= 1, "BruteCpu owner served nothing");
        assert_eq!(m.workers[w_rt].rejected + m.workers[w_cpu].rejected, 0);
        assert!(m.workers[w_rt].queue_hwm >= 1);
        svc.shutdown();
    }

    #[test]
    fn sharded_route_round_trip_exact() {
        // smoke test of the scatter-gather plumbing: a 2-shard RT route
        // on a 4-worker pool answers exactly like the kd-tree oracle
        let ds = DatasetKind::Uniform.generate(2_400, 78);
        let cfg = ServiceConfig {
            workers: 4,
            shards: 2,
            ..Default::default()
        };
        let (svc, handle) = Service::start(ds.points.clone(), cfg);
        assert_eq!(handle.workers(), 4, "sharded pool must not cap at 3");
        let queries: Vec<Point3> = ds.points[..24].to_vec();
        let resp = handle
            .query(KnnRequest::new(1, queries.clone(), 4).with_mode(QueryMode::Rt))
            .unwrap();
        assert_eq!(resp.path, RoutePath::Rt);
        assert_eq!(resp.neighbors.len(), 24);
        let tree = KdTree::build(&ds.points);
        for (q, got) in queries.iter().zip(&resp.neighbors) {
            let want = tree.knn(*q, 4);
            assert_eq!(got.len(), 4);
            for (g, w) in got.iter().zip(&want) {
                assert!((g.dist - w.dist).abs() < 1e-5);
            }
        }
        let m = handle.metrics().snapshot();
        assert_eq!(m.shard_builds, vec![1, 1], "one build per shard");
        assert_eq!(m.shard_queries.iter().sum::<u64>(), 48, "24 queries × 2 shards");
        assert_eq!(
            m.builds_of(RoutePath::Rt),
            2,
            "route gauge must surface the per-shard builds"
        );
        assert_eq!(m.responses, 1);
        assert_eq!(m.rt_requests, 1);
        svc.shutdown();
    }

    #[test]
    fn insert_is_visible_to_later_queries_on_every_route() {
        let ds = DatasetKind::Uniform.generate(2_200, 77);
        let cfg = ServiceConfig {
            workers: 2,
            ..Default::default()
        };
        let (svc, handle) = Service::start(ds.points.clone(), cfg);
        // prime both routes so the insert exercises built indexes too
        for (id, mode) in [(1u64, QueryMode::Rt), (2, QueryMode::Brute)] {
            handle
                .query(KnnRequest::new(id, ds.points[..4].to_vec(), 2).with_mode(mode))
                .unwrap();
        }
        // a far-away cluster the base dataset cannot explain
        let extra: Vec<Point3> = (0..8)
            .map(|i| Point3::new(10.0 + i as f32 * 1e-3, 10.0, 10.0))
            .collect();
        handle.insert(&extra).unwrap();
        assert_eq!(handle.data_len(), 2_200 + 8);
        for (id, mode) in [(3u64, QueryMode::Rt), (4, QueryMode::Brute)] {
            let resp = handle
                .query(KnnRequest::new(id, vec![Point3::splat(10.0)], 3).with_mode(mode))
                .unwrap();
            for n in &resp.neighbors[0] {
                assert!(
                    n.idx as usize >= 2_200,
                    "{mode:?} query near the inserted cluster found base point {}",
                    n.idx
                );
            }
        }
        let m = handle.metrics().snapshot();
        assert_eq!(m.inserts, 1);
        assert_eq!(m.points_inserted, 8);
        // the insert refit the Rt structure; it must not have rebuilt
        assert_eq!(m.builds_of(RoutePath::Rt), 1);
        svc.shutdown();
    }
}
