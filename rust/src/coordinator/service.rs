//! The query service: a **pool** of worker threads, each owning the
//! persistent [`NeighborIndex`]es for a disjoint shard of route paths,
//! fed through per-worker bounded queues with backpressure.
//!
//! This is where the paper's amortization argument pays off at the
//! serving layer: the owning worker builds each route's acceleration
//! structure **once per dataset** (tracked by the per-route build gauge)
//! and every batch after that only refits/queries it. Before the index
//! API, every batch paid a full BVH build; before the pool, batches from
//! one queue never overlapped.
//!
//! Pool architecture:
//!
//! - **Routing at submit time.** [`ServiceHandle::submit`] routes the
//!   request ([`Router::route`]) and picks the owning worker by
//!   rendezvous hashing ([`Router::worker_for`]) — a pure function of
//!   `(route, pool size)`, so a route's index is built exactly once, on
//!   exactly one worker, and never migrates.
//! - **Per-worker queues.** Each worker has its own bounded queue
//!   (`queue_depth` slots each); rejects, live depth and the high-water
//!   mark are accounted per worker in [`Metrics`]. Requests for one
//!   route keep their submit order (single queue, FIFO), which is what
//!   makes replays deterministic.
//! - **Two-level parallelism.** Workers serve batches concurrently
//!   (batch-level), and each worker's per-batch traversal fans out
//!   across the [`crate::exec`] engine threads (launch-level,
//!   `ServiceConfig::trueknn.threads`, 0 = all cores). Per-request
//!   results depend only on the request and the route's index state —
//!   never on batch composition or thread count — so responses are
//!   bitwise-identical to a `workers = 1` service by the engine's
//!   determinism contract.
//! - **Inserts are barriers.** [`ServiceHandle::insert`] broadcasts the
//!   points to every worker; a worker drains its pending batches before
//!   applying them, so a query observes exactly the inserts submitted
//!   before it — at any pool size.
//!
//! The PJRT client wraps raw C pointers and is not `Send`, so the
//! runtime (and every index) is constructed *inside* the worker that
//! owns the Brute route; `Service::start` waits for a readiness
//! handshake from each worker so the handle's router knows up front
//! whether the PJRT path exists.

use super::batcher::{BatcherConfig, DynamicBatcher};
use super::metrics::Metrics;
use super::request::{KnnRequest, KnnResponse, RoutePath};
use super::router::{Router, RouterConfig};
use crate::geom::Point3;
use crate::index::{BruteCpuIndex, BrutePjrtIndex, IndexConfig, NeighborIndex, TrueKnnIndex};
use crate::knn::TrueKnnParams;
use crate::runtime::PjrtRuntime;
use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, Sender, SyncSender, TrySendError};
use std::sync::Arc;
use std::time::Instant;

#[derive(Clone, Debug)]
pub struct ServiceConfig {
    pub batcher: BatcherConfig,
    pub router: RouterConfig,
    /// Pool size: worker threads, each owning a disjoint shard of route
    /// paths (0 = all available cores). Capped at
    /// [`RoutePath::COUNT`] — a worker beyond that could never own a
    /// route, yet would still replicate every insert.
    pub workers: usize,
    /// Bounded queue depth **per worker**; submits beyond it are
    /// rejected (backpressure).
    pub queue_depth: usize,
    /// Try to load PJRT artifacts in the owning worker (falls back to
    /// CPU brute).
    pub use_pjrt: bool,
    pub trueknn: TrueKnnParams,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self {
            batcher: BatcherConfig::default(),
            router: RouterConfig::default(),
            workers: 0,
            queue_depth: 256,
            use_pjrt: false,
            trueknn: TrueKnnParams {
                exclude_self: false, // service queries are external points
                ..Default::default()
            },
        }
    }
}

#[derive(Debug, PartialEq, Eq)]
pub enum ServiceError {
    QueueFull,
    ShutDown,
}

impl std::fmt::Display for ServiceError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServiceError::QueueFull => write!(f, "service queue full (backpressure)"),
            ServiceError::ShutDown => write!(f, "service is shut down"),
        }
    }
}

impl std::error::Error for ServiceError {}

enum Msg {
    Request(KnnRequest, RoutePath, Sender<KnnResponse>, Instant),
    /// Broadcast to every worker; applied between batches.
    Insert(Arc<Vec<Point3>>),
    Shutdown,
}

/// Handle returned by `Service::start`; cheap to clone, submits requests.
#[derive(Clone)]
pub struct ServiceHandle {
    txs: Arc<Vec<SyncSender<Msg>>>,
    router: Arc<Router>,
    /// Indexed points (base + inserts) — the `n` of the routing policy.
    data_len: Arc<AtomicUsize>,
    /// Serializes insert broadcasts: concurrent inserts must reach every
    /// worker's queue in one global order, or the workers' views of the
    /// data (and point ids) would fork per route.
    insert_lock: Arc<std::sync::Mutex<()>>,
    metrics: Arc<Metrics>,
    inflight: Arc<AtomicUsize>,
}

impl ServiceHandle {
    /// Submit a request; returns the response channel. Routes the
    /// request to its owning worker and applies backpressure by
    /// rejecting when that worker's queue is full.
    pub fn submit(&self, req: KnnRequest) -> Result<Receiver<KnnResponse>, ServiceError> {
        let (tx, rx) = std::sync::mpsc::channel();
        Metrics::inc(&self.metrics.requests);
        let path = self.router.route(&req, self.data_len.load(Ordering::SeqCst));
        let w = Router::worker_for(path, self.txs.len());
        let wm = &self.metrics.workers[w];
        // depth is incremented *before* the send so the worker-side
        // decrement can never observe it missing (no underflow); the
        // high-water mark is recorded only for accepted messages, and is
        // best-effort under contention (see its doc in WorkerMetrics)
        let depth = wm.queue_depth.fetch_add(1, Ordering::SeqCst) + 1;
        match self.txs[w].try_send(Msg::Request(req, path, tx, Instant::now())) {
            Ok(()) => {
                wm.queue_hwm.fetch_max(depth, Ordering::SeqCst);
                Metrics::inc(&wm.submitted);
                self.inflight.fetch_add(1, Ordering::SeqCst);
                Ok(rx)
            }
            Err(TrySendError::Full(_)) => {
                wm.queue_depth.fetch_sub(1, Ordering::SeqCst);
                Metrics::inc(&self.metrics.rejected);
                Metrics::inc(&wm.rejected);
                Err(ServiceError::QueueFull)
            }
            Err(TrySendError::Disconnected(_)) => {
                wm.queue_depth.fetch_sub(1, Ordering::SeqCst);
                Err(ServiceError::ShutDown)
            }
        }
    }

    /// Submit and wait for the response.
    pub fn query(&self, req: KnnRequest) -> Result<KnnResponse, ServiceError> {
        let rx = self.submit(req)?;
        rx.recv().map_err(|_| ServiceError::ShutDown)
    }

    /// Add points to the served dataset: broadcast to every worker, each
    /// of which updates its own indexes between batches. Uses a blocking
    /// send (never rejected) — inserts are rare, and dropping one on a
    /// full queue would silently fork the workers' views of the data.
    ///
    /// Ordering contract: queries **submitted** after `insert` returns
    /// observe the new points on every route; queries submitted before
    /// it may or may not, exactly as with a single worker.
    pub fn insert(&self, points: &[Point3]) -> Result<(), ServiceError> {
        if points.is_empty() {
            return Ok(());
        }
        let pts = Arc::new(points.to_vec());
        // one global insert order across all workers: without the lock,
        // two concurrent inserts could land as [A, B] on one worker and
        // [B, A] on another, forking point ids between routes
        let _broadcast = self.insert_lock.lock().unwrap();
        for (w, tx) in self.txs.iter().enumerate() {
            let wm = &self.metrics.workers[w];
            let depth = wm.queue_depth.fetch_add(1, Ordering::SeqCst) + 1;
            if tx.send(Msg::Insert(pts.clone())).is_err() {
                wm.queue_depth.fetch_sub(1, Ordering::SeqCst);
                return Err(ServiceError::ShutDown);
            }
            wm.queue_hwm.fetch_max(depth, Ordering::SeqCst);
            Metrics::inc(&wm.submitted);
        }
        self.data_len.fetch_add(points.len(), Ordering::SeqCst);
        Metrics::inc(&self.metrics.inserts);
        Metrics::add(&self.metrics.points_inserted, points.len() as u64);
        Ok(())
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    pub fn inflight(&self) -> usize {
        self.inflight.load(Ordering::SeqCst)
    }

    /// Pool size (resolved, never 0).
    pub fn workers(&self) -> usize {
        self.txs.len()
    }

    /// Points currently served (base dataset + accepted inserts).
    pub fn data_len(&self) -> usize {
        self.data_len.load(Ordering::SeqCst)
    }
}

/// The service: owns the worker pool; dropping shuts it down.
pub struct Service {
    handle: ServiceHandle,
    workers: Vec<std::thread::JoinHandle<()>>,
    txs: Vec<SyncSender<Msg>>,
}

impl Service {
    /// Start the pool over a fixed dataset. Blocks until every worker
    /// has reported ready (and the Brute owner has resolved PJRT
    /// availability), so routing decisions are stable from the first
    /// submit.
    pub fn start(data: Vec<Point3>, cfg: ServiceConfig) -> (Service, ServiceHandle) {
        let requested = if cfg.workers == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            cfg.workers
        };
        // only RoutePath::COUNT distinct owners can ever exist; extra
        // workers would idle forever while still replicating inserts
        let n_workers = requested.clamp(1, RoutePath::COUNT);
        let metrics = Arc::new(Metrics::with_workers(n_workers));
        let inflight = Arc::new(AtomicUsize::new(0));
        let base = Arc::new(data);
        let (ready_tx, ready_rx) = sync_channel::<bool>(n_workers);
        let mut txs = Vec::with_capacity(n_workers);
        let mut workers = Vec::with_capacity(n_workers);
        for w in 0..n_workers {
            let (tx, rx) = sync_channel::<Msg>(cfg.queue_depth);
            let worker_base = base.clone();
            let worker_cfg = cfg.clone();
            let worker_ready = ready_tx.clone();
            let worker_metrics = metrics.clone();
            let worker_inflight = inflight.clone();
            workers.push(std::thread::spawn(move || {
                worker_loop(
                    w,
                    n_workers,
                    worker_base,
                    worker_cfg,
                    rx,
                    worker_ready,
                    worker_metrics,
                    worker_inflight,
                );
            }));
            txs.push(tx);
        }
        drop(ready_tx);
        let mut pjrt_available = false;
        for _ in 0..n_workers {
            pjrt_available |= ready_rx.recv().unwrap_or(false);
        }
        let mut router_cfg = cfg.router.clone();
        router_cfg.pjrt_available = pjrt_available;
        let handle = ServiceHandle {
            txs: Arc::new(txs.clone()),
            router: Arc::new(Router::new(router_cfg)),
            data_len: Arc::new(AtomicUsize::new(base.len())),
            insert_lock: Arc::new(std::sync::Mutex::new(())),
            metrics,
            inflight,
        };
        (
            Service {
                handle: handle.clone(),
                workers,
                txs,
            },
            handle,
        )
    }

    pub fn handle(&self) -> ServiceHandle {
        self.handle.clone()
    }

    pub fn shutdown(mut self) {
        self.shutdown_and_join();
        // Drop runs next but finds the pool already drained: exactly one
        // Msg::Shutdown is ever sent per worker.
    }

    /// Shared by `shutdown` and `Drop`: signal every worker once and
    /// wait for all of them to drain. Idempotent — draining `workers`
    /// makes a second call a no-op.
    fn shutdown_and_join(&mut self) {
        if self.workers.is_empty() {
            return;
        }
        for tx in &self.txs {
            let _ = tx.send(Msg::Shutdown);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for Service {
    fn drop(&mut self) {
        self.shutdown_and_join();
    }
}

/// Per-worker index registry: one persistent [`NeighborIndex`] per
/// **owned** route path, built lazily on first use (the PJRT one eagerly
/// in the owning worker, because the router must know up front whether
/// that path exists).
///
/// The base dataset is shared read-only across the pool (`Arc`); a
/// worker only materializes its own copy inside the indexes it actually
/// builds, so idle workers cost no dataset memory.
struct IndexRegistry {
    base: Arc<Vec<Point3>>,
    /// Points inserted after start, in arrival order.
    extra: Vec<Point3>,
    trueknn: TrueKnnParams,
    by_path: HashMap<RoutePath, Box<dyn NeighborIndex>>,
}

impl IndexRegistry {
    fn new(base: Arc<Vec<Point3>>, cfg: &ServiceConfig) -> Self {
        IndexRegistry {
            base,
            extra: Vec::new(),
            trueknn: cfg.trueknn.clone(),
            by_path: HashMap::new(),
        }
    }

    /// Everything this registry indexes (base + inserts so far).
    fn full_data(&self) -> Vec<Point3> {
        self.base.iter().chain(self.extra.iter()).copied().collect()
    }

    /// Service queries are external points: never self-exclude. Brute
    /// scans inherit the service's launch-engine thread count so both
    /// routes get launch-level parallelism under batch-level parallelism.
    fn brute_config(&self) -> IndexConfig {
        IndexConfig {
            exclude_self: false,
            threads: self.trueknn.threads,
            ..Default::default()
        }
    }

    fn install(&mut self, path: RoutePath, index: Box<dyn NeighborIndex>, metrics: &Metrics) {
        metrics.set_route_builds(path, index.build_stats().counters.builds);
        self.by_path.insert(path, index);
    }

    /// The index serving `path`, building it on first use. The per-route
    /// build gauge tracks the index's build count — it stays at 1 across
    /// a serving session because every later batch on the same path
    /// reuses the structure.
    fn get(&mut self, path: RoutePath, metrics: &Metrics) -> &mut Box<dyn NeighborIndex> {
        if !self.by_path.contains_key(&path) {
            let data = self.full_data();
            let index: Box<dyn NeighborIndex> = match path {
                RoutePath::Rt => {
                    Box::new(TrueKnnIndex::new(data, self.trueknn.to_index_config()))
                }
                // Reached only if the eagerly-installed PJRT index is
                // missing (runtime load raced or failed): rebuild with
                // whatever runtime is available now.
                RoutePath::Brute => Box::new(BrutePjrtIndex::new(data, self.brute_config())),
                RoutePath::BruteCpu => Box::new(BruteCpuIndex::new(data, self.brute_config())),
            };
            self.install(path, index, metrics);
        }
        self.by_path.get_mut(&path).expect("just inserted")
    }

    /// Apply an insert to every already-built index (lazily-built ones
    /// pick the points up from `extra` at build time), refreshing the
    /// per-route build gauges in case an insert triggered a rebuild.
    fn apply_insert(&mut self, points: &[Point3], metrics: &Metrics) {
        self.extra.extend_from_slice(points);
        for (path, index) in self.by_path.iter_mut() {
            index.insert(points);
            metrics.set_route_builds(*path, index.build_stats().counters.builds);
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn worker_loop(
    worker_id: usize,
    n_workers: usize,
    base: Arc<Vec<Point3>>,
    cfg: ServiceConfig,
    rx: Receiver<Msg>,
    ready: SyncSender<bool>,
    metrics: Arc<Metrics>,
    inflight: Arc<AtomicUsize>,
) {
    let mut registry = IndexRegistry::new(base, &cfg);
    // PJRT runtime is constructed here: the client is not Send. Only the
    // worker that owns the Brute route loads it (eagerly, so the
    // readiness handshake can tell the router the path exists).
    let mut pjrt_available = false;
    if cfg.use_pjrt && Router::worker_for(RoutePath::Brute, n_workers) == worker_id {
        match PjrtRuntime::load_default() {
            Ok(rt) => {
                let index = BrutePjrtIndex::with_runtime(
                    registry.full_data(),
                    Some(rt),
                    registry.brute_config(),
                );
                registry.install(RoutePath::Brute, Box::new(index), &metrics);
                pjrt_available = true;
            }
            Err(e) => {
                crate::log_warn!("PJRT unavailable, brute falls back to CPU: {e}");
            }
        }
    }
    let _ = ready.send(pjrt_available);

    let mut batcher = DynamicBatcher::new(cfg.batcher.clone());
    // response channels ride alongside their request through the batcher
    let mut reply_of: HashMap<u64, Sender<KnnResponse>> = HashMap::new();

    'outer: loop {
        // block for the first message, then drain whatever else arrived
        match rx.recv() {
            Ok(msg) => {
                let keep = on_msg(
                    worker_id,
                    msg,
                    &mut registry,
                    &mut batcher,
                    &mut reply_of,
                    &metrics,
                    &inflight,
                );
                if !keep {
                    break 'outer;
                }
            }
            Err(_) => break 'outer,
        }
        while let Ok(msg) = rx.try_recv() {
            let keep = on_msg(
                worker_id,
                msg,
                &mut registry,
                &mut batcher,
                &mut reply_of,
                &metrics,
                &inflight,
            );
            if !keep {
                break 'outer;
            }
        }
        drain(worker_id, &mut registry, &mut batcher, &mut reply_of, &metrics, &inflight);
    }

    // Reconcile gauges for messages accepted behind the shutdown signal:
    // their replies are dropped (clients observe ShutDown on recv), but
    // queue depth and inflight must not stay overstated forever. A
    // submit that races past this sweep before the channel disconnects
    // can still leak one tick — the gauges are operator telemetry, not
    // invariants.
    let wm = &metrics.workers[worker_id];
    while let Ok(msg) = rx.try_recv() {
        match msg {
            Msg::Request(..) => {
                wm.queue_depth.fetch_sub(1, Ordering::SeqCst);
                inflight.fetch_sub(1, Ordering::SeqCst);
            }
            Msg::Insert(_) => {
                wm.queue_depth.fetch_sub(1, Ordering::SeqCst);
            }
            Msg::Shutdown => {}
        }
    }
}

/// Handle one queue message on the worker thread; returns `false` when
/// the worker should exit.
fn on_msg(
    worker_id: usize,
    msg: Msg,
    registry: &mut IndexRegistry,
    batcher: &mut DynamicBatcher,
    reply_of: &mut HashMap<u64, Sender<KnnResponse>>,
    metrics: &Arc<Metrics>,
    inflight: &Arc<AtomicUsize>,
) -> bool {
    let wm = &metrics.workers[worker_id];
    match msg {
        Msg::Request(req, path, reply, t) => {
            wm.queue_depth.fetch_sub(1, Ordering::SeqCst);
            reply_of.insert(req.id, reply);
            batcher.push(req, path, t);
            true
        }
        Msg::Insert(points) => {
            wm.queue_depth.fetch_sub(1, Ordering::SeqCst);
            // the insert is a barrier: everything submitted before it is
            // served against the pre-insert structures first
            drain(worker_id, registry, batcher, reply_of, metrics, inflight);
            registry.apply_insert(&points, metrics);
            Metrics::inc(&wm.inserts);
            true
        }
        Msg::Shutdown => {
            // serve what's queued, then exit
            drain(worker_id, registry, batcher, reply_of, metrics, inflight);
            false
        }
    }
}

fn drain(
    worker_id: usize,
    registry: &mut IndexRegistry,
    batcher: &mut DynamicBatcher,
    reply_of: &mut HashMap<u64, Sender<KnnResponse>>,
    metrics: &Arc<Metrics>,
    inflight: &Arc<AtomicUsize>,
) {
    while let Some(batch) = batcher.next_batch() {
        Metrics::inc(&metrics.batches);
        Metrics::inc(&metrics.workers[worker_id].batches);
        let served = Instant::now();
        let all_queries: Vec<Point3> = batch
            .requests
            .iter()
            .flat_map(|(r, _)| r.queries.iter().copied())
            .collect();

        // the batch carries its submit-time routing decision; the worker
        // never re-routes
        let path = batch.path;
        match path {
            RoutePath::Rt => Metrics::add(&metrics.rt_requests, batch.requests.len() as u64),
            RoutePath::Brute | RoutePath::BruteCpu => {
                Metrics::add(&metrics.brute_requests, batch.requests.len() as u64)
            }
        }
        let index = registry.get(path, metrics);
        let neighbors = index.knn(&all_queries, batch.k).neighbors;
        // refresh the gauge: queries only refit, but staying at the
        // index's own count keeps the claim honest if that ever changes
        metrics.set_route_builds(path, index.build_stats().counters.builds);
        let service_seconds = served.elapsed().as_secs_f64();

        for ((req, arrived), range) in batch.requests.iter().zip(&batch.ranges) {
            let latency = arrived.elapsed().as_secs_f64();
            metrics.record_latency(latency);
            Metrics::inc(&metrics.responses);
            Metrics::add(&metrics.queries_served, req.queries.len() as u64);
            inflight.fetch_sub(1, Ordering::SeqCst);
            if let Some(reply) = reply_of.remove(&req.id) {
                let _ = reply.send(KnnResponse {
                    id: req.id,
                    neighbors: neighbors[range.0..range.1].to_vec(),
                    path,
                    service_seconds,
                    latency_seconds: latency,
                });
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::DatasetKind;
    use crate::knn::kdtree::KdTree;

    #[test]
    fn service_round_trip_exact() {
        let ds = DatasetKind::Uniform.generate(2_000, 70);
        let queries: Vec<Point3> = ds.points[..32].to_vec();
        let (svc, handle) = Service::start(ds.points.clone(), ServiceConfig::default());
        let resp = handle
            .query(KnnRequest::new(1, queries.clone(), 4))
            .unwrap();
        assert_eq!(resp.neighbors.len(), 32);
        let tree = KdTree::build(&ds.points);
        for (q, got) in queries.iter().zip(&resp.neighbors) {
            let want = tree.knn(*q, 4);
            assert_eq!(got.len(), 4);
            for (g, w) in got.iter().zip(&want) {
                assert!((g.dist - w.dist).abs() < 1e-5);
            }
        }
        svc.shutdown();
    }

    #[test]
    fn concurrent_clients_all_served() {
        let ds = DatasetKind::Uniform.generate(3_000, 71);
        let (svc, handle) = Service::start(ds.points.clone(), ServiceConfig::default());
        let mut joins = Vec::new();
        for t in 0..4u64 {
            let h = handle.clone();
            let pts = ds.points.clone();
            joins.push(std::thread::spawn(move || {
                for i in 0..5u64 {
                    let id = t * 100 + i;
                    let qs = pts[(id as usize * 7) % 1000..][..8].to_vec();
                    let resp = h.query(KnnRequest::new(id, qs, 3)).unwrap();
                    assert_eq!(resp.id, id);
                    assert_eq!(resp.neighbors.len(), 8);
                    assert!(resp.neighbors.iter().all(|n| n.len() == 3));
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        let m = handle.metrics().snapshot();
        assert_eq!(m.responses, 20);
        assert_eq!(m.rejected, 0);
        assert_eq!(m.queries_served, 160);
        svc.shutdown();
    }

    #[test]
    fn explicit_rt_mode_routes_rt() {
        let ds = DatasetKind::Uniform.generate(2_500, 72);
        let (svc, handle) = Service::start(ds.points.clone(), ServiceConfig::default());
        let resp = handle
            .query(KnnRequest::new(9, ds.points[..4].to_vec(), 2).with_mode(QueryMode::Rt))
            .unwrap();
        assert_eq!(resp.path, RoutePath::Rt);
        let m = handle.metrics().snapshot();
        assert_eq!(m.rt_requests, 1);
        svc.shutdown();
    }

    use super::super::request::QueryMode;

    #[test]
    fn serving_many_batches_builds_one_index() {
        // the tentpole claim: N batches against one dataset = exactly 1
        // acceleration-structure build (the seed rebuilt the BVH per batch)
        let ds = DatasetKind::Taxi.generate(3_000, 74);
        let (svc, handle) = Service::start(ds.points.clone(), ServiceConfig::default());
        let n_batches = 6u64;
        for id in 0..n_batches {
            let q = ds.points[(id as usize * 31) % 2000..][..8].to_vec();
            // query() waits for the response, so every request is its own batch
            let resp = handle
                .query(KnnRequest::new(id, q, 4).with_mode(QueryMode::Rt))
                .unwrap();
            assert_eq!(resp.path, RoutePath::Rt);
            assert!(resp.neighbors.iter().all(|n| n.len() == 4));
        }
        let m = handle.metrics().snapshot();
        assert_eq!(m.batches, n_batches);
        assert_eq!(m.builds, 1, "BVH must be built once, not once per batch");
        assert_eq!(m.builds_of(RoutePath::Rt), 1);
        svc.shutdown();
    }

    #[test]
    fn mixed_mode_submissions_route_per_mode() {
        // regression for the old behavior where a whole batch followed
        // requests[0]'s mode: submit an interleaved burst and check every
        // response took the path its own request asked for
        let ds = DatasetKind::Uniform.generate(2_500, 75);
        let (svc, handle) = Service::start(ds.points.clone(), ServiceConfig::default());
        let mut rxs = Vec::new();
        for id in 0..12u64 {
            let mode = if id % 2 == 0 { QueryMode::Rt } else { QueryMode::Brute };
            let q = ds.points[(id as usize * 13) % 2000..][..4].to_vec();
            rxs.push((
                id,
                mode,
                handle
                    .submit(KnnRequest::new(id, q, 3).with_mode(mode))
                    .unwrap(),
            ));
        }
        for (id, mode, rx) in rxs {
            let resp = rx.recv().unwrap();
            assert_eq!(resp.id, id);
            let want = match mode {
                QueryMode::Rt => RoutePath::Rt,
                // no PJRT in this config: Brute lands on the CPU path
                QueryMode::Brute => RoutePath::BruteCpu,
                QueryMode::Auto => unreachable!(),
            };
            assert_eq!(resp.path, want, "request {id} mis-routed");
            assert!(resp.neighbors.iter().all(|n| n.len() == 3));
        }
        let m = handle.metrics().snapshot();
        assert_eq!(m.rt_requests, 6);
        assert_eq!(m.brute_requests, 6);
        svc.shutdown();
    }

    #[test]
    fn shutdown_serves_queued_work() {
        let ds = DatasetKind::Uniform.generate(1_000, 73);
        let (svc, handle) = Service::start(ds.points.clone(), ServiceConfig::default());
        let rx = handle
            .submit(KnnRequest::new(1, ds.points[..4].to_vec(), 2))
            .unwrap();
        svc.shutdown();
        let resp = rx.recv().expect("queued request must still be answered");
        assert_eq!(resp.id, 1);
    }

    #[test]
    fn pool_spreads_routes_across_workers() {
        // with 2 workers the rendezvous hash puts Rt and BruteCpu on
        // different workers (pinned by Router::worker_for); per-worker
        // batch counters must show both of them working
        let ds = DatasetKind::Uniform.generate(2_500, 76);
        let cfg = ServiceConfig {
            workers: 2,
            ..Default::default()
        };
        let (svc, handle) = Service::start(ds.points.clone(), cfg);
        assert_eq!(handle.workers(), 2);
        let w_rt = Router::worker_for(RoutePath::Rt, 2);
        let w_cpu = Router::worker_for(RoutePath::BruteCpu, 2);
        assert_ne!(w_rt, w_cpu, "2-worker pool must split the test routes");
        for id in 0..6u64 {
            let mode = if id % 2 == 0 { QueryMode::Rt } else { QueryMode::Brute };
            let q = ds.points[(id as usize * 11) % 2000..][..4].to_vec();
            let resp = handle.query(KnnRequest::new(id, q, 3).with_mode(mode)).unwrap();
            assert_eq!(resp.id, id);
        }
        let m = handle.metrics().snapshot();
        assert_eq!(m.workers.len(), 2);
        assert!(m.workers[w_rt].batches >= 1, "Rt owner served nothing");
        assert!(m.workers[w_cpu].batches >= 1, "BruteCpu owner served nothing");
        assert_eq!(m.workers[w_rt].rejected + m.workers[w_cpu].rejected, 0);
        assert!(m.workers[w_rt].queue_hwm >= 1);
        svc.shutdown();
    }

    #[test]
    fn insert_is_visible_to_later_queries_on_every_route() {
        let ds = DatasetKind::Uniform.generate(2_200, 77);
        let cfg = ServiceConfig {
            workers: 2,
            ..Default::default()
        };
        let (svc, handle) = Service::start(ds.points.clone(), cfg);
        // prime both routes so the insert exercises built indexes too
        for (id, mode) in [(1u64, QueryMode::Rt), (2, QueryMode::Brute)] {
            handle
                .query(KnnRequest::new(id, ds.points[..4].to_vec(), 2).with_mode(mode))
                .unwrap();
        }
        // a far-away cluster the base dataset cannot explain
        let extra: Vec<Point3> = (0..8)
            .map(|i| Point3::new(10.0 + i as f32 * 1e-3, 10.0, 10.0))
            .collect();
        handle.insert(&extra).unwrap();
        assert_eq!(handle.data_len(), 2_200 + 8);
        for (id, mode) in [(3u64, QueryMode::Rt), (4, QueryMode::Brute)] {
            let resp = handle
                .query(KnnRequest::new(id, vec![Point3::splat(10.0)], 3).with_mode(mode))
                .unwrap();
            for n in &resp.neighbors[0] {
                assert!(
                    n.idx as usize >= 2_200,
                    "{mode:?} query near the inserted cluster found base point {}",
                    n.idx
                );
            }
        }
        let m = handle.metrics().snapshot();
        assert_eq!(m.inserts, 1);
        assert_eq!(m.points_inserted, 8);
        // the insert refit the Rt structure; it must not have rebuilt
        assert_eq!(m.builds_of(RoutePath::Rt), 1);
        svc.shutdown();
    }
}
