//! Worker supervision: crash containment, deterministic restart and
//! scatter failover for the service pool.
//!
//! Each pool worker's serving loop ([`worker_body`]) runs under a
//! supervisor ([`supervise_worker`]) that catches panics — injected by a
//! [`crate::faults::FaultPlan`] or genuine — and restarts the loop
//! in-place on the same thread. Everything a restart needs to reproduce
//! the crashed incarnation's state bit for bit lives in the
//! [`WorkerCtx`] that survives the `catch_unwind` boundary:
//!
//! - the **base dataset** and partition replica (shared, immutable);
//! - a handle on the **shared insert log** — the append-once record of
//!   every accepted insert, in submit order; the rebuilt registry
//!   starts at sequence zero and pulls the log forward to each batch's
//!   fence, so it lands on the exact pre-crash index state (indexes are
//!   pure functions of `(base, log prefix, config)`);
//! - the **journal** — every accepted-but-unanswered request, in submit
//!   order, re-enqueued and served before the queue is touched again;
//! - the **batch sequence**, monotonic across restarts, so a scheduled
//!   fault fires exactly once and replayed batches sail past it.
//!
//! The poison ledger breaks crash loops: a crash is attributed to the
//! requests in flight at that moment ([`WorkerCtx::crashing_keys`]), and
//! an id that kills its worker [`POISON_STRIKES`] times is quarantined —
//! its journal entries fail with [`ServiceError::Poisoned`], later
//! submits of the id are refused at the boundary, and the pool survives.
//!
//! Hangs are handled by a separate **failover monitor** ([`run_monitor`],
//! one per sharded pool): workers heartbeat through [`WorkerHealth`],
//! and a scattered request whose shard partial is unmerged past the
//! heartbeat timeout — with a stale owner — is re-dispatched **at the
//! gather's original insert fence** to the shard's deterministic
//! failover owner ([`Router::worker_for_shard_excluding`]), which
//! rebuilds the shard from its own partition replica at exactly that
//! log prefix and delivers the identical partial (delivery is
//! idempotent and counter-deduped, so a recovered owner's duplicate is
//! merely dropped).

use super::metrics::Metrics;
use super::request::{KnnRequest, RoutePath};
use super::router::Router;
use super::service::{
    worker_body, Gather, InsertLog, Msg, ReplySink, ServiceConfig, ServiceError, ServiceHandle,
};
use crate::geom::Point3;
use crate::shard::Partition;
use std::collections::{BTreeSet, HashMap};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError, SyncSender};
use std::sync::{Arc, Mutex, PoisonError};
use std::time::{Duration, Instant};

/// Crashes one request id may cause before the ledger quarantines it.
pub(super) const POISON_STRIKES: u32 = 2;

/// Consecutive crashes **without batch progress** before the supervisor
/// gives up on a worker (a startup-time crash loop: the panic fires
/// before any batch is served, so restarting cannot help).
const MAX_CONSECUTIVE_RESTARTS: u32 = 4;

/// Monotonic time base shared by the pool's heartbeats: milliseconds
/// since service start, from one common epoch so staleness compares
/// across threads.
pub(super) struct ServiceClock {
    epoch: Instant,
}

impl Default for ServiceClock {
    fn default() -> Self {
        Self {
            // heartbeat epoch: feeds staleness intervals only, never
            // results — read through the sanctioned telemetry chokepoint
            epoch: crate::obs::clock::now(),
        }
    }
}

impl ServiceClock {
    /// Milliseconds elapsed since the clock's epoch.
    pub(super) fn now_ms(&self) -> u64 {
        self.epoch.elapsed().as_millis() as u64
    }
}

/// One worker's liveness beacon: the clock reading of its last
/// heartbeat. The worker beats at every message receipt and around every
/// batch; the monitor reads staleness to tell a hung worker from a busy
/// one.
pub(super) struct WorkerHealth {
    last_beat: AtomicU64,
}

impl WorkerHealth {
    /// A health slot initialized to "just beat" (a worker must get its
    /// startup grace period, not be declared stale before it runs).
    pub(super) fn new(clock: &ServiceClock) -> Self {
        Self {
            last_beat: AtomicU64::new(clock.now_ms()),
        }
    }

    /// Record a heartbeat now.
    pub(super) fn beat(&self, clock: &ServiceClock) {
        self.last_beat.store(clock.now_ms(), Ordering::SeqCst);
    }

    /// Milliseconds since the last heartbeat.
    pub(super) fn staleness_ms(&self, clock: &ServiceClock) -> u64 {
        clock.now_ms().saturating_sub(self.last_beat.load(Ordering::SeqCst))
    }
}

#[derive(Default)]
struct LedgerState {
    /// Crash count per request id. Never iterated — keyed access only
    /// (iteration order would be nondeterministic).
    strikes: HashMap<u64, u32>,
    /// Quarantined ids, ordered so any future listing is deterministic.
    quarantined: BTreeSet<u64>,
}

/// The pool-wide poison ledger: attributes worker crashes to the request
/// ids in flight and quarantines an id after [`POISON_STRIKES`] kills.
/// Shared by every supervisor (strikes) and every handle (submit-time
/// refusal), so a poisoned request is fenced out of the whole pool, not
/// one worker.
#[derive(Default)]
pub(super) struct PoisonLedger {
    state: Mutex<LedgerState>,
}

impl PoisonLedger {
    /// Record one crash attributed to `id`; returns true exactly once —
    /// on the strike that crosses the quarantine threshold.
    pub(super) fn strike(&self, id: u64) -> bool {
        let mut st = self.state.lock().unwrap_or_else(PoisonError::into_inner);
        let n = st.strikes.entry(id).or_insert(0);
        *n += 1;
        let n = *n;
        n >= POISON_STRIKES && st.quarantined.insert(id)
    }

    /// Is `id` quarantined?
    pub(super) fn is_poisoned(&self, id: u64) -> bool {
        self.state
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .quarantined
            .contains(&id)
    }
}

/// One accepted-but-unanswered request, as the supervisor's journal
/// holds it: everything needed to re-enqueue it verbatim after a crash.
pub(super) struct JournalEntry {
    pub(super) req: KnnRequest,
    pub(super) path: RoutePath,
    pub(super) shard: Option<usize>,
    /// Insert-log fence the request was stamped with at submit;
    /// replaying at the same fence reproduces the pre-crash serve bit
    /// for bit even if the log has grown since.
    pub(super) fence: u64,
    pub(super) sink: ReplySink,
    pub(super) arrived: Instant,
}

/// The crash-surviving state of one worker. [`worker_body`] borrows it
/// for an incarnation; everything incarnation-local (registry, batcher,
/// reply map) is rebuilt from these fields on restart.
pub(super) struct WorkerCtx {
    pub(super) worker_id: usize,
    pub(super) n_workers: usize,
    pub(super) base: Arc<Vec<Point3>>,
    /// The partition `Service::start` computed (shards > 1 only).
    pub(super) partition: Option<Arc<Partition>>,
    pub(super) cfg: ServiceConfig,
    pub(super) rx: Receiver<Msg>,
    /// Ready-handshake sender; taken by the first incarnation.
    pub(super) ready: Option<SyncSender<bool>>,
    pub(super) metrics: Arc<Metrics>,
    pub(super) inflight: Arc<AtomicUsize>,
    pub(super) health: Arc<Vec<WorkerHealth>>,
    pub(super) clock: Arc<ServiceClock>,
    pub(super) ledger: Arc<PoisonLedger>,
    /// Accepted, unanswered requests in submit order (replayed on
    /// restart).
    pub(super) journal: Vec<JournalEntry>,
    /// The pool-shared append-once insert log. Workers never copy it:
    /// each incarnation's registry starts at sequence zero and pulls
    /// the log forward to each batch's fence. With persistence on, cold
    /// start seeds the log with the WAL's replayed records, so a
    /// restarted process recovers exactly like a restarted worker.
    pub(super) log: Arc<InsertLog>,
    /// Validated snapshot bytes + WAL watermark found at cold start
    /// (persistence on, RT route unsharded only). Each incarnation's
    /// registry recovers the RT index from it instead of rebuilding.
    pub(super) snapshot: Option<(Arc<Vec<u8>>, u64)>,
    /// Snapshot files existed at cold start but none survived
    /// validation: the fresh RT build that replaces them is counted as
    /// `rebuilt`.
    pub(super) snapshot_rejected: bool,
    /// 1-based count of snapshot files this worker has written — the
    /// `op` coordinate of scheduled snapshot torn-write faults, kept
    /// monotonic across restarts like `batch_seq`.
    pub(super) snapshot_ops: u64,
    /// Per-worker batch sequence; monotonic across restarts.
    pub(super) batch_seq: u64,
    /// `(id, shard)` keys of the batch being served right now — the
    /// requests a crash at this moment is attributed to.
    pub(super) crashing_keys: Vec<(u64, Option<usize>)>,
    /// This worker's span sink (tracing on only). Lives here — not in
    /// incarnation state — so span sequence numbers stay monotonic and
    /// buffered spans survive across supervised restarts; the sink is
    /// single-owner, so recording needs no locks.
    pub(super) tracer: Option<crate::obs::SpanSink>,
}

impl WorkerCtx {
    /// Heartbeat: stamp this worker's health slot with the clock's now.
    pub(super) fn beat(&self) {
        self.health[self.worker_id].beat(&self.clock);
    }

    /// Retire the journal entry of an answered request (matched on id
    /// **and** shard: a worker owning several shards of one route holds
    /// one entry per shard).
    pub(super) fn complete(&mut self, id: u64, shard: Option<usize>) {
        if let Some(pos) = self
            .journal
            .iter()
            .position(|e| e.req.id == id && e.shard == shard)
        {
            self.journal.remove(pos);
        }
    }

    /// After a crash: strike every request that was in flight, and
    /// quarantine any id that crossed the threshold — its journal
    /// entries (all shards) fail with [`ServiceError::Poisoned`] and are
    /// **not** replayed.
    fn quarantine_poisoned(&mut self) {
        let keys = std::mem::take(&mut self.crashing_keys);
        for (id, _shard) in keys {
            if self.ledger.strike(id) {
                Metrics::inc(&self.metrics.poisoned);
                while let Some(pos) = self.journal.iter().position(|e| e.req.id == id) {
                    let entry = self.journal.remove(pos);
                    entry.sink.fail(ServiceError::Poisoned);
                    self.inflight.fetch_sub(1, Ordering::SeqCst);
                }
            }
        }
    }

    /// Fail every journaled request with `err` (the supervisor's
    /// give-up path): clients get a typed error instead of a hang.
    fn fail_all(&mut self, err: ServiceError) {
        for entry in self.journal.drain(..) {
            entry.sink.fail(err.clone());
            self.inflight.fetch_sub(1, Ordering::SeqCst);
        }
    }
}

/// Run one worker under supervision: serve until clean shutdown,
/// catching panics and restarting the serving loop with deterministic
/// state recovery (see the module docs). Gives up — failing the journal
/// with [`ServiceError::ShutDown`] — only on a crash loop that makes no
/// batch progress, which a restart cannot fix. Documented edge: the
/// give-up fails this worker's gather sinks too, even where a scatter
/// failover could still have saved them — a worker that cannot finish
/// startup is assumed misconfigured pool-wide.
pub(super) fn supervise_worker(mut ctx: WorkerCtx) {
    let mut consecutive = 0u32;
    let mut seq_at_last_crash = 0u64;
    loop {
        let outcome = catch_unwind(AssertUnwindSafe(|| worker_body(&mut ctx)));
        match outcome {
            Ok(()) => return,
            Err(_) => {
                // batch progress since the last crash resets the loop
                // detector: the pool is limping, not stuck
                if ctx.batch_seq > seq_at_last_crash {
                    consecutive = 0;
                }
                seq_at_last_crash = ctx.batch_seq;
                consecutive += 1;
                Metrics::inc(&ctx.metrics.restarts);
                ctx.quarantine_poisoned();
                if consecutive >= MAX_CONSECUTIVE_RESTARTS {
                    crate::log_warn!(
                        "worker {} crashed {consecutive} times without progress; giving up",
                        ctx.worker_id
                    );
                    ctx.fail_all(ServiceError::ShutDown);
                    return;
                }
                // exponential backoff (capped at 8x) between restarts,
                // so a tight crash loop does not spin a core
                std::thread::sleep(ctx.cfg.replay_backoff * (1u32 << (consecutive - 1).min(3)));
                ctx.beat();
            }
        }
    }
}

/// Everything the failover monitor thread needs: the pending-gather
/// list it sweeps, the health table it reads, and a handle to
/// re-dispatch timed-out partials through.
pub(super) struct MonitorCtx {
    pub(super) handle: ServiceHandle,
    pub(super) gathers: Arc<Mutex<Vec<Arc<Gather>>>>,
    pub(super) health: Arc<Vec<WorkerHealth>>,
    pub(super) clock: Arc<ServiceClock>,
    pub(super) timeout: Duration,
    pub(super) shards: usize,
    pub(super) stop: Receiver<()>,
    /// The shared control-event sink (tracing on only): re-dispatch
    /// events land in `trace-control.jsonl`, not a worker file.
    pub(super) tracer: Option<Arc<Mutex<crate::obs::SpanSink>>>,
}

/// The failover monitor loop (one thread per sharded pool): every
/// quarter-timeout tick, sweep the pending gathers and re-dispatch any
/// shard partial that timed out on a stale owner to the shard's
/// deterministic failover owner. Exits on the stop signal (or its
/// disconnect at service teardown).
pub(super) fn run_monitor(mc: MonitorCtx) {
    let tick = (mc.timeout / 4).max(Duration::from_millis(1));
    loop {
        match mc.stop.recv_timeout(tick) {
            Ok(()) | Err(RecvTimeoutError::Disconnected) => return,
            Err(RecvTimeoutError::Timeout) => sweep(&mc),
        }
    }
}

/// One monitor pass: retire completed gathers, then for each gather past
/// the timeout, re-dispatch every still-unmerged, not-yet-redispatched
/// shard whose owner's heartbeat is stale. The re-dispatch carries the
/// gather's original insert fence, so the failover target rebuilds the
/// shard from its partition replica **at that exact log prefix** and
/// delivers the identical partial; the `replays` counter records each
/// re-dispatch.
fn sweep(mc: &MonitorCtx) {
    let timeout_ms = mc.timeout.as_millis() as u64;
    let mut gathers = mc.gathers.lock().unwrap_or_else(PoisonError::into_inner);
    gathers.retain(|g| {
        g.state
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .reply
            .is_some()
    });
    for g in gathers.iter() {
        if g.submitted.elapsed() < mc.timeout {
            continue;
        }
        let stale: Vec<usize> = {
            let st = g.state.lock().unwrap_or_else(PoisonError::into_inner);
            (0..mc.shards)
                .filter(|&s| !st.merged[s] && !st.redispatched[s])
                .collect()
        };
        for s in stale {
            let owner = Router::worker_for_shard(g.path, s, mc.handle.workers());
            if mc.health[owner].staleness_ms(&mc.clock) < timeout_ms {
                // the owner is alive (maybe just slow): let it finish —
                // its delivery is the same bits a failover would produce
                continue;
            }
            let fo = Router::worker_for_shard_excluding(g.path, s, mc.handle.workers(), owner);
            let msg = Msg::Request(
                g.req.clone(),
                g.path,
                Some(s),
                g.fence,
                ReplySink::Gather(g.clone()),
                // re-dispatch arrival stamp: latency telemetry only
                crate::obs::clock::now(),
            );
            // a full failover queue just means we retry at the next
            // tick (redispatched stays false)
            if mc.handle.try_send(fo, msg).is_ok() {
                Metrics::inc(&mc.handle.metrics().replays);
                {
                    let mut st = g.state.lock().unwrap_or_else(PoisonError::into_inner);
                    st.redispatched[s] = true;
                }
                if let Some(tracer) = &mc.tracer {
                    let mut tr = tracer.lock().unwrap_or_else(PoisonError::into_inner);
                    tr.event(
                        g.id,
                        crate::obs::span::names::REDISPATCHED,
                        vec![
                            ("shard".to_string(), s as f64),
                            ("fence".to_string(), g.fence as f64),
                        ],
                    );
                    // control events are rare; land them immediately so
                    // a reader never races a buffered re-dispatch
                    tr.flush();
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poison_ledger_quarantines_on_the_second_strike_once() {
        let ledger = PoisonLedger::default();
        assert!(!ledger.is_poisoned(7));
        assert!(!ledger.strike(7), "first strike must not quarantine");
        assert!(!ledger.is_poisoned(7));
        assert!(ledger.strike(7), "second strike crosses the threshold");
        assert!(ledger.is_poisoned(7));
        assert!(!ledger.strike(7), "threshold crossing reports only once");
        assert!(ledger.is_poisoned(7));
        assert!(!ledger.is_poisoned(8), "ids are independent");
    }

    #[test]
    fn health_staleness_tracks_beats() {
        let clock = ServiceClock::default();
        let health = WorkerHealth::new(&clock);
        // a fresh slot starts from "just beat", and a beat resets it
        let before = health.staleness_ms(&clock);
        std::thread::sleep(Duration::from_millis(5));
        assert!(health.staleness_ms(&clock) >= before);
        health.beat(&clock);
        assert!(health.staleness_ms(&clock) <= 5, "beat must reset staleness");
    }
}
