//! CSV persistence for point clouds — lets users bring the paper's real
//! datasets (3DRoad, Porto CSV exports, KITTI .txt conversions) through
//! the same pipeline as the synthetic analogs.

use super::{Dataset, DatasetKind};
use crate::geom::Point3;
use std::io::{BufRead, BufWriter, Write};

#[derive(Debug)]
pub enum IoError {
    Io(std::io::Error),
    BadLine(usize, String),
}

impl std::fmt::Display for IoError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            IoError::Io(e) => write!(f, "io: {e}"),
            IoError::BadLine(line, row) => {
                write!(f, "line {line}: expected 2 or 3 comma-separated floats, got '{row}'")
            }
        }
    }
}

impl std::error::Error for IoError {}

impl From<std::io::Error> for IoError {
    fn from(e: std::io::Error) -> Self {
        IoError::Io(e)
    }
}

/// Load `x,y[,z]` rows; `#`-prefixed lines and a non-numeric first row
/// (header) are skipped. 2-column rows get z = 0 (paper §5.2).
pub fn load_csv(path: &str, kind: DatasetKind) -> Result<Dataset, IoError> {
    let file = std::fs::File::open(path)?;
    let reader = std::io::BufReader::new(file);
    let mut points = Vec::new();
    for (idx, line) in reader.lines().enumerate() {
        let line = line?;
        let trimmed = line.trim();
        if trimmed.is_empty() || trimmed.starts_with('#') {
            continue;
        }
        match parse_row(trimmed) {
            Some(p) => points.push(p),
            None if idx == 0 => continue, // header row
            None => return Err(IoError::BadLine(idx + 1, trimmed.to_string())),
        }
    }
    Ok(Dataset { kind, points })
}

fn parse_row(row: &str) -> Option<Point3> {
    let mut it = row.split(',').map(str::trim);
    let x: f32 = it.next()?.parse().ok()?;
    let y: f32 = it.next()?.parse().ok()?;
    let z: f32 = match it.next() {
        Some(tok) if !tok.is_empty() => tok.parse().ok()?,
        _ => 0.0,
    };
    if it.next().is_some() {
        return None; // too many columns
    }
    Some(Point3::new(x, y, z))
}

/// Write `x,y,z` rows with a provenance header.
pub fn save_csv(ds: &Dataset, path: &str) -> Result<(), IoError> {
    let file = std::fs::File::create(path)?;
    let mut w = BufWriter::new(file);
    writeln!(w, "# trueknn dataset kind={} n={}", ds.kind.name(), ds.len())?;
    for p in &ds.points {
        writeln!(w, "{},{},{}", p.x, p.y, p.z)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> String {
        let dir = std::env::temp_dir().join("trueknn_io_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name).to_string_lossy().into_owned()
    }

    #[test]
    fn round_trip() {
        let ds = DatasetKind::Uniform.generate(50, 4);
        let path = tmp("rt.csv");
        save_csv(&ds, &path).unwrap();
        let re = load_csv(&path, DatasetKind::Uniform).unwrap();
        assert_eq!(re.len(), 50);
        for (a, b) in ds.points.iter().zip(&re.points) {
            assert!(crate::geom::dist(*a, *b) < 1e-5);
        }
    }

    #[test]
    fn two_column_rows_get_zero_z() {
        let path = tmp("2d.csv");
        std::fs::write(&path, "lat,lon\n1.5,2.5\n3.0,4.0\n").unwrap();
        let ds = load_csv(&path, DatasetKind::Road).unwrap();
        assert_eq!(ds.len(), 2);
        assert_eq!(ds.points[0], Point3::new(1.5, 2.5, 0.0));
    }

    #[test]
    fn comments_and_blanks_skipped() {
        let path = tmp("c.csv");
        std::fs::write(&path, "# comment\n\n1,2,3\n").unwrap();
        assert_eq!(load_csv(&path, DatasetKind::Iono).unwrap().len(), 1);
    }

    #[test]
    fn bad_line_is_an_error() {
        let path = tmp("bad.csv");
        std::fs::write(&path, "1,2,3\nnope,really\n").unwrap();
        assert!(matches!(
            load_csv(&path, DatasetKind::Iono),
            Err(IoError::BadLine(2, _))
        ));
    }

    #[test]
    fn too_many_columns_rejected() {
        let path = tmp("wide.csv");
        // a bad *first* row is treated as a header; put a good row first
        std::fs::write(&path, "1,2,3\n1,2,3,4\n").unwrap();
        assert!(load_csv(&path, DatasetKind::Iono).is_err());
    }
}
