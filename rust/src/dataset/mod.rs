//! Datasets: synthetic analogs of the paper's evaluation datasets, CSV
//! persistence, and distance-distribution statistics.
//!
//! The paper evaluates on 3DRoad, Porto, KITTI, 3DIono (real) and a
//! uniform synthetic. The real datasets are not redistributable here, so
//! `synth` provides deterministic generators matched to each dataset's
//! *spatial character* (what the kNN algorithms are actually sensitive
//! to: clustering structure and outlier tail). See DESIGN.md §4.

pub mod synth;
pub mod io;
pub mod stats;

pub use stats::DistanceProfile;

use crate::geom::Point3;
use crate::util::Pcg32;

/// The five evaluation datasets (paper §5.1) by analog name.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum DatasetKind {
    /// 3DRoad analog: 2D road-network points (filamentary clusters).
    Road,
    /// Porto analog: 2D taxi-GPS trajectories (dense core + heavy outlier tail).
    Taxi,
    /// KITTI analog: 3D LiDAR-like radial surface scan.
    Lidar,
    /// 3DIono analog: 3D anisotropic Gaussian-mixture shells.
    Iono,
    /// UniformDist: U[0,1]^3, exactly as the paper.
    Uniform,
}

impl DatasetKind {
    pub const ALL: [DatasetKind; 5] = [
        DatasetKind::Road,
        DatasetKind::Taxi,
        DatasetKind::Lidar,
        DatasetKind::Iono,
        DatasetKind::Uniform,
    ];

    /// The four datasets the paper's main table sweeps (Table 1 / Fig 3).
    pub const PAPER_MAIN: [DatasetKind; 4] = [
        DatasetKind::Road,
        DatasetKind::Taxi,
        DatasetKind::Iono,
        DatasetKind::Lidar,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            DatasetKind::Road => "road",
            DatasetKind::Taxi => "taxi",
            DatasetKind::Lidar => "lidar",
            DatasetKind::Iono => "iono",
            DatasetKind::Uniform => "uniform",
        }
    }

    /// The paper dataset this analog stands in for.
    pub fn paper_name(&self) -> &'static str {
        match self {
            DatasetKind::Road => "3DRoad",
            DatasetKind::Taxi => "Porto",
            DatasetKind::Lidar => "KITTI",
            DatasetKind::Iono => "3DIono",
            DatasetKind::Uniform => "UniformDist",
        }
    }

    pub fn is_2d(&self) -> bool {
        matches!(self, DatasetKind::Road | DatasetKind::Taxi)
    }

    /// Generate `n` points with this kind's generator.
    pub fn generate(&self, n: usize, seed: u64) -> Dataset {
        let points = match self {
            DatasetKind::Road => synth::road(n, seed),
            DatasetKind::Taxi => synth::taxi(n, seed),
            DatasetKind::Lidar => synth::lidar(n, seed),
            DatasetKind::Iono => synth::iono(n, seed),
            DatasetKind::Uniform => synth::uniform(n, seed),
        };
        Dataset {
            kind: *self,
            points,
        }
    }
}

impl std::str::FromStr for DatasetKind {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "road" | "3droad" => Ok(DatasetKind::Road),
            "taxi" | "porto" => Ok(DatasetKind::Taxi),
            "lidar" | "kitti" => Ok(DatasetKind::Lidar),
            "iono" | "3diono" => Ok(DatasetKind::Iono),
            "uniform" | "uniformdist" => Ok(DatasetKind::Uniform),
            other => Err(format!(
                "unknown dataset '{other}' (expected road|taxi|lidar|iono|uniform)"
            )),
        }
    }
}

/// A point cloud plus its provenance.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub kind: DatasetKind,
    pub points: Vec<Point3>,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.points.len()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    /// Take the first `d` points — the paper "always used the first d
    /// points" for size sweeps (§5.3).
    pub fn prefix(&self, d: usize) -> Dataset {
        Dataset {
            kind: self.kind,
            points: self.points[..d.min(self.points.len())].to_vec(),
        }
    }

    /// Random sample of `m` points (paper Alg. 2 line 1).
    pub fn sample(&self, m: usize, rng: &mut Pcg32) -> Vec<Point3> {
        rng.sample_indices(self.points.len(), m)
            .into_iter()
            .map(|i| self.points[i])
            .collect()
    }

    pub fn bounding_box(&self) -> crate::geom::Aabb {
        let mut b = crate::geom::Aabb::EMPTY;
        for &p in &self.points {
            b.grow(p);
        }
        b
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kinds_parse_both_names() {
        assert_eq!("porto".parse::<DatasetKind>().unwrap(), DatasetKind::Taxi);
        assert_eq!("road".parse::<DatasetKind>().unwrap(), DatasetKind::Road);
        assert!("mars".parse::<DatasetKind>().is_err());
    }

    #[test]
    fn generators_are_deterministic_and_sized() {
        for kind in DatasetKind::ALL {
            let a = kind.generate(500, 9);
            let b = kind.generate(500, 9);
            assert_eq!(a.len(), 500, "{kind:?}");
            assert_eq!(a.points, b.points, "{kind:?} must be deterministic");
            assert!(a.points.iter().all(|p| p.is_finite()), "{kind:?}");
        }
    }

    #[test]
    fn two_d_datasets_have_zero_z() {
        for kind in DatasetKind::ALL {
            let d = kind.generate(200, 1);
            if kind.is_2d() {
                assert!(d.points.iter().all(|p| p.z == 0.0), "{kind:?}");
            } else {
                assert!(d.points.iter().any(|p| p.z != 0.0), "{kind:?}");
            }
        }
    }

    #[test]
    fn prefix_takes_first_points() {
        let d = DatasetKind::Uniform.generate(100, 3);
        let p = d.prefix(10);
        assert_eq!(p.len(), 10);
        assert_eq!(p.points[..], d.points[..10]);
        assert_eq!(d.prefix(1000).len(), 100);
    }

    #[test]
    fn sample_draws_from_dataset() {
        let d = DatasetKind::Uniform.generate(100, 3);
        let mut rng = Pcg32::new(1);
        let s = d.sample(10, &mut rng);
        assert_eq!(s.len(), 10);
        for p in &s {
            assert!(d.points.contains(p));
        }
    }
}
