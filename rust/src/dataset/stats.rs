//! Distance-distribution statistics over a dataset.
//!
//! The paper's baseline needs `maxDist` — the maximum distance between
//! any point and its k-th nearest neighbor (§5.2.1) — and the 99th-
//! percentile variant needs the 99th percentile of those distances
//! (§5.5.1). Computed exactly with the kd-tree reference.

use super::Dataset;
use crate::knn::kdtree::KdTree;
use crate::util::stats::percentile_sorted;

/// Exact distribution of k-th-NN distances over all points.
#[derive(Clone, Debug)]
pub struct DistanceProfile {
    /// Sorted k-th-neighbor distance per point.
    pub kth_dists: Vec<f64>,
    pub k: usize,
}

impl DistanceProfile {
    /// Compute the k-th-NN distance for every point (self excluded).
    pub fn compute(ds: &Dataset, k: usize) -> DistanceProfile {
        let tree = KdTree::build(&ds.points);
        let mut kth = Vec::with_capacity(ds.len());
        for (i, &p) in ds.points.iter().enumerate() {
            let nn = tree.knn_excluding(p, k, Some(i as u32));
            let far = nn.last().map(|h| h.dist as f64).unwrap_or(0.0);
            kth.push(far);
        }
        kth.sort_by(f64::total_cmp);
        DistanceProfile { kth_dists: kth, k }
    }

    /// The paper's `maxDist`: baseline radius guaranteeing completeness.
    pub fn max_dist(&self) -> f64 {
        *self.kth_dists.last().unwrap_or(&0.0)
    }

    /// Percentile radius (99.0 for the paper's outlier experiment).
    pub fn percentile_dist(&self, q: f64) -> f64 {
        if self.kth_dists.is_empty() {
            0.0
        } else {
            percentile_sorted(&self.kth_dists, q)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::DatasetKind;

    #[test]
    fn max_dist_dominates_percentiles() {
        let ds = DatasetKind::Taxi.generate(2_000, 8);
        let prof = DistanceProfile::compute(&ds, 5);
        let p99 = prof.percentile_dist(99.0);
        let p50 = prof.percentile_dist(50.0);
        assert!(prof.max_dist() >= p99);
        assert!(p99 >= p50);
        assert!(p50 > 0.0);
    }

    #[test]
    fn outlier_tail_visible_in_taxi() {
        // Porto-analog: maxDist should dwarf the median kNN distance —
        // this gap is the entire premise of the paper.
        let ds = DatasetKind::Taxi.generate(4_000, 9);
        let prof = DistanceProfile::compute(&ds, 5);
        assert!(
            prof.max_dist() > 5.0 * prof.percentile_dist(50.0),
            "maxDist {} vs median {}",
            prof.max_dist(),
            prof.percentile_dist(50.0)
        );
    }

    #[test]
    fn kth_dist_is_monotone_in_k() {
        let ds = DatasetKind::Uniform.generate(500, 10);
        let p1 = DistanceProfile::compute(&ds, 1);
        let p5 = DistanceProfile::compute(&ds, 5);
        assert!(p5.max_dist() >= p1.max_dist());
        assert!(p5.percentile_dist(50.0) >= p1.percentile_dist(50.0));
    }
}
