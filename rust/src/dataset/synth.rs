//! Deterministic synthetic generators standing in for the paper's
//! datasets (DESIGN.md §4 documents each substitution).
//!
//! What matters to TrueKNN is the *distance distribution*: how clustered
//! the bulk is and how heavy the outlier tail is — the tail is what makes
//! the paper's fixed-radius baseline pay an enormous maxDist radius for
//! every query. Each generator reproduces its original's qualitative
//! k-NN-distance profile.

use crate::geom::Point3;
use crate::util::Pcg32;

/// 3DRoad analog: points jittered along a random planar polyline road
/// network. Roads are generated as random walks between junctions of a
/// coarse grid, giving the 1-D filamentary clusters a road network has.
pub fn road(n: usize, seed: u64) -> Vec<Point3> {
    let mut rng = Pcg32::new(seed ^ 0x0A0D);
    let mut pts = Vec::with_capacity(n);
    // a few "towns" concentrate the junctions, as in North Jutland's
    // actual road graph: dense urban grids + sparse rural connectors
    let n_towns = 6;
    let towns: Vec<(f32, f32)> = (0..n_towns)
        .map(|_| (0.1 + 0.8 * rng.f32(), 0.1 + 0.8 * rng.f32()))
        .collect();
    let n_roads = (n / 1500).max(6);
    'outer: for _ in 0..n_roads {
        // rural connectors join two towns; urban streets stay inside one
        let rural = rng.f32() < 0.25;
        let (tx0, ty0) = towns[rng.below_usize(n_towns)];
        let ((x0, y0), (x1, y1)) = if rural {
            let (tx1, ty1) = towns[rng.below_usize(n_towns)];
            ((tx0, ty0), (tx1, ty1))
        } else {
            let span = 0.03 + 0.04 * rng.f32();
            (
                (tx0 + rng.normal() * span, ty0 + rng.normal() * span),
                (tx0 + rng.normal() * span, ty0 + rng.normal() * span),
            )
        };
        // rural roads are sampled ~10x sparser (same elevation-survey
        // spacing over much longer distance) → the heavy kth-NN tail
        // that makes the paper's 3DRoad baseline radius blow up
        let per_road = if rural {
            (n / n_roads / 8).max(8)
        } else {
            n / n_roads + 1
        };
        let mut wob_x = 0.0f32;
        let mut wob_y = 0.0f32;
        for i in 0..per_road {
            let t = i as f32 / per_road as f32;
            wob_x += rng.normal() * 0.0008;
            wob_y += rng.normal() * 0.0008;
            let jx = rng.normal() * 0.0004; // GPS-style jitter
            let jy = rng.normal() * 0.0004;
            pts.push(Point3::new2(
                x0 + (x1 - x0) * t + wob_x + jx,
                y0 + (y1 - y0) * t + wob_y + jy,
            ));
            if pts.len() == n {
                break 'outer;
            }
        }
    }
    while pts.len() < n {
        let (tx, ty) = towns[rng.below_usize(n_towns)];
        pts.push(Point3::new2(
            tx + rng.normal() * 0.05,
            ty + rng.normal() * 0.05,
        ));
    }
    pts
}

/// Porto analog: taxi GPS trajectories. Trips start near a dense city
/// core and random-walk outward; a few percent of trips are long
/// excursions far outside the core — the heavy outlier tail that makes
/// the paper's Porto baseline radii explode.
pub fn taxi(n: usize, seed: u64) -> Vec<Point3> {
    let mut rng = Pcg32::new(seed ^ 0x7A51);
    let mut pts = Vec::with_capacity(n);
    let trip_len = 200usize;
    while pts.len() < n {
        // trip start: clustered around the core with lognormal-ish spread
        let excursion = rng.f32() < 0.05;
        let spread = if excursion { 0.30 } else { 0.02 };
        let mut x = 0.5 + rng.normal() * spread;
        let mut y = 0.5 + rng.normal() * spread;
        // excursions are highway trips: fast driving = sparse GPS fixes,
        // so consecutive points sit far apart
        let step = if excursion { 0.02 } else { 0.0008 };
        let this_len = if excursion { trip_len / 4 } else { trip_len };
        for _ in 0..this_len {
            x += rng.normal() * step;
            y += rng.normal() * step;
            pts.push(Point3::new2(x, y));
            if pts.len() == n {
                break;
            }
        }
    }
    // lone GPS fixes far outside the city (sensor glitches / distant
    // pickups): the isolated outliers that drive the paper's maxDist
    // blow-up on Porto. A deterministic ~0.5% of points, so the tail is
    // present at every dataset size.
    let n_out = (n / 200).max(2).min(n);
    for i in rng.sample_indices(n, n_out) {
        pts[i] = Point3::new2(0.5 + rng.normal() * 0.8, 0.5 + rng.normal() * 0.8);
    }
    pts
}

/// KITTI analog: LiDAR-like scan. Points lie on surfaces at
/// ring-structured radial distances from a sensor at the origin, with
/// density decaying with range and vertical structure from scan rings.
pub fn lidar(n: usize, seed: u64) -> Vec<Point3> {
    let mut rng = Pcg32::new(seed ^ 0x11DA);
    let n_rings = 64;
    let mut pts = Vec::with_capacity(n);
    while pts.len() < n {
        let ring = rng.below(n_rings as u32) as f32;
        // elevation angle per ring, mostly near-horizontal like a HDL-64
        let elev = -0.4 + 0.45 * ring / n_rings as f32 + rng.normal() * 0.001;
        let azim = rng.f32() * std::f32::consts::TAU;
        // range: surfaces appear at quasi-discrete depths (walls, cars);
        // sample a mixture of a few "surface" depths plus ground returns
        let depth_class = rng.below(5);
        let base = match depth_class {
            0 => 0.05,
            1 => 0.12,
            2 => 0.25,
            3 => 0.45,
            _ => 0.8,
        };
        let range = base * (1.0 + rng.normal().abs() * 0.15);
        let (ce, se) = (elev.cos(), elev.sin());
        pts.push(Point3::new(
            range * ce * azim.cos() + 0.5,
            range * ce * azim.sin() + 0.5,
            range * se + 0.5,
        ));
    }
    pts
}

/// 3DIono analog: total-electron-content style field — anisotropic
/// Gaussian-mixture shells (ionospheric layers) plus a sparse uniform
/// background. Produces tight 3D clusters with moderate outliers; the
/// paper's small-k F9 experiment shows TrueKNN *losing* here, which our
/// profile reproduces (many tiny rounds on a tight core).
pub fn iono(n: usize, seed: u64) -> Vec<Point3> {
    let mut rng = Pcg32::new(seed ^ 0x1090);
    let n_blobs = 12;
    let blobs: Vec<(Point3, Point3)> = (0..n_blobs)
        .map(|_| {
            let c = Point3::new(rng.f32(), rng.f32(), 0.3 + 0.4 * rng.f32());
            // anisotropic: thin in z (layered shells), wide in x/y
            let s = Point3::new(
                0.02 + 0.05 * rng.f32(),
                0.02 + 0.05 * rng.f32(),
                0.002 + 0.006 * rng.f32(),
            );
            (c, s)
        })
        .collect();
    let mut pts = Vec::with_capacity(n);
    while pts.len() < n {
        if rng.f32() < 0.01 {
            // sparse background (measurement noise / sporadic E)
            pts.push(Point3::new(rng.f32(), rng.f32(), rng.f32()));
        } else {
            let (c, s) = blobs[rng.below_usize(n_blobs)];
            pts.push(Point3::new(
                c.x + rng.normal() * s.x,
                c.y + rng.normal() * s.y,
                c.z + rng.normal() * s.z,
            ));
        }
    }
    pts
}

/// UniformDist: U[0,1]^3, exactly the paper's synthetic dataset (§5.1).
pub fn uniform(n: usize, seed: u64) -> Vec<Point3> {
    let mut rng = Pcg32::new(seed ^ 0x0111F);
    (0..n)
        .map(|_| Point3::new(rng.f32(), rng.f32(), rng.f32()))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geom::dist;

    fn nn_dists(pts: &[Point3]) -> Vec<f64> {
        // brute-force 1-NN distance of a strided subsample — enough to
        // compare clustering profiles between generators
        let m = pts.len().min(200);
        let stride = pts.len() / m;
        (0..m)
            .map(|qi| {
                let i = qi * stride;
                let mut best = f32::INFINITY;
                for (j, &q) in pts.iter().enumerate() {
                    if i != j {
                        best = best.min(dist(pts[i], q));
                    }
                }
                best as f64
            })
            .collect()
    }

    #[test]
    fn taxi_has_heavier_tail_than_uniform() {
        let t = taxi(5_000, 1);
        let u = uniform(5_000, 1);
        let t_d = nn_dists(&t);
        let u_d = nn_dists(&u);
        let tail = |d: &[f64]| crate::util::percentile(d, 99.0) / crate::util::percentile(d, 50.0);
        assert!(
            tail(&t_d) > 2.0 * tail(&u_d),
            "taxi tail {} vs uniform tail {}",
            tail(&t_d),
            tail(&u_d)
        );
    }

    #[test]
    fn clustered_sets_are_denser_than_uniform() {
        // median NN distance should be far smaller for the clustered sets
        let u = crate::util::stats::median(&nn_dists(&uniform(5_000, 2)));
        for (name, pts) in [
            ("road", road(5_000, 2)),
            ("taxi", taxi(5_000, 2)),
            ("iono", iono(5_000, 2)),
        ] {
            let m = crate::util::stats::median(&nn_dists(&pts));
            assert!(m < u, "{name}: median NN {m} should be < uniform {u}");
        }
    }

    #[test]
    fn lidar_is_three_dimensional_and_bounded() {
        let pts = lidar(2_000, 3);
        assert!(pts.iter().any(|p| (p.z - 0.5).abs() > 0.01));
        for p in &pts {
            assert!(p.x > -1.0 && p.x < 2.0, "{p:?}");
        }
    }

    #[test]
    fn exact_sizes() {
        for f in [road, taxi, lidar, iono, uniform] {
            assert_eq!(f(777, 5).len(), 777);
        }
    }
}
