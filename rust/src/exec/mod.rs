//! Deterministic parallel execution engine — std-only (`std::thread::scope`),
//! no atomics, no locks, no work queues.
//!
//! # Determinism contract (shard-then-merge)
//!
//! Every parallel operation in the crate follows the same discipline so
//! that results are **bitwise-identical at any thread count**:
//!
//! 1. **Contiguous sharding.** Work of size `n` is split into contiguous
//!    index ranges ([`Executor::shard_ranges`]). Shard boundaries depend
//!    only on `n`, the thread count and a minimum chunk size — never on
//!    timing.
//! 2. **Isolated workers.** Each shard runs on its own thread with its
//!    own scratch state (traversal stack, [`crate::rt::HwCounters`],
//!    program shard). Workers share only immutable input; there are no
//!    atomics or mutexes in the hot loop, so there is nothing to race on.
//! 3. **Ordered merge.** The spawning thread joins workers **in shard
//!    order** and folds their outputs left-to-right. Every per-query
//!    output is produced by exactly one shard, and global counters are
//!    sums of per-item contributions, so the merged result is the same
//!    as a serial run — bitwise, not just approximately.
//!
//! The contract holds because the primitives this crate parallelizes are
//! item-independent: a ray launch only touches state keyed by its own
//! query id, a BVH subtree build only touches its own primitive range,
//! and a subtree refit only touches its own (preorder-contiguous) node
//! block. The engine makes that independence explicit instead of hiding
//! it behind synchronization.
//!
//! `Executor` is a trivially-copyable handle (just a resolved thread
//! count); scoped threads are spawned per operation. On the workloads
//! this crate cares about (≥ thousands of primitives per launch) the
//! spawn cost is noise; below the per-shard minimum the engine runs the
//! serial path on the calling thread, which by the contract above
//! produces the identical result.

use std::ops::Range;

/// Resolved parallelism handle. `Copy` on purpose: embedding it in a
/// scene or index costs one `usize` and no lifetime entanglement.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Executor {
    threads: usize,
}

impl Default for Executor {
    fn default() -> Self {
        Self::auto()
    }
}

impl Executor {
    /// `threads == 0` means "use the environment default": the
    /// `TRUEKNN_THREADS` count if set ([`env_threads`]), otherwise all
    /// available cores ([`Executor::auto`]). An explicit nonzero count
    /// always wins. This is the single resolution point, so every
    /// zero/unset thread knob in the crate (index configs, CLI flags,
    /// service configs) honors the variable consistently.
    pub fn new(threads: usize) -> Executor {
        if threads == 0 {
            match env_threads() {
                0 => Self::auto(),
                n => Executor { threads: n },
            }
        } else {
            Executor { threads }
        }
    }

    pub fn auto() -> Executor {
        Executor {
            threads: std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1),
        }
    }

    pub fn serial() -> Executor {
        Executor { threads: 1 }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Split `[0, n)` into at most `threads` contiguous ranges of at
    /// least `min_chunk` items each (except possibly when `n` itself is
    /// smaller). Deterministic in `(n, threads, min_chunk)`.
    pub fn shard_ranges(&self, n: usize, min_chunk: usize) -> Vec<Range<usize>> {
        if n == 0 {
            return Vec::new();
        }
        let min_chunk = min_chunk.max(1);
        let shards = (n / min_chunk).clamp(1, self.threads);
        let base = n / shards;
        let rem = n % shards;
        let mut ranges = Vec::with_capacity(shards);
        let mut start = 0;
        for s in 0..shards {
            let len = base + usize::from(s < rem);
            ranges.push(start..start + len);
            start += len;
        }
        debug_assert_eq!(start, n);
        ranges
    }

    /// Run `f(shard_index, range)` over the shards of `[0, n)` and return
    /// the outputs **in shard order**. Shard 0 runs on the calling
    /// thread; with one shard (or `n < 2·min_chunk`) no thread is
    /// spawned at all.
    pub fn run<T, F>(&self, n: usize, min_chunk: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize, Range<usize>) -> T + Sync,
    {
        let ranges = self.shard_ranges(n, min_chunk);
        if ranges.len() <= 1 {
            return ranges.into_iter().enumerate().map(|(i, r)| f(i, r)).collect();
        }
        std::thread::scope(|s| {
            let f = &f;
            let handles: Vec<_> = ranges
                .iter()
                .cloned()
                .enumerate()
                .skip(1)
                .map(|(i, r)| s.spawn(move || f(i, r)))
                .collect();
            let mut out = Vec::with_capacity(ranges.len());
            out.push(f(0, ranges[0].clone()));
            for h in handles {
                // lint: allow(panic-in-lib) — join only errs if the worker panicked; re-raising is the correct propagation
                out.push(h.join().expect("exec worker panicked"));
            }
            out
        })
    }

    /// Shard `data` into disjoint mutable chunks and run `f(offset, chunk)`
    /// on each concurrently. Chunks are disjoint slices of one buffer, so
    /// the writes cannot overlap; the merge is the buffer itself.
    pub fn for_each_chunk<T, F>(&self, data: &mut [T], min_chunk: usize, f: F)
    where
        T: Send,
        F: Fn(usize, &mut [T]) + Sync,
    {
        let ranges = self.shard_ranges(data.len(), min_chunk);
        if ranges.len() <= 1 {
            if !data.is_empty() {
                f(0, data);
            }
            return;
        }
        std::thread::scope(|s| {
            let f = &f;
            let mut rest = data;
            let mut first: Option<(usize, &mut [T])> = None;
            for r in ranges {
                let (chunk, tail) = std::mem::take(&mut rest).split_at_mut(r.len());
                rest = tail;
                let start = r.start;
                if first.is_none() {
                    // chunk 0 runs on the calling thread, below
                    first = Some((start, chunk));
                } else {
                    s.spawn(move || f(start, chunk));
                }
            }
            if let Some((start, chunk)) = first {
                f(start, chunk);
            }
        });
    }

    /// Shard two equal-length buffers into *matching* disjoint chunk
    /// pairs and run `f(offset, a_chunk, b_chunk)` on each concurrently —
    /// the gather/scatter primitive of per-query result assembly (drain
    /// heap `i` into output slot `i`). Chunk pairs cover the same index
    /// range of both buffers, so item `i` of `a` is always processed
    /// alongside item `i` of `b`, and the merged result is the buffers
    /// themselves.
    pub fn for_each_chunk2<A, B, F>(&self, a: &mut [A], b: &mut [B], min_chunk: usize, f: F)
    where
        A: Send,
        B: Send,
        F: Fn(usize, &mut [A], &mut [B]) + Sync,
    {
        assert_eq!(a.len(), b.len(), "paired buffers must match in length");
        let ranges = self.shard_ranges(a.len(), min_chunk);
        if ranges.len() <= 1 {
            if !a.is_empty() {
                f(0, a, b);
            }
            return;
        }
        std::thread::scope(|s| {
            let f = &f;
            let mut rest_a = a;
            let mut rest_b = b;
            let mut first: Option<(usize, &mut [A], &mut [B])> = None;
            for r in ranges {
                let (ca, ta) = std::mem::take(&mut rest_a).split_at_mut(r.len());
                let (cb, tb) = std::mem::take(&mut rest_b).split_at_mut(r.len());
                rest_a = ta;
                rest_b = tb;
                let start = r.start;
                if first.is_none() {
                    // chunk-pair 0 runs on the calling thread, below
                    first = Some((start, ca, cb));
                } else {
                    s.spawn(move || f(start, ca, cb));
                }
            }
            if let Some((start, ca, cb)) = first {
                f(start, ca, cb);
            }
        });
    }
}

/// Worker-thread count forced through the environment:
/// `TRUEKNN_THREADS=<n>` pins every thread knob left at its `0`/unset
/// default — resolution happens inside [`Executor::new`], so index
/// configs, CLI flags and the service all honor it uniformly (CI runs
/// the whole tier-1 suite at 1 and 2 this way). Unset, empty or `0`
/// keeps the all-cores default; an explicitly configured nonzero thread
/// count always wins over the variable.
pub fn env_threads() -> usize {
    std::env::var("TRUEKNN_THREADS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0)
}

/// Two-way fork-join: run `fa` on the calling thread and `fb` on a scoped
/// worker, returning both results. The recursion primitive of the
/// parallel BVH builder.
pub fn join<A, B, FA, FB>(fa: FA, fb: FB) -> (A, B)
where
    A: Send,
    B: Send,
    FA: FnOnce() -> A + Send,
    FB: FnOnce() -> B + Send,
{
    std::thread::scope(|s| {
        let hb = s.spawn(fb);
        let a = fa();
        // lint: allow(panic-in-lib) — join only errs if the worker panicked; re-raising is the correct propagation
        (a, hb.join().expect("exec join worker panicked"))
    })
}

/// The sanctioned scoped-spawn chokepoint for callers outside `exec`.
///
/// The `raw-threads` lint confines `std::thread::{spawn, scope}` to this
/// module and the coordinator service loop; everything else that needs
/// hand-rolled fan-out (the RT pipeline's shard workers, the BVH refit
/// frontier, the radix scatter phase) goes through this wrapper. The
/// callers keep the determinism discipline themselves — disjoint writes,
/// shard-order joins — but routing them here makes every spawn site in
/// the crate greppable from one place.
pub fn scope<'env, F, T>(f: F) -> T
where
    F: for<'scope> FnOnce(&'scope std::thread::Scope<'scope, 'env>) -> T,
{
    std::thread::scope(f)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_ranges_cover_and_respect_min_chunk() {
        for threads in [1usize, 2, 3, 8] {
            let ex = Executor::new(threads);
            for n in [0usize, 1, 7, 64, 100, 1_000] {
                for min_chunk in [1usize, 32, 500] {
                    let ranges = ex.shard_ranges(n, min_chunk);
                    assert!(ranges.len() <= threads);
                    let covered: usize = ranges.iter().map(|r| r.len()).sum();
                    assert_eq!(covered, n, "t={threads} n={n} mc={min_chunk}");
                    for w in ranges.windows(2) {
                        assert_eq!(w[0].end, w[1].start, "ranges must be contiguous");
                    }
                    if n >= min_chunk {
                        for r in &ranges {
                            assert!(r.len() >= min_chunk.min(n), "undersized shard");
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn zero_threads_resolves_to_available_parallelism() {
        assert!(Executor::new(0).threads() >= 1);
        assert_eq!(Executor::serial().threads(), 1);
    }

    #[test]
    fn run_returns_results_in_shard_order() {
        let ex = Executor::new(4);
        let out = ex.run(1_000, 1, |i, r| (i, r.start, r.end));
        assert_eq!(out.len(), 4);
        for (i, (si, start, end)) in out.iter().enumerate() {
            assert_eq!(i, *si);
            assert!(start < end);
        }
        assert_eq!(out[0].1, 0);
        assert_eq!(out[3].2, 1_000);
    }

    #[test]
    fn run_sums_match_serial() {
        let data: Vec<u64> = (0..10_000).collect();
        let serial: u64 = data.iter().sum();
        for threads in [1usize, 2, 8] {
            let parts = Executor::new(threads).run(data.len(), 64, |_, r| {
                data[r].iter().sum::<u64>()
            });
            assert_eq!(parts.iter().sum::<u64>(), serial);
        }
    }

    #[test]
    fn for_each_chunk_touches_every_item_once() {
        let mut data = vec![0u32; 5_000];
        Executor::new(8).for_each_chunk(&mut data, 16, |offset, chunk| {
            for (i, x) in chunk.iter_mut().enumerate() {
                *x += (offset + i) as u32 + 1;
            }
        });
        for (i, x) in data.iter().enumerate() {
            assert_eq!(*x, i as u32 + 1, "item {i}");
        }
    }

    #[test]
    fn for_each_chunk2_pairs_matching_indices() {
        let mut heaps: Vec<u32> = (0..3_000).collect();
        let mut out = vec![0u32; 3_000];
        Executor::new(8).for_each_chunk2(&mut heaps, &mut out, 16, |offset, a, b| {
            for (i, (x, y)) in a.iter_mut().zip(b.iter_mut()).enumerate() {
                assert_eq!(*x as usize, offset + i, "chunks must stay aligned");
                *y = *x * 2;
                *x = 0;
            }
        });
        for (i, y) in out.iter().enumerate() {
            assert_eq!(*y, i as u32 * 2, "item {i}");
        }
        assert!(heaps.iter().all(|&x| x == 0), "source drained");
    }

    #[test]
    #[should_panic(expected = "paired buffers must match")]
    fn for_each_chunk2_rejects_mismatched_lengths() {
        let mut a = vec![0u8; 3];
        let mut b = vec![0u8; 4];
        Executor::new(2).for_each_chunk2(&mut a, &mut b, 1, |_, _, _| {});
    }

    #[test]
    fn join_runs_both_sides() {
        let (a, b) = join(|| 2 + 2, || "ok");
        assert_eq!(a, 4);
        assert_eq!(b, "ok");
    }
}
