//! Ablations: §5.3.1 RTNN comparison, §4 refit-vs-rebuild, and the BVH
//! builder strategy ablation called out in DESIGN.md.

use super::workloads::{build, mid_size, ExpScale, EXP_SEED};
use crate::bench::{bench, fmt_secs, BenchConfig, Table};
use crate::bvh::{Bvh, BuildStrategy};
use crate::configx::KPolicy;
use crate::dataset::DatasetKind;
use crate::exec::Executor;
use crate::geom::Aabb;
use crate::index::{Backend, IndexBuilder, IndexConfig, NeighborIndex};
use crate::knn::rtnn::{rtnn_knns, RtnnParams};
use crate::rt::CostModel;

// ------------------------------------------------------- RTNN comparison

#[derive(Clone, Debug)]
pub struct RtnnCmpRow {
    pub n: usize,
    pub trueknn_s: f64,
    pub rtnn_s: f64,
}

impl RtnnCmpRow {
    pub fn speedup(&self) -> f64 {
        self.rtnn_s / self.trueknn_s.max(1e-12)
    }
}

/// §5.3.1: unoptimized TrueKNN vs fully-optimized RTNN (query sorting +
/// partitioning) at the complete maxDist radius, Porto analog.
/// Paper: TrueKNN 1.5–8× faster.
pub fn rtnn_cmp(scale: ExpScale, sizes: Option<&[usize]>) -> Vec<RtnnCmpRow> {
    let default_sizes = super::workloads::paper_sizes(scale);
    let sizes = sizes.unwrap_or(&default_sizes);
    let mut rows = Vec::new();
    for &n in sizes {
        let ds = build(DatasetKind::Taxi, n);
        let k = KPolicy::SqrtN.resolve(n);
        let prof = crate::dataset::DistanceProfile::compute(&ds, k);
        let mut t_index = IndexBuilder::new(Backend::TrueKnn)
            .config(IndexConfig {
                seed: EXP_SEED,
                ..Default::default()
            })
            .build(ds.points.clone());
        let mut t = t_index.knn(&ds.points, k);
        t_index.build_stats().absorb_into(&mut t, &CostModel::default());
        let r = rtnn_knns(
            &ds.points,
            &ds.points,
            &RtnnParams {
                k,
                radius: prof.max_dist() as f32 * 1.0001,
                partitions: 32,
                ..Default::default()
            },
        );
        rows.push(RtnnCmpRow {
            n,
            trueknn_s: t.sim_seconds,
            rtnn_s: r.sim_seconds,
        });
    }
    rows
}

pub fn render_rtnn(rows: &[RtnnCmpRow]) -> Table {
    let mut t = Table::new(
        "§5.3.1: unoptimized TrueKNN vs optimized RTNN (Porto analog, k=√N)",
        &["size", "TrueKNN", "RTNN", "TrueKNN speedup"],
    );
    for r in rows {
        t.row(vec![
            r.n.to_string(),
            fmt_secs(r.trueknn_s),
            fmt_secs(r.rtnn_s),
            format!("{:.1}x", r.speedup()),
        ]);
    }
    t
}

// ------------------------------------------------------ refit vs rebuild

#[derive(Clone, Debug)]
pub struct RefitRow {
    pub n: usize,
    pub refit_s: f64,
    pub rebuild_s: f64,
    /// Simulated (cost-model) seconds from the *counted* refit nodes —
    /// deterministic, unlike the wall-clock columns.
    pub refit_sim_s: f64,
    /// Simulated seconds for a full build over the same primitives.
    pub rebuild_sim_s: f64,
}

impl RefitRow {
    /// refit time / rebuild time (paper: 0.75–0.9, i.e. 10–25% faster).
    pub fn ratio(&self) -> f64 {
        self.refit_s / self.rebuild_s.max(1e-12)
    }

    /// Counter-based ratio: immune to machine load, used by the tests.
    pub fn sim_ratio(&self) -> f64 {
        self.refit_sim_s / self.rebuild_sim_s.max(1e-12)
    }
}

/// §4 ablation: wall-clock of BVH refit vs full rebuild when the sphere
/// radius grows (the operation TrueKNN performs between rounds).
pub fn refit_vs_rebuild(sizes: &[usize]) -> Vec<RefitRow> {
    refit_vs_rebuild_with(sizes, &BenchConfig::from_env())
}

/// [`refit_vs_rebuild`] with an explicit bench config — tests inject a
/// minimal one so the tier-1 path never spins the wall-clock harness
/// (their assertions are on the counter-driven `sim_ratio` anyway).
pub fn refit_vs_rebuild_with(sizes: &[usize], cfg: &BenchConfig) -> Vec<RefitRow> {
    let mut rows = Vec::new();
    for &n in sizes {
        let ds = build(DatasetKind::Uniform, n);
        let aabbs_small: Vec<Aabb> = ds
            .points
            .iter()
            .map(|&c| Aabb::around_sphere(c, 0.01))
            .collect();
        let aabbs_big: Vec<Aabb> = ds
            .points
            .iter()
            .map(|&c| Aabb::around_sphere(c, 0.02))
            .collect();
        let base = Bvh::build(&aabbs_small);
        let refit = bench("refit", cfg, || {
            let mut b = base.clone();
            std::hint::black_box(b.refit(&aabbs_big));
        });
        // subtract the clone cost measured separately
        let clone_only = bench("clone", cfg, || {
            std::hint::black_box(base.clone());
        });
        let rebuild = bench("rebuild", cfg, || {
            std::hint::black_box(Bvh::build(&aabbs_big));
        });
        // deterministic companion numbers: the simulator charges refit
        // per touched node and build per primitive
        let refit_nodes = base.nodes.len();
        let model = CostModel::default();
        rows.push(RefitRow {
            n,
            refit_s: (refit.median_s - clone_only.median_s).max(1e-9),
            rebuild_s: rebuild.median_s,
            refit_sim_s: model.refit_cost(refit_nodes as u64),
            rebuild_sim_s: model.build_cost(n as u64),
        });
    }
    rows
}

pub fn render_refit(rows: &[RefitRow]) -> Table {
    let mut t = Table::new(
        "§4 ablation: BVH refit vs rebuild (paper: refit 10–25% faster)",
        &["prims", "refit", "rebuild", "refit/rebuild", "sim ratio"],
    );
    for r in rows {
        t.row(vec![
            r.n.to_string(),
            fmt_secs(r.refit_s),
            fmt_secs(r.rebuild_s),
            format!("{:.2}", r.ratio()),
            format!("{:.2}", r.sim_ratio()),
        ]);
    }
    t
}

// --------------------------------------------------- builder strategies

#[derive(Clone, Debug)]
pub struct BuilderRow {
    pub strategy: &'static str,
    pub build_s: f64,
    pub sim_query_s: f64,
    pub surface_area: f64,
}

/// DESIGN.md ablation: median-split vs SAH — build cost vs query cost on
/// the clustered taxi analog.
pub fn builder_ablation(scale: ExpScale) -> Vec<BuilderRow> {
    builder_ablation_with(scale, &BenchConfig::from_env())
}

/// [`builder_ablation`] with an explicit bench config (see
/// [`refit_vs_rebuild_with`] for why tests inject one).
pub fn builder_ablation_with(scale: ExpScale, cfg: &BenchConfig) -> Vec<BuilderRow> {
    let ds = build(DatasetKind::Taxi, mid_size(scale).min(20_000));
    let r = 0.005f32;
    let aabbs: Vec<Aabb> = ds
        .points
        .iter()
        .map(|&c| Aabb::around_sphere(c, r))
        .collect();
    let mut rows = Vec::new();
    for (name, strat) in [
        ("median", BuildStrategy::MedianSplit),
        ("sah", BuildStrategy::Sah),
    ] {
        let b = bench(name, cfg, || {
            std::hint::black_box(Bvh::build_with(&aabbs, strat, 4));
        });
        let bvh = Bvh::build_with(&aabbs, strat, 4);
        // simulated query cost: traverse every point, count tests
        let mut counters = crate::rt::HwCounters::new();
        let scene = crate::rt::Scene::from_parts(
            ds.points.clone(),
            r,
            aabbs.clone(),
            bvh.clone(),
            Executor::serial(),
        );
        let rays: Vec<crate::geom::Ray> = ds
            .points
            .iter()
            .enumerate()
            .map(|(i, &p)| crate::geom::Ray::knn(p, i as u32))
            .collect();
        let mut prog = crate::knn::program::KnnProgram::new(ds.len(), 5, true);
        crate::rt::Pipeline::launch(&scene, &rays, &mut prog, &mut counters);
        let sim = crate::rt::CostModel::default().seconds(&counters, 1);
        rows.push(BuilderRow {
            strategy: name,
            build_s: b.median_s,
            sim_query_s: sim,
            surface_area: bvh.total_surface_area(),
        });
    }
    rows
}

pub fn render_builder(rows: &[BuilderRow]) -> Table {
    let mut t = Table::new(
        "Ablation: BVH builder strategy (taxi analog)",
        &["strategy", "build", "sim query", "surface area"],
    );
    for r in rows {
        t.row(vec![
            r.strategy.to_string(),
            fmt_secs(r.build_s),
            fmt_secs(r.sim_query_s),
            format!("{:.1}", r.surface_area),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trueknn_beats_rtnn_like_the_paper() {
        // both sides of this ratio are simulated seconds computed from
        // deterministic counters (finalize_sim_time), so the check is
        // load-immune
        let rows = rtnn_cmp(ExpScale::Small, Some(&[1_500]));
        assert!(
            rows[0].speedup() > 1.0,
            "TrueKNN {:.3}s vs RTNN {:.3}s",
            rows[0].trueknn_s,
            rows[0].rtnn_s
        );
    }

    #[test]
    fn refit_is_faster_than_rebuild() {
        // de-flaked: asserts on the counter-driven simulated ratio, not
        // wall-clock, so a loaded CI machine cannot fail it. One untimed
        // build supplies the node count; no bench harness on the test
        // path. The paper's band is 0.75–0.90.
        let n = 20_000usize;
        let ds = build(DatasetKind::Uniform, n);
        let aabbs: Vec<Aabb> = ds
            .points
            .iter()
            .map(|&c| Aabb::around_sphere(c, 0.01))
            .collect();
        let bvh = Bvh::build(&aabbs);
        let model = CostModel::default();
        let sim_ratio = model.refit_cost(bvh.nodes.len() as u64) / model.build_cost(n as u64);
        assert!(
            sim_ratio < 1.0,
            "simulated refit/rebuild ratio {sim_ratio} must be < 1"
        );
        assert!(
            (0.72..=0.92).contains(&sim_ratio),
            "sim ratio {sim_ratio} should sit in the paper's 10–25% band"
        );
        // smoke the bench driver itself (small n, minimal injected bench
        // config so no wall-clock harness spins on the test path): the
        // sim columns it reports must agree with the deterministic claim
        let fast = BenchConfig {
            warmup_iters: 0,
            iters: 1,
        };
        let rows = refit_vs_rebuild_with(&[2_000], &fast);
        assert!(rows[0].sim_ratio().is_finite() && rows[0].sim_ratio() < 1.0);
        assert!(rows[0].refit_s > 0.0 && rows[0].rebuild_s > 0.0);
    }

    #[test]
    fn sah_trades_build_time_for_query_quality() {
        // de-flaked: only counter/geometry assertions (the old wall-clock
        // “sah builds aren't free” clause was load-sensitive), and the
        // bench harness runs a single untimed-quality iteration
        let fast = BenchConfig {
            warmup_iters: 0,
            iters: 1,
        };
        let rows = builder_ablation_with(ExpScale::Small, &fast);
        let median = &rows[0];
        let sah = &rows[1];
        assert!(
            sah.surface_area <= median.surface_area * 1.05,
            "sah trees must not be worse"
        );
        assert!(
            sah.sim_query_s <= median.sim_query_s * 1.05,
            "sah simulated query cost {} must not exceed median {}",
            sah.sim_query_s,
            median.sim_query_s
        );
    }
}
