//! Figure drivers: Fig 3 (speedup vs size), Fig 4 (vs cuML brute force),
//! Fig 5 (impact of k), Fig 6 (round breakdown), Fig 7 (start radius),
//! Fig 8/9 (99th-percentile experiments).

use super::workloads::{build, mid_size, paper_sizes, run_pair, ExpScale, EXP_SEED};
use crate::bench::{fmt_count, fmt_secs, Table};
use crate::configx::KPolicy;
use crate::dataset::DatasetKind;
use crate::index::{Backend, IndexBuilder, IndexConfig, NeighborIndex};
use crate::knn::RoundStats;
use crate::rt::CostModel;

// ---------------------------------------------------------------- Fig 3

/// Fig 3 series: speedup vs dataset size per dataset (k=√N). Reuses the
/// Table 1 sweep rows.
pub fn fig3(rows: &[super::table1::Row]) -> Table {
    let mut t = Table::new(
        "Fig 3: TrueKNN speedup vs baseline while varying dataset size (k=√N)",
        &["dataset", "size", "speedup"],
    );
    for r in rows {
        t.row(vec![
            r.dataset.paper_name().to_string(),
            r.n.to_string(),
            format!("{:.1}x", r.speedup()),
        ]);
    }
    t
}

// ---------------------------------------------------------------- Fig 4

#[derive(Clone, Debug)]
pub struct Fig4Row {
    pub dataset: DatasetKind,
    pub n: usize,
    pub trueknn_wall_s: f64,
    pub brute_wall_s: f64,
    pub brute_path: &'static str,
}

impl Fig4Row {
    pub fn speedup(&self) -> f64 {
        self.brute_wall_s / self.trueknn_wall_s.max(1e-12)
    }
}

/// Fig 4: TrueKNN vs the cuML-analog brute force (PJRT artifacts when
/// available, CPU brute otherwise), k = 5, wall-clock on this testbed.
///
/// Both sides answer the same fixed 1024-query sample per cell (the
/// paper queries all points; per-query cost comparison is unchanged and
/// the full-set PJRT run at 50K would take ~10 min per cell on one core).
pub fn fig4(scale: ExpScale) -> Vec<Fig4Row> {
    let runtime = crate::runtime::PjrtRuntime::load_default().ok();
    let n_queries = 1024usize;
    let mut rows = Vec::new();
    for kind in DatasetKind::PAPER_MAIN {
        for &n in &paper_sizes(scale) {
            let ds = build(kind, n);
            let queries = &ds.points[..n_queries.min(n)];
            let mut t_index = IndexBuilder::new(Backend::TrueKnn)
                .config(IndexConfig {
                    seed: EXP_SEED,
                    exclude_self: false,
                    ..Default::default()
                })
                .build(ds.points.clone());
            let mut t = t_index.knn(queries, 5);
            t_index.build_stats().absorb_into(&mut t, &CostModel::default());
            let (brute_wall, path) = match runtime.as_ref() {
                Some(rt) => {
                    let b = crate::runtime::PjrtBruteForce::new(rt)
                        .knn(&ds.points, queries, 5, false)
                        // lint: allow(panic-in-lib) — experiment driver: a dead runtime should abort the figure run
                        .expect("pjrt brute force");
                    (b.wall_seconds, "pjrt")
                }
                None => {
                    let b = crate::knn::brute::brute_knn(&ds.points, queries, 5, false);
                    (b.wall_seconds, "cpu")
                }
            };
            rows.push(Fig4Row {
                dataset: kind,
                n,
                trueknn_wall_s: t.wall_seconds,
                brute_wall_s: brute_wall,
                brute_path: path,
            });
        }
    }
    rows
}

pub fn render_fig4(rows: &[Fig4Row]) -> Table {
    let mut t = Table::new(
        "Fig 4: TrueKNN speedup vs cuML-analog brute force (k=5, wall-clock)",
        &["dataset", "size", "TrueKNN", "brute", "path", "speedup"],
    );
    for r in rows {
        t.row(vec![
            r.dataset.paper_name().to_string(),
            r.n.to_string(),
            fmt_secs(r.trueknn_wall_s),
            fmt_secs(r.brute_wall_s),
            r.brute_path.to_string(),
            format!("{:.1}x", r.speedup()),
        ]);
    }
    t
}

// ---------------------------------------------------------------- Fig 5

#[derive(Clone, Debug)]
pub struct Fig5Row {
    pub dataset: DatasetKind,
    pub k: usize,
    pub speedup: f64,
}

/// Fig 5: impact of k (5 vs √N) at the mid size.
pub fn fig5(scale: ExpScale) -> Vec<Fig5Row> {
    let n = mid_size(scale);
    let mut rows = Vec::new();
    for kind in DatasetKind::PAPER_MAIN {
        let ds = build(kind, n);
        for k in [5usize, KPolicy::SqrtN.resolve(n)] {
            let out = run_pair(&ds, k, None);
            rows.push(Fig5Row {
                dataset: kind,
                k,
                speedup: out.speedup(),
            });
        }
    }
    rows
}

pub fn render_fig5(rows: &[Fig5Row], n: usize) -> Table {
    let mut t = Table::new(
        &format!("Fig 5: impact of k at {n} points"),
        &["dataset", "k", "speedup"],
    );
    for r in rows {
        t.row(vec![
            r.dataset.paper_name().to_string(),
            r.k.to_string(),
            format!("{:.1}x", r.speedup),
        ]);
    }
    t
}

// ---------------------------------------------------------------- Fig 6

/// Fig 6a/6b: per-round time and surviving query points on the 3DRoad
/// analog (k=5, start radius 0.001 like the paper's §5.4.1).
pub fn fig6(scale: ExpScale) -> Vec<RoundStats> {
    let ds = build(DatasetKind::Road, mid_size(scale));
    let mut index = IndexBuilder::new(Backend::TrueKnn)
        .seed(EXP_SEED)
        .start_radius(0.001)
        .build(ds.points.clone());
    index.knn(&ds.points, 5).rounds
}

pub fn render_fig6(rounds: &[RoundStats]) -> Table {
    let mut t = Table::new(
        "Fig 6: 3DRoad round breakdown (k=5, start radius 0.001)",
        &["round", "radius", "queries", "survivors", "tests", "sim time", "wall"],
    );
    for r in rounds {
        t.row(vec![
            r.round.to_string(),
            format!("{:.4}", r.radius),
            r.queries.to_string(),
            r.survivors.to_string(),
            fmt_count(r.prim_tests),
            fmt_secs(r.sim_seconds),
            fmt_secs(r.wall_seconds),
        ]);
    }
    t
}

// ---------------------------------------------------------------- Fig 7

#[derive(Clone, Debug)]
pub struct Fig7Row {
    pub start_radius: f32,
    pub sim_seconds: f64,
    pub rounds: usize,
}

/// Fig 7: sensitivity to the start radius on the Porto analog (k=√N):
/// sweep the sampled radius scaled by powers of two.
pub fn fig7(scale: ExpScale) -> Vec<Fig7Row> {
    let ds = build(DatasetKind::Taxi, mid_size(scale));
    let k = KPolicy::SqrtN.resolve(ds.len());
    let sampled = crate::knn::random_sample_radius(&ds.points, EXP_SEED);
    let mut rows = Vec::new();
    for scale_pow in [-3i32, -2, -1, 0, 1, 2, 3] {
        let r0 = sampled * (2.0f32).powi(scale_pow);
        let mut index = IndexBuilder::new(Backend::TrueKnn)
            .seed(EXP_SEED)
            .start_radius(r0)
            .build(ds.points.clone());
        let mut res = index.knn(&ds.points, k);
        index.build_stats().absorb_into(&mut res, &CostModel::default());
        rows.push(Fig7Row {
            start_radius: r0,
            sim_seconds: res.sim_seconds,
            rounds: res.rounds.len(),
        });
    }
    rows
}

pub fn render_fig7(rows: &[Fig7Row]) -> Table {
    let mut t = Table::new(
        "Fig 7: impact of start radius selection (Porto analog, k=√N)",
        &["start radius", "sim time", "rounds"],
    );
    for r in rows {
        t.row(vec![
            format!("{:.6}", r.start_radius),
            fmt_secs(r.sim_seconds),
            r.rounds.to_string(),
        ]);
    }
    t
}

// ------------------------------------------------------------ Fig 8 & 9

#[derive(Clone, Debug)]
pub struct PctRow {
    pub dataset: DatasetKind,
    pub n: usize,
    pub k: usize,
    pub speedup: f64,
}

/// Fig 8: 99th-percentile fixed-radius search, k=√N, on the three
/// outlier-bearing 3D-capable datasets (paper uses Porto/3DIono/KITTI).
pub fn fig8(scale: ExpScale) -> Vec<PctRow> {
    let mut rows = Vec::new();
    let sizes = &paper_sizes(scale)[..4];
    for kind in [DatasetKind::Taxi, DatasetKind::Iono, DatasetKind::Lidar] {
        for &n in sizes {
            let ds = build(kind, n);
            let k = KPolicy::SqrtN.resolve(n);
            let out = run_pair(&ds, k, Some(99.0));
            rows.push(PctRow {
                dataset: kind,
                n,
                k,
                speedup: out.speedup(),
            });
        }
    }
    rows
}

/// Fig 9: the same experiment with k=5 on 3DIono — the paper's honest
/// negative result (TrueKNN up to 1.6× *slower*: per-round overheads
/// don't amortize, §6.1).
pub fn fig9(scale: ExpScale) -> Vec<PctRow> {
    let mut rows = Vec::new();
    let sizes = &paper_sizes(scale)[..4];
    for &n in sizes {
        let ds = build(DatasetKind::Iono, n);
        let out = run_pair(&ds, 5, Some(99.0));
        rows.push(PctRow {
            dataset: DatasetKind::Iono,
            n,
            k: 5,
            speedup: out.speedup(),
        });
    }
    rows
}

pub fn render_pct(rows: &[PctRow], title: &str) -> Table {
    let mut t = Table::new(title, &["dataset", "size", "k", "speedup"]);
    for r in rows {
        t.row(vec![
            r.dataset.paper_name().to_string(),
            r.n.to_string(),
            r.k.to_string(),
            format!("{:.2}x", r.speedup),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::knn::{trueknn, TrueKnnParams};

    #[test]
    fn fig6_rounds_shrink_and_radius_doubles() {
        let ds = build(DatasetKind::Road, 1_500);
        let res = trueknn(
            &ds.points,
            &ds.points,
            &TrueKnnParams {
                k: 5,
                start_radius: Some(0.001),
                seed: EXP_SEED,
                ..Default::default()
            },
        );
        let rounds = res.rounds;
        assert!(rounds.len() >= 2);
        for w in rounds.windows(2) {
            assert!(w[1].queries <= w[0].queries);
        }
        // last round queries only the stragglers (paper: 3 points)
        let last = rounds.last().unwrap();
        assert!(
            last.queries < rounds[0].queries / 10,
            "last round queries {} vs first {}",
            last.queries,
            rounds[0].queries
        );
    }

    #[test]
    fn fig7_start_radius_barely_matters() {
        // tiny version of Fig 7: sim time across ±2 octaves must stay
        // within a small factor of the best
        let ds = build(DatasetKind::Taxi, 1_200);
        let sampled = crate::knn::random_sample_radius(&ds.points, EXP_SEED);
        let mut times = Vec::new();
        for pow in [-2i32, 0, 2] {
            let res = trueknn(
                &ds.points,
                &ds.points,
                &TrueKnnParams {
                    k: 10,
                    start_radius: Some(sampled * (2.0f32).powi(pow)),
                    seed: EXP_SEED,
                    ..Default::default()
                },
            );
            times.push(res.sim_seconds);
        }
        let best = times.iter().cloned().fold(f64::INFINITY, f64::min);
        let worst = times.iter().cloned().fold(0.0, f64::max);
        assert!(
            worst / best < 5.0,
            "start radius should be a minor effect: {times:?}"
        );
    }

    #[test]
    fn fig9_shape_small_k_iono_is_close() {
        // the paper's negative result: with k=5 and the tight 99th-pct
        // radius on 3DIono, TrueKNN's advantage collapses (can invert).
        // Shape check: speedup is small — far below the taxi sqrtN case.
        // Both speedups are counter-driven simulated ratios (run_pair
        // finalizes sim time from HwCounters), so a loaded machine
        // cannot flip this.
        let iono = run_pair(&build(DatasetKind::Iono, 1_500), 5, Some(99.0));
        let taxi = run_pair(&build(DatasetKind::Taxi, 1_500), 38, None);
        assert!(
            iono.speedup() < taxi.speedup() / 2.0,
            "iono p99 k=5 {:.2}x should collapse vs taxi {:.2}x",
            iono.speedup(),
            taxi.speedup()
        );
    }
}
