//! Experiment drivers — one per table/figure in the paper's evaluation
//! (§5) plus the §5.3.1 RTNN comparison and the §4 refit ablation.
//! DESIGN.md §6 maps each to its bench target.

pub mod workloads;
pub mod table1;
pub mod table2;
pub mod table3;
pub mod figures;
pub mod ablations;

pub use workloads::{paper_sizes, ExpScale};
