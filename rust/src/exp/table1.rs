//! Table 1 + Fig 3: execution time of TrueKNN vs the maxDist baseline
//! across the four main datasets and the size sweep, k = √DatasetSize.

use super::workloads::{build, paper_sizes, run_pair, ExpScale};
use crate::bench::{fmt_count, fmt_secs, Table};
use crate::configx::KPolicy;
use crate::dataset::DatasetKind;

#[derive(Clone, Debug)]
pub struct Row {
    pub dataset: DatasetKind,
    pub n: usize,
    pub k: usize,
    pub trueknn_s: f64,
    pub baseline_s: f64,
    pub trueknn_wall_s: f64,
    pub baseline_wall_s: f64,
    pub trueknn_tests: u64,
    pub baseline_tests: u64,
    pub rounds: usize,
}

impl Row {
    pub fn speedup(&self) -> f64 {
        self.baseline_s / self.trueknn_s.max(1e-12)
    }
}

/// Run the full sweep. `k_policy` is √N for Table 1 / Fig 3 and 5 for
/// the Fig 4/5 variants.
pub fn run(scale: ExpScale, k_policy: KPolicy) -> Vec<Row> {
    let mut rows = Vec::new();
    for kind in DatasetKind::PAPER_MAIN {
        for &n in &paper_sizes(scale) {
            let ds = build(kind, n);
            let k = k_policy.resolve(n);
            let out = run_pair(&ds, k, None);
            crate::log_info!(
                "table1: {} n={} k={} speedup {:.1}x",
                kind.name(),
                n,
                k,
                out.speedup()
            );
            rows.push(Row {
                dataset: kind,
                n,
                k,
                trueknn_s: out.trueknn.sim_seconds,
                baseline_s: out.baseline.sim_seconds,
                trueknn_wall_s: out.trueknn.wall_seconds,
                baseline_wall_s: out.baseline.wall_seconds,
                trueknn_tests: out.trueknn.counters.prim_tests,
                baseline_tests: out.baseline.counters.prim_tests,
                rounds: out.trueknn.rounds.len(),
            });
        }
    }
    rows
}

/// Render in the paper's Table 1 shape (per-dataset TrueKNN/Baseline
/// columns, one row per size), on simulated GPU seconds.
pub fn render(rows: &[Row]) -> Table {
    let mut t = Table::new(
        "Table 1: execution time, TrueKNN vs baseline (simulated GPU s; k=√N)",
        &[
            "size", "dataset", "k", "TrueKNN", "Baseline", "speedup", "rounds",
            "tests(T)", "tests(B)",
        ],
    );
    for r in rows {
        t.row(vec![
            r.n.to_string(),
            r.dataset.paper_name().to_string(),
            r.k.to_string(),
            fmt_secs(r.trueknn_s),
            fmt_secs(r.baseline_s),
            format!("{:.1}x", r.speedup()),
            r.rounds.to_string(),
            fmt_count(r.trueknn_tests),
            fmt_count(r.baseline_tests),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exp::workloads::run_pair;

    #[test]
    fn trueknn_wins_on_main_datasets() {
        // Miniature version of the sweep: one size, all four datasets.
        // Sizes below ~4K sit under the crossover where per-round fixed
        // costs (context switches) dominate — the same effect the paper
        // documents in §6.1/Fig 9 — so the check runs at 5K. The speedup
        // is a counter-driven simulated ratio: deterministic under load.
        for kind in DatasetKind::PAPER_MAIN {
            let ds = build(kind, 5_000);
            let k = KPolicy::SqrtN.resolve(5_000);
            let out = run_pair(&ds, k, None);
            assert!(
                out.speedup() > 1.0,
                "{kind:?}: speedup {} should exceed 1",
                out.speedup()
            );
        }
    }

    #[test]
    fn render_has_one_row_per_cell() {
        let rows = vec![Row {
            dataset: DatasetKind::Taxi,
            n: 1000,
            k: 31,
            trueknn_s: 0.5,
            baseline_s: 5.0,
            trueknn_wall_s: 0.1,
            baseline_wall_s: 0.9,
            trueknn_tests: 100,
            baseline_tests: 900,
            rounds: 7,
        }];
        let t = render(&rows);
        assert_eq!(t.rows.len(), 1);
        assert!(t.render().contains("10.0x"));
    }
}
