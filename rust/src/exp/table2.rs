//! Table 2: number of ray-sphere ("ray-object") intersection tests on
//! the Porto analog, TrueKNN vs baseline — the paper's direct evidence
//! for where the speedup comes from (§5.3.1).

use super::workloads::{build, paper_sizes, run_pair, ExpScale};
use crate::bench::{fmt_count, Table};
use crate::configx::KPolicy;
use crate::dataset::DatasetKind;

#[derive(Clone, Debug)]
pub struct Row {
    pub n: usize,
    pub trueknn_tests: u64,
    pub baseline_tests: u64,
}

impl Row {
    pub fn ratio(&self) -> f64 {
        self.baseline_tests as f64 / self.trueknn_tests.max(1) as f64
    }
}

pub fn run(scale: ExpScale) -> Vec<Row> {
    let mut rows = Vec::new();
    for &n in &paper_sizes(scale) {
        let ds = build(DatasetKind::Taxi, n);
        let k = KPolicy::SqrtN.resolve(n);
        let out = run_pair(&ds, k, None);
        rows.push(Row {
            n,
            trueknn_tests: out.trueknn.counters.prim_tests,
            baseline_tests: out.baseline.counters.prim_tests,
        });
    }
    rows
}

pub fn render(rows: &[Row]) -> Table {
    let mut t = Table::new(
        "Table 2: ray-sphere intersection tests, Porto analog (k=√N)",
        &["size", "TrueKNN", "Baseline", "ratio"],
    );
    for r in rows {
        t.row(vec![
            r.n.to_string(),
            fmt_count(r.trueknn_tests),
            fmt_count(r.baseline_tests),
            format!("{:.1}x", r.ratio()),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ratio_grows_with_size_like_the_paper() {
        // Paper: 9x at 100K growing to 32x at 1M. Shape check at 1/50
        // scale: the ratio must exceed 1 and grow from the smallest to
        // the largest size.
        let sizes = [1_000usize, 4_000];
        let mut ratios = Vec::new();
        for &n in &sizes {
            let ds = build(DatasetKind::Taxi, n);
            let k = KPolicy::SqrtN.resolve(n);
            let out = run_pair(&ds, k, None);
            ratios.push(out.test_ratio());
        }
        assert!(ratios[0] > 1.0, "ratios {ratios:?}");
        assert!(
            ratios[1] > ratios[0],
            "ratio must grow with n: {ratios:?}"
        );
    }
}
