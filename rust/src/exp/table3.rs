//! Table 3: TrueKNN speedup on the uniformly-distributed dataset — the
//! paper's worst case (no blatant outliers), both the unbounded kNNS
//! problem and the 99th-percentile variant (§5.5.2).

use super::workloads::{build, paper_sizes, run_pair, ExpScale};
use crate::bench::Table;
use crate::configx::KPolicy;
use crate::dataset::DatasetKind;

#[derive(Clone, Debug)]
pub struct Row {
    pub n: usize,
    pub knns_speedup: f64,
    pub p99_speedup: f64,
}

pub fn run(scale: ExpScale) -> Vec<Row> {
    let mut rows = Vec::new();
    // the paper sweeps 100K–800K here (four sizes)
    let sizes = &paper_sizes(scale)[..4];
    for &n in sizes {
        let ds = build(DatasetKind::Uniform, n);
        let k = KPolicy::SqrtN.resolve(n);
        let plain = run_pair(&ds, k, None);
        let p99 = run_pair(&ds, k, Some(99.0));
        rows.push(Row {
            n,
            knns_speedup: plain.speedup(),
            p99_speedup: p99.speedup(),
        });
    }
    rows
}

pub fn render(rows: &[Row]) -> Table {
    let mut t = Table::new(
        "Table 3: TrueKNN speedup on UniformDist (k=√N)",
        &["size", "kNNS", "99th-pct kNNS"],
    );
    for r in rows {
        t.row(vec![
            r.n.to_string(),
            format!("{:.2}x", r.knns_speedup),
            format!("{:.2}x", r.p99_speedup),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_wins_are_modest_but_real() {
        // Paper: 3.2–4.3x on kNNS, 1.2–1.8x on 99th pct — the smallest
        // margins of any dataset. Shape check: >1x on kNNS, and smaller
        // than the taxi speedup at the same size.
        // must sit above the small-n crossover (see table1 test note)
        let n = 6_000;
        let k = KPolicy::SqrtN.resolve(n);
        let uni = run_pair(&build(DatasetKind::Uniform, n), k, None);
        let taxi = run_pair(&build(DatasetKind::Taxi, n), k, None);
        assert!(uni.speedup() > 1.0, "uniform speedup {}", uni.speedup());
        assert!(
            taxi.speedup() > uni.speedup(),
            "outlier-heavy taxi ({:.1}x) must beat uniform ({:.1}x)",
            taxi.speedup(),
            uni.speedup()
        );
    }
}
