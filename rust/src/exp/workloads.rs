//! Shared experiment workloads: dataset sizes, k policies, and the
//! TrueKNN-vs-baseline pair runner every table/figure builds on.
//!
//! Scaling note (DESIGN.md §4): the paper sweeps 100K–1M points on an
//! RTX 2060. This testbed is a single CPU core running the RT-core
//! *simulator*, so the default sweep keeps the paper's ×10 span and both
//! k regimes at 1/20th the magnitude; `TRUEKNN_SCALE=full` restores
//! paper-scale sizes (slow: the baseline is intentionally O(n²) at
//! maxDist radius — that inefficiency is the paper's whole point).

use crate::configx::KPolicy;
use crate::dataset::{Dataset, DatasetKind, DistanceProfile};
use crate::index::{Backend, IndexBuilder, IndexConfig, NeighborIndex};
use crate::knn::KnnResult;
use crate::rt::CostModel;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExpScale {
    /// Default: 5K–50K points (×10 span like the paper's 100K–1M).
    Small,
    /// Paper-scale: 100K–1M (hours on one core; same code path).
    Full,
}

impl ExpScale {
    pub fn from_env() -> ExpScale {
        match std::env::var("TRUEKNN_SCALE").as_deref() {
            Ok("full") => ExpScale::Full,
            _ => ExpScale::Small,
        }
    }
}

/// The five sweep sizes of Table 1 / Fig 3, scaled.
pub fn paper_sizes(scale: ExpScale) -> Vec<usize> {
    match scale {
        ExpScale::Small => vec![5_000, 10_000, 20_000, 40_000, 50_000],
        ExpScale::Full => vec![100_000, 200_000, 400_000, 800_000, 1_000_000],
    }
}

/// The "400K" single-size experiments (Fig 5/6/7), scaled.
pub fn mid_size(scale: ExpScale) -> usize {
    match scale {
        ExpScale::Small => 20_000,
        ExpScale::Full => 400_000,
    }
}

pub const EXP_SEED: u64 = 20230621; // ICS'23 conference date

/// A TrueKNN-vs-baseline pair on one workload. The baseline radius is
/// the paper's best case: exactly maxDist (§5.2.1), or the given
/// percentile for the §5.5.1 variants.
pub struct PairOutcome {
    pub trueknn: KnnResult,
    pub baseline: KnnResult,
    pub max_dist: f64,
    pub radius_used: f64,
    pub k: usize,
    pub n: usize,
}

impl PairOutcome {
    /// Speedup by simulated GPU time (the paper's metric).
    pub fn speedup(&self) -> f64 {
        self.trueknn.sim_seconds.max(1e-12).recip() * self.baseline.sim_seconds
    }

    pub fn test_ratio(&self) -> f64 {
        self.baseline.counters.prim_tests as f64
            / self.trueknn.counters.prim_tests.max(1) as f64
    }
}

/// Run the canonical pair: TrueKNN (unbounded or percentile-capped) vs
/// fixed-radius baseline at the matching radius. Both sides go through
/// the index API; the one-time build is folded back into each result so
/// rows report build + query like the paper does.
pub fn run_pair(ds: &Dataset, k: usize, percentile: Option<f64>) -> PairOutcome {
    let prof = DistanceProfile::compute(ds, k);
    let max_dist = prof.max_dist();
    let radius_used = match percentile {
        Some(p) => prof.percentile_dist(p),
        None => max_dist,
    };
    // epsilon-inflate so f32 rounding can't miss the farthest neighbor
    let radius_f = (radius_used * 1.0001) as f32;
    let model = CostModel::default();

    let mut t_index = IndexBuilder::new(Backend::TrueKnn)
        .config(IndexConfig {
            seed: EXP_SEED,
            radius_cap: percentile.map(|_| radius_f),
            ..Default::default()
        })
        .build(ds.points.clone());
    let mut t = t_index.knn(&ds.points, k);
    t_index.build_stats().absorb_into(&mut t, &model);

    let mut b_index = IndexBuilder::new(Backend::FixedRadius)
        .radius(radius_f)
        .build(ds.points.clone());
    let mut b = b_index.knn(&ds.points, k);
    b_index.build_stats().absorb_into(&mut b, &model);

    PairOutcome {
        trueknn: t,
        baseline: b,
        max_dist,
        radius_used,
        k,
        n: ds.len(),
    }
}

/// Build a dataset for an experiment row.
pub fn build(kind: DatasetKind, n: usize) -> Dataset {
    kind.generate(n, EXP_SEED)
}

pub fn resolve_k(policy: KPolicy, n: usize) -> usize {
    policy.resolve(n)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sizes_span_10x() {
        for scale in [ExpScale::Small, ExpScale::Full] {
            let s = paper_sizes(scale);
            assert_eq!(s.len(), 5);
            assert_eq!(s[4] / s[0], 10);
        }
    }

    #[test]
    fn pair_outcome_on_tiny_taxi() {
        let ds = build(DatasetKind::Taxi, 1_500);
        let out = run_pair(&ds, 5, None);
        // both must be complete at maxDist / unbounded
        assert!(out.trueknn.is_complete(5, ds.len() - 1));
        assert!(out.baseline.is_complete(5, ds.len() - 1));
        // the paper's headline: TrueKNN does far fewer tests
        assert!(out.test_ratio() > 1.5, "ratio {}", out.test_ratio());
        assert!(out.speedup() > 1.0, "speedup {}", out.speedup());
    }

    #[test]
    fn percentile_pair_caps_radius() {
        let ds = build(DatasetKind::Taxi, 1_500);
        let out = run_pair(&ds, 5, Some(99.0));
        assert!(out.radius_used < out.max_dist);
        // capped TrueKNN leaves outliers short, same as the capped baseline
        let t_short = out.trueknn.neighbors.iter().filter(|n| n.len() < 5).count();
        assert!(t_short > 0);
    }
}
