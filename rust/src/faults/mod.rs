//! Seeded fault injection for the coordinator: deterministic,
//! replayable failure plans (std-only, **default inert**).
//!
//! A [`FaultPlan`] names *where* the service misbehaves — worker panics,
//! reply delays and queue stalls keyed by `(worker, batch sequence)`,
//! plus an optional poisoned request id that kills its worker every time
//! it is drained. The plan is pure data: the worker loop consults it at
//! fixed points and the plan never mutates, so the same plan over the
//! same request log reproduces the same crashes, the same restarts and
//! the same recovery counters on every run. That is what turns the
//! supervision layer's recovery paths (restart, deterministic rebuild,
//! submit-order replay, scatter failover, poison quarantine) into
//! ordinary assertable tests instead of hope.
//!
//! Batch sequence numbers are **per-worker and monotonic across
//! restarts** (they never reset when a worker is rebuilt), so a panic
//! scheduled at sequence `s` fires exactly once: the replayed batch
//! drains at a later sequence and sails past the trigger. A poisoned
//! request, by contrast, is matched by id and fires on every attempt —
//! exactly the crash loop the service's poison ledger must break.
//!
//! The default plan ([`FaultPlan::inert`], also `Default`) injects
//! nothing and is what every production configuration carries; plans
//! only become active when a test, the fault-injection CI leg
//! (`TRUEKNN_FAULT_SEED`) or the PR 7 bench installs one explicitly.

use crate::util::rng::Pcg32;

/// Panic payload of an injected crash: the worker loop raises it with
/// [`std::panic::panic_any`] when a plan's trigger fires, so the
/// supervisor (and anyone reading a test log) can tell a scheduled
/// fault from a genuine bug's `panic!` message.
#[derive(Clone, Copy, Debug)]
pub struct InjectedFault;

/// Upper bound a seeded plan uses for its panic trigger sequence, so an
/// injected crash lands within the first few batches of a test log.
const SEEDED_MAX_SEQ: u64 = 3;

/// Injected sleep length of a seeded reply delay, in milliseconds —
/// long enough to reorder deliveries, short enough for CI.
const SEEDED_DELAY_MS: u64 = 2;

/// Injected sleep length of a seeded queue stall, in milliseconds —
/// long enough to trip a test-sized heartbeat deadline.
const SEEDED_STALL_MS: u64 = 80;

/// Which persisted artifact a seeded I/O fault targets (see
/// [`IoFault`]): the checksummed index snapshot file or the durable
/// insert write-ahead log.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IoTarget {
    /// The `snapshot-*.tksn` container the worker writes.
    Snapshot,
    /// The `wal.log` append-only insert log.
    Wal,
}

/// One scheduled persistence-path I/O fault. These simulate the storage
/// failures the recovery layer must detect — a crash mid-write (torn
/// tail), a partially readable file, a silently flipped bit — and are
/// applied by the persist helpers themselves
/// ([`crate::persist::atomic_write`] / [`crate::persist::read_file`] /
/// the WAL append path), so the corruption lands in exactly the bytes a
/// real fault would hit while the plan stays pure data.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IoFault {
    /// Write only the first `keep` bytes of the `op`-th write to
    /// `target` (per-target op counters start at 1). Simulates a crash
    /// mid-write: the file ends in a torn record/blob the reader must
    /// truncate or reject.
    TornWrite {
        /// Victim artifact.
        target: IoTarget,
        /// 1-based per-target write-operation index the fault fires at.
        op: u64,
        /// Bytes actually written before the simulated crash.
        keep: usize,
    },
    /// Every read of `target` returns only its first `keep` bytes.
    ShortRead {
        /// Victim artifact.
        target: IoTarget,
        /// Bytes the read yields before the simulated truncation.
        keep: usize,
    },
    /// Flip one bit of byte `at` (modulo the payload length) in every
    /// write to `target`. Simulates silent media corruption the
    /// checksums must catch.
    FlipByte {
        /// Victim artifact.
        target: IoTarget,
        /// Byte offset to corrupt, taken modulo the payload length.
        at: usize,
    },
}

/// One scheduled fault: a kind, a victim worker and the per-worker
/// batch sequence number it triggers at.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Fault {
    /// Panic the worker right before it serves this batch.
    Panic {
        /// Victim worker id.
        worker: usize,
        /// Per-worker batch sequence the panic triggers at.
        seq: u64,
    },
    /// Sleep after computing a batch, before sending its replies.
    ReplyDelay {
        /// Victim worker id.
        worker: usize,
        /// Per-worker batch sequence the delay triggers at.
        seq: u64,
        /// Sleep length in milliseconds.
        millis: u64,
    },
    /// Sleep before serving a batch: the queue backs up and the worker's
    /// heartbeat goes stale, exercising the supervisor's failover path.
    QueueStall {
        /// Victim worker id.
        worker: usize,
        /// Per-worker batch sequence the stall triggers at.
        seq: u64,
        /// Sleep length in milliseconds.
        millis: u64,
    },
}

/// A deterministic, replayable fault schedule for the worker pool.
///
/// See the module docs for the trigger model. Construct with
/// [`FaultPlan::inert`] (no faults), the explicit `with_*` builders, or
/// [`FaultPlan::seeded`] for a reproducible pseudo-random plan.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct FaultPlan {
    faults: Vec<Fault>,
    /// Request id that panics its worker on **every** drain attempt.
    poison: Option<u64>,
    /// Scheduled persistence-path I/O faults (see [`IoFault`]).
    io: Vec<IoFault>,
}

impl FaultPlan {
    /// The empty plan: injects nothing. This is the default every
    /// service configuration ships with.
    pub fn inert() -> Self {
        Self::default()
    }

    /// True when this plan can never fire (the production fast path).
    pub fn is_inert(&self) -> bool {
        self.faults.is_empty() && self.poison.is_none() && self.io.is_empty()
    }

    /// Schedule a panic on `worker` at its batch sequence `seq`.
    pub fn with_panic(mut self, worker: usize, seq: u64) -> Self {
        self.faults.push(Fault::Panic { worker, seq });
        self
    }

    /// Schedule a reply delay of `millis` on `worker` at sequence `seq`.
    pub fn with_reply_delay(mut self, worker: usize, seq: u64, millis: u64) -> Self {
        self.faults.push(Fault::ReplyDelay { worker, seq, millis });
        self
    }

    /// Schedule a queue stall of `millis` on `worker` at sequence `seq`.
    pub fn with_queue_stall(mut self, worker: usize, seq: u64, millis: u64) -> Self {
        self.faults.push(Fault::QueueStall { worker, seq, millis });
        self
    }

    /// Mark request id `id` as poisoned: every batch containing it
    /// panics its worker, until the service's poison ledger quarantines
    /// the request after the second kill.
    pub fn with_poison(mut self, id: u64) -> Self {
        self.poison = Some(id);
        self
    }

    /// Schedule a torn write: the `op`-th write to `target` persists
    /// only its first `keep` bytes (see [`IoFault::TornWrite`]).
    pub fn with_torn_write(mut self, target: IoTarget, op: u64, keep: usize) -> Self {
        self.io.push(IoFault::TornWrite { target, op, keep });
        self
    }

    /// Schedule a short read: reads of `target` yield only the first
    /// `keep` bytes (see [`IoFault::ShortRead`]).
    pub fn with_short_read(mut self, target: IoTarget, keep: usize) -> Self {
        self.io.push(IoFault::ShortRead { target, keep });
        self
    }

    /// Schedule a flipped byte: every write to `target` has one bit of
    /// byte `at` (mod length) inverted (see [`IoFault::FlipByte`]).
    pub fn with_flip_byte(mut self, target: IoTarget, at: usize) -> Self {
        self.io.push(IoFault::FlipByte { target, at });
        self
    }

    /// Derive a reproducible pseudo-random plan for a pool of `workers`
    /// workers: one panic, one reply delay and one queue stall, each on
    /// an independently chosen victim within the first few batches. The
    /// same `(seed, workers)` always yields the same plan.
    pub fn seeded(seed: u64, workers: usize) -> Self {
        let w = workers.max(1);
        let mut rng = Pcg32::new(seed);
        let mut pick =
            |rng: &mut Pcg32| (rng.below_usize(w), 1 + rng.next_u64() % SEEDED_MAX_SEQ);
        let (pw, ps) = pick(&mut rng);
        let (dw, ds) = pick(&mut rng);
        let (sw, ss) = pick(&mut rng);
        FaultPlan::inert()
            .with_panic(pw, ps)
            .with_reply_delay(dw, ds, SEEDED_DELAY_MS)
            .with_queue_stall(sw, ss, SEEDED_STALL_MS)
    }

    /// Derive a reproducible pseudo-random **I/O** fault plan: exactly
    /// one of torn-write / short-read / flip-byte against one of the
    /// two persisted artifacts, with small seed-derived offsets. The
    /// same seed always yields the same plan; worker-loop faults are
    /// left empty so the plan exercises only the persistence paths.
    pub fn seeded_io(seed: u64) -> Self {
        let mut rng = Pcg32::new(seed);
        let target = if rng.next_u32() % 2 == 0 { IoTarget::Wal } else { IoTarget::Snapshot };
        match rng.next_u32() % 3 {
            0 => {
                // tear an early write a few bytes in
                let op = 1 + rng.next_u64() % 2;
                let keep = rng.below_usize(12);
                FaultPlan::inert().with_torn_write(target, op, keep)
            }
            1 => FaultPlan::inert().with_short_read(target, rng.below_usize(96)),
            _ => FaultPlan::inert().with_flip_byte(target, rng.below_usize(256)),
        }
    }

    /// The seed pinned by the fault-injection CI leg, if any: parses
    /// `TRUEKNN_FAULT_SEED` (decimal). Unset or unparsable = `None`.
    ///
    /// This is the lenient library-side reader; the `serve` CLI goes
    /// through [`crate::cli::env_parse`] instead, which turns a
    /// malformed value into a typed error rather than a silently
    /// disarmed plan.
    pub fn env_seed() -> Option<u64> {
        std::env::var("TRUEKNN_FAULT_SEED")
            .ok()
            .and_then(|v| v.trim().parse().ok())
    }

    /// Number of scheduled panics (the restart count a fully exercised
    /// plan produces, poison crashes excluded).
    pub fn panic_count(&self) -> usize {
        self.faults
            .iter()
            .filter(|f| matches!(f, Fault::Panic { .. }))
            .count()
    }

    /// The poisoned request id, if the plan carries one.
    pub fn poison_id(&self) -> Option<u64> {
        self.poison
    }

    /// Every scheduled fault, in insertion order.
    pub fn faults(&self) -> &[Fault] {
        &self.faults
    }

    /// Should `worker` panic right before serving batch `seq`?
    pub fn should_panic(&self, worker: usize, seq: u64) -> bool {
        self.faults
            .iter()
            .any(|f| matches!(f, Fault::Panic { worker: w, seq: s } if *w == worker && *s == seq))
    }

    /// Injected reply delay for `(worker, seq)`, in milliseconds.
    pub fn reply_delay_ms(&self, worker: usize, seq: u64) -> Option<u64> {
        self.faults.iter().find_map(|f| match f {
            Fault::ReplyDelay { worker: w, seq: s, millis } if *w == worker && *s == seq => {
                Some(*millis)
            }
            _ => None,
        })
    }

    /// Injected queue stall for `(worker, seq)`, in milliseconds.
    pub fn queue_stall_ms(&self, worker: usize, seq: u64) -> Option<u64> {
        self.faults.iter().find_map(|f| match f {
            Fault::QueueStall { worker: w, seq: s, millis } if *w == worker && *s == seq => {
                Some(*millis)
            }
            _ => None,
        })
    }

    /// Does this plan poison any of the given request ids?
    pub fn poisons_any<I: IntoIterator<Item = u64>>(&self, ids: I) -> bool {
        match self.poison {
            Some(p) => ids.into_iter().any(|id| id == p),
            None => false,
        }
    }

    /// Every scheduled I/O fault, in insertion order.
    pub fn io_faults(&self) -> &[IoFault] {
        &self.io
    }

    /// Bytes the `op`-th write to `target` should keep, if a torn write
    /// is scheduled there.
    pub fn torn_write(&self, target: IoTarget, op: u64) -> Option<usize> {
        self.io.iter().find_map(|f| match f {
            IoFault::TornWrite { target: t, op: o, keep } if *t == target && *o == op => {
                Some(*keep)
            }
            _ => None,
        })
    }

    /// Bytes a read of `target` should yield, if a short read is
    /// scheduled there.
    pub fn short_read(&self, target: IoTarget) -> Option<usize> {
        self.io.iter().find_map(|f| match f {
            IoFault::ShortRead { target: t, keep } if *t == target => Some(*keep),
            _ => None,
        })
    }

    /// Byte offset to corrupt in writes to `target`, if a flipped byte
    /// is scheduled there.
    pub fn flip_byte(&self, target: IoTarget) -> Option<usize> {
        self.io.iter().find_map(|f| match f {
            IoFault::FlipByte { target: t, at } if *t == target => Some(*at),
            _ => None,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_plan_is_inert() {
        let p = FaultPlan::default();
        assert!(p.is_inert());
        assert_eq!(p.panic_count(), 0);
        assert!(!p.should_panic(0, 0));
        assert_eq!(p.reply_delay_ms(0, 0), None);
        assert_eq!(p.queue_stall_ms(0, 0), None);
        assert!(!p.poisons_any([0, 1, 2]));
    }

    #[test]
    fn explicit_triggers_match_exactly_once_coordinates() {
        let p = FaultPlan::inert()
            .with_panic(1, 2)
            .with_reply_delay(0, 3, 7)
            .with_queue_stall(2, 1, 50)
            .with_poison(42);
        assert!(!p.is_inert());
        assert!(p.should_panic(1, 2));
        assert!(!p.should_panic(1, 3), "replayed batch must sail past");
        assert!(!p.should_panic(0, 2), "wrong worker must not trip");
        assert_eq!(p.reply_delay_ms(0, 3), Some(7));
        assert_eq!(p.queue_stall_ms(2, 1), Some(50));
        assert_eq!(p.poison_id(), Some(42));
        assert!(p.poisons_any([7, 42]));
        assert!(!p.poisons_any([7, 8]));
        assert_eq!(p.panic_count(), 1);
    }

    #[test]
    fn seeded_plans_are_reproducible_and_in_range() {
        let a = FaultPlan::seeded(0xF00D, 4);
        let b = FaultPlan::seeded(0xF00D, 4);
        assert_eq!(a, b, "same (seed, workers) must yield the same plan");
        assert_ne!(a, FaultPlan::seeded(0xF00E, 4));
        assert_eq!(a.panic_count(), 1);
        assert_eq!(a.faults().len(), 3);
        for f in a.faults() {
            let (w, s) = match *f {
                Fault::Panic { worker, seq } => (worker, seq),
                Fault::ReplyDelay { worker, seq, .. } => (worker, seq),
                Fault::QueueStall { worker, seq, .. } => (worker, seq),
            };
            assert!(w < 4);
            assert!((1..=SEEDED_MAX_SEQ).contains(&s));
        }
    }

    #[test]
    fn io_faults_match_their_target_and_op() {
        let p = FaultPlan::inert()
            .with_torn_write(IoTarget::Wal, 3, 5)
            .with_short_read(IoTarget::Snapshot, 64)
            .with_flip_byte(IoTarget::Snapshot, 17);
        assert!(!p.is_inert());
        assert_eq!(p.torn_write(IoTarget::Wal, 3), Some(5));
        assert_eq!(p.torn_write(IoTarget::Wal, 4), None, "wrong op must not trip");
        assert_eq!(p.torn_write(IoTarget::Snapshot, 3), None, "wrong target must not trip");
        assert_eq!(p.short_read(IoTarget::Snapshot), Some(64));
        assert_eq!(p.short_read(IoTarget::Wal), None);
        assert_eq!(p.flip_byte(IoTarget::Snapshot), Some(17));
        assert_eq!(p.flip_byte(IoTarget::Wal), None);
        assert_eq!(p.io_faults().len(), 3);
        assert_eq!(p.panic_count(), 0, "io faults are not worker-loop faults");
    }

    #[test]
    fn seeded_io_plans_are_reproducible_and_single_fault() {
        let a = FaultPlan::seeded_io(0xBEEF);
        assert_eq!(a, FaultPlan::seeded_io(0xBEEF));
        assert_eq!(a.io_faults().len(), 1);
        assert!(a.faults().is_empty(), "seeded_io must not schedule worker faults");
        // across a seed sweep every fault kind appears (guards against a
        // degenerate derivation that always picks the same arm)
        let mut kinds = [false; 3];
        for seed in 0..64u64 {
            match FaultPlan::seeded_io(seed).io_faults()[0] {
                IoFault::TornWrite { .. } => kinds[0] = true,
                IoFault::ShortRead { .. } => kinds[1] = true,
                IoFault::FlipByte { .. } => kinds[2] = true,
            }
        }
        assert_eq!(kinds, [true; 3]);
    }

    #[test]
    fn env_seed_parses_decimal() {
        // avoid mutating the process env (tests run in parallel): only
        // assert the unset/garbage behavior through the parser contract
        assert_eq!("20260808".trim().parse::<u64>().ok(), Some(20260808));
        assert_eq!("not-a-seed".trim().parse::<u64>().ok(), None);
    }
}
