//! Axis-aligned bounding box — the bounding volume of the paper's BVH
//! (§2.2.2) and the unit the RT core tests rays against in hardware.

use super::point::Point3;

#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Aabb {
    pub min: Point3,
    pub max: Point3,
}

impl Aabb {
    /// Empty box: grows from nothing under `grow`/`union`.
    pub const EMPTY: Aabb = Aabb {
        min: Point3::splat(f32::INFINITY),
        max: Point3::splat(f32::NEG_INFINITY),
    };

    pub fn new(min: Point3, max: Point3) -> Self {
        Self { min, max }
    }

    /// Box enclosing a sphere of radius `r` at `c` — the paper's
    /// `BoundingBox` program (Alg. 1 line 2).
    #[inline(always)]
    pub fn around_sphere(c: Point3, r: f32) -> Self {
        Self {
            min: c - Point3::splat(r),
            max: c + Point3::splat(r),
        }
    }

    #[inline(always)]
    pub fn grow(&mut self, p: Point3) {
        self.min = self.min.min(p);
        self.max = self.max.max(p);
    }

    #[inline(always)]
    pub fn union(&self, o: &Aabb) -> Aabb {
        Aabb {
            min: self.min.min(o.min),
            max: self.max.max(o.max),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.min.x > self.max.x || self.min.y > self.max.y || self.min.z > self.max.z
    }

    /// Point-in-box test — what the RT core evaluates for the paper's
    /// infinitesimal rays (a ray of length FLOAT_MIN intersects an AABB
    /// iff its origin lies inside it).
    #[inline(always)]
    pub fn contains(&self, p: Point3) -> bool {
        p.x >= self.min.x
            && p.x <= self.max.x
            && p.y >= self.min.y
            && p.y <= self.max.y
            && p.z >= self.min.z
            && p.z <= self.max.z
    }

    pub fn contains_box(&self, o: &Aabb) -> bool {
        o.is_empty()
            || (self.contains(o.min) && self.contains(o.max))
    }

    /// Squared distance from `p` to the closest point of the box (0 when
    /// inside). The shard scatter-gather prune's lower bound: per axis
    /// the gap is computed as a single subtraction, and f32 subtraction
    /// and multiplication are correctly rounded (hence monotone), so for
    /// any point `q` inside the box the computed value never exceeds the
    /// [`super::dist2`]-computed distance to `q` — pruning on it is
    /// exact even at the last representable bit.
    #[inline]
    pub fn dist2_to_point(&self, p: Point3) -> f32 {
        if self.is_empty() {
            return f32::INFINITY;
        }
        let axis_gap = |v: f32, lo: f32, hi: f32| {
            if v < lo {
                lo - v
            } else if v > hi {
                v - hi
            } else {
                0.0
            }
        };
        let dx = axis_gap(p.x, self.min.x, self.max.x);
        let dy = axis_gap(p.y, self.min.y, self.max.y);
        let dz = axis_gap(p.z, self.min.z, self.max.z);
        dx * dx + dy * dy + dz * dz
    }

    pub fn centroid(&self) -> Point3 {
        (self.min + self.max) * 0.5
    }

    pub fn extent(&self) -> Point3 {
        self.max - self.min
    }

    /// Surface area (for the SAH builder).
    pub fn surface_area(&self) -> f32 {
        if self.is_empty() {
            return 0.0;
        }
        let e = self.extent();
        2.0 * (e.x * e.y + e.y * e.z + e.z * e.x)
    }

    /// Index of the widest axis (0, 1 or 2).
    pub fn longest_axis(&self) -> usize {
        let e = self.extent();
        if e.x >= e.y && e.x >= e.z {
            0
        } else if e.y >= e.z {
            1
        } else {
            2
        }
    }

    /// Slab test for a finite ray segment; used by general ray queries
    /// (the paper's kNN rays use the degenerate `contains` form).
    pub fn intersects_ray(&self, origin: Point3, inv_dir: Point3, t_max: f32) -> bool {
        let mut t0 = 0.0f32;
        let mut t1 = t_max;
        for axis in 0..3 {
            let inv = inv_dir[axis];
            let mut near = (self.min[axis] - origin[axis]) * inv;
            let mut far = (self.max[axis] - origin[axis]) * inv;
            if near > far {
                std::mem::swap(&mut near, &mut far);
            }
            t0 = t0.max(near);
            t1 = t1.min(far);
            if t0 > t1 {
                return false;
            }
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_grows_to_point() {
        let mut b = Aabb::EMPTY;
        assert!(b.is_empty());
        b.grow(Point3::new(1.0, 2.0, 3.0));
        assert!(!b.is_empty());
        assert_eq!(b.min, b.max);
    }

    #[test]
    fn union_encloses_both() {
        let a = Aabb::new(Point3::ZERO, Point3::splat(1.0));
        let b = Aabb::new(Point3::splat(0.5), Point3::splat(2.0));
        let u = a.union(&b);
        assert!(u.contains_box(&a) && u.contains_box(&b));
        assert_eq!(u.min, Point3::ZERO);
        assert_eq!(u.max, Point3::splat(2.0));
    }

    #[test]
    fn sphere_box_contains_sphere_surface() {
        let b = Aabb::around_sphere(Point3::splat(1.0), 0.25);
        assert!(b.contains(Point3::new(1.25, 1.0, 1.0)));
        assert!(!b.contains(Point3::new(1.26, 1.0, 1.0)));
    }

    #[test]
    fn surface_area_unit_cube() {
        let b = Aabb::new(Point3::ZERO, Point3::splat(1.0));
        assert_eq!(b.surface_area(), 6.0);
        assert_eq!(Aabb::EMPTY.surface_area(), 0.0);
    }

    #[test]
    fn longest_axis_picks_widest() {
        let b = Aabb::new(Point3::ZERO, Point3::new(1.0, 3.0, 2.0));
        assert_eq!(b.longest_axis(), 1);
    }

    #[test]
    fn dist2_to_point_inside_face_corner_and_empty() {
        let b = Aabb::new(Point3::ZERO, Point3::splat(1.0));
        assert_eq!(b.dist2_to_point(Point3::splat(0.5)), 0.0, "inside");
        assert_eq!(b.dist2_to_point(Point3::new(2.0, 0.5, 0.5)), 1.0, "face");
        assert_eq!(b.dist2_to_point(Point3::new(2.0, 2.0, 2.0)), 3.0, "corner");
        assert_eq!(b.dist2_to_point(Point3::new(-1.0, 0.5, 0.5)), 1.0, "min side");
        assert_eq!(Aabb::EMPTY.dist2_to_point(Point3::ZERO), f32::INFINITY);
    }

    #[test]
    fn dist2_to_point_lower_bounds_member_distances() {
        use crate::geom::dist2;
        use crate::util::{prop, Pcg32};
        let mut rng = Pcg32::new(55);
        let pts = prop::random_cloud(&mut rng, 200, false);
        let mut b = Aabb::EMPTY;
        for &p in &pts {
            b.grow(p);
        }
        for _ in 0..200 {
            let q = Point3::new(
                rng.range_f32(-2.0, 3.0),
                rng.range_f32(-2.0, 3.0),
                rng.range_f32(-2.0, 3.0),
            );
            let lb = b.dist2_to_point(q);
            for &p in &pts {
                assert!(lb <= dist2(p, q), "box bound above a member distance");
            }
        }
    }

    #[test]
    fn slab_test_hits_and_misses() {
        let b = Aabb::new(Point3::ZERO, Point3::splat(1.0));
        let dir = Point3::new(1.0, 0.0, 0.0);
        let inv = Point3::new(1.0 / dir.x, f32::INFINITY, f32::INFINITY);
        assert!(b.intersects_ray(Point3::new(-1.0, 0.5, 0.5), inv, 10.0));
        assert!(!b.intersects_ray(Point3::new(-1.0, 2.5, 0.5), inv, 10.0));
        // segment too short to reach the box
        assert!(!b.intersects_ray(Point3::new(-1.0, 0.5, 0.5), inv, 0.5));
    }
}
