//! Geometric primitives: points, axis-aligned bounding boxes, spheres and
//! rays — the vocabulary of the simulated OptiX pipeline.
//!
//! Everything is natively 3D, exactly like the RT hardware the paper
//! targets; 2D datasets set `z = 0` (paper §5.2).

mod point;
mod aabb;
mod ray;
mod sphere;

pub use aabb::Aabb;
pub use point::Point3;
pub use ray::Ray;
pub use sphere::Sphere;

/// Squared Euclidean distance — the hot comparison in every intersection
/// test; kept separate so call sites avoid the sqrt.
#[inline(always)]
pub fn dist2(a: Point3, b: Point3) -> f32 {
    let dx = a.x - b.x;
    let dy = a.y - b.y;
    let dz = a.z - b.z;
    dx * dx + dy * dy + dz * dz
}

/// Euclidean distance.
#[inline(always)]
pub fn dist(a: Point3, b: Point3) -> f32 {
    dist2(a, b).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dist_matches_dist2() {
        let a = Point3::new(1.0, 2.0, 3.0);
        let b = Point3::new(4.0, 6.0, 3.0);
        assert_eq!(dist2(a, b), 25.0);
        assert_eq!(dist(a, b), 5.0);
    }
}
