//! 3D point / vector type.

use std::ops::{Add, Div, Index, Mul, Sub};

#[derive(Clone, Copy, Debug, PartialEq, Default)]
pub struct Point3 {
    pub x: f32,
    pub y: f32,
    pub z: f32,
}

impl Point3 {
    pub const ZERO: Point3 = Point3 {
        x: 0.0,
        y: 0.0,
        z: 0.0,
    };

    #[inline(always)]
    pub const fn new(x: f32, y: f32, z: f32) -> Self {
        Self { x, y, z }
    }

    /// 2D constructor used by the planar datasets (z pinned to 0, paper §5.2).
    #[inline(always)]
    pub const fn new2(x: f32, y: f32) -> Self {
        Self { x, y, z: 0.0 }
    }

    pub const fn splat(v: f32) -> Self {
        Self { x: v, y: v, z: v }
    }

    #[inline(always)]
    pub fn min(self, o: Point3) -> Point3 {
        Point3::new(self.x.min(o.x), self.y.min(o.y), self.z.min(o.z))
    }

    #[inline(always)]
    pub fn max(self, o: Point3) -> Point3 {
        Point3::new(self.x.max(o.x), self.y.max(o.y), self.z.max(o.z))
    }

    #[inline(always)]
    pub fn dot(self, o: Point3) -> f32 {
        self.x * o.x + self.y * o.y + self.z * o.z
    }

    pub fn cross(self, o: Point3) -> Point3 {
        Point3::new(
            self.y * o.z - self.z * o.y,
            self.z * o.x - self.x * o.z,
            self.x * o.y - self.y * o.x,
        )
    }

    #[inline(always)]
    pub fn norm2(self) -> f32 {
        self.dot(self)
    }

    #[inline(always)]
    pub fn norm(self) -> f32 {
        self.norm2().sqrt()
    }

    pub fn normalized(self) -> Point3 {
        let n = self.norm();
        if n == 0.0 {
            Point3::ZERO
        } else {
            self / n
        }
    }

    pub fn is_finite(self) -> bool {
        self.x.is_finite() && self.y.is_finite() && self.z.is_finite()
    }

    /// Component array view (used when flattening for the PJRT path).
    pub fn to_array(self) -> [f32; 3] {
        [self.x, self.y, self.z]
    }
}

impl Add for Point3 {
    type Output = Point3;
    #[inline(always)]
    fn add(self, o: Point3) -> Point3 {
        Point3::new(self.x + o.x, self.y + o.y, self.z + o.z)
    }
}

impl Sub for Point3 {
    type Output = Point3;
    #[inline(always)]
    fn sub(self, o: Point3) -> Point3 {
        Point3::new(self.x - o.x, self.y - o.y, self.z - o.z)
    }
}

impl Mul<f32> for Point3 {
    type Output = Point3;
    #[inline(always)]
    fn mul(self, s: f32) -> Point3 {
        Point3::new(self.x * s, self.y * s, self.z * s)
    }
}

impl Div<f32> for Point3 {
    type Output = Point3;
    #[inline(always)]
    fn div(self, s: f32) -> Point3 {
        Point3::new(self.x / s, self.y / s, self.z / s)
    }
}

impl Index<usize> for Point3 {
    type Output = f32;
    fn index(&self, i: usize) -> &f32 {
        match i {
            0 => &self.x,
            1 => &self.y,
            2 => &self.z,
            // lint: allow(panic-in-lib) — std Index contract: out-of-bounds must panic, like slice indexing
            _ => panic!("Point3 index {i} out of range"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vector_algebra() {
        let a = Point3::new(1.0, 2.0, 3.0);
        let b = Point3::new(4.0, 5.0, 6.0);
        assert_eq!(a + b, Point3::new(5.0, 7.0, 9.0));
        assert_eq!(b - a, Point3::splat(3.0));
        assert_eq!(a * 2.0, Point3::new(2.0, 4.0, 6.0));
        assert_eq!(a.dot(b), 32.0);
        assert_eq!(a.cross(b), Point3::new(-3.0, 6.0, -3.0));
    }

    #[test]
    fn normalize_handles_zero() {
        assert_eq!(Point3::ZERO.normalized(), Point3::ZERO);
        let n = Point3::new(3.0, 0.0, 4.0).normalized();
        assert!((n.norm() - 1.0).abs() < 1e-6);
    }

    #[test]
    fn min_max_componentwise() {
        let a = Point3::new(1.0, 5.0, 3.0);
        let b = Point3::new(2.0, 4.0, 3.0);
        assert_eq!(a.min(b), Point3::new(1.0, 4.0, 3.0));
        assert_eq!(a.max(b), Point3::new(2.0, 5.0, 3.0));
    }

    #[test]
    fn new2_pins_z() {
        assert_eq!(Point3::new2(1.0, 2.0).z, 0.0);
    }

    #[test]
    #[should_panic]
    fn index_out_of_range() {
        let _ = Point3::ZERO[3];
    }
}
