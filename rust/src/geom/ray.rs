//! Rays as launched by the paper's `RayGen` program (§2.3): origin at the
//! query point, fixed direction (0,0,1), and an infinitesimal extent —
//! "a ray of infinitesimal length is sufficient to intersect neighbors".

use super::point::Point3;

#[derive(Clone, Copy, Debug)]
pub struct Ray {
    pub origin: Point3,
    pub dir: Point3,
    pub t_min: f32,
    pub t_max: f32,
    /// Index of the query point that generated this ray (the OptiX launch
    /// index); lets intersection programs write results per query.
    pub query_id: u32,
}

impl Ray {
    /// The paper's kNN ray: direction (0,0,1), t ∈ [0, FLOAT_MIN].
    pub fn knn(origin: Point3, query_id: u32) -> Self {
        Self {
            origin,
            dir: Point3::new(0.0, 0.0, 1.0),
            t_min: 0.0,
            t_max: f32::MIN_POSITIVE,
            query_id,
        }
    }

    /// Is this ray degenerate (point-like)? True for all kNN rays; the
    /// traversal then reduces ray-AABB tests to point-in-box tests.
    pub fn is_point_like(&self) -> bool {
        self.t_max <= f32::MIN_POSITIVE
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn knn_ray_is_point_like() {
        let r = Ray::knn(Point3::splat(0.5), 7);
        assert!(r.is_point_like());
        assert_eq!(r.query_id, 7);
        assert_eq!(r.dir, Point3::new(0.0, 0.0, 1.0));
    }
}
