//! Sphere primitives — the scene objects of the RT-kNNS reduction
//! (§2.3): one sphere per data point, radius = current search radius.

use super::point::Point3;
use super::aabb::Aabb;

#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Sphere {
    pub center: Point3,
    pub radius: f32,
}

impl Sphere {
    pub fn new(center: Point3, radius: f32) -> Self {
        Self { center, radius }
    }

    pub fn aabb(&self) -> Aabb {
        Aabb::around_sphere(self.center, self.radius)
    }

    /// The paper's software `Intersection` program: does the (point-like)
    /// ray origin lie inside this sphere? Equivalent to
    /// `dist(origin, center) <= radius`.
    #[inline(always)]
    pub fn contains(&self, p: Point3) -> bool {
        super::dist2(self.center, p) <= self.radius * self.radius
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn contains_boundary_inclusive() {
        let s = Sphere::new(Point3::ZERO, 1.0);
        assert!(s.contains(Point3::new(1.0, 0.0, 0.0)));
        assert!(s.contains(Point3::ZERO));
        assert!(!s.contains(Point3::new(1.0, 0.1, 0.0)));
    }

    #[test]
    fn aabb_encloses_sphere() {
        let s = Sphere::new(Point3::new(1.0, -1.0, 2.0), 0.5);
        let b = s.aabb();
        assert_eq!(b.min, Point3::new(0.5, -1.5, 1.5));
        assert_eq!(b.max, Point3::new(1.5, -0.5, 2.5));
    }
}
