//! Exact backends: the kd-tree oracle, the CPU brute-force scan, and
//! the PJRT-accelerated brute force (cuML analog). These are the
//! shader-core side of the router's RT-vs-brute decision.
//!
//! All three honor `IndexConfig::threads` through the [`crate::exec`]
//! engine: queries are sharded contiguously, each worker computes its
//! queries exactly as the serial loop would, and the ordered merge (list
//! concat + integer counter sums) reproduces the serial result bit for
//! bit — the same determinism contract as the scene-backed backends.

use super::{finish_range, Backend, BuildStats, IndexConfig, NeighborIndex};
use crate::exec::Executor;
use crate::geom::{dist2, Point3};
use crate::knn::kdtree::KdTree;
use crate::knn::{KHeap, KnnResult, Neighbor};
use crate::rt::HwCounters;
use crate::runtime::{PjrtBruteForce, PjrtRuntime};
use crate::util::Stopwatch;

/// Per-shard minimum queries for the exact backends (a kd-tree descent
/// or a brute scan per query — substantial per item, so shard early).
const PAR_EXACT_MIN_QUERIES: usize = 16;

// ---------------------------------------------------------------- kdtree

/// Exact kd-tree oracle: median-split tree built once, descended per
/// query. The correctness reference every other backend is checked
/// against.
pub struct KdTreeIndex {
    cfg: IndexConfig,
    data: Vec<Point3>,
    tree: KdTree,
    exec: Executor,
    build: HwCounters,
    build_seconds: f64,
}

impl KdTreeIndex {
    /// Build the kd-tree over `data` (the timed "structure build").
    pub fn new(data: Vec<Point3>, cfg: IndexConfig) -> Self {
        let sw = Stopwatch::start();
        let tree = KdTree::build(&data);
        // charge tree construction like a BVH build so the amortization
        // telemetry is comparable across backends
        let mut build = HwCounters::new();
        build.builds += 1;
        build.build_prims += data.len() as u64;
        let exec = Executor::new(cfg.threads);
        KdTreeIndex {
            cfg,
            data,
            tree,
            exec,
            build,
            build_seconds: sw.elapsed_secs(),
        }
    }

    /// Restore an index serialized by its `snapshot_into`: the persisted
    /// tree arena is trusted (post-validation) instead of rebuilt, and
    /// its point array must mirror `data` exactly.
    pub(crate) fn decode_from(
        dec: &mut crate::persist::Dec<'_>,
        cfg: IndexConfig,
    ) -> Result<Self, crate::persist::PersistError> {
        let data = super::get_points(dec)?;
        let tree = KdTree::decode_from(dec)?;
        let build = HwCounters::decode_from(dec)?;
        let build_seconds = dec.get_f64()?;
        if tree.len() != data.len() {
            return Err(crate::persist::PersistError::Corrupt {
                what: "kdtree index",
                detail: format!("tree holds {} points, data {}", tree.len(), data.len()),
            });
        }
        let exec = Executor::new(cfg.threads);
        Ok(KdTreeIndex {
            cfg,
            data,
            tree,
            exec,
            build,
            build_seconds,
        })
    }
}

impl NeighborIndex for KdTreeIndex {
    fn backend(&self) -> Backend {
        Backend::KdTree
    }

    fn len(&self) -> usize {
        self.data.len()
    }

    fn knn(&mut self, queries: &[Point3], k: usize) -> KnnResult {
        let wall = Stopwatch::start();
        let mut result = KnnResult::new(queries.len());
        let tree = &self.tree;
        let exclude_self = self.cfg.exclude_self;
        let parts = self
            .exec
            .run(queries.len(), PAR_EXACT_MIN_QUERIES, |_, range| {
                range
                    .map(|i| {
                        let exclude = if exclude_self { Some(i as u32) } else { None };
                        tree.knn_excluding(queries[i], k, exclude)
                    })
                    .collect::<Vec<_>>()
            });
        result.neighbors = parts.concat();
        result.counters.rays = queries.len() as u64;
        result.wall_seconds = wall.elapsed_secs();
        // exact CPU path: measured, not modeled
        result.sim_seconds = result.wall_seconds;
        result
    }

    fn range(&mut self, queries: &[Point3], radius: f32) -> KnnResult {
        let wall = Stopwatch::start();
        let mut result = KnnResult::new(queries.len());
        let tree = &self.tree;
        let data = &self.data;
        let exclude_self = self.cfg.exclude_self;
        let parts = self
            .exec
            .run(queries.len(), PAR_EXACT_MIN_QUERIES, |_, range| {
                range
                    .map(|i| {
                        let q = queries[i];
                        tree.range(q, radius)
                            .into_iter()
                            .filter(|&p| !(exclude_self && p as usize == i))
                            .map(|p| Neighbor {
                                idx: p,
                                dist: dist2(data[p as usize], q),
                            })
                            .collect::<Vec<_>>()
                    })
                    .collect::<Vec<_>>()
            });
        result.neighbors = finish_range(parts.concat(), &self.exec);
        result.counters.rays = queries.len() as u64;
        result.wall_seconds = wall.elapsed_secs();
        result.sim_seconds = result.wall_seconds;
        result
    }

    fn insert(&mut self, points: &[Point3]) {
        if points.is_empty() {
            return;
        }
        let sw = Stopwatch::start();
        // a kd-tree has no refit lifecycle: inserts rebuild
        self.data.extend_from_slice(points);
        self.tree = KdTree::build(&self.data);
        self.build.builds += 1;
        self.build.build_prims += self.data.len() as u64;
        self.build_seconds += sw.elapsed_secs();
    }

    fn build_stats(&self) -> BuildStats {
        BuildStats {
            backend: Backend::KdTree,
            n_points: self.data.len(),
            counters: self.build,
            build_seconds: self.build_seconds,
            start_radius: None,
            radius_schedule: Vec::new(),
        }
    }

    fn snapshot_into(&self, enc: &mut crate::persist::Enc) {
        super::write_index_header(enc, false, Backend::KdTree, &self.cfg);
        super::put_points(enc, &self.data);
        self.tree.encode_into(enc);
        self.build.encode_into(enc);
        enc.put_f64(self.build_seconds);
    }
}

// ------------------------------------------------------------- brute cpu

/// Exhaustive CPU scan: no structure at all, every query checks every
/// point. The floor any acceleration claim is measured against.
pub struct BruteCpuIndex {
    cfg: IndexConfig,
    data: Vec<Point3>,
    exec: Executor,
}

impl BruteCpuIndex {
    /// Wrap `data` (no build work; brute force has no structure).
    pub fn new(data: Vec<Point3>, cfg: IndexConfig) -> Self {
        let exec = Executor::new(cfg.threads);
        BruteCpuIndex { cfg, data, exec }
    }

    /// Restore an index serialized by its `snapshot_into` (the point
    /// array is the entire state).
    pub(crate) fn decode_from(
        dec: &mut crate::persist::Dec<'_>,
        cfg: IndexConfig,
    ) -> Result<Self, crate::persist::PersistError> {
        let data = super::get_points(dec)?;
        Ok(BruteCpuIndex::new(data, cfg))
    }
}

/// Exhaustive range scan shared by the CPU backend and the PJRT range
/// path (the radius_count artifact returns counts, not neighbor lists).
/// Queries are sharded across `exec`; each worker scans `data` in order,
/// so the merged lists and summed counters equal the serial scan.
/// Returns per-query in-radius hits as (idx, dist²) for `finish_range`.
pub(crate) fn cpu_range_scan(
    data: &[Point3],
    queries: &[Point3],
    radius: f32,
    exclude_self: bool,
    counters: &mut HwCounters,
    exec: &Executor,
) -> Vec<Vec<Neighbor>> {
    let r2 = radius * radius;
    let parts = exec.run(queries.len(), PAR_EXACT_MIN_QUERIES, |_, range| {
        range
            .map(|qi| {
                let q = queries[qi];
                let mut hits = Vec::new();
                for (di, &d) in data.iter().enumerate() {
                    if exclude_self && di == qi {
                        continue;
                    }
                    let d2 = dist2(d, q);
                    if d2 <= r2 {
                        hits.push(Neighbor {
                            idx: di as u32,
                            dist: d2,
                        });
                    }
                }
                hits
            })
            .collect::<Vec<_>>()
    });
    counters.prim_tests += data.len() as u64 * queries.len() as u64;
    parts.concat()
}

/// Exhaustive scan shared by the CPU backend and the PJRT fallback.
/// Sharded across `exec` with the same ordered-merge contract as the
/// range scan: per-query heaps see the identical push sequence.
pub(crate) fn cpu_brute_scan(
    data: &[Point3],
    queries: &[Point3],
    k: usize,
    exclude_self: bool,
    cfg: &IndexConfig,
    exec: &Executor,
) -> KnnResult {
    let wall = Stopwatch::start();
    let mut result = KnnResult::new(queries.len());
    let parts = exec.run(queries.len(), PAR_EXACT_MIN_QUERIES, |_, range| {
        let mut neighbors = Vec::with_capacity(range.len());
        let mut heap_pushes = 0u64;
        for qi in range {
            let q = queries[qi];
            let mut heap = KHeap::new(k);
            for (di, &d) in data.iter().enumerate() {
                if exclude_self && di == qi {
                    continue;
                }
                heap.push(dist2(d, q), di as u32);
            }
            heap_pushes += heap.pushes;
            neighbors.push(heap.into_sorted());
        }
        (neighbors, heap_pushes)
    });
    let mut neighbors = Vec::with_capacity(queries.len());
    for (part, pushes) in parts {
        neighbors.extend(part);
        result.counters.heap_pushes += pushes;
    }
    result.neighbors = neighbors;
    result.counters.prim_tests += data.len() as u64 * queries.len() as u64;
    result.counters.rays = queries.len() as u64;
    result.wall_seconds = wall.elapsed_secs();
    // no BVH/ray machinery; simulated time is prim-test + sort cost only
    result.sim_seconds = cfg.cost_model.seconds(&result.counters, 1);
    result
}

impl NeighborIndex for BruteCpuIndex {
    fn backend(&self) -> Backend {
        Backend::BruteCpu
    }

    fn len(&self) -> usize {
        self.data.len()
    }

    fn knn(&mut self, queries: &[Point3], k: usize) -> KnnResult {
        cpu_brute_scan(
            &self.data,
            queries,
            k,
            self.cfg.exclude_self,
            &self.cfg,
            &self.exec,
        )
    }

    fn range(&mut self, queries: &[Point3], radius: f32) -> KnnResult {
        let wall = Stopwatch::start();
        let mut result = KnnResult::new(queries.len());
        let per_query = cpu_range_scan(
            &self.data,
            queries,
            radius,
            self.cfg.exclude_self,
            &mut result.counters,
            &self.exec,
        );
        result.neighbors = finish_range(per_query, &self.exec);
        result.counters.rays = queries.len() as u64;
        result.wall_seconds = wall.elapsed_secs();
        result.sim_seconds = self.cfg.cost_model.seconds(&result.counters, 1);
        result
    }

    fn insert(&mut self, points: &[Point3]) {
        self.data.extend_from_slice(points);
    }

    fn build_stats(&self) -> BuildStats {
        BuildStats {
            backend: Backend::BruteCpu,
            n_points: self.data.len(),
            counters: HwCounters::new(), // nothing to build
            build_seconds: 0.0,
            start_radius: None,
            radius_schedule: Vec::new(),
        }
    }

    fn snapshot_into(&self, enc: &mut crate::persist::Enc) {
        super::write_index_header(enc, false, Backend::BruteCpu, &self.cfg);
        super::put_points(enc, &self.data);
    }
}

// ------------------------------------------------------------ brute pjrt

/// Brute force through the AOT PJRT artifacts. The compiled executables
/// are the persistent structure: loaded and compiled once at build,
/// reused on every query. Falls back to the CPU scan when the runtime
/// (or the artifact directory) is unavailable, so results stay exact
/// either way.
pub struct BrutePjrtIndex {
    cfg: IndexConfig,
    data: Vec<Point3>,
    runtime: Option<PjrtRuntime>,
    /// Engine for the CPU fallback and range paths (the PJRT executables
    /// parallelize internally).
    exec: Executor,
}

impl BrutePjrtIndex {
    /// Load the default PJRT artifacts (warning + CPU fallback if absent).
    pub fn new(data: Vec<Point3>, cfg: IndexConfig) -> Self {
        let runtime = match PjrtRuntime::load_default() {
            Ok(rt) => Some(rt),
            Err(e) => {
                crate::log_warn!("PJRT unavailable, brute falls back to CPU: {e}");
                None
            }
        };
        Self::with_runtime(data, runtime, cfg)
    }

    /// Wrap an already-loaded runtime (the service loads it itself so the
    /// router can learn availability before any index exists).
    pub fn with_runtime(data: Vec<Point3>, runtime: Option<PjrtRuntime>, cfg: IndexConfig) -> Self {
        let exec = Executor::new(cfg.threads);
        BrutePjrtIndex {
            cfg,
            data,
            runtime,
            exec,
        }
    }

    /// Did the PJRT runtime actually load? (Else queries take the CPU scan.)
    pub fn pjrt_available(&self) -> bool {
        self.runtime.is_some()
    }

    /// Restore an index serialized by its `snapshot_into`. Only the
    /// point array persists; the PJRT executables are re-loaded from the
    /// artifact directory (they are AOT files on disk already — the
    /// snapshot would only duplicate them), silently falling back to the
    /// CPU scan exactly as a fresh build does.
    pub(crate) fn decode_from(
        dec: &mut crate::persist::Dec<'_>,
        cfg: IndexConfig,
    ) -> Result<Self, crate::persist::PersistError> {
        let data = super::get_points(dec)?;
        Ok(Self::with_runtime(data, PjrtRuntime::load_default().ok(), cfg))
    }
}

impl NeighborIndex for BrutePjrtIndex {
    fn backend(&self) -> Backend {
        Backend::BrutePjrt
    }

    fn len(&self) -> usize {
        self.data.len()
    }

    fn knn(&mut self, queries: &[Point3], k: usize) -> KnnResult {
        if let Some(rt) = self.runtime.as_ref() {
            match PjrtBruteForce::new(rt).knn(&self.data, queries, k, self.cfg.exclude_self) {
                Ok(res) => return res,
                Err(e) => {
                    crate::log_error!("PJRT execution failed, CPU fallback: {e}");
                }
            }
        }
        cpu_brute_scan(
            &self.data,
            queries,
            k,
            self.cfg.exclude_self,
            &self.cfg,
            &self.exec,
        )
    }

    fn range(&mut self, queries: &[Point3], radius: f32) -> KnnResult {
        // the radius_count artifact returns counts, not neighbor lists;
        // range queries take the exact CPU path
        let wall = Stopwatch::start();
        let mut result = KnnResult::new(queries.len());
        let per_query = cpu_range_scan(
            &self.data,
            queries,
            radius,
            self.cfg.exclude_self,
            &mut result.counters,
            &self.exec,
        );
        result.neighbors = finish_range(per_query, &self.exec);
        result.counters.rays = queries.len() as u64;
        result.wall_seconds = wall.elapsed_secs();
        result.sim_seconds = self.cfg.cost_model.seconds(&result.counters, 1);
        result
    }

    fn insert(&mut self, points: &[Point3]) {
        // the PJRT path re-shards data per call; no device structure to
        // maintain
        self.data.extend_from_slice(points);
    }

    fn build_stats(&self) -> BuildStats {
        BuildStats {
            backend: Backend::BrutePjrt,
            n_points: self.data.len(),
            counters: HwCounters::new(),
            build_seconds: 0.0,
            start_radius: None,
            radius_schedule: Vec::new(),
        }
    }

    fn snapshot_into(&self, enc: &mut crate::persist::Enc) {
        super::write_index_header(enc, false, Backend::BrutePjrt, &self.cfg);
        super::put_points(enc, &self.data);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::DatasetKind;

    #[test]
    fn kdtree_index_excludes_self_by_position() {
        let ds = DatasetKind::Uniform.generate(300, 95);
        let mut idx = KdTreeIndex::new(ds.points.clone(), IndexConfig::default());
        let res = idx.knn(&ds.points, 3);
        for (i, nb) in res.neighbors.iter().enumerate() {
            assert!(nb.iter().all(|n| n.idx as usize != i), "query {i} kept self");
        }
    }

    #[test]
    fn kdtree_insert_rebuilds_and_counts() {
        let ds = DatasetKind::Uniform.generate(100, 96);
        let mut idx = KdTreeIndex::new(ds.points.clone(), IndexConfig::default());
        idx.insert(&[Point3::splat(0.5)]);
        let stats = idx.build_stats();
        assert_eq!(stats.counters.builds, 2);
        assert_eq!(stats.n_points, 101);
    }

    #[test]
    fn brute_indexes_agree_with_each_other() {
        // without artifacts, BrutePjrt falls back to the same CPU scan
        let ds = DatasetKind::Iono.generate(400, 97);
        let mut cpu = BruteCpuIndex::new(ds.points.clone(), IndexConfig::default());
        let mut pjrt = BrutePjrtIndex::with_runtime(
            ds.points.clone(),
            PjrtRuntime::load_default().ok(),
            IndexConfig::default(),
        );
        let a = cpu.knn(&ds.points[..32], 5);
        let b = pjrt.knn(&ds.points[..32], 5);
        for (x, y) in a.neighbors.iter().zip(&b.neighbors) {
            assert_eq!(x.len(), y.len());
            for (g, w) in x.iter().zip(y) {
                assert!((g.dist - w.dist).abs() < 2e-3);
            }
        }
    }
}
