//! Unified neighbor-search indexes: build an acceleration structure
//! **once**, query it **many** times.
//!
//! The paper's headline algorithm amortizes BVH work across rounds; this
//! module amortizes it across *requests*. Every search algorithm in the
//! crate is exposed as a [`Backend`] behind one [`NeighborIndex`] trait
//! with a build/query lifecycle:
//!
//! ```no_run
//! use trueknn::dataset::DatasetKind;
//! use trueknn::index::{Backend, IndexBuilder, NeighborIndex};
//!
//! let ds = DatasetKind::Taxi.generate(10_000, 42);
//! let mut index = IndexBuilder::new(Backend::TrueKnn).build(ds.points.clone());
//! let a = index.knn(&ds.points[..64], 5);   // builds nothing: BVH persists
//! let b = index.knn(&ds.points[..64], 16);  // same structure, new k
//! assert_eq!(index.build_stats().counters.builds, 1);
//! # let _ = (a, b);
//! ```
//!
//! What persists per backend:
//!
//! | backend            | persistent structure                                 |
//! |--------------------|------------------------------------------------------|
//! | [`Backend::TrueKnn`]     | sphere BVH (refit between queries), Alg. 2 start radius, last radius schedule |
//! | [`Backend::FixedRadius`] | sphere BVH at the configured radius            |
//! | [`Backend::Rtnn`]        | sphere BVH + Morton query reordering per call  |
//! | [`Backend::KdTree`]      | exact kd-tree                                  |
//! | [`Backend::BruteCpu`]    | none (flat scan)                               |
//! | [`Backend::BrutePjrt`]   | compiled PJRT executables (loaded once)        |
//!
//! The old free functions (`knn::trueknn`, `knn::fixed_radius_knns`,
//! `knn::brute::brute_knn`) remain as thin shims that build a throwaway
//! index, run one query and fold the build cost back into the result's
//! *totals* (counters, `sim_seconds`, `wall_seconds` — identical to
//! before this module existed). Per-round telemetry is now query-only:
//! a fixed-radius `rounds[0]` no longer includes the one-time build,
//! which lives in [`BuildStats`] instead.

mod exact;
mod scene_backends;
mod trueknn;

pub use exact::{BruteCpuIndex, BrutePjrtIndex, KdTreeIndex};
pub use scene_backends::{FixedRadiusIndex, RtnnIndex};
pub use trueknn::TrueKnnIndex;

use crate::geom::{Aabb, Point3, Ray};
use crate::knn::{KnnResult, Neighbor};
use crate::rt::{CostModel, HwCounters, IntersectionProgram, Pipeline, Scene, ShardableProgram};
use crate::util::Stopwatch;

/// Which search algorithm backs a [`NeighborIndex`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Backend {
    /// The paper's TrueKNN (Alg. 3): multi-round growing-radius search.
    TrueKnn,
    /// Fixed-radius RT-kNNS baseline (Alg. 1).
    FixedRadius,
    /// RTNN-style baseline: fixed radius + Morton query reordering.
    Rtnn,
    /// Exact kd-tree (the validation oracle).
    KdTree,
    /// Exhaustive CPU scan.
    BruteCpu,
    /// Brute force through the AOT PJRT artifacts (CPU fallback when the
    /// runtime is unavailable).
    BrutePjrt,
}

impl Backend {
    /// Every backend, in the fixed presentation order used by sweeps
    /// and CLI listings.
    pub const ALL: [Backend; 6] = [
        Backend::TrueKnn,
        Backend::FixedRadius,
        Backend::Rtnn,
        Backend::KdTree,
        Backend::BruteCpu,
        Backend::BrutePjrt,
    ];

    /// Stable CLI/report label (also the `FromStr` canonical form).
    pub fn name(&self) -> &'static str {
        match self {
            Backend::TrueKnn => "trueknn",
            Backend::FixedRadius => "fixed-radius",
            Backend::Rtnn => "rtnn",
            Backend::KdTree => "kdtree",
            Backend::BruteCpu => "brute-cpu",
            Backend::BrutePjrt => "brute-pjrt",
        }
    }

    /// Stable numeric tag used inside snapshot payloads (the position in
    /// [`Backend::ALL`]). New backends append; existing tags never move.
    pub fn tag(&self) -> u32 {
        match self {
            Backend::TrueKnn => 0,
            Backend::FixedRadius => 1,
            Backend::Rtnn => 2,
            Backend::KdTree => 3,
            Backend::BruteCpu => 4,
            Backend::BrutePjrt => 5,
        }
    }

    /// Inverse of [`Backend::tag`]; `None` for tags from a future (or
    /// corrupt) snapshot.
    pub fn from_tag(tag: u32) -> Option<Backend> {
        match tag {
            0 => Some(Backend::TrueKnn),
            1 => Some(Backend::FixedRadius),
            2 => Some(Backend::Rtnn),
            3 => Some(Backend::KdTree),
            4 => Some(Backend::BruteCpu),
            5 => Some(Backend::BrutePjrt),
            _ => None,
        }
    }
}

impl std::fmt::Display for Backend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

impl std::str::FromStr for Backend {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "trueknn" => Ok(Backend::TrueKnn),
            "baseline" | "fixed" | "fixed-radius" => Ok(Backend::FixedRadius),
            "rtnn" => Ok(Backend::Rtnn),
            "kdtree" => Ok(Backend::KdTree),
            "brute" | "brute-cpu" => Ok(Backend::BruteCpu),
            "pjrt" | "brute-pjrt" => Ok(Backend::BrutePjrt),
            other => Err(format!(
                "unknown backend '{other}' (expected trueknn|baseline|rtnn|kdtree|brute|pjrt)"
            )),
        }
    }
}

/// Backend-agnostic index configuration. Fields irrelevant to a backend
/// are ignored (e.g. `partitions` only matters to [`Backend::Rtnn`]).
#[derive(Clone, Debug)]
pub struct IndexConfig {
    /// Query *j* excludes data point *j* — valid when the query set
    /// aliases the indexed data (the paper's "kNN of all points").
    pub exclude_self: bool,
    pub seed: u64,
    pub cost_model: CostModel,
    /// TrueKNN: override the Alg. 2 sampled start radius.
    pub start_radius: Option<f32>,
    /// TrueKNN: stop growing at this radius (the §5.5.1 percentile runs).
    pub radius_cap: Option<f32>,
    /// TrueKNN: safety valve on the doubling loop.
    pub max_rounds: usize,
    /// FixedRadius/Rtnn search radius. `None` derives the dataset's
    /// bounding-box diagonal — complete (exact) for in-bounds queries.
    pub radius: Option<f32>,
    /// Rtnn: number of Morton-ordered query chunks per launch.
    pub partitions: usize,
    /// Worker threads for the parallel launch engine and structure
    /// maintenance (0 = the environment default: `TRUEKNN_THREADS` if
    /// set, else all cores — resolved by [`crate::exec::Executor::new`]).
    /// Results are bitwise-identical at any value — this is purely a
    /// throughput knob.
    pub threads: usize,
    /// Morton query-cohort scheduling for parallel launches (on by
    /// default): sort each launch's rays along the Z-order curve into
    /// cache-sized cohorts before sharding, so every worker walks a
    /// compact run of BVH subtrees. Like `threads`, a pure schedule
    /// knob — results and counters are bitwise-identical either way.
    pub cohort_queries: bool,
    /// TrueKNN: keep survivors' partial heaps across rounds and discard
    /// hits inside the previous radius (shell re-query), instead of
    /// resetting and re-pushing everything each round. Exact either way;
    /// `false` restores the reset-per-round baseline for ablations.
    pub shell_requery: bool,
    /// Spatial shards (1 = unsharded). Above 1 the builder wraps the
    /// backend in a [`crate::shard::ShardedIndex`]: the dataset is split
    /// into balanced Morton-range shards, each with its own backend
    /// index, and queries scatter-gather exactly across them — results
    /// are bitwise-identical to the unsharded backend at any shard
    /// count (see the shard module's determinism contract).
    pub shards: usize,
    /// Sharded kNN speculation width: the first `speculation` shards of
    /// each query's box-distance order are fanned **in parallel,
    /// unpruned** before the pruned serial tail walk begins (see the
    /// shard module's two-phase plan). Like `threads` and
    /// `cohort_queries`, a pure schedule knob — results are
    /// bitwise-identical at any value, because the prune it skips is
    /// only ever a skip. `0` restores the fully serial pruned walk.
    pub speculation: usize,
}

impl Default for IndexConfig {
    fn default() -> Self {
        Self {
            exclude_self: true,
            seed: 42,
            cost_model: CostModel::default(),
            start_radius: None,
            radius_cap: None,
            max_rounds: 64,
            radius: None,
            partitions: 16,
            threads: 0,
            cohort_queries: true,
            shell_requery: true,
            shards: 1,
            speculation: 2,
        }
    }
}

/// `Option<f32>` wire form: presence tag byte, then the value if present.
fn put_opt_f32(enc: &mut crate::persist::Enc, v: Option<f32>) {
    match v {
        Some(x) => {
            enc.put_u8(1);
            enc.put_f32(x);
        }
        None => enc.put_u8(0),
    }
}

fn get_opt_f32(
    dec: &mut crate::persist::Dec<'_>,
) -> Result<Option<f32>, crate::persist::PersistError> {
    match dec.get_u8()? {
        0 => Ok(None),
        1 => Ok(Some(dec.get_f32()?)),
        t => Err(crate::persist::PersistError::Corrupt {
            what: "index config",
            detail: format!("option tag {t} is neither 0 nor 1"),
        }),
    }
}

impl IndexConfig {
    /// Serialize every field (including `threads`, which the loader
    /// overrides — see [`IndexBuilder::load`]) for a snapshot payload.
    pub fn encode_into(&self, enc: &mut crate::persist::Enc) {
        enc.put_u8(self.exclude_self as u8);
        enc.put_u64(self.seed);
        enc.put_f64(self.cost_model.c_aabb);
        enc.put_f64(self.cost_model.c_prim);
        enc.put_f64(self.cost_model.c_heap);
        enc.put_f64(self.cost_model.c_build);
        enc.put_f64(self.cost_model.c_refit);
        enc.put_f64(self.cost_model.c_switch);
        enc.put_f64(self.cost_model.c_launch);
        put_opt_f32(enc, self.start_radius);
        put_opt_f32(enc, self.radius_cap);
        enc.put_u64(self.max_rounds as u64);
        put_opt_f32(enc, self.radius);
        enc.put_u64(self.partitions as u64);
        enc.put_u64(self.threads as u64);
        enc.put_u8(self.cohort_queries as u8);
        enc.put_u8(self.shell_requery as u8);
        enc.put_u64(self.shards as u64);
        enc.put_u64(self.speculation as u64);
    }

    /// Decode a config written by [`IndexConfig::encode_into`].
    pub fn decode_from(
        dec: &mut crate::persist::Dec<'_>,
    ) -> Result<IndexConfig, crate::persist::PersistError> {
        Ok(IndexConfig {
            exclude_self: dec.get_u8()? != 0,
            seed: dec.get_u64()?,
            cost_model: CostModel {
                c_aabb: dec.get_f64()?,
                c_prim: dec.get_f64()?,
                c_heap: dec.get_f64()?,
                c_build: dec.get_f64()?,
                c_refit: dec.get_f64()?,
                c_switch: dec.get_f64()?,
                c_launch: dec.get_f64()?,
            },
            start_radius: get_opt_f32(dec)?,
            radius_cap: get_opt_f32(dec)?,
            max_rounds: dec.get_u64()? as usize,
            radius: get_opt_f32(dec)?,
            partitions: dec.get_u64()? as usize,
            threads: dec.get_u64()? as usize,
            cohort_queries: dec.get_u8()? != 0,
            shell_requery: dec.get_u8()? != 0,
            shards: dec.get_u64()? as usize,
            speculation: dec.get_u64()? as usize,
        })
    }

    /// Fold the *result-affecting* configuration into a fingerprint
    /// hasher. Everything except `threads` and `speculation`
    /// participates: both are pure schedule knobs (results are
    /// bitwise-identical at any value — the crate's determinism
    /// contract), so a snapshot written by an 8-thread speculative build
    /// must load into a 2-thread serial server.
    pub fn fingerprint_into(&self, h: &mut crate::persist::Fnv64) {
        h.write(&[self.exclude_self as u8]);
        h.write_u64(self.seed);
        for c in [
            self.cost_model.c_aabb,
            self.cost_model.c_prim,
            self.cost_model.c_heap,
            self.cost_model.c_build,
            self.cost_model.c_refit,
            self.cost_model.c_switch,
            self.cost_model.c_launch,
        ] {
            h.write_u64(c.to_bits());
        }
        for opt in [self.start_radius, self.radius_cap, self.radius] {
            match opt {
                Some(v) => {
                    h.write(&[1]);
                    h.write_f32(v);
                }
                None => h.write(&[0]),
            }
        }
        h.write_u64(self.max_rounds as u64);
        h.write_u64(self.partitions as u64);
        h.write(&[self.cohort_queries as u8, self.shell_requery as u8]);
        h.write_u64(self.shards as u64);
    }
}

/// Structure-maintenance telemetry: what it cost to *build* (and later
/// grow) the index, kept separate from per-query work so the
/// amortization is visible.
#[derive(Clone, Debug)]
pub struct BuildStats {
    pub backend: Backend,
    pub n_points: usize,
    /// Counters charged to structure maintenance: the initial build plus
    /// any `insert`-driven refits/rebuilds. `counters.builds` staying at
    /// 1 across a serving session is the amortization claim.
    pub counters: HwCounters,
    pub build_seconds: f64,
    /// TrueKNN: the effective Alg. 2 start radius (sampled once at build).
    pub start_radius: Option<f32>,
    /// TrueKNN: per-round radius schedule of the most recent query.
    pub radius_schedule: Vec<f32>,
}

impl BuildStats {
    /// Fold the one-time build cost into a query result — used by the
    /// legacy free-function shims, which by contract report build +
    /// query as one number.
    pub fn absorb_into(&self, result: &mut KnnResult, model: &CostModel) {
        result.counters.add(&self.counters);
        result.wall_seconds += self.build_seconds;
        result.finalize_sim_time(model);
    }
}

/// A build-once/query-many neighbor-search index.
///
/// Methods take `&mut self` because querying may *refit* the persistent
/// acceleration structure (TrueKNN refits between rounds and between
/// queries; `range` refits to the requested radius).
///
/// `Send` is a supertrait so index handles can cross thread boundaries —
/// the sharded scatter-gather fans disjoint `&mut` sub-indexes across
/// [`crate::exec::scope`] workers, and every backend is plain owned data.
pub trait NeighborIndex: Send {
    fn backend(&self) -> Backend;

    /// Number of indexed data points.
    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// k nearest neighbors of every query, sorted ascending by distance.
    fn knn(&mut self, queries: &[Point3], k: usize) -> KnnResult;

    /// All neighbors within `radius` of every query, sorted ascending.
    fn range(&mut self, queries: &[Point3], radius: f32) -> KnnResult;

    /// Add points to the index. Scene-backed backends graft them into
    /// the existing BVH and *refit* (no rebuild); the kd-tree rebuilds.
    fn insert(&mut self, points: &[Point3]);

    fn build_stats(&self) -> BuildStats;

    /// Serialize the index's complete state (backend tag, config, and
    /// every arena, including build counters) into a snapshot payload.
    /// [`IndexBuilder::load`] restores an index whose query results
    /// *and* counters are bitwise-identical to the original's.
    fn snapshot_into(&self, enc: &mut crate::persist::Enc);
}

/// Why [`IndexBuilder::try_build`] refused to build an index.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum BuildError {
    /// The dataset contains a NaN or infinite coordinate. Carries the
    /// index of the first offending point: every downstream structure
    /// (Morton codes, AABBs, kd-tree splits) silently corrupts on
    /// non-finite input, so it is rejected at the front door.
    NonFiniteCoordinate {
        /// Index of the first non-finite point in the input data.
        index: usize,
    },
    /// A snapshot could not be loaded: checksum, version, or config
    /// fingerprint mismatch, or a structurally invalid payload. The
    /// caller must fall back to a full deterministic rebuild — a
    /// partially-trusted file is never served.
    Persist(crate::persist::PersistError),
}

impl std::fmt::Display for BuildError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            BuildError::NonFiniteCoordinate { index } => {
                write!(f, "non-finite coordinate at data point {index}")
            }
            BuildError::Persist(e) => write!(f, "snapshot load failed: {e}"),
        }
    }
}

impl std::error::Error for BuildError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            BuildError::Persist(e) => Some(e),
            _ => None,
        }
    }
}

/// Front door: configure, then `build` to get a boxed index.
pub struct IndexBuilder {
    backend: Backend,
    cfg: IndexConfig,
}

impl IndexBuilder {
    /// A builder for `backend` with the default [`IndexConfig`].
    pub fn new(backend: Backend) -> Self {
        Self {
            backend,
            cfg: IndexConfig::default(),
        }
    }

    /// Replace the whole configuration at once.
    pub fn config(mut self, cfg: IndexConfig) -> Self {
        self.cfg = cfg;
        self
    }

    /// Drop each query point itself from its own result list.
    pub fn exclude_self(mut self, v: bool) -> Self {
        self.cfg.exclude_self = v;
        self
    }

    /// Seed for the backend's internal sampling (start-radius probe).
    pub fn seed(mut self, v: u64) -> Self {
        self.cfg.seed = v;
        self
    }

    /// Cost model used to synthesize the modeled-GPU timing estimates.
    pub fn cost_model(mut self, m: CostModel) -> Self {
        self.cfg.cost_model = m;
        self
    }

    /// Override TrueKNN's sampled initial search radius.
    pub fn start_radius(mut self, r: f32) -> Self {
        self.cfg.start_radius = Some(r);
        self
    }

    /// Cap TrueKNN's radius growth (trades completeness for time).
    pub fn radius_cap(mut self, r: f32) -> Self {
        self.cfg.radius_cap = Some(r);
        self
    }

    /// Bound the number of radius-doubling rounds (0 = unbounded).
    pub fn max_rounds(mut self, n: usize) -> Self {
        self.cfg.max_rounds = n;
        self
    }

    /// Fixed search radius for the fixed-radius/RTNN baselines.
    pub fn radius(mut self, r: f32) -> Self {
        self.cfg.radius = Some(r);
        self
    }

    /// Query partitions per round (RTNN batching knob).
    pub fn partitions(mut self, n: usize) -> Self {
        self.cfg.partitions = n;
        self
    }

    /// Worker threads (0 = all cores). Only changes throughput, never
    /// results.
    pub fn threads(mut self, n: usize) -> Self {
        self.cfg.threads = n;
        self
    }

    /// Toggle Morton query-cohort scheduling (on by default). Only
    /// changes the launch schedule, never results.
    pub fn cohort_queries(mut self, v: bool) -> Self {
        self.cfg.cohort_queries = v;
        self
    }

    /// Toggle TrueKNN shell re-query (on by default).
    pub fn shell_requery(mut self, v: bool) -> Self {
        self.cfg.shell_requery = v;
        self
    }

    /// Spatial shards (1 = unsharded; see [`IndexConfig::shards`]).
    pub fn shards(mut self, n: usize) -> Self {
        self.cfg.shards = n;
        self
    }

    /// Sharded-kNN speculation width (see [`IndexConfig::speculation`]).
    /// Only changes the schedule, never results.
    pub fn speculation(mut self, n: usize) -> Self {
        self.cfg.speculation = n;
        self
    }

    /// Validating build: reject degenerate datasets with a typed
    /// [`BuildError`] instead of letting NaN/infinite coordinates
    /// corrupt the acceleration structure. The service layer validates
    /// its own boundary ([`crate::coordinator::ServiceHandle`]); this is
    /// the same guard for direct library users. An empty dataset is
    /// *valid* (an empty index answers every query with no neighbors).
    pub fn try_build(self, data: Vec<Point3>) -> Result<Box<dyn NeighborIndex>, BuildError> {
        if let Some(index) = data.iter().position(|p| !p.is_finite()) {
            return Err(BuildError::NonFiniteCoordinate { index });
        }
        Ok(self.build(data))
    }

    /// Build the acceleration structure over `data` and return the index.
    pub fn build(self, data: Vec<Point3>) -> Box<dyn NeighborIndex> {
        if self.cfg.shards > 1 {
            return Box::new(crate::shard::ShardedIndex::new(self.backend, data, self.cfg));
        }
        match self.backend {
            Backend::TrueKnn => Box::new(TrueKnnIndex::new(data, self.cfg)),
            Backend::FixedRadius => Box::new(FixedRadiusIndex::new(data, self.cfg)),
            Backend::Rtnn => Box::new(RtnnIndex::new(data, self.cfg)),
            Backend::KdTree => Box::new(KdTreeIndex::new(data, self.cfg)),
            Backend::BruteCpu => Box::new(BruteCpuIndex::new(data, self.cfg)),
            Backend::BrutePjrt => Box::new(BrutePjrtIndex::new(data, self.cfg)),
        }
    }

    /// Fingerprint of this builder's result-affecting configuration
    /// (backend name + every [`IndexConfig`] field except the pure
    /// schedule knobs `threads` and `speculation`).
    /// Snapshots are fenced to it: [`IndexBuilder::load`] refuses a file
    /// written under any other configuration, because replaying a WAL on
    /// top of a differently-configured index would silently change
    /// results.
    pub fn fingerprint(&self) -> u64 {
        let mut h = crate::persist::Fnv64::new();
        h.write(self.backend.name().as_bytes());
        self.cfg.fingerprint_into(&mut h);
        h.finish()
    }

    /// Serialize `index` into a complete checksummed snapshot container
    /// fenced to this builder's [`fingerprint`](IndexBuilder::fingerprint)
    /// and stamped with the WAL `watermark` (sequence number of the last
    /// insert the snapshot includes; 0 = none).
    pub fn snapshot(&self, index: &dyn NeighborIndex, watermark: u64) -> Vec<u8> {
        let mut enc = crate::persist::Enc::new();
        index.snapshot_into(&mut enc);
        let mut w = crate::persist::SnapshotWriter::new(self.fingerprint(), watermark);
        w.section(crate::persist::SEC_INDEX, enc.into_bytes());
        w.finish()
    }

    /// Load a snapshot written by [`IndexBuilder::snapshot`] under the
    /// same configuration, returning the restored index and the WAL
    /// watermark it was stamped with. The persisted thread count is
    /// overridden by this builder's — threads never affect results, so a
    /// snapshot is portable across machine sizes. Any checksum, version,
    /// fingerprint, or structural failure is a typed
    /// [`BuildError::Persist`]; the caller rebuilds from source data.
    pub fn load(&self, bytes: &[u8]) -> Result<(Box<dyn NeighborIndex>, u64), BuildError> {
        let snap = crate::persist::Snapshot::parse(bytes).map_err(BuildError::Persist)?;
        snap.check_fingerprint(self.fingerprint())
            .map_err(BuildError::Persist)?;
        let payload = snap.section(crate::persist::SEC_INDEX).ok_or_else(|| {
            BuildError::Persist(crate::persist::PersistError::Corrupt {
                what: "snapshot container",
                detail: "no index section".to_string(),
            })
        })?;
        let mut dec = crate::persist::Dec::new(payload);
        let index = decode_index(&mut dec, self.cfg.threads).map_err(BuildError::Persist)?;
        if !dec.finished() {
            return Err(BuildError::Persist(crate::persist::PersistError::Corrupt {
                what: "snapshot container",
                detail: format!("{} trailing bytes after index payload", dec.remaining()),
            }));
        }
        Ok((index, snap.watermark))
    }
}

/// Common prefix of every serialized index: a sharded-wrapper flag, the
/// backend tag, then the full config. Written by each backend's
/// `snapshot_into`; consumed by [`decode_index`].
pub(crate) fn write_index_header(
    enc: &mut crate::persist::Enc,
    sharded: bool,
    backend: Backend,
    cfg: &IndexConfig,
) {
    enc.put_u8(sharded as u8);
    enc.put_u32(backend.tag());
    cfg.encode_into(enc);
}

/// Decode one serialized index (header + backend body), overriding the
/// persisted thread count with `threads`. Also the recursion point for
/// [`crate::shard::ShardedIndex`]'s per-shard inner indexes.
pub(crate) fn decode_index(
    dec: &mut crate::persist::Dec<'_>,
    threads: usize,
) -> Result<Box<dyn NeighborIndex>, crate::persist::PersistError> {
    let sharded = dec.get_u8()? != 0;
    let tag = dec.get_u32()?;
    let backend = Backend::from_tag(tag).ok_or_else(|| crate::persist::PersistError::Corrupt {
        what: "index payload",
        detail: format!("unknown backend tag {tag}"),
    })?;
    let mut cfg = IndexConfig::decode_from(dec)?;
    cfg.threads = threads;
    if sharded {
        return Ok(Box::new(crate::shard::ShardedIndex::decode_from(dec, backend, cfg)?));
    }
    Ok(match backend {
        Backend::TrueKnn => Box::new(TrueKnnIndex::decode_from(dec, cfg)?),
        Backend::FixedRadius => Box::new(FixedRadiusIndex::decode_from(dec, cfg)?),
        Backend::Rtnn => Box::new(RtnnIndex::decode_from(dec, cfg)?),
        Backend::KdTree => Box::new(KdTreeIndex::decode_from(dec, cfg)?),
        Backend::BruteCpu => Box::new(BruteCpuIndex::decode_from(dec, cfg)?),
        Backend::BrutePjrt => Box::new(BrutePjrtIndex::decode_from(dec, cfg)?),
    })
}

/// Shared codec for a point array (`len` + three `f32` words per point).
pub(crate) fn put_points(enc: &mut crate::persist::Enc, points: &[Point3]) {
    enc.put_len(points.len());
    for p in points {
        enc.put_f32(p.x);
        enc.put_f32(p.y);
        enc.put_f32(p.z);
    }
}

/// Inverse of [`put_points`].
pub(crate) fn get_points(
    dec: &mut crate::persist::Dec<'_>,
) -> Result<Vec<Point3>, crate::persist::PersistError> {
    let n = dec.get_len()?;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        out.push(Point3::new(dec.get_f32()?, dec.get_f32()?, dec.get_f32()?));
    }
    Ok(out)
}

/// Complete-search default radius for the fixed-radius backends: the
/// data bounding-box diagonal covers any in-bounds query's farthest
/// neighbor.
pub(crate) fn default_radius(data: &[Point3]) -> f32 {
    let mut bb = Aabb::EMPTY;
    for &p in data {
        bb.grow(p);
    }
    let diag = bb.extent().norm();
    if diag.is_finite() && diag > 0.0 {
        diag * 1.0001
    } else {
        1.0
    }
}

/// Intersection program for range queries: records every in-radius hit
/// with its squared distance.
pub(crate) struct RangeCollect {
    pub per_query: Vec<Vec<Neighbor>>,
    pub exclude_self: bool,
}

impl RangeCollect {
    /// Empty collector with one result bucket per query.
    pub fn new(n_queries: usize, exclude_self: bool) -> Self {
        Self {
            per_query: vec![Vec::new(); n_queries],
            exclude_self,
        }
    }
}

impl IntersectionProgram for RangeCollect {
    #[inline]
    fn hit(&mut self, ray: &Ray, prim: u32, dist2: f32) {
        if self.exclude_self && prim == ray.query_id {
            return;
        }
        self.per_query[ray.query_id as usize].push(Neighbor {
            idx: prim,
            dist: dist2, // squared until finish_range takes the sqrt
        });
    }
}

/// Per-shard state of [`RangeCollect`] for the parallel launch engine:
/// the owned queries' hit lists in ray order, addressed via `begin_ray`.
pub(crate) struct RangeShard {
    ids: Vec<u32>,
    per_query: Vec<Vec<Neighbor>>,
    cur: usize,
    exclude_self: bool,
}

impl IntersectionProgram for RangeShard {
    #[inline]
    fn begin_ray(&mut self, local_ray_index: u32) {
        self.cur = local_ray_index as usize;
    }

    #[inline]
    fn hit(&mut self, ray: &Ray, prim: u32, dist2: f32) {
        if self.exclude_self && prim == ray.query_id {
            return;
        }
        self.per_query[self.cur].push(Neighbor {
            idx: prim,
            dist: dist2,
        });
    }
}

impl ShardableProgram for RangeCollect {
    type Shard = RangeShard;

    fn split(&mut self, rays: &[Ray]) -> RangeShard {
        let ids: Vec<u32> = rays.iter().map(|r| r.query_id).collect();
        let per_query = ids
            .iter()
            .map(|&q| std::mem::take(&mut self.per_query[q as usize]))
            .collect();
        RangeShard {
            ids,
            per_query,
            cur: 0,
            exclude_self: self.exclude_self,
        }
    }

    fn merge(&mut self, shard: RangeShard) {
        for (q, hits) in shard.ids.into_iter().zip(shard.per_query) {
            self.per_query[q as usize] = hits;
        }
    }
}

/// Shared range-query path for the scene-backed backends: refit the
/// persistent BVH to the requested radius and launch once, sharded over
/// the scene's executor.
pub(crate) fn scene_range(
    scene: &mut Scene,
    queries: &[Point3],
    radius: f32,
    exclude_self: bool,
    model: &CostModel,
) -> KnnResult {
    let wall = Stopwatch::start();
    let mut result = KnnResult::new(queries.len());
    if scene.is_empty() || queries.is_empty() {
        result.wall_seconds = wall.elapsed_secs();
        return result;
    }
    let mut counters = HwCounters::new();
    if scene.radius != radius {
        scene.refit(radius, &mut counters);
    }
    counters.context_switches += 1;
    let rays: Vec<Ray> = queries
        .iter()
        .enumerate()
        .map(|(i, &p)| Ray::knn(p, i as u32))
        .collect();
    let mut prog = RangeCollect::new(queries.len(), exclude_self);
    let exec = scene.exec;
    Pipeline::launch_parallel(scene, &rays, &mut prog, &mut counters, &exec);
    result.neighbors = finish_range(prog.per_query, &exec);
    result.launches = 1;
    result.counters = counters;
    result.wall_seconds = wall.elapsed_secs();
    result.finalize_sim_time(model);
    result
}

/// Per-chunk minimum for the sharded per-query result assembly passes
/// (sqrt + sort of short neighbor lists — cheap per item).
pub(crate) const PAR_ASSEMBLY_MIN: usize = 512;

/// Drain every k-heap into its aligned result slot, sharded across
/// `exec` — the shared per-query assembly pass of TrueKNN and the
/// fixed-radius backends. Chunk pairs keep heap `i` aligned with output
/// slot `i`, so this equals the serial drain.
pub(crate) fn assemble_sorted(
    heaps: &mut [crate::knn::KHeap],
    out: &mut [Vec<Neighbor>],
    exec: &crate::exec::Executor,
) {
    exec.for_each_chunk2(heaps, out, PAR_ASSEMBLY_MIN, |_, heaps, out| {
        for (h, o) in heaps.iter_mut().zip(out.iter_mut()) {
            *o = std::mem::replace(h, crate::knn::KHeap::new(0)).into_sorted();
        }
    });
}

/// Convert collected squared distances to sorted real-distance lists —
/// per-query work sharded across `exec` (the per-query sqrt+sort is
/// independent, so the in-place chunked pass equals the serial one).
pub(crate) fn finish_range(
    mut per_query: Vec<Vec<Neighbor>>,
    exec: &crate::exec::Executor,
) -> Vec<Vec<Neighbor>> {
    exec.for_each_chunk(&mut per_query, PAR_ASSEMBLY_MIN, |_, chunk| {
        for hits in chunk.iter_mut() {
            for h in hits.iter_mut() {
                h.dist = h.dist.sqrt();
            }
            hits.sort_by(|a, b| {
                a.dist
                    .partial_cmp(&b.dist)
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then(a.idx.cmp(&b.idx))
            });
        }
    });
    per_query
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::DatasetKind;
    use crate::knn::kdtree::KdTree;

    #[test]
    fn backend_names_round_trip() {
        for b in Backend::ALL {
            assert_eq!(b.name().parse::<Backend>().unwrap(), b);
        }
        assert_eq!("baseline".parse::<Backend>().unwrap(), Backend::FixedRadius);
        assert!("warp".parse::<Backend>().is_err());
    }

    #[test]
    fn builder_produces_each_backend() {
        let ds = DatasetKind::Uniform.generate(200, 1);
        for b in Backend::ALL {
            let mut idx = IndexBuilder::new(b).build(ds.points.clone());
            assert_eq!(idx.backend(), b);
            assert_eq!(idx.len(), 200);
            let res = idx.knn(&ds.points[..8], 3);
            assert_eq!(res.neighbors.len(), 8);
            assert!(res.neighbors.iter().all(|n| n.len() == 3), "{b}");
        }
    }

    #[test]
    fn range_matches_kdtree_on_every_backend() {
        let ds = DatasetKind::Uniform.generate(300, 2);
        let tree = KdTree::build(&ds.points);
        let r = 0.25f32;
        for b in Backend::ALL {
            let mut idx = IndexBuilder::new(b).exclude_self(false).build(ds.points.clone());
            let res = idx.range(&ds.points[..16], r);
            for (qi, got) in res.neighbors.iter().enumerate() {
                let mut want = tree.range(ds.points[qi], r);
                want.sort_unstable();
                let mut got_ids: Vec<u32> = got.iter().map(|n| n.idx).collect();
                got_ids.sort_unstable();
                assert_eq!(got_ids, want, "{b} query {qi}");
                for w in got.windows(2) {
                    assert!(w[0].dist <= w[1].dist, "{b} unsorted range result");
                }
            }
        }
    }

    #[test]
    fn insert_then_query_finds_new_points() {
        let ds = DatasetKind::Uniform.generate(250, 3);
        let extra = DatasetKind::Uniform.generate(50, 4).points;
        for b in Backend::ALL {
            let mut idx = IndexBuilder::new(b).exclude_self(false).build(ds.points.clone());
            idx.insert(&extra);
            assert_eq!(idx.len(), 300, "{b}");
            let all: Vec<_> = ds.points.iter().chain(&extra).copied().collect();
            let tree = KdTree::build(&all);
            let res = idx.knn(&extra[..8], 4);
            for (qi, got) in res.neighbors.iter().enumerate() {
                let want = tree.knn(extra[qi], 4);
                assert_eq!(got.len(), want.len(), "{b} query {qi}");
                for (g, w) in got.iter().zip(&want) {
                    assert!(
                        (g.dist - w.dist).abs() < 1e-5,
                        "{b} query {qi}: {} vs {}",
                        g.dist,
                        w.dist
                    );
                }
            }
        }
    }

    #[test]
    fn try_build_rejects_non_finite_data_with_the_offender_index() {
        let mut pts = DatasetKind::Uniform.generate(50, 6).points;
        pts[17] = Point3::new(0.5, f32::NAN, 0.5);
        let err = IndexBuilder::new(Backend::TrueKnn)
            .try_build(pts)
            .unwrap_err();
        assert_eq!(err, BuildError::NonFiniteCoordinate { index: 17 });
        assert!(err.to_string().contains("17"));
        // a clean dataset builds; so does an empty one
        let ok = IndexBuilder::new(Backend::KdTree)
            .try_build(DatasetKind::Uniform.generate(50, 6).points)
            .unwrap();
        assert_eq!(ok.len(), 50);
        let empty = IndexBuilder::new(Backend::BruteCpu).try_build(Vec::new()).unwrap();
        assert!(empty.is_empty());
    }

    #[test]
    fn snapshot_round_trip_preserves_results_and_stats_bitwise() {
        let ds = DatasetKind::Taxi.generate(400, 7);
        for b in Backend::ALL {
            let mut idx = IndexBuilder::new(b).build(ds.points.clone());
            let _ = idx.knn(&ds.points[..32], 4); // leave post-query state behind
            let bytes = IndexBuilder::new(b).snapshot(idx.as_ref(), 9);
            let (mut loaded, watermark) = IndexBuilder::new(b).load(&bytes).unwrap();
            assert_eq!(watermark, 9, "{b}");
            assert_eq!(loaded.len(), idx.len(), "{b}");
            let want = idx.knn(&ds.points[..32], 4);
            let got = loaded.knn(&ds.points[..32], 4);
            for (g, w) in got.neighbors.iter().zip(&want.neighbors) {
                let gb: Vec<(u32, u32)> = g.iter().map(|n| (n.idx, n.dist.to_bits())).collect();
                let wb: Vec<(u32, u32)> = w.iter().map(|n| (n.idx, n.dist.to_bits())).collect();
                assert_eq!(gb, wb, "{b}");
            }
            assert_eq!(got.counters, want.counters, "{b} counters diverged after reload");
            let (gs, ws) = (loaded.build_stats(), idx.build_stats());
            assert_eq!(gs.counters, ws.counters, "{b}");
            assert_eq!(gs.start_radius.map(f32::to_bits), ws.start_radius.map(f32::to_bits));
        }
    }

    #[test]
    fn load_rejects_a_different_configuration() {
        let ds = DatasetKind::Uniform.generate(120, 8);
        let idx = IndexBuilder::new(Backend::KdTree).build(ds.points.clone());
        let bytes = IndexBuilder::new(Backend::KdTree).snapshot(idx.as_ref(), 0);
        // different seed → different fingerprint → typed refusal
        let err = IndexBuilder::new(Backend::KdTree).seed(7).load(&bytes).unwrap_err();
        assert!(matches!(
            err,
            BuildError::Persist(crate::persist::PersistError::FingerprintMismatch { .. })
        ));
        // different backend under the same config: also a fingerprint fence
        let err = IndexBuilder::new(Backend::BruteCpu).load(&bytes).unwrap_err();
        assert!(matches!(
            err,
            BuildError::Persist(crate::persist::PersistError::FingerprintMismatch { .. })
        ));
        // threads are NOT part of the fingerprint: a differently-threaded
        // builder loads the same file
        let (loaded, _) = IndexBuilder::new(Backend::KdTree).threads(2).load(&bytes).unwrap();
        assert_eq!(loaded.len(), 120);
    }

    #[test]
    fn bvh_persists_across_queries() {
        let ds = DatasetKind::Taxi.generate(800, 5);
        let mut idx = IndexBuilder::new(Backend::TrueKnn).build(ds.points.clone());
        for _ in 0..3 {
            let _ = idx.knn(&ds.points, 5);
        }
        let stats = idx.build_stats();
        assert_eq!(stats.counters.builds, 1, "BVH must persist across queries");
        assert!(stats.start_radius.unwrap() > 0.0);
        assert!(!stats.radius_schedule.is_empty());
    }
}
