//! Fixed-radius backends over a persistent scene: the paper's Alg. 1
//! baseline and the RTNN-style variant (Zhu, PPoPP'22).
//!
//! Both keep one sphere BVH at the configured search radius for their
//! whole lifetime. The RTNN index retains the query-reordering
//! optimization (Morton sort + chunked launches for ray coherence); the
//! per-call data-culling of the one-shot `knn::rtnn::rtnn_knns` is
//! inherently per-query-set (it builds a scene per query partition) and
//! cannot persist, so the free function remains the reference
//! implementation of that experiment.

use super::{
    assemble_sorted, default_radius, scene_range, Backend, BuildStats, IndexConfig, NeighborIndex,
};
use crate::exec::Executor;
use crate::geom::{Aabb, Point3, Ray};
use crate::knn::program::KnnProgram;
use crate::knn::rtnn::morton3;
use crate::knn::{KnnResult, RoundStats};
use crate::rt::{HwCounters, Pipeline, Scene};
use crate::util::Stopwatch;

/// Fixed-radius RT-kNNS baseline (Alg. 1): one scene at a
/// completeness-guaranteeing radius, one traversal per query.
pub struct FixedRadiusIndex {
    cfg: IndexConfig,
    radius: f32,
    scene: Scene,
    build: HwCounters,
    build_seconds: f64,
}

impl FixedRadiusIndex {
    /// Build the scene at `cfg.radius` (default: the data-diagonal
    /// complete-search radius).
    pub fn new(data: Vec<Point3>, cfg: IndexConfig) -> Self {
        let sw = Stopwatch::start();
        let radius = cfg.radius.unwrap_or_else(|| default_radius(&data));
        let exec = Executor::new(cfg.threads);
        let mut build = HwCounters::new();
        let mut scene = Scene::build_with_exec(data, radius, &mut build, exec);
        scene.cohort = cfg.cohort_queries;
        FixedRadiusIndex {
            cfg,
            radius,
            scene,
            build,
            build_seconds: sw.elapsed_secs(),
        }
    }

    /// The fixed search radius the scene was built at.
    pub fn radius(&self) -> f32 {
        self.radius
    }

    /// Restore an index serialized by its `snapshot_into` — the scene
    /// comes back at whatever radius the last call left it, so the next
    /// query's refit decision matches a never-persisted index exactly.
    pub(crate) fn decode_from(
        dec: &mut crate::persist::Dec<'_>,
        cfg: IndexConfig,
    ) -> Result<Self, crate::persist::PersistError> {
        let radius = dec.get_f32()?;
        let build = HwCounters::decode_from(dec)?;
        let build_seconds = dec.get_f64()?;
        let scene = Scene::decode_from(dec, Executor::new(cfg.threads))?;
        Ok(FixedRadiusIndex {
            cfg,
            radius,
            scene,
            build,
            build_seconds,
        })
    }
}

impl NeighborIndex for FixedRadiusIndex {
    fn backend(&self) -> Backend {
        Backend::FixedRadius
    }

    fn len(&self) -> usize {
        self.scene.len()
    }

    /// Alg. 1 lines 4–13 against the persistent scene: one launch, one
    /// ray per query. Queries farther than the index radius from their
    /// k-th neighbor come back short — by design (the paper's complaint).
    fn knn(&mut self, queries: &[Point3], k: usize) -> KnnResult {
        let wall = Stopwatch::start();
        let mut result = KnnResult::new(queries.len());
        let mut counters = HwCounters::new();
        // a range() call may have refit the scene to another radius
        if self.scene.radius != self.radius {
            self.scene.refit(self.radius, &mut counters);
        }
        counters.context_switches += 1;

        let rays: Vec<Ray> = queries
            .iter()
            .enumerate()
            .map(|(i, &p)| Ray::knn(p, i as u32))
            .collect();
        let mut program = KnnProgram::new(queries.len(), k, self.cfg.exclude_self);
        let exec = self.scene.exec;
        Pipeline::launch_parallel(&self.scene, &rays, &mut program, &mut counters, &exec);
        counters.heap_pushes += program.total_pushes();

        assemble_sorted(&mut program.heaps, &mut result.neighbors, &exec);
        result.launches = 1;
        result.counters = counters;
        result.wall_seconds = wall.elapsed_secs();
        result.rounds.push(RoundStats {
            round: 0,
            radius: self.radius,
            queries: queries.len(),
            survivors: result.neighbors.iter().filter(|n| n.len() < k).count(),
            prim_tests: result.counters.prim_tests,
            heap_pushes: result.counters.heap_pushes,
            sim_seconds: self.cfg.cost_model.seconds(&result.counters, 1),
            wall_seconds: result.wall_seconds,
        });
        result.finalize_sim_time(&self.cfg.cost_model);
        result
    }

    fn range(&mut self, queries: &[Point3], radius: f32) -> KnnResult {
        scene_range(
            &mut self.scene,
            queries,
            radius,
            self.cfg.exclude_self,
            &self.cfg.cost_model,
        )
    }

    fn insert(&mut self, points: &[Point3]) {
        let sw = Stopwatch::start();
        // keep the structure at the search radius before grafting so the
        // new prims get correctly-sized boxes
        if self.scene.radius != self.radius {
            self.scene.refit(self.radius, &mut self.build);
        }
        self.scene.insert(points, &mut self.build);
        self.build_seconds += sw.elapsed_secs();
    }

    fn build_stats(&self) -> BuildStats {
        BuildStats {
            backend: Backend::FixedRadius,
            n_points: self.scene.len(),
            counters: self.build,
            build_seconds: self.build_seconds,
            start_radius: None,
            radius_schedule: Vec::new(),
        }
    }

    fn snapshot_into(&self, enc: &mut crate::persist::Enc) {
        super::write_index_header(enc, false, Backend::FixedRadius, &self.cfg);
        enc.put_f32(self.radius);
        self.build.encode_into(enc);
        enc.put_f64(self.build_seconds);
        self.scene.encode_into(enc);
    }
}

/// RTNN-style baseline: fixed radius plus Morton query reordering and
/// query partitioning.
pub struct RtnnIndex {
    cfg: IndexConfig,
    radius: f32,
    scene: Scene,
    build: HwCounters,
    build_seconds: f64,
}

impl RtnnIndex {
    /// Build the scene at `cfg.radius` (default: the data-diagonal
    /// complete-search radius).
    pub fn new(data: Vec<Point3>, cfg: IndexConfig) -> Self {
        let sw = Stopwatch::start();
        let radius = cfg.radius.unwrap_or_else(|| default_radius(&data));
        let exec = Executor::new(cfg.threads);
        let mut build = HwCounters::new();
        let mut scene = Scene::build_with_exec(data, radius, &mut build, exec);
        scene.cohort = cfg.cohort_queries;
        RtnnIndex {
            cfg,
            radius,
            scene,
            build,
            build_seconds: sw.elapsed_secs(),
        }
    }

    /// Restore an index serialized by its `snapshot_into` (same wire
    /// shape as [`FixedRadiusIndex`]; the Morton reordering is per-call
    /// state and has nothing to persist).
    pub(crate) fn decode_from(
        dec: &mut crate::persist::Dec<'_>,
        cfg: IndexConfig,
    ) -> Result<Self, crate::persist::PersistError> {
        let radius = dec.get_f32()?;
        let build = HwCounters::decode_from(dec)?;
        let build_seconds = dec.get_f64()?;
        let scene = Scene::decode_from(dec, Executor::new(cfg.threads))?;
        Ok(RtnnIndex {
            cfg,
            radius,
            scene,
            build,
            build_seconds,
        })
    }
}

impl NeighborIndex for RtnnIndex {
    fn backend(&self) -> Backend {
        Backend::Rtnn
    }

    fn len(&self) -> usize {
        self.scene.len()
    }

    /// Fixed-radius search with RTNN's query reordering: queries are
    /// Morton-sorted and launched in spatial chunks so consecutive rays
    /// traverse the same BVH subtrees.
    fn knn(&mut self, queries: &[Point3], k: usize) -> KnnResult {
        let wall = Stopwatch::start();
        let mut result = KnnResult::new(queries.len());
        if self.scene.is_empty() || queries.is_empty() {
            result.wall_seconds = wall.elapsed_secs();
            return result;
        }
        let mut counters = HwCounters::new();
        if self.scene.radius != self.radius {
            self.scene.refit(self.radius, &mut counters);
        }

        // optimization 1: Z-order query sort
        let mut bb = Aabb::EMPTY;
        for &q in queries {
            bb.grow(q);
        }
        let mut order: Vec<u32> = (0..queries.len() as u32).collect();
        order.sort_by_key(|&i| morton3(queries[i as usize], &bb));

        // optimization 2: chunked launches along the curve
        let parts = self.cfg.partitions.max(1).min(order.len());
        let chunk = order.len().div_ceil(parts);
        let mut program = KnnProgram::new(queries.len(), k, self.cfg.exclude_self);
        let mut launches = 0u64;
        let mut prev_pushes = 0u64;
        let exec = self.scene.exec;

        for part in order.chunks(chunk) {
            counters.context_switches += 1;
            let rays: Vec<Ray> = part
                .iter()
                .map(|&q| Ray::knn(queries[q as usize], q))
                .collect();
            Pipeline::launch_parallel(&self.scene, &rays, &mut program, &mut counters, &exec);
            launches += 1;
            let pushes = program.total_pushes();
            counters.heap_pushes += pushes - prev_pushes;
            prev_pushes = pushes;
        }

        assemble_sorted(&mut program.heaps, &mut result.neighbors, &exec);
        result.launches = launches;
        result.counters = counters;
        result.wall_seconds = wall.elapsed_secs();
        result.rounds.push(RoundStats {
            round: 0,
            radius: self.radius,
            queries: queries.len(),
            survivors: result.neighbors.iter().filter(|n| n.len() < k).count(),
            prim_tests: result.counters.prim_tests,
            heap_pushes: result.counters.heap_pushes,
            sim_seconds: self.cfg.cost_model.seconds(&result.counters, launches),
            wall_seconds: result.wall_seconds,
        });
        result.finalize_sim_time(&self.cfg.cost_model);
        result
    }

    fn range(&mut self, queries: &[Point3], radius: f32) -> KnnResult {
        scene_range(
            &mut self.scene,
            queries,
            radius,
            self.cfg.exclude_self,
            &self.cfg.cost_model,
        )
    }

    fn insert(&mut self, points: &[Point3]) {
        let sw = Stopwatch::start();
        if self.scene.radius != self.radius {
            self.scene.refit(self.radius, &mut self.build);
        }
        self.scene.insert(points, &mut self.build);
        self.build_seconds += sw.elapsed_secs();
    }

    fn build_stats(&self) -> BuildStats {
        BuildStats {
            backend: Backend::Rtnn,
            n_points: self.scene.len(),
            counters: self.build,
            build_seconds: self.build_seconds,
            start_radius: None,
            radius_schedule: Vec::new(),
        }
    }

    fn snapshot_into(&self, enc: &mut crate::persist::Enc) {
        super::write_index_header(enc, false, Backend::Rtnn, &self.cfg);
        enc.put_f32(self.radius);
        self.build.encode_into(enc);
        enc.put_f64(self.build_seconds);
        self.scene.encode_into(enc);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::{DatasetKind, DistanceProfile};
    use crate::knn::kdtree::KdTree;

    #[test]
    fn fixed_index_reuses_one_scene_across_ks() {
        let ds = DatasetKind::Uniform.generate(700, 90);
        let prof = DistanceProfile::compute(&ds, 16);
        let mut idx = FixedRadiusIndex::new(
            ds.points.clone(),
            IndexConfig {
                radius: Some(prof.max_dist() as f32 * 1.0001),
                ..Default::default()
            },
        );
        let tree = KdTree::build(&ds.points);
        for k in [1usize, 5, 16] {
            let res = idx.knn(&ds.points, k);
            for (i, got) in res.neighbors.iter().enumerate() {
                let want = tree.knn_excluding(ds.points[i], k, Some(i as u32));
                assert_eq!(got.len(), want.len(), "k={k} query {i}");
                for (g, w) in got.iter().zip(&want) {
                    assert!((g.dist - w.dist).abs() < 1e-5, "k={k} query {i}");
                }
            }
        }
        assert_eq!(idx.build_stats().counters.builds, 1);
    }

    #[test]
    fn small_radius_leaves_queries_incomplete() {
        let ds = DatasetKind::Taxi.generate(1_000, 91);
        let mut idx = FixedRadiusIndex::new(
            ds.points.clone(),
            IndexConfig {
                radius: Some(1e-6),
                ..Default::default()
            },
        );
        let res = idx.knn(&ds.points, 5);
        assert!(!res.is_complete(5, ds.len() - 1));
        assert!(res.rounds[0].survivors > ds.len() / 2);
    }

    #[test]
    fn rtnn_index_exact_and_launches_in_chunks() {
        let ds = DatasetKind::Road.generate(600, 92);
        let mut idx = RtnnIndex::new(
            ds.points.clone(),
            IndexConfig {
                partitions: 8,
                ..Default::default()
            },
        );
        let res = idx.knn(&ds.points, 4);
        assert_eq!(res.launches, 8);
        let tree = KdTree::build(&ds.points);
        for (i, got) in res.neighbors.iter().enumerate() {
            let want = tree.knn_excluding(ds.points[i], 4, Some(i as u32));
            assert_eq!(got.len(), want.len(), "query {i}");
            for (g, w) in got.iter().zip(&want) {
                assert!((g.dist - w.dist).abs() < 1e-5, "query {i}");
            }
        }
    }

    #[test]
    fn range_then_knn_restores_the_index_radius() {
        let ds = DatasetKind::Uniform.generate(400, 93);
        let mut idx = FixedRadiusIndex::new(ds.points.clone(), IndexConfig::default());
        let r0 = idx.radius();
        let _ = idx.range(&ds.points[..8], 0.01);
        let res = idx.knn(&ds.points, 3);
        assert!(res.is_complete(3, ds.len() - 1), "refit back to {r0} failed");
        assert!(res.counters.refits >= 1, "knn must refit after range moved the scene");
    }
}
