//! TrueKNN as a persistent index — the paper's Algorithm 3 with the
//! scene lifecycle hoisted out of the per-call path.
//!
//! The free function rebuilt the BVH and re-sampled the start radius on
//! every invocation; this index does both exactly once. Between queries
//! the BVH is *refit* back down to the start radius (the same §4 refit
//! the algorithm already uses between rounds), so a serving loop pays
//! one build per dataset instead of one per batch.
//!
//! Two per-round optimizations on top of Alg. 3:
//!
//! - **Parallel launches**: every round's rays go through
//!   [`Pipeline::launch_parallel`], sharded across the configured
//!   executor (results bitwise-identical at any thread count).
//! - **Shell re-query** (`IndexConfig::shell_requery`, on by default):
//!   instead of resetting survivors' heaps and re-discovering every hit
//!   inside the grown radius (Alg. 3 line 3), survivors keep their
//!   partial heaps and the intersection program discards hits with
//!   `d2 ≤ r_prev²` — each round pays heap traffic only for the annulus
//!   `(r_prev, r]`. Exact, because a survivor (`< k` hits so far) kept
//!   *every* hit inside `r_prev` in its heap; the re-discovery overhead
//!   is the cost RTNN (Zhu, PPoPP'22) identifies as dominant in
//!   iterative RT neighbor search.
//! - **Parallel round bookkeeping**: the retire/compact of the active
//!   query set and the final per-query heap-drain assembly are sharded
//!   across the same executor (ordered merges, so both equal their
//!   serial forms bit for bit) — the per-round serial wall between
//!   launches is gone.

use super::{assemble_sorted, scene_range, Backend, BuildStats, IndexConfig, NeighborIndex};
use crate::exec::Executor;
use crate::geom::{Point3, Ray};
use crate::knn::program::KnnProgram;
use crate::knn::start_radius::random_sample_radius;
use crate::knn::{KnnResult, RoundStats};
use crate::rt::{HwCounters, Pipeline, Scene};
use crate::util::Stopwatch;

/// Per-chunk minimum for the sharded per-round retire filter (a heap
/// length check per query — very cheap per item).
const PAR_BOOKKEEPING_MIN: usize = 1024;

/// The paper's TrueKNN (Alg. 3): multi-round growing-radius search with
/// per-round retire filtering and shell re-query.
pub struct TrueKnnIndex {
    cfg: IndexConfig,
    scene: Scene,
    /// Effective Alg. 2 start radius: the config override, or the value
    /// sampled once at build time.
    start_radius: f32,
    /// Radius schedule of the most recent `knn` call.
    schedule: Vec<f32>,
    /// Structure-maintenance counters (build + inserts).
    build: HwCounters,
    build_seconds: f64,
}

impl TrueKnnIndex {
    /// Build the scene and sample the Alg. 2 start radius (unless
    /// overridden via `cfg.start_radius`).
    pub fn new(data: Vec<Point3>, cfg: IndexConfig) -> Self {
        let sw = Stopwatch::start();
        let start_radius = cfg
            .start_radius
            .unwrap_or_else(|| random_sample_radius(&data, cfg.seed));
        let mut initial = start_radius;
        if let Some(cap) = cfg.radius_cap {
            initial = initial.min(cap);
        }
        let exec = Executor::new(cfg.threads);
        let mut build = HwCounters::new();
        let mut scene = Scene::build_with_exec(data, initial, &mut build, exec);
        scene.cohort = cfg.cohort_queries;
        TrueKnnIndex {
            cfg,
            scene,
            start_radius,
            schedule: Vec::new(),
            build,
            build_seconds: sw.elapsed_secs(),
        }
    }

    /// Restore an index serialized by its `snapshot_into` — no sampling,
    /// no build: the persisted scene (at whatever radius the last query
    /// left it), start radius, schedule, and build counters come back
    /// exactly, so both future results and reported stats are
    /// bitwise-identical to an index that never went through disk.
    pub(crate) fn decode_from(
        dec: &mut crate::persist::Dec<'_>,
        cfg: IndexConfig,
    ) -> Result<Self, crate::persist::PersistError> {
        let start_radius = dec.get_f32()?;
        let n = dec.get_len()?;
        let mut schedule = Vec::with_capacity(n);
        for _ in 0..n {
            schedule.push(dec.get_f32()?);
        }
        let build = HwCounters::decode_from(dec)?;
        let build_seconds = dec.get_f64()?;
        let scene = Scene::decode_from(dec, Executor::new(cfg.threads))?;
        Ok(TrueKnnIndex {
            cfg,
            scene,
            start_radius,
            schedule,
            build,
            build_seconds,
        })
    }
}

impl NeighborIndex for TrueKnnIndex {
    fn backend(&self) -> Backend {
        Backend::TrueKnn
    }

    fn len(&self) -> usize {
        self.scene.len()
    }

    /// Algorithm 3 against the persistent scene. The result's counters
    /// cover only this call (inter-query refit + rounds); the one-time
    /// build lives in [`NeighborIndex::build_stats`].
    fn knn(&mut self, queries: &[Point3], k: usize) -> KnnResult {
        let wall_total = Stopwatch::start();
        let mut result = KnnResult::new(queries.len());
        if self.scene.is_empty() || queries.is_empty() || k == 0 {
            return result;
        }

        // A query can only ever find this many neighbors; completion must
        // be judged against it or k > n would loop forever.
        let max_possible = if self.cfg.exclude_self {
            self.scene.len().saturating_sub(1)
        } else {
            self.scene.len()
        };
        let target = k.min(max_possible);

        let mut radius = self.start_radius;
        if let Some(cap) = self.cfg.radius_cap {
            radius = radius.min(cap);
        }

        let mut counters = HwCounters::new();
        // Previous calls leave the scene at their final (grown) radius;
        // shrink it back with a refit — never a rebuild.
        if self.scene.radius != radius {
            self.scene.refit(radius, &mut counters);
        }
        counters.context_switches += 1; // upload + launch
        let mut program = KnnProgram::new(queries.len(), k, self.cfg.exclude_self);

        let mut active: Vec<u32> = (0..queries.len() as u32).collect();
        let mut launches = 0u64;
        let mut round = 0usize;
        let mut prev_pushes = 0u64;
        // Squared radius already searched by earlier rounds; the shell
        // filter drops re-discovered hits at or below it. Negative for
        // round 0 so distance-0 duplicates are accepted.
        let mut searched_r2 = -1.0f32;
        self.schedule.clear();

        // Alg. 3 lines 2–13.
        while !active.is_empty() && round < self.cfg.max_rounds {
            let round_wall = Stopwatch::start();
            let before = counters;
            self.schedule.push(radius);

            if self.cfg.shell_requery {
                // Survivors keep their partial heaps; only the annulus
                // (r_prev, r] may push.
                program.set_shell_floor(searched_r2);
            } else {
                // Ablation baseline: each round re-discovers everything
                // within the larger radius, so survivors' heaps restart
                // clean (Alg. 3 line 3).
                program.reset(&active);
            }
            let rays: Vec<Ray> = active
                .iter()
                .map(|&q| Ray::knn(queries[q as usize], q))
                .collect();
            let exec = self.scene.exec;
            Pipeline::launch_parallel(&self.scene, &rays, &mut program, &mut counters, &exec);
            launches += 1;
            let pushes = program.total_pushes();
            counters.heap_pushes += pushes - prev_pushes;
            prev_pushes = pushes;

            // Alg. 3 lines 4–8: retire completed queries — sharded
            // filter with an ordered concat, identical to a serial
            // `retain` (survivors keep their relative order) but off the
            // per-round serial wall between launches.
            let queried = active.len();
            let survivors = {
                let act: &[u32] = &active;
                let heaps = &program.heaps;
                exec.run(act.len(), PAR_BOOKKEEPING_MIN, |_, r| {
                    act[r]
                        .iter()
                        .copied()
                        .filter(|&q| heaps[q as usize].len() < target)
                        .collect::<Vec<u32>>()
                })
            };
            active = survivors.concat();

            let delta = counters.delta(&before);
            result.rounds.push(RoundStats {
                round,
                radius,
                queries: queried,
                survivors: active.len(),
                prim_tests: delta.prim_tests,
                heap_pushes: delta.heap_pushes,
                sim_seconds: self.cfg.cost_model.seconds(&delta, 1),
                wall_seconds: round_wall.elapsed_secs(),
            });

            if active.is_empty() {
                break;
            }
            searched_r2 = radius * radius;
            // 99th-percentile variant: stop once the cap radius has been
            // searched; survivors stay incomplete by design.
            if let Some(cap) = self.cfg.radius_cap {
                if radius >= cap {
                    break;
                }
                radius = (radius * 2.0).min(cap);
            } else {
                radius *= 2.0;
            }

            // Alg. 3 lines 10–11: grow spheres + refit (2 context
            // switches, §6.2.1).
            self.scene.refit(radius, &mut counters);
            round += 1;
        }

        // Per-query result assembly, sharded then merged in place.
        let exec = self.scene.exec;
        assemble_sorted(&mut program.heaps, &mut result.neighbors, &exec);
        result.launches = launches;
        result.counters = counters;
        result.wall_seconds = wall_total.elapsed_secs();
        result.finalize_sim_time(&self.cfg.cost_model);
        result
    }

    fn range(&mut self, queries: &[Point3], radius: f32) -> KnnResult {
        scene_range(
            &mut self.scene,
            queries,
            radius,
            self.cfg.exclude_self,
            &self.cfg.cost_model,
        )
    }

    fn insert(&mut self, points: &[Point3]) {
        let sw = Stopwatch::start();
        self.scene.insert(points, &mut self.build);
        self.build_seconds += sw.elapsed_secs();
    }

    fn build_stats(&self) -> BuildStats {
        BuildStats {
            backend: Backend::TrueKnn,
            n_points: self.scene.len(),
            counters: self.build,
            build_seconds: self.build_seconds,
            start_radius: Some(self.start_radius),
            radius_schedule: self.schedule.clone(),
        }
    }

    fn snapshot_into(&self, enc: &mut crate::persist::Enc) {
        super::write_index_header(enc, false, Backend::TrueKnn, &self.cfg);
        enc.put_f32(self.start_radius);
        enc.put_len(self.schedule.len());
        for &r in &self.schedule {
            enc.put_f32(r);
        }
        self.build.encode_into(enc);
        enc.put_f64(self.build_seconds);
        self.scene.encode_into(enc);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::DatasetKind;
    use crate::knn::kdtree::KdTree;

    #[test]
    fn repeated_queries_stay_exact_on_one_structure() {
        // the stale-structure trap: round N leaves the BVH at a huge
        // radius; the next call must shrink it back and stay exact
        let ds = DatasetKind::Taxi.generate(1_200, 80);
        let mut idx = TrueKnnIndex::new(ds.points.clone(), IndexConfig::default());
        let tree = KdTree::build(&ds.points);
        for pass in 0..3 {
            let res = idx.knn(&ds.points, 5);
            assert!(res.is_complete(5, ds.len() - 1), "pass {pass}");
            for (i, got) in res.neighbors.iter().enumerate() {
                let want = tree.knn_excluding(ds.points[i], 5, Some(i as u32));
                for (g, w) in got.iter().zip(&want) {
                    assert!((g.dist - w.dist).abs() < 1e-5, "pass {pass} query {i}");
                }
            }
        }
        let stats = idx.build_stats();
        assert_eq!(stats.counters.builds, 1);
        assert_eq!(stats.counters.build_prims, 1_200);
    }

    #[test]
    fn second_query_charges_a_refit_not_a_build() {
        let ds = DatasetKind::Uniform.generate(600, 81);
        let mut idx = TrueKnnIndex::new(ds.points.clone(), IndexConfig::default());
        let first = idx.knn(&ds.points[..32], 4);
        let second = idx.knn(&ds.points[..32], 4);
        assert_eq!(first.counters.builds, 0, "per-call counters exclude the build");
        assert_eq!(second.counters.builds, 0);
        // the second call starts by refitting the grown scene back down
        assert!(second.counters.refits >= first.counters.refits);
    }

    #[test]
    fn start_radius_persists_across_queries() {
        let ds = DatasetKind::Road.generate(900, 82);
        let mut idx = TrueKnnIndex::new(ds.points.clone(), IndexConfig::default());
        let r0 = idx.build_stats().start_radius.unwrap();
        let a = idx.knn(&ds.points, 3);
        let b = idx.knn(&ds.points, 3);
        assert!((a.rounds[0].radius - r0).abs() < 1e-12);
        assert!((b.rounds[0].radius - r0).abs() < 1e-12);
        // deterministic schedule: same start, same doubling
        assert_eq!(a.rounds.len(), b.rounds.len());
    }

    #[test]
    fn shell_requery_matches_reset_baseline_with_fewer_pushes() {
        let ds = DatasetKind::Taxi.generate(1_000, 83);
        // a pinned small start radius guarantees a multi-round search
        let mut shell = TrueKnnIndex::new(
            ds.points.clone(),
            IndexConfig {
                start_radius: Some(0.002),
                ..Default::default()
            },
        );
        let mut reset = TrueKnnIndex::new(
            ds.points.clone(),
            IndexConfig {
                start_radius: Some(0.002),
                shell_requery: false,
                ..Default::default()
            },
        );
        let a = shell.knn(&ds.points, 5);
        let b = reset.knn(&ds.points, 5);
        // identical neighbor distances, same schedule
        assert_eq!(a.rounds.len(), b.rounds.len());
        for (ga, gb) in a.neighbors.iter().zip(&b.neighbors) {
            assert_eq!(ga.len(), gb.len());
            for (x, y) in ga.iter().zip(gb) {
                assert!((x.dist - y.dist).abs() < 1e-6);
            }
        }
        // multi-round searches must save heap traffic
        assert!(a.rounds.len() > 1, "need multiple rounds to see the effect");
        assert!(
            a.counters.heap_pushes < b.counters.heap_pushes,
            "shell {} must push less than reset {}",
            a.counters.heap_pushes,
            b.counters.heap_pushes
        );
    }
}
