//! CPU brute-force exact kNN — the shader-core ("cuML") computation
//! pattern executed scalar-side. The PJRT-accelerated version of the
//! same computation lives in `runtime::brute` and is the Fig 4 baseline;
//! this one is the small-input fallback and the oracle of last resort.

use super::{KHeap, KnnResult, Neighbor};
use crate::geom::{dist2, Point3};
use crate::index::{BruteCpuIndex, IndexConfig, NeighborIndex};

/// Exact kNN by exhaustive scan: O(|queries| · |data|).
///
/// Compatibility shim over [`BruteCpuIndex`] (which has no build cost —
/// the scan has nothing to amortize).
pub fn brute_knn(
    data: &[Point3],
    queries: &[Point3],
    k: usize,
    exclude_self: bool,
) -> KnnResult {
    let mut index = BruteCpuIndex::new(
        data.to_vec(),
        IndexConfig {
            exclude_self,
            ..Default::default()
        },
    );
    index.knn(queries, k)
}

/// Convenience: single-query exact kNN.
pub fn brute_knn_single(data: &[Point3], q: Point3, k: usize) -> Vec<Neighbor> {
    let mut heap = KHeap::new(k);
    for (di, &d) in data.iter().enumerate() {
        heap.push(dist2(d, q), di as u32);
    }
    heap.into_sorted()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::knn::kdtree::KdTree;
    use crate::util::prop;

    #[test]
    fn brute_matches_kdtree() {
        prop::check("brute ≡ kdtree", 20, |rng| {
            let n = 2 + rng.below(200) as usize;
            let k = 1 + rng.below(8) as usize;
            let pts = prop::random_cloud(rng, n, false);
            let res = brute_knn(&pts, &pts, k, true);
            let tree = KdTree::build(&pts);
            for (i, got) in res.neighbors.iter().enumerate() {
                let want = tree.knn_excluding(pts[i], k, Some(i as u32));
                if got.len() != want.len() {
                    return Err(format!("q{i} len {} vs {}", got.len(), want.len()));
                }
                for (g, w) in got.iter().zip(&want) {
                    if (g.dist - w.dist).abs() > 1e-5 {
                        return Err(format!("q{i} {} vs {}", g.dist, w.dist));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn test_counts_are_quadratic() {
        let pts = prop::random_cloud(&mut crate::util::Pcg32::new(1), 100, false);
        let res = brute_knn(&pts, &pts, 3, true);
        assert_eq!(res.counters.prim_tests, 100 * 100);
    }

    #[test]
    fn single_query_includes_exact_point() {
        let pts = vec![Point3::ZERO, Point3::splat(1.0)];
        let nn = brute_knn_single(&pts, Point3::ZERO, 1);
        assert_eq!(nn[0].idx, 0);
        assert_eq!(nn[0].dist, 0.0);
    }
}
