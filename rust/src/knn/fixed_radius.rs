//! Fixed-radius RT-kNNS — the paper's Algorithm 1 and its evaluation
//! baseline (§5.2.1: radius = maxDist so every point is guaranteed to
//! find its k neighbors; §5.5.1 uses the 99th-percentile radius).

use super::program::KnnProgram;
use super::{KnnResult, RoundStats};
use crate::geom::{Point3, Ray};
use crate::rt::{CostModel, HwCounters, Pipeline, Scene};
use crate::util::Stopwatch;

#[derive(Clone, Debug)]
pub struct FixedRadiusParams {
    pub k: usize,
    pub radius: f32,
    /// Queries are dataset points themselves (exclude self-hits).
    pub exclude_self: bool,
    pub cost_model: CostModel,
}

impl Default for FixedRadiusParams {
    fn default() -> Self {
        Self {
            k: 5,
            radius: 1.0,
            exclude_self: true,
            cost_model: CostModel::default(),
        }
    }
}

/// One-shot fixed-radius kNN over `data`, querying every point of
/// `queries` (`queries` usually aliases `data`; pass the same slice).
pub fn fixed_radius_knns(
    data: &[Point3],
    queries: &[Point3],
    params: &FixedRadiusParams,
) -> KnnResult {
    let wall = Stopwatch::start();
    let mut result = KnnResult::new(queries.len());
    let mut counters = HwCounters::new();

    // Alg. 1 lines 1–3: spheres, AABBs, BVH.
    let scene = Scene::build(data.to_vec(), params.radius, &mut counters);
    // one host→device switch to upload + launch
    counters.context_switches += 1;

    // Alg. 1 lines 4–13: one ray per query.
    let rays: Vec<Ray> = queries
        .iter()
        .enumerate()
        .map(|(i, &p)| Ray::knn(p, i as u32))
        .collect();
    let mut program = KnnProgram::new(queries.len(), params.k, params.exclude_self);
    Pipeline::launch(&scene, &rays, &mut program, &mut counters);
    counters.heap_pushes = program.total_pushes();

    for (q, heap) in program.heaps.into_iter().enumerate() {
        result.neighbors[q] = heap.into_sorted();
    }
    result.launches = 1;
    result.counters = counters;
    result.wall_seconds = wall.elapsed_secs();
    result.rounds.push(RoundStats {
        round: 0,
        radius: params.radius,
        queries: queries.len(),
        survivors: result
            .neighbors
            .iter()
            .filter(|n| n.len() < params.k)
            .count(),
        prim_tests: result.counters.prim_tests,
        sim_seconds: params.cost_model.seconds(&result.counters, 1),
        wall_seconds: result.wall_seconds,
    });
    result.finalize_sim_time(&params.cost_model);
    result
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::{DatasetKind, DistanceProfile};
    use crate::knn::kdtree::KdTree;

    #[test]
    fn maxdist_radius_is_exact_and_complete() {
        let ds = DatasetKind::Uniform.generate(800, 30);
        let k = 5;
        let prof = DistanceProfile::compute(&ds, k);
        let params = FixedRadiusParams {
            k,
            radius: prof.max_dist() as f32 * 1.0001,
            ..Default::default()
        };
        let res = fixed_radius_knns(&ds.points, &ds.points, &params);
        assert!(res.is_complete(k, ds.len() - 1));

        let tree = KdTree::build(&ds.points);
        for (i, got) in res.neighbors.iter().enumerate() {
            let want = tree.knn_excluding(ds.points[i], k, Some(i as u32));
            assert_eq!(got.len(), want.len(), "query {i}");
            for (g, w) in got.iter().zip(&want) {
                assert!((g.dist - w.dist).abs() < 1e-5, "query {i}");
            }
        }
    }

    #[test]
    fn small_radius_misses_neighbors() {
        // the paper's core complaint about fixed-radius search
        let ds = DatasetKind::Taxi.generate(2_000, 31);
        let params = FixedRadiusParams {
            k: 5,
            radius: 1e-6,
            ..Default::default()
        };
        let res = fixed_radius_knns(&ds.points, &ds.points, &params);
        assert!(!res.is_complete(5, ds.len() - 1));
        let incomplete = res.rounds[0].survivors;
        assert!(incomplete > ds.len() / 2, "only {incomplete} incomplete");
    }

    #[test]
    fn larger_radius_costs_more_tests() {
        let ds = DatasetKind::Uniform.generate(1_000, 32);
        let small = fixed_radius_knns(
            &ds.points,
            &ds.points,
            &FixedRadiusParams {
                radius: 0.05,
                ..Default::default()
            },
        );
        let large = fixed_radius_knns(
            &ds.points,
            &ds.points,
            &FixedRadiusParams {
                radius: 0.8,
                ..Default::default()
            },
        );
        assert!(large.counters.prim_tests > 5 * small.counters.prim_tests);
        assert!(large.sim_seconds > small.sim_seconds);
    }
}
