//! Fixed-radius RT-kNNS — the paper's Algorithm 1 and its evaluation
//! baseline (§5.2.1: radius = maxDist so every point is guaranteed to
//! find its k neighbors; §5.5.1 uses the 99th-percentile radius).
//!
//! The algorithm lives in [`crate::index::FixedRadiusIndex`];
//! [`fixed_radius_knns`] is the one-shot compatibility shim.

use super::KnnResult;
use crate::geom::Point3;
use crate::index::{FixedRadiusIndex, IndexConfig, NeighborIndex};
use crate::rt::CostModel;

#[derive(Clone, Debug)]
pub struct FixedRadiusParams {
    pub k: usize,
    pub radius: f32,
    /// Queries are dataset points themselves (exclude self-hits).
    pub exclude_self: bool,
    pub cost_model: CostModel,
}

impl Default for FixedRadiusParams {
    fn default() -> Self {
        Self {
            k: 5,
            radius: 1.0,
            exclude_self: true,
            cost_model: CostModel::default(),
        }
    }
}

impl FixedRadiusParams {
    /// The equivalent index configuration.
    pub fn to_index_config(&self) -> IndexConfig {
        IndexConfig {
            exclude_self: self.exclude_self,
            cost_model: self.cost_model,
            radius: Some(self.radius),
            ..Default::default()
        }
    }
}

/// One-shot fixed-radius kNN over `data`, querying every point of
/// `queries` (`queries` usually aliases `data`; pass the same slice).
///
/// Compatibility shim over [`FixedRadiusIndex`]: builds, queries once
/// and folds the build into the result. Hold a [`FixedRadiusIndex`] to
/// amortize the BVH across query batches.
pub fn fixed_radius_knns(
    data: &[Point3],
    queries: &[Point3],
    params: &FixedRadiusParams,
) -> KnnResult {
    let mut index = FixedRadiusIndex::new(data.to_vec(), params.to_index_config());
    let mut result = index.knn(queries, params.k);
    index
        .build_stats()
        .absorb_into(&mut result, &params.cost_model);
    result
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::{DatasetKind, DistanceProfile};
    use crate::knn::kdtree::KdTree;

    #[test]
    fn maxdist_radius_is_exact_and_complete() {
        let ds = DatasetKind::Uniform.generate(800, 30);
        let k = 5;
        let prof = DistanceProfile::compute(&ds, k);
        let params = FixedRadiusParams {
            k,
            radius: prof.max_dist() as f32 * 1.0001,
            ..Default::default()
        };
        let res = fixed_radius_knns(&ds.points, &ds.points, &params);
        assert!(res.is_complete(k, ds.len() - 1));

        let tree = KdTree::build(&ds.points);
        for (i, got) in res.neighbors.iter().enumerate() {
            let want = tree.knn_excluding(ds.points[i], k, Some(i as u32));
            assert_eq!(got.len(), want.len(), "query {i}");
            for (g, w) in got.iter().zip(&want) {
                assert!((g.dist - w.dist).abs() < 1e-5, "query {i}");
            }
        }
    }

    #[test]
    fn small_radius_misses_neighbors() {
        // the paper's core complaint about fixed-radius search
        let ds = DatasetKind::Taxi.generate(2_000, 31);
        let params = FixedRadiusParams {
            k: 5,
            radius: 1e-6,
            ..Default::default()
        };
        let res = fixed_radius_knns(&ds.points, &ds.points, &params);
        assert!(!res.is_complete(5, ds.len() - 1));
        let incomplete = res.rounds[0].survivors;
        assert!(incomplete > ds.len() / 2, "only {incomplete} incomplete");
    }

    #[test]
    fn larger_radius_costs_more_tests() {
        let ds = DatasetKind::Uniform.generate(1_000, 32);
        let small = fixed_radius_knns(
            &ds.points,
            &ds.points,
            &FixedRadiusParams {
                radius: 0.05,
                ..Default::default()
            },
        );
        let large = fixed_radius_knns(
            &ds.points,
            &ds.points,
            &FixedRadiusParams {
                radius: 0.8,
                ..Default::default()
            },
        );
        assert!(large.counters.prim_tests > 5 * small.counters.prim_tests);
        assert!(large.sim_seconds > small.sim_seconds);
    }
}
