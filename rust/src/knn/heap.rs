//! Bounded max-heap holding the k best (smallest-distance) candidates —
//! the "list of k nearest neighbors" whose maintenance cost the paper
//! identifies as the sorting overhead (§3.4, §5.3.2).

use super::Neighbor;

/// Max-heap ordered lexicographically on `(dist, idx)`, capacity `k`.
/// `push` takes a *squared* distance (what traversals compute), takes
/// its square root once, and keeps the k smallest items under the
/// `(dist, id)` total order; `pushes` counts successful insertions (the
/// sorting-work telemetry fed to `HwCounters::heap_pushes`).
///
/// The ordering key is deliberately the **rounded Euclidean distance**
/// — the exact value reported in [`Neighbor::dist`] — not the squared
/// distance, and the id tie-break is load-bearing, not cosmetic. The
/// kept set is exactly the k lexicographically-smallest candidates
/// under `(dist, id)` *regardless of push order*, which is the same
/// total order the sharded gather merges under
/// ([`crate::shard::merge_topk`]). Cutting on `dist2` instead would
/// re-open a divergence: two distinct `dist2` values can round to the
/// same `f32` square root, so a single heap would order them while the
/// gather (which only sees `dist`) must tie-break by id. With every cut
/// on `(dist, id)`, results are bitwise-identical across shard counts
/// even at forced k-th-boundary ties.
#[derive(Clone, Debug)]
pub struct KHeap {
    k: usize,
    /// (dist, idx) max-heap, lexicographic order.
    items: Vec<(f32, u32)>,
    pub pushes: u64,
}

/// Strict "worse than" under the `(dist, idx)` total order. NaN never
/// enters the heap (rejected at `push`), so `total_cmp` here is purely
/// a deterministic tie-break, not a NaN policy.
#[inline]
fn worse(a: (f32, u32), b: (f32, u32)) -> bool {
    a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)) == std::cmp::Ordering::Greater
}

impl KHeap {
    pub fn new(k: usize) -> Self {
        Self {
            k,
            items: Vec::with_capacity(k),
            pushes: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    pub fn is_full(&self) -> bool {
        self.items.len() == self.k
    }

    pub fn capacity(&self) -> usize {
        self.k
    }

    /// Current worst (largest) kept distance, or +inf if not full. A
    /// traversal may skip a subtree only when every point it could hold
    /// is **strictly farther** than this — a candidate *at* the bound
    /// can still displace the current worst by winning the id tie-break.
    pub fn bound_dist(&self) -> f32 {
        if self.is_full() {
            self.items[0].0
        } else {
            f32::INFINITY
        }
    }

    pub fn clear(&mut self) {
        self.items.clear();
    }

    /// Offer a candidate by squared distance; returns true if kept.
    #[inline]
    pub fn push(&mut self, dist2: f32, idx: u32) -> bool {
        if self.k == 0 || dist2.is_nan() {
            // a NaN distance (degenerate query coordinates) is never a
            // valid neighbor and would poison the max-heap ordering
            return false;
        }
        // the ordering key is the rounded distance (see the type docs)
        let dist = dist2.sqrt();
        if self.items.len() < self.k {
            self.items.push((dist, idx));
            self.sift_up(self.items.len() - 1);
            self.pushes += 1;
            true
        } else if worse(self.items[0], (dist, idx)) {
            self.items[0] = (dist, idx);
            self.sift_down(0);
            self.pushes += 1;
            true
        } else {
            false
        }
    }

    fn sift_up(&mut self, mut i: usize) {
        while i > 0 {
            let parent = (i - 1) / 2;
            if worse(self.items[i], self.items[parent]) {
                self.items.swap(i, parent);
                i = parent;
            } else {
                break;
            }
        }
    }

    fn sift_down(&mut self, mut i: usize) {
        loop {
            let (l, r) = (2 * i + 1, 2 * i + 2);
            let mut largest = i;
            if l < self.items.len() && worse(self.items[l], self.items[largest]) {
                largest = l;
            }
            if r < self.items.len() && worse(self.items[r], self.items[largest]) {
                largest = r;
            }
            if largest == i {
                return;
            }
            self.items.swap(i, largest);
            i = largest;
        }
    }

    /// Drain into a `(dist, id)`-ascending neighbor list (distances were
    /// already rooted at push time).
    pub fn into_sorted(self) -> Vec<Neighbor> {
        let mut v: Vec<Neighbor> = self
            .items
            .into_iter()
            .map(|(dist, idx)| Neighbor { idx, dist })
            .collect();
        v.sort_by(|a, b| a.dist.total_cmp(&b.dist).then(a.idx.cmp(&b.idx)));
        v
    }

    /// Sorted copy without consuming.
    pub fn sorted(&self) -> Vec<Neighbor> {
        self.clone().into_sorted()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn keeps_k_smallest() {
        let mut h = KHeap::new(3);
        for (i, d) in [5.0f32, 1.0, 4.0, 2.0, 3.0, 0.5].iter().enumerate() {
            h.push(*d, i as u32);
        }
        let out = h.into_sorted();
        let dists: Vec<f32> = out.iter().map(|n| n.dist * n.dist).collect();
        assert_eq!(out.len(), 3);
        assert!((dists[0] - 0.5).abs() < 1e-6);
        assert!((dists[1] - 1.0).abs() < 1e-6);
        assert!((dists[2] - 2.0).abs() < 1e-6);
    }

    #[test]
    fn zero_capacity_rejects() {
        let mut h = KHeap::new(0);
        assert!(!h.push(1.0, 0));
        assert!(h.is_empty());
        assert_eq!(h.pushes, 0);
    }

    #[test]
    fn bound_tracks_worst_kept() {
        let mut h = KHeap::new(2);
        assert_eq!(h.bound_dist(), f32::INFINITY);
        h.push(4.0, 0);
        h.push(9.0, 1);
        assert_eq!(h.bound_dist(), 3.0);
        h.push(1.0, 2);
        assert_eq!(h.bound_dist(), 2.0);
    }

    #[test]
    fn heap_matches_sort_property() {
        prop::check("kheap ≡ sort-then-truncate", 50, |rng| {
            let n = 1 + rng.below(200) as usize;
            let k = 1 + rng.below(20) as usize;
            let xs: Vec<f32> = (0..n).map(|_| rng.f32()).collect();
            let mut h = KHeap::new(k);
            for (i, &x) in xs.iter().enumerate() {
                h.push(x, i as u32);
            }
            let got: Vec<f32> = h.into_sorted().iter().map(|n| n.dist * n.dist).collect();
            let mut want = xs.clone();
            want.sort_by(|a, b| a.partial_cmp(b).unwrap());
            want.truncate(k);
            if got.len() != want.len() {
                return Err(format!("len {} vs {}", got.len(), want.len()));
            }
            for (g, w) in got.iter().zip(&want) {
                if (g - w).abs() > 1e-5 {
                    return Err(format!("{g} vs {w}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn pushes_counts_insertions_only() {
        let mut h = KHeap::new(1);
        h.push(1.0, 0); // kept
        h.push(2.0, 1); // rejected
        h.push(0.5, 2); // replaces
        assert_eq!(h.pushes, 2);
    }

    #[test]
    fn boundary_ties_break_on_id_not_arrival() {
        // three candidates tie at the k-th distance; the two smallest ids
        // must win no matter which order they arrive in
        for order in [[5u32, 3, 4], [4, 5, 3], [3, 4, 5], [5, 4, 3]] {
            let mut h = KHeap::new(2);
            for id in order {
                h.push(1.0, id);
            }
            let got: Vec<u32> = h.into_sorted().iter().map(|n| n.idx).collect();
            assert_eq!(got, vec![3, 4], "arrival order {order:?}");
        }
    }

    #[test]
    fn kept_set_is_push_order_independent() {
        prop::check("kheap kept set ≡ (dist, id) sort prefix", 50, |rng| {
            let n = 2 + rng.below(100) as usize;
            let k = 1 + rng.below(8) as usize;
            // small value alphabet forces heavy distance ties
            let xs: Vec<(f32, u32)> = (0..n)
                .map(|i| ((rng.below(4) as f32) * 0.25, i as u32))
                .collect();
            let mut fwd = KHeap::new(k);
            let mut rev = KHeap::new(k);
            for &(d, i) in &xs {
                fwd.push(d, i);
            }
            for &(d, i) in xs.iter().rev() {
                rev.push(d, i);
            }
            let want: Vec<(u32, u32)> = {
                let mut v: Vec<(f32, u32)> = xs.clone();
                v.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
                v.truncate(k);
                v.into_iter().map(|(d, i)| (d.sqrt().to_bits(), i)).collect()
            };
            for (name, h) in [("fwd", fwd), ("rev", rev)] {
                let got: Vec<(u32, u32)> =
                    h.into_sorted().iter().map(|n| (n.dist.to_bits(), n.idx)).collect();
                if got != want {
                    return Err(format!("{name}: {got:?} vs {want:?}"));
                }
            }
            Ok(())
        });
    }
}
