//! Bounded max-heap holding the k best (smallest-distance) candidates —
//! the "list of k nearest neighbors" whose maintenance cost the paper
//! identifies as the sorting overhead (§3.4, §5.3.2).

use super::Neighbor;

/// Max-heap on squared distance, capacity `k`. `push` keeps the k
/// smallest items seen; `pushes` counts successful insertions (the
/// sorting-work telemetry fed to `HwCounters::heap_pushes`).
#[derive(Clone, Debug)]
pub struct KHeap {
    k: usize,
    /// (dist2, idx) max-heap order on dist2.
    items: Vec<(f32, u32)>,
    pub pushes: u64,
}

impl KHeap {
    pub fn new(k: usize) -> Self {
        Self {
            k,
            items: Vec::with_capacity(k),
            pushes: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    pub fn is_full(&self) -> bool {
        self.items.len() == self.k
    }

    pub fn capacity(&self) -> usize {
        self.k
    }

    /// Current worst (largest) kept squared distance, or +inf if not full.
    pub fn bound2(&self) -> f32 {
        if self.is_full() {
            self.items[0].0
        } else {
            f32::INFINITY
        }
    }

    pub fn clear(&mut self) {
        self.items.clear();
    }

    /// Offer a candidate; returns true if kept.
    #[inline]
    pub fn push(&mut self, dist2: f32, idx: u32) -> bool {
        if self.k == 0 || dist2.is_nan() {
            // a NaN distance (degenerate query coordinates) is never a
            // valid neighbor and would poison the max-heap ordering
            return false;
        }
        if self.items.len() < self.k {
            self.items.push((dist2, idx));
            self.sift_up(self.items.len() - 1);
            self.pushes += 1;
            true
        } else if dist2 < self.items[0].0 {
            self.items[0] = (dist2, idx);
            self.sift_down(0);
            self.pushes += 1;
            true
        } else {
            false
        }
    }

    fn sift_up(&mut self, mut i: usize) {
        while i > 0 {
            let parent = (i - 1) / 2;
            if self.items[i].0 > self.items[parent].0 {
                self.items.swap(i, parent);
                i = parent;
            } else {
                break;
            }
        }
    }

    fn sift_down(&mut self, mut i: usize) {
        loop {
            let (l, r) = (2 * i + 1, 2 * i + 2);
            let mut largest = i;
            if l < self.items.len() && self.items[l].0 > self.items[largest].0 {
                largest = l;
            }
            if r < self.items.len() && self.items[r].0 > self.items[largest].0 {
                largest = r;
            }
            if largest == i {
                return;
            }
            self.items.swap(i, largest);
            i = largest;
        }
    }

    /// Drain into a distance-ascending neighbor list.
    pub fn into_sorted(self) -> Vec<Neighbor> {
        let mut v: Vec<Neighbor> = self
            .items
            .into_iter()
            .map(|(d2, idx)| Neighbor {
                idx,
                dist: d2.sqrt(),
            })
            .collect();
        v.sort_by(|a, b| a.dist.total_cmp(&b.dist).then(a.idx.cmp(&b.idx)));
        v
    }

    /// Sorted copy without consuming.
    pub fn sorted(&self) -> Vec<Neighbor> {
        self.clone().into_sorted()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn keeps_k_smallest() {
        let mut h = KHeap::new(3);
        for (i, d) in [5.0f32, 1.0, 4.0, 2.0, 3.0, 0.5].iter().enumerate() {
            h.push(*d, i as u32);
        }
        let out = h.into_sorted();
        let dists: Vec<f32> = out.iter().map(|n| n.dist * n.dist).collect();
        assert_eq!(out.len(), 3);
        assert!((dists[0] - 0.5).abs() < 1e-6);
        assert!((dists[1] - 1.0).abs() < 1e-6);
        assert!((dists[2] - 2.0).abs() < 1e-6);
    }

    #[test]
    fn zero_capacity_rejects() {
        let mut h = KHeap::new(0);
        assert!(!h.push(1.0, 0));
        assert!(h.is_empty());
        assert_eq!(h.pushes, 0);
    }

    #[test]
    fn bound_tracks_worst_kept() {
        let mut h = KHeap::new(2);
        assert_eq!(h.bound2(), f32::INFINITY);
        h.push(4.0, 0);
        h.push(9.0, 1);
        assert_eq!(h.bound2(), 9.0);
        h.push(1.0, 2);
        assert_eq!(h.bound2(), 4.0);
    }

    #[test]
    fn heap_matches_sort_property() {
        prop::check("kheap ≡ sort-then-truncate", 50, |rng| {
            let n = 1 + rng.below(200) as usize;
            let k = 1 + rng.below(20) as usize;
            let xs: Vec<f32> = (0..n).map(|_| rng.f32()).collect();
            let mut h = KHeap::new(k);
            for (i, &x) in xs.iter().enumerate() {
                h.push(x, i as u32);
            }
            let got: Vec<f32> = h.into_sorted().iter().map(|n| n.dist * n.dist).collect();
            let mut want = xs.clone();
            want.sort_by(|a, b| a.partial_cmp(b).unwrap());
            want.truncate(k);
            if got.len() != want.len() {
                return Err(format!("len {} vs {}", got.len(), want.len()));
            }
            for (g, w) in got.iter().zip(&want) {
                if (g - w).abs() > 1e-5 {
                    return Err(format!("{g} vs {w}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn pushes_counts_insertions_only() {
        let mut h = KHeap::new(1);
        h.push(1.0, 0); // kept
        h.push(2.0, 1); // rejected
        h.push(0.5, 2); // replaces
        assert_eq!(h.pushes, 2);
    }
}
