//! Exact kd-tree kNN — the validation oracle for every RT path, and the
//! ball-tree stand-in for the paper's Alg. 2 start-radius sampler (the
//! paper uses scikit-learn; we build our own, §2.3 of DESIGN.md).

use super::{KHeap, Neighbor};
use crate::geom::{dist2, Point3};

#[derive(Clone, Debug)]
enum KdNode {
    Leaf {
        first: u32,
        count: u32,
    },
    Split {
        axis: u8,
        value: f32,
        left: u32,
        right: u32,
    },
}

#[derive(Clone, Debug)]
pub struct KdTree {
    nodes: Vec<KdNode>,
    /// Point ids in leaf order.
    order: Vec<u32>,
    points: Vec<Point3>,
    root: u32,
}

const LEAF: usize = 16;

impl KdTree {
    pub fn build(points: &[Point3]) -> KdTree {
        let mut tree = KdTree {
            nodes: Vec::new(),
            order: (0..points.len() as u32).collect(),
            points: points.to_vec(),
            root: 0,
        };
        if points.is_empty() {
            return tree;
        }
        let mut order = std::mem::take(&mut tree.order);
        let root = tree.subdivide(&mut order, 0, points.len());
        tree.order = order;
        tree.root = root;
        tree
    }

    fn subdivide(&mut self, order: &mut [u32], lo: usize, hi: usize) -> u32 {
        let idx = self.nodes.len() as u32;
        let count = hi - lo;
        if count <= LEAF {
            self.nodes.push(KdNode::Leaf {
                first: lo as u32,
                count: count as u32,
            });
            return idx;
        }
        // widest axis of the point extent
        let mut bb = crate::geom::Aabb::EMPTY;
        for &p in &order[lo..hi] {
            bb.grow(self.points[p as usize]);
        }
        let axis = bb.longest_axis();
        let mid = lo + count / 2;
        order[lo..hi].select_nth_unstable_by(mid - lo, |&a, &b| {
            self.points[a as usize][axis]
                .partial_cmp(&self.points[b as usize][axis])
                .unwrap_or(std::cmp::Ordering::Equal)
        });
        let value = self.points[order[mid] as usize][axis];
        self.nodes.push(KdNode::Split {
            axis: axis as u8,
            value,
            left: u32::MAX,
            right: u32::MAX,
        });
        let l = self.subdivide(order, lo, mid);
        let r = self.subdivide(order, mid, hi);
        if let KdNode::Split { left, right, .. } = &mut self.nodes[idx as usize] {
            *left = l;
            *right = r;
        }
        idx
    }

    /// Exact k nearest neighbors of `q`; `exclude` removes one point id
    /// (self-queries). Sorted ascending by distance.
    pub fn knn_excluding(&self, q: Point3, k: usize, exclude: Option<u32>) -> Vec<Neighbor> {
        if self.points.is_empty() || k == 0 {
            return Vec::new();
        }
        let mut heap = KHeap::new(k);
        self.search(self.root, q, exclude, &mut heap);
        heap.into_sorted()
    }

    pub fn knn(&self, q: Point3, k: usize) -> Vec<Neighbor> {
        self.knn_excluding(q, k, None)
    }

    fn search(&self, node: u32, q: Point3, exclude: Option<u32>, heap: &mut KHeap) {
        match &self.nodes[node as usize] {
            KdNode::Leaf { first, count } => {
                let first = *first as usize;
                let count = *count as usize;
                for &p in &self.order[first..first + count] {
                    if exclude == Some(p) {
                        continue;
                    }
                    heap.push(dist2(self.points[p as usize], q), p);
                }
            }
            KdNode::Split {
                axis,
                value,
                left,
                right,
            } => {
                let delta = q[*axis as usize] - value;
                let (near, far) = if delta < 0.0 {
                    (*left, *right)
                } else {
                    (*right, *left)
                };
                self.search(near, q, exclude, heap);
                // visit the far side up to *and including* the bound: a
                // point exactly at the k-th distance can still win the
                // heap's (dist, id) tie-break. Compared in rooted-distance
                // space — the heap's canonical order — via the same
                // monotone sqrt the candidate distances go through.
                if (delta * delta).sqrt() <= heap.bound_dist() {
                    self.search(far, q, exclude, heap);
                }
            }
        }
    }

    /// All points within radius `r` of `q` (used by tests).
    pub fn range(&self, q: Point3, r: f32) -> Vec<u32> {
        let mut out = Vec::new();
        if self.points.is_empty() {
            return out;
        }
        let r2 = r * r;
        let mut stack = vec![self.root];
        while let Some(node) = stack.pop() {
            match &self.nodes[node as usize] {
                KdNode::Leaf { first, count } => {
                    let first = *first as usize;
                    let count = *count as usize;
                    for &p in &self.order[first..first + count] {
                        if dist2(self.points[p as usize], q) <= r2 {
                            out.push(p);
                        }
                    }
                }
                KdNode::Split {
                    axis,
                    value,
                    left,
                    right,
                } => {
                    let delta = q[*axis as usize] - value;
                    if delta < 0.0 {
                        stack.push(*left);
                        if delta * delta <= r2 {
                            stack.push(*right);
                        }
                    } else {
                        stack.push(*right);
                        if delta * delta <= r2 {
                            stack.push(*left);
                        }
                    }
                }
            }
        }
        out
    }

    pub fn len(&self) -> usize {
        self.points.len()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    /// Serialize the tree for a crash-safe snapshot. Lives here because
    /// the node arena is private — the persist layer sees only bytes.
    pub fn encode_into(&self, enc: &mut crate::persist::Enc) {
        enc.put_u32(self.root);
        enc.put_len(self.nodes.len());
        for n in &self.nodes {
            match n {
                KdNode::Leaf { first, count } => {
                    enc.put_u8(0);
                    enc.put_u32(*first);
                    enc.put_u32(*count);
                }
                KdNode::Split { axis, value, left, right } => {
                    enc.put_u8(1);
                    enc.put_u8(*axis);
                    enc.put_f32(*value);
                    enc.put_u32(*left);
                    enc.put_u32(*right);
                }
            }
        }
        enc.put_len(self.order.len());
        for &i in &self.order {
            enc.put_u32(i);
        }
        enc.put_len(self.points.len());
        for p in &self.points {
            enc.put_f32(p.x);
            enc.put_f32(p.y);
            enc.put_f32(p.z);
        }
    }

    /// Decode a tree written by [`KdTree::encode_into`], re-validating
    /// every index (root, split children, leaf ranges, leaf-order ids)
    /// so corrupt payloads become typed errors instead of later panics.
    pub fn decode_from(
        dec: &mut crate::persist::Dec<'_>,
    ) -> Result<KdTree, crate::persist::PersistError> {
        use crate::persist::PersistError;
        let corrupt = |detail: String| PersistError::Corrupt { what: "kdtree", detail };
        let root = dec.get_u32()?;
        let n_nodes = dec.get_len()?;
        let mut nodes = Vec::with_capacity(n_nodes);
        for i in 0..n_nodes {
            match dec.get_u8()? {
                0 => nodes.push(KdNode::Leaf { first: dec.get_u32()?, count: dec.get_u32()? }),
                1 => nodes.push(KdNode::Split {
                    axis: dec.get_u8()?,
                    value: dec.get_f32()?,
                    left: dec.get_u32()?,
                    right: dec.get_u32()?,
                }),
                t => return Err(corrupt(format!("node {i} has unknown tag {t}"))),
            }
        }
        let n_order = dec.get_len()?;
        let mut order = Vec::with_capacity(n_order);
        for _ in 0..n_order {
            order.push(dec.get_u32()?);
        }
        let n_points = dec.get_len()?;
        let mut points = Vec::with_capacity(n_points);
        for _ in 0..n_points {
            points.push(Point3::new(dec.get_f32()?, dec.get_f32()?, dec.get_f32()?));
        }
        if order.len() != points.len() {
            return Err(corrupt(format!(
                "{} order entries for {} points",
                order.len(),
                points.len()
            )));
        }
        if order.iter().any(|&i| i as usize >= points.len()) {
            return Err(corrupt("leaf-order id out of range".to_string()));
        }
        if !points.is_empty() && root as usize >= nodes.len() {
            return Err(corrupt(format!("root {root} outside {} nodes", nodes.len())));
        }
        for (i, n) in nodes.iter().enumerate() {
            match n {
                KdNode::Leaf { first, count } => {
                    let end = (*first as usize).checked_add(*count as usize);
                    if end.is_none() || end.unwrap_or(usize::MAX) > order.len() {
                        return Err(corrupt(format!("leaf {i} range outside order")));
                    }
                }
                KdNode::Split { axis, left, right, .. } => {
                    if *axis > 2
                        || *left as usize >= nodes.len()
                        || *right as usize >= nodes.len()
                    {
                        return Err(corrupt(format!("split {i} has out-of-range fields")));
                    }
                }
            }
        }
        Ok(KdTree { nodes, order, points, root })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn brute_knn(pts: &[Point3], q: Point3, k: usize, exclude: Option<u32>) -> Vec<Neighbor> {
        let mut all: Vec<Neighbor> = pts
            .iter()
            .enumerate()
            .filter(|(i, _)| exclude != Some(*i as u32))
            .map(|(i, &p)| Neighbor {
                idx: i as u32,
                dist: crate::geom::dist(p, q),
            })
            .collect();
        all.sort_by(|a, b| a.dist.partial_cmp(&b.dist).unwrap().then(a.idx.cmp(&b.idx)));
        all.truncate(k);
        all
    }

    #[test]
    fn knn_matches_brute_force() {
        prop::check("kdtree knn ≡ brute force", 30, |rng| {
            let n = 1 + rng.below(400) as usize;
            let k = 1 + rng.below(12) as usize;
            let dims2 = rng.f32() < 0.3;
            let pts = prop::random_cloud(rng, n, dims2);
            let tree = KdTree::build(&pts);
            let qi = rng.below_usize(n);
            let exclude = if rng.f32() < 0.5 { Some(qi as u32) } else { None };
            let got = tree.knn_excluding(pts[qi], k, exclude);
            let want = brute_knn(&pts, pts[qi], k, exclude);
            if got.len() != want.len() {
                return Err(format!("len {} vs {}", got.len(), want.len()));
            }
            for (g, w) in got.iter().zip(&want) {
                if (g.dist - w.dist).abs() > 1e-5 {
                    return Err(format!("dist {} vs {}", g.dist, w.dist));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn range_matches_brute_force() {
        prop::check("kdtree range ≡ brute force", 30, |rng| {
            let n = 1 + rng.below(300) as usize;
            let pts = prop::random_cloud(rng, n, false);
            let tree = KdTree::build(&pts);
            let q = Point3::new(rng.f32(), rng.f32(), rng.f32());
            let r = rng.f32() * 0.5;
            let mut got = tree.range(q, r);
            got.sort_unstable();
            let mut want: Vec<u32> = (0..n as u32)
                .filter(|&i| crate::geom::dist(pts[i as usize], q) <= r)
                .collect();
            want.sort_unstable();
            if got != want {
                return Err(format!("got {got:?} want {want:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn empty_and_degenerate() {
        let tree = KdTree::build(&[]);
        assert!(tree.knn(Point3::ZERO, 3).is_empty());
        assert!(tree.range(Point3::ZERO, 1.0).is_empty());

        // all-identical points
        let pts = vec![Point3::splat(0.3); 40];
        let tree = KdTree::build(&pts);
        let nn = tree.knn(Point3::splat(0.3), 5);
        assert_eq!(nn.len(), 5);
        assert!(nn.iter().all(|n| n.dist == 0.0));
    }

    #[test]
    fn k_larger_than_n_returns_all() {
        let pts = vec![
            Point3::new(0.0, 0.0, 0.0),
            Point3::new(1.0, 0.0, 0.0),
            Point3::new(2.0, 0.0, 0.0),
        ];
        let tree = KdTree::build(&pts);
        let nn = tree.knn(Point3::ZERO, 10);
        assert_eq!(nn.len(), 3);
        assert_eq!(nn[0].idx, 0);
        assert_eq!(nn[2].idx, 2);
    }
}
