//! k-nearest-neighbor search algorithms: the paper's TrueKNN (Alg. 3),
//! its fixed-radius RT-kNNS baseline (Alg. 1), the RTNN-style optimized
//! baseline, a CPU brute force (cuML stand-in when PJRT is not wanted)
//! and an exact kd-tree reference used for validation and for the
//! start-radius sampler (Alg. 2).

pub mod heap;
pub mod kdtree;
pub mod program;
pub mod fixed_radius;
pub mod trueknn;
pub mod start_radius;
pub mod rtnn;
pub mod brute;

pub use fixed_radius::{fixed_radius_knns, FixedRadiusParams};
pub use heap::KHeap;
pub use start_radius::random_sample_radius;
pub use trueknn::{trueknn, TrueKnnParams};

use crate::rt::{CostModel, HwCounters};

/// One neighbor: data-point index + Euclidean distance.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Neighbor {
    pub idx: u32,
    pub dist: f32,
}

/// Per-round telemetry (drives Fig 6a/6b).
#[derive(Clone, Debug)]
pub struct RoundStats {
    pub round: usize,
    pub radius: f32,
    /// Query points launched this round.
    pub queries: usize,
    /// Query points still incomplete *after* this round.
    pub survivors: usize,
    /// Software intersection tests this round.
    pub prim_tests: u64,
    /// Annulus heap pushes this round (k-heap insertions from shell
    /// re-query hits — the per-round slice of `HwCounters::heap_pushes`,
    /// surfaced so trace round spans match the flat counters exactly).
    pub heap_pushes: u64,
    /// Simulated GPU seconds for this round.
    pub sim_seconds: f64,
    /// Wall-clock seconds for this round.
    pub wall_seconds: f64,
}

/// Result of any search path: per-query sorted neighbor lists plus the
/// complete cost telemetry.
#[derive(Clone, Debug)]
pub struct KnnResult {
    /// `neighbors[q]` sorted ascending by distance, length ≤ k.
    pub neighbors: Vec<Vec<Neighbor>>,
    pub counters: HwCounters,
    /// Number of optixLaunch-equivalents issued.
    pub launches: u64,
    pub rounds: Vec<RoundStats>,
    pub sim_seconds: f64,
    pub wall_seconds: f64,
}

impl KnnResult {
    pub fn new(n_queries: usize) -> Self {
        Self {
            neighbors: vec![Vec::new(); n_queries],
            counters: HwCounters::new(),
            launches: 0,
            rounds: Vec::new(),
            sim_seconds: 0.0,
            wall_seconds: 0.0,
        }
    }

    /// Recompute simulated time from the counters (used after merges).
    pub fn finalize_sim_time(&mut self, model: &CostModel) {
        self.sim_seconds = model.seconds(&self.counters, self.launches);
    }

    /// Check every query found exactly `min(k, max_possible)` neighbors.
    pub fn is_complete(&self, k: usize, max_possible: usize) -> bool {
        let want = k.min(max_possible);
        self.neighbors.iter().all(|n| n.len() == want)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn result_completeness_check() {
        let mut r = KnnResult::new(2);
        r.neighbors[0] = vec![Neighbor { idx: 1, dist: 0.1 }];
        r.neighbors[1] = vec![Neighbor { idx: 0, dist: 0.1 }];
        assert!(r.is_complete(1, 10));
        assert!(!r.is_complete(2, 10));
        assert!(r.is_complete(5, 1)); // capped by availability
    }
}
