//! The kNN `Intersection` program: mirrors the paper's implementation
//! choice of doing all kNN logic inside the software intersection test
//! with AnyHit/ClosestHit disabled (§4).

use super::KHeap;
use crate::geom::Ray;
use crate::rt::IntersectionProgram;

/// Maintains one bounded k-heap per query point. Query ids are *global*
/// dataset indices, so TrueKNN can launch shrinking ray subsets across
/// rounds while results land in stable slots.
pub struct KnnProgram {
    pub heaps: Vec<KHeap>,
    /// Exclude the sphere whose id equals the ray's query id (self-hit
    /// when the query set is the dataset itself).
    pub exclude_self: bool,
}

impl KnnProgram {
    pub fn new(n_queries: usize, k: usize, exclude_self: bool) -> Self {
        Self {
            heaps: (0..n_queries).map(|_| KHeap::new(k)).collect(),
            exclude_self,
        }
    }

    /// Reset the heaps for a re-queried subset (each TrueKNN round
    /// re-discovers everything inside the bigger radius, §3.3).
    pub fn reset(&mut self, query_ids: &[u32]) {
        for &q in query_ids {
            self.heaps[q as usize].clear();
        }
    }

    /// Total heap insertions across all queries (sorting-work telemetry).
    pub fn total_pushes(&self) -> u64 {
        self.heaps.iter().map(|h| h.pushes).sum()
    }
}

impl IntersectionProgram for KnnProgram {
    #[inline]
    fn hit(&mut self, ray: &Ray, prim: u32, dist2: f32) {
        if self.exclude_self && prim == ray.query_id {
            return;
        }
        self.heaps[ray.query_id as usize].push(dist2, prim);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rt::{HwCounters, Pipeline, Scene};
    use crate::geom::Point3;
    use crate::util::prop;
    use crate::util::Pcg32;

    #[test]
    fn program_collects_k_nearest_within_radius() {
        let mut rng = Pcg32::new(21);
        let pts = prop::random_cloud(&mut rng, 500, false);
        let r = 0.2;
        let k = 5;
        let mut c = HwCounters::new();
        let scene = Scene::build(pts.clone(), r, &mut c);
        let rays: Vec<crate::geom::Ray> = (0..pts.len())
            .map(|i| crate::geom::Ray::knn(pts[i], i as u32))
            .collect();
        let mut prog = KnnProgram::new(pts.len(), k, true);
        Pipeline::launch(&scene, &rays, &mut prog, &mut c);

        let tree = crate::knn::kdtree::KdTree::build(&pts);
        for i in 0..pts.len() {
            let got = prog.heaps[i].sorted();
            let exact = tree.knn_excluding(pts[i], k, Some(i as u32));
            let exact_in_r: Vec<_> = exact.into_iter().filter(|n| n.dist <= r).collect();
            assert_eq!(got.len(), exact_in_r.len(), "query {i}");
            for (g, w) in got.iter().zip(&exact_in_r) {
                assert!((g.dist - w.dist).abs() < 1e-5, "query {i}");
            }
        }
    }

    #[test]
    fn self_hit_excluded_only_when_asked() {
        let pts = vec![Point3::ZERO, Point3::new(0.1, 0.0, 0.0)];
        let mut c = HwCounters::new();
        let scene = Scene::build(pts.clone(), 1.0, &mut c);
        let rays = vec![crate::geom::Ray::knn(pts[0], 0)];

        let mut incl = KnnProgram::new(2, 5, false);
        Pipeline::launch(&scene, &rays, &mut incl, &mut c);
        assert_eq!(incl.heaps[0].len(), 2, "self included");

        let mut excl = KnnProgram::new(2, 5, true);
        Pipeline::launch(&scene, &rays, &mut excl, &mut c);
        let got = excl.heaps[0].sorted();
        assert_eq!(got.len(), 1, "self excluded");
        assert_eq!(got[0].idx, 1);
    }

    #[test]
    fn reset_clears_only_named_queries() {
        let mut prog = KnnProgram::new(3, 2, false);
        prog.heaps[0].push(1.0, 1);
        prog.heaps[1].push(1.0, 1);
        prog.heaps[2].push(1.0, 1);
        prog.reset(&[0, 2]);
        assert!(prog.heaps[0].is_empty());
        assert_eq!(prog.heaps[1].len(), 1);
        assert!(prog.heaps[2].is_empty());
    }
}
