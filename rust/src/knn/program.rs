//! The kNN `Intersection` program: mirrors the paper's implementation
//! choice of doing all kNN logic inside the software intersection test
//! with AnyHit/ClosestHit disabled (§4).
//!
//! Two execution features layer on top of the basic k-heap maintenance:
//!
//! - **Shell (annulus) filter** for TrueKNN's shell re-query: survivors
//!   keep their partial heaps across rounds, and hits with
//!   `dist2 <= min_dist2` (already discovered inside the previous
//!   round's radius) are discarded before touching the heap. Exact,
//!   because a surviving query's `< k` prior hits all already sit in its
//!   heap — only the annulus `(r_prev, r]` contributes new candidates.
//! - **Sharding** for the parallel launch engine: each query's heap is
//!   *moved* into the shard that owns its ray and moved back on merge,
//!   so every heap sees the exact push sequence of a serial run.

use super::KHeap;
use crate::geom::Ray;
use crate::rt::{IntersectionProgram, ShardableProgram};

/// Maintains one bounded k-heap per query point. Query ids are *global*
/// dataset indices, so TrueKNN can launch shrinking ray subsets across
/// rounds while results land in stable slots.
pub struct KnnProgram {
    pub heaps: Vec<KHeap>,
    /// Exclude the sphere whose id equals the ray's query id (self-hit
    /// when the query set is the dataset itself).
    pub exclude_self: bool,
    /// Shell floor: hits at squared distance ≤ this are discarded.
    /// Negative (the default) accepts everything including exact
    /// duplicates at distance 0.
    min_dist2: f32,
}

impl KnnProgram {
    pub fn new(n_queries: usize, k: usize, exclude_self: bool) -> Self {
        Self {
            heaps: (0..n_queries).map(|_| KHeap::new(k)).collect(),
            exclude_self,
            min_dist2: -1.0,
        }
    }

    /// Reset the heaps for a re-queried subset — the pre-shell-re-query
    /// TrueKNN behavior (each round re-discovers everything inside the
    /// bigger radius, §3.3), kept for the ablation baseline.
    pub fn reset(&mut self, query_ids: &[u32]) {
        for &q in query_ids {
            self.heaps[q as usize].clear();
        }
    }

    /// Set the shell floor for the next launch: hits with
    /// `dist2 <= min_dist2` are dropped before the heap. Pass the
    /// previous round's squared radius to pay heap traffic only for the
    /// annulus; a negative value disables the filter.
    pub fn set_shell_floor(&mut self, min_dist2: f32) {
        self.min_dist2 = min_dist2;
    }

    /// Total heap insertions across all queries (sorting-work telemetry).
    pub fn total_pushes(&self) -> u64 {
        self.heaps.iter().map(|h| h.pushes).sum()
    }
}

impl IntersectionProgram for KnnProgram {
    #[inline]
    fn hit(&mut self, ray: &Ray, prim: u32, dist2: f32) {
        if dist2 <= self.min_dist2 {
            return;
        }
        if self.exclude_self && prim == ray.query_id {
            return;
        }
        self.heaps[ray.query_id as usize].push(dist2, prim);
    }
}

/// Per-shard state: the owned queries' heaps in ray order, addressed by
/// `begin_ray` so the hit path stays lookup-free.
pub struct KnnShard {
    ids: Vec<u32>,
    heaps: Vec<KHeap>,
    cur: usize,
    exclude_self: bool,
    min_dist2: f32,
}

impl IntersectionProgram for KnnShard {
    #[inline]
    fn begin_ray(&mut self, local_ray_index: u32) {
        self.cur = local_ray_index as usize;
    }

    #[inline]
    fn hit(&mut self, ray: &Ray, prim: u32, dist2: f32) {
        if dist2 <= self.min_dist2 {
            return;
        }
        if self.exclude_self && prim == ray.query_id {
            return;
        }
        self.heaps[self.cur].push(dist2, prim);
    }
}

impl ShardableProgram for KnnProgram {
    type Shard = KnnShard;

    fn split(&mut self, rays: &[Ray]) -> KnnShard {
        let ids: Vec<u32> = rays.iter().map(|r| r.query_id).collect();
        let heaps = ids
            .iter()
            .map(|&q| std::mem::replace(&mut self.heaps[q as usize], KHeap::new(0)))
            .collect();
        KnnShard {
            ids,
            heaps,
            cur: 0,
            exclude_self: self.exclude_self,
            min_dist2: self.min_dist2,
        }
    }

    fn merge(&mut self, shard: KnnShard) {
        for (q, h) in shard.ids.into_iter().zip(shard.heaps) {
            self.heaps[q as usize] = h;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geom::Point3;
    use crate::rt::{HwCounters, Pipeline, Scene};
    use crate::util::prop;
    use crate::util::Pcg32;

    #[test]
    fn program_collects_k_nearest_within_radius() {
        let mut rng = Pcg32::new(21);
        let pts = prop::random_cloud(&mut rng, 500, false);
        let r = 0.2;
        let k = 5;
        let mut c = HwCounters::new();
        let scene = Scene::build(pts.clone(), r, &mut c);
        let rays: Vec<crate::geom::Ray> = (0..pts.len())
            .map(|i| crate::geom::Ray::knn(pts[i], i as u32))
            .collect();
        let mut prog = KnnProgram::new(pts.len(), k, true);
        Pipeline::launch(&scene, &rays, &mut prog, &mut c);

        let tree = crate::knn::kdtree::KdTree::build(&pts);
        for i in 0..pts.len() {
            let got = prog.heaps[i].sorted();
            let exact = tree.knn_excluding(pts[i], k, Some(i as u32));
            let exact_in_r: Vec<_> = exact.into_iter().filter(|n| n.dist <= r).collect();
            assert_eq!(got.len(), exact_in_r.len(), "query {i}");
            for (g, w) in got.iter().zip(&exact_in_r) {
                assert!((g.dist - w.dist).abs() < 1e-5, "query {i}");
            }
        }
    }

    #[test]
    fn self_hit_excluded_only_when_asked() {
        let pts = vec![Point3::ZERO, Point3::new(0.1, 0.0, 0.0)];
        let mut c = HwCounters::new();
        let scene = Scene::build(pts.clone(), 1.0, &mut c);
        let rays = vec![crate::geom::Ray::knn(pts[0], 0)];

        let mut incl = KnnProgram::new(2, 5, false);
        Pipeline::launch(&scene, &rays, &mut incl, &mut c);
        assert_eq!(incl.heaps[0].len(), 2, "self included");

        let mut excl = KnnProgram::new(2, 5, true);
        Pipeline::launch(&scene, &rays, &mut excl, &mut c);
        let got = excl.heaps[0].sorted();
        assert_eq!(got.len(), 1, "self excluded");
        assert_eq!(got[0].idx, 1);
    }

    #[test]
    fn reset_clears_only_named_queries() {
        let mut prog = KnnProgram::new(3, 2, false);
        prog.heaps[0].push(1.0, 1);
        prog.heaps[1].push(1.0, 1);
        prog.heaps[2].push(1.0, 1);
        prog.reset(&[0, 2]);
        assert!(prog.heaps[0].is_empty());
        assert_eq!(prog.heaps[1].len(), 1);
        assert!(prog.heaps[2].is_empty());
    }

    #[test]
    fn shell_floor_drops_already_discovered_hits() {
        let pts = vec![
            Point3::ZERO,
            Point3::new(0.1, 0.0, 0.0), // d2 = 0.01 — inside the shell floor
            Point3::new(0.5, 0.0, 0.0), // d2 = 0.25 — in the annulus
        ];
        let mut c = HwCounters::new();
        let scene = Scene::build(pts.clone(), 1.0, &mut c);
        let rays = vec![crate::geom::Ray::knn(pts[0], 0)];

        let mut prog = KnnProgram::new(3, 5, true);
        prog.set_shell_floor(0.04); // previous radius 0.2 squared
        Pipeline::launch(&scene, &rays, &mut prog, &mut c);
        let got = prog.heaps[0].sorted();
        assert_eq!(got.len(), 1, "only the annulus hit may land");
        assert_eq!(got[0].idx, 2);

        // distance-0 duplicates pass the default (negative) floor
        let mut dup = KnnProgram::new(3, 5, false);
        Pipeline::launch(&scene, &rays, &mut dup, &mut c);
        assert_eq!(dup.heaps[0].len(), 3, "default floor accepts d2 = 0");
    }

    #[test]
    fn split_and_merge_round_trip_preserves_heaps_and_pushes() {
        let mut prog = KnnProgram::new(4, 2, false);
        prog.heaps[1].push(1.0, 7);
        prog.heaps[3].push(2.0, 8);
        let rays = vec![
            crate::geom::Ray::knn(Point3::ZERO, 3),
            crate::geom::Ray::knn(Point3::ZERO, 1),
        ];
        let mut shard = prog.split(&rays);
        assert!(prog.heaps[1].is_empty() && prog.heaps[3].is_empty());
        // shard state follows begin_ray, not query-id arithmetic
        shard.begin_ray(0);
        shard.hit(&rays[0], 9, 0.5);
        prog.merge(shard);
        assert_eq!(prog.heaps[3].len(), 2, "shard pushed into query 3");
        assert_eq!(prog.heaps[1].len(), 1, "query 1 restored untouched");
        assert_eq!(prog.total_pushes(), 3);
    }
}
