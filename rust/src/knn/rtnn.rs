//! RTNN-style optimized fixed-radius baseline (Zhu, PPoPP'22 — the
//! paper's §5.3.1 comparison). RTNN keeps the single-radius search but
//! adds two optimizations:
//!
//! 1. **query reordering**: sort queries along a Morton (Z-order) curve
//!    so consecutive rays touch the same BVH subtrees (ray coherence —
//!    on the GPU this reduces divergence; in our simulator it improves
//!    cache locality, which shows up in wall-clock);
//! 2. **query partitioning**: split sorted queries into spatial chunks
//!    and search each chunk against only the data points that can
//!    possibly be within `radius` of the chunk's bounding box — this
//!    genuinely removes intersection tests, the effect RTNN reports.
//!
//! The paper shows *unoptimized* TrueKNN still beats this by 1.5–8×.
//!
//! [`rtnn_knns`] stays a one-shot function: the partition-culling step
//! builds a scene per *query* chunk, which by construction cannot
//! persist across query sets. The build-once variant is
//! [`crate::index::RtnnIndex`], which keeps one full-data BVH alive and
//! retains the Morton reordering (optimization 1) only.

use super::program::KnnProgram;
use super::{KnnResult, RoundStats};
use crate::geom::{Aabb, Point3, Ray};
use crate::rt::{CostModel, HwCounters, Pipeline, Scene};
use crate::util::Stopwatch;

#[derive(Clone, Debug)]
pub struct RtnnParams {
    pub k: usize,
    pub radius: f32,
    pub exclude_self: bool,
    /// Number of spatial query partitions.
    pub partitions: usize,
    pub cost_model: CostModel,
}

impl Default for RtnnParams {
    fn default() -> Self {
        Self {
            k: 5,
            radius: 1.0,
            exclude_self: true,
            partitions: 16,
            cost_model: CostModel::default(),
        }
    }
}

/// 30-bit 3D Morton code over the unit-normalized position. The
/// canonical encoder lives in [`crate::store`] (the launch engine's
/// cohort scheduling shares it); re-exported here for compatibility.
pub use crate::store::morton3;

/// RTNN fixed-radius kNN with both optimizations enabled.
pub fn rtnn_knns(data: &[Point3], queries: &[Point3], params: &RtnnParams) -> KnnResult {
    let wall = Stopwatch::start();
    let mut result = KnnResult::new(queries.len());
    if data.is_empty() || queries.is_empty() {
        return result;
    }
    let mut counters = HwCounters::new();

    // --- optimization 1: Z-order query sort ---
    let mut bb = Aabb::EMPTY;
    for &q in queries {
        bb.grow(q);
    }
    let mut order: Vec<u32> = (0..queries.len() as u32).collect();
    order.sort_by_key(|&i| morton3(queries[i as usize], &bb));

    // --- optimization 2: spatial query partitioning ---
    let parts = params.partitions.max(1).min(order.len());
    let chunk = order.len().div_ceil(parts);
    let mut program = KnnProgram::new(queries.len(), params.k, params.exclude_self);
    let mut launches = 0u64;
    let mut prev_pushes = 0u64;

    for part in order.chunks(chunk) {
        // chunk bounds inflated by the radius: only data points inside
        // can intersect any chunk query
        let mut pb = Aabb::EMPTY;
        for &q in part {
            pb.grow(queries[q as usize]);
        }
        pb.min = pb.min - Point3::splat(params.radius);
        pb.max = pb.max + Point3::splat(params.radius);

        // cull data and remember original ids
        let mut ids: Vec<u32> = Vec::new();
        let mut culled: Vec<Point3> = Vec::new();
        for (i, &d) in data.iter().enumerate() {
            if pb.contains(d) {
                ids.push(i as u32);
                culled.push(d);
            }
        }
        if culled.is_empty() {
            continue;
        }
        let scene = Scene::build(culled, params.radius, &mut counters);
        counters.context_switches += 1;
        let rays: Vec<Ray> = part
            .iter()
            .map(|&q| Ray::knn(queries[q as usize], q))
            .collect();
        // remap prim ids back to global ids inside a shim program
        let mut shim = Remap {
            inner: &mut program,
            ids: &ids,
        };
        Pipeline::launch(&scene, &rays, &mut shim, &mut counters);
        launches += 1;
        let pushes = program.total_pushes();
        counters.heap_pushes += pushes - prev_pushes;
        prev_pushes = pushes;
    }

    for (q, heap) in program.heaps.into_iter().enumerate() {
        result.neighbors[q] = heap.into_sorted();
    }
    result.launches = launches;
    result.counters = counters;
    result.wall_seconds = wall.elapsed_secs();
    result.rounds.push(RoundStats {
        round: 0,
        radius: params.radius,
        queries: queries.len(),
        survivors: result
            .neighbors
            .iter()
            .filter(|n| n.len() < params.k)
            .count(),
        prim_tests: result.counters.prim_tests,
        heap_pushes: result.counters.heap_pushes,
        sim_seconds: params.cost_model.seconds(&result.counters, launches),
        wall_seconds: result.wall_seconds,
    });
    result.finalize_sim_time(&params.cost_model);
    result
}

/// Adapter translating culled-scene primitive ids back to dataset ids.
struct Remap<'a> {
    inner: &'a mut KnnProgram,
    ids: &'a [u32],
}

impl crate::rt::IntersectionProgram for Remap<'_> {
    #[inline]
    fn hit(&mut self, ray: &Ray, prim: u32, dist2: f32) {
        let global = self.ids[prim as usize];
        if self.inner.exclude_self && global == ray.query_id {
            return;
        }
        self.inner.heaps[ray.query_id as usize].push(dist2, global);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::{DatasetKind, DistanceProfile};
    use crate::knn::{fixed_radius_knns, FixedRadiusParams};

    #[test]
    fn morton_orders_near_points_together() {
        let bb = Aabb::new(Point3::ZERO, Point3::splat(1.0));
        let a = morton3(Point3::new(0.1, 0.1, 0.1), &bb);
        let b = morton3(Point3::new(0.12, 0.1, 0.1), &bb);
        let c = morton3(Point3::new(0.9, 0.9, 0.9), &bb);
        assert!(a.abs_diff(b) < a.abs_diff(c));
    }

    #[test]
    fn rtnn_is_exact_at_maxdist_radius() {
        let ds = DatasetKind::Uniform.generate(800, 60);
        let k = 5;
        let prof = DistanceProfile::compute(&ds, k);
        let r = prof.max_dist() as f32 * 1.0001;
        let rtnn = rtnn_knns(
            &ds.points,
            &ds.points,
            &RtnnParams {
                k,
                radius: r,
                ..Default::default()
            },
        );
        let base = fixed_radius_knns(
            &ds.points,
            &ds.points,
            &FixedRadiusParams {
                k,
                radius: r,
                ..Default::default()
            },
        );
        assert!(rtnn.is_complete(k, ds.len() - 1));
        for (a, b) in rtnn.neighbors.iter().zip(&base.neighbors) {
            assert_eq!(a.len(), b.len());
            for (x, y) in a.iter().zip(b) {
                assert!((x.dist - y.dist).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn partitioning_reduces_traversal_work() {
        // RTNN's partitioning lets each query traverse a much smaller
        // BVH. Software prim tests are bounded below by true candidate
        // counts either way, so the hardware-side traversal (ray-AABB
        // tests) is where the win shows; prim tests must not regress.
        let ds = DatasetKind::Road.generate(3_000, 61);
        let prof = DistanceProfile::compute(&ds, 5);
        let r = prof.percentile_dist(90.0) as f32;
        let plain = fixed_radius_knns(
            &ds.points,
            &ds.points,
            &FixedRadiusParams {
                k: 5,
                radius: r,
                ..Default::default()
            },
        );
        let opt = rtnn_knns(
            &ds.points,
            &ds.points,
            &RtnnParams {
                k: 5,
                radius: r,
                partitions: 32,
                ..Default::default()
            },
        );
        assert!(
            opt.counters.aabb_tests < plain.counters.aabb_tests,
            "rtnn aabb {} vs plain {}",
            opt.counters.aabb_tests,
            plain.counters.aabb_tests
        );
        assert!(
            opt.counters.prim_tests <= plain.counters.prim_tests * 110 / 100,
            "rtnn prim {} vs plain {}",
            opt.counters.prim_tests,
            plain.counters.prim_tests
        );
    }

    #[test]
    fn single_partition_degenerates_to_plain() {
        let ds = DatasetKind::Uniform.generate(300, 62);
        let r = 0.3;
        let opt = rtnn_knns(
            &ds.points,
            &ds.points,
            &RtnnParams {
                k: 3,
                radius: r,
                partitions: 1,
                ..Default::default()
            },
        );
        let plain = fixed_radius_knns(
            &ds.points,
            &ds.points,
            &FixedRadiusParams {
                k: 3,
                radius: r,
                ..Default::default()
            },
        );
        // same completeness; test counts equal since nothing is culled
        // (partition box inflated by r covers everything here)
        for (a, b) in opt.neighbors.iter().zip(&plain.neighbors) {
            assert_eq!(a.len(), b.len());
        }
    }
}
