//! Start-radius selection by random sampling — the paper's Algorithm 2.
//!
//! Sample 100 points, find each sample's 4 nearest neighbors (the paper
//! uses scikit-learn's ball tree; we use our exact kd-tree), and return
//! the *minimum* sample-to-neighbor distance. A deliberately small start:
//! §3.2 shows undershooting is far cheaper than overshooting.

use crate::geom::Point3;
use crate::knn::kdtree::KdTree;
use crate::util::Pcg32;

pub const SAMPLE_SIZE: usize = 100;
pub const SAMPLE_K: usize = 4;

/// Algorithm 2. Returns the start radius; degenerate inputs (all points
/// identical → min distance 0) fall back to a tiny fraction of the
/// bounding-box diagonal so round 1 is still meaningful.
pub fn random_sample_radius(points: &[Point3], seed: u64) -> f32 {
    random_sample_radius_with(points, seed, SAMPLE_SIZE, SAMPLE_K)
}

pub fn random_sample_radius_with(
    points: &[Point3],
    seed: u64,
    sample_size: usize,
    k: usize,
) -> f32 {
    if points.len() < 2 {
        return 1.0;
    }
    let mut rng = Pcg32::new(seed ^ 0x5A3B);
    let idx = rng.sample_indices(points.len(), sample_size.min(points.len()));
    let tree = KdTree::build(points);
    let mut min_dist = f32::INFINITY;
    for &i in &idx {
        for n in tree.knn_excluding(points[i], k, Some(i as u32)) {
            if n.dist > 0.0 {
                min_dist = min_dist.min(n.dist);
            }
        }
    }
    if !min_dist.is_finite() || min_dist == 0.0 {
        // all sampled points coincide; fall back to a sliver of the
        // dataset extent so the doubling loop can take over
        let mut bb = crate::geom::Aabb::EMPTY;
        for &p in points {
            bb.grow(p);
        }
        let diag = bb.extent().norm();
        if diag > 0.0 {
            diag * 1e-4
        } else {
            1e-6
        }
    } else {
        min_dist
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::{DatasetKind, DistanceProfile};

    #[test]
    fn radius_is_small_but_positive() {
        let ds = DatasetKind::Uniform.generate(5_000, 40);
        let r = random_sample_radius(&ds.points, 1);
        assert!(r > 0.0);
        // must be well under the baseline's maxDist radius
        let prof = DistanceProfile::compute(&ds, 5);
        assert!(
            (r as f64) < prof.max_dist(),
            "start {r} vs maxDist {}",
            prof.max_dist()
        );
    }

    #[test]
    fn deterministic_per_seed_and_varies_across_seeds() {
        let ds = DatasetKind::Taxi.generate(3_000, 41);
        let a = random_sample_radius(&ds.points, 7);
        let b = random_sample_radius(&ds.points, 7);
        assert_eq!(a, b);
        let radii: Vec<f32> = (0..8)
            .map(|s| random_sample_radius(&ds.points, s))
            .collect();
        let distinct = radii
            .iter()
            .map(|r| r.to_bits())
            .collect::<std::collections::HashSet<_>>()
            .len();
        assert!(distinct > 1, "different samples should give different radii");
    }

    #[test]
    fn degenerate_inputs_fall_back() {
        assert_eq!(random_sample_radius(&[], 1), 1.0);
        assert_eq!(random_sample_radius(&[Point3::ZERO], 1), 1.0);
        let dup = vec![Point3::splat(0.5); 200];
        let r = random_sample_radius(&dup, 1);
        assert!(r > 0.0 && r.is_finite());
    }
}
