//! TrueKNN — the paper's Algorithm 3, the system's headline contribution.
//!
//! Multi-round fixed-radius search: start from the sampled radius
//! (Alg. 2), remove every query that filled its k-heap, double the
//! radius, *refit* the BVH (not rebuild, §4) and re-query only the
//! survivors, until none remain. Per-round telemetry feeds Fig 6.
//!
//! The algorithm now lives in [`crate::index::TrueKnnIndex`], which
//! keeps the BVH and the sampled start radius alive across queries;
//! [`trueknn`] below is a compatibility shim that builds a throwaway
//! index, runs one query and folds the build cost back into the result
//! — identical totals to the original one-shot implementation.

use super::KnnResult;
use crate::geom::Point3;
use crate::index::{IndexConfig, NeighborIndex, TrueKnnIndex};
use crate::rt::CostModel;

#[derive(Clone, Debug)]
pub struct TrueKnnParams {
    pub k: usize,
    /// Override the Alg. 2 sampled start radius (Fig 7 sensitivity).
    pub start_radius: Option<f32>,
    /// Stop growing once the radius reaches this cap — the paper's
    /// 99th-percentile experiment (§5.5.1) terminates at the cap and
    /// leaves outlier queries incomplete.
    pub radius_cap: Option<f32>,
    pub exclude_self: bool,
    pub seed: u64,
    pub cost_model: CostModel,
    /// Safety valve; the radius doubles each round so 64 rounds cover
    /// any f32 scale.
    pub max_rounds: usize,
    /// Worker threads for the parallel launch engine (0 = the
    /// environment default: `TRUEKNN_THREADS` if set, else all cores —
    /// resolved by [`crate::exec::Executor::new`]). Results are
    /// identical at any value.
    pub threads: usize,
}

impl Default for TrueKnnParams {
    fn default() -> Self {
        Self {
            k: 5,
            start_radius: None,
            radius_cap: None,
            exclude_self: true,
            seed: 42,
            cost_model: CostModel::default(),
            max_rounds: 64,
            threads: 0,
        }
    }
}

impl TrueKnnParams {
    /// The equivalent index configuration (k is a per-query argument in
    /// the index API, not part of the build).
    pub fn to_index_config(&self) -> IndexConfig {
        IndexConfig {
            exclude_self: self.exclude_self,
            seed: self.seed,
            cost_model: self.cost_model,
            start_radius: self.start_radius,
            radius_cap: self.radius_cap,
            max_rounds: self.max_rounds,
            threads: self.threads,
            ..Default::default()
        }
    }
}

/// Algorithm 3 over `data`, querying all of `queries` (usually the same
/// slice — the paper's "find the k nearest neighbors of all points").
///
/// Compatibility shim over [`TrueKnnIndex`]: builds a one-shot index,
/// queries it once and folds the build into the reported counters /
/// timings, matching the historical one-shot behavior. Callers issuing
/// more than one query against the same data should hold a
/// [`TrueKnnIndex`] instead and pay the build once.
pub fn trueknn(data: &[Point3], queries: &[Point3], params: &TrueKnnParams) -> KnnResult {
    if data.is_empty() || queries.is_empty() || params.k == 0 {
        return KnnResult::new(queries.len());
    }
    let mut index = TrueKnnIndex::new(data.to_vec(), params.to_index_config());
    let mut result = index.knn(queries, params.k);
    index
        .build_stats()
        .absorb_into(&mut result, &params.cost_model);
    result
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::DatasetKind;
    use crate::knn::kdtree::KdTree;
    use crate::knn::{fixed_radius_knns, FixedRadiusParams};

    fn assert_exact(res: &KnnResult, points: &[Point3], k: usize) {
        let tree = KdTree::build(points);
        for (i, got) in res.neighbors.iter().enumerate() {
            let want = tree.knn_excluding(points[i], k, Some(i as u32));
            assert_eq!(got.len(), want.len(), "query {i} count");
            for (g, w) in got.iter().zip(&want) {
                assert!(
                    (g.dist - w.dist).abs() < 1e-5,
                    "query {i}: {} vs {}",
                    g.dist,
                    w.dist
                );
            }
        }
    }

    #[test]
    fn exact_on_every_dataset_kind() {
        for kind in DatasetKind::ALL {
            let ds = kind.generate(600, 50);
            let res = trueknn(&ds.points, &ds.points, &TrueKnnParams::default());
            assert!(res.is_complete(5, ds.len() - 1), "{kind:?} incomplete");
            assert_exact(&res, &ds.points, 5);
        }
    }

    #[test]
    fn multiple_rounds_happen_and_radius_doubles() {
        let ds = DatasetKind::Taxi.generate(2_000, 51);
        let res = trueknn(&ds.points, &ds.points, &TrueKnnParams::default());
        assert!(res.rounds.len() > 2, "expected multi-round execution");
        for w in res.rounds.windows(2) {
            assert!((w[1].radius / w[0].radius - 2.0).abs() < 1e-3);
            // survivors shrink monotonically
            assert!(w[1].queries <= w[0].queries);
            assert_eq!(w[1].queries, w[0].survivors);
        }
    }

    #[test]
    fn fewer_prim_tests_than_maxdist_baseline() {
        // the paper's core result (Table 2)
        let ds = DatasetKind::Taxi.generate(3_000, 52);
        let k = 5;
        let t = trueknn(&ds.points, &ds.points, &TrueKnnParams::default());
        let prof = crate::dataset::DistanceProfile::compute(&ds, k);
        let b = fixed_radius_knns(
            &ds.points,
            &ds.points,
            &FixedRadiusParams {
                k,
                radius: prof.max_dist() as f32 * 1.0001,
                ..Default::default()
            },
        );
        assert!(
            b.counters.prim_tests > 2 * t.counters.prim_tests,
            "baseline {} vs trueknn {}",
            b.counters.prim_tests,
            t.counters.prim_tests
        );
        assert!(b.sim_seconds > t.sim_seconds);
    }

    #[test]
    fn radius_cap_terminates_with_outliers_unresolved() {
        let ds = DatasetKind::Taxi.generate(2_000, 53);
        let prof = crate::dataset::DistanceProfile::compute(&ds, 5);
        let cap = prof.percentile_dist(99.0) as f32;
        let res = trueknn(
            &ds.points,
            &ds.points,
            &TrueKnnParams {
                radius_cap: Some(cap),
                ..Default::default()
            },
        );
        // ~99% of queries complete, outliers are (correctly) left short
        let complete = res.neighbors.iter().filter(|n| n.len() == 5).count();
        assert!(complete >= ds.len() * 97 / 100, "complete {complete}");
        assert!(complete < ds.len(), "cap must leave outliers unresolved");
        assert!(res.rounds.last().unwrap().radius <= cap * 1.0001);
    }

    #[test]
    fn k_larger_than_dataset_terminates() {
        let ds = DatasetKind::Uniform.generate(10, 54);
        let res = trueknn(
            &ds.points,
            &ds.points,
            &TrueKnnParams {
                k: 50,
                ..Default::default()
            },
        );
        for n in &res.neighbors {
            assert_eq!(n.len(), 9, "all other points found");
        }
    }

    #[test]
    fn empty_inputs_are_safe() {
        let res = trueknn(&[], &[], &TrueKnnParams::default());
        assert!(res.neighbors.is_empty());
        let ds = DatasetKind::Uniform.generate(5, 55);
        let res = trueknn(
            &ds.points,
            &ds.points,
            &TrueKnnParams {
                k: 0,
                ..Default::default()
            },
        );
        assert!(res.neighbors.iter().all(|n| n.is_empty()));
    }

    #[test]
    fn explicit_start_radius_is_honored() {
        let ds = DatasetKind::Uniform.generate(500, 56);
        let res = trueknn(
            &ds.points,
            &ds.points,
            &TrueKnnParams {
                start_radius: Some(0.001),
                ..Default::default()
            },
        );
        assert!((res.rounds[0].radius - 0.001).abs() < 1e-9);
        assert!(res.is_complete(5, ds.len() - 1));
    }
}
