//! TrueKNN: RT-core-accelerated unbounded k-nearest-neighbor search.
//!
//! Reproduction of "RT-kNNS Unbound: Using RT Cores to Accelerate
//! Unrestricted Neighbor Search" (Nagarajan, Mandarapu, Kulkarni, ICS'23)
//! on a three-layer Rust + JAX + Pallas stack:
//!
//! - **Layer 3 (this crate)** — the coordinator: datasets, the simulated
//!   RT-core pipeline (BVH build/refit/traversal with a hardware cost
//!   model), the TrueKNN multi-round algorithm and every baseline the
//!   paper compares against, a batching query service, and the benchmark
//!   harness that regenerates every table and figure in the paper.
//! - **Layer 2 (python/compile/model.py)** — JAX compute graphs for the
//!   brute-force ("shader core" / cuML-analog) distance + top-k path,
//!   AOT-lowered to HLO text at build time.
//! - **Layer 1 (python/compile/kernels/)** — Pallas tiled pairwise
//!   distance kernel feeding Layer 2, validated against a pure-jnp oracle.
//!
//! Python never runs on the query path: `runtime` loads the AOT artifacts
//! through PJRT and executes them from Rust.
//!
//! # The `NeighborIndex` API: build once, query many
//!
//! Every search algorithm is a [`index::Backend`] behind the
//! [`index::NeighborIndex`] trait. Build an index over your data once,
//! then issue as many `knn` / `range` queries as you like — the
//! acceleration structure (BVH, kd-tree, compiled PJRT executables)
//! persists across calls and grows in place via `insert`:
//!
//! ```no_run
//! use trueknn::dataset::DatasetKind;
//! use trueknn::index::{Backend, IndexBuilder, NeighborIndex};
//!
//! let ds = DatasetKind::Taxi.generate(50_000, 42);
//! let mut index = IndexBuilder::new(Backend::TrueKnn)
//!     .seed(42)
//!     .build(ds.points.clone());
//! let nn5 = index.knn(&ds.points[..1024], 5);    // one BVH build, above
//! let nn16 = index.knn(&ds.points[..1024], 16);  // reuses it (refit only)
//! let near = index.range(&ds.points[..64], 0.05);
//! assert_eq!(index.build_stats().counters.builds, 1);
//! # let _ = (nn5, nn16, near);
//! ```
//!
//! The batching service ([`coordinator::Service`]) is a route-sharded
//! worker pool: each route path is pinned to one pool worker (rendezvous
//! hashing), which holds that route's persistent index — so a serving
//! session performs exactly one acceleration-structure build per route
//! per dataset (visible as the per-route `builds` gauge) no matter how
//! many batches are served or how many workers run. A hot route can
//! additionally shard its *dataset* ([`shard`], `IndexConfig::shards` /
//! `ServiceConfig::shards`): balanced Morton-range shards, one backend
//! index each, queried by exact scatter-gather — bitwise-identical to
//! the unsharded index at any shard count, while the route's batches
//! spread across `min(shards, pool)` workers.
//!
//! # Durability model
//!
//! The service can run crash-safe ([`persist`],
//! `coordinator::PersistConfig`): an accepted insert is appended to a
//! checksummed write-ahead log **before** it becomes visible to
//! queries, and built indexes are snapshotted to a versioned,
//! checksummed `TKSN` container via temp-file + fsync + atomic rename.
//! What is durable when:
//!
//! - **At insert acknowledgement** — the insert's WAL record has been
//!   written (and fsynced when `wal_group_commit == 1`). With a group
//!   commit window of `n`, up to the last `n - 1` acknowledged inserts
//!   may be lost to a *power* failure; a process crash loses nothing.
//! - **At snapshot watermark `w`** — every insert with WAL sequence
//!   `≤ w` is inside the snapshot payload; cold start loads the newest
//!   valid snapshot and replays only records past `w`, in sequence
//!   order.
//! - **At clean shutdown** — queues drain, the WAL is fsynced, and a
//!   final snapshot is written, so the next start replays zero records.
//!
//! Recovery never serves from a partially-trusted file: any checksum,
//! version, or config-fingerprint mismatch rejects the whole snapshot
//! (`snapshot_corrupt` metric) and falls back to the deterministic
//! rebuild from base data + full WAL (`rebuilt`), which is
//! bitwise-identical to a never-crashed service by the determinism
//! contract. A torn WAL tail is truncated at the last intact record.
//!
//! # Observability model
//!
//! The serving stack is traceable ([`obs`], `ServiceConfig::trace` /
//! `trueknn serve --trace-dir`) without compromising the determinism
//! contract, because every observable is classified up front:
//!
//! - **Deterministic** — counters (`heap_pushes`, `shard_queries`,
//!   per-round radius/survivor telemetry) and span *structure*: which
//!   spans a request produces, their names, parent links, and counter
//!   attributes are a pure function of the request stream and
//!   configuration. The tracing-on/off oracle tests assert responses
//!   are bitwise-identical with tracing enabled vs disabled.
//! - **Wall-clock** — span start/end timestamps and latency histogram
//!   samples. These are measurements, not state: they are read through
//!   the single sanctioned chokepoint [`obs::clock::now`], quarantined
//!   inside span records and [`coordinator::MetricsSnapshot`] duration
//!   fields, and never branched on by any result path.
//!
//! Latency distributions use fixed-bucket log2 histograms
//! ([`obs::LogHistogram`]) whose bucket math is pure `u64` arithmetic;
//! per-worker histograms merge in worker-index order into the
//! `MetricsSnapshot` p50/p95/p99 fields. Trace files are CRC-framed
//! JSONL ([`obs::trace`]) read back by `trueknn trace`
//! ([`obs::profile`]), which reconstructs per-request span trees and
//! the TrueKNN round-by-round convergence table.
//!
//! ## Migrating from the free functions
//!
//! The historical one-shot entry points remain as shims over the trait;
//! each maps to a backend:
//!
//! | free function               | backend                        |
//! |-----------------------------|--------------------------------|
//! | `knn::trueknn`              | [`index::Backend::TrueKnn`]    |
//! | `knn::fixed_radius_knns`    | [`index::Backend::FixedRadius`]|
//! | `knn::rtnn::rtnn_knns`      | [`index::Backend::Rtnn`] (Morton reordering; the per-call partition culling stays one-shot) |
//! | `knn::kdtree::KdTree::knn`  | [`index::Backend::KdTree`]     |
//! | `knn::brute::brute_knn`     | [`index::Backend::BruteCpu`]   |
//! | `runtime::PjrtBruteForce`   | [`index::Backend::BrutePjrt`]  |
//!
//! # Determinism contract
//!
//! Results **and** counters are bitwise-identical at any threads ×
//! workers × shards × speculation. Three mechanisms carry the claim:
//!
//! * **One total order on every top-k cut.** Neighbors are ranked by
//!   the strict `(distance, id)` lexicographic order — on the *rounded*
//!   distance (the f32 sqrt actually returned), because distinct
//!   squared distances can round to the same sqrt. Every boundary tie
//!   at the k-th slot therefore resolves identically in the heap, the
//!   kd-tree, the shard merge, and the service gather, so shard count
//!   and merge order can never pick a different (equally-near) winner.
//! * **Speculation is a pure schedule knob.** `IndexConfig::speculation`
//!   only chooses how many shards are probed eagerly in parallel; the
//!   candidate set every query sees — and the order-independent cut
//!   above — is unchanged at any setting (it is excluded from the
//!   snapshot config fingerprint for the same reason).
//! * **Inserts are fenced.** The service appends each insert once to a
//!   shared log and stamps every request with the log sequence it must
//!   observe; all shards of a scattered request share one fence, and
//!   crash replay / failover re-serve at the original fence. Visibility
//!   is a pure function of submit order, not of pool size or timing.
//!
//! The contract is enforced statically by `trueknn lint`
//! ([`analysis`]), whose rules cite it by id:
//!
//! * `unordered-iteration` — no `HashMap`/`HashSet` walk may feed a
//!   result, snapshot, or emission path; iterate sorted keys or an
//!   ordered structure. Keyed access is order-free and stays legal.
//! * `wallclock-in-core` — `Instant::now`/`SystemTime` live only in
//!   the measurement shells (`bench`, `exp`, `util::timer`) and the
//!   sanctioned telemetry chokepoint [`obs::clock`]; core and merge
//!   paths are replayable, and serving code reads time exclusively
//!   through `obs::clock::now()`.
//! * `raw-threads` — all fan-out goes through [`exec::Executor`] /
//!   [`exec::scope`] or the coordinator service loop; no raw
//!   `thread::spawn` elsewhere.
//! * `sync-in-exec` — the exec engine is lock-free: workers write
//!   disjoint slots, merges are sequential; no `Mutex`/`Atomic*`/`mpsc`
//!   inside `exec/`.
//! * `float-reduce-order` — float reductions in parallel-reachable
//!   modules use ordered sequential merges, never chunk-shaped
//!   `.sum::<f32>()`/`fold` reassociation.
//! * `panic-in-lib` — library code propagates errors; every remaining
//!   `unwrap`/`expect` carries an inline justified allow.
//! * `channel-unwrap-in-coordinator` — channel send/recv results in the
//!   coordinator are recovery-path signals (a worker may be mid-restart
//!   behind a disconnected channel), never `unwrap`/`expect` sites.
//! * `truncating-id-cast` — id arithmetic never truncates through
//!   bare `as u32`/`as usize` in merge/remap paths; widening goes
//!   through checked helpers.
//! * `pub-missing-docs` — the `index`/`shard`/`coordinator`/`persist`
//!   public API documents its contracts.
//! * `io-unwrap-in-persist` — filesystem results in `persist/` and the
//!   coordinator recovery paths are corruption signals that must reach
//!   the rebuild fallback as typed errors, never `unwrap`/`expect`
//!   sites.
//!
//! `cargo run --release -- lint` exits with the finding count; the CI
//! `determinism-lint` job and `tests/lint_suite.rs` both gate on zero.

#![forbid(unsafe_code)]

pub mod analysis;
pub mod faults;
pub mod obs;
pub mod util;
pub mod exec;
pub mod geom;
pub mod store;
pub mod dataset;
pub mod bvh;
pub mod rt;
pub mod knn;
pub mod index;
pub mod shard;
pub mod persist;
pub mod runtime;
pub mod coordinator;
pub mod bench;
pub mod exp;
pub mod cli;
pub mod configx;
