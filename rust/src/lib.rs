//! TrueKNN: RT-core-accelerated unbounded k-nearest-neighbor search.
//!
//! Reproduction of "RT-kNNS Unbound: Using RT Cores to Accelerate
//! Unrestricted Neighbor Search" (Nagarajan, Mandarapu, Kulkarni, ICS'23)
//! on a three-layer Rust + JAX + Pallas stack:
//!
//! - **Layer 3 (this crate)** — the coordinator: datasets, the simulated
//!   RT-core pipeline (BVH build/refit/traversal with a hardware cost
//!   model), the TrueKNN multi-round algorithm and every baseline the
//!   paper compares against, a batching query service, and the benchmark
//!   harness that regenerates every table and figure in the paper.
//! - **Layer 2 (python/compile/model.py)** — JAX compute graphs for the
//!   brute-force ("shader core" / cuML-analog) distance + top-k path,
//!   AOT-lowered to HLO text at build time.
//! - **Layer 1 (python/compile/kernels/)** — Pallas tiled pairwise
//!   distance kernel feeding Layer 2, validated against a pure-jnp oracle.
//!
//! Python never runs on the query path: `runtime` loads the AOT artifacts
//! through PJRT and executes them from Rust.

pub mod util;
pub mod geom;
pub mod dataset;
pub mod bvh;
pub mod rt;
pub mod knn;
pub mod runtime;
pub mod coordinator;
pub mod bench;
pub mod exp;
pub mod cli;
pub mod configx;
