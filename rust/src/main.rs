//! `trueknn` — the launcher binary.
//!
//! ```text
//! trueknn gen       generate a synthetic dataset analog to CSV
//! trueknn knn       run a single kNN search (any algorithm)
//! trueknn exp       regenerate a paper table/figure (table1|fig6|...)
//! trueknn runtime   inspect/smoke-test the PJRT artifacts
//! trueknn serve     run the batching query service demo (worker pool)
//! trueknn snapshot  build/validate an offline checksummed index snapshot
//! trueknn trace     profile a serve run's trace directory (span trees)
//! trueknn bench     perf microbenches, writes BENCH_PR2/.../PR10.json
//! trueknn lint      determinism-contract analyzer (exit = finding count)
//! ```

use trueknn::cli::{Args, CliError, Command};
use trueknn::configx::{KPolicy, RunConfig};
use trueknn::dataset::{Dataset, DatasetKind};
use trueknn::exp::{self, ExpScale};
use trueknn::index::{Backend, IndexBuilder, IndexConfig, NeighborIndex};
use trueknn::knn;
use trueknn::{log_error, log_info};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = match argv.first().map(String::as_str) {
        Some("gen") => dispatch(cmd_gen(), &argv[1..], run_gen),
        Some("knn") => dispatch(cmd_knn(), &argv[1..], run_knn),
        Some("exp") => dispatch(cmd_exp(), &argv[1..], run_exp),
        Some("runtime") => dispatch(cmd_runtime(), &argv[1..], run_runtime),
        Some("serve") => dispatch(cmd_serve(), &argv[1..], run_serve),
        Some("snapshot") => dispatch(cmd_snapshot(), &argv[1..], run_snapshot),
        Some("trace") => dispatch(cmd_trace(), &argv[1..], run_trace),
        Some("bench") => dispatch(cmd_bench(), &argv[1..], run_bench),
        // lint bypasses dispatch(): its exit code is the finding count,
        // not the 0/1 ok/error convention
        Some("lint") => run_lint(&argv[1..]),
        Some("--help") | Some("-h") | None => {
            print_usage();
            0
        }
        Some(other) => {
            eprintln!("unknown command '{other}'");
            print_usage();
            2
        }
    };
    std::process::exit(code);
}

fn print_usage() {
    println!("trueknn — RT-accelerated unbounded kNN search (ICS'23 reproduction)");
    println!("commands:");
    println!("  gen      generate a synthetic dataset to CSV");
    println!("  knn      run one kNN search (trueknn|baseline|rtnn|kdtree|brute|pjrt)");
    println!("  exp      regenerate a paper table/figure");
    println!("  runtime  inspect the PJRT artifacts");
    println!("  serve    run the batching query service demo (worker pool)");
    println!("  snapshot build an index offline into a checksummed snapshot blob");
    println!("  trace    profile a serve run's trace directory (span trees, convergence)");
    println!("  bench    perf microbenches (BENCH_PR2/.../PR10.json)");
    println!("  lint     determinism-contract analyzer (exit code = finding count)");
    println!("run `trueknn <command> --help` for options");
}

fn dispatch(cmd: Command, argv: &[String], f: fn(&Args) -> Result<(), String>) -> i32 {
    match cmd.parse(argv) {
        Ok(args) => match f(&args) {
            Ok(()) => 0,
            Err(e) => {
                log_error!("{e}");
                1
            }
        },
        Err(CliError::HelpRequested) => {
            print!("{}", cmd.usage());
            0
        }
        Err(e) => {
            eprintln!("{e}\n\n{}", cmd.usage());
            2
        }
    }
}

// ------------------------------------------------------------------- gen

fn cmd_gen() -> Command {
    Command::new("gen", "generate a synthetic dataset analog to CSV")
        .opt("dataset", "road|taxi|lidar|iono|uniform", "taxi")
        .opt("n", "number of points", "10000")
        .opt("seed", "PRNG seed", "42")
        .req("out", "output CSV path")
}

fn run_gen(a: &Args) -> Result<(), String> {
    let kind: DatasetKind = a.get_str("dataset", "taxi").parse()?;
    let n: usize = a.get_parse("n", 10_000).map_err(|e| e.to_string())?;
    let seed: u64 = a.get_parse("seed", 42).map_err(|e| e.to_string())?;
    let out = a.get("out").ok_or("--out is required")?;
    let ds = kind.generate(n, seed);
    trueknn::dataset::io::save_csv(&ds, out).map_err(|e| e.to_string())?;
    log_info!("wrote {} points ({}) to {out}", ds.len(), kind.name());
    Ok(())
}

// ------------------------------------------------------------------- knn

fn cmd_knn() -> Command {
    Command::new("knn", "run a single kNN search through the index API")
        .opt(
            "config",
            "run-config JSON file; supplies dataset/n/k/seed/percentile/start-radius/threads",
            "",
        )
        .opt("dataset", "road|taxi|lidar|iono|uniform", "taxi")
        .opt("input", "CSV file instead of a generator", "")
        .opt("n", "number of points", "10000")
        .opt("k", "neighbors per point, or 'sqrt'", "5")
        .opt("seed", "PRNG seed", "42")
        .opt("algo", "trueknn|baseline|rtnn|kdtree|brute|pjrt", "trueknn")
        .opt("percentile", "cap search at this percentile radius", "")
        .opt("start-radius", "override the sampled start radius", "")
        .opt("threads", "launch-engine worker threads (0 = all cores)", "0")
        .flag("verify", "check results against the exact kd-tree")
}

fn load_dataset(a: &Args) -> Result<Dataset, String> {
    let kind: DatasetKind = a.get_str("dataset", "taxi").parse()?;
    let input = a.get_str("input", "");
    if !input.is_empty() {
        return trueknn::dataset::io::load_csv(&input, kind).map_err(|e| e.to_string());
    }
    let n: usize = a.get_parse("n", 10_000).map_err(|e| e.to_string())?;
    let seed: u64 = a.get_parse("seed", 42).map_err(|e| e.to_string())?;
    Ok(kind.generate(n, seed))
}

fn run_knn(a: &Args) -> Result<(), String> {
    // a --config file supplies the whole run description; the individual
    // flags cover the same knobs for quick one-offs
    let file_cfg: Option<RunConfig> = match a.get_str("config", "").as_str() {
        "" => None,
        path => Some(RunConfig::from_file(path).map_err(|e| e.to_string())?),
    };
    let ds = match &file_cfg {
        Some(rc) => rc.dataset.generate(rc.n, rc.seed),
        None => load_dataset(a)?,
    };
    let k = match &file_cfg {
        Some(rc) => rc.k.resolve(ds.len()),
        None => match a.get_str("k", "5").as_str() {
            "sqrt" => KPolicy::SqrtN.resolve(ds.len()),
            s => s.parse::<usize>().map_err(|_| format!("bad k '{s}'"))?,
        },
    };
    let algo = a.get_str("algo", "trueknn");
    let percentile: Option<f64> = match &file_cfg {
        Some(rc) => rc.percentile_cap,
        None => match a.get_str("percentile", "").as_str() {
            "" => None,
            s => Some(s.parse().map_err(|_| format!("bad percentile '{s}'"))?),
        },
    };

    // `rtnn` keeps the paper-faithful one-shot implementation: its
    // per-partition data culling builds a scene per *query* chunk and
    // cannot go through a persistent index (see knn::rtnn docs). This
    // keeps `trueknn knn --algo rtnn` numbers consistent with the
    // `trueknn exp rtnn` ablation. `Backend::Rtnn` (Morton reordering
    // over one persistent BVH) remains available through the library.
    if algo == "rtnn" {
        let prof = trueknn::dataset::DistanceProfile::compute(&ds, k);
        let radius = (prof.percentile_dist(percentile.unwrap_or(100.0)) * 1.0001) as f32;
        let result = knn::rtnn::rtnn_knns(
            &ds.points,
            &ds.points,
            &knn::rtnn::RtnnParams {
                k,
                radius,
                ..Default::default()
            },
        );
        return report_knn(a, &ds, k, "rtnn", percentile, &result);
    }

    // every other algorithm goes through the unified index API:
    // configure, build once, query
    let backend: Backend = algo.parse()?;
    let mut cfg = match &file_cfg {
        // seed, start radius and threads flow straight from the file
        Some(rc) => rc.to_index_config(),
        None => IndexConfig {
            seed: a.get_parse("seed", 42).map_err(|e| e.to_string())?,
            threads: a.get_parse("threads", 0).map_err(|e| e.to_string())?,
            ..Default::default()
        },
    };
    match backend {
        Backend::TrueKnn => {
            cfg.radius_cap = percentile.map(|p| {
                let prof = trueknn::dataset::DistanceProfile::compute(&ds, k);
                (prof.percentile_dist(p) * 1.0001) as f32
            });
            if file_cfg.is_none() {
                cfg.start_radius = match a.get_str("start-radius", "").as_str() {
                    "" => None,
                    s => Some(s.parse::<f32>().map_err(|_| "bad start-radius")?),
                };
            }
        }
        Backend::FixedRadius | Backend::Rtnn => {
            let prof = trueknn::dataset::DistanceProfile::compute(&ds, k);
            let radius = (prof.percentile_dist(percentile.unwrap_or(100.0)) * 1.0001) as f32;
            log_info!("fixed search radius (maxDist rule): {radius}");
            cfg.radius = Some(radius);
        }
        Backend::KdTree | Backend::BruteCpu | Backend::BrutePjrt => {}
    }
    let cost_model = cfg.cost_model;
    let mut index = IndexBuilder::new(backend).config(cfg).build(ds.points.clone());
    let mut result = index.knn(&ds.points, k);
    // the one-shot CLI reports build + query as one number, like the
    // original free functions did
    if matches!(backend, Backend::TrueKnn | Backend::FixedRadius | Backend::Rtnn) {
        index.build_stats().absorb_into(&mut result, &cost_model);
    }
    report_knn(a, &ds, k, &algo, percentile, &result)
}

/// Shared result reporting + optional oracle verification for `knn`.
fn report_knn(
    a: &Args,
    ds: &Dataset,
    k: usize,
    algo: &str,
    percentile: Option<f64>,
    result: &trueknn::knn::KnnResult,
) -> Result<(), String> {
    println!(
        "algo={algo} dataset={} n={} k={k}",
        ds.kind.name(),
        ds.len()
    );
    println!(
        "sim_time={:.4}s wall_time={:.4}s rounds={} launches={}",
        result.sim_seconds,
        result.wall_seconds,
        result.rounds.len(),
        result.launches
    );
    println!(
        "tests: ray-sphere={} ray-aabb={} heap_pushes={} switches={}",
        result.counters.prim_tests,
        result.counters.aabb_tests,
        result.counters.heap_pushes,
        result.counters.context_switches
    );
    let complete = result
        .neighbors
        .iter()
        .filter(|nb| nb.len() >= k.min(ds.len() - 1))
        .count();
    println!("complete queries: {complete}/{}", ds.len());

    if a.flag("verify") {
        let tree = knn::kdtree::KdTree::build(&ds.points);
        let mut bad = 0;
        for (i, got) in result.neighbors.iter().enumerate() {
            let want = tree.knn_excluding(ds.points[i], got.len(), Some(i as u32));
            for (g, w) in got.iter().zip(&want) {
                if (g.dist - w.dist).abs() > 1e-4 {
                    bad += 1;
                    break;
                }
            }
        }
        if bad > 0 && percentile.is_none() && algo != "baseline" {
            return Err(format!("verification FAILED for {bad} queries"));
        }
        println!("verification: {bad} mismatching queries (0 expected for unbounded search)");
    }
    Ok(())
}

// ------------------------------------------------------------------- exp

fn cmd_exp() -> Command {
    Command::new(
        "exp",
        "regenerate a paper table/figure: table1|table2|table3|fig3|fig4|fig5|fig6|fig7|fig8|fig9|rtnn|refit|builder|all",
    )
    .opt("scale", "small|full (TRUEKNN_SCALE also works)", "")
}

fn run_exp(a: &Args) -> Result<(), String> {
    let scale = match a.get_str("scale", "").as_str() {
        "full" => ExpScale::Full,
        "small" => ExpScale::Small,
        _ => ExpScale::from_env(),
    };
    let which = a
        .positional
        .first()
        .map(String::as_str)
        .unwrap_or("all")
        .to_string();
    run_experiment(&which, scale)
}

/// Shared by the CLI and the bench binaries.
fn run_experiment(which: &str, scale: ExpScale) -> Result<(), String> {
    let all = which == "all";
    let mut matched = false;
    if all || which == "table1" || which == "fig3" {
        matched = true;
        let rows = exp::table1::run(scale, KPolicy::SqrtN);
        exp::table1::render(&rows).print();
        exp::figures::fig3(&rows).print();
    }
    if all || which == "table2" {
        matched = true;
        let rows = exp::table2::run(scale);
        exp::table2::render(&rows).print();
    }
    if all || which == "table3" {
        matched = true;
        let rows = exp::table3::run(scale);
        exp::table3::render(&rows).print();
    }
    if all || which == "fig4" {
        matched = true;
        let rows = exp::figures::fig4(scale);
        exp::figures::render_fig4(&rows).print();
    }
    if all || which == "fig5" {
        matched = true;
        let rows = exp::figures::fig5(scale);
        exp::figures::render_fig5(&rows, exp::workloads::mid_size(scale)).print();
    }
    if all || which == "fig6" {
        matched = true;
        let rounds = exp::figures::fig6(scale);
        exp::figures::render_fig6(&rounds).print();
    }
    if all || which == "fig7" {
        matched = true;
        let rows = exp::figures::fig7(scale);
        exp::figures::render_fig7(&rows).print();
    }
    if all || which == "fig8" {
        matched = true;
        let rows = exp::figures::fig8(scale);
        exp::figures::render_pct(&rows, "Fig 8: 99th-percentile speedups (k=√N)").print();
    }
    if all || which == "fig9" {
        matched = true;
        let rows = exp::figures::fig9(scale);
        exp::figures::render_pct(&rows, "Fig 9: 99th-percentile 3DIono (k=5)").print();
    }
    if all || which == "rtnn" {
        matched = true;
        let rows = exp::ablations::rtnn_cmp(scale, None);
        exp::ablations::render_rtnn(&rows).print();
    }
    if all || which == "refit" {
        matched = true;
        let rows = exp::ablations::refit_vs_rebuild(&[10_000, 50_000, 200_000]);
        exp::ablations::render_refit(&rows).print();
    }
    if all || which == "builder" {
        matched = true;
        let rows = exp::ablations::builder_ablation(scale);
        exp::ablations::render_builder(&rows).print();
    }
    if !matched {
        return Err(format!("unknown experiment '{which}'"));
    }
    Ok(())
}

// --------------------------------------------------------------- runtime

fn cmd_runtime() -> Command {
    Command::new("runtime", "inspect and smoke-test the PJRT artifacts")
        .flag("smoke", "execute a tiny brute-force query through PJRT")
}

fn run_runtime(a: &Args) -> Result<(), String> {
    let rt = trueknn::runtime::PjrtRuntime::load_default().map_err(|e| e.to_string())?;
    println!("artifact dir: {}", rt.dir.display());
    let mut names = rt.program_names();
    names.sort();
    for name in names {
        let Some(s) = rt.spec(name) else { continue };
        println!("  {name}: q={} n={} k={}", s.q, s.n, s.k);
    }
    if a.flag("smoke") {
        let ds = DatasetKind::Uniform.generate(1_000, 1);
        let bf = trueknn::runtime::PjrtBruteForce::new(&rt);
        let res = bf
            .knn(&ds.points, &ds.points[..16], 5, false)
            .map_err(|e| e.to_string())?;
        let tree = knn::kdtree::KdTree::build(&ds.points);
        for (i, got) in res.neighbors.iter().enumerate() {
            let want = tree.knn(ds.points[i], 5);
            for (g, w) in got.iter().zip(&want) {
                if (g.dist - w.dist).abs() > 1e-3 {
                    return Err(format!("smoke mismatch on query {i}"));
                }
            }
        }
        println!("PJRT smoke test OK ({} launches)", res.launches);
    }
    Ok(())
}

// ----------------------------------------------------------------- serve

fn cmd_serve() -> Command {
    Command::new("serve", "run the batching query service demo")
        .opt(
            "config",
            "run-config JSON file; supplies dataset/n/seed/threads/workers/shards",
            "",
        )
        .opt("dataset", "road|taxi|lidar|iono|uniform", "taxi")
        .opt("n", "dataset size", "20000")
        .opt("requests", "number of client requests", "64")
        .opt("queries-per-request", "queries per request", "16")
        .opt("k", "neighbors per query", "5")
        .opt("threads", "launch-engine worker threads (0 = all cores)", "0")
        .opt("workers", "coordinator pool workers (0 = all cores)", "0")
        .opt(
            "shards",
            "spatial shards for the RT route's dataset (1 = unsharded)",
            "1",
        )
        .opt(
            "data-dir",
            "enable crash-safe persistence (WAL + snapshots) in this directory",
            "",
        )
        .opt(
            "snapshot-interval",
            "inserts between index snapshots (0 = only at clean shutdown)",
            "0",
        )
        .opt(
            "trace-dir",
            "capture per-request span traces into this directory (read with `trueknn trace`)",
            "",
        )
        .opt(
            "metrics-out",
            "write the final metrics snapshot (latency histograms included) as JSON",
            "",
        )
        .flag("pjrt", "use the PJRT brute path when routed")
}

fn run_serve(a: &Args) -> Result<(), String> {
    use trueknn::coordinator::{KnnRequest, Service, ServiceConfig};
    let file_cfg: Option<RunConfig> = match a.get_str("config", "").as_str() {
        "" => None,
        path => Some(RunConfig::from_file(path).map_err(|e| e.to_string())?),
    };
    let ds = match &file_cfg {
        Some(rc) => rc.dataset.generate(rc.n, rc.seed),
        None => {
            let kind: DatasetKind = a.get_str("dataset", "taxi").parse()?;
            let n: usize = a.get_parse("n", 20_000).map_err(|e| e.to_string())?;
            kind.generate(n, 42)
        }
    };
    let n_req: usize = a.get_parse("requests", 64).map_err(|e| e.to_string())?;
    let qpr: usize = a
        .get_parse("queries-per-request", 16)
        .map_err(|e| e.to_string())?;
    let k: usize = a.get_parse("k", 5).map_err(|e| e.to_string())?;

    let mut cfg = ServiceConfig {
        use_pjrt: a.flag("pjrt"),
        ..Default::default()
    };
    // 0 resolves to the TRUEKNN_THREADS-aware default inside
    // Executor::new, exactly like the knn/config path
    cfg.trueknn.threads = match &file_cfg {
        Some(rc) => rc.threads.unwrap_or(0),
        None => a.get_parse("threads", 0).map_err(|e| e.to_string())?,
    };
    cfg.workers = match &file_cfg {
        Some(rc) => rc.workers.unwrap_or(0),
        None => a.get_parse("workers", 0).map_err(|e| e.to_string())?,
    };
    cfg.shards = match &file_cfg {
        Some(rc) => rc.shards.unwrap_or(1),
        None => a.get_parse("shards", 1).map_err(|e| e.to_string())?,
    }
    .max(1);
    // the fault-injection CI leg (and curious operators) can arm a
    // seeded plan end-to-end; unset, the plan stays inert. The checked
    // parse makes a malformed seed a hard error instead of a silently
    // disarmed plan.
    if let Some(seed) =
        trueknn::cli::env_parse::<u64>("TRUEKNN_FAULT_SEED").map_err(|e| e.to_string())?
    {
        let pool = if cfg.workers == 0 { 2 } else { cfg.workers };
        cfg.faults = trueknn::faults::FaultPlan::seeded(seed, pool);
        log_info!("fault injection armed: TRUEKNN_FAULT_SEED={seed}");
    }
    let data_dir = a.get_str("data-dir", "");
    if !data_dir.is_empty() {
        let mut pc = trueknn::coordinator::PersistConfig::at(&data_dir);
        pc.snapshot_interval = a
            .get_parse("snapshot-interval", 0)
            .map_err(|e| e.to_string())?;
        log_info!(
            "crash-safe persistence at {data_dir} (snapshot interval {})",
            pc.snapshot_interval
        );
        cfg.persist = Some(pc);
    }
    let trace_dir = a.get_str("trace-dir", "");
    if !trace_dir.is_empty() {
        log_info!("request tracing to {trace_dir}");
        cfg.trace = Some(trueknn::coordinator::TraceConfig::new(&trace_dir));
    }
    let persist_on = cfg.persist.is_some();
    let (svc, handle) = Service::start(ds.points.clone(), cfg);

    let sw = trueknn::util::Stopwatch::start();
    let mut rng = trueknn::util::Pcg32::new(7);
    let mut receivers = Vec::new();
    for id in 0..n_req as u64 {
        let queries: Vec<_> = (0..qpr)
            .map(|_| ds.points[rng.below_usize(ds.len())])
            .collect();
        receivers.push(
            handle
                .submit(KnnRequest::new(id, queries, k))
                .map_err(|e| e.to_string())?,
        );
    }
    let mut served = 0;
    for rx in receivers {
        let resp = rx
            .recv()
            .map_err(|e| e.to_string())?
            .map_err(|e| e.to_string())?;
        served += resp.neighbors.len();
    }
    let elapsed = sw.elapsed_secs();
    let m = handle.metrics().snapshot();
    println!(
        "served {served} queries in {elapsed:.3}s ({:.0} q/s, {} pool workers)",
        served as f64 / elapsed,
        handle.workers()
    );
    println!(
        "batches={} rt={} brute={} rejected={} mean_latency={:.2}ms max_latency={:.2}ms",
        m.batches,
        m.rt_requests,
        m.brute_requests,
        m.rejected,
        m.latency_mean_s * 1e3,
        m.latency_max_s * 1e3
    );
    // log2-bucket upper bounds: "p99 requests finished within this"
    println!(
        "latency percentiles: p50<={:.2}ms p95<={:.2}ms p99<={:.2}ms",
        m.latency_p50_s * 1e3,
        m.latency_p95_s * 1e3,
        m.latency_p99_s * 1e3
    );
    let builds: Vec<String> = m
        .route_builds
        .iter()
        .map(|(p, b)| format!("{}={b}", p.name()))
        .collect();
    println!("builds: {}", builds.join(" "));
    // the supervision story: what the pool survived while serving
    println!(
        "recovery: restarts={} replays={} deadline_misses={} poisoned={}",
        m.restarts, m.replays, m.deadline_misses, m.poisoned
    );
    // the durability story: what cold start found on disk this run
    if persist_on {
        println!(
            "durability: recovered={} rebuilt={} wal_replayed={} snapshot_corrupt={}",
            m.recovered, m.rebuilt, m.wal_replayed, m.snapshot_corrupt
        );
    }
    // sharded RT route: where each shard's structure work and traffic went
    if !m.shard_builds.is_empty() {
        let per: Vec<String> = m
            .shard_builds
            .iter()
            .zip(&m.shard_queries)
            .enumerate()
            .map(|(s, (b, q))| format!("s{s}:builds={b},queries={q}"))
            .collect();
        println!("rt shards: {}", per.join(" "));
    }
    // the operator's backpressure story: which queues filled, who rejected
    for (w, ws) in m.workers.iter().enumerate() {
        println!(
            "worker {w}: submitted={} batches={} rejected={} queue_hwm={}",
            ws.submitted, ws.batches, ws.rejected, ws.queue_hwm
        );
    }
    // shut down first: the clean exit drains every worker's trace ring,
    // so a --trace-dir capture is complete before anyone reads it
    svc.shutdown();
    let metrics_out = a.get_str("metrics-out", "");
    if !metrics_out.is_empty() {
        std::fs::write(&metrics_out, metrics_to_json(&m).to_string())
            .map_err(|e| format!("writing {metrics_out}: {e}"))?;
        log_info!("wrote {metrics_out}");
    }
    Ok(())
}

/// Serialize a [`MetricsSnapshot`] for `serve --metrics-out`: every
/// counter, the recovery/durability story, and the merged per-stage
/// latency histograms (nonzero log2 buckets as `[bit_length, count]`
/// pairs, plus the percentile upper bounds in seconds).
///
/// [`MetricsSnapshot`]: trueknn::coordinator::MetricsSnapshot
fn metrics_to_json(m: &trueknn::coordinator::MetricsSnapshot) -> trueknn::configx::Json {
    use trueknn::configx::Json;
    use trueknn::obs::LogHistogram;
    let hist = |h: &LogHistogram| {
        let buckets: Vec<Json> = h
            .buckets()
            .iter()
            .enumerate()
            .filter(|&(_, &c)| c > 0)
            .map(|(b, &c)| Json::Arr(vec![Json::Num(b as f64), Json::Num(c as f64)]))
            .collect();
        Json::obj(vec![
            ("count", Json::Num(h.count() as f64)),
            ("buckets", Json::Arr(buckets)),
            ("p50_s", Json::Num(LogHistogram::seconds(h.percentile_upper_ns(50)))),
            ("p95_s", Json::Num(LogHistogram::seconds(h.percentile_upper_ns(95)))),
            ("p99_s", Json::Num(LogHistogram::seconds(h.percentile_upper_ns(99)))),
        ])
    };
    let workers: Vec<Json> = m
        .workers
        .iter()
        .map(|w| {
            Json::obj(vec![
                ("submitted", Json::Num(w.submitted as f64)),
                ("rejected", Json::Num(w.rejected as f64)),
                ("batches", Json::Num(w.batches as f64)),
                ("inserts", Json::Num(w.inserts as f64)),
                ("queue_hwm", Json::Num(w.queue_hwm as f64)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("requests", Json::Num(m.requests as f64)),
        ("responses", Json::Num(m.responses as f64)),
        ("rejected", Json::Num(m.rejected as f64)),
        ("batches", Json::Num(m.batches as f64)),
        ("rt_requests", Json::Num(m.rt_requests as f64)),
        ("brute_requests", Json::Num(m.brute_requests as f64)),
        ("queries_served", Json::Num(m.queries_served as f64)),
        ("inserts", Json::Num(m.inserts as f64)),
        ("builds", Json::Num(m.builds as f64)),
        ("restarts", Json::Num(m.restarts as f64)),
        ("replays", Json::Num(m.replays as f64)),
        ("deadline_misses", Json::Num(m.deadline_misses as f64)),
        ("poisoned", Json::Num(m.poisoned as f64)),
        ("recovered", Json::Num(m.recovered as f64)),
        ("rebuilt", Json::Num(m.rebuilt as f64)),
        ("wal_replayed", Json::Num(m.wal_replayed as f64)),
        ("snapshot_corrupt", Json::Num(m.snapshot_corrupt as f64)),
        ("latency_mean_s", Json::Num(m.latency_mean_s)),
        ("latency_max_s", Json::Num(m.latency_max_s)),
        ("latency_p50_s", Json::Num(m.latency_p50_s)),
        ("latency_p95_s", Json::Num(m.latency_p95_s)),
        ("latency_p99_s", Json::Num(m.latency_p99_s)),
        ("hist_e2e", hist(&m.hist_e2e)),
        ("hist_queue_wait", hist(&m.hist_queue_wait)),
        ("hist_fence", hist(&m.hist_fence)),
        ("hist_service", hist(&m.hist_service)),
        ("hist_merge", hist(&m.hist_merge)),
        (
            "shard_queries",
            Json::Arr(m.shard_queries.iter().map(|&q| Json::Num(q as f64)).collect()),
        ),
        ("workers", Json::Arr(workers)),
    ])
}

// -------------------------------------------------------------- snapshot

fn cmd_snapshot() -> Command {
    Command::new(
        "snapshot",
        "build an index offline and write (or validate) a checksummed snapshot blob",
    )
    .opt("dataset", "road|taxi|lidar|iono|uniform", "taxi")
    .opt("input", "CSV file instead of a generator", "")
    .opt("n", "number of points", "10000")
    .opt("seed", "PRNG seed", "42")
    .opt("algo", "trueknn|baseline|rtnn|kdtree|brute|pjrt", "trueknn")
    .opt("k", "neighbors for the fixed-radius rule (baseline/rtnn only)", "5")
    .opt("shards", "spatial shards inside the snapshot (1 = unsharded)", "1")
    .opt("threads", "build worker threads (0 = all cores)", "0")
    .opt("out", "output snapshot path", "")
    .opt("check", "validate an existing snapshot blob instead of building", "")
}

/// `trueknn snapshot`: the offline snapshot builder. A build farm can
/// produce checksummed index blobs ahead of time and ship them to
/// serving hosts, whose cold start then skips the full rebuild — the
/// same [`IndexBuilder::load`] fences (section + container CRCs, format
/// version, config fingerprint) guard the hand-off. `--check` instead
/// re-validates an existing blob under the current flags; it must be
/// invoked with the same dataset/config flags as the build, because the
/// seed (and, for the fixed-radius backends, the derived radius)
/// participates in the fingerprint.
fn run_snapshot(a: &Args) -> Result<(), String> {
    use trueknn::faults::{FaultPlan, IoTarget};

    let backend: Backend = a.get_str("algo", "trueknn").parse()?;
    let k: usize = a.get_parse("k", 5).map_err(|e| e.to_string())?;
    let ds = load_dataset(a)?;
    let mut cfg = IndexConfig {
        seed: a.get_parse("seed", 42).map_err(|e| e.to_string())?,
        threads: a.get_parse("threads", 0).map_err(|e| e.to_string())?,
        shards: a.get_parse("shards", 1).map_err(|e| e.to_string())?,
        ..Default::default()
    };
    cfg.shards = cfg.shards.max(1);
    if matches!(backend, Backend::FixedRadius | Backend::Rtnn) {
        // the fixed-radius baselines carry their search radius in the
        // config fingerprint, so build and check both derive it the same
        // deterministic way the `knn` command does (maxDist rule)
        let prof = trueknn::dataset::DistanceProfile::compute(&ds, k);
        cfg.radius = Some((prof.percentile_dist(100.0) * 1.0001) as f32);
    }
    let make = || IndexBuilder::new(backend).config(cfg.clone());

    let check = a.get_str("check", "");
    if !check.is_empty() {
        let bytes = std::fs::read(&check).map_err(|e| format!("reading {check}: {e}"))?;
        let (ix, watermark) = make().load(&bytes).map_err(|e| e.to_string())?;
        log_info!(
            "{check}: valid {} snapshot ({} bytes) — {} points, watermark {watermark}",
            ix.backend().name(),
            bytes.len(),
            ix.len()
        );
        log_info!("config fingerprint {:#018x}", make().fingerprint());
        return Ok(());
    }

    let out = a.get_str("out", "");
    if out.is_empty() {
        return Err("--out is required (or pass --check to validate a blob)".into());
    }
    let sw = trueknn::util::Stopwatch::start();
    let mut index = make().try_build(ds.points.clone()).map_err(|e| e.to_string())?;
    let build_s = sw.elapsed_secs();
    let bytes = make().snapshot(index.as_ref(), 0);

    // prove the blob round-trips before publishing it: a build farm must
    // never ship a snapshot that fails its own validation, and the
    // reload must answer bitwise-identically to the index it came from
    let (mut reloaded, _) = make().load(&bytes).map_err(|e| e.to_string())?;
    let probes = &ds.points[..ds.len().min(16)];
    let pk = k.clamp(1, ds.len().saturating_sub(1).max(1));
    let want = index.knn(probes, pk);
    let got = reloaded.knn(probes, pk);
    let identical = want.neighbors.len() == got.neighbors.len()
        && want.neighbors.iter().zip(&got.neighbors).all(|(x, y)| {
            x.len() == y.len()
                && x.iter()
                    .zip(y)
                    .all(|(p, q)| p.idx == q.idx && p.dist.to_bits() == q.dist.to_bits())
        });
    if !identical {
        return Err("reloaded snapshot answered differently from the index it came from".into());
    }

    // same crash-safe discipline as the service's snapshot writer: temp
    // sibling + fsync + atomic rename, so a crash mid-write can never
    // leave a half-written blob under the published name
    trueknn::persist::atomic_write(
        std::path::Path::new(&out),
        &bytes,
        &FaultPlan::inert(),
        IoTarget::Snapshot,
        0,
    )
    .map_err(|e| e.to_string())?;
    log_info!(
        "wrote {out}: {} bytes, {} {} points in {} shard(s), built in {build_s:.3}s",
        bytes.len(),
        index.len(),
        backend.name(),
        cfg.shards
    );
    log_info!(
        "config fingerprint {:#018x}; reload verified bitwise on {} probe queries",
        make().fingerprint(),
        probes.len()
    );
    Ok(())
}

// ----------------------------------------------------------------- trace

fn cmd_trace() -> Command {
    Command::new(
        "trace",
        "profile a serve run's trace directory: per-stage attribution, per-shard leg skew, TrueKNN convergence",
    )
    .req("dir", "trace directory written by `serve --trace-dir`")
    .opt("tree", "also render the span tree of this request id", "")
    .flag("json", "emit the machine-readable profile JSON")
}

/// `trueknn trace`: the offline profiler over a serve run's span
/// capture. Reads every CRC-framed `trace-*.jsonl` under `--dir`
/// (tolerating a crashed writer's torn tail), reconstructs span trees,
/// and prints the aggregate report — or, with `--tree <id>`, one
/// request's tree. The convergence table's counters are deterministic
/// (they mirror the engine's own round bookkeeping), so the report is
/// auditable against `MetricsSnapshot` and the BENCH gates.
fn run_trace(a: &Args) -> Result<(), String> {
    use trueknn::obs::profile;
    let dir = a.get("dir").ok_or("--dir is required")?;
    let (records, truncated) = trueknn::obs::trace::read_trace_dir(std::path::Path::new(dir))?;
    if records.is_empty() {
        return Err(format!("no verified trace records under {dir}"));
    }
    if truncated {
        log_info!("a trace file ended in a torn frame; profiling the verified prefix");
    }
    let tree_id = a.get_str("tree", "");
    if !tree_id.is_empty() {
        let id: u64 = tree_id
            .parse()
            .map_err(|e| format!("--tree wants a request id: {e}"))?;
        let tree = profile::span_tree(&records, id)
            .ok_or_else(|| format!("no spans for request {id} under {dir}"))?;
        print!("{}", profile::render_tree(&tree));
        if !a.flag("json") {
            println!();
        }
    }
    let prof = profile::Profile::build(&records, truncated);
    if a.flag("json") {
        let s = profile::to_json(&prof).to_string();
        println!("{s}");
    } else {
        print!("{}", profile::render_text(&prof));
    }
    Ok(())
}

// ------------------------------------------------------------------ lint

fn cmd_lint() -> Command {
    Command::new(
        "lint",
        "run the determinism-contract analyzer (exit code = finding count)",
    )
    .opt("root", "source tree to scan", "src")
    .opt("config", "lint.toml path", "lint.toml")
    .flag("json", "emit the machine-readable JSON report")
}

/// `lint` has its own driver: the exit code is the number of findings
/// (clamped to 200), so CI and scripts can gate on it directly.
fn run_lint(argv: &[String]) -> i32 {
    let cmd = cmd_lint();
    let args = match cmd.parse(argv) {
        Ok(a) => a,
        Err(CliError::HelpRequested) => {
            print!("{}", cmd.usage());
            return 0;
        }
        Err(e) => {
            eprintln!("{e}\n\n{}", cmd.usage());
            return 2;
        }
    };
    let root = std::path::PathBuf::from(args.get_str("root", "src"));
    let config = std::path::PathBuf::from(args.get_str("config", "lint.toml"));
    let cfg = match trueknn::analysis::LintConfig::load(&config) {
        Ok(c) => c,
        Err(e) => {
            log_error!("{e}");
            return 2;
        }
    };
    match trueknn::analysis::run_tree(&root, &cfg) {
        Ok(report) => {
            if args.flag("json") {
                let s = trueknn::analysis::to_json(&report).to_string();
                println!("{s}");
            } else {
                print!("{}", trueknn::analysis::render_text(&report));
            }
            report.findings.len().min(200) as i32
        }
        Err(e) => {
            log_error!("{e}");
            2
        }
    }
}

// ----------------------------------------------------------------- bench

fn cmd_bench() -> Command {
    Command::new(
        "bench",
        "perf microbenches: launch throughput + shell re-query (PR2), SoA leaf loop + cohort scheduling + round bookkeeping (PR3), worker-pool serving throughput (PR4), sharded hot-route throughput (PR5), determinism-lint gate cost (PR6), supervised recovery cost (PR7), crash-safe persistence cost (PR8), pipelined scatter-gather + fenced inserts (PR9), tracing overhead + transparency (PR10)",
    )
    .opt("n", "points for the launch-throughput bench", "100000")
    .opt("shell-n", "points for the TrueKNN shell/round bench", "20000")
    .opt("serve-n", "points for the pool serving bench", "20000")
    .opt("serve-requests", "requests per pool-serving replay", "48")
    .opt("serve-queries", "queries per request in the serving bench", "16")
    .opt("iters", "timed iterations per configuration", "3")
    .opt("out", "PR2 output JSON path", "BENCH_PR2.json")
    .opt("pr3-out", "PR3 output JSON path", "BENCH_PR3.json")
    .opt("pr4-out", "PR4 output JSON path", "BENCH_PR4.json")
    .opt("pr5-out", "PR5 output JSON path", "BENCH_PR5.json")
    .opt("pr6-out", "PR6 output JSON path", "BENCH_PR6.json")
    .opt("pr7-out", "PR7 output JSON path", "BENCH_PR7.json")
    .opt("pr8-out", "PR8 output JSON path", "BENCH_PR8.json")
    .opt("pr9-out", "PR9 output JSON path", "BENCH_PR9.json")
    .opt("pr10-out", "PR10 output JSON path", "BENCH_PR10.json")
}

fn run_bench(a: &Args) -> Result<(), String> {
    let n: usize = a.get_parse("n", 100_000).map_err(|e| e.to_string())?;
    let shell_n: usize = a.get_parse("shell-n", 20_000).map_err(|e| e.to_string())?;
    let serve_n: usize = a.get_parse("serve-n", 20_000).map_err(|e| e.to_string())?;
    let serve_requests: usize = a.get_parse("serve-requests", 48).map_err(|e| e.to_string())?;
    let serve_queries: usize = a.get_parse("serve-queries", 16).map_err(|e| e.to_string())?;
    let iters: usize = a.get_parse("iters", 3).map_err(|e| e.to_string())?;
    let out = a.get_str("out", "BENCH_PR2.json");
    let pr3_out = a.get_str("pr3-out", "BENCH_PR3.json");
    let pr4_out = a.get_str("pr4-out", "BENCH_PR4.json");
    let pr5_out = a.get_str("pr5-out", "BENCH_PR5.json");
    let pr6_out = a.get_str("pr6-out", "BENCH_PR6.json");
    let pr7_out = a.get_str("pr7-out", "BENCH_PR7.json");
    let pr8_out = a.get_str("pr8-out", "BENCH_PR8.json");
    let pr9_out = a.get_str("pr9-out", "BENCH_PR9.json");
    let pr10_out = a.get_str("pr10-out", "BENCH_PR10.json");

    let report = trueknn::bench::pr2::run(n, shell_n, iters);
    trueknn::bench::pr2::render(&report).print();
    if !report.shell_exact {
        return Err("shell re-query changed results vs the reset baseline".into());
    }
    std::fs::write(&out, trueknn::bench::pr2::to_json(&report).to_string())
        .map_err(|e| e.to_string())?;
    log_info!("wrote {out}");

    let pr3 = trueknn::bench::pr3::run(n, shell_n, iters);
    trueknn::bench::pr3::render(&pr3).print();
    if !pr3.layout_match {
        return Err("SoA leaf loop changed results vs the AoS reference".into());
    }
    if !pr3.cohort_match {
        return Err("cohort scheduling changed results".into());
    }
    std::fs::write(&pr3_out, trueknn::bench::pr3::to_json(&pr3).to_string())
        .map_err(|e| e.to_string())?;
    log_info!("wrote {pr3_out}");

    let pr4 = trueknn::bench::pr4::run(serve_n, serve_requests, serve_queries, iters);
    trueknn::bench::pr4::render(&pr4).print();
    if !pr4.pool_match {
        return Err("worker pool changed responses vs the single-worker oracle".into());
    }
    std::fs::write(&pr4_out, trueknn::bench::pr4::to_json(&pr4).to_string())
        .map_err(|e| e.to_string())?;
    log_info!("wrote {pr4_out}");

    let pr5 = trueknn::bench::pr5::run(serve_n, serve_requests, serve_queries, iters);
    trueknn::bench::pr5::render(&pr5).print();
    if !pr5.shard_match {
        return Err("dataset sharding changed responses vs the unsharded oracle".into());
    }
    std::fs::write(&pr5_out, trueknn::bench::pr5::to_json(&pr5).to_string())
        .map_err(|e| e.to_string())?;
    log_info!("wrote {pr5_out}");

    let pr6 = trueknn::bench::pr6::run(iters)?;
    trueknn::bench::pr6::render(&pr6).print();
    if !pr6.under_budget() {
        return Err(format!(
            "lint gate blew its budget: {:.3}s >= {:.1}s over {} files",
            pr6.lint_seconds, pr6.budget_seconds, pr6.files
        ));
    }
    std::fs::write(&pr6_out, trueknn::bench::pr6::to_json(&pr6).to_string())
        .map_err(|e| e.to_string())?;
    log_info!("wrote {pr6_out}");

    let pr7 = trueknn::bench::pr7::run(serve_n, serve_requests, serve_queries, iters);
    trueknn::bench::pr7::render(&pr7).print();
    if !pr7.results_match {
        return Err("recovery changed responses vs the no-fault baseline".into());
    }
    if pr7.restarts != 1 {
        return Err(format!(
            "the injected kill must produce exactly one restart, saw {}",
            pr7.restarts
        ));
    }
    std::fs::write(&pr7_out, trueknn::bench::pr7::to_json(&pr7).to_string())
        .map_err(|e| e.to_string())?;
    log_info!("wrote {pr7_out}");

    let pr8 = trueknn::bench::pr8::run(&[2_000, 8_000, serve_n], iters);
    trueknn::bench::pr8::render(&pr8).print();
    if !pr8.results_match {
        return Err("a loaded snapshot answered differently from its original index".into());
    }
    std::fs::write(&pr8_out, trueknn::bench::pr8::to_json(&pr8).to_string())
        .map_err(|e| e.to_string())?;
    log_info!("wrote {pr8_out}");

    let pr9 = trueknn::bench::pr9::run(serve_n, serve_requests, serve_queries, iters);
    trueknn::bench::pr9::render(&pr9).print();
    if !pr9.serve_match {
        return Err("incremental gather changed responses vs the unsharded oracle".into());
    }
    if !pr9.spec_match {
        return Err("shard speculation changed results vs the serial oracle".into());
    }
    if !pr9.insert_match {
        return Err("insert schedule changed the fenced answer".into());
    }
    std::fs::write(&pr9_out, trueknn::bench::pr9::to_json(&pr9).to_string())
        .map_err(|e| e.to_string())?;
    log_info!("wrote {pr9_out}");

    let pr10 = trueknn::bench::pr10::run(serve_n, serve_requests, serve_queries, iters);
    trueknn::bench::pr10::render(&pr10).print();
    if !pr10.results_match {
        return Err("tracing changed responses vs the untraced run — transparency broken".into());
    }
    if pr10.overhead_frac > trueknn::bench::pr10::OVERHEAD_BUDGET {
        return Err(format!(
            "tracing overhead {:.1}% exceeds the {:.0}% budget",
            pr10.overhead_frac * 100.0,
            trueknn::bench::pr10::OVERHEAD_BUDGET * 100.0
        ));
    }
    std::fs::write(&pr10_out, trueknn::bench::pr10::to_json(&pr10).to_string())
        .map_err(|e| e.to_string())?;
    log_info!("wrote {pr10_out}");
    Ok(())
}
