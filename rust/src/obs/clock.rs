//! The sanctioned wall-clock chokepoint.
//!
//! Core serving code never reads the monotonic clock directly: the
//! `wallclock-in-core` lint flags any `Instant::now()` outside the
//! measurement shells (`bench`, `exp`, `util::timer`) and this module.
//! Every telemetry timestamp in the coordinator flows through
//! [`now`], so a reviewer auditing the determinism contract has
//! exactly one call site to reason about — and the contract itself is
//! simple: values derived from [`now`] may be *recorded* (span
//! timestamps, histogram samples) but never *branched on* in a
//! result path.
//!
//! Tests that need reproducible timelines use [`MockClock`] instead: a
//! seeded, purely deterministic nanosecond counter with no connection
//! to the host clock at all.

use std::time::Instant;

/// Read the monotonic wall clock, for telemetry only.
///
/// This is the single sanctioned clock read for the serving stack.
/// The returned `Instant` (and durations derived from it) must only
/// feed span records and latency histograms — never a result, a
/// counter the determinism oracle compares, or a control-flow branch
/// that affects responses.
pub fn now() -> Instant {
    Instant::now()
}

/// A seeded, deterministic test clock.
///
/// `MockClock` is a plain nanosecond counter: it starts at a value
/// scrambled from the seed, moves only when told ([`advance`] /
/// [`tick`]), and never consults the host. Two clocks built from the
/// same seed produce identical timelines, which makes span-duration
/// and histogram assertions exact instead of flaky.
///
/// [`advance`]: MockClock::advance
/// [`tick`]: MockClock::tick
#[derive(Debug, Clone)]
pub struct MockClock {
    now_ns: u64,
    state: u64,
}

impl MockClock {
    /// A clock seeded at a deterministic, nonzero starting instant.
    pub fn new(seed: u64) -> Self {
        let mut clock = MockClock { now_ns: 0, state: seed };
        // burn one state step so seed 0 still yields a scrambled,
        // nonzero epoch
        clock.now_ns = clock.next_state() >> 34;
        clock
    }

    /// Current mock time, in nanoseconds since the mock epoch.
    pub fn now_ns(&self) -> u64 {
        self.now_ns
    }

    /// Move the clock forward by exactly `ns` nanoseconds.
    pub fn advance(&mut self, ns: u64) {
        self.now_ns = self.now_ns.saturating_add(ns);
    }

    /// Move the clock forward by a seeded pseudo-random step (between
    /// 1µs and ~1ms) and return the new time. Useful for generating
    /// varied but reproducible span timelines.
    pub fn tick(&mut self) -> u64 {
        let step = 1_000 + (self.next_state() % 1_000_000);
        self.advance(step);
        self.now_ns
    }

    /// One splitmix64 step: the standard, fully deterministic stream.
    fn next_state(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_seed_same_timeline() {
        let mut a = MockClock::new(42);
        let mut b = MockClock::new(42);
        assert_eq!(a.now_ns(), b.now_ns());
        for _ in 0..100 {
            assert_eq!(a.tick(), b.tick());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let a = MockClock::new(1);
        let b = MockClock::new(2);
        assert_ne!(a.now_ns(), b.now_ns());
    }

    #[test]
    fn advance_is_exact_and_saturating() {
        let mut c = MockClock::new(0);
        let t0 = c.now_ns();
        c.advance(123);
        assert_eq!(c.now_ns(), t0 + 123);
        c.advance(u64::MAX);
        assert_eq!(c.now_ns(), u64::MAX);
    }

    #[test]
    fn ticks_move_strictly_forward() {
        let mut c = MockClock::new(7);
        let mut prev = c.now_ns();
        for _ in 0..50 {
            let t = c.tick();
            assert!(t > prev);
            prev = t;
        }
    }
}
