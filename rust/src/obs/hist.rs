//! Fixed-bucket log2 latency histograms.
//!
//! Bucket math is pure integer arithmetic: a nanosecond sample lands
//! in the bucket indexed by its bit length (bucket 0 holds exactly the
//! value 0; bucket `b ≥ 1` holds `[2^(b-1), 2^b - 1]`; bucket 63
//! absorbs everything from `2^62` up to `u64::MAX`). No floats touch
//! recording, merging, or percentile extraction, so histogram state is
//! a deterministic function of the multiset of samples — merging two
//! histograms is per-bucket `u64` addition, which is associative and
//! commutative, and the coordinator merges worker histograms in
//! worker-index order so snapshots are reproducible byte-for-byte
//! given identical samples.
//!
//! Percentiles are *bucket upper bounds*: `p99` answers "99% of
//! samples were at most this many nanoseconds", rounded up to the
//! nearest power-of-two boundary. Conversion to floating seconds
//! happens only at the display edge ([`LogHistogram::seconds`]).

use std::sync::atomic::{AtomicU64, Ordering};

/// Number of histogram buckets: one per `u64` bit length, plus the
/// zero bucket folded into index 0.
pub const BUCKETS: usize = 64;

/// Bucket index for a nanosecond sample: its bit length, clamped to
/// the top bucket.
fn bucket_of(ns: u64) -> usize {
    if ns == 0 {
        0
    } else {
        ((64 - ns.leading_zeros()) as usize).min(BUCKETS - 1)
    }
}

/// Inclusive upper bound of a bucket, in nanoseconds.
fn upper_bound(bucket: usize) -> u64 {
    match bucket {
        0 => 0,
        b if b >= BUCKETS - 1 => u64::MAX,
        b => (1u64 << b) - 1,
    }
}

/// A plain (single-owner) log2 histogram of nanosecond durations.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LogHistogram {
    buckets: [u64; BUCKETS],
}

impl Default for LogHistogram {
    fn default() -> Self {
        LogHistogram { buckets: [0; BUCKETS] }
    }
}

impl LogHistogram {
    /// An empty histogram.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one sample of `ns` nanoseconds.
    pub fn record(&mut self, ns: u64) {
        self.buckets[bucket_of(ns)] += 1;
    }

    /// Total number of recorded samples.
    pub fn count(&self) -> u64 {
        self.buckets.iter().sum()
    }

    /// True when no samples have been recorded.
    pub fn is_empty(&self) -> bool {
        self.count() == 0
    }

    /// Fold another histogram into this one (per-bucket addition —
    /// associative and commutative, so any merge order yields the same
    /// state; the coordinator still merges in worker-index order for
    /// auditability).
    pub fn merge(&mut self, other: &LogHistogram) {
        for (mine, theirs) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *mine += theirs;
        }
    }

    /// The raw bucket counts, indexed by bit length.
    pub fn buckets(&self) -> &[u64; BUCKETS] {
        &self.buckets
    }

    /// Upper-bound nanoseconds of the bucket containing the `pct`-th
    /// percentile sample (rank rounded up). Returns 0 on an empty
    /// histogram. Pure integer math end to end.
    pub fn percentile_upper_ns(&self, pct: u64) -> u64 {
        let n = self.count();
        if n == 0 {
            return 0;
        }
        let rank = (n * pct).div_ceil(100).max(1);
        let mut cumulative = 0u64;
        for (b, &c) in self.buckets.iter().enumerate() {
            cumulative += c;
            if cumulative >= rank {
                return upper_bound(b);
            }
        }
        upper_bound(BUCKETS - 1)
    }

    /// Display-edge conversion of a nanosecond bound to seconds. This
    /// is the only place histogram values meet floating point.
    pub fn seconds(ns: u64) -> f64 {
        ns as f64 / 1e9
    }
}

/// A shared log2 histogram: per-bucket atomic counters a worker
/// records into without coordination. `Relaxed` ordering is enough —
/// each increment is an independent count and snapshots only run at
/// quiescent points (or tolerate being approximate mid-run, like every
/// other gauge in [`crate::coordinator::Metrics`]).
pub struct AtomicHistogram {
    buckets: [AtomicU64; BUCKETS],
}

impl Default for AtomicHistogram {
    fn default() -> Self {
        AtomicHistogram { buckets: std::array::from_fn(|_| AtomicU64::new(0)) }
    }
}

impl AtomicHistogram {
    /// An empty shared histogram.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one sample of `ns` nanoseconds.
    pub fn record(&self, ns: u64) {
        self.buckets[bucket_of(ns)].fetch_add(1, Ordering::Relaxed);
    }

    /// Copy the current counts into a plain [`LogHistogram`].
    pub fn snapshot(&self) -> LogHistogram {
        let mut h = LogHistogram::new();
        for (dst, src) in h.buckets.iter_mut().zip(self.buckets.iter()) {
            *dst = src.load(Ordering::Relaxed);
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_boundaries_are_powers_of_two() {
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(1), 1);
        assert_eq!(bucket_of(2), 2);
        assert_eq!(bucket_of(3), 2);
        assert_eq!(bucket_of(4), 3);
        assert_eq!(bucket_of(u64::MAX), 63);
        assert_eq!(upper_bound(0), 0);
        assert_eq!(upper_bound(1), 1);
        assert_eq!(upper_bound(2), 3);
        assert_eq!(upper_bound(63), u64::MAX);
    }

    #[test]
    fn percentiles_are_bucket_upper_bounds() {
        let mut h = LogHistogram::new();
        // 99 fast samples (bucket of 100ns) and one slow outlier
        for _ in 0..99 {
            h.record(100);
        }
        h.record(1_000_000);
        assert_eq!(h.count(), 100);
        assert_eq!(h.percentile_upper_ns(50), upper_bound(bucket_of(100)));
        assert_eq!(h.percentile_upper_ns(99), upper_bound(bucket_of(100)));
        assert_eq!(h.percentile_upper_ns(100), upper_bound(bucket_of(1_000_000)));
    }

    #[test]
    fn empty_histogram_reports_zero() {
        let h = LogHistogram::new();
        assert!(h.is_empty());
        assert_eq!(h.percentile_upper_ns(50), 0);
    }

    #[test]
    fn merge_is_addition() {
        let mut a = LogHistogram::new();
        let mut b = LogHistogram::new();
        a.record(10);
        a.record(1000);
        b.record(10);
        let mut merged = a.clone();
        merged.merge(&b);
        assert_eq!(merged.count(), 3);
        assert_eq!(merged.buckets()[bucket_of(10)], 2);
    }

    #[test]
    fn atomic_snapshot_matches_plain_recording() {
        let atomic = AtomicHistogram::new();
        let mut plain = LogHistogram::new();
        for ns in [0u64, 1, 7, 4096, 123_456_789] {
            atomic.record(ns);
            plain.record(ns);
        }
        assert_eq!(atomic.snapshot(), plain);
    }
}
