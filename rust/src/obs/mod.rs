//! Observability: request-scoped tracing and convergence telemetry.
//!
//! This layer makes the serving pipeline *visible* without making it
//! *nondeterministic*. The split is strict:
//!
//! - **Deterministic**: span structure (which spans exist, their
//!   parent/child shape, their names and ordering keys), and every
//!   counter attribute carried on a span (shard, fence, round index,
//!   radius, survivors, heap pushes). These are pure functions of the
//!   request stream and configuration — bitwise identical across runs.
//! - **Wall-clock**: span start/end timestamps and every latency
//!   histogram sample. These are telemetry-only measurements,
//!   quarantined inside span records and [`MetricsSnapshot`]
//!   duration fields; no result path ever reads them back.
//!
//! The quarantine is enforced three ways: the sanctioned clock
//! chokepoint ([`clock::now`]) is the only place outside the
//! measurement shells where the `wallclock-in-core` lint permits a
//! monotonic clock read; the tracing-on/off oracle tests assert
//! bitwise-identical responses with tracing enabled vs disabled; and
//! the `BENCH_PR10` gate re-checks both properties under a serving
//! sweep in CI.
//!
//! Pieces:
//!
//! - [`clock`] — the sanctioned monotonic clock read plus a seeded
//!   deterministic [`clock::MockClock`] for tests.
//! - [`hist`] — fixed-bucket log2 latency histograms with pure-integer
//!   bucket math, mergeable across workers in worker-index order.
//! - [`span`] — the span record model and the span-name taxonomy.
//! - [`trace`] — per-worker single-owner span sinks drained to
//!   length-prefixed, CRC-framed JSONL trace files.
//! - [`profile`] — the `trueknn trace` reader: span-tree
//!   reconstruction, per-stage attribution, per-shard leg skew, and
//!   the TrueKNN per-round convergence table.
//!
//! [`MetricsSnapshot`]: crate::coordinator::MetricsSnapshot

pub mod clock;
pub mod hist;
pub mod profile;
pub mod span;
pub mod trace;

pub use hist::{AtomicHistogram, LogHistogram};
pub use span::SpanRecord;
pub use trace::{SpanSink, TraceConfig, Tracing};
