//! The `trueknn trace` profiler: span-tree reconstruction and
//! aggregate reports over a serve run's trace directory.
//!
//! All aggregation is deterministic given a trace directory: records
//! are keyed and grouped through `BTreeMap`s, sums use integer
//! nanoseconds, and floating point appears only where a value is
//! inherently a measurement (radii, skew ratios at the display edge).

use std::collections::BTreeMap;

use super::span::{names, SpanRecord};
use crate::configx::Json;

/// Per-stage time attribution: every span name seen, with its count
/// and total duration.
#[derive(Debug, Clone, PartialEq)]
pub struct StageAgg {
    /// Span taxonomy name.
    pub name: String,
    /// Number of spans with this name.
    pub count: u64,
    /// Summed duration across them, in nanoseconds.
    pub total_ns: u64,
}

/// Per-shard scatter-leg load: how much leg time each shard absorbed.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardAgg {
    /// Shard index (from the leg span's `shard` attribute).
    pub shard: u64,
    /// Number of leg spans that served this shard.
    pub legs: u64,
    /// Summed leg duration, in nanoseconds.
    pub total_ns: u64,
    /// Slowest single leg, in nanoseconds.
    pub max_ns: u64,
}

/// One row of the TrueKNN convergence table: every round-`i` span in
/// the trace, aggregated.
#[derive(Debug, Clone, PartialEq)]
pub struct RoundAgg {
    /// Round index within the shell re-query loop.
    pub round: u64,
    /// Number of round spans at this index.
    pub count: u64,
    /// Smallest radius observed at this round.
    pub radius_min: f64,
    /// Largest radius observed at this round.
    pub radius_max: f64,
    /// Total queries still active entering this round.
    pub queries: u64,
    /// Total queries still unconverged after this round.
    pub survivors: u64,
    /// Total annulus heap pushes performed in this round.
    pub heap_pushes: u64,
}

/// The full profile of one trace directory.
#[derive(Debug, Clone, PartialEq)]
pub struct Profile {
    /// Verified records read.
    pub records: u64,
    /// Distinct request traces (control-only trace 0 excluded).
    pub traces: u64,
    /// True when any trace file ended in a torn frame (the verified
    /// prefix is still profiled).
    pub truncated: bool,
    /// Per-stage attribution, sorted by span name.
    pub stages: Vec<StageAgg>,
    /// Per-shard leg load, sorted by shard index.
    pub shards: Vec<ShardAgg>,
    /// Convergence table, sorted by round index.
    pub rounds: Vec<RoundAgg>,
    /// Monitor re-dispatch events observed.
    pub redispatched: u64,
    /// Cold-start recovery (snapshot rejection) events observed.
    pub recoveries: u64,
}

impl Profile {
    /// Aggregate a record set (as returned by
    /// [`read_trace_dir`](super::trace::read_trace_dir)).
    pub fn build(records: &[SpanRecord], truncated: bool) -> Profile {
        let mut stages: BTreeMap<String, StageAgg> = BTreeMap::new();
        let mut shards: BTreeMap<u64, ShardAgg> = BTreeMap::new();
        let mut rounds: BTreeMap<u64, RoundAgg> = BTreeMap::new();
        let mut traces: BTreeMap<u64, ()> = BTreeMap::new();
        let mut redispatched = 0u64;
        let mut recoveries = 0u64;
        for rec in records {
            if rec.trace != 0 {
                traces.insert(rec.trace, ());
            }
            let stage = stages.entry(rec.name.clone()).or_insert_with(|| StageAgg {
                name: rec.name.clone(),
                count: 0,
                total_ns: 0,
            });
            stage.count += 1;
            stage.total_ns += rec.duration_ns();
            match rec.name.as_str() {
                names::SHARD_LEG => {
                    let shard = rec.attr("shard").unwrap_or(-1.0) as i64;
                    if shard >= 0 {
                        let agg = shards.entry(shard as u64).or_insert_with(|| ShardAgg {
                            shard: shard as u64,
                            legs: 0,
                            total_ns: 0,
                            max_ns: 0,
                        });
                        agg.legs += 1;
                        agg.total_ns += rec.duration_ns();
                        agg.max_ns = agg.max_ns.max(rec.duration_ns());
                    }
                }
                names::ROUND => {
                    let round = rec.attr("round").unwrap_or(0.0) as u64;
                    let radius = rec.attr("radius").unwrap_or(0.0);
                    let agg = rounds.entry(round).or_insert_with(|| RoundAgg {
                        round,
                        count: 0,
                        radius_min: f64::INFINITY,
                        radius_max: f64::NEG_INFINITY,
                        queries: 0,
                        survivors: 0,
                        heap_pushes: 0,
                    });
                    agg.count += 1;
                    agg.radius_min = agg.radius_min.min(radius);
                    agg.radius_max = agg.radius_max.max(radius);
                    agg.queries += rec.attr("queries").unwrap_or(0.0) as u64;
                    agg.survivors += rec.attr("survivors").unwrap_or(0.0) as u64;
                    agg.heap_pushes += rec.attr("heap_pushes").unwrap_or(0.0) as u64;
                }
                names::REDISPATCHED => redispatched += 1,
                names::RECOVERY => recoveries += 1,
                _ => {}
            }
        }
        Profile {
            records: records.len() as u64,
            traces: traces.len() as u64,
            truncated,
            stages: stages.into_values().collect(),
            shards: shards.into_values().collect(),
            rounds: rounds.into_values().collect(),
            redispatched,
            recoveries,
        }
    }

    /// Leg skew across shards: slowest shard's total leg time divided
    /// by the fastest shard's. 1.0 means perfectly balanced; `None`
    /// with fewer than two shards.
    pub fn leg_skew(&self) -> Option<f64> {
        if self.shards.len() < 2 {
            return None;
        }
        let mut min = u64::MAX;
        let mut max = 0u64;
        for s in &self.shards {
            min = min.min(s.total_ns);
            max = max.max(s.total_ns);
        }
        if min == 0 {
            return None;
        }
        Some(max as f64 / min as f64)
    }
}

/// One node of a reconstructed span tree.
#[derive(Debug, Clone)]
pub struct SpanNode {
    /// The record at this node (the synthesized `request` root uses a
    /// zero-filled record with only name/trace/timestamps set).
    pub record: SpanRecord,
    /// Children, sorted by (start, span id).
    pub children: Vec<SpanNode>,
}

/// Reconstruct the span tree of one trace: a synthesized `request`
/// root spanning the earliest start to the latest end, with every
/// `parent = 0` record as a direct child and deeper records attached
/// by parent id. Returns `None` when the trace has no records.
pub fn span_tree(records: &[SpanRecord], trace: u64) -> Option<SpanNode> {
    let mut mine: Vec<&SpanRecord> = records.iter().filter(|r| r.trace == trace).collect();
    if mine.is_empty() {
        return None;
    }
    mine.sort_by_key(|r| (r.start_ns, r.span));
    let start = mine.iter().map(|r| r.start_ns).min().unwrap_or(0);
    let end = mine.iter().map(|r| r.end_ns).max().unwrap_or(0);
    let mut by_parent: BTreeMap<u64, Vec<&SpanRecord>> = BTreeMap::new();
    for r in &mine {
        by_parent.entry(r.parent).or_default().push(r);
    }
    fn attach(rec: &SpanRecord, by_parent: &BTreeMap<u64, Vec<&SpanRecord>>) -> SpanNode {
        let children = by_parent
            .get(&rec.span)
            .map(|kids| kids.iter().map(|k| attach(k, by_parent)).collect())
            .unwrap_or_default();
        SpanNode { record: rec.clone(), children }
    }
    let children: Vec<SpanNode> = by_parent
        .get(&0)
        .map(|tops| tops.iter().map(|r| attach(r, &by_parent)).collect())
        .unwrap_or_default();
    let root = SpanRecord {
        trace,
        span: 0,
        parent: 0,
        name: names::REQUEST.to_string(),
        worker: 0,
        start_ns: start,
        end_ns: end,
        attrs: Vec::new(),
    };
    Some(SpanNode { record: root, children })
}

/// Render one span tree as an indented text block.
pub fn render_tree(node: &SpanNode) -> String {
    let mut out = String::new();
    fn walk(node: &SpanNode, depth: usize, out: &mut String) {
        let rec = &node.record;
        let indent = "  ".repeat(depth);
        let attrs: Vec<String> =
            rec.attrs.iter().map(|(k, v)| format!("{k}={v}")).collect();
        let attrs = if attrs.is_empty() {
            String::new()
        } else {
            format!(" [{}]", attrs.join(" "))
        };
        out.push_str(&format!(
            "{indent}{} {:.3}ms (worker {}){attrs}\n",
            rec.name,
            rec.duration_ns() as f64 / 1e6,
            rec.worker,
        ));
        for child in &node.children {
            walk(child, depth + 1, out);
        }
    }
    walk(node, 0, &mut out);
    out
}

/// Render the aggregate profile as the `trueknn trace` text report.
pub fn render_text(profile: &Profile) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "trace profile: {} records across {} requests{}\n",
        profile.records,
        profile.traces,
        if profile.truncated { " (torn tail: partial)" } else { "" },
    ));
    out.push_str("\nper-stage attribution:\n");
    out.push_str(&format!(
        "  {:<14} {:>8} {:>12} {:>12}\n",
        "stage", "spans", "total ms", "mean µs"
    ));
    for s in &profile.stages {
        let mean_us = if s.count == 0 { 0.0 } else { s.total_ns as f64 / s.count as f64 / 1e3 };
        out.push_str(&format!(
            "  {:<14} {:>8} {:>12.3} {:>12.2}\n",
            s.name,
            s.count,
            s.total_ns as f64 / 1e6,
            mean_us,
        ));
    }
    if !profile.shards.is_empty() {
        out.push_str("\nper-shard leg load:\n");
        out.push_str(&format!(
            "  {:<6} {:>8} {:>12} {:>12}\n",
            "shard", "legs", "total ms", "max ms"
        ));
        for s in &profile.shards {
            out.push_str(&format!(
                "  {:<6} {:>8} {:>12.3} {:>12.3}\n",
                s.shard,
                s.legs,
                s.total_ns as f64 / 1e6,
                s.max_ns as f64 / 1e6,
            ));
        }
        if let Some(skew) = profile.leg_skew() {
            out.push_str(&format!("  leg skew (slowest/fastest shard): {skew:.2}x\n"));
        }
    }
    if !profile.rounds.is_empty() {
        out.push_str("\nTrueKNN convergence (per shell re-query round):\n");
        out.push_str(&format!(
            "  {:<6} {:>6} {:>12} {:>10} {:>10} {:>12}\n",
            "round", "spans", "radius", "queries", "survivors", "heap pushes"
        ));
        for r in &profile.rounds {
            let radius = if r.radius_min == r.radius_max {
                format!("{:.4}", r.radius_min)
            } else {
                format!("{:.3}..{:.3}", r.radius_min, r.radius_max)
            };
            out.push_str(&format!(
                "  {:<6} {:>6} {:>12} {:>10} {:>10} {:>12}\n",
                r.round, r.count, radius, r.queries, r.survivors, r.heap_pushes,
            ));
        }
    }
    if profile.redispatched > 0 || profile.recoveries > 0 {
        out.push_str(&format!(
            "\ncontrol events: {} redispatched, {} recovery\n",
            profile.redispatched, profile.recoveries,
        ));
    }
    out
}

/// Serialize the profile for `trueknn trace --json`.
pub fn to_json(profile: &Profile) -> Json {
    let stages = profile
        .stages
        .iter()
        .map(|s| {
            Json::obj(vec![
                ("name", Json::Str(s.name.clone())),
                ("count", Json::Num(s.count as f64)),
                ("total_ns", Json::Num(s.total_ns as f64)),
            ])
        })
        .collect();
    let shards = profile
        .shards
        .iter()
        .map(|s| {
            Json::obj(vec![
                ("shard", Json::Num(s.shard as f64)),
                ("legs", Json::Num(s.legs as f64)),
                ("total_ns", Json::Num(s.total_ns as f64)),
                ("max_ns", Json::Num(s.max_ns as f64)),
            ])
        })
        .collect();
    let rounds = profile
        .rounds
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("round", Json::Num(r.round as f64)),
                ("count", Json::Num(r.count as f64)),
                ("radius_min", Json::Num(r.radius_min)),
                ("radius_max", Json::Num(r.radius_max)),
                ("queries", Json::Num(r.queries as f64)),
                ("survivors", Json::Num(r.survivors as f64)),
                ("heap_pushes", Json::Num(r.heap_pushes as f64)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("records", Json::Num(profile.records as f64)),
        ("traces", Json::Num(profile.traces as f64)),
        ("truncated", Json::Bool(profile.truncated)),
        ("stages", Json::Arr(stages)),
        ("shards", Json::Arr(shards)),
        ("rounds", Json::Arr(rounds)),
        ("leg_skew", profile.leg_skew().map(Json::Num).unwrap_or(Json::Null)),
        ("redispatched", Json::Num(profile.redispatched as f64)),
        ("recoveries", Json::Num(profile.recoveries as f64)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(trace: u64, span: u64, parent: u64, name: &str, start: u64, end: u64) -> SpanRecord {
        SpanRecord {
            trace,
            span,
            parent,
            name: name.to_string(),
            worker: span >> 32,
            start_ns: start,
            end_ns: end,
            attrs: Vec::new(),
        }
    }

    fn with_attrs(mut rec: SpanRecord, attrs: &[(&str, f64)]) -> SpanRecord {
        rec.attrs = attrs.iter().map(|(k, v)| (k.to_string(), *v)).collect();
        rec
    }

    fn sample_records() -> Vec<SpanRecord> {
        let leg0 = with_attrs(
            span(1, (1 << 32) | 2, 0, names::SHARD_LEG, 100, 700),
            &[("shard", 0.0), ("fence", 3.0)],
        );
        let leg1 = with_attrs(
            span(1, (2 << 32) | 2, 0, names::SHARD_LEG, 100, 400),
            &[("shard", 1.0), ("fence", 3.0)],
        );
        let round = with_attrs(
            span(1, (1 << 32) | 3, (1 << 32) | 2, names::ROUND, 120, 300),
            &[
                ("round", 0.0),
                ("radius", 0.5),
                ("queries", 16.0),
                ("survivors", 4.0),
                ("heap_pushes", 64.0),
            ],
        );
        vec![
            span(1, (1 << 32) | 1, 0, names::QUEUE_WAIT, 0, 100),
            leg0,
            leg1,
            round,
            span(1, (2 << 32) | 3, 0, names::GATHER_MERGE, 400, 450),
        ]
    }

    #[test]
    fn profile_aggregates_stages_shards_and_rounds() {
        let p = Profile::build(&sample_records(), false);
        assert_eq!(p.records, 5);
        assert_eq!(p.traces, 1);
        let legs = p.stages.iter().find(|s| s.name == names::SHARD_LEG).unwrap();
        assert_eq!(legs.count, 2);
        assert_eq!(legs.total_ns, 600 + 300);
        assert_eq!(p.shards.len(), 2);
        assert_eq!(p.shards[0].shard, 0);
        assert_eq!(p.shards[0].total_ns, 600);
        assert_eq!(p.rounds.len(), 1);
        assert_eq!(p.rounds[0].heap_pushes, 64);
        assert_eq!(p.rounds[0].survivors, 4);
        let skew = p.leg_skew().unwrap();
        assert!((skew - 2.0).abs() < 1e-9);
    }

    #[test]
    fn span_tree_synthesizes_the_request_root() {
        let records = sample_records();
        let tree = span_tree(&records, 1).unwrap();
        assert_eq!(tree.record.name, names::REQUEST);
        assert_eq!(tree.record.start_ns, 0);
        assert_eq!(tree.record.end_ns, 700);
        // queue_wait, two legs, gather_merge at the top; the round
        // nests under leg 0
        assert_eq!(tree.children.len(), 4);
        let leg0 = tree
            .children
            .iter()
            .find(|c| c.record.name == names::SHARD_LEG && c.record.attr("shard") == Some(0.0))
            .unwrap();
        assert_eq!(leg0.children.len(), 1);
        assert_eq!(leg0.children[0].record.name, names::ROUND);
        assert!(span_tree(&records, 99).is_none());
    }

    #[test]
    fn renderers_and_json_cover_every_section() {
        let p = Profile::build(&sample_records(), true);
        let text = render_text(&p);
        assert!(text.contains("torn tail"));
        assert!(text.contains("per-stage attribution"));
        assert!(text.contains("per-shard leg load"));
        assert!(text.contains("convergence"));
        let tree = span_tree(&sample_records(), 1).unwrap();
        let rendered = render_tree(&tree);
        assert!(rendered.contains(names::REQUEST));
        assert!(rendered.contains("shard=0"));
        let j = crate::configx::parse_json(&to_json(&p).to_string()).unwrap();
        assert_eq!(j.get("records").and_then(Json::as_f64), Some(5.0));
        assert_eq!(j.get("truncated").and_then(Json::as_bool), Some(true));
    }
}
