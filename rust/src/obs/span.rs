//! Span records and the serving-stack span taxonomy.
//!
//! A span is one timed region of one request's life, keyed by the
//! request id (`trace`). Span *structure* — names, parent links,
//! counter attributes — is deterministic; span *timestamps* are
//! wall-clock telemetry quarantined inside the record (nanoseconds
//! relative to the trace epoch, never absolute time, never read back
//! by result paths).
//!
//! Span ids pack the owning worker into the high bits
//! (`worker << 32 | seq`), so ids are unique across the pool without
//! coordination and still round-trip exactly through JSON number
//! formatting (the largest id stays far below 2^53).
//!
//! See [`names`] for the taxonomy; the coordinator's module docs carry
//! the full table of which stage emits which span.

use crate::configx::Json;

/// The span-name taxonomy. Every record written by the serving stack
/// uses one of these names; the profiler groups stages by them.
pub mod names {
    /// Synthesized root: one per request, parent of everything below.
    /// Writers never emit it; the trace reader reconstructs it.
    pub const REQUEST: &str = "request";
    /// Time between a request arriving at a worker's queue and the
    /// worker starting to serve its batch.
    pub const QUEUE_WAIT: &str = "queue_wait";
    /// Replaying fenced inserts a batch is ordered after.
    pub const FENCE_CATCHUP: &str = "fence_catchup";
    /// One scatter leg of a sharded request on one worker
    /// (attrs: `shard`, `fence`, `batch`).
    pub const SHARD_LEG: &str = "shard_leg";
    /// The single-index service stage of a direct (unsharded) request.
    pub const SERVICE: &str = "service";
    /// One TrueKNN shell re-query round inside a leg or service span
    /// (attrs: `round`, `radius`, `queries`, `survivors`,
    /// `heap_pushes`).
    pub const ROUND: &str = "round";
    /// Merging one leg's partial results into a request's gather
    /// accumulator.
    pub const GATHER_MERGE: &str = "gather_merge";
    /// Handing the finished response to the reply sink.
    pub const REPLY: &str = "reply";
    /// Event: the monitor re-dispatched a stuck scatter leg
    /// (attrs: `shard`, `fence`).
    pub const REDISPATCHED: &str = "redispatched";
    /// Event: cold-start recovery rejected a corrupt snapshot and fell
    /// back to a deterministic rebuild.
    pub const RECOVERY: &str = "recovery";
}

/// Worker-id sentinel for records written by control threads (the
/// monitor, cold-start recovery) rather than a pool worker.
pub const CONTROL_WORKER: u64 = 0xFFFF;

/// One span (or zero-duration event) record.
#[derive(Debug, Clone, PartialEq)]
pub struct SpanRecord {
    /// Request id this span belongs to (0 for control events not tied
    /// to a request).
    pub trace: u64,
    /// Unique span id: `worker << 32 | seq`.
    pub span: u64,
    /// Parent span id, or 0 when the parent is the synthesized
    /// per-request root.
    pub parent: u64,
    /// Taxonomy name (see [`names`]).
    pub name: String,
    /// Worker that recorded the span ([`CONTROL_WORKER`] for control
    /// threads).
    pub worker: u64,
    /// Start, in nanoseconds since the trace epoch (wall-clock
    /// telemetry — quarantined here, never read by result paths).
    pub start_ns: u64,
    /// End, in nanoseconds since the trace epoch (same quarantine).
    pub end_ns: u64,
    /// Counter attributes: deterministic values (shard, fence, round,
    /// radius, survivors, …) keyed by name, in insertion order.
    pub attrs: Vec<(String, f64)>,
}

impl SpanRecord {
    /// Duration in nanoseconds (saturating: a torn record never
    /// underflows).
    pub fn duration_ns(&self) -> u64 {
        self.end_ns.saturating_sub(self.start_ns)
    }

    /// Look up one attribute by name.
    pub fn attr(&self, key: &str) -> Option<f64> {
        self.attrs.iter().find(|(k, _)| k == key).map(|(_, v)| *v)
    }

    /// Serialize to the canonical JSON object shape. Object keys are
    /// emitted in sorted order by the JSON layer, so the byte output
    /// is deterministic for a given record.
    pub fn to_json(&self) -> Json {
        let attrs: Vec<(&str, Json)> =
            self.attrs.iter().map(|(k, v)| (k.as_str(), Json::Num(*v))).collect();
        Json::obj(vec![
            ("trace", Json::Num(self.trace as f64)),
            ("span", Json::Num(self.span as f64)),
            ("parent", Json::Num(self.parent as f64)),
            ("name", Json::Str(self.name.clone())),
            ("worker", Json::Num(self.worker as f64)),
            ("start_ns", Json::Num(self.start_ns as f64)),
            ("end_ns", Json::Num(self.end_ns as f64)),
            ("attrs", Json::obj(attrs)),
        ])
    }

    /// Parse a record from its JSON object shape. Attributes come back
    /// sorted by key (the JSON object is ordered); missing or
    /// mistyped fields yield `None` rather than a panic — a trace file
    /// is external input.
    pub fn from_json(j: &Json) -> Option<SpanRecord> {
        let num = |key: &str| j.get(key).and_then(Json::as_f64);
        let mut attrs = Vec::new();
        if let Some(Json::Obj(map)) = j.get("attrs") {
            for (k, v) in map {
                attrs.push((k.clone(), v.as_f64()?));
            }
        }
        Some(SpanRecord {
            trace: num("trace")? as u64,
            span: num("span")? as u64,
            parent: num("parent")? as u64,
            name: j.get("name")?.as_str()?.to_string(),
            worker: num("worker")? as u64,
            start_ns: num("start_ns")? as u64,
            end_ns: num("end_ns")? as u64,
            attrs,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> SpanRecord {
        SpanRecord {
            trace: 7,
            span: (3u64 << 32) | 12,
            parent: (3u64 << 32) | 11,
            name: names::SHARD_LEG.to_string(),
            worker: 3,
            start_ns: 1_000,
            end_ns: 5_500,
            attrs: vec![("fence".to_string(), 9.0), ("shard".to_string(), 2.0)],
        }
    }

    #[test]
    fn json_round_trip_is_exact() {
        let rec = sample();
        let j = crate::configx::parse_json(&rec.to_json().to_string()).unwrap();
        let back = SpanRecord::from_json(&j).unwrap();
        assert_eq!(back, rec);
    }

    #[test]
    fn duration_saturates_and_attrs_resolve() {
        let mut rec = sample();
        assert_eq!(rec.duration_ns(), 4_500);
        assert_eq!(rec.attr("shard"), Some(2.0));
        assert_eq!(rec.attr("missing"), None);
        rec.end_ns = 0;
        assert_eq!(rec.duration_ns(), 0);
    }

    #[test]
    fn malformed_json_is_none_not_a_panic() {
        let j = crate::configx::parse_json(r#"{"trace": 1, "name": "x"}"#).unwrap();
        assert!(SpanRecord::from_json(&j).is_none());
    }
}
