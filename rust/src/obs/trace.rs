//! Trace capture: per-worker span sinks and CRC-framed trace files.
//!
//! Each pool worker owns exactly one [`SpanSink`] — a single-owner
//! ring buffer that is lock-free by construction (only its worker
//! thread ever touches it; the crate forbids `unsafe`, so exclusive
//! `&mut` ownership *is* the synchronization). Control threads (the
//! monitor, cold-start recovery) share one sink behind a mutex since
//! their event rate is a handful per run.
//!
//! A full ring drains to disk as one appended batch of frames. The
//! on-disk format reuses the persist codec's framing discipline: each
//! record is
//!
//! ```text
//!   len   u32  (byte length of the JSON line, excluding newline)
//!   crc   u32  (crc32 of the JSON line bytes)
//!   json  len bytes (one compact JSON object, sorted keys)
//!   '\n'  1 byte (keeps the file greppable as JSONL)
//! ```
//!
//! so a reader can both stream it as JSONL *and* verify every record
//! against torn writes — a crashed worker leaves at most one partial
//! frame at the tail, which the CRC check isolates without poisoning
//! the records before it.
//!
//! Trace I/O failures never propagate into serving: a failed flush is
//! counted, logged, and dropped — observability must not become an
//! availability dependency.

use std::path::{Path, PathBuf};
use std::time::Instant;

use super::span::{SpanRecord, CONTROL_WORKER};
use crate::persist::{crc32, Dec, Enc, PersistError};

/// Default ring capacity (records buffered per worker before a drain).
const DEFAULT_RING: usize = 1024;

/// Serving-stack tracing configuration ([`ServiceConfig::trace`]).
///
/// [`ServiceConfig::trace`]: crate::coordinator::ServiceConfig
#[derive(Debug, Clone)]
pub struct TraceConfig {
    /// Directory trace files are written under (created if absent).
    pub dir: PathBuf,
    /// Records buffered per worker between drains.
    pub ring_capacity: usize,
}

impl TraceConfig {
    /// Tracing into `dir` with the default ring capacity.
    pub fn new(dir: impl Into<PathBuf>) -> Self {
        TraceConfig { dir: dir.into(), ring_capacity: DEFAULT_RING }
    }
}

/// A live tracing session: the shared epoch every sink stamps
/// timestamps against, plus the factory for per-worker sinks.
pub struct Tracing {
    dir: PathBuf,
    epoch: Instant,
    ring_capacity: usize,
}

impl Tracing {
    /// Start a session: create the trace directory and fix the epoch.
    /// Fails only on directory-creation I/O errors; callers treat that
    /// like a disabled persistence layer (warn and serve untraced).
    pub fn create(cfg: &TraceConfig) -> Result<Tracing, std::io::Error> {
        std::fs::create_dir_all(&cfg.dir)?;
        Ok(Tracing {
            dir: cfg.dir.clone(),
            epoch: super::clock::now(),
            ring_capacity: cfg.ring_capacity.max(1),
        })
    }

    /// The sink for pool worker `worker`, writing
    /// `trace-worker-{worker}.jsonl`.
    pub fn worker(&self, worker: usize) -> SpanSink {
        SpanSink::new(
            worker as u64,
            self.epoch,
            self.dir.join(format!("trace-worker-{worker}.jsonl")),
            self.ring_capacity,
        )
    }

    /// The shared control sink (monitor re-dispatches, recovery
    /// events), writing `trace-control.jsonl`.
    pub fn control(&self) -> SpanSink {
        SpanSink::new(
            CONTROL_WORKER,
            self.epoch,
            self.dir.join("trace-control.jsonl"),
            self.ring_capacity,
        )
    }
}

/// A single-owner span buffer draining to one trace file.
pub struct SpanSink {
    worker: u64,
    epoch: Instant,
    seq: u64,
    ring: Vec<SpanRecord>,
    ring_capacity: usize,
    path: PathBuf,
    io_errors: u64,
}

impl SpanSink {
    fn new(worker: u64, epoch: Instant, path: PathBuf, ring_capacity: usize) -> Self {
        SpanSink {
            worker,
            epoch,
            seq: 0,
            ring: Vec::with_capacity(ring_capacity),
            ring_capacity,
            path,
            io_errors: 0,
        }
    }

    /// The worker id this sink stamps on its records.
    pub fn worker(&self) -> u64 {
        self.worker
    }

    /// Allocate the next span id (`worker << 32 | seq`): unique across
    /// the pool without coordination, and survives worker restarts
    /// because the sink lives in the supervisor-owned worker context.
    pub fn next_id(&mut self) -> u64 {
        self.seq += 1;
        (self.worker << 32) | (self.seq & 0xFFFF_FFFF)
    }

    /// Nanoseconds from the session epoch to `t` (zero if `t` somehow
    /// precedes the epoch).
    pub fn ns_since_epoch(&self, t: Instant) -> u64 {
        t.duration_since(self.epoch).as_nanos() as u64
    }

    /// Current telemetry time, as nanoseconds since the session epoch.
    pub fn now_ns(&self) -> u64 {
        self.ns_since_epoch(super::clock::now())
    }

    /// Buffer one record, draining the ring to disk when full.
    pub fn push(&mut self, rec: SpanRecord) {
        self.ring.push(rec);
        if self.ring.len() >= self.ring_capacity {
            self.flush();
        }
    }

    /// Buffer a zero-duration event with the given attributes.
    pub fn event(&mut self, trace: u64, name: &str, attrs: Vec<(String, f64)>) {
        let now = self.now_ns();
        let span = self.next_id();
        let worker = self.worker;
        self.push(SpanRecord {
            trace,
            span,
            parent: 0,
            name: name.to_string(),
            worker,
            start_ns: now,
            end_ns: now,
            attrs,
        });
    }

    /// Drain buffered records to the trace file. I/O failures are
    /// counted and logged, never propagated — tracing must not take
    /// the serving path down with it.
    pub fn flush(&mut self) {
        if self.ring.is_empty() {
            return;
        }
        let mut enc = Enc::new();
        for rec in &self.ring {
            let line = rec.to_json().to_string();
            enc.put_u32(line.len() as u32);
            enc.put_u32(crc32(line.as_bytes()));
            enc.put_bytes(line.as_bytes());
            enc.put_u8(b'\n');
        }
        let bytes = enc.into_bytes();
        let res = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&self.path)
            .and_then(|mut f| std::io::Write::write_all(&mut f, &bytes));
        if let Err(e) = res {
            self.io_errors += 1;
            crate::log_warn!("trace flush to {} failed: {e}", self.path.display());
        }
        self.ring.clear();
    }

    /// Flushes that failed on I/O (each one dropped a ring's records).
    pub fn io_errors(&self) -> u64 {
        self.io_errors
    }
}

/// Decode one trace file's frames. Records after a torn or corrupt
/// frame are unreachable (framing is sequential), so decoding stops
/// there: the successfully verified prefix comes back along with
/// `truncated = true`. A missing file is an error; an empty file is an
/// empty, non-truncated trace.
pub fn read_frames(bytes: &[u8]) -> (Vec<SpanRecord>, bool) {
    let mut dec = Dec::new(bytes);
    let mut records = Vec::new();
    while !dec.finished() {
        match read_one(&mut dec) {
            Ok(Some(rec)) => records.push(rec),
            Ok(None) => continue,
            Err(_) => return (records, true),
        }
    }
    (records, false)
}

/// One frame: length, CRC, JSON payload, newline. `Ok(None)` means the
/// frame verified but its JSON no longer parses as a span record
/// (e.g. a newer writer) — skippable, unlike a CRC failure.
fn read_one(dec: &mut Dec<'_>) -> Result<Option<SpanRecord>, PersistError> {
    let len = dec.get_u32()? as usize;
    let crc = dec.get_u32()?;
    let payload = dec.get_bytes(len)?;
    if crc32(payload) != crc {
        return Err(PersistError::Corrupt {
            what: "trace frame",
            detail: format!("crc mismatch in a {len}-byte frame"),
        });
    }
    let newline = dec.get_u8()?;
    if newline != b'\n' {
        return Err(PersistError::Corrupt {
            what: "trace frame",
            detail: "missing newline terminator".to_string(),
        });
    }
    let text = match std::str::from_utf8(payload) {
        Ok(t) => t,
        Err(_) => return Ok(None),
    };
    let parsed = match crate::configx::parse_json(text) {
        Ok(j) => j,
        Err(_) => return Ok(None),
    };
    Ok(SpanRecord::from_json(&parsed))
}

/// Read and verify every frame of one trace file.
pub fn read_trace_file(path: &Path) -> Result<(Vec<SpanRecord>, bool), String> {
    let bytes = std::fs::read(path)
        .map_err(|e| format!("reading trace file {}: {e}", path.display()))?;
    Ok(read_frames(&bytes))
}

/// Read every `trace-*.jsonl` file under `dir`, in sorted filename
/// order (worker files first by index, then the control file), and
/// return all verified records plus whether any file had a torn tail.
pub fn read_trace_dir(dir: &Path) -> Result<(Vec<SpanRecord>, bool), String> {
    let entries = std::fs::read_dir(dir)
        .map_err(|e| format!("reading trace dir {}: {e}", dir.display()))?;
    let mut paths: Vec<PathBuf> = Vec::new();
    for entry in entries {
        let entry = entry.map_err(|e| format!("listing trace dir {}: {e}", dir.display()))?;
        let path = entry.path();
        let is_trace = path
            .file_name()
            .and_then(|n| n.to_str())
            .is_some_and(|n| n.starts_with("trace-") && n.ends_with(".jsonl"));
        if is_trace {
            paths.push(path);
        }
    }
    paths.sort();
    let mut records = Vec::new();
    let mut truncated = false;
    for path in &paths {
        let (mut recs, torn) = read_trace_file(path)?;
        records.append(&mut recs);
        truncated = truncated || torn;
    }
    Ok((records, truncated))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::span::names;

    fn temp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("trueknn-trace-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn rec(sink: &mut SpanSink, trace: u64, name: &str) -> SpanRecord {
        let span = sink.next_id();
        SpanRecord {
            trace,
            span,
            parent: 0,
            name: name.to_string(),
            worker: sink.worker(),
            start_ns: 10 * span,
            end_ns: 10 * span + 5,
            attrs: vec![("shard".to_string(), 1.0)],
        }
    }

    #[test]
    fn frames_round_trip_through_the_file() {
        let dir = temp_dir("roundtrip");
        let tracing = Tracing::create(&TraceConfig::new(&dir)).unwrap();
        let mut sink = tracing.worker(3);
        let a = rec(&mut sink, 1, names::QUEUE_WAIT);
        let b = rec(&mut sink, 1, names::SHARD_LEG);
        sink.push(a.clone());
        sink.push(b.clone());
        sink.flush();
        let (records, truncated) =
            read_trace_file(&dir.join("trace-worker-3.jsonl")).unwrap();
        assert!(!truncated);
        assert_eq!(records, vec![a, b]);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn ring_drains_at_capacity_without_explicit_flush() {
        let dir = temp_dir("ring");
        let cfg = TraceConfig { dir: dir.clone(), ring_capacity: 2 };
        let tracing = Tracing::create(&cfg).unwrap();
        let mut sink = tracing.worker(0);
        let a = rec(&mut sink, 1, names::REPLY);
        let b = rec(&mut sink, 2, names::REPLY);
        sink.push(a);
        sink.push(b);
        // capacity reached: the ring drained itself
        let (records, _) = read_trace_file(&dir.join("trace-worker-0.jsonl")).unwrap();
        assert_eq!(records.len(), 2);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn torn_tail_preserves_the_verified_prefix() {
        let dir = temp_dir("torn");
        let tracing = Tracing::create(&TraceConfig::new(&dir)).unwrap();
        let mut sink = tracing.worker(0);
        let a = rec(&mut sink, 1, names::REPLY);
        let b = rec(&mut sink, 2, names::REPLY);
        sink.push(a.clone());
        sink.push(b);
        sink.flush();
        let path = dir.join("trace-worker-0.jsonl");
        let bytes = std::fs::read(&path).unwrap();
        // tear the file mid-way through the second frame
        let (records, truncated) = read_frames(&bytes[..bytes.len() - 3]);
        assert!(truncated);
        assert_eq!(records, vec![a]);
        // flip one payload byte in the first frame: its crc fails and
        // nothing survives
        let mut corrupt = bytes.clone();
        corrupt[10] ^= 0x01;
        let (records, truncated) = read_frames(&corrupt);
        assert!(truncated);
        assert!(records.is_empty());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn dir_reader_collects_worker_and_control_files() {
        let dir = temp_dir("dir");
        let tracing = Tracing::create(&TraceConfig::new(&dir)).unwrap();
        let mut w0 = tracing.worker(0);
        let mut ctl = tracing.control();
        let a = rec(&mut w0, 1, names::REPLY);
        w0.push(a);
        ctl.event(1, names::REDISPATCHED, vec![("shard".to_string(), 0.0)]);
        w0.flush();
        ctl.flush();
        let (records, truncated) = read_trace_dir(&dir).unwrap();
        assert!(!truncated);
        assert_eq!(records.len(), 2);
        assert!(records.iter().any(|r| r.name == names::REDISPATCHED
            && r.worker == crate::obs::span::CONTROL_WORKER));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn span_ids_pack_worker_and_sequence() {
        let dir = temp_dir("ids");
        let tracing = Tracing::create(&TraceConfig::new(&dir)).unwrap();
        let mut sink = tracing.worker(5);
        assert_eq!(sink.next_id(), (5u64 << 32) | 1);
        assert_eq!(sink.next_id(), (5u64 << 32) | 2);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
