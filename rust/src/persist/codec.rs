//! Little-endian byte codec for the snapshot and WAL payloads: a
//! growing encoder ([`Enc`]) and a bounds-checked cursor decoder
//! ([`Dec`]). All multi-byte values are little-endian; floats travel as
//! their IEEE-754 bit patterns, so a round trip is bitwise exact
//! (including NaN payloads and signed zeros).

use super::PersistError;

/// Append-only little-endian encoder over a growing byte buffer.
#[derive(Default)]
pub struct Enc {
    buf: Vec<u8>,
}

impl Enc {
    /// An empty encoder.
    pub fn new() -> Self {
        Self::default()
    }

    /// Append one byte.
    pub fn put_u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    /// Append a `u32` (little-endian).
    pub fn put_u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Append a `u64` (little-endian).
    pub fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Append an `f32` as its bit pattern (bitwise-exact round trip).
    pub fn put_f32(&mut self, v: f32) {
        self.put_u32(v.to_bits());
    }

    /// Append an `f64` as its bit pattern (bitwise-exact round trip).
    pub fn put_f64(&mut self, v: f64) {
        self.put_u64(v.to_bits());
    }

    /// Append a collection length as a `u64` (usize widths differ
    /// across hosts; a snapshot must not).
    pub fn put_len(&mut self, n: usize) {
        self.put_u64(n as u64);
    }

    /// Append raw bytes verbatim (no length prefix).
    pub fn put_bytes(&mut self, b: &[u8]) {
        self.buf.extend_from_slice(b);
    }

    /// Bytes encoded so far.
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    /// True when nothing has been encoded.
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Consume the encoder, yielding its buffer.
    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }
}

/// Bounds-checked little-endian cursor over a byte slice. Every getter
/// returns [`PersistError::Corrupt`] instead of panicking when the
/// slice runs out — a truncated payload is a data problem, not a bug.
pub struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    /// A cursor at the start of `buf`.
    pub fn new(buf: &'a [u8]) -> Self {
        Dec { buf, pos: 0 }
    }

    /// Bytes not yet consumed.
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Current cursor offset from the start of the buffer. Decoders
    /// embed this in corruption diagnostics so an operator can see
    /// *where* in a payload a parse failed, not just that it did.
    pub fn position(&self) -> usize {
        self.pos
    }

    /// True when every byte has been consumed (decoders check this to
    /// reject payloads with trailing garbage).
    pub fn finished(&self) -> bool {
        self.remaining() == 0
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], PersistError> {
        if self.remaining() < n {
            return Err(PersistError::Corrupt {
                what: "payload",
                detail: format!(
                    "needed {n} bytes at offset {}, only {} remain",
                    self.pos,
                    self.remaining()
                ),
            });
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    /// Read one byte.
    pub fn get_u8(&mut self) -> Result<u8, PersistError> {
        Ok(self.take(1)?[0])
    }

    /// Read a `u32` (little-endian).
    pub fn get_u32(&mut self) -> Result<u32, PersistError> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    /// Read a `u64` (little-endian).
    pub fn get_u64(&mut self) -> Result<u64, PersistError> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    /// Read an `f32` from its bit pattern.
    pub fn get_f32(&mut self) -> Result<f32, PersistError> {
        Ok(f32::from_bits(self.get_u32()?))
    }

    /// Read an `f64` from its bit pattern.
    pub fn get_f64(&mut self) -> Result<f64, PersistError> {
        Ok(f64::from_bits(self.get_u64()?))
    }

    /// Read a collection length written by [`Enc::put_len`], checked
    /// against both `usize` range and the bytes actually remaining (an
    /// element is at least one byte, so a length beyond `remaining` is
    /// structurally impossible and rejected before any allocation).
    pub fn get_len(&mut self) -> Result<usize, PersistError> {
        let v = self.get_u64()?;
        let n = usize::try_from(v).map_err(|_| PersistError::Corrupt {
            what: "length",
            detail: format!("{v} overflows usize"),
        })?;
        if n > self.remaining() {
            return Err(PersistError::Corrupt {
                what: "length",
                detail: format!("{n} elements with only {} bytes left", self.remaining()),
            });
        }
        Ok(n)
    }

    /// Read `n` raw bytes.
    pub fn get_bytes(&mut self, n: usize) -> Result<&'a [u8], PersistError> {
        self.take(n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_is_bitwise_exact() {
        let mut e = Enc::new();
        e.put_u8(7);
        e.put_u32(0xDEAD_BEEF);
        e.put_u64(u64::MAX - 1);
        e.put_f32(-0.0);
        e.put_f32(f32::NAN);
        e.put_f64(std::f64::consts::PI);
        e.put_len(3);
        e.put_bytes(b"xyz");
        let bytes = e.into_bytes();
        let mut d = Dec::new(&bytes);
        assert_eq!(d.get_u8().unwrap(), 7);
        assert_eq!(d.get_u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(d.get_u64().unwrap(), u64::MAX - 1);
        assert_eq!(d.get_f32().unwrap().to_bits(), (-0.0f32).to_bits());
        assert!(d.get_f32().unwrap().is_nan());
        assert_eq!(d.get_f64().unwrap(), std::f64::consts::PI);
        let n = d.get_len().unwrap();
        assert_eq!(d.get_bytes(n).unwrap(), b"xyz");
        assert!(d.finished());
    }

    #[test]
    fn truncated_reads_are_typed_errors_not_panics() {
        let bytes = [1u8, 2, 3];
        let mut d = Dec::new(&bytes);
        assert!(d.get_u32().is_err());
        // the failed read consumed nothing
        assert_eq!(d.remaining(), 3);
        assert_eq!(d.get_u8().unwrap(), 1);
    }

    #[test]
    fn absurd_lengths_are_rejected_before_allocation() {
        let mut e = Enc::new();
        e.put_u64(u64::MAX);
        let bytes = e.into_bytes();
        assert!(matches!(
            Dec::new(&bytes).get_len(),
            Err(PersistError::Corrupt { what: "length", .. })
        ));
        let mut e = Enc::new();
        e.put_len(10); // 10 "elements" but zero payload bytes follow
        let bytes = e.into_bytes();
        assert!(Dec::new(&bytes).get_len().is_err());
    }
}
