//! Crash-safe persistence: checksummed index snapshots and the durable
//! insert write-ahead log (std-only).
//!
//! This layer exists so a crashed or redeployed service recovers to a
//! **bitwise-identical** serving state without re-building acceleration
//! structures from raw points (the cost the paper's whole amortization
//! argument is about). Two artifacts, two trust models:
//!
//! - **Snapshots** ([`snapshot`]) — one contiguous `TKSN` container per
//!   built index: magic + format version + config fingerprint +
//!   sequence watermark + an offset-table manifest over checksummed
//!   sections, closed by a whole-file CRC32. The arena `Vec`s inside an
//!   index are already contiguous deterministic-preorder layouts, so a
//!   load is a sequential read + reconstruction, not a rebuild.
//!   Snapshots are written via temp-file + fsync + atomic rename
//!   ([`atomic_write`]) and are **never partially trusted**: any
//!   checksum, version, or fingerprint mismatch rejects the whole file
//!   and the caller falls back to a deterministic rebuild.
//! - **The WAL** ([`wal`]) — an append-only log of every accepted
//!   insert, written *before* the in-memory broadcast. Records are
//!   length-prefixed, checksummed, and carry a contiguous sequence
//!   number; a torn tail (crash mid-append) is detected and truncated
//!   on open. The snapshot's watermark fences replay: records past it
//!   are re-applied in sequence order, records at or below it are
//!   already inside the snapshot.
//!
//! Integrity primitives are std-only: [`crc32`] (IEEE, const-generated
//! table) for payload checksums and [`Fnv64`] for the config
//! fingerprint. Seeded I/O faults ([`crate::faults::IoFault`]) are
//! applied *inside* [`atomic_write`] / [`read_file`] / the WAL append,
//! so torn-write/short-read/flip-a-byte scenarios corrupt exactly the
//! bytes a real fault would.
//!
//! Everything here propagates [`PersistError`]; the `io-unwrap-in-persist`
//! lint rule statically rejects `unwrap`/`expect` on I/O results in this
//! module and the coordinator's recovery paths.

mod codec;
/// The versioned, checksummed snapshot container (`TKSN` blobs).
pub mod snapshot;
/// The durable, length-prefixed, checksummed insert log.
pub mod wal;

pub use codec::{Dec, Enc};
pub use snapshot::{Snapshot, SnapshotWriter, FORMAT_VERSION, SEC_INDEX, SEC_PARTITION};
pub use wal::{Wal, WalRecord};

use crate::faults::{FaultPlan, IoTarget};
use std::fs::{self, File};
use std::io::Write;
use std::path::Path;

/// Why a persistence operation failed: an I/O error on a named
/// operation, or a trust failure (corruption, stale format, foreign
/// config) that must send the caller down the rebuild path.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum PersistError {
    /// The underlying filesystem operation failed.
    Io {
        /// Which operation (`"create"`, `"write"`, `"sync"`, …).
        op: &'static str,
        /// The OS error, stringified (kept `Clone`/`Eq` for the
        /// coordinator's typed-error plumbing).
        detail: String,
    },
    /// The bytes failed structural validation or a checksum.
    Corrupt {
        /// Which structure was being decoded.
        what: &'static str,
        /// Human-readable mismatch description.
        detail: String,
    },
    /// The container was written by a different format version.
    VersionMismatch {
        /// Version found in the file.
        found: u32,
        /// Version this build reads.
        expected: u32,
    },
    /// The container was built under a different result-affecting
    /// configuration (backend or `IndexConfig` fields).
    FingerprintMismatch {
        /// Fingerprint found in the file.
        found: u64,
        /// Fingerprint of the loading configuration.
        expected: u64,
    },
}

impl std::fmt::Display for PersistError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PersistError::Io { op, detail } => write!(f, "persist i/o failure in {op}: {detail}"),
            PersistError::Corrupt { what, detail } => {
                write!(f, "corrupt {what}: {detail}")
            }
            PersistError::VersionMismatch { found, expected } => {
                write!(f, "snapshot format version {found} (this build reads {expected})")
            }
            PersistError::FingerprintMismatch { found, expected } => {
                write!(
                    f,
                    "snapshot config fingerprint {found:#018x} does not match {expected:#018x}"
                )
            }
        }
    }
}

impl std::error::Error for PersistError {}

/// Wrap an [`std::io::Error`] with the operation it interrupted.
pub(crate) fn io_err(op: &'static str, e: std::io::Error) -> PersistError {
    PersistError::Io { op, detail: e.to_string() }
}

const fn crc32_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static CRC32_TABLE: [u32; 256] = crc32_table();

/// CRC32 (IEEE 802.3 polynomial) over `bytes` — the per-section and
/// whole-file checksum of the snapshot container and the per-record
/// checksum of the WAL.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = CRC32_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    !c
}

/// Incremental FNV-1a (64-bit) hasher: the config fingerprint that
/// fences a snapshot to the exact result-affecting configuration it was
/// built under.
#[derive(Clone, Copy, Debug)]
pub struct Fnv64(u64);

impl Default for Fnv64 {
    fn default() -> Self {
        Self::new()
    }
}

impl Fnv64 {
    /// A hasher at the FNV-1a offset basis.
    pub fn new() -> Self {
        Fnv64(0xcbf2_9ce4_8422_2325)
    }

    /// Fold `bytes` into the hash.
    pub fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x100_0000_01b3);
        }
    }

    /// Fold a `u64` (little-endian bytes) into the hash.
    pub fn write_u64(&mut self, v: u64) {
        self.write(&v.to_le_bytes());
    }

    /// Fold an `f32` (bit pattern) into the hash.
    pub fn write_f32(&mut self, v: f32) {
        self.write(&v.to_bits().to_le_bytes());
    }

    /// The current hash value.
    pub fn finish(&self) -> u64 {
        self.0
    }
}

/// Write `bytes` to `path` crash-safely: temp file in the same
/// directory, `write_all` + `sync_all`, then atomic rename over the
/// destination. Scheduled faults for `target` are applied to the bytes
/// first (a flipped byte, then a torn truncation at write op `op`) —
/// simulating a non-atomic storage layer so the *reader's* corruption
/// detection can be exercised end to end.
pub fn atomic_write(
    path: &Path,
    bytes: &[u8],
    faults: &FaultPlan,
    target: IoTarget,
    op: u64,
) -> Result<(), PersistError> {
    let mut corrupted: Vec<u8>;
    let mut data: &[u8] = bytes;
    if faults.flip_byte(target).is_some() || faults.torn_write(target, op).is_some() {
        corrupted = bytes.to_vec();
        if let Some(at) = faults.flip_byte(target) {
            if !corrupted.is_empty() {
                let i = at % corrupted.len();
                corrupted[i] ^= 0x01;
            }
        }
        if let Some(keep) = faults.torn_write(target, op) {
            corrupted.truncate(keep);
        }
        data = &corrupted;
    }
    let tmp = tmp_sibling(path);
    let mut f = File::create(&tmp).map_err(|e| io_err("create", e))?;
    f.write_all(data).map_err(|e| io_err("write", e))?;
    f.sync_all().map_err(|e| io_err("sync", e))?;
    drop(f);
    fs::rename(&tmp, path).map_err(|e| io_err("rename", e))?;
    // best-effort directory sync: the rename is durable on its own for
    // the contents; losing the *name* on power loss degrades to the
    // rebuild path, which is always correct
    if let Some(dir) = path.parent() {
        if let Ok(d) = File::open(dir) {
            let _ = d.sync_all();
        }
    }
    Ok(())
}

/// The temp-file name `atomic_write` stages under: a `.tmp`-suffixed
/// sibling (same directory, so the rename is atomic on every sane
/// filesystem). One writer per path by construction — each snapshot
/// path is owned by exactly one worker.
fn tmp_sibling(path: &Path) -> std::path::PathBuf {
    let mut name = path.file_name().map(|n| n.to_os_string()).unwrap_or_default();
    name.push(".tmp");
    path.with_file_name(name)
}

/// Read a whole file, applying any scheduled short-read fault for
/// `target` (the returned bytes are truncated to the fault's `keep`).
pub fn read_file(path: &Path, faults: &FaultPlan, target: IoTarget) -> Result<Vec<u8>, PersistError> {
    let mut bytes = fs::read(path).map_err(|e| io_err("read", e))?;
    if let Some(keep) = faults.short_read(target) {
        bytes.truncate(keep);
    }
    Ok(bytes)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_matches_known_vectors() {
        // the standard IEEE check values
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
    }

    #[test]
    fn crc32_detects_any_single_byte_change() {
        let data: Vec<u8> = (0..255u8).collect();
        let base = crc32(&data);
        for i in 0..data.len() {
            let mut mutated = data.clone();
            mutated[i] ^= 0x01;
            assert_ne!(crc32(&mutated), base, "flip at {i} went undetected");
        }
    }

    #[test]
    fn fnv64_is_order_sensitive_and_stable() {
        let mut a = Fnv64::new();
        a.write(b"ab");
        let mut b = Fnv64::new();
        b.write(b"ba");
        assert_ne!(a.finish(), b.finish());
        // FNV-1a 64 reference value for "a"
        let mut c = Fnv64::new();
        c.write(b"a");
        assert_eq!(c.finish(), 0xaf63_dc4c_8601_ec8c);
    }

    #[test]
    fn atomic_write_round_trips_and_replaces() {
        let dir = std::env::temp_dir()
            .join(format!("trueknn-persist-unit-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("blob.bin");
        let inert = FaultPlan::inert();
        atomic_write(&path, b"first", &inert, IoTarget::Snapshot, 1).unwrap();
        assert_eq!(read_file(&path, &inert, IoTarget::Snapshot).unwrap(), b"first");
        atomic_write(&path, b"second", &inert, IoTarget::Snapshot, 2).unwrap();
        assert_eq!(read_file(&path, &inert, IoTarget::Snapshot).unwrap(), b"second");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn io_faults_corrupt_writes_and_reads() {
        let dir = std::env::temp_dir()
            .join(format!("trueknn-persist-faults-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("blob.bin");
        let inert = FaultPlan::inert();
        // torn write keeps a prefix
        let torn = FaultPlan::inert().with_torn_write(IoTarget::Snapshot, 1, 3);
        atomic_write(&path, b"abcdef", &torn, IoTarget::Snapshot, 1).unwrap();
        assert_eq!(read_file(&path, &inert, IoTarget::Snapshot).unwrap(), b"abc");
        // ...but only at its scheduled op
        atomic_write(&path, b"abcdef", &torn, IoTarget::Snapshot, 2).unwrap();
        assert_eq!(read_file(&path, &inert, IoTarget::Snapshot).unwrap(), b"abcdef");
        // flipped byte lands in the file
        let flip = FaultPlan::inert().with_flip_byte(IoTarget::Snapshot, 1);
        atomic_write(&path, b"abcdef", &flip, IoTarget::Snapshot, 1).unwrap();
        assert_eq!(read_file(&path, &inert, IoTarget::Snapshot).unwrap(), b"accdef");
        // short read truncates without touching the file
        atomic_write(&path, b"abcdef", &inert, IoTarget::Snapshot, 1).unwrap();
        let short = FaultPlan::inert().with_short_read(IoTarget::Snapshot, 2);
        assert_eq!(read_file(&path, &short, IoTarget::Snapshot).unwrap(), b"ab");
        assert_eq!(read_file(&path, &inert, IoTarget::Snapshot).unwrap(), b"abcdef");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
