//! The `TKSN` snapshot container: one contiguous, versioned,
//! checksummed blob per built index.
//!
//! Layout (all little-endian):
//!
//! ```text
//!   magic      "TKSN"                        4 bytes
//!   version    u32  = FORMAT_VERSION
//!   fingerprint u64  (config fingerprint, see IndexBuilder)
//!   watermark  u64  (WAL sequence fence: records ≤ watermark are
//!                    inside this snapshot; replay starts past it)
//!   n_sections u32
//!   table      n_sections × { kind u32, offset u64, len u64, crc u32 }
//!   payloads   section bytes at their recorded offsets
//!   footer     u32  = crc32(everything before the footer)
//! ```
//!
//! Trust model: a reader verifies the whole-file CRC **first** (any
//! single flipped byte anywhere — header, table, payload, or footer —
//! fails here), then magic, version, table bounds, and every section's
//! own CRC. A file is either fully trusted or fully rejected; there is
//! no partial load.

use super::codec::{Dec, Enc};
use super::{crc32, PersistError};

/// Snapshot container format version this build writes and reads.
pub const FORMAT_VERSION: u32 = 1;

/// Section kind: the serialized index payload (backend tag + config +
/// backend-specific arenas).
pub const SEC_INDEX: u32 = 1;

/// Section kind: a serialized [`crate::shard::Partition`] (shipped
/// separately so rebalance can hand pre-built shard membership around).
pub const SEC_PARTITION: u32 = 2;

const MAGIC: &[u8; 4] = b"TKSN";

/// Human-readable name of a section kind, used in corruption
/// diagnostics so `trueknn snapshot --check` and the serve recovery
/// log name the failing section instead of only counting the failure.
pub fn section_name(kind: u32) -> &'static str {
    match kind {
        SEC_INDEX => "index",
        SEC_PARTITION => "partition",
        _ => "unknown",
    }
}

/// Builder for a `TKSN` container: collect sections, then
/// [`SnapshotWriter::finish`] into the final checksummed blob.
pub struct SnapshotWriter {
    fingerprint: u64,
    watermark: u64,
    sections: Vec<(u32, Vec<u8>)>,
}

impl SnapshotWriter {
    /// A writer for a snapshot fenced to `fingerprint` (the builder
    /// config) and `watermark` (the highest WAL sequence number whose
    /// insert is reflected in the payload).
    pub fn new(fingerprint: u64, watermark: u64) -> Self {
        SnapshotWriter { fingerprint, watermark, sections: Vec::new() }
    }

    /// Append one section. Sections keep their insertion order.
    pub fn section(&mut self, kind: u32, payload: Vec<u8>) {
        self.sections.push((kind, payload));
    }

    /// Assemble the container: header, offset table, payloads,
    /// whole-file CRC footer.
    pub fn finish(self) -> Vec<u8> {
        let header_len = 4 + 4 + 8 + 8 + 4 + self.sections.len() * 24;
        let mut enc = Enc::new();
        enc.put_bytes(MAGIC);
        enc.put_u32(FORMAT_VERSION);
        enc.put_u64(self.fingerprint);
        enc.put_u64(self.watermark);
        enc.put_u32(self.sections.len() as u32);
        let mut offset = header_len as u64;
        for (kind, payload) in &self.sections {
            enc.put_u32(*kind);
            enc.put_u64(offset);
            enc.put_u64(payload.len() as u64);
            enc.put_u32(crc32(payload));
            offset += payload.len() as u64;
        }
        for (_, payload) in &self.sections {
            enc.put_bytes(payload);
        }
        let mut bytes = enc.into_bytes();
        let footer = crc32(&bytes);
        bytes.extend_from_slice(&footer.to_le_bytes());
        bytes
    }
}

/// One verified section of a parsed snapshot.
pub struct SnapshotSection {
    /// Section kind (`SEC_*`).
    pub kind: u32,
    /// The section's payload, CRC-verified.
    pub payload: Vec<u8>,
}

/// A fully verified `TKSN` container. Constructing one via
/// [`Snapshot::parse`] implies every checksum passed; fingerprint
/// enforcement is the caller's last step ([`Snapshot::check_fingerprint`])
/// because only the caller knows its expected configuration.
pub struct Snapshot {
    /// Config fingerprint recorded at write time.
    pub fingerprint: u64,
    /// WAL sequence fence recorded at write time.
    pub watermark: u64,
    /// Verified sections, in file order.
    pub sections: Vec<SnapshotSection>,
}

impl Snapshot {
    /// Parse and fully verify a container. Any mismatch — length,
    /// whole-file CRC, magic, version, table bounds, section CRC —
    /// rejects the entire file with a typed error; no partially-trusted
    /// state escapes.
    pub fn parse(bytes: &[u8]) -> Result<Snapshot, PersistError> {
        let min = 4 + 4 + 8 + 8 + 4 + 4;
        if bytes.len() < min {
            return Err(PersistError::Corrupt {
                what: "snapshot container",
                detail: format!("{} bytes is below the {min}-byte minimum", bytes.len()),
            });
        }
        let body = &bytes[..bytes.len() - 4];
        let footer_bytes = &bytes[bytes.len() - 4..];
        let footer =
            u32::from_le_bytes([footer_bytes[0], footer_bytes[1], footer_bytes[2], footer_bytes[3]]);
        let actual = crc32(body);
        if actual != footer {
            return Err(PersistError::Corrupt {
                what: "snapshot container",
                detail: format!("whole-file crc {actual:#010x} != footer {footer:#010x}"),
            });
        }
        let mut dec = Dec::new(body);
        let magic = dec.get_bytes(4)?;
        if magic != MAGIC {
            return Err(PersistError::Corrupt {
                what: "snapshot container",
                detail: format!("bad magic {magic:?}"),
            });
        }
        let version = dec.get_u32()?;
        if version != FORMAT_VERSION {
            return Err(PersistError::VersionMismatch { found: version, expected: FORMAT_VERSION });
        }
        let fingerprint = dec.get_u64()?;
        let watermark = dec.get_u64()?;
        let n_sections = dec.get_u32()? as usize;
        let header_len = 4 + 4 + 8 + 8 + 4 + n_sections.saturating_mul(24);
        if body.len() < header_len {
            return Err(PersistError::Corrupt {
                what: "snapshot table",
                detail: format!("{n_sections} sections overflow the {}-byte body", body.len()),
            });
        }
        let mut sections = Vec::with_capacity(n_sections);
        for _ in 0..n_sections {
            let kind = dec.get_u32()?;
            let offset = dec.get_u64()?;
            let len = dec.get_u64()?;
            let crc = dec.get_u32()?;
            let end = offset.checked_add(len).ok_or_else(|| PersistError::Corrupt {
                what: "snapshot table",
                detail: format!(
                    "{} section (kind {kind}) at offset {offset}: range overflows",
                    section_name(kind)
                ),
            })?;
            if offset < header_len as u64 || end > body.len() as u64 {
                return Err(PersistError::Corrupt {
                    what: "snapshot table",
                    detail: format!(
                        "{} section (kind {kind}) [{offset}, {end}) outside payload area [{header_len}, {})",
                        section_name(kind),
                        body.len()
                    ),
                });
            }
            let payload = &body[offset as usize..end as usize];
            let actual = crc32(payload);
            if actual != crc {
                return Err(PersistError::Corrupt {
                    what: "snapshot section",
                    detail: format!(
                        "{} section (kind {kind}) at offset {offset}: crc {actual:#010x} != recorded {crc:#010x}",
                        section_name(kind)
                    ),
                });
            }
            sections.push(SnapshotSection { kind, payload: payload.to_vec() });
        }
        Ok(Snapshot { fingerprint, watermark, sections })
    }

    /// Enforce the config fence: the snapshot must have been written
    /// under exactly the caller's result-affecting configuration.
    pub fn check_fingerprint(&self, expected: u64) -> Result<(), PersistError> {
        if self.fingerprint != expected {
            return Err(PersistError::FingerprintMismatch {
                found: self.fingerprint,
                expected,
            });
        }
        Ok(())
    }

    /// The first section of `kind`, if present.
    pub fn section(&self, kind: u32) -> Option<&[u8]> {
        self.sections
            .iter()
            .find(|s| s.kind == kind)
            .map(|s| s.payload.as_slice())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Vec<u8> {
        let mut w = SnapshotWriter::new(0xFEED_F00D_CAFE_BABE, 42);
        w.section(SEC_INDEX, vec![1, 2, 3, 4, 5]);
        w.section(SEC_PARTITION, vec![9, 8, 7]);
        w.finish()
    }

    #[test]
    fn round_trip_preserves_sections_and_fences() {
        let bytes = sample();
        let snap = Snapshot::parse(&bytes).unwrap();
        assert_eq!(snap.fingerprint, 0xFEED_F00D_CAFE_BABE);
        assert_eq!(snap.watermark, 42);
        assert_eq!(snap.section(SEC_INDEX), Some(&[1u8, 2, 3, 4, 5][..]));
        assert_eq!(snap.section(SEC_PARTITION), Some(&[9u8, 8, 7][..]));
        assert_eq!(snap.section(99), None);
        snap.check_fingerprint(0xFEED_F00D_CAFE_BABE).unwrap();
        assert!(matches!(
            snap.check_fingerprint(1),
            Err(PersistError::FingerprintMismatch { .. })
        ));
    }

    #[test]
    fn every_single_byte_flip_is_rejected() {
        let bytes = sample();
        for i in 0..bytes.len() {
            let mut mutated = bytes.clone();
            mutated[i] ^= 0x01;
            assert!(
                Snapshot::parse(&mutated).is_err(),
                "flip at byte {i} parsed successfully"
            );
        }
    }

    #[test]
    fn every_truncation_is_rejected() {
        let bytes = sample();
        for end in 0..bytes.len() {
            assert!(
                Snapshot::parse(&bytes[..end]).is_err(),
                "truncation to {end} bytes parsed successfully"
            );
        }
    }

    #[test]
    fn stale_version_is_a_typed_mismatch() {
        let mut bytes = sample();
        // version field sits right after the 4-byte magic; bump it and
        // re-seal the footer so only the version check can fire
        bytes[4] = bytes[4].wrapping_add(1);
        let body_len = bytes.len() - 4;
        let crc = crc32(&bytes[..body_len]).to_le_bytes();
        bytes[body_len..].copy_from_slice(&crc);
        assert!(matches!(
            Snapshot::parse(&bytes),
            Err(PersistError::VersionMismatch { found, expected: FORMAT_VERSION })
                if found == FORMAT_VERSION + 1
        ));
    }

    #[test]
    fn section_corruption_names_the_section_and_offset() {
        let mut bytes = sample();
        // the index payload starts right after the header + table
        // (2 sections × 24 bytes); corrupt its first byte and re-seal
        // the footer so only the per-section crc check can fire
        let header_len = 4 + 4 + 8 + 8 + 4 + 2 * 24;
        bytes[header_len] ^= 0x01;
        let body_len = bytes.len() - 4;
        let crc = crc32(&bytes[..body_len]).to_le_bytes();
        bytes[body_len..].copy_from_slice(&crc);
        let err = Snapshot::parse(&bytes).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("index section"), "section name missing: {msg}");
        assert!(msg.contains(&format!("offset {header_len}")), "offset missing: {msg}");
    }

    #[test]
    fn section_names_cover_known_kinds() {
        assert_eq!(section_name(SEC_INDEX), "index");
        assert_eq!(section_name(SEC_PARTITION), "partition");
        assert_eq!(section_name(77), "unknown");
    }

    #[test]
    fn empty_container_parses() {
        let bytes = SnapshotWriter::new(7, 0).finish();
        let snap = Snapshot::parse(&bytes).unwrap();
        assert_eq!(snap.fingerprint, 7);
        assert_eq!(snap.watermark, 0);
        assert!(snap.sections.is_empty());
    }
}
