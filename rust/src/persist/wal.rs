//! The durable insert write-ahead log: length-prefixed, checksummed,
//! sequence-fenced records, appended **before** the in-memory insert
//! broadcast so no acknowledged insert can be lost to a crash.
//!
//! Record wire format (little-endian):
//!
//! ```text
//!   len  u32   payload bytes
//!   seq  u64   contiguous, starting at 1
//!   crc  u32   crc32(payload)
//!   payload    count u32, then count × (x f32, y f32, z f32)
//! ```
//!
//! [`Wal::open`] replays the file front to back and stops at the first
//! record that is short, checksum-broken, or out of sequence — the
//! **torn tail** a crash mid-append leaves behind — and truncates the
//! file there, so the log is always well-formed after open. Everything
//! past a tear is unrecoverable by construction (later appends landed
//! behind a hole) and is deliberately dropped rather than guessed at.
//!
//! Group commit: `group_commit = n` fsyncs every `n`-th append
//! (`1` = every append, the durable default). The window between
//! appends and the next fsync is the only data a power loss may take;
//! a process crash loses nothing (the OS holds the written bytes).

use super::codec::{Dec, Enc};
use super::{crc32, io_err, PersistError};
use crate::faults::{FaultPlan, IoTarget};
use crate::geom::Point3;
use std::fs::{File, OpenOptions};
use std::io::Write;
use std::path::Path;

/// Per-record header bytes: len u32 + seq u64 + crc u32.
const HEADER: usize = 16;

/// One replayed WAL record: the insert batch and its sequence number.
pub struct WalRecord {
    /// Contiguous record sequence number, starting at 1.
    pub seq: u64,
    /// The insert batch exactly as accepted.
    pub points: Vec<Point3>,
}

/// An open write-ahead log: append-only handle plus the group-commit
/// bookkeeping. Construct with [`Wal::open`], which also replays and
/// repairs the existing file.
pub struct Wal {
    file: File,
    next_seq: u64,
    /// Appends since the last fsync.
    pending: u64,
    group_commit: u64,
    /// 1-based append counter, the `op` coordinate of torn-write faults.
    write_ops: u64,
    faults: FaultPlan,
}

impl Wal {
    /// Open (or create) the log at `path`, replay every intact record,
    /// truncate any torn tail, and return the handle plus the replayed
    /// records in sequence order. A scheduled short-read fault makes
    /// the tail *appear* torn — the truncation then makes the loss
    /// real, which is exactly the conservative behavior the recovery
    /// contract wants (never serve from bytes that failed validation).
    pub fn open(
        path: &Path,
        group_commit: u64,
        faults: FaultPlan,
    ) -> Result<(Wal, Vec<WalRecord>), PersistError> {
        let mut bytes = match std::fs::read(path) {
            Ok(b) => b,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Vec::new(),
            Err(e) => return Err(io_err("read", e)),
        };
        if let Some(keep) = faults.short_read(IoTarget::Wal) {
            bytes.truncate(keep);
        }
        let (records, valid_end) = replay(&bytes);
        let file = OpenOptions::new()
            .read(true)
            .append(true)
            .create(true)
            .open(path)
            .map_err(|e| io_err("open", e))?;
        let disk_len = file.metadata().map_err(|e| io_err("metadata", e))?.len();
        if (valid_end as u64) < disk_len {
            file.set_len(valid_end as u64).map_err(|e| io_err("set_len", e))?;
            file.sync_all().map_err(|e| io_err("sync", e))?;
        }
        let next_seq = records.last().map_or(1, |r| r.seq + 1);
        let wal = Wal {
            file,
            next_seq,
            pending: 0,
            group_commit: group_commit.max(1),
            write_ops: 0,
            faults,
        };
        Ok((wal, records))
    }

    /// Append one insert batch; returns its sequence number. The write
    /// hits the OS before this returns; it hits the *disk* by the next
    /// group-commit fsync (immediately when `group_commit == 1`).
    /// Scheduled WAL faults corrupt the record bytes here — a torn
    /// write at this op persists only a prefix, so the tail of the log
    /// (this record and anything appended after it) is lost at the next
    /// open.
    pub fn append(&mut self, points: &[Point3]) -> Result<u64, PersistError> {
        let seq = self.next_seq;
        let mut payload = Enc::new();
        payload.put_u32(points.len() as u32);
        for p in points {
            payload.put_f32(p.x);
            payload.put_f32(p.y);
            payload.put_f32(p.z);
        }
        let payload = payload.into_bytes();
        let mut rec = Enc::new();
        rec.put_u32(payload.len() as u32);
        rec.put_u64(seq);
        rec.put_u32(crc32(&payload));
        rec.put_bytes(&payload);
        let mut bytes = rec.into_bytes();
        self.write_ops += 1;
        if let Some(at) = self.faults.flip_byte(IoTarget::Wal) {
            if !bytes.is_empty() {
                let i = at % bytes.len();
                bytes[i] ^= 0x01;
            }
        }
        if let Some(keep) = self.faults.torn_write(IoTarget::Wal, self.write_ops) {
            bytes.truncate(keep);
        }
        self.file.write_all(&bytes).map_err(|e| io_err("write", e))?;
        self.next_seq += 1;
        self.pending += 1;
        if self.pending >= self.group_commit {
            self.sync()?;
        }
        Ok(seq)
    }

    /// Fsync any appends still in the group-commit window (no-op when
    /// none are pending).
    pub fn sync(&mut self) -> Result<(), PersistError> {
        if self.pending > 0 {
            self.file.sync_all().map_err(|e| io_err("sync", e))?;
            self.pending = 0;
        }
        Ok(())
    }

    /// The sequence number the next append will carry.
    pub fn next_seq(&self) -> u64 {
        self.next_seq
    }

    /// Records durably accepted so far (`next_seq - 1`).
    pub fn record_count(&self) -> u64 {
        self.next_seq - 1
    }
}

/// Scan `bytes` front to back, yielding every intact record and the
/// byte offset where the intact prefix ends (the truncation point for
/// a torn tail).
fn replay(bytes: &[u8]) -> (Vec<WalRecord>, usize) {
    let mut records = Vec::new();
    let mut pos = 0usize;
    let mut expected_seq = 1u64;
    while bytes.len() - pos >= HEADER {
        let mut dec = Dec::new(&bytes[pos..]);
        // header reads cannot fail: HEADER bytes were checked above
        let (Ok(len), Ok(seq), Ok(crc)) = (dec.get_u32(), dec.get_u64(), dec.get_u32()) else {
            break;
        };
        let len = len as usize;
        let Some(end) = pos.checked_add(HEADER).and_then(|s| s.checked_add(len)) else {
            break;
        };
        if end > bytes.len() {
            break; // short record: torn tail
        }
        let payload = &bytes[pos + HEADER..end];
        if crc32(payload) != crc || seq != expected_seq {
            break; // corrupt or out-of-sequence: torn tail
        }
        let Ok(points) = decode_points(payload) else {
            break;
        };
        records.push(WalRecord { seq, points });
        pos = end;
        expected_seq += 1;
    }
    (records, pos)
}

/// Decode one record payload: count-prefixed point triples, with the
/// count cross-checked against the payload length.
fn decode_points(payload: &[u8]) -> Result<Vec<Point3>, PersistError> {
    let mut dec = Dec::new(payload);
    let count = dec.get_u32()? as usize;
    if payload.len() != 4 + count * 12 {
        return Err(PersistError::Corrupt {
            what: "wal record",
            detail: format!("count {count} does not match {} payload bytes", payload.len()),
        });
    }
    let mut points = Vec::with_capacity(count);
    for _ in 0..count {
        let x = dec.get_f32()?;
        let y = dec.get_f32()?;
        let z = dec.get_f32()?;
        points.push(Point3::new(x, y, z));
    }
    Ok(points)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;
    use std::sync::atomic::{AtomicU64, Ordering};

    fn temp_wal(tag: &str) -> PathBuf {
        static N: AtomicU64 = AtomicU64::new(0);
        let dir = std::env::temp_dir().join(format!(
            "trueknn-wal-unit-{}-{}-{}",
            std::process::id(),
            tag,
            N.fetch_add(1, Ordering::SeqCst)
        ));
        std::fs::create_dir_all(&dir).unwrap();
        dir.join("wal.log")
    }

    fn pts(vals: &[f32]) -> Vec<Point3> {
        vals.iter().map(|&v| Point3::new(v, v + 0.5, -v)).collect()
    }

    #[test]
    fn append_then_reopen_replays_in_order() {
        let path = temp_wal("roundtrip");
        let (mut wal, initial) = Wal::open(&path, 1, FaultPlan::inert()).unwrap();
        assert!(initial.is_empty());
        assert_eq!(wal.append(&pts(&[1.0])).unwrap(), 1);
        assert_eq!(wal.append(&pts(&[2.0, 3.0])).unwrap(), 2);
        assert_eq!(wal.record_count(), 2);
        drop(wal);
        let (wal, records) = Wal::open(&path, 1, FaultPlan::inert()).unwrap();
        assert_eq!(records.len(), 2);
        assert_eq!(records[0].seq, 1);
        assert_eq!(records[0].points, pts(&[1.0]));
        assert_eq!(records[1].seq, 2);
        assert_eq!(records[1].points, pts(&[2.0, 3.0]));
        assert_eq!(wal.next_seq(), 3);
        let _ = std::fs::remove_dir_all(path.parent().unwrap());
    }

    #[test]
    fn every_truncation_of_the_last_record_recovers_the_exact_prefix() {
        let path = temp_wal("truncate");
        let (mut wal, _) = Wal::open(&path, 1, FaultPlan::inert()).unwrap();
        wal.append(&pts(&[1.0])).unwrap();
        wal.append(&pts(&[2.0])).unwrap();
        drop(wal);
        let full = std::fs::read(&path).unwrap();
        let first_len = full.len() / 2; // two identical-shape records
        for cut in first_len..full.len() {
            std::fs::write(&path, &full[..cut]).unwrap();
            let (wal, records) = Wal::open(&path, 1, FaultPlan::inert()).unwrap();
            assert_eq!(records.len(), 1, "cut at {cut} must keep exactly record 1");
            assert_eq!(records[0].points, pts(&[1.0]));
            assert_eq!(wal.next_seq(), 2);
            drop(wal);
            // the torn tail was physically truncated
            assert_eq!(std::fs::read(&path).unwrap().len(), first_len, "cut at {cut}");
        }
        let _ = std::fs::remove_dir_all(path.parent().unwrap());
    }

    #[test]
    fn appends_resume_after_a_tail_repair() {
        let path = temp_wal("resume");
        let (mut wal, _) = Wal::open(&path, 1, FaultPlan::inert()).unwrap();
        wal.append(&pts(&[1.0])).unwrap();
        wal.append(&pts(&[2.0])).unwrap();
        drop(wal);
        let full = std::fs::read(&path).unwrap();
        std::fs::write(&path, &full[..full.len() - 3]).unwrap();
        let (mut wal, records) = Wal::open(&path, 1, FaultPlan::inert()).unwrap();
        assert_eq!(records.len(), 1);
        assert_eq!(wal.append(&pts(&[9.0])).unwrap(), 2, "seq continues past the repair");
        drop(wal);
        let (_, records) = Wal::open(&path, 1, FaultPlan::inert()).unwrap();
        assert_eq!(records.len(), 2);
        assert_eq!(records[1].points, pts(&[9.0]));
        let _ = std::fs::remove_dir_all(path.parent().unwrap());
    }

    #[test]
    fn torn_and_flipped_appends_are_lost_on_reopen() {
        // torn write on the 2nd append: record 2 never survives a reopen
        let path = temp_wal("torn");
        let plan = FaultPlan::inert().with_torn_write(IoTarget::Wal, 2, 7);
        let (mut wal, _) = Wal::open(&path, 1, plan).unwrap();
        wal.append(&pts(&[1.0])).unwrap();
        wal.append(&pts(&[2.0])).unwrap(); // torn on disk
        drop(wal);
        let (_, records) = Wal::open(&path, 1, FaultPlan::inert()).unwrap();
        assert_eq!(records.len(), 1);
        assert_eq!(records[0].points, pts(&[1.0]));

        // flipped byte: every record is corrupt, nothing replays
        let path = temp_wal("flip");
        let plan = FaultPlan::inert().with_flip_byte(IoTarget::Wal, 20);
        let (mut wal, _) = Wal::open(&path, 1, plan).unwrap();
        wal.append(&pts(&[1.0])).unwrap();
        drop(wal);
        let (_, records) = Wal::open(&path, 1, FaultPlan::inert()).unwrap();
        assert!(records.is_empty());
        let _ = std::fs::remove_dir_all(path.parent().unwrap());
    }

    #[test]
    fn group_commit_defers_the_sync_not_the_write() {
        let path = temp_wal("group");
        let (mut wal, _) = Wal::open(&path, 8, FaultPlan::inert()).unwrap();
        for i in 0..5 {
            wal.append(&pts(&[i as f32])).unwrap();
        }
        // a process crash (handle drop without sync) loses nothing: the
        // bytes are in the OS already
        drop(wal);
        let (mut wal, records) = Wal::open(&path, 8, FaultPlan::inert()).unwrap();
        assert_eq!(records.len(), 5);
        wal.sync().unwrap();
        let _ = std::fs::remove_dir_all(path.parent().unwrap());
    }
}
