//! Simulated-GPU-time model over `HwCounters`.
//!
//! The unit costs are calibrated to the qualitative regime of the RTX
//! 2060 testbed the paper used (§5.2, §6.2.1):
//! - a hardware ray-AABB test is the cheapest event;
//! - a software ray-sphere `Intersection` program invocation costs a few
//!   times more (it leaves the RT core for the SM);
//! - maintaining the k-nearest list costs per heap operation — the
//!   "sorting time" of §3.4;
//! - a BVH *refit* is 20% cheaper per primitive than a *build*, matching
//!   the paper's measured 10–25% (§4);
//! - a host↔device context switch is microseconds — irrelevant for big
//!   rounds, dominant when a round queries 3 points (§6.2.1 / Fig 9).
//!
//! Absolute values are not the claim (see DESIGN.md §7); every experiment
//! reports simulated time and wall-clock side by side.

use super::HwCounters;

#[derive(Clone, Copy, Debug)]
pub struct CostModel {
    /// Seconds per hardware ray-AABB test.
    pub c_aabb: f64,
    /// Seconds per software ray-sphere test.
    pub c_prim: f64,
    /// Seconds per k-heap push (candidate sorting).
    pub c_heap: f64,
    /// Seconds per primitive at BVH build.
    pub c_build: f64,
    /// Seconds per node at BVH refit.
    pub c_refit: f64,
    /// Seconds per host↔device context switch.
    pub c_switch: f64,
    /// Fixed per-launch overhead (kernel dispatch).
    pub c_launch: f64,
}

impl Default for CostModel {
    fn default() -> Self {
        Self {
            c_aabb: 0.4e-9,
            c_prim: 2.0e-9,
            c_heap: 4.0e-9,
            c_build: 25.0e-9,
            // A BVH over n prims with leaf size 4 has ~n/2 nodes, so a
            // whole-tree refit costs ~0.8× a build — inside the paper's
            // measured "refit is 10–25% faster than rebuild" band (§4).
            // (On this CPU substrate the *wall-clock* refit is ~30×
            // cheaper; the model pins the GPU ratio the paper reports.)
            c_refit: 40.0e-9,
            c_switch: 30.0e-6,
            c_launch: 10.0e-6,
        }
    }
}

impl CostModel {
    /// Simulated seconds for a counter block; `launches` = number of
    /// optixLaunch invocations the block spans.
    pub fn seconds(&self, c: &HwCounters, launches: u64) -> f64 {
        self.c_aabb * c.aabb_tests as f64
            + self.c_prim * c.prim_tests as f64
            + self.c_heap * c.heap_pushes as f64
            + self.c_build * c.build_prims as f64
            + self.c_refit * c.refit_nodes as f64
            + self.c_switch * c.context_switches as f64
            + self.c_launch * launches as f64
    }

    /// Cost of one full BVH build over `n` primitives vs one refit of the
    /// same tree — used by the A1 ablation (refit 10–25% cheaper).
    pub fn build_cost(&self, prims: u64) -> f64 {
        self.c_build * prims as f64
    }

    pub fn refit_cost(&self, nodes: u64) -> f64 {
        self.c_refit * nodes as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn costs_are_additive() {
        let m = CostModel::default();
        let a = HwCounters {
            prim_tests: 1_000,
            ..Default::default()
        };
        let b = HwCounters {
            aabb_tests: 1_000,
            ..Default::default()
        };
        let mut ab = a;
        ab.add(&b);
        let sum = m.seconds(&a, 1) + m.seconds(&b, 1);
        assert!((m.seconds(&ab, 2) - sum).abs() < 1e-15);
    }

    #[test]
    fn software_tests_cost_more_than_hardware() {
        let m = CostModel::default();
        assert!(m.c_prim > m.c_aabb);
    }

    #[test]
    fn refit_is_10_to_25_pct_cheaper_than_build() {
        let m = CostModel::default();
        // a BVH over n prims with leaf_size 4 has ~2·(n/4) ≈ n/2 nodes;
        // the simulated refit/rebuild ratio must land in the paper's
        // measured band (refit 10–25% faster, i.e. ratio 0.75–0.90).
        let n = 100_000u64;
        let nodes = 2 * n / 4;
        let ratio = m.refit_cost(nodes) / m.build_cost(n);
        assert!((0.72..=0.92).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn context_switch_dominates_tiny_rounds() {
        let m = CostModel::default();
        // a round testing 3 rays against a handful of prims…
        let tiny = HwCounters {
            rays: 3,
            aabb_tests: 60,
            prim_tests: 40,
            context_switches: 2,
            ..Default::default()
        };
        let work = m.c_aabb * 60.0 + m.c_prim * 40.0;
        let overhead = m.c_switch * 2.0 + m.c_launch;
        assert!(overhead > 100.0 * work, "switch must dominate tiny rounds");
        assert!(m.seconds(&tiny, 1) > overhead);
    }
}
