//! Hardware event counters — the simulator's ground truth.
//!
//! Table 2 of the paper compares ray-sphere ("ray-object") intersection
//! test counts; §5.3.1 notes ray-AABB tests happen in hardware and are
//! unobservable on the real GPU. Our simulator observes both.

#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct HwCounters {
    /// Rays launched (one per active query point per round).
    pub rays: u64,
    /// Ray-AABB containment tests (the RT core's hardware unit).
    pub aabb_tests: u64,
    /// Ray-sphere tests (the software `Intersection` program).
    pub prim_tests: u64,
    /// Sphere hits recorded (neighbor candidates found).
    pub hits: u64,
    /// Bounded-heap insertions — the paper's "sorting time" proxy.
    pub heap_pushes: u64,
    /// BVH full builds, and primitives touched by them.
    pub builds: u64,
    pub build_prims: u64,
    /// BVH refits, and nodes touched by them.
    pub refits: u64,
    pub refit_nodes: u64,
    /// Host↔device context switches (§6.2.1: two per round — device→host
    /// to grow the boxes, host→device to relaunch RayGen).
    pub context_switches: u64,
}

impl HwCounters {
    pub fn new() -> Self {
        Self::default()
    }

    /// Accumulate another counter block (used to sum per-round counters).
    pub fn add(&mut self, o: &HwCounters) {
        self.rays += o.rays;
        self.aabb_tests += o.aabb_tests;
        self.prim_tests += o.prim_tests;
        self.hits += o.hits;
        self.heap_pushes += o.heap_pushes;
        self.builds += o.builds;
        self.build_prims += o.build_prims;
        self.refits += o.refits;
        self.refit_nodes += o.refit_nodes;
        self.context_switches += o.context_switches;
    }

    /// Serialize all ten counters (fixed field order) for a crash-safe
    /// snapshot — counters are part of the determinism contract, so a
    /// loaded index must report the build it didn't have to redo.
    pub fn encode_into(&self, enc: &mut crate::persist::Enc) {
        enc.put_u64(self.rays);
        enc.put_u64(self.aabb_tests);
        enc.put_u64(self.prim_tests);
        enc.put_u64(self.hits);
        enc.put_u64(self.heap_pushes);
        enc.put_u64(self.builds);
        enc.put_u64(self.build_prims);
        enc.put_u64(self.refits);
        enc.put_u64(self.refit_nodes);
        enc.put_u64(self.context_switches);
    }

    /// Decode counters written by [`HwCounters::encode_into`].
    pub fn decode_from(
        dec: &mut crate::persist::Dec<'_>,
    ) -> Result<HwCounters, crate::persist::PersistError> {
        Ok(HwCounters {
            rays: dec.get_u64()?,
            aabb_tests: dec.get_u64()?,
            prim_tests: dec.get_u64()?,
            hits: dec.get_u64()?,
            heap_pushes: dec.get_u64()?,
            builds: dec.get_u64()?,
            build_prims: dec.get_u64()?,
            refits: dec.get_u64()?,
            refit_nodes: dec.get_u64()?,
            context_switches: dec.get_u64()?,
        })
    }

    /// Field-wise difference against an earlier snapshot of the same
    /// accumulator (used for per-round telemetry deltas).
    pub fn delta(&self, before: &HwCounters) -> HwCounters {
        HwCounters {
            rays: self.rays - before.rays,
            aabb_tests: self.aabb_tests - before.aabb_tests,
            prim_tests: self.prim_tests - before.prim_tests,
            hits: self.hits - before.hits,
            heap_pushes: self.heap_pushes - before.heap_pushes,
            builds: self.builds - before.builds,
            build_prims: self.build_prims - before.build_prims,
            refits: self.refits - before.refits,
            refit_nodes: self.refit_nodes - before.refit_nodes,
            context_switches: self.context_switches - before.context_switches,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_accumulates_all_fields() {
        let mut a = HwCounters {
            rays: 1,
            aabb_tests: 2,
            prim_tests: 3,
            hits: 4,
            heap_pushes: 5,
            builds: 6,
            build_prims: 7,
            refits: 8,
            refit_nodes: 9,
            context_switches: 10,
        };
        let b = a;
        a.add(&b);
        assert_eq!(a.rays, 2);
        assert_eq!(a.aabb_tests, 4);
        assert_eq!(a.prim_tests, 6);
        assert_eq!(a.context_switches, 20);
    }
}
