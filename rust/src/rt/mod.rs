//! The simulated RT core + OptiX pipeline (paper §2.2).
//!
//! The paper runs on an RTX 2060: the Bounding Volume Hierarchy is
//! traversed and ray-AABB tests evaluated in *hardware* (RT cores), while
//! the ray-sphere test runs as a *software* OptiX `Intersection` program
//! on the shader cores. We have no RT hardware, so this module is a
//! faithful functional simulator of that pipeline with an explicit cost
//! model:
//!
//! - `Scene` owns the sphere primitives and their BVH, supporting the
//!   OptiX `build` and `refit` operations;
//! - `Pipeline::launch` plays the role of `optixLaunch`: it runs RayGen
//!   over a query batch, traverses the BVH per ray and invokes the
//!   user's `IntersectionProgram` on candidate primitives;
//! - `HwCounters` tallies every event class the paper reasons about
//!   (ray-AABB tests, ray-sphere tests, BVH node visits, builds, refits,
//!   host↔device context switches);
//! - `CostModel` converts those tallies into *simulated GPU time* so
//!   experiments can report the paper's metrics alongside wall-clock.
//!
//! See DESIGN.md §2 for why this substitution preserves the paper's
//! claims (they are framed in exactly these event counts).

mod counters;
mod cost;
mod scene;
mod pipeline;

pub use counters::HwCounters;
pub use cost::CostModel;
pub use pipeline::{CollectHits, CollectHitsShard, IntersectionProgram, Pipeline, ShardableProgram};
pub use scene::Scene;
