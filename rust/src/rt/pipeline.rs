//! `optixLaunch` equivalent: run a batch of rays through the scene's BVH
//! and invoke the user's software `Intersection` program on hits.
//!
//! The paper's kNN rays are point-like (origin = query point, length
//! FLOAT_MIN, §2.3), so the hardware ray-AABB test degenerates to a
//! point-in-box test, and the software ray-sphere test to a point-in-
//! sphere test. Both are counted per invocation.
//!
//! §Perf notes: the traversal loop is the simulator's hot path (billions
//! of events per baseline run). It reads sphere centers from the scene's
//! *leaf-ordered* copy (contiguous within a leaf), reuses one traversal
//! stack across all rays of a launch, computes the squared distance once
//! and passes it to the program, and only touches the primitive-id
//! remapping table on an actual hit.

use super::{HwCounters, Scene};
use crate::geom::{dist2, Ray};

/// The user's software intersection program (OptiX `Intersection`). The
/// paper implements the whole kNN logic here, with AnyHit/ClosestHit
/// disabled for speed (§4) — we mirror that structure. `hit` fires once
/// per ray-sphere test that succeeds (origin inside the sphere).
pub trait IntersectionProgram {
    fn hit(&mut self, ray: &Ray, prim: u32, dist2: f32);
}

/// Stateless launcher; all state lives in the scene and the program.
pub struct Pipeline;

impl Pipeline {
    /// Launch `rays` against `scene`. Per ray: traverse the BVH (counting
    /// one hardware AABB test per node visited), then run the software
    /// intersection test on each leaf primitive (counting one software
    /// test each). Results accumulate in `program`.
    pub fn launch<P: IntersectionProgram>(
        scene: &Scene,
        rays: &[Ray],
        program: &mut P,
        counters: &mut HwCounters,
    ) {
        let r2 = scene.radius * scene.radius;
        let nodes = &scene.bvh.nodes;
        let ordered = &scene.ordered_centers;
        let prim_ids = &scene.bvh.prim_order;
        if nodes.is_empty() {
            counters.rays += rays.len() as u64;
            return;
        }
        let root = scene.bvh.root;
        let mut stack: Vec<u32> = Vec::with_capacity(128);

        let mut aabb_tests = 0u64;
        let mut prim_tests = 0u64;
        let mut hits = 0u64;
        for ray in rays {
            counters.rays += 1;
            let origin = ray.origin;
            stack.clear();
            stack.push(root);
            while let Some(idx) = stack.pop() {
                let node = &nodes[idx as usize];
                aabb_tests += 1;
                if !node.aabb.contains(origin) {
                    continue;
                }
                if node.is_leaf() {
                    let first = node.first_prim as usize;
                    let count = node.prim_count as usize;
                    prim_tests += count as u64;
                    for j in first..first + count {
                        let d2 = dist2(ordered[j], origin);
                        if d2 <= r2 {
                            hits += 1;
                            program.hit(ray, prim_ids[j], d2);
                        }
                    }
                } else {
                    stack.push(node.left);
                    stack.push(node.right);
                }
            }
        }
        counters.aabb_tests += aabb_tests;
        counters.prim_tests += prim_tests;
        counters.hits += hits;
    }
}

/// A trivial program that records hit primitive ids — used by tests and
/// by the fixed-radius *range query* public API.
#[derive(Default)]
pub struct CollectHits {
    pub per_query: Vec<Vec<u32>>,
}

impl CollectHits {
    pub fn new(n_queries: usize) -> Self {
        Self {
            per_query: vec![Vec::new(); n_queries],
        }
    }
}

impl IntersectionProgram for CollectHits {
    fn hit(&mut self, ray: &Ray, prim: u32, _dist2: f32) {
        self.per_query[ray.query_id as usize].push(prim);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geom::dist;
    use crate::geom::Point3;
    use crate::util::{prop, Pcg32};

    /// Brute-force oracle: all points within r of q.
    fn oracle(pts: &[Point3], q: Point3, r: f32) -> Vec<u32> {
        let mut v: Vec<u32> = (0..pts.len() as u32)
            .filter(|&i| dist(pts[i as usize], q) <= r)
            .collect();
        v.sort_unstable();
        v
    }

    #[test]
    fn launch_matches_brute_force_oracle() {
        prop::check("pipeline ≡ brute force range query", 25, |rng| {
            let n = 16 + rng.below(300) as usize;
            let dims2 = rng.f32() < 0.3;
            let pts = prop::random_cloud(rng, n, dims2);
            let r = 0.02 + rng.f32() * 0.2;
            let mut counters = HwCounters::new();
            let scene = Scene::build(pts.clone(), r, &mut counters);
            let n_q = 10.min(n);
            let rays: Vec<Ray> = (0..n_q)
                .map(|i| Ray::knn(pts[i * (n / n_q)], i as u32))
                .collect();
            let mut prog = CollectHits::new(n_q);
            Pipeline::launch(&scene, &rays, &mut prog, &mut counters);
            for (qi, ray) in rays.iter().enumerate() {
                let mut got = prog.per_query[qi].clone();
                got.sort_unstable();
                let want = oracle(&pts, ray.origin, r);
                if got != want {
                    return Err(format!("query {qi}: got {got:?} want {want:?}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn counters_scale_with_radius() {
        let mut rng = Pcg32::new(6);
        let pts = prop::random_cloud(&mut rng, 1_000, false);
        let rays: Vec<Ray> = pts
            .iter()
            .enumerate()
            .map(|(i, &p)| Ray::knn(p, i as u32))
            .collect();

        let run = |r: f32| {
            let mut c = HwCounters::new();
            let scene = Scene::build(pts.clone(), r, &mut c);
            let mut prog = CollectHits::new(pts.len());
            Pipeline::launch(&scene, &rays, &mut prog, &mut c);
            c
        };
        let small = run(0.01);
        let large = run(0.5);
        assert!(
            large.prim_tests > 10 * small.prim_tests,
            "large radius must blow up software tests: {} vs {}",
            large.prim_tests,
            small.prim_tests
        );
        assert!(large.hits > small.hits);
        assert_eq!(small.rays, 1_000);
    }

    #[test]
    fn every_ray_hits_its_own_sphere() {
        // each data point's own sphere always contains it (dist 0)
        let mut rng = Pcg32::new(7);
        let pts = prop::random_cloud(&mut rng, 200, false);
        let mut c = HwCounters::new();
        let scene = Scene::build(pts.clone(), 1e-6, &mut c);
        let rays: Vec<Ray> = pts
            .iter()
            .enumerate()
            .map(|(i, &p)| Ray::knn(p, i as u32))
            .collect();
        let mut prog = CollectHits::new(pts.len());
        Pipeline::launch(&scene, &rays, &mut prog, &mut c);
        for (i, hits) in prog.per_query.iter().enumerate() {
            assert!(
                hits.contains(&(i as u32)),
                "ray {i} must intersect its own sphere"
            );
        }
    }

    #[test]
    fn empty_scene_launch_is_safe() {
        let mut c = HwCounters::new();
        let scene = Scene::build(Vec::new(), 0.1, &mut c);
        let rays = vec![Ray::knn(Point3::ZERO, 0)];
        let mut prog = CollectHits::new(1);
        Pipeline::launch(&scene, &rays, &mut prog, &mut c);
        assert_eq!(c.rays, 1);
        assert_eq!(c.prim_tests, 0);
        assert!(prog.per_query[0].is_empty());
    }
}
